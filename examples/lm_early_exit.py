"""DART on a language model: train a small multi-exit LM, then decode with
REAL per-token layer skipping + CALM state propagation (DESIGN.md §3),
through the ``repro.engine`` LM decode engine.

Run:  PYTHONPATH=src python examples/lm_early_exit.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.routing import DartParams
from repro.data.datasets import DatasetConfig, make_batch
from repro.engine import LMDecodeEngine
from repro.models.transformer_lm import LMConfig
from repro.runtime.trainer import Trainer, TrainConfig

DATA = DatasetConfig(name="tokens", n_train=2048)
CFG = LMConfig(name="lm-demo", n_layers=6, d_model=64, n_heads=4,
               n_kv_heads=2, d_ff=128, vocab=64, exit_layers=(1, 3),
               max_seq=64, remat=False)


def main():
    print("training 6-layer LM with exits at layers 1 and 3 ...")
    tr = Trainer(CFG, TrainConfig(batch_size=16, steps=400, lr=5e-3,
                                  log_every=30), DATA, data_kind="tokens")
    tr.run()
    print("loss:", [round(h["loss"], 3) for h in tr.history])

    dart = DartParams(tau=jnp.asarray([0.35, 0.4]), coef=jnp.ones(2),
                      beta_diff=0.15)
    srv = LMDecodeEngine(CFG, tr.params, dart)

    prompts, _ = make_batch(DATA, range(8), kind="tokens", seq_len=17,
                            vocab=CFG.vocab)
    gen, stages = srv.generate(prompts[:, :9], n_new=16, max_len=64)
    print("\ngenerated shapes:", gen.shape)
    print("exit-stage histogram over generated tokens:",
          np.bincount(stages.ravel(), minlength=3).tolist(),
          "(stage 0 = after layer 1, 1 = after layer 3, 2 = full depth)")
    total = srv.layers_run + srv.layers_skipped
    print(f"layers run {srv.layers_run}, skipped {srv.layers_skipped} "
          f"({100*srv.layers_skipped/max(total,1):.1f}% of full-depth "
          f"compute avoided; skipped layers only pay the KV-projection "
          f"propagation)")

    # token continuation quality check: motif should be continued
    print("\nprompt   :", prompts[0, :9].tolist())
    print("generated:", gen[0].tolist())


if __name__ == "__main__":
    main()
