"""DART on a language model: train a small multi-exit LM, then decode with
REAL per-token layer skipping + CALM state propagation (DESIGN.md §3),
through the queue-backed session handle over the ``repro.engine`` LM
decode engine: concurrent callers submit prompts with deadlines and the
scheduler consolidates them into shared bucketed decode loops — each
consolidated bucket running the SHARDED jit-end-to-end decode step (one
donated-cache compiled program per (stage, bucket); the eager per-stage
oracle is one ``mode="eager"`` away).

Run:  PYTHONPATH=src python examples/lm_early_exit.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.routing import DartParams
from repro.data.datasets import DatasetConfig, make_batch
from repro.engine import LMDecodeEngine
from repro.launch.mesh import make_serving_mesh
from repro.models.transformer_lm import LMConfig
from repro.runtime.trainer import Trainer, TrainConfig

DATA = DatasetConfig(name="tokens", n_train=2048)
CFG = LMConfig(name="lm-demo", n_layers=6, d_model=64, n_heads=4,
               n_kv_heads=2, d_ff=128, vocab=64, exit_layers=(1, 3),
               max_seq=64, remat=False)


def main():
    print("training 6-layer LM with exits at layers 1 and 3 ...")
    tr = Trainer(CFG, TrainConfig(batch_size=16, steps=400, lr=5e-3,
                                  log_every=30), DATA, data_kind="tokens")
    tr.run()
    print("loss:", [round(h["loss"], 3) for h in tr.history])

    dart = DartParams(tau=jnp.asarray([0.35, 0.4]), coef=jnp.ones(2),
                      beta_diff=0.15)
    srv = LMDecodeEngine(CFG, tr.params, dart, mesh=make_serving_mesh())

    prompts, _ = make_batch(DATA, range(8), kind="tokens", seq_len=17,
                            vocab=CFG.vocab)
    # sanity: the fused compiled decode loop is bit-identical to the
    # eager per-stage oracle (tokens AND exit depths)
    chk_s = srv.generate(prompts[:2, :9], n_new=4)
    chk_e = srv.generate(prompts[:2, :9], n_new=4, mode="eager")
    assert all(np.array_equal(a, b) for a, b in zip(chk_s, chk_e))
    # Queue-backed session: 8 concurrent "callers" each submit one
    # prompt; the scheduler lanes them by (prompt_len, n_new) and all
    # eight share ONE bucketed early-exit decode loop.
    session = srv.session()
    futs = [session.submit(prompts[i, :9], n_new=16) for i in range(8)]
    outs = [f.result() for f in futs]
    session.close()
    gen = np.concatenate([o["tokens"] for o in outs])
    stages = np.concatenate([o["stages"] for o in outs])
    sstats = session.stats()
    print(f"\nsession: {sstats['scheduler']['submitted']} callers -> "
          f"{sstats['scheduler']['flush_deadline'] + sstats['scheduler']['flush_size'] + sstats['scheduler']['flush_forced'] + sstats['scheduler']['flush_hold']} "
          f"consolidated decode call(s); p95 latency "
          f"{sstats['requests']['latency_ms']['p95']:.0f} ms")
    print("generated shapes:", gen.shape)
    print("exit-stage histogram over generated tokens:",
          np.bincount(stages.ravel(), minlength=3).tolist(),
          "(stage 0 = after layer 1, 1 = after layer 3, 2 = full depth)")
    total = srv.layers_run + srv.layers_skipped
    print(f"layers run {srv.layers_run}, skipped {srv.layers_skipped} "
          f"({100*srv.layers_skipped/max(total,1):.1f}% of full-depth "
          f"compute avoided; skipped layers only pay the KV-projection "
          f"propagation)")

    # token continuation quality check: motif should be continued
    print("\nprompt   :", prompts[0, :9].tolist())
    print("generated:", gen[0].tolist())


if __name__ == "__main__":
    main()
