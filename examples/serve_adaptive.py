"""Online-adaptation serving demo (paper §II.C) on the engine API.

A DartEngine session handles a request stream whose class mix SHIFTS
midway (deployment drift).  The adaptive manager — sliding-window stats,
temporal EMA (Eq. 13), class-aware updates from pseudo-labels (Eq. 14),
UCB1 strategy selection (Eq. 15) — retunes coefficients online; the
whole serving state (thresholds + window + counters) lives in ONE pytree
(``engine.state``) and is checkpointed atomically mid-stream.

Run:  PYTHONPATH=src python examples/serve_adaptive.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
import dataclasses
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import adaptive as AD
from repro.core.routing import DartParams
from repro.data.datasets import DatasetConfig, make_batch
from repro.engine import DartEngine
from benchmarks.common import train_model

CIFAR = DatasetConfig(name="synth-cifar", n_train=2048, n_eval=4096)


def stream(phase, step, batch=32):
    """Phase 0: easy classes (0-4).  Phase 1: hard classes (5-9)."""
    base = step * batch * 2
    idx = [base + i * 2 + (0 if phase == 0 else 1) * 0 for i in range(batch)]
    idx = [i - (i % 10) + (i % 5) + (5 if phase else 0) for i in idx]
    return make_batch(CIFAR, idx, split="eval")


def main():
    tb = registry.paper_testbeds()
    cfg = dataclasses.replace(tb["alexnet"], channels=(16, 32, 48, 32, 32),
                              fc_dims=(128, 64))
    tr = train_model(cfg, CIFAR, steps=80, batch=32)
    acfg = AD.AdaptiveConfig(n_exits=3, n_classes=10, window=512,
                             ucb_enabled=True)
    engine = DartEngine.from_config(
        cfg, tr.params,
        dart=DartParams(tau=jnp.asarray([0.5, 0.55]), coef=jnp.ones(2),
                        beta_diff=0.3),
        adaptive_cfg=acfg, adapt=True, update_every=64)
    engine.measure_costs((32, 32, 3))
    engine.cum_costs = engine.cum_costs / engine.cum_costs[-1]

    print("phase,step,mean_exit,mean_macs,coef_mean,strategy")
    for phase in (0, 1):
        for step in range(12):
            x, y = stream(phase, step)
            out = engine.infer(x, mode="compacted")
            coef = float(np.mean(np.asarray(
                AD.effective_coef(engine.state.adaptive, acfg))))
            strategy = AD.STRATEGIES[
                int(engine.state.adaptive["active_strategy"])]
            print(f"{phase},{step},{out['exit_idx'].mean():.2f},"
                  f"{out['macs'].mean():.3f},{coef:.4f},{strategy}")
        if phase == 0:
            # checkpoint the FULL serving state mid-stream (one pytree)
            ckdir = tempfile.mkdtemp()
            engine.save_state(ckdir, step=0)
            seen = int(engine.state.adaptive["seen"])
            engine.restore_state(ckdir)
            assert int(engine.state.adaptive["seen"]) == seen
            print(f"# state checkpointed + restored at phase boundary "
                  f"(window seen={seen})")

    stats = engine.stats()
    print("\nexit counts:", stats["exit_counts"].tolist())
    print(f"served {stats['served']} requests, "
          f"mean normalized MACs {stats['mean_macs']:.3f} (static = 1.0)")

    # ------------------------------------------------------------------
    # Sharded serving: the same engine API, jit-end-to-end over a mesh.
    # Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 to see
    # real data parallelism; docs/serving.md explains the paths.
    # ------------------------------------------------------------------
    from repro.launch.mesh import make_serving_mesh

    sharded = DartEngine.from_config(
        cfg, tr.params, mesh=make_serving_mesh(),
        dart=engine.dart_params(coef=np.asarray(engine.state.coef)),
        adaptive_cfg=acfg, adapt=True, update_every=64,
        cum_costs=engine.cum_costs)
    for step in range(8):
        x, _ = stream(1, step)
        out = sharded.infer(x, mode="masked")      # ONE compiled step
    sstats = sharded.stats()
    print(f"\nsharded engine: {sstats['replicas']} replica(s), "
          f"served {sstats['served']} "
          f"(per replica {sstats['served_per_replica'].tolist()}), "
          f"one compiled step/request "
          f"(traces: {sorted(sharded.trace_counts)})")

    # ------------------------------------------------------------------
    # Async serving: callers submit INDIVIDUAL requests with deadlines;
    # the repro.serving scheduler estimates difficulty at admission
    # (Eq. 8), lanes requests by difficulty class, and flushes
    # consolidated buckets on size-or-deadline.  docs/serving.md
    # ("Async serving") explains the lifecycle.
    # ------------------------------------------------------------------
    from repro.serving import AsyncDartServer, SchedulerConfig

    with AsyncDartServer(sharded, SchedulerConfig(
            max_batch=32, flush_ms=10.0)) as server:
        futs = [server.submit(stream(1, s, batch=4)[0],
                              deadline_ms=5000.0,    # demo SLO: compile
                              priority=s % 2)        # time counts too
                for s in range(16)]
        outs = [f.result(timeout=600) for f in futs]
    astats = server.stats()
    sch = astats["scheduler"]
    print(f"async scheduler: {sch['submitted']} requests -> "
          f"{sch['flush_deadline'] + sch['flush_size'] + sch['flush_hold']}"
          f" consolidated flushes "
          f"(per-class exit-depth prior: "
          f"{[None if d is None else round(d, 2) for d in sch['depth_prior']]})")
    lm = astats["requests"]["latency_ms"]
    print(f"  latency p50/p95/p99 = {lm['p50']:.0f}/{lm['p95']:.0f}/"
          f"{lm['p99']:.0f} ms, deadline miss rate "
          f"{100 * astats['requests']['miss_rate']:.0f}%  "
          f"(folded into EngineState -> survives checkpoints)")
    print(f"  mean exit depth served: "
          f"{float(np.mean(np.concatenate([o['exit_idx'] for o in outs]))):.2f}")


if __name__ == "__main__":
    main()
