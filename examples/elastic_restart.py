"""Fault-tolerance walkthrough: crash → restart → identical trajectory,
heartbeat failure detection, straggler shard reassignment, and serving-
state recovery (the engine's FULL session state — thresholds, §II.C
sliding window, UCB arms, counters — is ONE pytree, so a serving replica
restarts exactly where it died).

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""
import tempfile
import time

import numpy as np

from repro.data.datasets import DatasetConfig, make_batch
from repro.engine import DartEngine
from repro.models.cnn_zoo import AlexNetConfig
from repro.runtime.fault import (HeartbeatMonitor, ShardPlan,
                                 StragglerPolicy,
                                 simulate_failure_and_recover)
from repro.runtime.trainer import Trainer, TrainConfig

DATA = DatasetConfig(name="synth-cifar", n_train=512)
MODEL = AlexNetConfig(img_res=32, n_classes=10,
                      channels=(8, 16, 24, 16, 16), fc_dims=(64, 32))


def main():
    # 1. crash-recovery determinism ---------------------------------------
    ck = tempfile.mkdtemp()
    tc = TrainConfig(batch_size=16, steps=20, lr=1e-3, ckpt_dir=ck,
                     ckpt_every=5, log_every=5, warmup=0)
    print("training to step 10, then 'crashing' ...")
    before, after, tr = simulate_failure_and_recover(
        MODEL, tc, fail_at=10, total_steps=20, data_cfg=DATA)
    print("pre-crash:", [(h["step"], round(h["loss"], 3)) for h in before])
    print("post-resume:", [(h["step"], round(h["loss"], 3)) for h in after])

    straight = Trainer(MODEL, TrainConfig(batch_size=16, steps=20, lr=1e-3,
                                          log_every=5, warmup=0), DATA)
    straight.run()
    import jax
    max_dev = max(float(np.max(np.abs(np.asarray(a, np.float64)
                                      - np.asarray(b, np.float64))))
                  for a, b in zip(jax.tree.leaves(straight.params),
                                  jax.tree.leaves(tr.params)))
    print(f"max param deviation vs never-crashed run: {max_dev:.2e} "
          f"(stateless data + atomic ckpt => deterministic recovery)")

    # 2. heartbeat failure detection --------------------------------------
    print("\nheartbeat monitor: worker w2 goes silent ...")
    dead = []
    mon = HeartbeatMonitor([f"w{i}" for i in range(4)], timeout_s=0.2,
                           on_failure=lambda w: dead.append(w))
    t0 = time.monotonic()
    while time.monotonic() - t0 < 0.6:
        for w in ("w0", "w1", "w3"):
            mon.beat(w)
        time.sleep(0.03)
    mon.close()
    print("detected dead workers:", dead)

    # 3. straggler mitigation ----------------------------------------------
    print("\nstraggler mitigation: re-slicing the slow worker's shard ...")
    plan = ShardPlan.even(["w0", "w1", "w2", "w3"], np.arange(64))
    pol = StragglerPolicy(factor=3.0)
    for _ in range(10):
        pol.record(0.1)
    slow = 0.45
    if pol.is_straggling(slow):
        plan = plan.reassign("w2")
    sizes = {w: len(ix) for w, ix in plan.assignments.items()}
    print("new shard sizes:", sizes, "(total",
          sum(sizes.values()), "— no data lost)")

    # 4. serving-state recovery -------------------------------------------
    print("\nserving replica crash: EngineState round-trips as one pytree")
    engine = DartEngine.from_config(MODEL, tr.params, adapt=True,
                                    update_every=16)
    x, _ = make_batch(DATA, range(48), split="eval")
    engine.infer(x, mode="compacted")
    ckdir = tempfile.mkdtemp()
    engine.save_state(ckdir, step=0)

    replica = DartEngine.from_config(MODEL, tr.params, adapt=True,
                                     update_every=16)
    replica.restore_state(ckdir)
    same = (int(replica.state.served) == int(engine.state.served)
            and int(replica.state.adaptive["seen"])
            == int(engine.state.adaptive["seen"]))
    a, b2 = engine.infer(x[:16], "compacted"), replica.infer(x[:16],
                                                            "compacted")
    print(f"counters restored: {same}; post-restore decisions identical: "
          f"{bool(np.array_equal(a['exit_idx'], b2['exit_idx']))}")


if __name__ == "__main__":
    main()
