"""DART on a diffusion transformer: early-exit denoising (DESIGN.md §3)
through the engine's pluggable strategies.

A small DiT is trained with per-exit ε-heads (Eq. 18 with MSE); DDIM
sampling then exits each step at the earliest CONVERGED head, gated by the
latent+timestep difficulty.  The exit criterion and difficulty estimator
are the registered ``diffusion-convergence`` / ``latent`` strategies —
the same engine that serves classifiers routes diffusion exits.
High-noise (early) steps are easy — expect shallow exits there and
deeper exits near the end of the trajectory.

Run:  PYTHONPATH=src python examples/dit_early_exit.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.routing import DartParams
from repro.data.datasets import DatasetConfig
from repro.engine import DartEngine
from repro.models.dit import (DiTConfig, dit_forward, cosine_alpha_bar)
from repro.runtime.trainer import Trainer, TrainConfig

CFG = DiTConfig(name="dit-demo", img_res=64, patch=2, n_layers=4,
                d_model=64, n_heads=4, n_classes=10, exit_layers=(0, 1),
                remat=False)
DATA = DatasetConfig(name="latents", img_res=64, n_train=1024)


def main():
    print("training 4-layer DiT with exits after layers 0 and 1 ...")
    tr = Trainer(CFG, TrainConfig(batch_size=16, steps=200, lr=1e-3,
                                  log_every=30), DATA, data_kind="latents")
    tr.run()
    print("loss:", [round(h["loss"], 3) for h in tr.history])

    engine = DartEngine.from_config(
        CFG, tr.params,
        dart=DartParams(tau=jnp.asarray([0.93, 0.93]), coef=jnp.ones(2),
                        beta_diff=0.05),
        confidence="diffusion-convergence", difficulty="latent",
        adapt=False)
    abar = cosine_alpha_bar()
    b = 8
    key = jax.random.key(0)
    xt = jax.random.normal(key, (b, 8, 8, 4))
    y = jnp.arange(b) % 10
    steps = np.linspace(999, 120, 25).astype(int)  # stop above the low-noise regime: the demo model is tiny/undertrained and its x0-estimates blow up as abar->1

    @jax.jit
    def denoise(xt, t, t_prev, y):
        out = dit_forward(tr.params, xt, t, y, CFG)
        eps_stack = jnp.stack([e[..., :4] for e in out["exit_eps"]])
        routed = engine.route(eps_stack, xt, signal_frac=jnp.sqrt(abar[t]))
        eps = jnp.take_along_axis(
            eps_stack, routed["exit_idx"][None, :, None, None, None],
            axis=0)[0]
        at = abar[t][:, None, None, None]
        ap = abar[t_prev][:, None, None, None]
        x0 = (xt - jnp.sqrt(1 - at) * eps) / jnp.sqrt(at)
        return jnp.sqrt(ap) * x0 + jnp.sqrt(1 - ap) * eps, routed["exit_idx"]

    print("\nsampler_step,t,mean_exit_depth")
    depth_by_phase = {"early(noisy)": [], "late(clean)": []}
    for i, t in enumerate(steps):
        t_prev = steps[i + 1] if i + 1 < len(steps) else 0
        tb = jnp.full((b,), t)
        xt, exit_idx = denoise(xt, tb, jnp.full((b,), t_prev), y)
        d = float(jnp.mean(exit_idx))
        phase = "early(noisy)" if t > 500 else "late(clean)"
        depth_by_phase[phase].append(d)
        if i % 5 == 0:
            print(f"{i},{t},{d:.2f}")
    print("\nmean exit depth  early(noisy):",
          round(float(np.mean(depth_by_phase['early(noisy)'])), 3),
          " late(clean):",
          round(float(np.mean(depth_by_phase['late(clean)'])), 3))
    print("latent stats after sampling: mean",
          float(jnp.mean(xt)), "std", float(jnp.std(xt)))


if __name__ == "__main__":
    main()
