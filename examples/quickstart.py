"""Quickstart: the full DART pipeline through the `repro.engine` API.

The whole lifecycle is five lines:

    engine = DartEngine.from_config(cfg, params)   # wire up
    engine.calibrate(cal_data)                     # §II.B policy fit
    out = engine.infer(x, mode="compacted")        # Alg. 1 serving
    engine.update()                                # §II.C adaptation
    engine.stats()                                 # metering

This script: (1) trains a 3-exit AlexNet on synth-CIFAR with the Eq. 18
multi-exit loss, (2) runs the paper's Table I protocol (Static /
BranchyNet / RL-Agent / DART — all registered policy optimizers), and
(3) serves a few batches through the compacting engine.

Run:  PYTHONPATH=src python examples/quickstart.py
      (QUICKSTART_STEPS / QUICKSTART_EVAL shrink it for smoke tests)
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
import dataclasses

import numpy as np

from repro.configs import registry
from repro.data.datasets import DatasetConfig, make_batch
from repro.engine import DartEngine
from benchmarks.common import evaluate_methods, print_rows, train_model

STEPS = int(os.environ.get("QUICKSTART_STEPS", 200))
N_EVAL = int(os.environ.get("QUICKSTART_EVAL", 512))
CIFAR = DatasetConfig(name="synth-cifar", n_train=2048, n_eval=2048)


def main():
    tb = registry.paper_testbeds()
    cfg = dataclasses.replace(tb["alexnet"], channels=(16, 32, 48, 32, 32),
                              fc_dims=(128, 64))
    print(f"training 3-exit AlexNet on synth-CIFAR ({STEPS} steps) ...")
    tr = train_model(cfg, CIFAR, steps=STEPS, batch=32)
    print(f"final train loss: {tr.history[-1]['loss']:.3f}")

    # -- Table I protocol (all four methods via the optimizer registry) --
    rows, diag = evaluate_methods(cfg, tr.params, CIFAR, n_eval=N_EVAL)
    print_rows("Quickstart — Table I protocol (synth-CIFAR)", rows)
    print(f"\nDART thresholds (Eq. 12/DP): "
          f"{np.round(diag['dart_tau'], 3).tolist()}")
    print(f"DART exit distribution: {diag['exit_dist']['dart']}")
    print(f"mean difficulty alpha: {diag['mean_alpha']:.3f} "
          f"(paper: CIFAR-10 ~0.85)")
    dart = rows[3]
    print(f"\nDART: {dart['speedup']:.2f}x speedup, "
          f"{dart['power_eff']:.2f}x power efficiency, "
          f"DAES {dart['daes']:.2f} (static {rows[0]['daes']:.2f})")

    # -- the 5-line serving session -------------------------------------
    engine = DartEngine.from_config(cfg, tr.params,
                                    cum_costs=diag["cum_macs"])
    engine.calibrate(engine.collect_calibration(CIFAR, n=256))
    x, _ = make_batch(CIFAR, range(64), split="eval")
    out = engine.infer(x, mode="compacted")
    stats = engine.stats()
    print(f"\nengine session: served {stats['served']} samples, "
          f"exit counts {stats['exit_counts'].tolist()}, "
          f"mean exit {out['exit_idx'].mean():.2f}, "
          f"mean MACs {out['macs'].mean()/1e6:.2f}M "
          f"(full depth {engine.cum_costs[-1]/1e6:.2f}M)")


if __name__ == "__main__":
    main()
