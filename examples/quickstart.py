"""Quickstart: the full DART pipeline on a small multi-exit CNN.

  1. train a 3-exit AlexNet on synth-CIFAR with the Eq. 18 multi-exit loss
  2. estimate per-input difficulty (Eqs. 1-8)
  3. jointly optimize exit thresholds with the DP of §II.B
  4. serve with the compacting engine and compare against
     Static / BranchyNet / RL-Agent — the paper's Table I protocol

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
import dataclasses

import numpy as np

from repro.configs import registry
from repro.data.datasets import DatasetConfig
from benchmarks.common import evaluate_methods, print_rows, train_model

CIFAR = DatasetConfig(name="synth-cifar", n_train=2048, n_eval=2048)


def main():
    tb = registry.paper_testbeds()
    cfg = dataclasses.replace(tb["alexnet"], channels=(16, 32, 48, 32, 32),
                              fc_dims=(128, 64))
    print("training 3-exit AlexNet on synth-CIFAR ...")
    tr = train_model(cfg, CIFAR, steps=200, batch=32)
    print(f"final train loss: {tr.history[-1]['loss']:.3f}")

    rows, diag = evaluate_methods(cfg, tr.params, CIFAR, n_eval=512)
    print_rows("Quickstart — Table I protocol (synth-CIFAR)", rows)
    print(f"\nDART thresholds (Eq. 12/DP): "
          f"{np.round(diag['dart_tau'], 3).tolist()}")
    print(f"DART exit distribution: {diag['exit_dist']['dart']}")
    print(f"mean difficulty alpha: {diag['mean_alpha']:.3f} "
          f"(paper: CIFAR-10 ~0.85)")
    dart = rows[3]
    print(f"\nDART: {dart['speedup']:.2f}x speedup, "
          f"{dart['power_eff']:.2f}x power efficiency, "
          f"DAES {dart['daes']:.2f} (static {rows[0]['daes']:.2f})")


if __name__ == "__main__":
    main()
