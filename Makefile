PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test smoke engine-test bench bench-serving bench-async bench-lm \
    bench-cascade bench-predict bench-chaos bench-kernels bench-obs dartop \
    perf-check docs-check deps

# Tier-1 verify (ROADMAP): docs lint + the full test suite, fail-fast.
test: docs-check
	$(PY) -m pytest -x -q

# Engine-focused subset (fast iteration on the serving path).
engine-test:
	$(PY) -m pytest -q tests/test_engine.py \
	    tests/test_engine_serving_compat.py tests/test_sharded_engine.py \
	    tests/test_serving.py tests/test_lm_sharded.py

# End-to-end smoke: quickstart with tiny settings (~1 min on CPU).
smoke:
	QUICKSTART_STEPS=30 QUICKSTART_EVAL=128 $(PY) examples/quickstart.py

# Paper-protocol benchmarks (quick budget).
bench:
	$(PY) -m benchmarks.run

# Sharded request-stream serving benchmark (8 fake CPU devices).
bench-serving:
	$(PY) -m benchmarks.serving_sharded

# Async scheduler benchmark: open-loop Poisson load sweep vs per-request
# eager dispatch (>= 2x sustained throughput at equal p95).
bench-async:
	$(PY) -m benchmarks.serving_async

# Sharded bucketed LM decode session vs eager per-request decode
# (>= 1.5x tokens/s at equal p95; JSON to artifacts/perf/).
bench-lm:
	$(PY) -m benchmarks.serving_lm

# Difficulty-routed multi-model cascade vs biggest-member-only serving
# (cascade sustains more samples/s at equal p95; JSON to
# artifacts/perf/serving_cascade.json).
bench-cascade:
	$(PY) -m benchmarks.serving_cascade

# Admission-time exit-depth prediction A/B: predictor-on vs predictor-off
# (on beats off on sustained throughput at equal p95, DAES no worse;
# JSON to artifacts/perf/serving_predict.json).
bench-predict:
	$(PY) -m benchmarks.serving_predict

# Fault-tolerant serving under a kill-and-rejoin chaos schedule
# (degraded-floor + recovery ratios and fault-plan determinism; JSON to
# artifacts/perf/serving_chaos.json).
bench-chaos:
	$(PY) -m benchmarks.serving_chaos

# Fused-kernel microbenchmarks vs the composed XLA reference chains
# (dispatch backends + the >=1.3x acceptance gate; JSON to
# artifacts/bench/).
bench-kernels:
	$(PY) -m benchmarks.kernels_bench

# Observability overhead smoke: enabled-vs-disabled throughput ratio
# (<=5% cost gate via perf-check) + Prometheus exposition validation
# (JSON to artifacts/perf/obs.json, metrics to artifacts/perf/metrics.prom).
bench-obs:
	$(PY) -m benchmarks.serving_async --smoke

# One-shot dashboard probe over the exported metrics file.
dartop:
	$(PY) tools/dartop.py --once --file artifacts/perf/metrics.prom

# Perf regression gate: run the smoke sweep, fail on >15% regression vs
# benchmarks/baselines/smoke.json.
perf-check:
	$(PY) -m benchmarks.perf_iterate --check

# Lint docs/ + README: compile python snippets, validate intra-repo links.
docs-check:
	$(PY) tools/docs_check.py

deps:
	pip install -r requirements-test.txt
