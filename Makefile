PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test smoke engine-test bench deps

# Tier-1 verify (ROADMAP): the full test suite, fail-fast.
test:
	$(PY) -m pytest -x -q

# Engine-focused subset (fast iteration on the serving path).
engine-test:
	$(PY) -m pytest -q tests/test_engine.py tests/test_server.py

# End-to-end smoke: quickstart with tiny settings (~1 min on CPU).
smoke:
	QUICKSTART_STEPS=30 QUICKSTART_EVAL=128 $(PY) examples/quickstart.py

# Paper-protocol benchmarks (quick budget).
bench:
	$(PY) -m benchmarks.run

deps:
	pip install -r requirements-test.txt
