"""Gradient compression: quantization error bounds, error feedback, and
end-to-end convergence under compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # deterministic fallback (raises under REPRO_REQUIRE_HYPOTHESIS=1,
    # which CI sets — there the real package must be installed)
    from _hypothesis_compat import given, settings, strategies as st

from _prop import examples

from repro.parallel.compression import (CompressionConfig, compress_grads,
                                        init_error_feedback, quantize_int8,
                                        dequantize_int8, topk_sparsify)


@settings(max_examples=examples(30), deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 1e3))
def test_int8_quantization_error_bound(seed, scale):
    x = jax.random.normal(jax.random.key(seed), (256,)) * scale
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) / 2 + 1e-9 * scale


def test_topk_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 0.0, 1.0, -2.0])
    sparse, mask = topk_sparsify(x, 0.25)
    nz = set(np.nonzero(np.asarray(sparse))[0].tolist())
    assert nz == {1, 3}


def test_error_feedback_identity():
    """compressed + residual == original (nothing is lost, only delayed)."""
    g = {"w": jax.random.normal(jax.random.key(0), (128,))}
    for scheme in ("int8", "topk"):
        cfg = CompressionConfig(scheme, topk_frac=0.05)
        cg, ef, _ = compress_grads(g, init_error_feedback(g), cfg)
        np.testing.assert_allclose(np.asarray(cg["w"] + ef["w"]),
                                   np.asarray(g["w"]), atol=1e-5)


def test_wire_bytes_shrink():
    g = {"w": jax.random.normal(jax.random.key(0), (1024,))}
    _, _, raw = compress_grads(g, init_error_feedback(g),
                               CompressionConfig("none"))
    _, _, w8 = compress_grads(g, init_error_feedback(g),
                              CompressionConfig("int8"))
    _, _, wk = compress_grads(g, init_error_feedback(g),
                              CompressionConfig("topk", 0.01))
    assert w8 <= raw / 3.9
    assert wk <= raw / 20


@pytest.mark.parametrize("scheme,frac", [("int8", 0.0), ("topk", 0.1)])
def test_convergence_with_error_feedback(scheme, frac):
    """SGD on a quadratic still converges under compression with EF —
    the Stich et al. guarantee this module relies on."""
    target = jnp.asarray([1.0, -1.0, 2.0, 0.3])
    p = {"w": jnp.zeros(4)}
    ef = init_error_feedback(p)
    cfg = CompressionConfig(scheme, topk_frac=frac)
    lr = 0.3
    for _ in range(300):
        g = jax.grad(lambda q: jnp.sum((q["w"] - target) ** 2))(p)
        cg, ef, _ = compress_grads(g, ef, cfg)
        p = jax.tree.map(lambda x, u: x - lr * u, p, cg)
    np.testing.assert_allclose(p["w"], target, atol=0.15)
