"""Routing / baselines / DAES tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import routing as R
from repro.core import baselines as BL
from repro.core import daes
from repro.core.policy import CalibrationData
from repro.core.routing import DartParams


def test_confidence_matches_softmax_max():
    lg = jax.random.normal(jax.random.key(0), (3, 5, 11))
    c = R.confidence_from_logits(lg)
    want = jnp.max(jax.nn.softmax(lg, axis=-1), axis=-1)
    np.testing.assert_allclose(c, want, rtol=1e-6)


def test_entropy_uniform_is_log_v():
    lg = jnp.zeros((2, 16))
    e = R.entropy_from_logits(lg)
    np.testing.assert_allclose(e, np.log(16), rtol=1e-6)


def test_diffusion_confidence_converged_exits():
    """Identical consecutive predictions => confidence 1; first exit 0."""
    eps = jnp.ones((3, 2, 4, 4, 1))
    conf = R.diffusion_confidence(eps)
    assert conf.shape == (3, 2)
    np.testing.assert_allclose(conf[0], 0.0)
    np.testing.assert_allclose(conf[1:], 1.0, atol=1e-6)
    # diverging predictions => low confidence
    eps2 = jnp.stack([jnp.zeros((2, 4, 4, 1)), jnp.ones((2, 4, 4, 1)),
                      -jnp.ones((2, 4, 4, 1))])
    conf2 = R.diffusion_confidence(eps2)
    assert float(conf2[2].mean()) < 0.2


def test_classify_routed_selects_first_confident():
    logits = jnp.full((3, 2, 4), -5.0)
    # sample 0: exit 0 confident; sample 1: nothing confident -> final
    logits = logits.at[0, 0, 1].set(10.0)
    imgs = jnp.full((2, 16, 16, 3), 0.5)          # alpha ~ 0
    dart = DartParams(tau=jnp.full((2,), 0.9), coef=jnp.ones(2),
                      beta_diff=0.0)
    out = R.classify_routed(logits, imgs, dart)
    assert int(out["exit_idx"][0]) == 0
    assert int(out["exit_idx"][1]) == 2
    assert int(out["pred"][0]) == 1


def test_multi_exit_xent_weighting():
    e, b, c = 3, 8, 5
    logits = jax.random.normal(jax.random.key(1), (e, b, c))
    y = jax.random.randint(jax.random.key(2), (b,), 0, c)
    loss, aux = R.multi_exit_xent(logits, y, policy_weight=0.0)
    ces = aux["ce_per_exit"]
    want = sum((i + 1) / e * ces[i] for i in range(e))
    np.testing.assert_allclose(loss, want, rtol=1e-6)


def test_branchynet_entropy_routing():
    ent = np.array([[0.1, 0.5, 0.2], [2.0, 0.1, 0.3], [2.0, 2.0, 2.0]])
    pol = BL.BranchyNetPolicy(np.array([0.5, 0.4]))
    idx = pol.route(ent)
    np.testing.assert_array_equal(idx, [0, 1, 2])


def test_rl_agent_learns_to_exit_when_early_is_good():
    rs = np.random.RandomState(0)
    n, e = 800, 3
    conf = rs.rand(n, e)
    correct = np.ones((n, e))                  # every exit always right
    data = CalibrationData(conf, correct, rs.rand(n),
                           np.array([0.2, 0.6, 1.0]))
    pol = BL.fit_rl_agent(data, beta_opt=1.0, epochs=8)
    idx = pol.route(conf)
    assert idx.mean() < 0.5                    # exits early to save cost


def test_static_route():
    idx = BL.static_route(np.zeros((5, 4)))
    assert np.all(idx == 3)


def test_daes_formula():
    st = daes.MethodMeasurement("static", accuracy=0.9, time_s=1.0,
                                macs=100.0)
    m = daes.MethodMeasurement("dart", accuracy=0.8, time_s=0.25, macs=25.0)
    # speedup 4, power_eff 4 => DAES = 0.8*4*4 / (1+0.5)
    assert daes.daes(st, m, 0.5) == pytest.approx(0.8 * 4 * 4 / 1.5)
    assert daes.daes(st, st, 0.5) == pytest.approx(0.9 / 1.5)
    row = daes.summary_row(st, m, 0.5)
    assert row["speedup"] == pytest.approx(4.0)


def test_routed_macs():
    macs = R.routed_macs(jnp.asarray([0, 2, 1]), [10.0, 20.0, 30.0])
    np.testing.assert_allclose(macs, [10.0, 30.0, 20.0])
