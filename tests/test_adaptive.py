"""Tests for adaptive coefficient management (paper §II.C, Eqs. 13–15)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adaptive as AD


def cfg(**kw):
    base = dict(n_exits=4, n_classes=10, window=128, update_every=16)
    base.update(kw)
    return AD.AdaptiveConfig(**base)


def record_uniform(state, c, *, correct=1.0, n=32, cost=0.5, cls=None):
    b = n
    return AD.record_batch(
        state, c,
        jnp.arange(b) % c.n_exits,
        jnp.full((b,), cls if cls is not None else 0, jnp.int32)
        if cls is not None else jnp.arange(b) % c.n_classes,
        jnp.full((b,), 0.8), jnp.full((b,), correct), jnp.full((b,), cost))


def test_ring_buffer_wraps():
    c = cfg(window=16)
    st = AD.init_state(c)
    for _ in range(3):
        st = record_uniform(st, c, n=10)
    assert int(st["seen"]) == 30
    assert int(st["ptr"]) == 30 % 16
    ws = AD.window_stats(st, c)
    assert float(ws["n"]) == 16


def test_temporal_update_direction():
    """Eq. 13: low accuracy -> coefficients rise (conservative); high
    accuracy -> they fall toward aggressive exits."""
    c = cfg(a_target=0.85)
    st_low = record_uniform(AD.init_state(c), c, correct=0.3)
    st_low = AD.temporal_update(st_low, c)
    assert float(st_low["coef_temporal"][0]) > 1.0

    st_hi = record_uniform(AD.init_state(c), c, correct=1.0)
    st_hi = AD.temporal_update(st_hi, c)
    assert float(st_hi["coef_temporal"][0]) < 1.0


def test_temporal_update_is_ema_with_decay():
    c = cfg(alpha_decay=0.95)
    st = record_uniform(AD.init_state(c), c, correct=0.0)
    before = np.asarray(st["coef_temporal"])
    st = AD.temporal_update(st, c)
    after = np.asarray(st["coef_temporal"])
    target = 1.0 + c.kappa * (c.a_target - 0.0)
    np.testing.assert_allclose(after, 0.95 * before + 0.05 * target,
                               rtol=1e-5)


def test_coefficients_clamped():
    c = cfg(coef_min=0.5, coef_max=1.5, kappa=100.0)
    st = record_uniform(AD.init_state(c), c, correct=0.0)
    for _ in range(50):
        st = AD.temporal_update(st, c)
    assert float(jnp.max(st["coef_temporal"])) <= 1.5 + 1e-6


def test_class_aware_update_eq14():
    """Eq. 14: underperforming class coefficient rises by η(A_t − A_c)."""
    c = cfg(eta=0.1, a_target=0.85)
    st = AD.init_state(c)
    st = record_uniform(st, c, correct=0.0, cls=3)     # class 3 fails
    st2 = AD.class_aware_update(st, c)
    delta = np.asarray(st2["coef_class"] - st["coef_class"])
    assert delta[3].mean() == pytest.approx(0.1 * 0.85, rel=1e-4)
    # classes without data do not move
    assert np.abs(delta[5]).max() < 1e-7


def test_ucb_prefers_best_arm():
    """Eq. 15 regret check: after warmup, the best-reward arm dominates."""
    c = cfg(ucb_enabled=True)
    st = AD.init_state(c)
    rewards = {0: 0.9, 1: 0.2, 2: 0.4, 3: 0.1}
    picks = []
    for t in range(300):
        arm = int(st["active_strategy"])
        picks.append(arm)
        st = AD.ucb_update(st, c, rewards[arm]
                           + 0.05 * np.random.RandomState(t).randn())
    late = picks[150:]
    assert np.mean(np.asarray(late) == 0) > 0.6, np.bincount(late)


def test_ucb_explores_all_arms_first():
    c = cfg()
    st = AD.init_state(c)
    seen = set()
    for _ in range(len(AD.STRATEGIES)):
        seen.add(int(st["active_strategy"]))
        st = AD.ucb_update(st, c, 0.5)
    assert seen == set(range(len(AD.STRATEGIES)))


def test_effective_coef_strategies():
    c = cfg()
    st = AD.init_state(c)
    st["coef_temporal"] = jnp.full((3,), 1.2)
    st["coef_class"] = jnp.full((10, 3), 0.8)
    for arm, want in [(0, 1.2), (1, 0.8), (2, 1.0), (3, 1.0)]:
        st["active_strategy"] = jnp.asarray(arm)
        got = AD.effective_coef(st, c)
        assert float(got[0]) == pytest.approx(want), arm
    # per-class indexing
    st["active_strategy"] = jnp.asarray(1)
    got = AD.effective_coef(st, c, pseudo_class=jnp.asarray([2, 5]))
    assert got.shape == (2, 3)


def test_periodic_update_runs_jitted():
    import jax
    c = cfg()
    st = record_uniform(AD.init_state(c), c)
    f = jax.jit(lambda s: AD.periodic_update(s, c))
    st2 = f(st)
    assert int(st2["t"]) == int(st["t"]) + 1
