"""Fault tolerance: heartbeats, stragglers, deterministic checkpoint-resume."""
import time

import numpy as np

from repro.data.datasets import DatasetConfig
from repro.models.cnn_zoo import AlexNetConfig
from repro.runtime.fault import (HeartbeatMonitor, ShardPlan,
                                 StragglerPolicy, resume,
                                 simulate_failure_and_recover)
from repro.runtime.trainer import Trainer, TrainConfig

DATA = DatasetConfig(name="synth-cifar", n_train=256, n_eval=64)
MODEL = AlexNetConfig(img_res=32, n_classes=10,
                      channels=(8, 16, 24, 16, 16), fc_dims=(64, 32))


def test_heartbeat_detects_dead_worker():
    failures = []
    mon = HeartbeatMonitor(["w0", "w1"], timeout_s=0.15,
                           on_failure=failures.append)
    t0 = time.monotonic()
    while time.monotonic() - t0 < 0.5:
        mon.beat("w0")                   # w1 goes silent
        time.sleep(0.02)
    mon.close()
    assert failures == ["w1"]
    assert "w0" not in mon.dead


def test_heartbeat_elastic_membership():
    """add_worker (re-)registers with a fresh deadline and clears the
    death mark; remove_worker deregisters without firing the callback."""
    failures = []
    mon = HeartbeatMonitor(["w0"], timeout_s=0.15,
                           on_failure=failures.append)
    mon.add_worker("w1")
    assert sorted(mon.workers()) == ["w0", "w1"]
    mon.remove_worker("w1")                 # drained, not failed
    t0 = time.monotonic()
    while time.monotonic() - t0 < 0.5:
        mon.beat("w0")
        time.sleep(0.02)
    assert failures == [] and "w1" not in mon.dead
    # a dead worker re-registered via add_worker is live again
    mon.add_worker("w2")
    t0 = time.monotonic()
    while "w2" not in mon.dead and time.monotonic() - t0 < 2.0:
        mon.beat("w0")
        time.sleep(0.02)
    assert failures == ["w2"]
    mon.add_worker("w2")
    assert "w2" not in mon.dead
    mon.close()


def test_heartbeat_callback_may_reenter_monitor():
    """The recovery callback runs outside the monitor lock: calling
    beat/add_worker from inside it must not deadlock the watch thread."""
    mon = None
    recovered = []

    def on_failure(w):
        recovered.append(w)
        mon.add_worker(w + "-replacement")  # re-enter under no lock
        mon.beat(w + "-replacement")

    mon = HeartbeatMonitor(["w0", "w1"], timeout_s=0.1,
                           on_failure=on_failure)
    t0 = time.monotonic()
    while time.monotonic() - t0 < 0.6:
        for w in mon.workers():              # everyone but w1 stays live
            if w != "w1":
                mon.beat(w)
        time.sleep(0.02)
    mon.close()
    assert recovered == ["w1"]
    assert "w1-replacement" in mon.workers()
    assert "w1-replacement" not in mon.dead


def test_shard_plan_reassignment_loses_nothing():
    idx = np.arange(64)
    plan = ShardPlan.even(["a", "b", "c", "d"], idx)
    plan2 = plan.reassign("c")
    assert "c" not in plan2.assignments
    got = np.sort(np.concatenate(list(plan2.assignments.values())))
    np.testing.assert_array_equal(got, idx)


def test_straggler_policy_deadline():
    pol = StragglerPolicy(factor=3.0)
    for _ in range(10):
        pol.record(0.1)
    assert not pol.is_straggling(0.25)
    assert pol.is_straggling(0.5)


def test_crash_resume_is_deterministic(tmp_path):
    """Train 8 steps straight vs train 4 + crash + resume 4: identical
    final parameters (atomic ckpt + stateless data + pure step)."""
    def run(ckpt_dir, fail):
        tc = TrainConfig(batch_size=16, steps=8, lr=1e-3,
                         ckpt_dir=str(ckpt_dir), ckpt_every=4,
                         log_every=4, warmup=0)
        if fail:
            before, after, tr = simulate_failure_and_recover(
                MODEL, tc, fail_at=4, total_steps=8, data_cfg=DATA)
            return tr
        tr = Trainer(MODEL, tc, DATA)
        tr.run()
        return tr

    t_straight = run(tmp_path / "a", fail=False)
    t_resumed = run(tmp_path / "b", fail=True)
    assert t_straight.step == t_resumed.step == 8
    import jax
    for a, b in zip(jax.tree.leaves(t_straight.params),
                    jax.tree.leaves(t_resumed.params)):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64),
                                   atol=1e-6, rtol=1e-5)


def test_resume_without_checkpoint_starts_fresh(tmp_path):
    tc = TrainConfig(batch_size=16, steps=4, lr=1e-3,
                     ckpt_dir=str(tmp_path / "none"), warmup=0)
    tr = resume(MODEL, tc, data_cfg=DATA)
    assert tr.step == 0
