"""Data substrate: determinism, stateless resume, difficulty structure."""
import numpy as np

from repro.data.datasets import (DatasetConfig, make_batch, MNIST, CIFAR,
                                 synth_tokens_sample)
from repro.data.pipeline import DataPipeline, batch_indices, eval_batches
from repro.core import difficulty as D
import jax.numpy as jnp


def test_determinism_across_calls():
    for cfg, kind in [(MNIST, None), (CIFAR, None)]:
        x1, y1 = make_batch(cfg, range(16))
        x2, y2 = make_batch(cfg, range(16))
        np.testing.assert_array_equal(x1, x2)
        np.testing.assert_array_equal(y1, y2)


def test_split_independence():
    x_tr, _ = make_batch(CIFAR, range(8), split="train")
    x_ev, _ = make_batch(CIFAR, range(8), split="eval")
    assert not np.array_equal(x_tr, x_ev)


def test_images_in_unit_range_and_labeled():
    x, y = make_batch(CIFAR, range(32))
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert set(np.unique(y)) <= set(range(10))


def test_class_difficulty_profile():
    """synth-cifar class 8 ('ship', high clutter) must be measurably harder
    than class 1 ('car', low clutter) under the paper's α (Fig. 2 setup)."""
    idx_easy = [1 + 10 * i for i in range(64)]
    idx_hard = [8 + 10 * i for i in range(64)]
    x_easy, _ = make_batch(CIFAR, idx_easy)
    x_hard, _ = make_batch(CIFAR, idx_hard)
    a_easy = float(jnp.mean(D.image_difficulty(jnp.asarray(x_easy))))
    a_hard = float(jnp.mean(D.image_difficulty(jnp.asarray(x_hard))))
    assert a_hard > a_easy, (a_easy, a_hard)


def test_batch_indices_stateless_resume():
    """Restarting at step t yields the same indices — the fault-tolerance
    guarantee that no data is skipped or repeated after recovery."""
    for step in [0, 3, 97]:
        i1 = batch_indices(CIFAR, step, 32)
        i2 = batch_indices(CIFAR, step, 32)
        np.testing.assert_array_equal(i1, i2)
    # consecutive steps within an epoch do not overlap
    cfg = DatasetConfig(n_train=1000)
    a = set(batch_indices(cfg, 0, 100))
    b = set(batch_indices(cfg, 1, 100))
    assert not a & b


def test_pipeline_prefetch_order_and_resume():
    pipe = DataPipeline(CIFAR, 8, start_step=5)
    s, x, y = next(pipe)
    assert s == 5
    s2, _, _ = next(pipe)
    assert s2 == 6
    pipe.close()
    # a fresh pipeline from the same step yields identical data
    pipe2 = DataPipeline(CIFAR, 8, start_step=5)
    _, x2, _ = next(pipe2)
    pipe2.close()
    np.testing.assert_array_equal(np.asarray(x), np.asarray(x2))


def test_eval_batches_cover_split():
    cfg = DatasetConfig(n_eval=25)
    seen = 0
    for x, y in eval_batches(cfg, 10):
        seen += x.shape[0]
    assert seen == 25


def test_token_dataset_structure():
    seq, label = synth_tokens_sample(DatasetConfig(), 7, seq_len=64,
                                     vocab=128)
    assert seq.shape == (64,) and seq.dtype == np.int32
    assert seq.min() >= 0 and seq.max() < 128
    # motif structure: the sequence is far from uniform-random
    _, counts = np.unique(seq, return_counts=True)
    assert counts.max() > 64 / 128 * 4
