"""Tests for the joint DP exit-policy optimizer (paper §II.B)."""
import numpy as np
import pytest

from repro.core import policy as POL
from repro.core import thresholds as TH


def make_calibration(seed=0, n=2500, e=4, difficulty_hurts=True):
    """Synthetic calibration set with confidence correlated to correctness
    and difficulty degrading early exits (the regime DART targets)."""
    rs = np.random.RandomState(seed)
    skill = np.linspace(0.55, 0.93, e)
    alpha = rs.rand(n)
    degrade = 0.35 * alpha[:, None] * (1 - skill[None]) * 2 \
        if difficulty_hurts else 0.0
    p_correct = np.clip(skill[None] - degrade, 0.05, 0.99)
    correct = (rs.rand(n, e) < p_correct).astype(float)
    conf = np.clip(0.55 * correct + 0.25 * rs.rand(n, e)
                   + 0.2 * skill[None], 0, 1)
    cum = np.linspace(1.0 / e, 1.0, e)
    return POL.CalibrationData(conf, correct, alpha, cum,
                               labels=rs.randint(0, 10, n))


def test_dp_beats_independent():
    data = make_calibration()
    dp = POL.optimize_joint_dp(data, beta_opt=0.5)
    ind = POL.optimize_independent(data, beta_opt=0.5)
    assert dp.objective >= ind.objective - 1e-9


def test_bruteforce_is_upper_bound():
    data = make_calibration(n=1200, e=3)
    dp = POL.optimize_joint_dp(data, beta_opt=0.5)
    bf = POL.optimize_brute_force(data, beta_opt=0.5)
    assert bf.objective >= dp.objective - 1e-9
    # and DP should land close to the oracle (within 5% of J range)
    ind = POL.optimize_independent(data, beta_opt=0.5)
    rng_ = max(bf.objective - ind.objective, 1e-6)
    assert (bf.objective - dp.objective) <= 0.6 * rng_ + 1e-9


def test_dp_generalizes_to_holdout():
    data = make_calibration(n=4000)
    train, val = data.split(0.7)
    dp = POL.optimize_joint_dp(train, beta_opt=0.5)
    j_val = float(TH.objective(val.conf, val.alpha, val.correct,
                               val.cum_costs, dp.tau, dp.coef,
                               dp.beta_diff, 0.5))
    ind = POL.optimize_independent(train, beta_opt=0.5)
    j_val_ind = float(TH.objective(val.conf, val.alpha, val.correct,
                                   val.cum_costs, ind.tau, ind.coef,
                                   ind.beta_diff, 0.5))
    assert j_val >= j_val_ind - 0.02


@pytest.mark.parametrize("beta_opt", [0.0, 0.3, 1.0])
def test_higher_cost_pressure_exits_earlier(beta_opt):
    data = make_calibration()
    res = POL.optimize_joint_dp(data, beta_opt=beta_opt)
    idx = TH.simulate_routing(data.conf, data.alpha, res.tau, res.coef,
                              res.beta_diff)
    mean_exit = float(np.mean(np.asarray(idx)))
    if not hasattr(test_higher_cost_pressure_exits_earlier, "_prev"):
        test_higher_cost_pressure_exits_earlier._prev = []
    test_higher_cost_pressure_exits_earlier._prev.append(
        (beta_opt, mean_exit))
    prev = test_higher_cost_pressure_exits_earlier._prev
    if len(prev) == 3:
        assert prev[0][1] >= prev[-1][1] - 0.25, prev


def test_dp_thresholds_rise_with_alpha_bin():
    """The DP solution should be (weakly) more conservative for harder
    α bins when difficulty hurts early-exit accuracy."""
    data = make_calibration(n=6000)
    res = POL.optimize_joint_dp(data, beta_opt=0.5, n_alpha_bins=3)
    thr = res.dp_thresholds          # (E-1, A)
    rising = (thr[:, -1] >= thr[:, 0] - 0.15).mean()
    assert rising >= 0.5, thr


def test_fit_beta_diff_grid():
    data = make_calibration()
    res = POL.optimize_joint_dp(data, beta_opt=0.5, fit_beta_diff=True)
    assert 0.0 <= res.beta_diff <= 0.5


def test_empirical_tables_are_distributions():
    data = make_calibration(n=800)
    acc, trans = POL._empirical_tables(data, 4, 8)
    assert acc.shape == (4, 4, 8)
    assert np.all(acc >= 0) and np.all(acc <= 1)
    np.testing.assert_allclose(trans.sum(-1), 1.0, atol=1e-6)
