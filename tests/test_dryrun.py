"""Dry-run machinery tests: HLO collective parsing + reduced-config cells
compiling on the REAL production meshes (512 fake devices, subprocess)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch.dryrun import parse_collectives, _result_bytes


def test_result_bytes_parsing():
    line = ("%all-reduce.5 = f32[16,512,2048]{2,1,0} "
            "all-reduce(f32[16,512,2048]{2,1,0} %x), replica_groups={}")
    assert _result_bytes(line) == 16 * 512 * 2048 * 4


def test_result_bytes_tuple():
    line = ("%ar = (bf16[8,4]{1,0}, bf16[8,4]{1,0}) all-reduce(%a, %b), "
            "replica_groups={}")
    assert _result_bytes(line) == 2 * 8 * 4 * 2


def test_parse_collectives_classes_and_wire_factor():
    hlo = """
  %ag = bf16[64,128]{1,0} all-gather(bf16[4,128]{1,0} %p), dims={0}
  %ar.1 = f32[32]{0} all-reduce(f32[32]{0} %x), to_apply=%sum
  %rs = f32[4,8]{1,0} reduce-scatter(f32[64,8]{1,0} %y), dims={0}
  %a2a = bf16[16,16]{1,0} all-to-all(bf16[16,16]{1,0} %z), dims={0}
  %cp-start = bf16[8]{0} collective-permute-start(bf16[8]{0} %w)
  %cp-done = bf16[8]{0} collective-permute-done(%cp-start)
"""
    st = parse_collectives(hlo)
    assert st["all-gather"]["count"] == 1
    assert st["all-gather"]["bytes"] == 64 * 128 * 2
    assert st["all-reduce"]["bytes"] == 32 * 4 * 2.0       # wire factor 2
    assert st["reduce-scatter"]["count"] == 1
    assert st["all-to-all"]["count"] == 1
    assert st["collective-permute"]["count"] == 1          # -done skipped
    # bf16 correction halves only the f32 entries
    st2 = parse_collectives(hlo, bf16_model=True)
    f32_bytes = 32 * 4 * 2.0 + 4 * 8 * 4
    assert st2["total_bytes_bf16corr"] == pytest.approx(
        st2["total_bytes"] - f32_bytes / 2)


CELL_SCRIPT = textwrap.dedent("""
    import os, sys, json
    sys.path.insert(0, %r)
    from repro.launch.dryrun import run_cell
    arch, shape, outdir = sys.argv[1], sys.argv[2], sys.argv[3]
    rec = run_cell(arch, shape, multi_pod=(sys.argv[4] == "multi"),
                   outdir=outdir, reduced=True)
    assert rec["flops_per_device"] > 0
    assert rec["memory"]["temp_bytes"] >= 0
    print("CELL_OK", json.dumps({k: rec[k] for k in
                                 ("arch", "shape", "mesh", "devices")}))
""" % os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.mark.parametrize("arch,shape,pod", [
    ("vit-s16", "serve_b128", "single"),
    ("tinyllama-1.1b", "decode_32k", "multi"),
    ("granite-moe-3b-a800m", "train_4k", "single"),
    ("dit-s2", "gen_fast", "multi"),
])
def test_reduced_cell_compiles_on_production_mesh(arch, shape, pod,
                                                  tmp_path):
    """REDUCED configs through the REAL 256/512-device dry-run path —
    exercises mesh building, sharding resolution, lower+compile, and
    artifact writing without the full-config compile times."""
    r = subprocess.run(
        [sys.executable, "-c", CELL_SCRIPT, arch, shape, str(tmp_path),
         pod], capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "CELL_OK" in r.stdout
    arts = list(os.listdir(tmp_path))
    assert len(arts) == 1
    with open(os.path.join(tmp_path, arts[0])) as f:
        rec = json.load(f)
    assert rec["devices"] == (512 if pod == "multi" else 256)
    assert "collectives" in rec
