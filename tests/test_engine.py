"""DartEngine: masked == compacted routing, EngineState checkpoint
round-trip, registry lookups, BatchCompactor overflow semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as CK
from repro.core.policy import CalibrationData
from repro.core.routing import DartParams
from repro.data.datasets import DatasetConfig, make_batch
from repro.engine import (BatchCompactor, BatchTooLarge, DartEngine,
                          EngineState, get_confidence, get_difficulty,
                          get_optimizer, route_policy)
from repro.models.cnn_zoo import AlexNetConfig
from repro.runtime.trainer import Trainer, TrainConfig

DATA = DatasetConfig(name="synth-cifar", n_train=256, n_eval=128)


@pytest.fixture(scope="module")
def trained_cnn():
    mc = AlexNetConfig(img_res=32, n_classes=10,
                       channels=(16, 24, 32, 24, 24), fc_dims=(96, 48))
    tr = Trainer(mc, TrainConfig(batch_size=32, steps=15, lr=3e-3), DATA)
    tr.run()
    return mc, tr.params


def _engine(trained_cnn, **kw):
    mc, params = trained_cnn
    kw.setdefault("cum_costs", [0.3, 0.7, 1.0])
    kw.setdefault("adapt", False)
    return DartEngine.from_config(mc, params, **kw)


# ---------------------------------------------------------------------------
# masked vs compacted equivalence (ported from test_server)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tau", [0.0, 0.35, 0.9])
def test_engine_modes_bit_identical(trained_cnn, tau):
    eng = _engine(trained_cnn,
                  dart=DartParams(tau=jnp.full((2,), tau),
                                  coef=jnp.ones(2), beta_diff=0.3))
    x, _ = make_batch(DATA, range(48), split="eval")
    out = eng.infer(x, mode="compacted")
    ref = eng.infer(x, mode="masked")
    np.testing.assert_array_equal(out["exit_idx"],
                                  np.asarray(ref["exit_idx"]))
    np.testing.assert_array_equal(out["pred"], np.asarray(ref["pred"]))
    np.testing.assert_allclose(out["conf"], np.asarray(ref["conf"]),
                               rtol=2e-5, atol=2e-5)


def test_engine_unknown_mode(trained_cnn):
    eng = _engine(trained_cnn)
    x, _ = make_batch(DATA, range(4), split="eval")
    with pytest.raises(ValueError, match="unknown mode"):
        eng.infer(x, mode="warp")


# ---------------------------------------------------------------------------
# EngineState: pytree + checkpoint round-trip
# ---------------------------------------------------------------------------

def test_engine_state_is_one_pytree(trained_cnn):
    eng = _engine(trained_cnn, adapt=True, update_every=16)
    x, _ = make_batch(DATA, range(32), split="eval")
    eng.infer(x, mode="compacted")
    leaves, treedef = jax.tree.flatten(eng.state)
    assert all(hasattr(l, "shape") for l in leaves)
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert isinstance(rebuilt, EngineState)
    assert int(rebuilt.served) == 32

    # jit straight over the state object
    served = jax.jit(lambda s: s.served + 1)(eng.state)
    assert int(served) == 33


def test_engine_state_checkpoint_roundtrip(tmp_path, trained_cnn):
    eng = _engine(trained_cnn, adapt=True, update_every=16)
    x, _ = make_batch(DATA, range(48), split="eval")
    eng.infer(x, mode="compacted")
    eng.save_state(str(tmp_path), step=7)

    replica = _engine(trained_cnn, adapt=True, update_every=16)
    step = replica.restore_state(str(tmp_path))
    assert step == 7
    for a, b in zip(jax.tree.leaves(eng.state),
                    jax.tree.leaves(replica.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # identical state => identical decisions
    a = eng.infer(x[:16], mode="masked")
    b = replica.infer(x[:16], mode="masked")
    np.testing.assert_array_equal(np.asarray(a["exit_idx"]),
                                  np.asarray(b["exit_idx"]))


def test_engine_state_restore_via_checkpoint_module(tmp_path, trained_cnn):
    eng = _engine(trained_cnn)
    CK.save(str(tmp_path), 3, eng.state)
    restored, step, _ = CK.restore(str(tmp_path), eng.state)
    assert step == 3
    assert isinstance(restored, EngineState)
    np.testing.assert_array_equal(np.asarray(restored.tau),
                                  np.asarray(eng.state.tau))


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------

def test_registry_unknown_names_raise():
    with pytest.raises(KeyError, match="unknown confidence"):
        get_confidence("nope")
    with pytest.raises(KeyError, match="unknown difficulty"):
        get_difficulty("nope")
    with pytest.raises(KeyError, match="unknown optimizer"):
        get_optimizer("nope")


def test_engine_rejects_unknown_strategy(trained_cnn):
    mc, params = trained_cnn
    with pytest.raises(KeyError, match="unknown confidence"):
        DartEngine.from_config(mc, params, confidence="nope")
    with pytest.raises(KeyError, match="unknown difficulty"):
        DartEngine.from_config(mc, params, difficulty="nope")
    with pytest.raises(KeyError, match="unknown optimizer"):
        DartEngine.from_config(mc, params, optimizer="nope")


def _synthetic_calibration(rng, n=256, e=3):
    conf = np.sort(rng.rand(n, e), axis=1)          # deeper => more confident
    correct = (rng.rand(n, e) < conf).astype(float)
    return CalibrationData(conf=conf, correct=correct, alpha=rng.rand(n),
                           cum_costs=np.array([0.3, 0.7, 1.0]),
                           labels=rng.randint(0, 10, n),
                           entropy=1.0 - conf)


def test_all_optimizers_return_policy_and_route(rng):
    data = _synthetic_calibration(rng)
    for name in ("joint_dp", "independent", "static", "branchynet",
                 "rl_agent"):
        pol = get_optimizer(name)(data, beta_opt=0.5)
        assert pol.tau.shape == (2,)
        idx = route_policy(pol, data)
        assert idx.shape == (256,)
        assert idx.min() >= 0 and idx.max() <= 2
    # static never exits early
    pol = get_optimizer("static")(data, beta_opt=0.5)
    assert np.all(route_policy(pol, data) == 2)


def test_calibrate_installs_policy(trained_cnn, rng):
    eng = _engine(trained_cnn)
    data = _synthetic_calibration(rng)
    pol = eng.calibrate(data)
    np.testing.assert_allclose(np.asarray(eng.state.tau), pol.tau,
                               rtol=1e-6)
    assert float(eng.state.beta_diff) == pytest.approx(pol.beta_diff)


# ---------------------------------------------------------------------------
# BatchCompactor: overflow is an error, oversized batches are split
# ---------------------------------------------------------------------------

def test_compactor_bucket_semantics():
    c = BatchCompactor((1, 2, 4, 8))
    assert c.bucket_for(1) == 1
    assert c.bucket_for(3) == 4
    assert c.bucket_for(8) == 8
    with pytest.raises(BatchTooLarge):
        c.bucket_for(9)
    assert c.chunks(20) == [(0, 8), (8, 16), (16, 20)]
    assert c.chunks(8) == [(0, 8)]
    with pytest.raises(BatchTooLarge):
        c.pad(np.zeros((9, 2)), 8)


def test_split_request_routes_under_one_policy(trained_cnn):
    """A chunked oversized request must defer the §II.C periodic update
    past its last chunk: every sample is gated under the same
    coefficients, so compacted still matches the masked reference."""
    mc, params = trained_cnn
    eng = DartEngine.from_config(
        mc, params, cum_costs=[0.3, 0.7, 1.0], buckets=(1, 2, 4, 8, 16),
        adapt=True, update_every=16,
        dart=DartParams(tau=jnp.full((2,), 0.35), coef=jnp.ones(2),
                        beta_diff=0.3))
    x, _ = make_batch(DATA, range(40), split="eval")    # 3 chunks
    ref = eng.infer(x, mode="masked")                   # pre-serving state
    out = eng.infer(x, mode="compacted")
    np.testing.assert_array_equal(out["exit_idx"],
                                  np.asarray(ref["exit_idx"]))
    np.testing.assert_array_equal(out["pred"], np.asarray(ref["pred"]))
    # the deferred update did run once the request completed
    assert int(eng.state.adaptive["t"]) == 1
    assert int(eng.state.since_update) == 0


def test_engine_splits_oversized_batches(trained_cnn):
    eng = _engine(trained_cnn, buckets=(1, 2, 4, 8, 16),
                  dart=DartParams(tau=jnp.full((2,), 0.35),
                                  coef=jnp.ones(2), beta_diff=0.3))
    x, _ = make_batch(DATA, range(40), split="eval")     # 40 > 16
    out = eng.infer(x, mode="compacted")
    ref = eng.infer(x, mode="masked")
    assert len(out["pred"]) == 40
    np.testing.assert_array_equal(out["exit_idx"],
                                  np.asarray(ref["exit_idx"]))
    np.testing.assert_array_equal(out["pred"], np.asarray(ref["pred"]))
    assert int(eng.state.served) == 40
    assert int(np.asarray(eng.state.exit_counts).sum()) == 40
