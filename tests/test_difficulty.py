"""Property tests for the difficulty estimator (paper §II.A)."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # deterministic fallback (raises under REPRO_REQUIRE_HYPOTHESIS=1,
    # which CI sets — there the real package must be installed)
    from _hypothesis_compat import given, settings, strategies as st

from _prop import examples

from repro.core import difficulty as D


@settings(max_examples=examples(25), deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       h=st.integers(8, 48), w=st.integers(8, 48),
       c=st.sampled_from([1, 3]))
def test_alpha_in_unit_interval(seed, h, w, c):
    img = jax.random.uniform(jax.random.key(seed), (2, h, w, c))
    comp = D.image_difficulty_components(img)
    for k in ("edge", "variance", "gradient", "alpha"):
        assert bool(jnp.all(comp[k] >= 0.0)) and bool(jnp.all(comp[k] <= 1.0)), k


def test_constant_image_is_easiest():
    img = jnp.full((1, 32, 32, 3), 0.5)
    comp = D.image_difficulty_components(img)
    assert float(comp["alpha"][0]) < 1e-5


def test_noise_is_harder_than_flat():
    flat = jnp.full((1, 32, 32, 3), 0.5)
    noise = jax.random.uniform(jax.random.key(0), (1, 32, 32, 3))
    assert float(D.image_difficulty(noise)[0]) \
        > float(D.image_difficulty(flat)[0])


def test_monotone_in_noise_level():
    """More additive noise => higher difficulty (statistically)."""
    base = jnp.full((4, 32, 32, 3), 0.5)
    key = jax.random.key(1)
    alphas = []
    for lvl in [0.0, 0.1, 0.3, 0.6]:
        img = jnp.clip(base + lvl * jax.random.normal(key, base.shape), 0, 1)
        alphas.append(float(jnp.mean(D.image_difficulty(img))))
    assert alphas == sorted(alphas), alphas


def test_fusion_weights_respected():
    img = jax.random.uniform(jax.random.key(2), (2, 32, 32, 3))
    comp = D.image_difficulty_components(img)
    manual = np.clip(0.4 * np.asarray(comp["edge"])
                     + 0.3 * np.asarray(comp["variance"])
                     + 0.3 * np.asarray(comp["gradient"]), 0, 1)
    np.testing.assert_allclose(np.asarray(comp["alpha"]), manual, rtol=1e-6)


def test_edge_density_definition():
    """Eq. 4 on a half-black/half-white image: the single vertical edge
    activates exactly one interior column band."""
    img = jnp.concatenate([jnp.zeros((1, 16, 8, 1)),
                           jnp.ones((1, 16, 8, 1))], axis=2)
    e = float(D.edge_density(img, tau_edge=0.5)[0])
    # Sobel support around the boundary: 2 interior columns of (h-2) rows
    expected = 2 * 14 / (14 * 14)
    assert abs(e - expected) < 1e-6


@settings(max_examples=examples(20), deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_token_difficulty_bounds(seed):
    emb = jax.random.normal(jax.random.key(seed), (3, 12, 16)) * 2
    a = D.token_difficulty(emb)
    assert a.shape == (3,)
    assert bool(jnp.all((a >= 0) & (a <= 1)))


def test_token_difficulty_short_sequence():
    emb = jax.random.normal(jax.random.key(0), (2, 1, 16))
    a = D.token_difficulty(emb)
    assert a.shape == (2,) and bool(jnp.all(jnp.isfinite(a)))


def test_latent_difficulty_scales_with_signal():
    lat = jax.random.uniform(jax.random.key(0), (2, 16, 16, 4))
    hi = D.latent_difficulty(lat, jnp.array([1.0, 1.0]))
    lo = D.latent_difficulty(lat, jnp.array([0.1, 0.1]))
    assert bool(jnp.all(hi >= lo))


def test_estimator_flops_budget():
    """The paper's overhead claim: ~78.9 KFLOPs per input, 50.3x cheaper
    than RACENet's 3.96 MFLOPs."""
    fl = D.estimator_flops(32, 32, 3)
    assert 40_000 < fl < 120_000
    assert 3_960_000 / fl > 30


def test_difficulty_ema_decode():
    a0 = jnp.array([0.5, 0.9])
    emb = jnp.zeros((2, 1, 16))
    a1 = D.token_difficulty_ema(a0, emb, decay=0.9)
    np.testing.assert_allclose(a1, 0.9 * a0, atol=1e-6)
