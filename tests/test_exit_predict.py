"""Admission-time exit-depth prediction (ISSUE 9).

Covers, per the acceptance list:

* conservative head-skip is BIT-IDENTICAL to the eager oracle — the
  served decisions (pred / exit_idx for the classifier, tokens AND
  exit stages for LM decode) match per-request inference with no
  ``min_exit``, on a 1-device mesh in-process and on an 8-fake-device
  mesh in a subprocess — while the predictor actually engages
  (``skip_stages > 0``, otherwise the test proves nothing);
* head-skip variants compile separately but only once:
  ``trace_counts`` stays one per (stage, bucket) key and repeats never
  retrace;
* the predictor converges online on a synthetic difficulty→depth
  stream (depth heads ordered, bands settle, band hit rate high);
* the admission-time SLO quote error lands in ``stats()``
  (``requests.quote``) and in the obs exposition
  (``dart_quote_mean_abs_err_ms`` + ``dart_predictor_*``).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.obs as obs
from repro.core.routing import DartParams
from repro.data.datasets import DatasetConfig, make_batch
from repro.engine import DartEngine, LMDecodeEngine
from repro.launch.mesh import make_serving_mesh
from repro.models.transformer_lm import LMConfig, lm_init
from repro.models.vit import ViTConfig, vit_init
from repro.obs import metrics as M
from repro.parallel.sharding import unzip
from repro.serving import AsyncDartServer, ExitDepthPredictor, \
    SchedulerConfig

DATA = DatasetConfig(name="synth-cifar", n_train=128, n_eval=128)
VC = ViTConfig(name="vt-pred", img_res=32, patch=8, n_layers=3,
               d_model=32, n_heads=2, d_ff=64, n_classes=10,
               exit_layers=(0, 1))
# tau[0]=0.9 with beta_diff=0.3: Eq. 19 unclipped threshold exceeds
# the softmax-max confidence bound (1.0) whenever alpha >= 1/3 — true
# for every synth-cifar eval image — so the conservative bound rules
# gate 0 out and min_exit=1 engages on every served bucket.
TAU = (0.9, 0.2)

LM_CFG = LMConfig(name="lm-pred-t", n_layers=4, d_model=32, n_heads=2,
                  n_kv_heads=1, d_ff=64, vocab=32, exit_layers=(0, 2),
                  max_seq=64, remat=False)
# the LM session's decode-time alpha infimum is 0.0, so ruling gate 0
# out needs coef[0]*tau[0] >= 1.0 on its own
LM_COEF = (1.2, 1.0)
LM_TAU = (0.9, 0.1)


@pytest.fixture(autouse=True)
def _fresh_obs():
    obs.reset()
    yield
    obs.reset()


@pytest.fixture(scope="module")
def vit_params():
    return unzip(vit_init(jax.random.key(0), VC))[0]


@pytest.fixture(scope="module")
def images():
    x, _ = make_batch(DATA, range(64), split="eval")
    return np.asarray(x)


def make_engine(params, **kw):
    kw.setdefault("cum_costs", [0.4, 0.7, 1.0])
    kw.setdefault("adapt", True)
    kw.setdefault("update_every", 10 ** 9)
    return DartEngine.from_config(
        VC, params,
        dart=DartParams(tau=jnp.asarray(TAU), coef=jnp.ones(2),
                        beta_diff=0.3), **kw)


def _lm_dart():
    return DartParams(tau=jnp.asarray(LM_TAU), coef=jnp.asarray(LM_COEF),
                      beta_diff=0.3)


# ---------------------------------------------------------------------------
# the sound bound itself
# ---------------------------------------------------------------------------
def test_min_exit_bound_manual(vit_params):
    eng = make_engine(vit_params)
    # alpha below 1/3: 0.9 + 0.3*alpha < 1.0 — nothing provably cold
    assert eng.min_exit_bound(0.0) == 0
    # above: gate 0 ruled out; gate 1 (tau=0.2) never is
    assert eng.min_exit_bound(0.5) == 1
    assert eng.min_exit_bound(1.0) == 1
    # the final stage can never be skipped
    assert eng.min_exit_bound(1.0) < eng.n_exits


# ---------------------------------------------------------------------------
# conservative server == eager oracle (classifier, 1-device mesh)
# ---------------------------------------------------------------------------
def test_conservative_server_bit_identical_to_oracle(vit_params, images):
    eng = make_engine(vit_params, mesh=make_serving_mesh())
    srv = AsyncDartServer(eng, SchedulerConfig(
        max_batch=8, flush_ms=1.0, mode="compacted",
        predict="conservative"))
    reqs = [images[i:i + 4] for i in range(0, len(images), 4)]
    futs = [srv.submit(x, deadline_ms=10_000) for x in reqs]
    outs = [f.result(timeout=120) for f in futs]
    srv.close()
    # head-skip must have engaged, or this equivalence proves nothing
    ps = srv.predictor.stats()
    assert ps["skip_stages"] > 0, ps
    assert ps["skip_calls"] > 0
    # per-request oracle on the same engine, no min_exit, no recording
    for x, out in zip(reqs, outs):
        ref = eng.infer(x, mode="compacted", record=False)
        np.testing.assert_array_equal(out["pred"], np.asarray(ref["pred"]))
        np.testing.assert_array_equal(out["exit_idx"],
                                      np.asarray(ref["exit_idx"]))
        np.testing.assert_allclose(out["conf"], np.asarray(ref["conf"]),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(out["macs"], np.asarray(ref["macs"]),
                                   rtol=2e-5, atol=2e-5)
    # the stats surface carries the predictor block
    st = srv.stats()
    assert st["scheduler"]["predictor"]["mode"] == "conservative"
    assert st["scheduler"]["predictor"]["observed"] > 0


def test_skip_variants_trace_once_per_key(vit_params, images):
    """min_exit variants are distinct compiled programs (min_exit=0
    preserves the legacy step-cache keys) but each traces exactly once,
    and repeats reuse."""
    eng = make_engine(vit_params, mesh=make_serving_mesh())
    x = images[:8]
    base = eng.infer(x, mode="compacted", record=False)
    n0 = dict(eng.trace_counts)
    assert all(n == 1 for n in n0.values()), n0
    out = eng.infer(x, mode="compacted", record=False, min_exit=1)
    assert eng.trace_counts != n0          # new skip-variant programs
    assert all(n == 1 for n in eng.trace_counts.values()), \
        eng.trace_counts
    # decisions unchanged under the sound bound
    np.testing.assert_array_equal(out["pred"], base["pred"])
    np.testing.assert_array_equal(out["exit_idx"], base["exit_idx"])
    # repeats of BOTH variants never retrace
    eng.infer(x, mode="compacted", record=False)
    eng.infer(x, mode="compacted", record=False, min_exit=1)
    assert all(n == 1 for n in eng.trace_counts.values()), \
        eng.trace_counts
    with pytest.raises(ValueError, match="min_exit"):
        eng.infer(x, mode="compacted", min_exit=eng.n_exits)


# ---------------------------------------------------------------------------
# conservative LM session == eager oracle (tokens AND stages)
# ---------------------------------------------------------------------------
def test_lm_session_conservative_matches_oracle():
    params = unzip(lm_init(jax.random.key(0), LM_CFG))[0]
    eng = LMDecodeEngine(LM_CFG, params, _lm_dart())
    assert eng.min_exit_bound(0.0) == 1    # coef[0]*tau[0] = 1.08 >= 1
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, LM_CFG.vocab, (3, 7)),
               rng.randint(0, LM_CFG.vocab, (2, 7))]
    session = eng.session(SchedulerConfig(
        max_batch=8, flush_ms=1.0, policy="reject",
        predict="conservative"))
    futs = [session.submit(p, deadline_ms=60_000, n_new=6)
            for p in prompts]
    outs = [f.result(timeout=120) for f in futs]
    session.close()
    ps = session.predictor.stats()
    assert ps["skip_stages"] > 0, ps
    # oracle: a fresh identical engine, per-request, no min_exit
    oracle = LMDecodeEngine(LM_CFG, params, _lm_dart())
    for p, out in zip(prompts, outs):
        tok_ref, stg_ref = oracle.generate(p, n_new=6)
        np.testing.assert_array_equal(out["tokens"], tok_ref)
        np.testing.assert_array_equal(out["stages"], stg_ref)


# ---------------------------------------------------------------------------
# predictor training dynamics
# ---------------------------------------------------------------------------
def test_predictor_converges_on_synthetic_stream():
    pred = ExitDepthPredictor(3)
    rng = np.random.default_rng(0)
    for _ in range(40):
        a = rng.uniform(0.0, 1.0, 16)
        e = np.where(a < 0.35, 0, np.where(a < 0.7, 1, 2))
        pred.observe(a, e)
    d_easy = pred.predict_depth(0.1)
    d_mid = pred.predict_depth(0.5)
    d_hard = pred.predict_depth(0.9)
    assert d_easy < d_mid < d_hard
    assert (pred.depth_band(0.1), pred.depth_band(0.5),
            pred.depth_band(0.9)) == (0, 1, 2)
    st = pred.stats()
    assert st["observed"] == 640
    assert st["hit_rate"] > 0.8, st
    # the one-lock admission fast path agrees with the split calls
    d, band = pred.admit_info(0.5)
    assert band == pred.depth_band(0.5)
    assert d == pytest.approx(pred.predict_depth(0.5))


def test_predictor_band_is_sticky_near_boundary():
    """A depth hovering at a rounding boundary must not flip the lane
    band back and forth — that would split one class across two lanes
    and fragment bucket consolidation."""
    pred = ExitDepthPredictor(3, priors=lambda: None, band_hysteresis=0.25)
    # train class of alpha=0.5 to depth ~1.0, then nudge: band stays
    for _ in range(30):
        pred.observe(np.full(8, 0.5), np.full(8, 1, np.int64))
    band0 = pred.depth_band(0.5)
    assert band0 == 1
    # a handful of depth-2 observations move the head a little, but not
    # past the hysteresis margin — the band must hold
    pred.observe(np.full(4, 0.5), np.full(4, 2, np.int64))
    assert pred.depth_band(0.5) == band0
    # mode and ctor validation
    with pytest.raises(ValueError, match="unknown mode"):
        ExitDepthPredictor(3, mode="yolo")
    with pytest.raises(ValueError, match="n_exits"):
        ExitDepthPredictor(0)


# ---------------------------------------------------------------------------
# SLO quote error: stats() + obs exposition
# ---------------------------------------------------------------------------
def test_quote_error_in_stats_and_obs(vit_params, images):
    obs.configure(enabled=True)
    eng = make_engine(vit_params, mesh=make_serving_mesh())
    srv = AsyncDartServer(eng, SchedulerConfig(
        max_batch=8, flush_ms=1.0, mode="compacted",
        predict="conservative"))
    # wave 1 seeds the per-stage service EMA (quotes are None while the
    # planner has no realized service times); wave 2 gets real quotes
    for wave in range(2):
        futs = [srv.submit(images[i:i + 4], deadline_ms=10_000)
                for i in range(0, 32, 4)]
        for f in futs:
            f.result(timeout=120)
    st = srv.stats()
    srv.close()
    q = st["requests"].get("quote")
    assert q is not None, st["requests"]
    assert q["quoted"] >= 8
    assert q["mean_quote_ms"] > 0.0
    assert q["mean_abs_err_ms"] >= 0.0
    # per-stage service EMA backing the quote is surfaced too
    assert "stage_ms_ema" in st["scheduler"]
    # and the obs exposition carries the predictor + quote families
    fams = M.parse_prometheus(obs.get_registry().render())
    assert "dart_predictor_events_total" in fams
    assert "dart_predictor_hit_rate" in fams
    assert "dart_quote_mean_abs_err_ms" in fams
    assert "dart_quote_mean_ms" in fams
    events = {lbl.get("event"): v for _, lbl, v
              in fams["dart_predictor_events_total"]["samples"]}
    assert events["skip_stages"] > 0, events


# ---------------------------------------------------------------------------
# 8-fake-device mesh (subprocess)
# ---------------------------------------------------------------------------
MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, %r)
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.routing import DartParams
    from repro.engine import LMDecodeEngine
    from repro.launch.mesh import make_serving_mesh
    from repro.models.transformer_lm import LMConfig, lm_init
    from repro.parallel.sharding import unzip

    cfg = LMConfig(name="lm-pred-8dev", n_layers=4, d_model=32,
                   n_heads=2, n_kv_heads=1, d_ff=64, vocab=32,
                   exit_layers=(0, 2), max_seq=64, remat=False)
    params = unzip(lm_init(jax.random.key(0), cfg))[0]
    dart = DartParams(tau=jnp.asarray((0.9, 0.1)),
                      coef=jnp.asarray((1.2, 1.0)), beta_diff=0.3)
    prompts = np.random.RandomState(0).randint(0, cfg.vocab, (5, 7))

    sh = LMDecodeEngine(cfg, params, dart, mesh=make_serving_mesh())
    assert sh.n_replicas == 8, sh.n_replicas
    m = sh.min_exit_bound(0.0)
    assert m == 1, m

    # head-skip on the fused sharded decode == the eager oracle, on
    # tokens AND exit stages
    oracle = LMDecodeEngine(cfg, params, dart)
    tok_ref, stg_ref = oracle.generate(prompts, n_new=8)
    tok_s, stg_s = sh.generate(prompts, n_new=8, min_exit=m)
    np.testing.assert_array_equal(tok_s, tok_ref)
    np.testing.assert_array_equal(stg_s, stg_ref)

    # skip variants trace once per (stage, bucket) key, repeats reuse
    before = dict(sh.trace_counts)
    assert all(n == 1 for n in before.values()), before
    sh.generate(prompts, n_new=8, min_exit=m)
    assert sh.trace_counts == before, sh.trace_counts
    # the unskipped variant compiles separately — and only once
    sh.generate(prompts, n_new=8)
    assert len(sh.trace_counts) > len(before)
    assert all(n == 1 for n in sh.trace_counts.values()), sh.trace_counts
    print("EXIT_PREDICT_8DEV_OK")
""" % os.path.join(os.path.dirname(__file__), "..", "src"))


def test_head_skip_equivalence_on_8_devices():
    """Conservative head-skip == eager oracle with 8 fake devices
    (subprocess; the in-process suite is pinned to one device)."""
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "EXIT_PREDICT_8DEV_OK" in r.stdout
