"""Continuous batching (slot pool + paged KV cache) differential /
property harness — ISSUE 7.

The load-bearing property: for EVERY interleaving of admissions into
the slot pool, every request's tokens AND exit stages are bit-identical
to the eager per-request oracle run at the decoder's padded view
length.  On top of that, structural invariants hold after every step
(no slot double-allocation, every freed page returns to the free list,
active-mask ∧ page-table ∧ free-list consistency), exactly ONE decode
step (and one embed step) is ever compiled regardless of admission
pattern, and a starved senior request reserves freed capacity instead
of being backfilled around forever.

In-process tests run mesh-less and on the 1-device ("data",) mesh; the
real 8-replica run executes in a subprocess with
``--xla_force_host_platform_device_count=8`` like test_lm_sharded.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # deterministic fallback (raises under REPRO_REQUIRE_HYPOTHESIS=1,
    # which CI sets — there the real package must be installed)
    from _hypothesis_compat import given, settings, strategies as st

from _prop import examples

from repro.core.routing import DartParams
from repro.engine import LMDecodeEngine
from repro.engine.compactor import OutOfCapacity
from repro.launch.mesh import make_serving_mesh
from repro.models.transformer_lm import LMConfig, lm_init
from repro.parallel.sharding import unzip
from repro.serving.loop import SchedulerConfig
from repro.serving.request import RequestRejected

CFG = LMConfig(name="lm-cont-t", n_layers=4, d_model=32, n_heads=2,
               n_kv_heads=1, d_ff=64, vocab=32, exit_layers=(0, 2),
               max_seq=64, remat=False)

POOL = dict(n_slots=4, page_size=4, max_len=16)


@pytest.fixture(scope="module")
def lm_params():
    return unzip(lm_init(jax.random.key(0), CFG))[0]


_PARAMS_CACHE = []


def _params():
    # module-scope cache usable from hypothesis-driven tests (which
    # cannot take pytest fixtures)
    if not _PARAMS_CACHE:
        _PARAMS_CACHE.append(unzip(lm_init(jax.random.key(0), CFG))[0])
    return _PARAMS_CACHE[0]


def _dart(tau):
    return DartParams(tau=jnp.full((2,), tau), coef=jnp.ones(2),
                      beta_diff=0.1)


def _engine(tau=0.0, mesh=None):
    return LMDecodeEngine(CFG, _params(), _dart(tau), mesh=mesh)


def _random_stream(rs, n_reqs, view_len):
    """(tag, prompts, n_new) requests whose KV footprint fits a slot."""
    reqs = []
    for i in range(n_reqs):
        b = int(rs.randint(1, 3))
        s0 = int(rs.randint(2, 8))
        n_new = int(rs.randint(1, view_len - s0 + 2))
        reqs.append((i, rs.randint(0, CFG.vocab, (b, s0)), n_new))
    return reqs


def _drive(dec, rs, reqs):
    """Random admission interleaving: requests are admitted FIFO but at
    random steps (whenever capacity allows AND a coin flip agrees —
    except into an idle pool, which always admits, guaranteeing
    progress).  Invariants are checked after every admission batch and
    every step."""
    results = {}
    pending = list(reqs)
    steps = 0
    while len(results) < len(reqs):
        steps += 1
        assert steps < 1000, "stream did not converge"
        while pending:
            tag, p, n = pending[0]
            if not dec.can_admit(p.shape[0], p.shape[1], n):
                break
            if dec.active_rows and rs.rand() < 0.5:
                break                   # defer: vary the interleaving
            dec.admit(p, n, tag=tag)
            pending.pop(0)
        dec.check_invariants()
        for tag, toks, stgs in dec.step():
            results[tag] = (toks, stgs)
        dec.check_invariants()
    return results


# ---------------------------------------------------------------------------
# the differential property (satellite 1)
# ---------------------------------------------------------------------------
@settings(max_examples=examples(5), deadline=None)
@given(seed=st.integers(0, 10_000),
       tau=st.sampled_from([0.0, 0.05, 1.0]))
def test_random_streams_match_eager_oracle(seed, tau):
    """Slot-pool decode ≡ eager oracle on tokens AND exit stages for a
    random request stream under a random admission interleaving, with
    the structural invariants holding after every step."""
    rs = np.random.RandomState(seed)
    eng = _engine(tau)
    oracle = _engine(tau)
    dec = eng.continuous(**POOL)
    reqs = _random_stream(rs, int(rs.randint(3, 7)), dec.view_len)
    results = _drive(dec, rs, reqs)
    for tag, p, n in reqs:
        toks, stgs = results[tag]
        # the oracle must run at the decoder's padded view length: the
        # attention reduction shape is part of the bit-identity contract
        ot, os_ = oracle.generate(p, n, max_len=dec.view_len,
                                  mode="eager")
        np.testing.assert_array_equal(toks, ot, err_msg=f"req {tag}")
        np.testing.assert_array_equal(stgs, os_, err_msg=f"req {tag}")
    # drained pool: every slot and page back on the free lists
    assert dec.pool.in_use == 0 and dec.allocator.in_use == 0
    dec.check_invariants()


def test_stream_telemetry_matches_eager_engine(lm_params):
    """Device telemetry (served / exit_counts / total_macs) and host
    diagnostics folded by the continuous path equal an eager engine
    serving the identical stream."""
    rs = np.random.RandomState(3)
    eng = _engine(0.0)
    eager = _engine(0.0)
    dec = eng.continuous(**POOL)
    reqs = _random_stream(rs, 4, dec.view_len)
    _drive(dec, rs, reqs)
    for _, p, n in reqs:
        eager.generate(p, n, max_len=dec.view_len, mode="eager")
    a, b = eng.stats(), eager.stats()
    assert a["served"] == b["served"]
    np.testing.assert_array_equal(a["exit_counts"], b["exit_counts"])
    np.testing.assert_allclose(a["total_macs"], b["total_macs"],
                               rtol=1e-5)
    assert a["layers_run"] == b["layers_run"]
    assert a["layers_skipped"] == b["layers_skipped"]
    np.testing.assert_array_equal(eng.stats_exit, eager.stats_exit)
    cont = a["continuous"]
    assert cont["decode_steps"] > 0
    assert cont["slot_steps"] >= a["served"]
    assert cont["pages_peak"] > 0


# ---------------------------------------------------------------------------
# trace-count regression (satellite 2)
# ---------------------------------------------------------------------------
def test_one_decode_trace_for_every_admission_pattern(lm_params):
    """trace_counts stays at ONE compiled decode step and ONE embed
    step no matter how requests arrive: all-at-once, one-at-a-time,
    staggered mid-flight, different prompt lengths and n_new."""
    eng = _engine(0.0)
    dec = eng.continuous(**POOL)
    rs = np.random.RandomState(7)
    # pattern 1: everything up front
    for i in range(3):
        dec.admit(rs.randint(0, CFG.vocab, (1, 5)), 4, tag=("a", i))
    while dec.active_rows:
        dec.step()
    # pattern 2: staggered admissions joining mid-flight
    dec.admit(rs.randint(0, CFG.vocab, (1, 3)), 8, tag="b0")
    dec.step()
    dec.admit(rs.randint(0, CFG.vocab, (2, 6)), 5, tag="b1")
    dec.step()
    dec.admit(rs.randint(0, CFG.vocab, (1, 7)), 2, tag="b2")
    while dec.active_rows:
        dec.step()
    key_d = ("lm-cont-decode", dec.n_slots, dec.page_size,
             dec.pages_per_slot)
    key_e = ("lm-cont-embed", dec.n_slots)
    assert eng.trace_counts[key_d] == 1, eng.trace_counts
    assert eng.trace_counts[key_e] == 1, eng.trace_counts
    # prefill compiles once per distinct prompt length, never more
    pf = {k: n for k, n in eng.trace_counts.items()
          if k[0] == "lm-cont-prefill"}
    assert pf and all(n == 1 for n in pf.values()), pf
    # a SECOND decoder of the same geometry reuses every compiled step
    dec2 = eng.continuous(**POOL)
    dec2.admit(rs.randint(0, CFG.vocab, (1, 5)), 3, tag="c")
    while dec2.active_rows:
        dec2.step()
    assert eng.trace_counts[key_d] == 1
    assert eng.trace_counts[key_e] == 1


# ---------------------------------------------------------------------------
# allocator / reclamation edges (satellites 1 + 4)
# ---------------------------------------------------------------------------
def test_admission_is_all_or_nothing_and_bounded(lm_params):
    eng = _engine(1.0)
    dec = eng.continuous(**POOL)
    rs = np.random.RandomState(11)
    # a request that can never fit raises ValueError, not OutOfCapacity
    with pytest.raises(ValueError, match="can never fit"):
        dec.admit(rs.randint(0, CFG.vocab, (1, 30)), 20)
    # fill the pool, then an admissible-shape request must raise
    # OutOfCapacity and leave NO partial allocation behind
    dec.admit(rs.randint(0, CFG.vocab, (4, 5)), 8, tag="full")
    held_before = (dec.pool.in_use, dec.allocator.in_use)
    with pytest.raises(OutOfCapacity):
        dec.admit(rs.randint(0, CFG.vocab, (1, 5)), 8)
    assert (dec.pool.in_use, dec.allocator.in_use) == held_before
    dec.check_invariants()
    # early-exit completion frees everything the same call
    while dec.active_rows:
        dec.step()
    assert dec.pool.in_use == 0 and dec.allocator.in_use == 0


def test_midflight_release_frees_slot_and_pages(lm_params):
    """A request cancelled mid-cascade releases its KV pages and slots
    immediately; the survivor stream is unaffected (its results still
    match the oracle)."""
    eng = _engine(1.0)
    oracle = _engine(1.0)
    dec = eng.continuous(**POOL)
    rs = np.random.RandomState(13)
    pa = rs.randint(0, CFG.vocab, (2, 5))
    pb = rs.randint(0, CFG.vocab, (2, 5))
    dec.admit(pa, 8, tag="a")
    dec.admit(pb, 8, tag="b")
    dec.step()
    dec.step()
    in_use = dec.allocator.in_use
    assert dec.release("a")
    dec.check_invariants()
    assert dec.pool.in_use == 2
    assert dec.allocator.in_use == in_use // 2
    # freed capacity is admittable THAT step
    assert dec.can_admit(2, 5, 8)
    events = []
    while dec.active_rows:
        events += dec.step()
    assert [t for t, _, _ in events] == ["b"]
    ot, os_ = oracle.generate(pb, 8, max_len=dec.view_len, mode="eager")
    np.testing.assert_array_equal(events[0][1], ot)
    np.testing.assert_array_equal(events[0][2], os_)
    assert dec.allocator.in_use == 0 and dec.pool.in_use == 0


# ---------------------------------------------------------------------------
# session: starvation / requeue edges (satellite 4)
# ---------------------------------------------------------------------------
class _FakeClock:
    def __init__(self):
        self.t = 100.0

    def now(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _cont_session(eng, clock, **cfg_kw):
    cfg = SchedulerConfig(policy="reject", flush_ms=0.0, **cfg_kw)
    return eng.session(continuous=True, cfg=cfg, clock=clock.now,
                       start=False, **POOL)


def test_starved_senior_reserves_freed_capacity(lm_params):
    """A wide request that cannot fit the busy pool is NOT backfilled
    around forever: after starve_ms, freed slots are held for it, so it
    completes before later-submitted juniors that would individually
    fit."""
    eng = _engine(1.0)
    clock = _FakeClock()
    sess = _cont_session(eng, clock, starve_ms=10.0)
    rs = np.random.RandomState(17)
    # stagger pool occupancy: 2 rows finish early, 2 late
    f_short = sess.submit(rs.randint(0, CFG.vocab, (2, 5)), n_new=2)
    f_long = sess.submit(rs.randint(0, CFG.vocab, (2, 5)), n_new=8)
    sess.pump()                      # both admitted: pool full
    assert sess.decoder.active_rows == 4
    big = sess.submit(rs.randint(0, CFG.vocab, (3, 5)), n_new=2)
    clock.advance(0.1)               # senior now starved (> starve_ms)
    # juniors in a DIFFERENT lane (shorter prompts): they are lane
    # heads in their own right, so only pop_next's head-of-line
    # reservation keeps them from backfilling around the senior
    smalls = [sess.submit(rs.randint(0, CFG.vocab, (1, 4)), n_new=2)
              for _ in range(3)]
    order = []
    for _ in range(200):
        sess.pump()
        for name, f in [("big", big)] + \
                [(f"s{i}", f) for i, f in enumerate(smalls)]:
            if f.done() and name not in order:
                order.append(name)
        if len(order) == 4:
            break
    assert f_short.done() and f_long.done()
    # the short request's freed slots were RESERVED: no small ran
    # before the starved big request
    assert order[0] == "big", order
    assert set(order[1:]) == {"s0", "s1", "s2"}
    sess.close()


def test_fresh_senior_is_not_reserved_for_prematurely(lm_params):
    """Before starve_ms elapses, juniors may backfill around a senior
    that doesn't fit — reservation is a starvation remedy, not a
    head-of-line blockade."""
    eng = _engine(1.0)
    clock = _FakeClock()
    sess = _cont_session(eng, clock, starve_ms=10_000.0)
    rs = np.random.RandomState(19)
    f_long = sess.submit(rs.randint(0, CFG.vocab, (2, 5)), n_new=6)
    sess.pump()                      # 2 slots busy
    big = sess.submit(rs.randint(0, CFG.vocab, (3, 5)), n_new=2)
    # a different lane (shorter prompt): an independent lane head
    small = sess.submit(rs.randint(0, CFG.vocab, (1, 4)), n_new=2)
    for _ in range(50):
        sess.pump()
        if small.done():
            break
    # the junior ran in the leftover slots while the big one waited
    assert small.done() and not big.done()
    for _ in range(200):
        sess.pump()
        if big.done():
            break
    assert big.done() and f_long.done()
    sess.close()


def test_requeue_bypasses_backpressure_and_completes(lm_params):
    """A requeued continuation (the cascade-escalation path) is exempt
    from the lane limit AND keeps its original submit time, so it
    outranks fresh juniors at the next refill."""
    eng = _engine(1.0)
    clock = _FakeClock()
    sess = _cont_session(eng, clock, starve_ms=10.0, max_queue=1)
    rs = np.random.RandomState(23)
    blocker = sess.submit(rs.randint(0, CFG.vocab, (4, 5)), n_new=4)
    sess.pump()                      # pool now full
    # fill the (1-deep) lane, then requeue past the limit
    f1 = sess.submit(rs.randint(0, CFG.vocab, (1, 5)), n_new=2)
    cont = sess._admit(rs.randint(0, CFG.vocab, (1, 5)),
                       None, 0, now=clock.now(), n_new=2)
    assert sess.queue.push(
        sess._admit(rs.randint(0, CFG.vocab, (1, 5)), None, 0,
                    now=clock.now(), n_new=2)) == "rejected"
    assert sess.queue.requeue(cont) == "queued"
    for _ in range(200):
        sess.pump()
        if f1.done() and cont.future.done():
            break
    assert blocker.done() and f1.done() and cont.future.done()
    assert not isinstance(cont.future.exception(), Exception)
    sess.close()


def test_impossible_request_rejected_at_submit(lm_params):
    eng = _engine(1.0)
    clock = _FakeClock()
    sess = _cont_session(eng, clock)
    fut = sess.submit(np.zeros((1, 30), np.int64), n_new=20)
    with pytest.raises(RequestRejected):
        fut.result(timeout=5)
    sess.close()


def test_session_stream_matches_oracle(lm_params):
    """End-to-end through the continuous session (worker thread): every
    caller's tokens/stages equal the per-request oracle."""
    eng = _engine(0.05)
    oracle = _engine(0.05)
    sess = eng.session(continuous=True, **POOL)
    rs = np.random.RandomState(29)
    prompts = rs.randint(0, CFG.vocab, (6, 5))
    futs = [sess.submit(prompts[i], n_new=6) for i in range(6)]
    outs = [f.result(timeout=300) for f in futs]
    view = sess.decoder.view_len
    sess.close()
    ot, os_ = oracle.generate(prompts, 6, max_len=view, mode="eager")
    for i, o in enumerate(outs):
        np.testing.assert_array_equal(o["tokens"][0], ot[i])
        np.testing.assert_array_equal(o["stages"][0], os_[i])
    assert eng.stats()["requests"]["requests"] == 6


# ---------------------------------------------------------------------------
# sharded: 1-device mesh in-process, 8 fake devices in a subprocess
# ---------------------------------------------------------------------------
def test_continuous_on_mesh_matches_oracle(lm_params):
    eng = LMDecodeEngine(CFG, lm_params, _dart(0.0),
                         mesh=make_serving_mesh())
    dec = eng.continuous(**POOL)
    rs = np.random.RandomState(31)
    reqs = _random_stream(rs, 4, dec.view_len)
    results = _drive(dec, rs, reqs)
    for tag, p, n in reqs:
        ot, os_ = eng.generate(p, n, max_len=dec.view_len, mode="eager")
        np.testing.assert_array_equal(results[tag][0], ot)
        np.testing.assert_array_equal(results[tag][1], os_)
    key_d = ("lm-cont-decode", dec.n_slots, dec.page_size,
             dec.pages_per_slot)
    assert eng.trace_counts[key_d] == 1


MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, %r)
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.routing import DartParams
    from repro.engine import LMDecodeEngine
    from repro.launch.mesh import make_serving_mesh
    from repro.models.transformer_lm import LMConfig, lm_init
    from repro.parallel.sharding import unzip

    cfg = LMConfig(name="lm-cont-8dev", n_layers=4, d_model=32,
                   n_heads=2, n_kv_heads=1, d_ff=64, vocab=32,
                   exit_layers=(0, 2), max_seq=64, remat=False)
    params = unzip(lm_init(jax.random.key(0), cfg))[0]
    dart = DartParams(tau=jnp.full((2,), 0.0), coef=jnp.ones(2),
                      beta_diff=0.1)
    eng = LMDecodeEngine(cfg, params, dart, mesh=make_serving_mesh())
    assert eng.n_replicas == 8, eng.n_replicas

    dec = eng.continuous(n_slots=8, page_size=4, max_len=16)
    assert dec.n_pages %% 8 == 0
    # slot pool and page store physically sharded over the data axis
    spec = jax.sharding.PartitionSpec("data")
    leaf = dec.pages[0]["c_kv"] if cfg.attn_kind == "mla" \\
        else dec.pages[0]["k"]
    assert leaf.sharding.spec == spec, leaf.sharding
    assert dec.alpha.sharding.spec == spec, dec.alpha.sharding

    rs = np.random.RandomState(0)
    reqs = [(i, rs.randint(0, cfg.vocab, (1 + int(rs.randint(2)),
                                          2 + int(rs.randint(6)))),
             1 + int(rs.randint(8))) for i in range(5)]
    results = {}
    pending = list(reqs)
    while len(results) < len(reqs):
        while pending:
            tag, p, n = pending[0]
            if not dec.can_admit(p.shape[0], p.shape[1], n):
                break
            dec.admit(p, n, tag=tag)
            pending.pop(0)
        dec.check_invariants()
        for tag, toks, stgs in dec.step():
            results[tag] = (toks, stgs)
        dec.check_invariants()
    for tag, p, n in reqs:
        ot, os_ = eng.generate(p, n, max_len=dec.view_len, mode="eager")
        np.testing.assert_array_equal(results[tag][0], ot)
        np.testing.assert_array_equal(results[tag][1], os_)
    assert dec.pool.in_use == 0 and dec.allocator.in_use == 0

    # ONE decode + ONE embed trace regardless of admission pattern,
    # with 8 replicas
    key_d = ("lm-cont-decode", dec.n_slots, dec.page_size,
             dec.pages_per_slot)
    key_e = ("lm-cont-embed", dec.n_slots)
    assert eng.trace_counts[key_d] == 1, eng.trace_counts
    assert eng.trace_counts[key_e] == 1, eng.trace_counts

    # telemetry reduced over replicas == an eager engine on the stream
    eager = LMDecodeEngine(cfg, params, dart)
    for _, p, n in reqs:
        eager.generate(p, n, max_len=dec.view_len, mode="eager")
    a, b = eng.stats(), eager.stats()
    assert a["served"] == b["served"], (a["served"], b["served"])
    np.testing.assert_array_equal(a["exit_counts"], b["exit_counts"])
    assert a["continuous"]["decode_steps"] > 0
    print("LM_CONT_8DEV_OK")
""" % os.path.join(os.path.dirname(__file__), "..", "src"))


def test_continuous_equivalence_on_8_devices():
    """Differential + invariants + one-trace assertions on an
    8-fake-device ("data",) mesh (subprocess)."""
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "LM_CONT_8DEV_OK" in r.stdout
