"""Segment-scan path (deepseek-size compile control) == unrolled path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models.transformer_lm import (lm_init, lm_forward,
                                         lm_multi_exit_loss,
                                         lm_prefill_scan, scan_segments)
from repro.parallel.sharding import unzip

KEY = jax.random.key(0)


def cfgs():
    cfg_u = registry.get_reduced("deepseek-v3-671b")
    return cfg_u, dataclasses.replace(cfg_u, layer_scan=True)


def test_segments_cover_all_layers():
    _, cfg_s = cfgs()
    segs = scan_segments(cfg_s)
    covered = []
    for a, b in segs:
        covered.extend(range(a, b))
    assert covered == list(range(cfg_s.n_dense_layers, cfg_s.n_layers))
    # exits land exactly at segment ends
    ends = {b - 1 for _, b in segs[:-1]}
    assert ends <= set(cfg_s.exit_layers)


def test_scan_forward_matches_unrolled():
    cfg_u, cfg_s = cfgs()
    pu, _ = unzip(lm_init(KEY, cfg_u))
    ps, _ = unzip(lm_init(KEY, cfg_s))
    toks = jax.random.randint(KEY, (2, 16), 0, cfg_u.vocab)
    fu = lm_forward(pu, toks, cfg_u)
    fs = lm_forward(ps, toks, cfg_s)
    np.testing.assert_allclose(fu["final_hidden"], fs["final_hidden"],
                               atol=1e-5)
    assert len(fs["exit_hidden"]) == cfg_s.n_exits
    for a, b in zip(fu["exit_hidden"], fs["exit_hidden"]):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_scan_loss_and_grad_match():
    cfg_u, cfg_s = cfgs()
    pu, _ = unzip(lm_init(KEY, cfg_u))
    ps, _ = unzip(lm_init(KEY, cfg_s))
    toks = jax.random.randint(KEY, (2, 16), 0, cfg_u.vocab)
    lu, _ = lm_multi_exit_loss(pu, toks, toks, cfg_u, xent_chunks=2)
    ls, _ = lm_multi_exit_loss(ps, toks, toks, cfg_s, xent_chunks=2)
    assert abs(float(lu) - float(ls)) < 1e-4
    g = jax.grad(lambda p: lm_multi_exit_loss(p, toks, toks, cfg_s,
                                              xent_chunks=2)[0])(ps)
    gn = sum(float(jnp.sum(jnp.square(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_scan_prefill_matches_forward():
    _, cfg_s = cfgs()
    ps, _ = unzip(lm_init(KEY, cfg_s))
    toks = jax.random.randint(KEY, (2, 16), 0, cfg_s.vocab)
    dense_c, seg_c, exit_h = lm_prefill_scan(ps, toks, cfg_s)
    full = lm_forward(ps, toks, cfg_s)
    np.testing.assert_allclose(exit_h[-1], full["final_hidden"][:, -1],
                               atol=3e-5)
    assert len(dense_c) == cfg_s.n_dense_layers
    segs = scan_segments(cfg_s)
    assert len(seg_c) == len(segs)
    for (a, b), c in zip(segs, seg_c):
        assert c["c_kv"].shape[0] == b - a
