"""Per-architecture smoke tests: every assigned arch's REDUCED config runs
one forward / train step on CPU with finite outputs and correct shapes.
The FULL configs are exercised only via the dry-run (no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import get_family, family_of
from repro.models.transformer_lm import lm_multi_exit_loss
from repro.models.dit import diffusion_loss
from repro.core import routing as R
from repro.parallel.sharding import unzip, param_count

KEY = jax.random.key(0)
ARCHS = sorted(registry.ASSIGNED)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = registry.get(arch)
    spec = {
        "tinyllama-1.1b": dict(n_layers=22, d_model=2048, n_heads=32,
                               n_kv_heads=4, d_ff=5632, vocab=32000),
        "internlm2-20b": dict(n_layers=48, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_ff=16384, vocab=92544),
        "granite-moe-3b-a800m": dict(n_layers=32, d_model=1536, n_heads=24,
                                     n_kv_heads=8, vocab=49155),
        "deepseek-v3-671b": dict(n_layers=61, d_model=7168, n_heads=128,
                                 vocab=129280),
        "dit-s2": dict(n_layers=12, d_model=384, n_heads=6, patch=2,
                       img_res=256),
        "dit-xl2": dict(n_layers=28, d_model=1152, n_heads=16, patch=2),
        "vit-h14": dict(n_layers=32, d_model=1280, n_heads=16, d_ff=5120,
                        patch=14),
        "vit-s16": dict(n_layers=12, d_model=384, n_heads=6, d_ff=1536,
                        patch=16),
        "convnext-b": dict(depths=(3, 3, 27, 3), dims=(128, 256, 512, 1024)),
        "resnet-152": dict(depths=(3, 8, 36, 3), width=64),
    }[arch]
    for k, v in spec.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    if arch == "granite-moe-3b-a800m":
        assert cfg.moe.n_experts == 40 and cfg.moe.top_k == 8
    if arch == "deepseek-v3-671b":
        assert cfg.moe.n_experts == 256 and cfg.moe.top_k == 8
        assert cfg.attn_kind == "mla" and cfg.moe.n_shared == 1 and cfg.mtp


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_smoke_forward_and_train_step(arch):
    cfg = registry.get_reduced(arch)
    fam_name = family_of(cfg)
    fam = get_family(cfg)
    p, _ = unzip(fam.init(KEY, cfg))
    assert param_count(p) > 0

    if fam_name == "lm":
        toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
        out = fam.forward(p, toks, cfg)
        assert len(out["exit_hidden"]) == cfg.n_exits
        for h in out["exit_hidden"]:
            assert h.shape == (2, 16, cfg.d_model)
            assert bool(jnp.all(jnp.isfinite(h)))
        loss, _ = lm_multi_exit_loss(p, toks, toks, cfg, xent_chunks=2)
        g = jax.grad(lambda p: lm_multi_exit_loss(
            p, toks, toks, cfg, xent_chunks=2)[0])(p)
        assert bool(jnp.isfinite(loss))
        gn = sum(float(jnp.sum(jnp.square(x))) for x in jax.tree.leaves(g))
        assert np.isfinite(gn) and gn > 0
    elif fam_name == "dit":
        lat = jax.random.normal(KEY, (2, cfg.latent_res, cfg.latent_res,
                                      cfg.in_channels))
        t = jnp.array([5, 200])
        y = jnp.array([0, 3])
        out = fam.forward(p, lat, t, y, cfg)
        assert len(out["exit_eps"]) == cfg.n_exits
        for e in out["exit_eps"]:
            assert e.shape == (2, cfg.latent_res, cfg.latent_res,
                               cfg.out_channels)
            assert bool(jnp.all(jnp.isfinite(e)))
        loss, _ = diffusion_loss(p, cfg, lat, y, KEY)
        assert bool(jnp.isfinite(loss))
    else:
        imgs = jax.random.uniform(KEY, (2, cfg.img_res, cfg.img_res, 3))
        out = fam.forward(p, imgs, cfg, train=True)
        el = out["exit_logits"]
        assert el.shape == (cfg.n_exits, 2, cfg.n_classes)
        assert bool(jnp.all(jnp.isfinite(el)))
        loss, _ = R.multi_exit_xent(el, jnp.array([0, 1]))
        assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_shapes_assigned(arch):
    shapes = registry.shapes(arch)
    names = {s.name for s in shapes}
    fam = family_of(registry.get(arch))
    if fam == "lm":
        assert names == {"train_4k", "prefill_32k", "decode_32k",
                         "long_500k"}
    elif fam == "dit":
        assert names == {"train_256", "gen_1024", "gen_fast", "train_1024"}
    else:
        assert names == {"cls_224", "cls_384", "serve_b1", "serve_b128"}


def test_cells_count_is_40():
    assert len(registry.cells()) == 40


def test_paper_testbeds_instantiate():
    tb = registry.paper_testbeds()
    assert set(tb) >= {"alexnet", "resnet-18", "vgg16", "levit-128s"}
