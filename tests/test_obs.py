"""repro.obs: the serving observability layer.

Covers, per the PR 8 acceptance list:

* metrics registry round-trip — what ``render()`` writes,
  ``parse_prometheus`` reads back verbatim (incl. escaped labels and
  histogram series), and the percentile estimator agrees between the
  registry and the dashboard;
* the tracer ring — bounded, drop-oldest, corruption-free on overflow,
  Chrome ``trace_event`` export loads as one track per lane;
* disabled mode is INERT: serving a seeded stream with obs off records
  nothing, registers nothing, and produces bit-identical outputs to the
  same stream served with obs ON (tracing must never perturb results);
* enabled mode RECONCILES: the sum of per-span exits equals the
  EngineState telemetry exit histogram after the ``stats()`` reduction,
  and every cataloged metric family shows up in the exposition;
* exporters — textfile + stdlib http endpoint serve parseable text, and
  ``tools/dartop.py --once --json`` consumes it end to end;
* structured logging — a dispatcher failure logs a ``repro.obs.*``
  record and counts ``dart_errors_total``, instead of only failing the
  future silently;
* continuous batching — slot spans carry slot ids, occupancy gauges
  export, and obs-on does not add compiled-step retraces
  (``trace_counts`` stays 1 per key).
"""
import json
import logging
import subprocess
import sys
import urllib.request
from concurrent.futures import Future
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.obs as obs
from repro.core.routing import DartParams
from repro.data.datasets import DatasetConfig, make_batch
from repro.engine import DartEngine, LMDecodeEngine
from repro.models.transformer_lm import LMConfig, lm_init
from repro.models.vit import ViTConfig, vit_init
from repro.obs import metrics as M
from repro.obs import trace as T
from repro.obs.stats import SUMMARY_KEYS
from repro.parallel.sharding import unzip
from repro.serving import AsyncDartServer, SchedulerConfig
from repro.serving.loop import _BucketScheduler
from repro.serving.request import DispatchError, Request

ROOT = Path(__file__).resolve().parent.parent
DATA = DatasetConfig(name="synth-cifar", n_train=128, n_eval=128)

LM_CFG = LMConfig(name="lm-obs-t", n_layers=4, d_model=32, n_heads=2,
                  n_kv_heads=1, d_ff=64, vocab=32, exit_layers=(0, 2),
                  max_seq=64, remat=False)


@pytest.fixture(autouse=True)
def _fresh_obs():
    obs.reset()
    yield
    obs.reset()


@pytest.fixture(scope="module")
def vit_engine_factory():
    vc = ViTConfig(name="vt-obs", img_res=32, patch=8, n_layers=3,
                   d_model=32, n_heads=2, d_ff=64, n_classes=10,
                   exit_layers=(0, 1))
    params, _ = unzip(vit_init(jax.random.key(0), vc))

    def make(**kw):
        kw.setdefault("cum_costs", [0.4, 0.7, 1.0])
        kw.setdefault("adapt", True)
        kw.setdefault("update_every", 10 ** 9)
        return DartEngine.from_config(
            vc, params,
            dart=DartParams(tau=jnp.full((2,), 0.2), coef=jnp.ones(2),
                            beta_diff=0.3), **kw)
    return make


@pytest.fixture(scope="module")
def eval_images():
    x, _ = make_batch(DATA, range(64), split="eval")
    return np.asarray(x)


def _serve_stream(engine, images):
    """Serve the images 4-at-a-time through a threaded server; returns
    (per-request results, server stats, the closed server).  Callers
    that scrape afterwards must keep the server referenced — the pull
    collector is weakref-bound to it."""
    srv = AsyncDartServer(engine, SchedulerConfig(max_batch=8,
                                                  flush_ms=1.0))
    futs = [srv.submit(images[i:i + 4], deadline_ms=10_000)
            for i in range(0, len(images), 4)]
    outs = [f.result(timeout=120) for f in futs]
    srv.close()
    return outs, srv.stats(), srv


# ---------------------------------------------------------------------------
# metrics: exposition round-trip
# ---------------------------------------------------------------------------
def test_counter_roundtrip_with_escaped_labels():
    r = M.Registry()
    nasty = 'quo"te\\back\nnewline'
    r.counter("dart_x_total", "help with\nnewline", ("lane",)).inc(
        3, lane=nasty)
    fams = M.parse_prometheus(r.render())
    assert fams["dart_x_total"]["type"] == "counter"
    assert fams["dart_x_total"]["help"] == "help with\nnewline"
    [(name, labels, value)] = fams["dart_x_total"]["samples"]
    assert (name, labels["lane"], value) == ("dart_x_total", nasty, 3.0)


def test_histogram_exposition_and_percentile():
    r = M.Registry()
    h = r.histogram("lat_ms", "x", ("lane",), buckets=(1, 10, 100))
    for v in (0.5, 5, 5, 50):
        h.observe(v, lane="a")
    fams = M.parse_prometheus(r.render())
    fam = fams["lat_ms"]
    assert fam["type"] == "histogram"
    by_le = {lab["le"]: v for n, lab, v in fam["samples"]
             if n == "lat_ms_bucket"}
    assert by_le == {"1": 1.0, "10": 3.0, "100": 4.0, "+Inf": 4.0}
    [(_, _, total)] = [s for s in fam["samples"] if s[0] == "lat_ms_sum"]
    assert total == pytest.approx(60.5)
    # registry estimator == dashboard estimator, cumulative -> counts
    assert h.percentile(50, lane="a") == pytest.approx(
        M.estimate_percentile((1, 10, 100), [1, 2, 1, 0], 50))


def test_registry_redeclaration_must_agree():
    r = M.Registry()
    c = r.counter("n_total", "x", ("lane",))
    assert r.counter("n_total", "x", ("lane",)) is c
    with pytest.raises(ValueError):
        r.counter("n_total", "x", ("member",))
    with pytest.raises(ValueError):
        r.gauge("n_total", "x", ("lane",))
    with pytest.raises(ValueError):
        c.inc(1, wrong="label")


def test_collectors_raising_or_dead_are_dropped():
    r = M.Registry()
    calls = []
    r.register_collector(lambda reg: calls.append("ok"))
    r.register_collector(lambda reg: "dead")
    r.register_collector(lambda reg: 1 / 0)
    r.collect()
    r.collect()
    assert calls == ["ok", "ok"]       # survivor ran twice
    with r._lock:
        assert len(r._collectors) == 1  # dead + raising removed


# ---------------------------------------------------------------------------
# tracer ring
# ---------------------------------------------------------------------------
def test_ring_overflow_drops_oldest_without_corruption():
    tr = T.Tracer(capacity=8)
    for i in range(100):
        tr.record("admit", ts=float(i), rid=i, lane=i % 3)
    spans = tr.spans()
    assert [s["rid"] for s in spans] == list(range(92, 100))
    assert len(tr) == 8 and tr.dropped == 92
    assert all(s["ts"] == float(s["rid"]) for s in spans)
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_chrome_trace_tracks_per_lane(tmp_path):
    tr = T.Tracer()
    tr.record("queue_wait", ts=1.0, dur=0.5, rid=0, lane=(0, 1))
    tr.record("compiled_step", ts=1.5, dur=0.25, rid=0, lane=(0, 1),
              n=np.int64(4))
    tr.record("exit", ts=2.0, rid=1, lane=(1, 0),
              exits=np.asarray([2, 2]))
    path = tmp_path / "spans.jsonl"
    assert tr.export_jsonl(str(path)) == 3
    doc = T.chrome_trace(T.load_jsonl(str(path)))
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    xs = [e for e in events if e["ph"] == "X"]
    assert len(meta) == 2 and len(xs) == 3      # one track per lane
    assert {e["tid"] for e in xs} == {m["tid"] for m in meta}
    assert xs[0]["ts"] == pytest.approx(1.0e6)  # seconds -> micros
    assert xs[0]["dur"] == pytest.approx(0.5e6)
    json.dumps(doc)                              # fully serializable


# ---------------------------------------------------------------------------
# disabled mode is inert; enabled mode reconciles
# ---------------------------------------------------------------------------
def test_disabled_inert_and_bit_identical(vit_engine_factory, eval_images):
    assert not obs.is_enabled()
    off, _, _ = _serve_stream(vit_engine_factory(), eval_images)
    assert len(obs.get_tracer()) == 0
    assert "dart_" not in obs.get_registry().render()

    obs.configure(enabled=True)
    on, _, _srv = _serve_stream(vit_engine_factory(), eval_images)
    assert len(obs.get_tracer()) > 0
    for a, b in zip(off, on):
        for k in ("pred", "conf", "exit_idx", "alpha", "macs"):
            assert np.array_equal(a[k], b[k]), k


def test_spans_reconcile_with_engine_telemetry(vit_engine_factory,
                                               eval_images):
    obs.configure(enabled=True)
    eng = vit_engine_factory()
    _, stats, srv = _serve_stream(eng, eval_images)
    for k in SUMMARY_KEYS:
        assert k in stats
    span_exits = np.zeros(eng.n_exits, np.int64)
    for s in obs.get_tracer().spans("exit"):
        for e in s["exits"]:
            span_exits[int(e)] += 1
    assert np.array_equal(span_exits, np.asarray(stats["exit_counts"]))
    assert stats["scheduler"]["starved"] == 0
    # one admit + queue_wait + compiled_step per request
    n_req = len(eval_images) // 4
    assert len(obs.get_tracer().spans("admit")) == n_req
    assert len(obs.get_tracer().spans("queue_wait")) == n_req

    fams = M.parse_prometheus(obs.get_registry().render())
    for fam in ("dart_requests_total", "dart_requests_completed_total",
                "dart_request_latency_ms", "dart_exits_total",
                "dart_flushes_total", "dart_lane_daes",
                "dart_lane_speedup", "dart_lane_power_eff",
                "dart_depth_prior", "dart_queue_depth",
                "dart_scheduler_events_total", "dart_engine_latency_ms",
                "dart_engine_exits_total", "dart_trace_total",
                "dart_recompiles_total", "dart_kernel_dispatch_total"):
        assert fam in fams, fam
    # counters mirror the scheduler's own view
    comp = sum(v for n, lab, v in
               fams["dart_requests_completed_total"]["samples"])
    assert comp == stats["scheduler"]["completed"] == n_req


# ---------------------------------------------------------------------------
# exporters + dashboard
# ---------------------------------------------------------------------------
def test_textfile_http_and_dartop_roundtrip(vit_engine_factory,
                                            eval_images, tmp_path):
    prom = tmp_path / "metrics.prom"
    obs.configure(enabled=True, textfile=str(prom), http_port=0)
    _, _, srv = _serve_stream(vit_engine_factory(), eval_images)
    obs.flush_textfile()

    # the http endpoint serves the same (parseable) exposition
    port = obs.OBS.http_port
    with urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=10) as r:
        assert r.headers["Content-Type"].startswith("text/plain")
        fams = M.parse_prometheus(r.read().decode())
    assert "dart_requests_total" in fams
    assert "dart_request_latency_ms" in fams

    out = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "dartop.py"),
         "--once", "--json", "--file", str(prom)],
        capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    view = json.loads(out.stdout)
    assert view["scheduler"]["completed"] == len(eval_images) // 4
    assert view["latency_ms"]                       # per-lane p50/p95
    for d in view["latency_ms"].values():
        assert set(d) == {"p50", "p95", "count"}
    assert sum(sum(h.values()) for h in view["exits"].values()) \
        == len(eval_images)
    assert view["recompiles"] == 0


# ---------------------------------------------------------------------------
# structured logging on dispatcher failure (satellite 2)
# ---------------------------------------------------------------------------
class _Boom(RuntimeError):
    pass


class _FailingScheduler(_BucketScheduler):
    def _admit(self, x, deadline_ms, priority, *, now, **kw):
        return Request(rid=next(self._rid), x=np.asarray(x), n=1,
                       alpha=np.zeros(1, np.float32), lane=0,
                       predicted_cost=1.0, priority=priority,
                       t_submit=now, deadline_s=None, future=Future())

    def _dispatch(self, reqs, reason):
        raise _Boom("engine exploded")


def test_dispatch_failure_is_logged_and_counted(caplog):
    sched = _FailingScheduler(SchedulerConfig(), start=False)
    fut = sched.submit(np.zeros(3))
    with caplog.at_level(logging.ERROR, logger="repro.obs"):
        sched.flush()
    with pytest.raises(DispatchError) as ei:
        fut.result(timeout=5)
    assert isinstance(ei.value.cause, _Boom)
    assert ei.value.stage == "dispatch"
    assert sched.counters["dispatch_errors"] == 1
    errs = obs.get_registry().counter(
        "dart_errors_total", "scheduler/dispatcher errors by component",
        ("component",))
    assert errs.value(component="dispatch") == 1
    rec = [r for r in caplog.records
           if r.name == "repro.obs.dispatch"]
    assert rec and "bucket dispatch failed" in rec[0].getMessage()
    assert "rids=" in rec[0].getMessage()


# ---------------------------------------------------------------------------
# continuous batching: slot spans, occupancy gauges, no retraces
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def lm_engine():
    params = unzip(lm_init(jax.random.key(0), LM_CFG))[0]
    return LMDecodeEngine(LM_CFG, params, DartParams(
        tau=jnp.full((2,), 0.0), coef=jnp.ones(2), beta_diff=0.1))


def test_continuous_slot_spans_and_occupancy(lm_engine):
    obs.configure(enabled=True)
    sess = lm_engine.session(continuous=True, n_slots=4, page_size=4,
                             max_len=16, start=False)
    rs = np.random.RandomState(3)
    futs = [sess.submit(rs.randint(0, LM_CFG.vocab, (1, 4)), n_new=3)
            for _ in range(5)]
    sess.flush()
    for f in futs:
        f.result(timeout=120)

    slot_spans = obs.get_tracer().spans("slot")
    assert len(slot_spans) == 5
    assert all(s["slots"] for s in slot_spans)       # real slot ids
    exits = obs.get_tracer().spans("exit")
    assert sum(s["n_tokens"] for s in exits) == 5 * 3

    fams = M.parse_prometheus(obs.get_registry().render())
    occ = {n: fams[n]["samples"][0][2]
           for n in ("dart_slots_total", "dart_pages_total",
                     "dart_pages_peak", "dart_slots_in_use",
                     "dart_pages_in_use")}
    assert occ["dart_slots_total"] == 4
    assert occ["dart_pages_peak"] >= 1
    assert occ["dart_slots_in_use"] == 0             # all retired
    assert "dart_lm_tokens_total" in fams
    assert "starved" in sess.stats()["scheduler"]
    # obs-on added no compiled-step retraces
    assert all(c == 1 for c in lm_engine.trace_counts.values())
    assert lm_engine.trace_counts
