"""repro.cascade: difficulty-routed multi-model cascade serving.

Covers: the escalation gate/prior math against hand-computed values,
batched cascade inference bit-identical to the per-request oracle
(masked and compacted), cascade-absolute cost accounting recomputed
from member curves, the joint cascade DP beating independent
calibration on its own objective, the async scheduler integration
(facade dispatch, escalation re-enqueue, partial-escalation future
assembly, requeue bypassing backpressure, per-lane DAES/stats), and an
8-fake-device subprocess run asserting sharded-member equivalence plus
the one-trace-per-(member, bucket) compile guarantee.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import difficulty as DIFF
from repro.core import policy as POL
from repro.core.routing import DartParams
from repro.data.datasets import DatasetConfig, make_batch
from repro.engine import DartEngine
from repro.engine.registry import get_optimizer
from repro.models.vit import ViTConfig, vit_init
from repro.parallel.sharding import unzip
from repro.cascade import CascadeEngine, CascadeAsyncServer
from repro.serving import (AsyncDartServer, RequestQueue, SchedulerConfig)
from repro.serving.request import Request

DATA = DatasetConfig(name="synth-cifar", n_train=128, n_eval=128)


def _make_member(seed, n_layers, d_model, costs):
    vc = ViTConfig(name=f"casc-vt{seed}", img_res=32, patch=8,
                   n_layers=n_layers, d_model=d_model, n_heads=2,
                   d_ff=2 * d_model, n_classes=10,
                   exit_layers=tuple(range(n_layers - 1)))
    params, _ = unzip(vit_init(jax.random.key(seed), vc))
    return DartEngine.from_config(
        vc, params, cum_costs=costs, adapt=False,
        dart=DartParams(tau=jnp.full((n_layers - 1,), 0.2),
                        coef=jnp.ones(n_layers - 1), beta_diff=0.3))


@pytest.fixture(scope="module")
def members():
    return (_make_member(0, 3, 32, [0.4, 0.7, 1.0]),
            _make_member(1, 4, 48, [0.3, 0.55, 0.8, 1.0]))


@pytest.fixture(scope="module")
def eval_images():
    x, _ = make_batch(DATA, range(64), split="eval")
    return np.asarray(x)


def _partial_theta(members, x, beta_esc):
    """A theta that escalates roughly half the stream — makes the
    partial-escalation paths (mixed members within one request) real."""
    small = members[0]
    alpha = np.asarray(small._alpha(jnp.asarray(x)))
    out = small.infer(x, mode="masked", record=False, alpha=alpha)
    margin = np.asarray(out["conf"]) - beta_esc * alpha
    return float(np.quantile(margin, 0.5))


@pytest.fixture(scope="module")
def cascade(members, eval_images):
    theta = _partial_theta(members, eval_images, beta_esc=0.1)
    return CascadeEngine(list(members), member_costs=[0.25, 1.0],
                         theta=np.array([theta]), beta_esc=0.1)


# ---------------------------------------------------------------------------
# construction + gate math
# ---------------------------------------------------------------------------
def test_constructor_validation(members):
    small, big = members
    with pytest.raises(ValueError, match="at least 2"):
        CascadeEngine([small])
    with pytest.raises(ValueError, match="increasing capacity"):
        CascadeEngine([small, big], member_costs=[1.0, 0.25])
    with pytest.raises(ValueError, match="3 costs for 2"):
        CascadeEngine([small, big], member_costs=[0.25, 0.5, 1.0])
    with pytest.raises(ValueError, match="theta"):
        CascadeEngine([small, big], member_costs=[0.25, 1.0],
                      theta=np.array([0.3, 0.3]))
    # costs normalize to biggest = 1
    c = CascadeEngine([small, big], member_costs=[1.0, 4.0])
    np.testing.assert_allclose(c.member_costs, [0.25, 1.0])


def test_escalation_gate_hand_computed():
    alpha = np.array([0.0, 0.5, 1.0])
    conf = np.array([0.55, 0.55, 0.55])
    # eff = clip(0.4 + 0.3*alpha) = [0.4, 0.55, 0.7]; gate is conf <= eff
    np.testing.assert_array_equal(
        POL.escalation_gate(0.4, alpha, conf, 0.3),
        [False, True, True])
    # sentinels: clip(-1 + .3a) = 0 never catches softmax conf > 0;
    # clip(1 + .3a) = 1 catches everything
    assert not POL.escalation_gate(-1.0, alpha, conf, 0.3).any()
    assert POL.escalation_gate(1.0, alpha, conf, 0.3).all()


def test_escalation_prior_hand_computed():
    a = POL.escalation_alpha(np.array([0.2, 0.8]), np.array([0.9, 0.1]),
                             prior_weight=0.5)
    # 0.5*0.2 + 0.5*(1-0.9) = 0.15 ; 0.5*0.8 + 0.5*0.9 = 0.85
    np.testing.assert_allclose(a, [0.15, 0.85], atol=1e-7)
    # w=0 keeps the raw alpha, w=1 is pure residual uncertainty
    np.testing.assert_allclose(
        POL.escalation_alpha(np.array([0.3]), np.array([0.4]), 0.0), [0.3])
    np.testing.assert_allclose(
        POL.escalation_alpha(np.array([0.3]), np.array([0.4]), 1.0), [0.6])


def test_theta_sentinels_control_escalation(cascade, eval_images):
    x = eval_images[:16]
    never = CascadeEngine(cascade.members, member_costs=[0.25, 1.0],
                          theta=np.array([-1.0]), beta_esc=0.1)
    out = never.infer(x)
    assert (out["member"] == 0).all()
    always = CascadeEngine(cascade.members, member_costs=[0.25, 1.0],
                           theta=np.array([1.0]), beta_esc=0.1)
    out = always.infer(x)
    assert (out["member"] == 1).all()


# ---------------------------------------------------------------------------
# batched == per-request oracle (the tentpole equivalence)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["masked", "compacted"])
def test_batched_matches_oracle(cascade, eval_images, mode):
    out = cascade.infer(eval_images, mode=mode)
    ref = cascade.infer(eval_images, mode="oracle")
    # the theta fixture is tuned for a real mix of terminal members
    assert len(np.unique(ref["member"])) == 2, ref["member"]
    for k in ("pred", "exit_idx", "member"):
        np.testing.assert_array_equal(out[k], ref[k], err_msg=k)
    np.testing.assert_allclose(out["conf"], ref["conf"], atol=2e-5)
    np.testing.assert_allclose(out["macs"], ref["macs"], atol=1e-9)
    np.testing.assert_allclose(out["alpha"], ref["alpha"], atol=2e-5)


def test_macs_accounting_recomputed(cascade, eval_images):
    """Cascade macs = every visited member's routed cost in cascade
    units, recomputed from the member curves."""
    x = eval_images[:32]
    out = cascade.infer(x)
    alpha = np.asarray(cascade._alpha(jnp.asarray(x)))
    small = cascade.members[0].infer(x, mode="masked", record=False,
                                     alpha=alpha)
    cum0 = np.asarray(cascade.members[0].cum_costs, float)
    cum1 = np.asarray(cascade.members[1].cum_costs, float)
    want = 0.25 * cum0[np.asarray(small["exit_idx"])] / cum0[-1]
    esc = out["member"] == 1
    want[esc] += 1.0 * cum1[out["exit_idx"][esc]] / cum1[-1]
    np.testing.assert_allclose(out["macs"], want, atol=1e-9)
    # stats() agrees with the per-sample sum
    c = CascadeEngine(cascade.members, member_costs=[0.25, 1.0],
                      theta=cascade.theta, beta_esc=cascade.beta_esc)
    c.infer(x, record=True)
    st = c.stats()
    assert st["admitted"] == 32
    assert st["escalated"] == [int(esc.sum())]
    np.testing.assert_allclose(st["total_macs"], out["macs"].sum(),
                               rtol=1e-6)


def test_cum_costs_is_biggest_member_curve(cascade):
    np.testing.assert_allclose(
        cascade.cum_costs, np.asarray([0.3, 0.55, 0.8, 1.0]))
    assert cascade.n_exits == 4
    # the flush planner's bucket key is conservative across members
    assert cascade.bucket_key(5) == max(m.bucket_key(5)
                                        for m in cascade.members)


# ---------------------------------------------------------------------------
# joint cascade DP (tentpole optimizer)
# ---------------------------------------------------------------------------
def make_cascade_calibration(seed=0, n=900, member_exits=(3, 4),
                             member_costs=(0.25, 1.0)):
    """Synthetic cascade pool: a weak-but-cheap member and a strong one,
    confidence correlated with correctness, difficulty degrading the
    small member faster (the regime where escalation pays)."""
    rs = np.random.RandomState(seed)
    alpha = rs.rand(n)
    ms = []
    for m, e in enumerate(member_exits):
        top = 0.75 + 0.2 * m           # the big member is simply better
        skill = np.linspace(0.5, top, e)
        degrade = (0.45 - 0.2 * m) * alpha[:, None] * (1 - skill[None])
        p = np.clip(skill[None] - degrade, 0.05, 0.99)
        correct = (rs.rand(n, e) < p).astype(float)
        conf = np.clip(0.55 * correct + 0.25 * rs.rand(n, e)
                       + 0.2 * skill[None], 0, 1)
        cum = np.linspace(1.0 / e, 1.0, e)
        ms.append(POL.CalibrationData(conf, correct, alpha, cum,
                                      labels=rs.randint(0, 10, n)))
    return POL.CascadeCalibrationData(ms, np.asarray(member_costs))


def test_cascade_dp_beats_independent():
    data = make_cascade_calibration()
    dp = POL.optimize_cascade_dp(data, beta_opt=0.5)
    ind = POL.optimize_cascade_independent(data, beta_opt=0.5)
    assert dp.objective >= ind.objective - 1e-9
    assert dp.theta.shape == (1,)
    # the reported objective is exactly the replayed cascade J
    j = POL.cascade_objective(data, dp.members, dp.theta, beta_opt=0.5,
                              beta_esc=dp.beta_esc,
                              prior_weight=dp.prior_weight)
    np.testing.assert_allclose(dp.objective, j, atol=1e-12)
    assert dp.method == "cascade_dp"
    assert len(dp.diagnostics["seed_objectives"]) == 2


def test_simulate_cascade_cost_endpoints():
    data = make_cascade_calibration(n=300)
    pols = [POL.optimize_joint_dp(d, beta_opt=0.5) for d in data.members]
    # theta=-1: nobody escalates -> cost is the small member's routed
    # cost alone, scaled to cascade units
    sim = POL.simulate_cascade(data, pols, [-1.0])
    assert (sim["member"] == 0).all()
    cum = np.asarray(data.members[0].cum_costs)
    np.testing.assert_allclose(
        sim["cost"], 0.25 * cum[sim["exit_idx"]] / cum[-1], atol=1e-12)
    # theta=+1: everybody escalates -> both members pay
    sim = POL.simulate_cascade(data, pols, [1.0])
    assert (sim["member"] == 1).all()
    assert (sim["cost"] > 0.25 / len(cum) - 1e-12).all()


def test_optimizer_registry_exposes_cascade():
    assert get_optimizer("cascade_dp") is POL.optimize_cascade_dp
    assert get_optimizer("cascade_independent") is \
        POL.optimize_cascade_independent


def test_calibrate_installs_joint_policy(members):
    cascade = CascadeEngine(list(members), member_costs=[0.25, 1.0],
                            beta_esc=0.1)
    cal = cascade.collect_calibration(DATA, n=96, batch=32)
    assert isinstance(cal, POL.CascadeCalibrationData)
    np.testing.assert_allclose(cal.members[1].alpha, cal.members[0].alpha)
    pol = cascade.calibrate(cal, sweeps=1)
    assert pol.method == "cascade_dp"
    np.testing.assert_allclose(np.asarray(cascade.theta), pol.theta)
    for eng, p in zip(cascade.members, pol.members):
        np.testing.assert_allclose(np.asarray(eng.state.tau), p.tau,
                                   atol=1e-7)
    # the installed policy is what batched inference routes with
    out = cascade.infer(np.asarray(make_batch(DATA, range(16),
                                              split="eval")[0]))
    ref = cascade.infer(np.asarray(make_batch(DATA, range(16),
                                              split="eval")[0]),
                        mode="oracle")
    np.testing.assert_array_equal(out["member"], ref["member"])
    np.testing.assert_array_equal(out["exit_idx"], ref["exit_idx"])


# ---------------------------------------------------------------------------
# async scheduler integration
# ---------------------------------------------------------------------------
class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def test_facade_dispatches_to_cascade_server(cascade, members):
    srv = AsyncDartServer(cascade, SchedulerConfig(pipeline_depth=0),
                          start=False)
    assert type(srv) is CascadeAsyncServer
    plain = AsyncDartServer(members[0], SchedulerConfig(pipeline_depth=0),
                            start=False)
    assert type(plain) is AsyncDartServer
    srv.close()
    plain.close()


def test_serving_matches_oracle(cascade, eval_images):
    """Requests served through the scheduler (escalations re-enqueued
    across members) resolve to the per-request oracle's outputs."""
    ref = cascade.infer(eval_images[:48], mode="oracle")
    with AsyncDartServer(cascade, SchedulerConfig(
            max_batch=16, flush_ms=2.0, pipeline_depth=0)) as srv:
        futs = [srv.submit(eval_images[i:i + 6]) for i in range(0, 48, 6)]
        res = [f.result(timeout=120) for f in futs]
        st = srv.stats()
        esc = srv.counters.get("escalated", 0)
    got = {k: np.concatenate([r[k] for r in res])
           for k in ("pred", "conf", "exit_idx", "member", "macs")}
    for k in ("pred", "exit_idx", "member"):
        np.testing.assert_array_equal(got[k], ref[k], err_msg=k)
    np.testing.assert_allclose(got["conf"], ref["conf"], atol=2e-5)
    np.testing.assert_allclose(got["macs"], ref["macs"], atol=1e-9)
    assert esc == int((ref["member"] == 1).sum())
    # per-(terminal member, class) DAES lanes + cascade stats surfaced
    assert all(isinstance(k, tuple) and len(k) == 2 for k in st["daes"])
    assert set(m for m, _ in st["daes"]) == set(np.unique(ref["member"]))
    assert st["admitted"] == 48
    assert "requests" in st


def test_partial_escalation_assembles_one_future(cascade, eval_images):
    """One request whose samples split across members still resolves as
    a single future with per-sample member/macs stitched in order."""
    ref = cascade.infer(eval_images[:48], mode="oracle")
    mixed = np.concatenate([eval_images[:48][ref["member"] == 0][:3],
                            eval_images[:48][ref["member"] == 1][:3]])
    clock = FakeClock()
    srv = AsyncDartServer(cascade, SchedulerConfig(
        max_batch=8, flush_ms=1.0, pipeline_depth=0), clock=clock,
        start=False)
    fut = srv.submit(mixed)
    clock.advance(0.01)
    assert srv.pump()                    # member-0 bucket; escalations
    assert not fut.done()                # ... leave the future pending
    assert srv.counters.get("escalated", 0) == 3
    lanes = srv.queue.keys()
    assert lanes and all(l[0] == 1 for l in lanes)
    clock.advance(0.01)
    assert srv.pump()                    # member-1 bucket resolves it
    res = fut.result(timeout=5)
    np.testing.assert_array_equal(res["member"], [0, 0, 0, 1, 1, 1])
    r2 = cascade.infer(mixed, mode="oracle")
    np.testing.assert_array_equal(res["pred"], r2["pred"])
    np.testing.assert_array_equal(res["exit_idx"], r2["exit_idx"])
    np.testing.assert_allclose(res["macs"], r2["macs"], atol=1e-9)
    srv.close()


def test_requeue_bypasses_backpressure():
    q = RequestQueue(max_queue=1, policy="reject")
    from concurrent.futures import Future

    def req(rid):
        return Request(rid=rid, x=np.zeros((1, 2)), n=1,
                       alpha=np.zeros(1), lane=(1, 0), predicted_cost=0.1,
                       priority=0, t_submit=0.0, deadline_s=None,
                       future=Future())
    assert q.push(req(0)) == "queued"
    assert q.push(req(1)) == "rejected"       # lane full
    assert q.requeue(req(2)) == "queued"      # escalation: always admits
    assert q.depth((1, 0)) == 2


def test_cascade_planner_priors_and_member_choice(cascade):
    from repro.cascade.serving import CascadePlanner
    pl = CascadePlanner(cascade, edges=(0.35, 0.65))
    # cold start: optimistic, smallest member for every class
    assert [pl.choose_member(c) for c in range(3)] == [0, 0, 0]
    # a class observed to always escalate routes straight to the big one
    pl.observe_escalation(0, 2, np.ones(8, bool))
    assert pl.choose_member(2) == 1
    assert pl.choose_member(0) == 0
    pr = pl.priors()
    assert pr["escalation"] == [[None, None, 1.0]]
    assert len(pr["depth"]) == 2
    # predicted cost from the big member is just its own depth prior
    a = 0.9
    want = 1.0 * pl.members[1].predicted_cost(a, 2)
    np.testing.assert_allclose(pl.predicted_cost(1, a, 2), want)
    # from the small member it adds the escalation-weighted big cost
    want0 = 0.25 * pl.members[0].predicted_cost(a, 2) \
        + 1.0 * 1.0 * pl.members[1].predicted_cost(a, 2)
    np.testing.assert_allclose(pl.predicted_cost(0, a, 2), want0)


def test_default_edges_single_source(members):
    """Satellite: (0.35, 0.65) lives in ONE place — core.difficulty."""
    from repro.cascade.serving import CascadePlanner
    from repro.serving.planner import AdmissionPlanner
    assert DIFF.DEFAULT_EDGES == (0.35, 0.65)
    assert tuple(AdmissionPlanner(members[0]).edges) == DIFF.DEFAULT_EDGES
    assert SchedulerConfig().edges == DIFF.DEFAULT_EDGES
    casc = CascadeEngine(list(members), member_costs=[0.25, 1.0])
    assert tuple(CascadePlanner(casc).edges) == DIFF.DEFAULT_EDGES


# ---------------------------------------------------------------------------
# sharded members on 8 fake devices (subprocess)
# ---------------------------------------------------------------------------
MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, %r)
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.routing import DartParams
    from repro.data.datasets import DatasetConfig, make_batch
    from repro.engine import DartEngine
    from repro.launch.mesh import make_serving_mesh
    from repro.models.vit import ViTConfig, vit_init
    from repro.parallel.sharding import unzip
    from repro.cascade import CascadeEngine

    DATA = DatasetConfig(name="synth-cifar", n_train=128, n_eval=128)
    mesh = make_serving_mesh()

    def member(seed, n_layers, d_model, costs):
        vc = ViTConfig(name=f"casc-sh{seed}", img_res=32, patch=8,
                       n_layers=n_layers, d_model=d_model, n_heads=2,
                       d_ff=2 * d_model, n_classes=10,
                       exit_layers=tuple(range(n_layers - 1)))
        params, _ = unzip(vit_init(jax.random.key(seed), vc))
        return DartEngine.from_config(
            vc, params, mesh=mesh, cum_costs=costs, adapt=False,
            dart=DartParams(tau=jnp.full((n_layers - 1,), 0.2),
                            coef=jnp.ones(n_layers - 1), beta_diff=0.3))

    small = member(0, 3, 32, [0.4, 0.7, 1.0])
    big = member(1, 4, 48, [0.3, 0.55, 0.8, 1.0])
    assert small.n_replicas == big.n_replicas == 8

    x, _ = make_batch(DATA, range(48), split="eval")
    x = np.asarray(x)
    # pick a theta that splits the stream across members
    alpha = np.asarray(small._alpha(jnp.asarray(x)))
    probe = small.infer(x, mode="eager", alpha=alpha)
    theta = float(np.quantile(np.asarray(probe["conf"])
                              - 0.1 * alpha, 0.5))
    casc = CascadeEngine([small, big], member_costs=[0.25, 1.0],
                         theta=np.array([theta]), beta_esc=0.1)

    ref = casc.infer(x, mode="oracle")
    assert len(np.unique(ref["member"])) == 2, ref["member"]
    for mode in ("masked", "compacted"):
        out = casc.infer(x, mode=mode)
        for k in ("pred", "exit_idx", "member"):
            np.testing.assert_array_equal(out[k], ref[k], err_msg=k)
        np.testing.assert_allclose(out["conf"], ref["conf"], rtol=2e-5,
                                   atol=2e-5)
        np.testing.assert_allclose(out["macs"], ref["macs"], rtol=2e-5,
                                   atol=2e-5)

    # one trace per (member, stage, bucket) even with 8 replicas and
    # varying batch shapes
    for n in (3, 17, 48):
        casc.infer(x[:n], mode="masked")
    tc = casc.trace_counts
    assert tc, "sharded members must record traces"
    assert all(v == 1 for v in tc.values()), tc
    assert set(k[0] for k in tc) <= {0, 1}, tc
    print("CASCADE_SHARDED_OK")
""" % os.path.join(os.path.dirname(__file__), "..", "src"))


def test_sharded_cascade_on_8_devices():
    """Batched == oracle on sharded members + the per-(member, bucket)
    single-trace guarantee, on an 8-fake-device mesh (subprocess)."""
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "CASCADE_SHARDED_OK" in r.stdout
