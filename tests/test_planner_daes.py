"""Satellite coverage: AdmissionPlanner telemetry priors and the DAES
metric stack (Eqs. 9, 20-22) against hand-computed values."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import daes as DAES
from repro.core import difficulty as DIFF
from repro.core.routing import DartParams
from repro.engine import DartEngine
from repro.models.vit import ViTConfig, vit_init
from repro.parallel.sharding import unzip
from repro.serving import AdmissionPlanner

CUM = [0.4, 0.7, 1.0]


@pytest.fixture(scope="module")
def engine():
    vc = ViTConfig(name="pl-vt", img_res=32, patch=8, n_layers=3,
                   d_model=32, n_heads=2, d_ff=64, n_classes=10,
                   exit_layers=(0, 1))
    params, _ = unzip(vit_init(jax.random.key(0), vc))
    return DartEngine.from_config(
        vc, params, cum_costs=CUM, adapt=False,
        dart=DartParams(tau=jnp.full((2,), 0.2), coef=jnp.ones(2),
                        beta_diff=0.3))


# ---------------------------------------------------------------------------
# AdmissionPlanner priors
# ---------------------------------------------------------------------------
def test_observe_folds_per_class_ema(engine):
    pl = AdmissionPlanner(engine, edges=(0.35, 0.65), ema_decay=0.9)
    assert pl.priors() == [None, None, None]
    # first observation SETS the class EMA (no decay on cold start):
    # class 0 (alpha .1, .2) depths (0, 2) -> 1.0; class 2 (alpha .9)
    # depth 1 -> 1.0
    pl.observe(np.array([0, 2, 1]), np.array([0.1, 0.2, 0.9]))
    pr = pl.priors()
    np.testing.assert_allclose(pr[0], 1.0)
    assert pr[1] is None
    np.testing.assert_allclose(pr[2], 1.0)
    # second observation folds: 0.9*1.0 + 0.1*2.0 = 1.1 for class 0
    pl.observe(np.array([2]), np.array([0.1]))
    np.testing.assert_allclose(pl.priors()[0], 1.1)
    np.testing.assert_allclose(pl.priors()[2], 1.0)
    assert pl.priors()[1] is None


def test_predicted_cost_fallback_chain(engine):
    pl = AdmissionPlanner(engine, edges=(0.35, 0.65), ema_decay=0.9)
    # 1. never-seen class, never-served engine: linear-in-alpha depth
    #    alpha=0.5 -> depth 1.0 -> interp on cum/cum[-1] = 0.7
    np.testing.assert_allclose(pl.predicted_cost(0.5, 1), 0.7)
    #    fractional depth interpolates the curve: 0.25*(n_exits-1)=0.5
    #    -> (0.4 + 0.7)/2 = 0.55
    np.testing.assert_allclose(pl.predicted_cost(0.25, 0), 0.55)
    # 2. any observation seeds the GLOBAL depth fallback, which then
    #    covers classes never seen themselves
    pl.observe(np.array([2, 2]), np.array([0.9, 0.9]))      # class 2
    np.testing.assert_allclose(pl.predicted_cost(0.1, 0), 1.0)
    # 3. the per-class EMA wins over the global fallback where it exists
    pl.observe(np.array([0, 0]), np.array([0.1, 0.1]))      # class 0
    np.testing.assert_allclose(pl.predicted_cost(0.1, 0), 0.4)


def test_classify_uses_mean_alpha(engine):
    pl = AdmissionPlanner(engine, edges=(0.35, 0.65))
    dclass, cost = pl.classify(np.array([0.8, 1.0]))
    assert dclass == 2
    np.testing.assert_allclose(cost, pl.predicted_cost(0.9, 2))
    assert pl.classify(np.array([0.1]))[0] == 0


def test_admit_alpha_matches_engine(engine):
    """Admission's alpha is the engine's own Eq. 8 estimator — computed
    once, handed to dispatch."""
    pl = AdmissionPlanner(engine)
    x = np.asarray(jax.random.normal(jax.random.key(1), (4, 32, 32, 3)))
    alpha, dclass, cost = pl.admit(x)
    np.testing.assert_allclose(
        alpha, np.asarray(engine._alpha(jnp.asarray(x))), atol=1e-6)
    assert dclass == int(DIFF.difficulty_class(float(alpha.mean()),
                                               pl.edges))
    assert cost > 0


# ---------------------------------------------------------------------------
# DAES metric stack (Eqs. 9, 20-22), hand-computed
# ---------------------------------------------------------------------------
def _meas():
    static = DAES.MethodMeasurement("static", accuracy=0.92, time_s=0.10,
                                    macs=4e8, energy_j=2.0)
    m = DAES.MethodMeasurement("dart", accuracy=0.90, time_s=0.04,
                               macs=1e8, energy_j=0.6)
    return static, m


def test_speedup_power_daes_hand_computed():
    static, m = _meas()
    np.testing.assert_allclose(DAES.speedup(static, m), 2.5)       # Eq.20
    np.testing.assert_allclose(
        DAES.power_efficiency(static, m, "macs"), 4.0)             # Eq.22
    np.testing.assert_allclose(
        DAES.power_efficiency(static, m, "measured"), 2.0 / 0.6)
    # Eq. 9: 0.90 * 2.5 * 4.0 / (1 + 0.85)
    np.testing.assert_allclose(
        DAES.daes(static, m, 0.85, "macs"), 0.9 * 2.5 * 4.0 / 1.85)
    np.testing.assert_allclose(DAES.avg_power(m), 0.6 / 0.04)      # Eq.21
    assert DAES.avg_power(DAES.MethodMeasurement("x", 1, 1, 1)) is None


def test_summary_row_fields():
    static, m = _meas()
    row = DAES.summary_row(static, m, 0.85)
    np.testing.assert_allclose(row["acc_pct"], 90.0)
    np.testing.assert_allclose(row["time_ms"], 40.0)
    np.testing.assert_allclose(row["macs_m"], 100.0)
    np.testing.assert_allclose(row["speedup"], 2.5)
    np.testing.assert_allclose(row["daes"],
                               DAES.daes(static, m, 0.85))


def test_lane_accumulator_rows_hand_computed():
    acc = DAES.LaneDaesAccumulator(static_macs=1.0)
    assert acc.rows() == {}
    # two observations in one lane: mean conf 0.8, mean macs 0.25,
    # mean alpha 0.5
    acc.observe((0, 1), conf=[0.7, 0.9], macs=[0.2, 0.3],
                alpha=[0.4, 0.6])
    acc.observe((1, 2), conf=[0.6], macs=[1.0], alpha=[0.9])
    rows = acc.rows()
    assert set(rows) == {(0, 1), (1, 2)}
    r = rows[(0, 1)]
    assert r["n"] == 2
    np.testing.assert_allclose(r["acc_pct"], 80.0)
    np.testing.assert_allclose(r["speedup"], 1.0 / 0.25)   # time ∝ macs
    np.testing.assert_allclose(r["power_eff"], 1.0 / 0.25)
    # Eq. 9 with pseudo-accuracy: 0.8 * 4 * 4 / 1.5
    np.testing.assert_allclose(r["daes"], 0.8 * 4 * 4 / 1.5)
    # a lane that pays the full static cost has speedup exactly 1
    np.testing.assert_allclose(rows[(1, 2)]["speedup"], 1.0)
    np.testing.assert_allclose(rows[(1, 2)]["daes"],
                               0.6 * 1.0 * 1.0 / 1.9)


def test_server_stats_exports_per_lane_daes(engine):
    """Satellite: stats()["daes"] reports Eq. 9 per difficulty class."""
    from repro.serving import AsyncDartServer, SchedulerConfig
    from repro.data.datasets import DatasetConfig, make_batch
    x, _ = make_batch(DatasetConfig(name="synth-cifar", n_train=128,
                                    n_eval=128), range(24), split="eval")
    x = np.asarray(x)
    with AsyncDartServer(engine, SchedulerConfig(
            max_batch=8, flush_ms=2.0, pipeline_depth=0)) as srv:
        for i in range(0, 24, 6):
            srv.submit(x[i:i + 6]).result(timeout=60)
        daes_rows = srv.stats()["daes"]
    assert daes_rows, "serving must export at least one DAES lane"
    assert sum(r["n"] for r in daes_rows.values()) == 24
    for r in daes_rows.values():
        assert r["speedup"] >= 1.0 - 1e-9      # early exits only save
        assert r["daes"] > 0
