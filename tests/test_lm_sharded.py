"""Sharded (jit-end-to-end) LM decode must match the eager per-stage
oracle — generated tokens, exit depths, and telemetry after the
cross-replica reduction — compile at most once per (stage, bucket), and
round-trip its EngineState through checkpoints.

In-process tests run on a 1-device ("data",) mesh (the conftest pins the
test process to ONE device); the real 8-replica run executes in a
subprocess with ``--xla_force_host_platform_device_count=8``, mirroring
test_sharded_engine's multi-device pattern.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.routing import DartParams
from repro.engine import LMDecodeEngine
from repro.launch.mesh import make_serving_mesh
from repro.models.transformer_lm import LMConfig, lm_init
from repro.parallel.sharding import unzip

CFG = LMConfig(name="lm-sharded-t", n_layers=4, d_model=32, n_heads=2,
               n_kv_heads=1, d_ff=64, vocab=32, exit_layers=(0, 2),
               max_seq=64, remat=False)


@pytest.fixture(scope="module")
def lm_params():
    return unzip(lm_init(jax.random.key(0), CFG))[0]


@pytest.fixture(scope="module")
def prompts():
    return np.random.RandomState(0).randint(0, CFG.vocab, (5, 7))


def _dart(tau):
    return DartParams(tau=jnp.full((2,), tau), coef=jnp.ones(2),
                      beta_diff=0.1)


def _sharded(params, tau=0.0, **kw):
    return LMDecodeEngine(CFG, params, _dart(tau),
                          mesh=make_serving_mesh(), **kw)


@pytest.mark.parametrize("tau", [0.0, 0.05, 1.0])
def test_sharded_generate_matches_eager_oracle(lm_params, prompts, tau):
    """Tokens AND exit depths bit-equal to the eager per-stage path, at
    thresholds that exercise mixed exits (tau=0.0 fires a majority at
    stage 0 with survivors reaching full depth — the CALM propagation
    inside the fused step feeds later tokens' attention, so any
    divergence compounds over the 8 decode steps)."""
    eager = LMDecodeEngine(CFG, lm_params, _dart(tau))
    sh = _sharded(lm_params, tau=tau)
    tok_e, stg_e = eager.generate(prompts, n_new=8)
    tok_s, stg_s = sh.generate(prompts, n_new=8)
    np.testing.assert_array_equal(tok_s, tok_e)
    np.testing.assert_array_equal(stg_s, stg_e)
    # the oracle mode on the SAME sharded engine agrees and never
    # perturbs served-traffic accounting — neither the EngineState
    # telemetry nor the host diagnostics
    before = (sh.stats()["served"], sh.layers_run, sh.layers_skipped,
              sh.stats_exit.copy())
    tok_o, stg_o = sh.generate(prompts, n_new=8, mode="eager")
    np.testing.assert_array_equal(tok_o, tok_s)
    np.testing.assert_array_equal(stg_o, stg_s)
    assert sh.stats()["served"] == before[0]
    assert (sh.layers_run, sh.layers_skipped) == before[1:3]
    np.testing.assert_array_equal(sh.stats_exit, before[3])


def test_telemetry_matches_eager_after_reduction(lm_params, prompts):
    """served / exit_counts / total_macs reduced over replicas must equal
    the eager engine's host-side fold on the identical stream."""
    eager = LMDecodeEngine(CFG, lm_params, _dart(0.0))
    sh = _sharded(lm_params)
    eager.generate(prompts, n_new=6)
    eager.generate(prompts[:2], n_new=4)
    sh.generate(prompts, n_new=6)
    sh.generate(prompts[:2], n_new=4)
    a, b = sh.stats(), eager.stats()
    assert a["served"] == b["served"] == 5 * 6 + 2 * 4
    np.testing.assert_array_equal(a["exit_counts"], b["exit_counts"])
    np.testing.assert_allclose(a["total_macs"], b["total_macs"], rtol=1e-5)
    assert a["layers_run"] == b["layers_run"]
    assert a["layers_skipped"] == b["layers_skipped"]
    # driving the eager decode_step API directly on a sharded engine
    # must default to record=False: a host-side fold would broadcast
    # scalar adds over the replica-sharded counters
    cache = sh.prefill(prompts[:2, :3], sh.init_cache(2, 8))
    sh.decode_step(prompts[:2, 3], cache, 3,
                   np.full(2, 0.5, np.float32))
    assert sh.stats()["served"] == a["served"]


def test_one_trace_per_stage_bucket_and_no_realloc(lm_params, prompts):
    """Every (stage, bucket) compiles at most once, and repeated
    generates with the same shapes add NO traces — the donated
    cache/state buffers are reused, not reallocated/recompiled."""
    sh = _sharded(lm_params)
    sh.generate(prompts, n_new=6)
    assert sh.trace_counts
    assert all(n == 1 for n in sh.trace_counts.values()), sh.trace_counts
    before = dict(sh.trace_counts)
    sh.generate(prompts, n_new=6)
    sh.generate(prompts, n_new=6)
    assert sh.trace_counts == before
    # a different batch size compiles its new buckets ONCE, then reuses
    sh.generate(prompts[:3], n_new=6)
    again = dict(sh.trace_counts)
    assert all(n == 1 for n in again.values()), again
    sh.generate(prompts[:3], n_new=6)
    assert sh.trace_counts == again


def test_checkpoint_roundtrip_decode_state(tmp_path, lm_params, prompts):
    sh = _sharded(lm_params)
    sh.generate(prompts, n_new=5)
    sh.record_requests([12.5, 80.0], [False, True])
    sh.save_state(str(tmp_path), step=7)
    replica = _sharded(lm_params)
    assert replica.restore_state(str(tmp_path)) == 7
    for a, b in zip(jax.tree.leaves(sh.state),
                    jax.tree.leaves(replica.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert replica.stats()["served"] == 25
    assert replica.stats()["requests"]["deadline_miss"] == 1
    # the restored engine keeps serving through the compiled path
    t1, s1 = sh.generate(prompts, n_new=3)
    t2, s2 = replica.generate(prompts, n_new=3)
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(s1, s2)


def test_session_over_sharded_engine_matches_direct(lm_params, prompts):
    """Concurrent callers through engine.session() get the sharded
    bucketed decode loop and bit-identical outputs to direct eager
    generation."""
    ref = LMDecodeEngine(CFG, lm_params, _dart(0.0))
    ref_tok, ref_stg = ref.generate(prompts, n_new=6)
    sh = _sharded(lm_params)
    with sh.session() as sess:
        futs = [sess.submit(prompts[i], n_new=6)
                for i in range(len(prompts))]
        outs = [f.result(timeout=300) for f in futs]
    tok = np.concatenate([o["tokens"] for o in outs])
    stg = np.concatenate([o["stages"] for o in outs])
    np.testing.assert_array_equal(tok, ref_tok)
    np.testing.assert_array_equal(stg, ref_stg)
    # request latency telemetry landed in the EngineState
    assert sh.stats()["requests"]["requests"] == len(prompts)
    # consolidated decode went through the compiled path
    assert any(k[0] == "lm-stage" for k in sh.trace_counts)


def test_unknown_mode_raises(lm_params, prompts):
    eng = LMDecodeEngine(CFG, lm_params, _dart(0.0))
    with pytest.raises(ValueError, match="unknown mode"):
        eng.generate(prompts, n_new=2, mode="warp")
    with pytest.raises(ValueError, match="needs a mesh"):
        eng.generate(prompts, n_new=2, mode="sharded")


MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, %r)
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.routing import DartParams
    from repro.engine import LMDecodeEngine
    from repro.launch.mesh import make_serving_mesh
    from repro.models.transformer_lm import LMConfig, lm_init
    from repro.parallel.sharding import unzip

    cfg = LMConfig(name="lm-8dev", n_layers=4, d_model=32, n_heads=2,
                   n_kv_heads=1, d_ff=64, vocab=32, exit_layers=(0, 2),
                   max_seq=64, remat=False)
    params = unzip(lm_init(jax.random.key(0), cfg))[0]
    dart = DartParams(tau=jnp.full((2,), 0.0), coef=jnp.ones(2),
                      beta_diff=0.1)
    prompts = np.random.RandomState(0).randint(0, cfg.vocab, (5, 7))

    eng = LMDecodeEngine(cfg, params, dart, mesh=make_serving_mesh())
    assert eng.n_replicas == 8, eng.n_replicas
    # telemetry physically sharded over the data axis, policy replicated
    assert str(eng.state.served.sharding.spec) == "PartitionSpec('data',)"
    assert eng.state.tau.sharding.spec == jax.sharding.PartitionSpec()
    # buckets pad to replica multiples: 5 prompts -> 8 rows
    assert eng.bucket_key(5) == 8 and eng.bucket_key(3) == 8

    tok_s, stg_s = eng.generate(prompts, n_new=8)
    tok_o, stg_o = eng.generate(prompts, n_new=8, mode="eager")
    np.testing.assert_array_equal(tok_s, tok_o)
    np.testing.assert_array_equal(stg_s, stg_o)

    # telemetry after all-reduce == an eager engine on the same stream
    eager = LMDecodeEngine(cfg, params, dart)
    eager.generate(prompts, n_new=8)
    a, b = eng.stats(), eager.stats()
    assert a["served"] == b["served"] == 40, (a["served"], b["served"])
    np.testing.assert_array_equal(a["exit_counts"], b["exit_counts"])
    np.testing.assert_allclose(a["total_macs"], b["total_macs"],
                               rtol=1e-5)

    # one trace per (stage, bucket) even with 8 replicas; repeats reuse
    before = dict(eng.trace_counts)
    assert all(n == 1 for n in before.values()), before
    eng.generate(prompts, n_new=8)
    assert eng.trace_counts == before, eng.trace_counts
    print("LM_SHARDED_OK")
""" % os.path.join(os.path.dirname(__file__), "..", "src"))


def test_sharded_lm_equivalence_on_8_devices():
    """Full oracle-equivalence + sharding-layout + recompile assertions
    on an 8-fake-device ("data",) mesh (subprocess)."""
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "LM_SHARDED_OK" in r.stdout
