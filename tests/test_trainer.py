"""Training-loop integration: losses decrease per family; microbatching
and compression paths train; BN stats update."""
import jax.numpy as jnp
import numpy as np

from repro.data.datasets import DatasetConfig
from repro.models.cnn_zoo import AlexNetConfig
from repro.models.dit import DiTConfig
from repro.models.resnet import ResNetConfig
from repro.models.transformer_lm import LMConfig
from repro.parallel.compression import CompressionConfig
from repro.runtime.trainer import Trainer, TrainConfig

DATA = DatasetConfig(name="synth-cifar", n_train=256, n_eval=64)


def losses(hist):
    return [h["loss"] for h in hist]


def test_cnn_loss_decreases():
    mc = AlexNetConfig(img_res=32, n_classes=10,
                       channels=(8, 16, 24, 16, 16), fc_dims=(64, 32))
    tr = Trainer(mc, TrainConfig(batch_size=16, steps=40, lr=3e-3,
                                 log_every=5), DATA)
    h = tr.run()
    assert min(losses(h)[1:]) < losses(h)[0]


def test_lm_loss_decreases():
    mc = LMConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                  d_ff=64, vocab=64, exit_layers=(0,), max_seq=32,
                  remat=False)
    tr = Trainer(mc, TrainConfig(batch_size=8, steps=30, lr=3e-3,
                                 log_every=5), DATA, data_kind="tokens")
    h = tr.run()
    assert losses(h)[-1] < losses(h)[0]


def test_dit_loss_decreases():
    mc = DiTConfig(name="d", img_res=64, patch=2, n_layers=2, d_model=32,
                   n_heads=2, n_classes=10, exit_layers=(0,), remat=False)
    tr = Trainer(mc, TrainConfig(batch_size=8, steps=30, lr=1e-3,
                                 log_every=5),
                 DatasetConfig(name="latents", img_res=64, n_train=128),
                 data_kind="latents")
    h = tr.run()
    assert losses(h)[-1] < losses(h)[0] * 1.05


def test_bn_running_stats_update():
    mc = ResNetConfig(name="r", depths=(1, 1), width=8, block="basic",
                      img_res=32, n_classes=10, small_input=True,
                      exit_stages=(0,))
    tr = Trainer(mc, TrainConfig(batch_size=16, steps=3, lr=1e-3), DATA)
    before = np.asarray(tr.params["stem"]["bn"]["mean"]).copy()
    tr.run()
    after = np.asarray(tr.params["stem"]["bn"]["mean"])
    assert not np.allclose(before, after)


def test_microbatching_matches_plain_step():
    """One microbatched step == one plain step, bit-for-bit (params)."""
    mc = AlexNetConfig(img_res=32, n_classes=10,
                       channels=(8, 16, 24, 16, 16), fc_dims=(64, 32))
    tc_a = TrainConfig(batch_size=16, steps=1, lr=3e-3, warmup=0)
    tc_b = TrainConfig(batch_size=16, steps=1, lr=3e-3, warmup=0,
                       microbatches=4)
    tr_a = Trainer(mc, tc_a, DATA)
    tr_b = Trainer(mc, tc_b, DATA)
    from repro.data.datasets import make_batch
    x, y = make_batch(DATA, range(16))
    tr_a.train_step((jnp.asarray(x), jnp.asarray(y)))
    tr_b.train_step((jnp.asarray(x), jnp.asarray(y)))
    import jax
    for a, b in zip(jax.tree.leaves(tr_a.params),
                    jax.tree.leaves(tr_b.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-4)


def test_microbatching_trains():
    mc = AlexNetConfig(img_res=32, n_classes=10,
                       channels=(8, 16, 24, 16, 16), fc_dims=(64, 32))
    tr = Trainer(mc, TrainConfig(batch_size=16, steps=25, lr=3e-3,
                                 microbatches=4, log_every=5), DATA)
    h = tr.run()
    assert min(losses(h)[1:]) < losses(h)[0]


def test_compressed_training_matches_uncompressed_direction():
    mc = AlexNetConfig(img_res=32, n_classes=10,
                       channels=(8, 16, 24, 16, 16), fc_dims=(64, 32))
    tc = TrainConfig(batch_size=16, steps=40, lr=3e-3, log_every=5,
                     compression=CompressionConfig("int8"))
    tr = Trainer(mc, tc, DATA)
    h = tr.run()
    assert min(losses(h)[1:]) < losses(h)[0]
