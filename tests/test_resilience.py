"""Fault-tolerant serving (ISSUE 10): the chaos-injected engine pool.

Covers the tentpole surface end to end:

* deterministic fault plans — same seed => same plan, same plan over
  the same call sequence => identical injection traces (the CI
  determinism contract);
* structured dispatch/complete/step failure across all three
  schedulers (classifier, cascade, LM-continuous): the bucket's
  futures fail with :class:`DispatchError`, the daemon survives, and
  the NEXT bucket succeeds;
* EnginePool mechanics: retry-on-death, output-validation quarantine,
  straggler hedging, bounded requeue when nothing is live, the
  degradation ladder engaging AND reversing (drain/join), and the
  atomic serving-state snapshot round-trip;
* the chaos property: random request streams x random fault schedules
  => every future resolves exactly once (a result or a structured
  error, never a hang), and every request no fault touched is
  bit-identical to the eager single-engine oracle.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, strategies as st

from _prop import examples

from repro.cascade import CascadeEngine
from repro.core.routing import DartParams
from repro.engine import DartEngine, LMDecodeEngine
from repro.models.transformer_lm import LMConfig, lm_init
from repro.models.vit import ViTConfig, vit_init
from repro.parallel.sharding import unzip
from repro.runtime.chaos import (FaultInjector, FaultPlan, FaultSpec,
                                 InjectedEngineDeath, NullInjector)
from repro.serving import (AsyncDartServer, DispatchError, EnginePool,
                           InvalidEngineOutput, NoHealthyEngines,
                           PooledDartServer, RequestShed, ResilienceConfig,
                           SchedulerConfig, pooled_cascade_server,
                           pooled_lm_session)
from repro.serving.resilience import (_TAU_ALWAYS_FIRE, validate_output)

CFG = ViTConfig(name="res-vt", img_res=32, patch=8, n_layers=3, d_model=32,
                n_heads=2, d_ff=64, n_classes=10, exit_layers=(0, 1))
COSTS = [0.4, 0.7, 1.0]
ORIG_TAU = 0.2

LMCFG = LMConfig(name="res-lm", n_layers=2, d_model=16, n_heads=2,
                 n_kv_heads=1, d_ff=32, vocab=16, exit_layers=(0,),
                 max_seq=32, remat=False)

_CACHE: dict = {}


def _vit_params():
    if "vit" not in _CACHE:
        _CACHE["vit"] = unzip(vit_init(jax.random.key(0), CFG))[0]
    return _CACHE["vit"]


def _mk_engine():
    return DartEngine.from_config(
        CFG, _vit_params(), cum_costs=COSTS, adapt=False,
        dart=DartParams(tau=jnp.full((2,), ORIG_TAU), coef=jnp.ones(2),
                        beta_diff=0.3))


def _pool_engines():
    """Three cached same-params engines (two poolable + one oracle),
    usable from hypothesis tests (which cannot take fixtures).  Pool
    engines get their policy reset so ladder residue from a previous
    example cannot leak across examples."""
    if "engines" not in _CACHE:
        _CACHE["engines"] = (_mk_engine(), _mk_engine(), _mk_engine())
    e0, e1, oracle = _CACHE["engines"]
    for eng in (e0, e1):
        eng.state = eng.state.with_policy(tau=jnp.full((2,), ORIG_TAU))
    return e0, e1, oracle


def _images(seed, n):
    return np.random.RandomState(seed).rand(
        n, 32, 32, 3).astype(np.float32)


def _rcfg(**kw):
    kw.setdefault("backoff_s", 0.001)
    kw.setdefault("requeue_backoff_s", 0.001)
    kw.setdefault("call_timeout_s", 30.0)
    return ResilienceConfig(**kw)


def _drive(srv, futs, rounds=400):
    for _ in range(rounds):
        if all(f.done() for f in futs):
            return
        srv.flush()
        time.sleep(0.002)
    raise AssertionError("futures did not resolve while driving")


# ---------------------------------------------------------------------------
# fault plans: determinism + replay
# ---------------------------------------------------------------------------
def test_fault_plan_generate_deterministic_and_json_roundtrip():
    a = FaultPlan.generate(seed=11, n_faults=6)
    b = FaultPlan.generate(seed=11, n_faults=6)
    assert a.to_json() == b.to_json()
    assert FaultPlan.generate(seed=12, n_faults=6).to_json() != a.to_json()
    back = FaultPlan.from_json(a.to_json())
    assert back.specs == a.specs
    with pytest.raises(ValueError, match="unknown kind"):
        FaultSpec("melted", "step", 0)
    with pytest.raises(ValueError, match="unknown cut point"):
        FaultSpec("straggler", "nowhere", 0)


def _scripted_fire(inj):
    """A fixed fire() sequence (what a scheduler run would produce);
    returns the injection trace."""
    for i in range(12):
        for eng in ("e0", "e1"):
            for point in ("dispatch", "step", "complete"):
                try:
                    inj.fire(point, engine=eng)
                except InjectedEngineDeath:
                    pass
    return inj.trace


def test_same_plan_replayed_twice_yields_identical_traces():
    plan = FaultPlan.generate(seed=23, n_faults=5, horizon=12,
                              max_delay_s=0.0)
    t1 = _scripted_fire(FaultInjector(plan))
    t2 = _scripted_fire(FaultInjector(plan))
    assert t1 == t2 and len(t1) > 0


def test_targeted_spec_counts_per_engine_and_fires_once():
    inj = FaultInjector(FaultPlan([
        FaultSpec("nan_output", "step", 1, engine="e1")]))
    assert inj.fire("step", engine="e0") is None    # e1 count untouched
    assert inj.fire("step", engine="e1") is None    # e1 call #0
    assert inj.fire("step", engine="e1") == "nan_output"  # e1 call #1
    assert inj.fire("step", engine="e1") is None    # fires at most once
    assert inj.counts()[("step", "e1")] == 3


def test_null_injector_still_validates_cut_points():
    inj = NullInjector()
    assert inj.fire("dispatch") is None
    with pytest.raises(ValueError, match="unknown cut point"):
        inj.fire("dispach")


def test_validate_output_quarantines_poisoned_results():
    ok = {"conf": np.array([0.5, 0.9]), "exit_idx": np.array([0, 1])}
    validate_output(ok, n_exits=3)
    with pytest.raises(InvalidEngineOutput, match="non-finite"):
        validate_output({"conf": np.array([0.5, np.nan])}, n_exits=3)
    with pytest.raises(InvalidEngineOutput, match="out of range"):
        validate_output({"conf": np.array([0.5]),
                         "exit_idx": np.array([7])}, n_exits=3)
    with pytest.raises(InvalidEngineOutput, match="decode exit stage"):
        validate_output((np.zeros((1, 2), np.int32),
                         np.array([[9]], np.int32)), n_exits=3)


# ---------------------------------------------------------------------------
# structured failure paths: the three schedulers survive a bad bucket
# ---------------------------------------------------------------------------
class _Boom(RuntimeError):
    pass


def _boom_once(srv):
    """Replace the dispatch seam so the FIRST bucket raises."""
    state = {"n": 0}
    orig = srv._engine_call

    def call(fn):
        state["n"] += 1
        if state["n"] == 1:
            raise _Boom("injected dispatch failure")
        return orig(fn)
    srv._engine_call = call
    return state


def test_classifier_dispatch_failure_daemon_survives():
    eng = _mk_engine()
    x = _images(0, 4)
    with AsyncDartServer(eng, SchedulerConfig(max_batch=4,
                                              flush_ms=1.0)) as srv:
        _boom_once(srv)
        with pytest.raises(DispatchError) as ei:
            srv.submit(x[:2]).result(timeout=60)
        assert ei.value.stage == "dispatch"
        assert isinstance(ei.value.cause, _Boom)
        assert srv._thread.is_alive()
        out = srv.submit(x[2:]).result(timeout=60)
        assert out["pred"].shape == (2,)
    assert srv.counters["dispatch_errors"] == 1


def test_classifier_complete_failure_is_structured():
    eng = _mk_engine()
    srv = AsyncDartServer(eng, SchedulerConfig(max_batch=4), start=False)
    orig = srv._complete
    state = {"n": 0}

    def complete(reqs, out, t0):
        state["n"] += 1
        if state["n"] == 1:
            raise _Boom("injected materialization failure")
        return orig(reqs, out, t0)
    srv._complete = complete
    f1 = srv.submit(_images(1, 2))
    _drive(srv, [f1])
    with pytest.raises(DispatchError) as ei:
        f1.result(timeout=5)
    assert ei.value.stage == "complete"
    f2 = srv.submit(_images(2, 2))
    _drive(srv, [f2])
    assert f2.result(timeout=5)["pred"].shape == (2,)
    assert srv.counters["complete_errors"] == 1
    srv.close()


@pytest.fixture(scope="module")
def cascade_members():
    vc = ViTConfig(name="res-casc", img_res=32, patch=8, n_layers=3,
                   d_model=16, n_heads=2, d_ff=32, n_classes=10,
                   exit_layers=(0, 1))
    params, _ = unzip(vit_init(jax.random.key(1), vc))
    small = DartEngine.from_config(
        vc, params, cum_costs=COSTS, adapt=False,
        dart=DartParams(tau=jnp.full((2,), ORIG_TAU), coef=jnp.ones(2),
                        beta_diff=0.3))
    return (small, _mk_engine())


def _mk_cascade(members):
    # theta=-1.0 never escalates: the failure/chaos behaviour under
    # test is scheduler-level, independent of escalation volume
    return CascadeEngine(list(members), member_costs=[0.25, 1.0],
                         theta=np.array([-1.0]), beta_esc=0.1)


def test_cascade_dispatch_failure_daemon_survives(cascade_members):
    cas = _mk_cascade(cascade_members)
    x = _images(3, 4)
    with AsyncDartServer(cas, SchedulerConfig(max_batch=4,
                                              flush_ms=1.0)) as srv:
        _boom_once(srv)
        with pytest.raises(DispatchError) as ei:
            srv.submit(x[:2]).result(timeout=60)
        assert ei.value.stage == "dispatch"
        assert isinstance(ei.value.cause, _Boom)
        assert srv._thread.is_alive()
        out = srv.submit(x[2:]).result(timeout=60)
        assert out["pred"].shape == (2,)


def test_lm_continuous_step_failure_fails_pool_not_daemon():
    if "lm" not in _CACHE:
        _CACHE["lm"] = unzip(lm_init(jax.random.key(0), LMCFG))[0]
    eng = LMDecodeEngine(LMCFG, _CACHE["lm"],
                         DartParams(tau=jnp.full((1,), 1.0),
                                    coef=jnp.ones(1), beta_diff=0.1))
    sess = eng.session(continuous=True,
                       cfg=SchedulerConfig(policy="reject", flush_ms=0.0),
                       start=False, n_slots=4, page_size=4, max_len=16)
    rs = np.random.RandomState(5)
    f1 = sess.submit(rs.randint(0, LMCFG.vocab, (1, 4)), n_new=2)
    sess.pump()                       # admit into the slot pool
    orig = sess.decoder.step
    state = {"n": 0}

    def step():
        state["n"] += 1
        if state["n"] == 1:
            raise _Boom("injected decode-step failure")
        return orig()
    sess.decoder.step = step
    sess.pump()                       # the poisoned step
    with pytest.raises(DispatchError) as ei:
        f1.result(timeout=5)
    assert ei.value.stage == "step"
    assert isinstance(ei.value.cause, _Boom)
    assert sess.counters["step_errors"] == 1
    # the session keeps serving
    f2 = sess.submit(rs.randint(0, LMCFG.vocab, (1, 4)), n_new=2)
    for _ in range(200):
        if f2.done():
            break
        sess.pump()
    out2 = f2.result(timeout=5)
    assert out2["tokens"].shape == (1, 2)
    sess.close()


# ---------------------------------------------------------------------------
# EnginePool mechanics
# ---------------------------------------------------------------------------
def test_pool_retries_past_injected_death_and_ladder_engages():
    e0, e1, _ = _pool_engines()
    inj = FaultInjector(FaultPlan([
        FaultSpec("engine_death", "step", 0, engine="e0")]))
    pool = EnginePool({"e0": e0, "e1": e1}, _rcfg(), injector=inj,
                      heartbeat=False)
    srv = PooledDartServer(pool, SchedulerConfig(edges=(), max_batch=4),
                           start=False)
    futs = [srv.submit(_images(7, 2)) for _ in range(4)]
    _drive(srv, futs)
    for f in futs:                    # one engine dies, the other serves
        assert f.result(timeout=5)["pred"].shape == (2,)
    p = srv.stats()["pool"]
    assert p["deaths"] >= 1 and p["retries"] >= 1
    assert p["faults_injected"] >= 1
    assert p["rung"] >= 2             # <=1 of 2 live
    # at least the faulted bucket is marked (round-robin may serve the
    # first bucket cleanly from e1 before e0's death spec fires)
    assert p["touched_rids"] >= 2
    assert DEAD_STATES & set(p["engines"].values())
    srv.close()
    pool.close()


DEAD_STATES = {"dead"}


def test_pool_quarantines_nan_output_and_serves_from_peer():
    e0, e1, _ = _pool_engines()
    inj = FaultInjector(FaultPlan([
        FaultSpec("nan_output", "step", 0)]))   # whichever engine is first
    pool = EnginePool({"e0": e0, "e1": e1}, _rcfg(), injector=inj,
                      heartbeat=False)
    srv = PooledDartServer(pool, SchedulerConfig(edges=(), max_batch=4),
                           start=False)
    f = srv.submit(_images(8, 2))
    _drive(srv, [f])
    out = f.result(timeout=5)
    assert np.all(np.isfinite(out["conf"]))     # the NaN never leaked
    p = srv.stats()["pool"]
    assert p["quarantined"] == 1 and p["retries"] >= 1
    assert p["touched_rids"] == 1
    srv.close()
    pool.close()


def test_pool_hedges_straggler_first_result_wins():
    e0, e1, _ = _pool_engines()
    x = _images(9, 2)
    for eng in (e0, e1):              # warm so call times are stable
        eng.infer(x, mode="masked", record=False)
    inj = FaultInjector(FaultPlan([
        FaultSpec("straggler", "step", 0, delay_s=1.0)]))
    pool = EnginePool({"e0": e0, "e1": e1},
                      _rcfg(hedge_factor=3.0, straggler_window=10),
                      injector=inj, heartbeat=False)
    for _ in range(6):                # seed the rolling median: ~60ms cap
        pool.straggler.record(0.02)
    t0 = time.monotonic()
    out = pool.call(lambda eng: eng.infer(x, mode="masked", record=False))
    assert np.asarray(out["pred"]).shape == (2,)
    assert time.monotonic() - t0 < 1.0          # did not wait out the hold
    st_ = pool.stats()
    assert st_["hedges"] == 1 and st_["stragglers"] == 1
    assert st_["straggler_deadline_ms"] is not None
    pool.close()


def test_requeue_is_bounded_when_nothing_is_live():
    e0, e1, _ = _pool_engines()
    pool = EnginePool({"e0": e0, "e1": e1}, _rcfg(requeue_limit=3),
                      heartbeat=False)
    pool._mark_dead("e0", reason="test")
    pool._mark_dead("e1", reason="test")
    srv = PooledDartServer(pool, SchedulerConfig(edges=(), max_batch=4),
                           start=False)
    # priority above the rung-4 shed floor: reaches the requeue path
    f = srv.submit(_images(10, 2), priority=5)
    srv.flush()                       # requeues resolve within one flush
    with pytest.raises(DispatchError) as ei:
        f.result(timeout=5)
    assert isinstance(ei.value.cause, NoHealthyEngines)
    assert srv.counters["requeued"] == 3        # bounded, then failed
    assert srv.stats()["pool"]["requeues"] == 3
    srv.close()
    pool.close()


def test_ladder_rungs_engage_and_reverse():
    """4 pool slots over one shared engine: kill 3 -> rung 3 installs
    the scaled-tau + max-depth-cap policy; kill the 4th -> rung 4
    sheds below the priority floor; joins reverse everything."""
    e0, _, _ = _pool_engines()
    pool = EnginePool({n: e0 for n in ("a", "b", "c", "d")}, _rcfg(),
                      heartbeat=False)
    srv = PooledDartServer(pool, SchedulerConfig(edges=(), max_batch=4),
                           start=False)
    for name in ("a", "b", "c"):
        pool._mark_dead(name, reason="test")
    assert pool.rung == 3
    tau = np.asarray(e0.state.tau)
    assert tau[0] == pytest.approx(ORIG_TAU * pool.cfg.degraded_tau_scale)
    assert tau[1] == _TAU_ALWAYS_FIRE           # capped stage always fires
    assert pool.alpha_scale == pool.cfg.degraded_alpha_scale
    pool._mark_dead("d", reason="test")
    assert pool.rung == 4 and pool.shed_floor is not None
    with pytest.raises(RequestShed):
        srv.submit(_images(11, 2), priority=0).result(timeout=5)
    assert srv.counters["shed_degraded"] == 1
    for name in ("a", "b", "c", "d"):
        pool.join(name, warm=False)
    assert pool.rung == 0 and pool.shed_floor is None
    assert pool.alpha_scale == 1.0
    np.testing.assert_allclose(np.asarray(e0.state.tau),
                               np.full((2,), ORIG_TAU))
    hist = [h["to"] for h in pool.rung_history]
    assert hist[-1] == 0 and max(hist) == 4     # engaged AND reversed
    srv.close()
    pool.close()


def test_drain_is_not_a_failure_and_join_restores_capacity():
    e0, e1, _ = _pool_engines()
    pool = EnginePool({"e0": e0, "e1": e1}, _rcfg(), heartbeat=False)
    pool.drain("e1")
    st_ = pool.stats()
    assert st_["engines"]["e1"] == "drained"
    assert st_["deaths"] == 0 and st_["drains"] == 1
    assert pool.rung == 2
    pool.join("e1", warm=False)
    assert pool.stats()["engines"]["e1"] == "healthy"
    assert pool.rung == 0 and pool.stats()["joins"] == 1
    pool.close()


def test_snapshot_roundtrip_restores_learned_priors(tmp_path):
    e0, e1, _ = _pool_engines()
    pool = EnginePool({"e0": e0, "e1": e1}, _rcfg(), heartbeat=False)
    srv = PooledDartServer(pool, SchedulerConfig(edges=(), max_batch=4),
                           start=False)
    futs = [srv.submit(_images(12, 2)) for _ in range(4)]
    _drive(srv, futs)
    [f.result(timeout=5) for f in futs]
    snap = str(tmp_path / "snap")
    srv.snapshot(snap, step=7)
    learned = srv.planner.state_dict()
    srv.close()
    pool.close()

    e0b, e1b, _ = _pool_engines()
    pool2 = EnginePool({"e0": e0b, "e1": e1b}, _rcfg(), heartbeat=False)
    srv2 = PooledDartServer(pool2, SchedulerConfig(edges=(), max_batch=4),
                            start=False)
    assert srv2.planner.state_dict() != learned  # cold start differs
    assert srv2.restore_snapshot(snap) == 7
    assert srv2.planner.state_dict() == learned
    srv2.close()
    pool2.close()


def test_pooled_lm_session_survives_engine_death():
    if "lm" not in _CACHE:
        _CACHE["lm"] = unzip(lm_init(jax.random.key(0), LMCFG))[0]

    def mk_lm():
        return LMDecodeEngine(LMCFG, _CACHE["lm"],
                              DartParams(tau=jnp.full((1,), 1.0),
                                         coef=jnp.ones(1), beta_diff=0.1))
    l0, l1, oracle = mk_lm(), mk_lm(), mk_lm()
    inj = FaultInjector(FaultPlan([
        FaultSpec("engine_death", "step", 0)]))
    pool = EnginePool({"l0": l0, "l1": l1}, _rcfg(), injector=inj,
                      heartbeat=False)
    sess = pooled_lm_session(pool, SchedulerConfig(max_batch=2),
                             start=False)
    prompts = np.random.RandomState(6).randint(0, LMCFG.vocab, (2, 4))
    f = sess.submit(prompts, n_new=3)
    _drive(sess, [f])
    out = f.result(timeout=5)
    ref_toks, ref_stages = oracle.generate(prompts, 3)
    np.testing.assert_array_equal(out["tokens"], ref_toks)
    np.testing.assert_array_equal(out["stages"], ref_stages)
    assert sess.stats()["pool"]["deaths"] == 1
    sess.close()
    pool.close()


def test_pooled_cascade_server_survives_engine_death(cascade_members):
    cas0 = _mk_cascade(cascade_members)
    cas1 = _mk_cascade(cascade_members)   # same members: same pure fn
    inj = FaultInjector(FaultPlan([
        FaultSpec("engine_death", "step", 0)]))
    pool = EnginePool({"c0": cas0, "c1": cas1}, _rcfg(), injector=inj,
                      heartbeat=False)
    srv = pooled_cascade_server(pool, SchedulerConfig(edges=(),
                                                      max_batch=4),
                                start=False)
    x = _images(13, 2)
    f = srv.submit(x)
    _drive(srv, [f])
    out = f.result(timeout=5)
    assert out["pred"].shape == (2,)
    assert (out["member"] == 0).all()     # theta sentinel: no escalation
    assert srv.stats()["pool"]["deaths"] == 1
    srv.close()
    pool.close()


def test_wedged_engine_is_declared_dead_and_call_rerouted():
    e0, e1, _ = _pool_engines()
    pool = EnginePool({"e0": e0, "e1": e1},
                      _rcfg(call_timeout_s=0.2, hedge=False, retries=2),
                      heartbeat=False)
    release = threading.Event()
    x = _images(14, 2)

    def wedge_or_serve(eng):
        if eng is e0:
            release.wait(5.0)          # a stuck compiled step
            raise RuntimeError("was wedged")
        return eng.infer(x, mode="masked", record=False)
    # pin round-robin so the first pick is e0
    pool._rr = len(pool.engines) - 1
    out = pool.call(wedge_or_serve)
    release.set()
    assert np.asarray(out["pred"]).shape == (2,)
    assert pool.stats()["engines"]["e0"] == "dead"
    pool.close()


# ---------------------------------------------------------------------------
# the chaos property (ISSUE 10 acceptance)
# ---------------------------------------------------------------------------
@settings(max_examples=examples(4), deadline=None)
@given(seed=st.integers(0, 10_000))
def test_chaos_streams_resolve_exactly_once_and_match_oracle(seed):
    """Random request streams x random fault schedules: every future
    resolves exactly once — a result or a structured error, never a
    hang or a double resolution; telemetry invariants hold; untouched
    requests are bit-identical to the eager oracle."""
    rs = np.random.RandomState(seed)
    plan = FaultPlan.generate(seed, n_faults=int(rs.randint(1, 6)),
                              engines=("e0", "e1"), horizon=16,
                              max_delay_s=0.02)
    e0, e1, oracle = _pool_engines()
    pool = EnginePool({"e0": e0, "e1": e1}, _rcfg(call_timeout_s=10.0),
                      injector=FaultInjector(plan), heartbeat=False)
    srv = PooledDartServer(
        pool, SchedulerConfig(edges=(),
                              max_batch=int(rs.choice([4, 8]))),
        start=False)
    n_req = int(rs.randint(4, 10))
    xs, futs, resolutions = [], [], []
    for _ in range(n_req):
        x = rs.rand(int(rs.randint(1, 4)), 32, 32, 3).astype(np.float32)
        xs.append(x)
        f = srv.submit(x)
        f.add_done_callback(lambda _f: resolutions.append(1))
        futs.append(f)
    _drive(srv, futs, rounds=600)
    assert len(resolutions) == n_req             # exactly once each
    n_ok = n_err = 0
    for f in futs:
        exc = f.exception(timeout=1)
        if exc is None:
            out = f.result()
            assert np.all(np.isfinite(np.asarray(out["conf"])))
            n_ok += 1
        else:
            assert isinstance(exc, (DispatchError, RequestShed))
            n_err += 1
    assert n_ok + n_err == n_req
    p = srv.stats()["pool"]
    assert p["faults_injected"] <= len(plan)
    assert p["deaths"] <= 2                      # an engine dies once
    assert p["quarantined"] <= p["retries"] + 1
    # rids any fault/rung touched are excluded; the rest must be
    # bit-identical to serving alone through the oracle engine
    for rid, (x, f) in enumerate(zip(xs, futs)):
        if rid in srv.touched_rids or f.exception() is not None:
            continue
        out = f.result()
        ref = oracle.infer(x, mode="masked", record=False)
        np.testing.assert_array_equal(out["pred"], np.asarray(ref["pred"]))
        np.testing.assert_array_equal(out["exit_idx"],
                                      np.asarray(ref["exit_idx"]))
        np.testing.assert_array_equal(out["conf"], np.asarray(ref["conf"]))
    srv.close()
    pool.close()
