"""ShardedDartEngine: compiled (jit-end-to-end) serving must match the
eager oracle — predictions, exit indices and telemetry after the
cross-replica reduction — and compile at most once per compactor bucket.

In-process tests run on a 1-device ("data",) mesh (the conftest pins the
test process to ONE device); the real 8-replica run executes in a
subprocess with ``--xla_force_host_platform_device_count=8``, mirroring
test_sharding's multi-device pattern.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.routing import DartParams
from repro.data.datasets import DatasetConfig, make_batch
from repro.engine import DartEngine, ShardedDartEngine
from repro.launch.mesh import make_serving_mesh
from repro.models.cnn_zoo import AlexNetConfig
from repro.runtime.trainer import Trainer, TrainConfig

DATA = DatasetConfig(name="synth-cifar", n_train=256, n_eval=128)
COSTS = [0.3, 0.7, 1.0]


@pytest.fixture(scope="module")
def trained_cnn():
    mc = AlexNetConfig(img_res=32, n_classes=10,
                       channels=(16, 24, 32, 24, 24), fc_dims=(96, 48))
    tr = Trainer(mc, TrainConfig(batch_size=32, steps=15, lr=3e-3), DATA)
    tr.run()
    return mc, tr.params


def _dart(tau):
    return DartParams(tau=jnp.full((2,), tau), coef=jnp.ones(2),
                      beta_diff=0.3)


def _sharded(trained_cnn, tau=0.2, **kw):
    mc, params = trained_cnn
    kw.setdefault("cum_costs", COSTS)
    kw.setdefault("adapt", True)
    kw.setdefault("update_every", 64)
    return DartEngine.from_config(mc, params, mesh=make_serving_mesh(),
                                  dart=_dart(tau), **kw)


def _eager(trained_cnn, tau=0.2, **kw):
    mc, params = trained_cnn
    kw.setdefault("cum_costs", COSTS)
    kw.setdefault("adapt", True)
    kw.setdefault("update_every", 64)
    return DartEngine.from_config(mc, params, dart=_dart(tau), **kw)


def test_mesh_kwarg_dispatches_to_sharded(trained_cnn):
    eng = _sharded(trained_cnn)
    assert isinstance(eng, ShardedDartEngine)
    assert eng.n_replicas == 1
    # policy replicated, telemetry row-sharded on the leading replica axis
    assert eng.state.tau.sharding.spec == jax.sharding.PartitionSpec()
    assert eng.state.served.shape == (1,)
    assert eng.state.adaptive["buf_conf"].shape[0] == 1


@pytest.mark.parametrize("tau", [0.0, 0.2, 0.9])
def test_compiled_matches_eager_oracle(trained_cnn, tau):
    eng = _sharded(trained_cnn, tau=tau)
    x, _ = make_batch(DATA, range(48), split="eval")
    ref = eng.infer(x, mode="eager")
    out = eng.infer(x, mode="masked")
    np.testing.assert_array_equal(out["exit_idx"],
                                  np.asarray(ref["exit_idx"]))
    np.testing.assert_array_equal(out["pred"], np.asarray(ref["pred"]))
    np.testing.assert_allclose(out["conf"], np.asarray(ref["conf"]),
                               rtol=2e-5, atol=2e-5)
    com = eng.infer(x, mode="compacted")
    np.testing.assert_array_equal(com["exit_idx"], out["exit_idx"])
    np.testing.assert_array_equal(com["pred"], out["pred"])


def test_unknown_mode_raises(trained_cnn):
    eng = _sharded(trained_cnn)
    x, _ = make_batch(DATA, range(4), split="eval")
    with pytest.raises(ValueError, match="unknown mode"):
        eng.infer(x, mode="warp")


def test_telemetry_matches_eager_after_reduction(trained_cnn):
    """served / exit_counts / total_macs / §II.C window stats must agree
    with an eager engine that served the identical stream."""
    sh = _sharded(trained_cnn)
    eg = _eager(trained_cnn)
    x, _ = make_batch(DATA, range(48), split="eval")
    sh.infer(x, mode="masked")
    sh.infer(x[:17], mode="compacted")
    eg.infer(x, mode="masked", record=True)
    eg.infer(x[:17], mode="compacted")
    a, b = sh.stats(), eg.stats()
    assert a["served"] == b["served"] == 65
    np.testing.assert_array_equal(a["exit_counts"], b["exit_counts"])
    np.testing.assert_allclose(a["total_macs"], b["total_macs"], rtol=1e-5)
    np.testing.assert_allclose(float(a["window"]["acc"]),
                               float(b["window"]["acc"]), atol=1e-6)
    np.testing.assert_allclose(float(a["window"]["cost"]),
                               float(b["window"]["cost"]), atol=1e-6)


def test_one_trace_per_bucket(trained_cnn):
    """Distinct batch sizes inside one bucket must share a compilation;
    a new bucket triggers exactly one new trace."""
    eng = _sharded(trained_cnn)
    x, _ = make_batch(DATA, range(16), split="eval")
    for n in (3, 4, 3):                         # all land in bucket 4
        eng.infer(x[:n], mode="masked")
    assert eng.trace_counts == {("masked", 4, True): 1}
    for n in (7, 8, 5):                         # bucket 8
        eng.infer(x[:n], mode="masked")
    assert eng.trace_counts[("masked", 8, True)] == 1
    assert eng.trace_counts[("masked", 4, True)] == 1
    # compacted: one trace per (stage, bucket) + one telemetry fold
    eng.infer(x[:13], mode="compacted")
    eng.infer(x[:16], mode="compacted")
    for key, n in eng.trace_counts.items():
        assert n == 1, (key, n)


def test_oversized_request_chunks_and_defers_update(trained_cnn):
    eng = _sharded(trained_cnn, buckets=(1, 2, 4, 8, 16), update_every=16)
    x, _ = make_batch(DATA, range(40), split="eval")    # 3 chunks
    ref = eng.infer(x, mode="eager")
    out = eng.infer(x, mode="masked")
    assert len(out["pred"]) == 40
    np.testing.assert_array_equal(out["exit_idx"],
                                  np.asarray(ref["exit_idx"]))
    np.testing.assert_array_equal(out["pred"], np.asarray(ref["pred"]))
    # the deferred §II.C update ran exactly once, after the last chunk
    assert int(eng.state.adaptive["t"]) == 1
    assert int(np.sum(np.asarray(eng.state.since_update))) == 0
    assert eng.stats()["served"] == 40


def test_update_reduces_merged_window_and_replicates_policy(trained_cnn):
    sh = _sharded(trained_cnn, update_every=10 ** 9)
    eg = _eager(trained_cnn, update_every=10 ** 9)
    x, _ = make_batch(DATA, range(48), split="eval")
    sh.infer(x, mode="masked")
    eg.infer(x, mode="masked", record=True)
    sh.update()
    eg.update()
    np.testing.assert_allclose(
        np.asarray(sh.state.adaptive["coef_temporal"]),
        np.asarray(eg.state.adaptive["coef_temporal"]), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(sh.state.adaptive["coef_class"]),
        np.asarray(eg.state.adaptive["coef_class"]), atol=1e-6)
    assert int(sh.state.adaptive["t"]) == int(eg.state.adaptive["t"]) == 1
    # coefficients stay replica-free (shared policy)
    assert sh.state.adaptive["coef_temporal"].shape == (2,)


def test_checkpoint_roundtrip_sharded(tmp_path, trained_cnn):
    eng = _sharded(trained_cnn)
    x, _ = make_batch(DATA, range(32), split="eval")
    eng.infer(x, mode="masked")
    eng.save_state(str(tmp_path), step=5)
    replica = _sharded(trained_cnn)
    assert replica.restore_state(str(tmp_path)) == 5
    for a, b in zip(jax.tree.leaves(eng.state),
                    jax.tree.leaves(replica.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert replica.stats()["served"] == 32


MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, %r)
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.routing import DartParams
    from repro.data.datasets import DatasetConfig, make_batch
    from repro.engine import DartEngine
    from repro.launch.mesh import make_serving_mesh
    from repro.models.cnn_zoo import AlexNetConfig
    from repro.runtime.trainer import Trainer, TrainConfig

    DATA = DatasetConfig(name="synth-cifar", n_train=256, n_eval=128)
    mc = AlexNetConfig(img_res=32, n_classes=10,
                       channels=(16, 24, 32, 24, 24), fc_dims=(96, 48))
    tr = Trainer(mc, TrainConfig(batch_size=32, steps=10, lr=3e-3), DATA)
    tr.run()
    mesh = make_serving_mesh()
    dart = DartParams(tau=jnp.full((2,), 0.2), coef=jnp.ones(2),
                      beta_diff=0.3)
    eng = DartEngine.from_config(mc, tr.params, mesh=mesh, dart=dart,
                                 cum_costs=[0.3, 0.7, 1.0], adapt=True,
                                 update_every=64)
    assert eng.n_replicas == 8, eng.n_replicas
    # telemetry physically sharded over the data axis, policy replicated
    assert str(eng.state.served.sharding.spec) == "PartitionSpec('data',)"
    assert str(eng.state.adaptive["buf_conf"].sharding.spec) == \\
        "PartitionSpec('data',)"
    assert eng.state.tau.sharding.spec == jax.sharding.PartitionSpec()

    x, _ = make_batch(DATA, range(48), split="eval")
    ref = eng.infer(x, mode="eager")
    out = eng.infer(x, mode="masked")
    np.testing.assert_array_equal(out["exit_idx"],
                                  np.asarray(ref["exit_idx"]))
    np.testing.assert_array_equal(out["pred"], np.asarray(ref["pred"]))
    np.testing.assert_allclose(out["conf"], np.asarray(ref["conf"]),
                               rtol=2e-5, atol=2e-5)
    com = eng.infer(x, mode="compacted")
    np.testing.assert_array_equal(com["exit_idx"], out["exit_idx"])
    np.testing.assert_array_equal(com["pred"], out["pred"])

    # telemetry after all-reduce == eager engine on the same stream
    eager = DartEngine.from_config(mc, tr.params, dart=dart,
                                   cum_costs=[0.3, 0.7, 1.0], adapt=True,
                                   update_every=64)
    eager.infer(x, mode="masked", record=True)
    eager.infer(x, mode="compacted")
    a, b = eng.stats(), eager.stats()
    assert a["served"] == b["served"] == 96, (a["served"], b["served"])
    np.testing.assert_array_equal(a["exit_counts"], b["exit_counts"])
    np.testing.assert_allclose(a["total_macs"], b["total_macs"],
                               rtol=1e-5)
    np.testing.assert_allclose(float(a["window"]["acc"]),
                               float(b["window"]["acc"]), atol=1e-6)

    # one trace per bucket even with 8 replicas
    for n in (3, 4, 48, 17):
        eng.infer(x[:n], mode="masked")
    masked_keys = [k for k in eng.trace_counts if k[0] == "masked"]
    assert all(eng.trace_counts[k] == 1 for k in masked_keys), \\
        eng.trace_counts
    # buckets are padded to multiples of 8 replicas:
    # n=3,4 -> bucket 4 -> 8; n=17 -> bucket 32; n=48 -> bucket 64
    assert set(k[1] for k in masked_keys) == {8, 32, 64}, masked_keys

    eng.update()
    eng.infer(x, mode="masked")
    print("SHARDED_OK")
""" % os.path.join(os.path.dirname(__file__), "..", "src"))


def test_sharded_equivalence_on_8_devices():
    """Full equivalence + sharding-layout + recompile assertions on an
    8-fake-device ("data",) mesh (subprocess)."""
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SHARDED_OK" in r.stdout
