"""Optimizer substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adamw, sgd, warmup_cosine, linear_decay,
                         clip_by_global_norm, global_norm, trainable_mask,
                         GradAccumulator)


def quad_loss(p, target):
    return jnp.sum((p["w"] - target) ** 2)


@pytest.mark.parametrize("make_opt", [
    lambda: adamw(0.05), lambda: sgd(0.02, momentum=0.9)])
def test_convergence_on_quadratic(make_opt):
    target = jnp.array([1.0, -2.0, 0.5])
    p = {"w": jnp.zeros(3)}
    opt = make_opt()
    st = opt.init(p)
    for _ in range(400):
        g = jax.grad(quad_loss)(p, target)
        p, st = opt.update(g, st, p)
    np.testing.assert_allclose(p["w"], target, atol=0.2)


def test_adamw_bf16_moments():
    opt = adamw(0.05, moment_dtype=jnp.bfloat16)
    p = {"w": jnp.zeros(4)}
    st = opt.init(p)
    assert st.inner["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones(4)}
    p2, st = opt.update(g, st, p)
    assert bool(jnp.all(p2["w"] < 0))


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 10.0), "b": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(800), rel=1e-5)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)
    # below-threshold gradients pass through untouched
    small = {"a": jnp.full((4,), 1e-3), "b": jnp.zeros(4)}
    out, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(out["a"], small["a"], rtol=1e-6)


def test_trainable_mask_filters_bn_stats():
    from repro.models.batchnorm import bn_init
    from repro.parallel.sharding import unzip
    p_tree = {"bn": bn_init(8, jnp.float32),
              "w": __import__("repro.parallel.sharding",
                              fromlist=["Param"]).Param(jnp.ones(3),
                                                        ("embed",))}
    values, axes = unzip(p_tree)
    mask = trainable_mask(axes)
    assert mask["w"] is True
    assert mask["bn"]["mean"] is False and mask["bn"]["var"] is False
    assert mask["bn"]["scale"] is True

    opt = sgd(0.1, mask=mask)
    st = opt.init(values)
    g = jax.tree.map(jnp.ones_like, values)
    new, _ = opt.update(g, st, values)
    np.testing.assert_array_equal(new["bn"]["mean"], values["bn"]["mean"])
    assert not np.allclose(new["bn"]["scale"], values["bn"]["scale"])


def test_schedules():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0, rel=1e-3)
    assert float(s(100)) == pytest.approx(0.0, abs=1e-3)
    assert float(s(55)) < float(s(11))
    ld = linear_decay(1.0, 100)
    assert float(ld(0)) == 1.0 and float(ld(100)) == pytest.approx(0.1)


def test_grad_accumulation_matches_full_batch():
    """Microbatched gradients == full-batch gradients (linear loss in B)."""
    w = {"w": jnp.asarray([[0.3, -0.2], [0.1, 0.4]])}
    x = jax.random.normal(jax.random.key(0), (8, 2))
    y = jax.random.normal(jax.random.key(1), (8, 2))

    def loss(p, batch):
        bx, by = batch
        return jnp.mean((bx @ p["w"] - by) ** 2), {}

    (full, _), gfull = jax.value_and_grad(loss, has_aux=True)(w, (x, y))
    acc = GradAccumulator(4)
    l_acc, g_acc, _ = acc.accumulate(loss, w, (x, y))
    np.testing.assert_allclose(l_acc, full, rtol=1e-6)
    np.testing.assert_allclose(g_acc["w"], gfull["w"], rtol=1e-5)
