"""kernels.dispatch — backend policy, VMEM fallback boundary, and
kernel-vs-XLA-ref parity INSIDE the compiled sharded serving steps.

The parity tests force ``pallas-interpret`` so the actual kernel bodies
run inside the jit-end-to-end engines (shard_map-wrapped over the
("data",) mesh) and compare against the eager oracle; the 8-replica run
executes in a subprocess with ``--xla_force_host_platform_device_count=8``
(same pattern as test_sharded_engine / test_lm_sharded).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.routing import DartParams
from repro.data.datasets import DatasetConfig, make_batch
from repro.engine import DartEngine, LMDecodeEngine
from repro.kernels import dispatch
from repro.kernels.exit_gate.ref import ref_exit_gate
from repro.launch.mesh import make_serving_mesh
from repro.models.cnn_zoo import AlexNetConfig
from repro.models.transformer_lm import LMConfig, lm_init
from repro.parallel.sharding import unzip
from repro.runtime.trainer import Trainer, TrainConfig


# ---------------------------------------------------------------------------
# backend selection policy
# ---------------------------------------------------------------------------

def test_auto_policy_never_interprets():
    """Interpret mode must be opt-in: auto selection is pallas on TPU
    and the XLA ref everywhere else — never the interpreter."""
    for kernel in ("exit_gate", "difficulty", "exit_head"):
        chosen = dispatch.select_backend(kernel, vmem_bytes=1024)
        expect = "pallas" if jax.default_backend() == "tpu" else "xla"
        assert chosen == expect


def test_forced_backend_scope_and_validation():
    assert dispatch.forced_backend() is None
    with dispatch.force_backend("pallas-interpret"):
        assert dispatch.forced_backend() == "pallas-interpret"
        assert dispatch.select_backend("exit_gate", vmem_bytes=0) == \
            "pallas-interpret"
        with dispatch.force_backend("xla"):
            assert dispatch.select_backend("exit_gate", vmem_bytes=0) == \
                "xla"
        assert dispatch.forced_backend() == "pallas-interpret"
    assert dispatch.forced_backend() is None
    with pytest.raises(ValueError, match="unknown backend"):
        with dispatch.force_backend("cuda"):
            pass


def test_env_backend_validation(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "xla")
    assert dispatch.forced_backend() == "xla"
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "auto")
    assert dispatch.forced_backend() is None
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "warp")
    with pytest.raises(ValueError, match="REPRO_KERNEL_BACKEND"):
        dispatch.forced_backend()


# ---------------------------------------------------------------------------
# VMEM fallback boundary
# ---------------------------------------------------------------------------

def test_vmem_boundary_select():
    """Just-under stays on the (forced) pallas backend; just-over
    degrades to the XLA ref — even under force."""
    budget = dispatch.VMEM_BUDGET_BYTES
    with dispatch.force_backend("pallas-interpret"):
        assert dispatch.select_backend("exit_gate",
                                       vmem_bytes=budget) == \
            "pallas-interpret"
        assert dispatch.select_backend("exit_gate",
                                       vmem_bytes=budget + 1) == "xla"


def test_vmem_boundary_end_to_end(monkeypatch):
    """The fused gate crosses the budget on real shapes: the kernel runs
    for a just-under row and the ref runs (bitwise) for a just-over
    row."""
    from repro.kernels.exit_gate import exit_gate_kernel as KMOD
    calls = []
    orig = KMOD.exit_gate_pallas

    def spy(*a, **kw):
        calls.append(kw.get("block_b"))
        return orig(*a, **kw)

    monkeypatch.setattr(KMOD, "exit_gate_pallas", spy)
    budget = dispatch.VMEM_BUDGET_BYTES
    v_under = budget // 8           # block_b=1 -> v * 8 bytes == budget
    v_over = v_under + 1
    rng = np.random.RandomState(0)
    with dispatch.force_backend("pallas-interpret"):
        lg = jnp.asarray(rng.randn(1, v_under), jnp.float32)
        got = dispatch.exit_gate(lg, jnp.zeros(1))
        assert len(calls) == 1      # kernel traced
        want = ref_exit_gate(lg, jnp.zeros(1))
        np.testing.assert_allclose(got[0], want[0], rtol=3e-5, atol=3e-6)
        np.testing.assert_array_equal(got[2], want[2])

        lg = jnp.asarray(rng.randn(1, v_over), jnp.float32)
        got = dispatch.exit_gate(lg, jnp.zeros(1))
        assert len(calls) == 1      # fell back: no new kernel trace
        want = ref_exit_gate(lg, jnp.zeros(1))
        np.testing.assert_array_equal(got[0], want[0])   # ref bitwise
        np.testing.assert_array_equal(got[2], want[2])


# ---------------------------------------------------------------------------
# parity inside the compiled sharded steps (1-device mesh in-process)
# ---------------------------------------------------------------------------

DATA = DatasetConfig(name="synth-cifar", n_train=256, n_eval=128)
COSTS = [0.3, 0.7, 1.0]


@pytest.fixture(scope="module")
def trained_cnn():
    mc = AlexNetConfig(img_res=32, n_classes=10,
                       channels=(16, 24, 32, 24, 24), fc_dims=(96, 48))
    tr = Trainer(mc, TrainConfig(batch_size=32, steps=10, lr=3e-3), DATA)
    tr.run()
    return mc, tr.params


def _dart(tau):
    return DartParams(tau=jnp.full((2,), tau), coef=jnp.ones(2),
                      beta_diff=0.3)


def test_kernels_inside_sharded_steps_match_oracle(trained_cnn):
    """With pallas-interpret forced, the masked AND compacted compiled
    steps run the actual kernel bodies (shard_map-wrapped) — decisions
    must match the eager oracle and confidences must be allclose."""
    mc, params = trained_cnn
    x, _ = make_batch(DATA, range(24), split="eval")
    with dispatch.force_backend("pallas-interpret"):
        eng = DartEngine.from_config(mc, params, mesh=make_serving_mesh(),
                                     dart=_dart(0.2), cum_costs=COSTS)
        ref = eng.infer(x, mode="eager")
        out = eng.infer(x, mode="masked")
        np.testing.assert_array_equal(out["exit_idx"],
                                      np.asarray(ref["exit_idx"]))
        np.testing.assert_array_equal(out["pred"], np.asarray(ref["pred"]))
        np.testing.assert_allclose(out["conf"], np.asarray(ref["conf"]),
                                   rtol=3e-5, atol=3e-5)
        np.testing.assert_allclose(out["alpha"], np.asarray(ref["alpha"]),
                                   rtol=3e-5, atol=3e-5)
        com = eng.infer(x, mode="compacted")
        np.testing.assert_array_equal(com["exit_idx"], out["exit_idx"])
        np.testing.assert_array_equal(com["pred"], out["pred"])


def test_no_retrace_after_kernel_wiring(trained_cnn):
    """One trace per (step, bucket) must survive the kernel wiring —
    repeated serving in one bucket never retraces, on either backend."""
    mc, params = trained_cnn
    x, _ = make_batch(DATA, range(16), split="eval")
    for backend in (None, "pallas-interpret"):
        with dispatch.force_backend(backend):
            eng = DartEngine.from_config(mc, params,
                                         mesh=make_serving_mesh(),
                                         dart=_dart(0.2), cum_costs=COSTS)
            for n in (3, 4, 3, 4):              # one bucket
                eng.infer(x[:n], mode="masked")
            assert eng.trace_counts == {("masked", 4, True): 1}, \
                (backend, eng.trace_counts)
            eng.infer(x[:13], mode="compacted")
            eng.infer(x[:16], mode="compacted")
            for key, count in eng.trace_counts.items():
                assert count == 1, (backend, key, count)


LM_CFG = LMConfig(name="lm-dispatch-t", n_layers=4, d_model=32, n_heads=2,
                  n_kv_heads=1, d_ff=64, vocab=32, exit_layers=(0, 2),
                  max_seq=64, remat=False)


def test_fused_exit_head_inside_decode_step_matches_oracle():
    """The fused exit-head kernel inside the compiled (stage, bucket)
    decode step must reproduce the eager oracle's tokens and exit
    depths."""
    params = unzip(lm_init(jax.random.key(0), LM_CFG))[0]
    prompts = np.random.RandomState(0).randint(0, LM_CFG.vocab, (5, 7))
    dart = DartParams(tau=jnp.full((2,), 0.05), coef=jnp.ones(2),
                      beta_diff=0.1)
    eager = LMDecodeEngine(LM_CFG, params, dart)
    tok_e, stg_e = eager.generate(prompts, n_new=8)
    with dispatch.force_backend("pallas-interpret"):
        sh = LMDecodeEngine(LM_CFG, params, dart,
                            mesh=make_serving_mesh())
        tok_s, stg_s = sh.generate(prompts, n_new=8)
    np.testing.assert_array_equal(tok_s, tok_e)
    np.testing.assert_array_equal(stg_s, stg_e)
    # one trace per (stage, bucket) with the kernel in the step
    for key, count in sh.trace_counts.items():
        assert count == 1, (key, count)


# ---------------------------------------------------------------------------
# 8-replica parity (subprocess, fake devices)
# ---------------------------------------------------------------------------

MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, %r)
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.routing import DartParams
    from repro.data.datasets import DatasetConfig, make_batch
    from repro.engine import DartEngine, LMDecodeEngine
    from repro.kernels import dispatch
    from repro.launch.mesh import make_serving_mesh
    from repro.models.cnn_zoo import AlexNetConfig
    from repro.models.transformer_lm import LMConfig, lm_init
    from repro.parallel.sharding import unzip
    from repro.runtime.trainer import Trainer, TrainConfig

    DATA = DatasetConfig(name="synth-cifar", n_train=256, n_eval=128)
    mc = AlexNetConfig(img_res=32, n_classes=10,
                       channels=(16, 24, 32, 24, 24), fc_dims=(96, 48))
    tr = Trainer(mc, TrainConfig(batch_size=32, steps=8, lr=3e-3), DATA)
    tr.run()
    dart = DartParams(tau=jnp.full((2,), 0.2), coef=jnp.ones(2),
                      beta_diff=0.3)
    x, _ = make_batch(DATA, range(24), split="eval")
    with dispatch.force_backend("pallas-interpret"):
        eng = DartEngine.from_config(mc, tr.params,
                                     mesh=make_serving_mesh(), dart=dart,
                                     cum_costs=[0.3, 0.7, 1.0])
        assert eng.n_replicas == 8, eng.n_replicas
        ref = eng.infer(x, mode="eager")
        out = eng.infer(x, mode="masked")
        np.testing.assert_array_equal(out["exit_idx"],
                                      np.asarray(ref["exit_idx"]))
        np.testing.assert_array_equal(out["pred"],
                                      np.asarray(ref["pred"]))
        np.testing.assert_allclose(out["conf"], np.asarray(ref["conf"]),
                                   rtol=3e-5, atol=3e-5)
        com = eng.infer(x, mode="compacted")
        np.testing.assert_array_equal(com["exit_idx"], out["exit_idx"])
        np.testing.assert_array_equal(com["pred"], out["pred"])
        for key, count in eng.trace_counts.items():
            assert count == 1, (key, count)

        # a non-replica-divisible admission batch degrades to the xla
        # ref instead of a broken shard_map
        a3 = np.asarray(eng._alpha(jnp.asarray(x[:3])))
        np.testing.assert_allclose(
            a3, np.asarray(ref["alpha"])[:3], rtol=3e-5, atol=3e-5)

    cfg = LMConfig(name="lm-dispatch-8", n_layers=4, d_model=32,
                   n_heads=2, n_kv_heads=1, d_ff=64, vocab=32,
                   exit_layers=(0, 2), max_seq=64, remat=False)
    params = unzip(lm_init(jax.random.key(0), cfg))[0]
    prompts = np.random.RandomState(0).randint(0, cfg.vocab, (5, 7))
    ldart = DartParams(tau=jnp.full((2,), 0.05), coef=jnp.ones(2),
                       beta_diff=0.1)
    tok_e, stg_e = LMDecodeEngine(cfg, params, ldart).generate(
        prompts, n_new=6)
    with dispatch.force_backend("pallas-interpret"):
        sh = LMDecodeEngine(cfg, params, ldart, mesh=make_serving_mesh())
        tok_s, stg_s = sh.generate(prompts, n_new=6)
    np.testing.assert_array_equal(tok_s, tok_e)
    np.testing.assert_array_equal(stg_s, stg_e)
    print("DISPATCH_8DEV_OK")
""" % os.path.join(os.path.dirname(__file__), "..", "src"))


def test_kernel_parity_on_8_devices():
    """Forced-kernel parity inside the compiled sharded steps on an
    8-fake-device ("data",) mesh (subprocess)."""
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DISPATCH_8DEV_OK" in r.stdout
