"""Per-kernel allclose sweeps (shapes × dtypes) against the ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch
from repro.kernels.difficulty.difficulty_kernel import difficulty_pallas
from repro.kernels.difficulty.ref import ref_components
from repro.kernels.difficulty import ops as dops
from repro.kernels.exit_gate.exit_gate_kernel import exit_gate_pallas
from repro.kernels.exit_gate.ref import ref_exit_gate
from repro.kernels.exit_gate import ops as gops
from repro.kernels.exit_head.exit_head_kernel import exit_head_gate_pallas
from repro.kernels.exit_head.ref import ref_exit_head_gate
from repro.core.difficulty import DifficultyConfig


DIFF_SHAPES = [(1, 28, 28, 1), (4, 32, 32, 3), (2, 64, 64, 3),
               (3, 48, 80, 3), (2, 224, 224, 3), (1, 128, 128, 4)]


@pytest.mark.parametrize("shape", DIFF_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_difficulty_kernel_matches_ref(shape, dtype):
    img = jax.random.uniform(jax.random.key(hash(shape) % 1000),
                             shape).astype(dtype)
    got = difficulty_pallas(img)
    want = ref_components(img)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("params", [
    dict(tau_edge=0.05, var_scale=0.1, grad_scale=0.1, w1=0.5, w2=0.25,
         w3=0.25),
    dict(tau_edge=0.3, var_scale=0.02, grad_scale=0.5, w1=0.2, w2=0.4,
         w3=0.4),
])
def test_difficulty_kernel_param_sweep(params):
    img = jax.random.uniform(jax.random.key(7), (3, 40, 40, 3))
    got = difficulty_pallas(img, **params)
    want = ref_components(img, **params)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


def test_difficulty_ops_dispatch_and_fallback():
    cfg = DifficultyConfig()
    small = jax.random.uniform(jax.random.key(0), (2, 32, 32, 3))
    # auto on CPU: the xla ref chain
    np.testing.assert_allclose(dops.components(small, cfg),
                               ref_components(small), rtol=2e-5, atol=2e-6)
    with dispatch.force_backend("pallas-interpret"):
        # forced kernel path matches the ref
        np.testing.assert_allclose(dops.components(small, cfg),
                                   ref_components(small), rtol=2e-5,
                                   atol=2e-6)
        # oversized image falls back to the jnp ref
        big = jax.random.uniform(jax.random.key(1), (1, 2048, 1024, 3))
        np.testing.assert_allclose(dops.components(big, cfg),
                                   ref_components(big), rtol=2e-5,
                                   atol=2e-6)


GATE_SHAPES = [(1, 2), (8, 10), (4, 1000), (2, 32000), (1, 129280),
               (16, 49155)]


@pytest.mark.parametrize("shape", GATE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_exit_gate_matches_ref(shape, dtype):
    b, v = shape
    lg = (jax.random.normal(jax.random.key(v), (b, v)) * 4).astype(dtype)
    th = jax.random.uniform(jax.random.key(v + 1), (b,))
    got = exit_gate_pallas(lg, th)
    want = ref_exit_gate(lg, th)
    np.testing.assert_allclose(got[0], want[0], rtol=3e-5, atol=3e-6)
    np.testing.assert_allclose(got[1], want[1], rtol=3e-4, atol=3e-5)
    np.testing.assert_array_equal(got[2], want[2])
    np.testing.assert_array_equal(got[3], want[3])


def test_exit_gate_tie_breaking():
    """argmax must pick the FIRST maximal index, like jnp.argmax."""
    lg = jnp.zeros((2, 64)).at[0, 5].set(3.0).at[0, 9].set(3.0) \
        .at[1, 0].set(1.0)
    got = exit_gate_pallas(lg, jnp.zeros(2))
    want = ref_exit_gate(lg, jnp.zeros(2))
    np.testing.assert_array_equal(got[2], want[2])
    assert int(got[2][0]) == 5


def test_exit_gate_threshold_edge():
    """fire must be a STRICT > comparison (Alg. 1 line 8)."""
    lg = jnp.log(jnp.array([[0.7, 0.2, 0.1]]))
    conf = ref_exit_gate(lg, jnp.zeros(1))[0]
    got_eq = exit_gate_pallas(lg, conf)         # τ == conf -> no fire
    assert int(got_eq[3][0]) == 0
    got_lt = exit_gate_pallas(lg, conf - 1e-3)
    assert int(got_lt[3][0]) == 1


def test_softmax_confidence_nd():
    lg = jax.random.normal(jax.random.key(3), (5, 7, 33))
    ref_conf = jnp.max(jax.nn.softmax(lg, axis=-1), axis=-1)
    for backend in (None, "pallas-interpret"):
        conf, pred = gops.softmax_confidence(lg, backend=backend)
        np.testing.assert_allclose(conf, ref_conf, rtol=2e-5, atol=2e-6)
        np.testing.assert_array_equal(pred, jnp.argmax(lg, axis=-1))


@pytest.mark.parametrize("block_b", [1, 2, 4])
def test_exit_gate_blocked_rows_match(block_b):
    """The autotuned rows-per-grid-step variant must match block_b=1."""
    lg = jax.random.normal(jax.random.key(9), (8, 50)) * 3
    th = jax.random.uniform(jax.random.key(10), (8,))
    got = exit_gate_pallas(lg, th, block_b=block_b)
    want = ref_exit_gate(lg, th)
    np.testing.assert_allclose(got[0], want[0], rtol=3e-5, atol=3e-6)
    np.testing.assert_array_equal(got[2], want[2])
    np.testing.assert_array_equal(got[3], want[3])


def test_exit_gate_blocked_requires_divisor():
    lg = jnp.zeros((6, 8))
    with pytest.raises(ValueError, match="does not divide"):
        exit_gate_pallas(lg, jnp.zeros(6), block_b=4)


# ---------------------------------------------------------------------------
# fused LM exit head (rmsnorm -> unembed -> conf -> Eq. 19 gate)
# ---------------------------------------------------------------------------

HEAD_SHAPES = [(1, 8, 16, None), (4, 32, 64, 16), (2, 16, 100, 25),
               (3, 24, 96, 96), (5, 64, 1000, 250)]


@pytest.mark.parametrize("shape", HEAD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_exit_head_matches_ref(shape, dtype):
    b, d, v, block_v = shape
    key = jax.random.key(b * 1000 + v)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    h = (jax.random.normal(k1, (b, d)) * 2).astype(dtype)
    scale = (1.0 + 0.1 * jax.random.normal(k2, (d,))).astype(dtype)
    tab = jax.random.normal(k3, (v, d)).astype(dtype)
    th = jax.random.uniform(k4, (b,))
    got = exit_head_gate_pallas(h, scale, tab, th, block_v=block_v)
    want = ref_exit_head_gate(h, scale, tab, th)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got[0], want[0], rtol=tol, atol=tol)
    np.testing.assert_array_equal(got[1], want[1])
    np.testing.assert_array_equal(got[2], want[2])


def test_exit_head_tie_and_threshold_edge():
    """Cross-block argmax ties resolve to the FIRST index; the gate is
    a strict > compare."""
    d, v = 8, 32
    h = jnp.ones((1, d))
    scale = jnp.ones((d,))
    # two identical unembed rows (5 and 21) in different vocab blocks
    tab = jnp.zeros((v, d)).at[5].set(0.3).at[21].set(0.3)
    got = exit_head_gate_pallas(h, scale, tab, jnp.zeros(1), block_v=16)
    want = ref_exit_head_gate(h, scale, tab, jnp.zeros(1))
    assert int(got[1][0]) == int(want[1][0]) == 5
    conf = ref_exit_head_gate(h, scale, tab, jnp.zeros(1))[0]
    eq = exit_head_gate_pallas(h, scale, tab, conf, block_v=16)
    assert int(eq[2][0]) == 0                # tau == conf -> no fire
    lt = exit_head_gate_pallas(h, scale, tab, conf - 1e-3, block_v=16)
    assert int(lt[2][0]) == 1


def test_exit_head_block_v_divides_and_fits():
    budget = dispatch.VMEM_BUDGET_BYTES
    for v, d in [(32, 16), (32000, 4096), (129280, 7168), (997, 64)]:
        bv = dispatch.exit_head_block_v(v, d)
        assert v % bv == 0
        assert dispatch._head_step_bytes(bv, d) <= budget
