"""Property-test example budgets — the two-tier CI knob (ISSUE 9).

PR CI runs every ``max_examples`` budget as written; the nightly
workflow (.github/workflows/nightly.yml) sets
``REPRO_HYPOTHESIS_PROFILE=nightly`` to multiply every budget 10x.
Budgets route through :func:`examples` because hypothesis gives an
explicit per-test ``@settings(max_examples=...)`` precedence over a
loaded profile — scaling at the decorator is the only place the
nightly raise actually bites.  The deterministic fallback shim
(``_hypothesis_compat``) honours the same variable.
"""
import os

PROFILES = {"nightly": 10}
SCALE = PROFILES.get(os.environ.get("REPRO_HYPOTHESIS_PROFILE", ""), 1)


def examples(n: int) -> int:
    """The effective example budget for a ``max_examples=n`` test."""
    return int(n) * SCALE
