"""Deterministic fallback for the tiny hypothesis API surface the
property tests use (``given`` / ``settings`` / ``st.integers`` /
``st.floats`` / ``st.sampled_from``).

The real hypothesis package (pinned in requirements-test.txt) is the
primary engine — it shrinks failures and explores adversarially.  This
shim exists so environments where test extras cannot be installed still
RUN the properties (seeded uniform sampling, same example counts)
instead of skipping them wholesale, which is how three test modules
stayed perpetually skipped through PRs 1-6.

When ``REPRO_REQUIRE_HYPOTHESIS`` is set (CI does this), importing the
shim raises ImportError: the fallback must never mask a broken test
environment where the declared dependency should have been installed.
"""
import os

if os.environ.get("REPRO_REQUIRE_HYPOTHESIS"):
    raise ImportError(
        "REPRO_REQUIRE_HYPOTHESIS is set: the real hypothesis package "
        "(requirements-test.txt) is required; the deterministic "
        "fallback shim is disabled")

import zlib

import numpy as np

DEFAULT_EXAMPLES = 20


class _Strategy:
    """A draw rule: maps a RandomState to one example."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(
            rng.randint(min_value, max_value + 1, dtype=np.int64)))

    @staticmethod
    def floats(min_value, max_value):
        lo, hi = float(min_value), float(max_value)
        return _Strategy(lambda rng: lo + (hi - lo) * float(
            rng.random_sample()))

    @staticmethod
    def sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng: opts[int(rng.randint(len(opts)))])


st = strategies


def settings(max_examples: int = DEFAULT_EXAMPLES, deadline=None,
             **_ignored):
    """Records the example budget on the decorated function; ``given``
    reads it at call time, so either decorator order works."""
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        def runner():
            n = getattr(runner, "_max_examples",
                        getattr(fn, "_max_examples", DEFAULT_EXAMPLES))
            # seeded per test NAME: deterministic across runs and
            # independent of suite ordering
            rng = np.random.RandomState(
                zlib.crc32(fn.__name__.encode()) & 0x7FFFFFFF)
            for i in range(n):
                drawn = {k: s.example(rng) for k, s in strats.items()}
                try:
                    fn(**drawn)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example ({i + 1}/{n}): "
                        f"{drawn!r}") from e
        # NOT functools.wraps: pytest would follow __wrapped__ to the
        # inner signature and demand fixtures for the drawn arguments
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner
    return deco
