"""Model-zoo correctness: decode==prefill==full-forward, chunked==dense
attention, MoE dispatch semantics, MLA absorbed-decode equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.sharding import unzip, param_count
from repro.models.transformer_lm import (LMConfig, lm_init, lm_forward,
                                         lm_prefill, lm_decode_step,
                                         lm_init_cache, lm_multi_exit_loss,
                                         lm_param_count, lm_kv_propagate)
from repro.models.moe import MoEConfig, moe_init, moe_apply
from repro.models.layers import dense_attention, chunked_attention
from repro.models import layers as L


KEY = jax.random.key(0)


def tiny_lm(**kw):
    base = dict(name="t", n_layers=3, d_model=48, n_heads=4, n_kv_heads=2,
                d_ff=96, vocab=64, exit_layers=(0,), max_seq=32,
                remat=False)
    base.update(kw)
    return LMConfig(**base)


@pytest.mark.parametrize("attn_kind,extra", [
    ("gqa", {}),
    ("mla", dict(n_kv_heads=4, q_lora_rank=24, kv_lora_rank=12,
                 qk_nope_dim=12, qk_rope_dim=8, v_head_dim=12)),
])
def test_decode_matches_full_forward(attn_kind, extra):
    cfg = tiny_lm(attn_kind=attn_kind, **extra)
    p, _ = unzip(lm_init(KEY, cfg))
    toks = jax.random.randint(KEY, (2, 9), 0, cfg.vocab)
    full = lm_forward(p, toks, cfg)
    cache = lm_init_cache(cfg, 2, 16)
    cache, exit_h_pref = lm_prefill(p, toks[:, :8], cfg, cache)
    eh, cache = lm_decode_step(p, toks[:, 8:9], cache, 8, cfg)
    np.testing.assert_allclose(eh[-1], full["final_hidden"][:, 8],
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(eh[0], full["exit_hidden"][0][:, 8],
                               atol=3e-4, rtol=3e-4)
    # prefill's last-position exit hiddens match the full forward too
    np.testing.assert_allclose(exit_h_pref[-1], full["final_hidden"][:, 7],
                               atol=3e-4, rtol=3e-4)


def test_multi_step_decode_consistency():
    cfg = tiny_lm()
    p, _ = unzip(lm_init(KEY, cfg))
    toks = jax.random.randint(KEY, (1, 12), 0, cfg.vocab)
    full = lm_forward(p, toks, cfg)
    cache = lm_init_cache(cfg, 1, 16)
    cache, _ = lm_prefill(p, toks[:, :6], cfg, cache)
    for i in range(6, 12):
        eh, cache = lm_decode_step(p, toks[:, i:i + 1], cache, i, cfg)
        np.testing.assert_allclose(eh[-1], full["final_hidden"][:, i],
                                   atol=5e-4, rtol=5e-4,
                                   err_msg=f"step {i}")


def test_kv_propagation_fills_deeper_layers():
    """CALM state propagation: after propagation, deeper-layer caches hold
    finite entries at the current position and later decode steps run."""
    cfg = tiny_lm(n_layers=4, exit_layers=(1,))
    p, _ = unzip(lm_init(KEY, cfg))
    toks = jax.random.randint(KEY, (2, 6), 0, cfg.vocab)
    cache = lm_init_cache(cfg, 2, 16)
    cache, _ = lm_prefill(p, toks[:, :5], cfg, cache)
    eh, cache_full = lm_decode_step(p, toks[:, 5:6], cfg=cfg, cache=cache,
                                    cache_index=5)
    cache_prop = lm_kv_propagate(p, eh[0], cfg, cache, 5, from_layer=2)
    for layer in (2, 3):
        k = cache_prop[layer]["k"][:, 5]
        assert bool(jnp.all(jnp.isfinite(k)))
        assert float(jnp.abs(k).sum()) > 0
    # propagated KV differs from full-compute KV (it is an approximation)
    assert not np.allclose(cache_prop[3]["k"][:, 5],
                           cache_full[3]["k"][:, 5])


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("qc,kc", [(4, 4), (8, 16), (16, 8)])
def test_chunked_attention_equivalence(causal, qc, kc):
    q = jax.random.normal(jax.random.key(1), (2, 16, 4, 8))
    k = jax.random.normal(jax.random.key(2), (2, 16, 2, 8))
    v = jax.random.normal(jax.random.key(3), (2, 16, 2, 8))
    d = dense_attention(q, k, v, causal=causal)
    c = chunked_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
    np.testing.assert_allclose(d, c, atol=3e-5, rtol=3e-5)


def test_moe_capacity_semantics():
    """With uniform routing and generous capacity nothing is dropped:
    output == Σ_k prob_k · FFN_{e_k}(x) for every token."""
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff=16, capacity_factor=8.0)
    p, _ = unzip(moe_init(KEY, 8, cfg, jnp.float32))
    x = jax.random.normal(jax.random.key(5), (16, 8))
    out, aux = moe_apply(p, x, cfg)

    # manual dense reference
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, ids = jax.lax.top_k(probs, 2)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    want = jnp.zeros_like(x)
    for t in range(16):
        acc = jnp.zeros(8)
        for j in range(2):
            e = int(ids[t, j])
            h = jax.nn.silu(x[t] @ p["w_gate"][e]) * (x[t] @ p["w_up"][e])
            acc = acc + top_p[t, j] * (h @ p["w_down"][e])
        want = want.at[t].set(acc)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_moe_grad_flows_to_router_and_experts():
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff=16)
    p, _ = unzip(moe_init(KEY, 8, cfg, jnp.float32))
    x = jax.random.normal(jax.random.key(6), (32, 8))

    def loss(p):
        out, aux = moe_apply(p, x, cfg)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(p)
    for name in ("router", "w_gate", "w_down"):
        assert float(jnp.abs(g[name]).sum()) > 0, name


def test_moe_aux_loss_balanced_vs_collapsed():
    """Collapsed routing (one hot expert) must cost more aux loss than
    near-uniform routing."""
    cfg = MoEConfig(n_experts=4, top_k=1, aux_loss_weight=1.0, d_ff=8)
    from repro.models.moe import _route
    x = jax.random.normal(jax.random.key(7), (256, 8))
    w_uniform = jnp.zeros((8, 4))
    _, _, aux_u = _route(x, w_uniform, cfg)
    w_collapsed = jnp.zeros((8, 4)).at[:, 0].set(10.0)
    _, _, aux_c = _route(x, w_collapsed, cfg)
    assert float(aux_c) > float(aux_u)


def test_param_count_analytic_close():
    cfg = tiny_lm(tie_embeddings=False)
    p, _ = unzip(lm_init(KEY, cfg))
    got = param_count(p)
    want = lm_param_count(cfg)
    assert abs(got - want) / want < 0.02, (got, want)


def test_multi_exit_loss_weights():
    """Eq. 18: w_i = i/N — the final head must carry the largest weight."""
    cfg = tiny_lm(exit_layers=(0, 1))
    p, _ = unzip(lm_init(KEY, cfg))
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    loss, aux = lm_multi_exit_loss(p, toks, toks, cfg, xent_chunks=2)
    ces = aux["ce_per_exit"]
    manual = sum((i + 1) / 3 * ces[i] for i in range(3))
    assert float(loss) >= float(manual) - 1e-5   # + policy/aux are >= 0
