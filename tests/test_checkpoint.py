"""Checkpoint substrate: roundtrip, async, atomicity, integrity, GC."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as CK


@pytest.fixture
def tmpdir(tmp_path):
    return str(tmp_path)


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "d": jnp.asarray(3)},
            "e": [jnp.zeros(2), jnp.full((2, 2), -1.0)]}


def test_roundtrip_bitexact(tmpdir):
    t = tree()
    CK.save(tmpdir, 3, t, {"lr": 0.1})
    got, step, extra = CK.restore(tmpdir, t)
    assert step == 3 and extra["lr"] == 0.1
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


import jax  # noqa: E402  (used above in tree comparisons)


def test_async_save_and_latest(tmpdir):
    t = tree()
    f1 = CK.save_async(tmpdir, 1, t)
    f2 = CK.save_async(tmpdir, 2, t)
    f1.result(); f2.result()
    assert CK.latest_step(tmpdir) == 2


def test_crc_detects_corruption(tmpdir):
    t = tree()
    CK.save(tmpdir, 1, t)
    d = os.path.join(tmpdir, "step_00000001")
    victim = os.path.join(d, "leaf_00000.bin")
    raw = bytearray(open(victim, "rb").read())
    raw[0] ^= 0xFF
    open(victim, "wb").write(bytes(raw))
    with pytest.raises(IOError, match="CRC"):
        CK.restore(tmpdir, t)


def test_structure_mismatch_raises(tmpdir):
    CK.save(tmpdir, 1, tree())
    with pytest.raises(ValueError, match="leaf count"):
        CK.restore(tmpdir, {"only": jnp.zeros(1)})


def test_tmp_dirs_invisible(tmpdir):
    """A torn write (left-over .tmp) must not be considered a checkpoint."""
    os.makedirs(os.path.join(tmpdir, "step_00000009.tmp"))
    assert CK.latest_step(tmpdir) is None


def test_manager_gc_and_backpressure(tmpdir):
    mgr = CK.CheckpointManager(tmpdir, keep=2, save_every=1)
    t = tree()
    for s in range(1, 6):
        mgr.maybe_save(s, t)
    mgr.wait()
    mgr._gc()
    steps = sorted(d for d in os.listdir(tmpdir) if d.startswith("step_"))
    assert len(steps) <= 2
    assert CK.latest_step(tmpdir) == 5


def test_restore_respects_target_dtype(tmpdir):
    t = {"w": jnp.ones((4,), jnp.float32)}
    CK.save(tmpdir, 1, t)
    target = {"w": jnp.zeros((4,), jnp.bfloat16)}
    got, _, _ = CK.restore(tmpdir, target)
    assert got["w"].dtype == jnp.bfloat16
