"""Docs lint: python snippets compile, intra-repo links resolve, and
the pages ISSUE 2 promises actually exist."""
import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")


def test_docs_pages_exist():
    for page in ("architecture.md", "serving.md", "paper_map.md"):
        assert os.path.exists(os.path.join(ROOT, "docs", page)), page


def test_docs_check_passes():
    r = subprocess.run([sys.executable,
                        os.path.join(ROOT, "tools", "docs_check.py")],
                       capture_output=True, text=True, timeout=60)
    assert r.returncode == 0, r.stderr
    assert "all links OK" in r.stdout


def test_docs_check_catches_bad_snippet(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("intro\n```python\ndef broken(:\n```\n"
                   "and a [dead link](nope/missing.md)\n")
    r = subprocess.run([sys.executable,
                        os.path.join(ROOT, "tools", "docs_check.py"),
                        str(bad)],
                       capture_output=True, text=True, timeout=60)
    assert r.returncode == 1
    assert "does not compile" in r.stderr
    assert "broken link" in r.stderr
