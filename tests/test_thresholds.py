"""Property tests for Eq. 12 / Eq. 19 / Algorithm 1."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # deterministic fallback (raises under REPRO_REQUIRE_HYPOTHESIS=1,
    # which CI sets — there the real package must be installed)
    from _hypothesis_compat import given, settings, strategies as st

from _prop import examples

from repro.core import thresholds as TH


@settings(max_examples=examples(50), deadline=None)
@given(seed=st.integers(0, 10_000), e=st.integers(2, 6),
       b=st.integers(1, 16), beta=st.floats(0.0, 1.0))
def test_select_exit_matches_sequential_alg1(seed, e, b, beta):
    """Vectorized routing == per-sample Algorithm 1 loop."""
    rs = np.random.RandomState(seed)
    conf = rs.rand(e, b).astype(np.float32)
    tau = rs.rand(e - 1).astype(np.float32)
    coef = 0.5 + rs.rand(e - 1).astype(np.float32)
    alpha = rs.rand(b).astype(np.float32)

    eff = TH.adapt_thresholds(jnp.asarray(tau), jnp.asarray(coef),
                              jnp.asarray(alpha), beta)
    idx, c = TH.select_exit(jnp.asarray(conf), eff)

    for s in range(b):
        expected = e - 1
        for i in range(e - 1):
            t = np.clip(coef[i] * tau[i] + beta * alpha[s], 0.0, 1.0)
            if conf[i, s] > t:
                expected = i
                break
        assert int(idx[s]) == expected, (s, int(idx[s]), expected)
        assert float(c[s]) == pytest.approx(conf[expected, s])


@settings(max_examples=examples(50), deadline=None)
@given(seed=st.integers(0, 10_000), beta=st.floats(0.0, 1.0))
def test_adapted_thresholds_clamped_and_monotone_in_alpha(seed, beta):
    rs = np.random.RandomState(seed)
    tau = rs.rand(3)
    coef = rs.rand(3) * 2
    a1, a2 = sorted(rs.rand(2))
    e1 = TH.adapt_thresholds(jnp.asarray(tau), jnp.asarray(coef),
                             jnp.asarray([a1]), beta)
    e2 = TH.adapt_thresholds(jnp.asarray(tau), jnp.asarray(coef),
                             jnp.asarray([a2]), beta)
    assert bool(jnp.all(e1 >= 0)) and bool(jnp.all(e1 <= 1))
    # harder inputs never get LOWER thresholds (Eq. 19, β ≥ 0)
    assert bool(jnp.all(e2 >= e1))


def test_harder_inputs_exit_later_on_average():
    """The paper's central behavioural claim."""
    rs = np.random.RandomState(0)
    n, e = 2000, 4
    conf = rs.rand(n, e).astype(np.float32)
    tau = np.full(e - 1, 0.5, np.float32)
    easy = TH.simulate_routing(conf, np.zeros(n), tau, np.ones(e - 1), 0.4)
    hard = TH.simulate_routing(conf, np.ones(n), tau, np.ones(e - 1), 0.4)
    assert float(jnp.mean(hard)) > float(jnp.mean(easy))


def test_candidate_thresholds_are_quantiles():
    conf = np.linspace(0, 1, 101)
    cand = TH.candidate_thresholds(conf)
    np.testing.assert_allclose(cand, np.arange(0.1, 0.91, 0.1), atol=1e-9)
    assert np.all(np.diff(cand) >= 0)


def test_exit_distribution_and_expected_cost():
    idx = jnp.asarray([0, 0, 1, 3])
    pi = TH.exit_distribution(idx, 4)
    np.testing.assert_allclose(pi, [0.5, 0.25, 0.0, 0.25])
    c = TH.expected_cost(idx, [0.1, 0.4, 0.7, 1.0])
    assert float(c) == pytest.approx((0.1 + 0.1 + 0.4 + 1.0) / 4)


def test_objective_accuracy_cost_tradeoff():
    """β_opt = 0 maximizes accuracy; large β_opt prefers cheap exits."""
    rs = np.random.RandomState(1)
    n, e = 1000, 3
    conf = rs.rand(n, e)
    correct = np.tile([0.0, 0.0, 1.0], (n, 1))     # only final is right
    alpha = rs.rand(n)
    cum = np.array([0.2, 0.6, 1.0])
    tau_never = np.array([1.0, 1.0])               # never exit early
    tau_always = np.array([0.0, 0.0])
    j_acc = TH.objective(conf, alpha, correct, cum, tau_never,
                         np.ones(2), 0.0, beta_opt=0.0)
    j_acc2 = TH.objective(conf, alpha, correct, cum, tau_always,
                          np.ones(2), 0.0, beta_opt=0.0)
    assert float(j_acc) > float(j_acc2)
    j_cost = TH.objective(conf, alpha, correct, cum, tau_always,
                          np.ones(2), 0.0, beta_opt=10.0)
    j_cost2 = TH.objective(conf, alpha, correct, cum, tau_never,
                           np.ones(2), 0.0, beta_opt=10.0)
    assert float(j_cost) > float(j_cost2)
