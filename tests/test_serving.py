"""repro.serving: the async difficulty-aware request scheduler.

Covers: deadline-flush vs size-flush ordering,
future results identical to the eager oracle, backpressure shedding
lowest-priority first, latency-percentile telemetry against a
recomputed reference, and a seeded burst test that is deterministic on
the CPU backend (run the suite with ``JAX_PLATFORMS=cpu``; the conftest
already pins tests to the host platform's single device).

Scheduler-logic tests drive the loop manually (``start=False`` + a fake
clock + ``pump()``) so nothing depends on wall-clock timing; one test
exercises the real background dispatcher thread end to end.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.routing import DartParams
from repro.data.datasets import DatasetConfig, make_batch
from repro.engine import DartEngine
from repro.engine import state as ST
from repro.models.vit import ViTConfig, vit_init
from repro.parallel.sharding import unzip
from repro.serving import (AsyncDartServer, RequestShed, RequestRejected,
                           SchedulerConfig)

DATA = DatasetConfig(name="synth-cifar", n_train=128, n_eval=128)
COSTS = [0.4, 0.7, 1.0]


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture(scope="module")
def vit_engine_factory():
    vc = ViTConfig(name="vt", img_res=32, patch=8, n_layers=3, d_model=32,
                   n_heads=2, d_ff=64, n_classes=10, exit_layers=(0, 1))
    params, _ = unzip(vit_init(jax.random.key(0), vc))

    def make(**kw):
        kw.setdefault("cum_costs", COSTS)
        kw.setdefault("adapt", True)
        kw.setdefault("update_every", 10 ** 9)
        return DartEngine.from_config(
            vc, params,
            dart=DartParams(tau=jnp.full((2,), 0.2), coef=jnp.ones(2),
                            beta_diff=0.3), **kw)
    return make


@pytest.fixture(scope="module")
def eval_images():
    x, _ = make_batch(DATA, range(96), split="eval")
    return np.asarray(x)


# ---------------------------------------------------------------------------
# flush ordering
# ---------------------------------------------------------------------------
def test_deadline_flush_preempts_size_flush(vit_engine_factory, eval_images):
    """A deadline-pressed lane must dispatch before a size-ready lane,
    and a size-ready lane before a merely-held one."""
    eng = vit_engine_factory()
    alpha = np.asarray(eng._alpha(jnp.asarray(eval_images)))
    med = float(np.median(alpha))
    easy = eval_images[alpha <= med]
    hard = eval_images[alpha > med]
    clock = FakeClock()
    srv = AsyncDartServer(
        eng, SchedulerConfig(max_batch=8, flush_ms=50.0, margin_ms=5.0,
                             pipeline_depth=0, edges=(med,)),
        clock=clock, start=False)
    # lane 0 (easy): size-ready; lane 1 (hard): one small request whose
    # deadline falls inside the scheduling slack
    size_futs = [srv.submit(easy[i:i + 3]) for i in range(0, 9, 3)]
    ddl_fut = srv.submit(hard[:2], deadline_ms=4.0)
    assert srv.pump()                       # 1st decision: deadline lane
    assert ddl_fut.done() and not any(f.done() for f in size_futs)
    assert srv.counters["flush_deadline"] == 1
    assert srv.pump()                       # 2nd: the size-ready lane
    assert sum(f.done() for f in size_futs) >= 2
    assert srv.counters["flush_size"] == 1
    # a lone small request only flushes once its hold expires
    hold_fut = srv.submit(easy[10:12])
    assert not srv.pump()
    clock.advance(0.051)                    # > flush_ms
    assert srv.pump()
    assert hold_fut.done()
    assert srv.counters["flush_hold"] == 1
    srv.close()


def test_size_flush_stops_at_bucket_boundary(vit_engine_factory,
                                             eval_images):
    """The flush never grows into the next power-of-two bucket when the
    larger bucket would be mostly padding (min_fill)."""
    eng = vit_engine_factory()
    clock = FakeClock()
    # 8 + 3 queued samples: taking the 3-sample request would pad the
    # flushed bucket to 16 at 11/16 fill >= 0.5 -> taken; but at
    # min_fill=0.75 the flush must stop at the exactly-full 8-bucket.
    srv = AsyncDartServer(
        eng, SchedulerConfig(max_batch=16, flush_ms=10.0,
                             pipeline_depth=0, edges=()),
        clock=clock, start=False)
    srv_hi = AsyncDartServer(
        eng, SchedulerConfig(max_batch=16, flush_ms=10.0, min_fill=0.75,
                             pipeline_depth=0, edges=()),
        clock=clock, start=False)
    futs = {}
    for s in (srv, srv_hi):
        futs[s] = (s.submit(eval_images[:8]), s.submit(eval_images[8:11]))
    clock.advance(0.011)                    # hold expires for both
    for s in (srv, srv_hi):
        f8, f3 = futs[s]
        assert s.pump()                     # hold flush (non-forced take)
        assert f8.done()
        assert f3.done() is (s is srv)      # 0.5 takes it, 0.75 doesn't
        s.close()
        assert f3.done()
    # a size flush triggers WITHOUT any clock advance once the lane
    # exactly fills a bucket at >= half the target
    srv3 = AsyncDartServer(
        eng, SchedulerConfig(max_batch=16, flush_ms=10.0,
                             pipeline_depth=0, edges=()),
        clock=FakeClock(), start=False)
    f8 = srv3.submit(eval_images[:8])
    assert srv3.pump()
    assert f8.done() and srv3.counters["flush_size"] == 1
    srv3.close()


# ---------------------------------------------------------------------------
# oracle equivalence
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["masked", "compacted"])
def test_futures_match_eager_oracle(vit_engine_factory, eval_images, mode):
    """Every completed request's outputs must be identical to serving
    that request alone through the eager engine."""
    eng = vit_engine_factory()
    oracle = vit_engine_factory()
    with AsyncDartServer(eng, SchedulerConfig(max_batch=16, flush_ms=2.0,
                                              mode=mode)) as srv:
        sizes = [1, 3, 4, 2, 7, 1, 5, 4, 6, 3]
        reqs, start = [], 0
        for n in sizes:
            reqs.append((start, n, srv.submit(eval_images[start:start + n],
                                              deadline_ms=500.0)))
            start += n
        outs = [(a, n, f.result(timeout=120)) for a, n, f in reqs]
    for a, n, out in outs:
        ref = oracle.infer(eval_images[a:a + n], mode="masked",
                           record=False)
        np.testing.assert_array_equal(out["exit_idx"],
                                      np.asarray(ref["exit_idx"]))
        np.testing.assert_array_equal(out["pred"], np.asarray(ref["pred"]))
        np.testing.assert_allclose(out["conf"], np.asarray(ref["conf"]),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_array_equal(out["alpha"],
                                      np.asarray(ref["alpha"]))
    # per-sample serving telemetry folded for every dispatched sample
    assert int(np.sum(np.asarray(eng.state.served))) == sum(
        n for _, n, _ in outs)


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------
def test_backpressure_sheds_lowest_priority_first(vit_engine_factory,
                                                  eval_images):
    eng = vit_engine_factory()
    srv = AsyncDartServer(
        eng, SchedulerConfig(max_queue=2, policy="shed", edges=()),
        clock=FakeClock(), start=False)
    f_p1 = srv.submit(eval_images[:1], priority=1)
    f_p2 = srv.submit(eval_images[1:2], priority=2)
    # newcomer with the lowest priority is itself shed
    f_p0 = srv.submit(eval_images[2:3], priority=0)
    with pytest.raises(RequestShed):
        f_p0.result(timeout=5)
    # higher-priority newcomer evicts the lowest-priority queued request
    f_p9 = srv.submit(eval_images[3:4], priority=9)
    with pytest.raises(RequestShed):
        f_p1.result(timeout=5)
    assert srv.queue.shed == 2
    srv.close()          # drains the survivors
    assert f_p2.result(timeout=5)["pred"].shape == (1,)
    assert f_p9.result(timeout=5)["pred"].shape == (1,)


def test_backpressure_reject_and_degrade(vit_engine_factory, eval_images):
    eng = vit_engine_factory()
    srv = AsyncDartServer(
        eng, SchedulerConfig(max_queue=1, policy="reject", edges=()),
        clock=FakeClock(), start=False)
    ok = srv.submit(eval_images[:1])
    bad = srv.submit(eval_images[1:2])
    with pytest.raises(RequestRejected):
        bad.result(timeout=5)
    assert srv.queue.rejected == 1
    srv.close()
    assert ok.result(timeout=5)["deadline_missed"] is False

    # degrade-alpha: the over-limit request is admitted with scaled-down
    # difficulty (earlier exits = cheaper), re-laned as easy traffic
    eng2 = vit_engine_factory()
    alpha = np.asarray(eng2._alpha(jnp.asarray(eval_images[:2])))
    # put the class edge between the degraded and original difficulty
    edge = 0.5 * float(alpha.min())
    srv2 = AsyncDartServer(
        eng2, SchedulerConfig(max_queue=1, policy="degrade-alpha",
                              degrade_factor=0.25, edges=(edge,)),
        clock=FakeClock(), start=False)
    a = srv2.submit(eval_images[:1])        # lane 1 (hard), fills it
    b = srv2.submit(eval_images[1:2])       # lane 1 full -> degraded
    assert srv2.counters["degraded"] == 1
    srv2.close()
    a_out, b_out = a.result(timeout=5), b.result(timeout=5)
    assert a_out["lane"] == 1 and b_out["lane"] == 0
    np.testing.assert_allclose(b_out["alpha"], 0.25 * alpha[1:2],
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------
def test_latency_percentiles_match_recomputed_reference(vit_engine_factory,
                                                        eval_images):
    # Driven on a FakeClock with manual pumps: latencies are exact
    # scheduler-clock values, so the percentile comparison (and the
    # zero-miss assertion at a 10s SLO) cannot depend on host speed.
    eng = vit_engine_factory()
    clk = FakeClock()
    srv = AsyncDartServer(eng, SchedulerConfig(max_batch=8, flush_ms=1.0),
                          clock=clk, start=False)
    futs = []
    for i in range(0, 48, 2):
        futs.append(srv.submit(eval_images[i:i + 2], deadline_ms=1e4))
        clk.advance(0.003)         # staggered submits → distinct latencies
    for _ in range(1000):
        if all(f.done() for f in futs):
            break
        clk.advance(0.005)
        if not srv.pump():
            srv.flush()
    srv.close()
    lats = [f.result(timeout=5)["latency_ms"] for f in futs]
    st = srv.stats()
    assert st["requests"]["requests"] == len(lats)
    assert st["requests"]["deadline_miss"] == 0
    ref = np.percentile(np.asarray(lats, np.float32), [50.0, 95.0, 99.0])
    got = st["requests"]["latency_ms"]
    np.testing.assert_allclose([got["p50"], got["p95"], got["p99"]], ref,
                               rtol=1e-5)


def test_latency_ring_buffer_wraps(vit_engine_factory):
    """EngineState latency fold: ring overwrite keeps the LAST w records
    and the lifetime counters keep counting."""
    eng = vit_engine_factory()
    state = ST.EngineState.create(3, eng.acfg, lat_window=4)
    lats = [10.0, 20.0, 30.0, 40.0, 50.0, 60.0]
    state = ST.record_requests(state, lats[:3], missed=[True, False, False])
    state = ST.record_requests(state, lats[3:], missed=[False, True, False])
    st = ST.request_stats(state)
    assert st["requests"] == 6 and st["deadline_miss"] == 2
    assert st["miss_rate"] == pytest.approx(2 / 6)
    window = {50.0, 60.0, 30.0, 40.0}       # last 4, ring order
    assert set(np.asarray(state.lat_ms).tolist()) == window
    np.testing.assert_allclose(
        st["latency_ms"]["p95"],
        np.percentile(np.asarray(sorted(window), np.float32), 95.0))


def test_bad_request_fails_its_future_not_the_loop(vit_engine_factory,
                                                   eval_images):
    """An input the engine rejects (here: wrong channel count) must fail
    THAT bucket's futures and leave the scheduler serving."""
    eng = vit_engine_factory()
    clock = FakeClock()
    srv = AsyncDartServer(eng, SchedulerConfig(edges=()), clock=clock,
                          start=False)
    bad = srv.submit(np.zeros((2, 32, 32, 5), np.float32))
    clock.advance(1.0)                      # hold expires
    assert srv.pump()                       # dispatch fails, loop lives
    with pytest.raises(Exception):
        bad.result(timeout=5)
    assert srv.counters["dispatch_errors"] == 1
    ok = srv.submit(eval_images[:2])
    clock.advance(1.0)
    srv.close()
    assert ok.result(timeout=5)["pred"].shape == (2,)


def test_oversized_masked_request_dispatches_unpadded(vit_engine_factory,
                                                      eval_images):
    """A single request larger than the biggest bucket must not trip
    bucket_key overflow — it dispatches unpadded."""
    eng = vit_engine_factory(buckets=(1, 2, 4, 8))
    clock = FakeClock()
    srv = AsyncDartServer(eng, SchedulerConfig(max_batch=8, edges=()),
                          clock=clock, start=False)
    fut = srv.submit(eval_images[:12])      # 12 > max_bucket 8
    clock.advance(1.0)
    assert srv.pump()
    srv.close()
    out = fut.result(timeout=5)
    assert out["pred"].shape == (12,)
    oracle = vit_engine_factory(buckets=(1, 2, 4, 8))
    ref = oracle.infer(eval_images[:12], mode="masked", record=False)
    np.testing.assert_array_equal(out["exit_idx"],
                                  np.asarray(ref["exit_idx"]))


def test_max_batch_clamps_to_engine_buckets(vit_engine_factory,
                                            eval_images):
    """A consolidation target beyond the engine's largest bucket must
    clamp, not wedge the dispatcher with BatchTooLarge mid-flush."""
    eng = vit_engine_factory(buckets=(1, 2, 4, 8))
    clock = FakeClock()
    srv = AsyncDartServer(eng, SchedulerConfig(edges=()),  # max_batch=64
                          clock=clock, start=False)
    assert srv.max_batch == 8
    futs = [srv.submit(eval_images[i:i + 3]) for i in (0, 3, 6)]
    clock.advance(1.0)                      # hold expires: 9 queued
    while srv.pump():
        pass
    srv.close()
    for i, f in enumerate(futs):
        assert f.result(timeout=5)["pred"].shape == (3,)
    assert srv.last_error is None


def test_restore_pre_latency_checkpoint(vit_engine_factory, eval_images,
                                        tmp_path):
    """Checkpoints written before EngineState grew the latency leaves
    (a strict prefix of the new flatten order) must still restore."""
    from repro import checkpoint as CK
    eng = vit_engine_factory()
    eng.infer(eval_images[:16], mode="masked", record=True)
    legacy = [getattr(eng.state, f) for f in ST.LEGACY_FIELDS]
    CK.save(str(tmp_path), 3, legacy)       # legacy-shaped manifest
    eng2 = vit_engine_factory()
    assert eng2.restore_state(str(tmp_path)) == 3
    assert int(eng2.state.served) == 16     # legacy telemetry restored
    assert int(eng2.state.lat_count) == 0   # fresh latency counters
    np.testing.assert_array_equal(np.asarray(eng2.state.exit_counts),
                                  np.asarray(eng.state.exit_counts))


def test_planner_seeds_prior_from_engine_window(vit_engine_factory,
                                                eval_images):
    """An engine that already served (e.g. restored from a checkpoint)
    seeds the planner's cold-start depth prediction from its §II.C
    window — the exit-count prior from telemetry."""
    from repro.core import adaptive as AD
    from repro.serving import AdmissionPlanner
    eng = vit_engine_factory()
    fresh = AdmissionPlanner(eng)
    assert fresh._global_depth is None          # nothing served yet
    eng.infer(eval_images[:32], mode="masked", record=True)
    seeded = AdmissionPlanner(eng)
    np.testing.assert_allclose(
        seeded._global_depth,
        float(AD.window_exit_depth(eng.state.adaptive, eng.acfg)),
        rtol=1e-6)
    # and the prediction runs through the cumulative cost curve
    cost = seeded.predicted_cost(0.5, dclass=0)
    assert COSTS[0] <= cost <= COSTS[-1]


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------
def test_seeded_burst_is_deterministic(vit_engine_factory, eval_images):
    """A seeded bursty arrival pattern driven through a fake clock must
    reproduce decisions, flush reasons and telemetry exactly."""
    def run_once():
        eng = vit_engine_factory()
        clock = FakeClock()
        srv = AsyncDartServer(
            eng, SchedulerConfig(max_batch=8, flush_ms=10.0, margin_ms=2.0,
                                 pipeline_depth=1, edges=(0.35, 0.65)),
            clock=clock, start=False)
        rng = np.random.RandomState(7)
        futs = []
        for _ in range(6):                          # 6 bursts
            for _ in range(int(rng.randint(1, 5))):
                n = int(rng.randint(1, 5))
                a = int(rng.randint(0, len(eval_images) - n))
                futs.append(srv.submit(
                    eval_images[a:a + n],
                    deadline_ms=float(rng.randint(20, 80)),
                    priority=int(rng.randint(0, 3))))
            clock.advance(0.004)
            while srv.pump():
                pass
        clock.advance(1.0)
        srv.close()
        outs = [f.result(timeout=5) for f in futs]
        sig = {
            "exit_idx": np.concatenate([o["exit_idx"] for o in outs]),
            "pred": np.concatenate([o["pred"] for o in outs]),
            "lanes": [o["lane"] for o in outs],
            "flushes": {k: v for k, v in srv.counters.items()
                        if k.startswith("flush_")},
            "served": int(np.sum(np.asarray(srv.engine.state.served))),
        }
        return sig

    a, b = run_once(), run_once()
    np.testing.assert_array_equal(a["exit_idx"], b["exit_idx"])
    np.testing.assert_array_equal(a["pred"], b["pred"])
    assert a["lanes"] == b["lanes"]
    assert a["flushes"] == b["flushes"]
    assert a["served"] == b["served"]


# ---------------------------------------------------------------------------
# LM decode session
# ---------------------------------------------------------------------------
def test_lm_session_matches_direct_generate():
    from repro.engine import LMDecodeEngine
    from repro.models.transformer_lm import LMConfig
    from repro.runtime.trainer import Trainer, TrainConfig

    lc = LMConfig(name="lm-sess", n_layers=4, d_model=32, n_heads=2,
                  n_kv_heads=1, d_ff=64, vocab=32, exit_layers=(1,),
                  max_seq=32, remat=False)
    tr = Trainer(lc, TrainConfig(batch_size=8, steps=5, lr=3e-3),
                 DatasetConfig(name="tokens", n_train=128),
                 data_kind="tokens")
    tr.run()
    dart = DartParams(tau=jnp.asarray([0.3]), coef=jnp.ones(1),
                      beta_diff=0.15)
    prompts, _ = make_batch(DatasetConfig(name="tokens", n_train=128),
                            range(4), kind="tokens", seq_len=9,
                            vocab=lc.vocab)
    ref_eng = LMDecodeEngine(lc, tr.params, dart)
    ref_tok, ref_stg = ref_eng.generate(prompts, n_new=6)

    eng = LMDecodeEngine(lc, tr.params, dart)
    sess = eng.session(start=False, clock=FakeClock())
    futs = [sess.submit(prompts[i], n_new=6) for i in range(4)]
    sess.close()                            # flushes one consolidated call
    outs = [f.result(timeout=5) for f in futs]
    np.testing.assert_array_equal(
        np.concatenate([o["tokens"] for o in outs]), ref_tok)
    np.testing.assert_array_equal(
        np.concatenate([o["stages"] for o in outs]), ref_stg)
    # all four callers shared one bucketed decode loop
    assert sess.counters["flush_forced"] == 1
    assert sess.stats()["requests"]["requests"] == 4
