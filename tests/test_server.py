"""Serving engine: compacted execution == masked Alg. 1 reference,
adaptive updates, cost accounting."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.routing import DartParams
from repro.data.datasets import DatasetConfig, make_batch
from repro.models.cnn_zoo import AlexNetConfig
from repro.models.vit import ViTConfig, vit_init
from repro.parallel.sharding import unzip
from repro.runtime.server import DartServer, _next_bucket
from repro.runtime.trainer import Trainer, TrainConfig

import jax

DATA = DatasetConfig(name="synth-cifar", n_train=256, n_eval=128)


@pytest.fixture(scope="module")
def trained_cnn():
    mc = AlexNetConfig(img_res=32, n_classes=10,
                       channels=(16, 24, 32, 24, 24), fc_dims=(96, 48))
    tr = Trainer(mc, TrainConfig(batch_size=32, steps=15, lr=3e-3), DATA)
    tr.run()
    return mc, tr.params


def test_bucket_rounding():
    assert _next_bucket(1, (1, 2, 4, 8)) == 1
    assert _next_bucket(3, (1, 2, 4, 8)) == 4
    # n > max bucket used to clamp (negative pad silently corrupted
    # infer_batch); it must now raise — oversized batches are split.
    with pytest.raises(ValueError):
        _next_bucket(9, (1, 2, 4, 8))


def test_oversized_batch_is_split_not_corrupted(trained_cnn):
    """Batches larger than the biggest bucket are served in chunks and
    still match the masked reference exactly."""
    mc, params = trained_cnn
    dart = DartParams(tau=jnp.full((2,), 0.35), coef=jnp.ones(2),
                      beta_diff=0.3)
    srv = DartServer(mc, params, dart, cum_costs=[0.3, 0.7, 1.0],
                     adapt=False, buckets=(1, 2, 4, 8, 16))
    x, _ = make_batch(DATA, range(40), split="eval")    # 40 > 16
    out = srv.infer_batch(x)
    ref = srv.masked_reference(x)
    assert len(out["pred"]) == 40
    np.testing.assert_array_equal(out["exit_idx"],
                                  np.asarray(ref["exit_idx"]))
    np.testing.assert_array_equal(out["pred"], np.asarray(ref["pred"]))
    assert srv.stats.served == 40


@pytest.mark.parametrize("tau", [0.0, 0.35, 0.9])
def test_compacted_equals_masked(trained_cnn, tau):
    """The engine's stage-compacted decisions must be bit-identical to the
    masked-mode Algorithm 1 reference at any threshold."""
    mc, params = trained_cnn
    dart = DartParams(tau=jnp.full((2,), tau), coef=jnp.ones(2),
                      beta_diff=0.3)
    srv = DartServer(mc, params, dart, cum_costs=[0.3, 0.7, 1.0],
                     adapt=False)
    x, y = make_batch(DATA, range(48), split="eval")
    out = srv.infer_batch(x)
    ref = srv.masked_reference(x)
    np.testing.assert_array_equal(out["exit_idx"], np.asarray(ref["exit_idx"]))
    np.testing.assert_array_equal(out["pred"], np.asarray(ref["pred"]))
    np.testing.assert_allclose(out["conf"], np.asarray(ref["conf"]),
                               rtol=2e-5, atol=2e-5)


def test_zero_threshold_exits_everything_early(trained_cnn):
    mc, params = trained_cnn
    dart = DartParams(tau=jnp.zeros(2), coef=jnp.zeros(2), beta_diff=0.0)
    srv = DartServer(mc, params, dart, cum_costs=[0.3, 0.7, 1.0],
                     adapt=False)
    x, _ = make_batch(DATA, range(16), split="eval")
    out = srv.infer_batch(x)
    assert np.all(out["exit_idx"] == 0)
    assert out["macs"].mean() == pytest.approx(0.3)


def test_infinite_threshold_never_exits_early(trained_cnn):
    mc, params = trained_cnn
    dart = DartParams(tau=jnp.ones(2), coef=jnp.full((2,), 10.0),
                      beta_diff=1.0)
    srv = DartServer(mc, params, dart, cum_costs=[0.3, 0.7, 1.0],
                     adapt=False)
    x, _ = make_batch(DATA, range(16), split="eval")
    out = srv.infer_batch(x)
    assert np.all(out["exit_idx"] == 2)


def test_adaptive_state_progresses(trained_cnn):
    mc, params = trained_cnn
    dart = DartParams(tau=jnp.full((2,), 0.4), coef=jnp.ones(2))
    srv = DartServer(mc, params, dart, cum_costs=[0.3, 0.7, 1.0],
                     adapt=True, update_every=16)
    x, _ = make_batch(DATA, range(64), split="eval")
    for i in range(0, 64, 16):
        srv.infer_batch(x[i:i + 16])
    assert int(srv.astate["seen"]) == 64
    assert int(srv.astate["t"]) >= 3          # UCB updates happened
    assert srv.stats.served == 64


def test_exit_stats_accounting(trained_cnn):
    mc, params = trained_cnn
    dart = DartParams(tau=jnp.full((2,), 0.2), coef=jnp.ones(2),
                      beta_diff=0.1)
    srv = DartServer(mc, params, dart, cum_costs=[0.3, 0.7, 1.0],
                     adapt=False)
    x, _ = make_batch(DATA, range(32), split="eval")
    out = srv.infer_batch(x)
    assert srv.stats.exit_counts.sum() == 32
    want = np.array([0.3, 0.7, 1.0])[out["exit_idx"]]
    np.testing.assert_allclose(out["macs"], want)


def test_server_works_for_vit():
    vc = ViTConfig(name="vt", img_res=32, patch=8, n_layers=3, d_model=32,
                   n_heads=2, d_ff=64, n_classes=10, exit_layers=(0, 1))
    params, _ = unzip(vit_init(jax.random.key(0), vc))
    dart = DartParams(tau=jnp.full((2,), 0.2), coef=jnp.ones(2))
    srv = DartServer(vc, params, dart, cum_costs=[0.4, 0.7, 1.0],
                     adapt=False)
    x, _ = make_batch(DATA, range(8), split="eval")
    out = srv.infer_batch(x)
    ref = srv.masked_reference(x)
    np.testing.assert_array_equal(out["exit_idx"],
                                  np.asarray(ref["exit_idx"]))
    np.testing.assert_array_equal(out["pred"], np.asarray(ref["pred"]))
