"""Serving-engine compatibility: compacted execution == masked Alg. 1
reference, adaptive updates, cost accounting — on the ``repro.engine``
API.

(Formerly tests/test_server.py.  The legacy ``DartServer`` /
``LMDecodeServer`` shims this file once covered were removed in PR 4;
every path here runs on ``DartEngine`` directly.)
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.routing import DartParams
from repro.data.datasets import DatasetConfig, make_batch
from repro.engine import BatchTooLarge, DartEngine
from repro.models.cnn_zoo import AlexNetConfig
from repro.models.vit import ViTConfig, vit_init
from repro.parallel.sharding import unzip
from repro.runtime.trainer import Trainer, TrainConfig

import jax

DATA = DatasetConfig(name="synth-cifar", n_train=256, n_eval=128)


@pytest.fixture(scope="module")
def trained_cnn():
    mc = AlexNetConfig(img_res=32, n_classes=10,
                       channels=(16, 24, 32, 24, 24), fc_dims=(96, 48))
    tr = Trainer(mc, TrainConfig(batch_size=32, steps=15, lr=3e-3), DATA)
    tr.run()
    return mc, tr.params


def _engine(mc, params, dart, **kw):
    kw.setdefault("cum_costs", [0.3, 0.7, 1.0])
    kw.setdefault("adapt", False)
    return DartEngine.from_config(mc, params, dart=dart, **kw)


def test_bucket_rounding():
    from repro.engine import BatchCompactor
    c = BatchCompactor((1, 2, 4, 8))
    assert c.bucket_for(1) == 1
    assert c.bucket_for(3) == 4
    # n > max bucket used to clamp (negative pad silently corrupted
    # serving); it must now raise — oversized batches are split.
    with pytest.raises(BatchTooLarge):
        c.bucket_for(9)


def test_bucket_key_is_the_shared_cache_key(trained_cnn):
    """Eager and sharded engines must agree on what shares a compiled
    shape: ``engine.bucket_key`` = bucket rounded to a replica multiple."""
    mc, params = trained_cnn
    dart = DartParams(tau=jnp.full((2,), 0.35), coef=jnp.ones(2))
    eager = _engine(mc, params, dart)
    assert eager.replica_multiple == 1
    assert [eager.bucket_key(n) for n in (1, 3, 5, 9)] == [1, 4, 8, 16]
    from repro.launch.mesh import make_serving_mesh
    sharded = _engine(mc, params, dart, mesh=make_serving_mesh())
    assert sharded.replica_multiple == sharded.n_replicas
    for n in (1, 3, 5, 9):
        assert sharded.bucket_key(n) % sharded.n_replicas == 0
        assert sharded.bucket_key(n) >= eager.bucket_key(n)


def test_oversized_batch_is_split_not_corrupted(trained_cnn):
    """Batches larger than the biggest bucket are served in chunks and
    still match the masked reference exactly."""
    mc, params = trained_cnn
    dart = DartParams(tau=jnp.full((2,), 0.35), coef=jnp.ones(2),
                      beta_diff=0.3)
    eng = _engine(mc, params, dart, buckets=(1, 2, 4, 8, 16))
    x, _ = make_batch(DATA, range(40), split="eval")    # 40 > 16
    out = eng.infer(x, mode="compacted")
    ref = eng.infer(x, mode="masked")
    assert len(out["pred"]) == 40
    np.testing.assert_array_equal(out["exit_idx"],
                                  np.asarray(ref["exit_idx"]))
    np.testing.assert_array_equal(out["pred"], np.asarray(ref["pred"]))
    assert int(eng.state.served) == 40


@pytest.mark.parametrize("tau", [0.0, 0.35, 0.9])
def test_compacted_equals_masked(trained_cnn, tau):
    """The engine's stage-compacted decisions must be bit-identical to the
    masked-mode Algorithm 1 reference at any threshold."""
    mc, params = trained_cnn
    dart = DartParams(tau=jnp.full((2,), tau), coef=jnp.ones(2),
                      beta_diff=0.3)
    eng = _engine(mc, params, dart)
    x, y = make_batch(DATA, range(48), split="eval")
    out = eng.infer(x, mode="compacted")
    ref = eng.infer(x, mode="masked")
    np.testing.assert_array_equal(out["exit_idx"], np.asarray(ref["exit_idx"]))
    np.testing.assert_array_equal(out["pred"], np.asarray(ref["pred"]))
    np.testing.assert_allclose(out["conf"], np.asarray(ref["conf"]),
                               rtol=2e-5, atol=2e-5)


def test_precomputed_alpha_matches_internal_estimate(trained_cnn):
    """infer(alpha=...) with the admission-time Eq. 8 estimate must be
    indistinguishable from the engine estimating difficulty itself (the
    async scheduler depends on this)."""
    mc, params = trained_cnn
    dart = DartParams(tau=jnp.full((2,), 0.35), coef=jnp.ones(2),
                      beta_diff=0.3)
    eng = _engine(mc, params, dart)
    x, _ = make_batch(DATA, range(24), split="eval")
    alpha = np.asarray(eng._alpha(jnp.asarray(x)))
    for mode in ("masked", "compacted"):
        ref = eng.infer(x, mode=mode, record=False)
        out = eng.infer(x, mode=mode, record=False, alpha=alpha)
        np.testing.assert_array_equal(np.asarray(out["exit_idx"]),
                                      np.asarray(ref["exit_idx"]))
        np.testing.assert_array_equal(np.asarray(out["pred"]),
                                      np.asarray(ref["pred"]))


def test_masked_pad_to_bucket_is_transparent(trained_cnn):
    """infer(mode="masked", pad_to=bucket) must neither change outputs
    nor leak padded lanes into telemetry (the async scheduler pads every
    consolidated dispatch to its bucket)."""
    mc, params = trained_cnn
    dart = DartParams(tau=jnp.full((2,), 0.35), coef=jnp.ones(2),
                      beta_diff=0.3)
    eng = _engine(mc, params, dart, adapt=True, update_every=10 ** 9)
    x, _ = make_batch(DATA, range(11), split="eval")
    ref = eng.infer(x, mode="masked", record=False)
    out = eng.infer(x, mode="masked", record=True,
                    pad_to=eng.bucket_key(11))
    for k in ("exit_idx", "pred", "alpha"):
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(ref[k]))
    assert out["pred"].shape == (11,)
    assert out["conf_stack"].shape[1] == 11
    assert int(eng.state.served) == 11      # padding never recorded


def test_zero_threshold_exits_everything_early(trained_cnn):
    mc, params = trained_cnn
    dart = DartParams(tau=jnp.zeros(2), coef=jnp.zeros(2), beta_diff=0.0)
    eng = _engine(mc, params, dart)
    x, _ = make_batch(DATA, range(16), split="eval")
    out = eng.infer(x, mode="compacted")
    assert np.all(out["exit_idx"] == 0)
    assert out["macs"].mean() == pytest.approx(0.3)


def test_infinite_threshold_never_exits_early(trained_cnn):
    mc, params = trained_cnn
    dart = DartParams(tau=jnp.ones(2), coef=jnp.full((2,), 10.0),
                      beta_diff=1.0)
    eng = _engine(mc, params, dart)
    x, _ = make_batch(DATA, range(16), split="eval")
    out = eng.infer(x, mode="compacted")
    assert np.all(out["exit_idx"] == 2)


def test_adaptive_state_progresses(trained_cnn):
    mc, params = trained_cnn
    dart = DartParams(tau=jnp.full((2,), 0.4), coef=jnp.ones(2))
    eng = _engine(mc, params, dart, adapt=True, update_every=16)
    x, _ = make_batch(DATA, range(64), split="eval")
    for i in range(0, 64, 16):
        eng.infer(x[i:i + 16], mode="compacted")
    assert int(eng.state.adaptive["seen"]) == 64
    assert int(eng.state.adaptive["t"]) >= 3      # UCB updates happened
    assert int(eng.state.served) == 64


def test_exit_stats_accounting(trained_cnn):
    mc, params = trained_cnn
    dart = DartParams(tau=jnp.full((2,), 0.2), coef=jnp.ones(2),
                      beta_diff=0.1)
    eng = _engine(mc, params, dart)
    x, _ = make_batch(DATA, range(32), split="eval")
    out = eng.infer(x, mode="compacted")
    assert np.asarray(eng.state.exit_counts).sum() == 32
    want = np.array([0.3, 0.7, 1.0])[out["exit_idx"]]
    np.testing.assert_allclose(out["macs"], want)


def test_engine_works_for_vit():
    vc = ViTConfig(name="vt", img_res=32, patch=8, n_layers=3, d_model=32,
                   n_heads=2, d_ff=64, n_classes=10, exit_layers=(0, 1))
    params, _ = unzip(vit_init(jax.random.key(0), vc))
    dart = DartParams(tau=jnp.full((2,), 0.2), coef=jnp.ones(2))
    eng = DartEngine.from_config(vc, params, dart=dart,
                                 cum_costs=[0.4, 0.7, 1.0], adapt=False)
    x, _ = make_batch(DATA, range(8), split="eval")
    out = eng.infer(x, mode="compacted")
    ref = eng.infer(x, mode="masked")
    np.testing.assert_array_equal(out["exit_idx"],
                                  np.asarray(ref["exit_idx"]))
    np.testing.assert_array_equal(out["pred"], np.asarray(ref["pred"]))


def test_legacy_shims_are_gone():
    """PR 4 removed runtime.server / runtime.lm_server outright; the
    import paths must stay dead so nothing silently resurrects them."""
    with pytest.raises(ImportError):
        import repro.runtime.server          # noqa: F401
    with pytest.raises(ImportError):
        import repro.runtime.lm_server       # noqa: F401
