"""Sharding rules: logical-axis resolution, divisibility downgrades, and a
multi-device (8 fake CPU devices) end-to-end train-step in a subprocess."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel import sharding as SH


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_resolve_basic():
    mesh = FakeMesh({"data": 4, "model": 2})
    spec = SH.resolve_spec((8, 16), ("batch", "mlp"), SH.LM_RULES, mesh)
    assert spec == P("data", "model")


def test_resolve_downgrades_nondivisible():
    mesh = FakeMesh({"data": 4, "model": 16})
    dg = []
    spec = SH.resolve_spec((6, 40), ("batch", "experts"), SH.LM_RULES, mesh,
                           "x", dg)
    assert spec == P()          # 6 % 4 != 0, 40 % 16 != 0 -> replicate
    assert len(dg) == 2


def test_resolve_tuple_prefix():
    """batch=4 on (pod=2, data=16) resolves to the divisible prefix (pod,)."""
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = SH.resolve_spec((4, 8), ("batch", None), SH.LM_RULES, mesh)
    assert spec == P("pod")


def test_resolve_no_axis_reuse():
    """Two dims must never claim the same mesh axis."""
    mesh = FakeMesh({"data": 2, "model": 2})
    spec = SH.resolve_spec((4, 4), ("mlp", "vocab"), SH.LM_RULES, mesh)
    entries = [e for e in spec if e is not None]
    assert len(entries) == len(set(entries)) <= 1


def test_param_tagging_through_unzip():
    from repro.models.layers import linear_init
    tree = linear_init(jax.random.key(0), 8, 16, jnp.float32)
    values, axes = SH.unzip(tree)
    assert axes["w"] == ("embed", "mlp")
    assert values["w"].shape == (8, 16)


def test_abstract_init_no_allocation():
    from repro.models.vit import ViTConfig, vit_init
    cfg = ViTConfig(name="t", img_res=224, patch=14, n_layers=32,
                    d_model=1280, n_heads=16, d_ff=5120,
                    param_dtype=jnp.bfloat16)  # ViT-H: 632M params
    tree = SH.abstract_init(vit_init, jax.random.key(0), cfg)
    values, _ = SH.unzip(tree)
    n = SH.param_count(values)
    assert 6.0e8 < n < 7.5e8
    assert all(isinstance(v, jax.ShapeDtypeStruct)
               for v in jax.tree.leaves(values))


MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "%s")
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import registry
    from repro.launch import steps as S
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(4, 2)
    arch = sys.argv[1]
    shape_name = sys.argv[2]
    sp0 = next(s for s in registry.shapes(arch) if s.name == shape_name)
    import dataclasses
    sp = dataclasses.replace(sp0, batch=8,
                             seq_len=32 if sp0.seq_len else None,
                             img_res=32 if sp0.img_res else None)
    b = S.build(arch, sp, mesh, reduced=True)
    # CONCRETE execution on 8 fake devices: materialize zeros and run.
    def zeros_like_sds(x, s):
        return jax.device_put(jnp.zeros(x.shape, x.dtype), s)
    args = jax.tree.map(zeros_like_sds, b.inputs, b.in_shardings,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    out = jax.jit(b.step, in_shardings=b.in_shardings)(*args)
    leaves = [np.asarray(x) for x in jax.tree.leaves(out)]
    assert all(np.all(np.isfinite(l)) for l in leaves if l.dtype.kind == "f")
    print("MULTIDEV_OK", arch, shape_name)
""" % os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.mark.parametrize("arch,shape", [
    ("tinyllama-1.1b", "train_4k"),
    ("granite-moe-3b-a800m", "train_4k"),
    ("deepseek-v3-671b", "decode_32k"),
    ("vit-s16", "serve_b128"),
    ("dit-s2", "gen_fast"),
])
def test_multidevice_step_executes(arch, shape):
    """Reduced configs run CONCRETELY under a 4x2 fake-device mesh — proves
    the sharded step functions are not just compilable but executable."""
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT, arch, shape],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MULTIDEV_OK" in r.stdout
