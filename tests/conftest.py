import os
import sys

# Tests must see exactly ONE device (the dry-run sets its own flags in a
# subprocess); never set xla_force_host_platform_device_count here.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

# Two-tier property budgets (ISSUE 9): PR CI runs the default example
# counts; the nightly workflow exports REPRO_HYPOTHESIS_PROFILE=nightly
# for a 10x budget.  The profile is registered here (conftest imports
# before any test module) so unpinned @given tests pick it up; tests
# that pin max_examples route the pin through tests/_prop.examples(),
# which reads the same variable — hypothesis gives explicit per-test
# settings precedence over profiles, so the decorator is where the
# raise must land (and the _prop scale also reaches the deterministic
# fallback shim that way).  Guarded: the extras may not be installed.
_PROFILE = os.environ.get("REPRO_HYPOTHESIS_PROFILE")
if _PROFILE:
    try:
        from hypothesis import settings as _h_settings
        _h_settings.register_profile("nightly", max_examples=200,
                                     deadline=None)
        _h_settings.load_profile(_PROFILE)
    except ImportError:
        pass


@pytest.fixture
def rng():
    return np.random.RandomState(0)
