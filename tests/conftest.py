import os
import sys

# Tests must see exactly ONE device (the dry-run sets its own flags in a
# subprocess); never set xla_force_host_platform_device_count here.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)
