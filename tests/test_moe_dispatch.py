"""MoE dispatch equivalence on an 8-fake-device mesh (subprocess)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, %r)
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.moe import MoEConfig, moe_init, moe_apply
    from repro.parallel.sharding import unzip

    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(2, 4)
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff=16, capacity_factor=8.0)
    p, _ = unzip(moe_init(jax.random.key(0), 8, cfg, jnp.float32))
    x = jax.random.normal(jax.random.key(1), (32, 8))
    ref, aux_ref = moe_apply(p, x, cfg)
    for dispatch in ("ar", "a2a"):
        out, aux = jax.jit(lambda p, x, d=dispatch: moe_apply(
            p, x, cfg, mesh=mesh, ep_mode="ep", dispatch=d))(p, x)
        np.testing.assert_allclose(out, ref, atol=2e-5, err_msg=dispatch)
        # the aux loss is a per-shard estimator (standard practice);
        # it must be CLOSE to, not identical with, the global value
        np.testing.assert_allclose(float(aux), float(aux_ref), atol=2e-3,
                                   err_msg=dispatch + "-aux")

    # routed-compute path gradients must match exactly between dispatches
    def loss(p, dispatch):
        out, aux = moe_apply(p, x, cfg, mesh=mesh, ep_mode="ep",
                             dispatch=dispatch)
        return jnp.sum(out ** 2)
    g_ar = jax.jit(jax.grad(lambda p: loss(p, "ar")))(p)
    g_a2a = jax.jit(jax.grad(lambda p: loss(p, "a2a")))(p)
    for a, b in zip(jax.tree.leaves(g_ar), jax.tree.leaves(g_a2a)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-5, rtol=3e-4)
    # tp mode (granite layout: E not divisible by mesh) also matches
    p_tp, _ = unzip(moe_init(jax.random.key(0), 8, cfg, jnp.float32,
                             ep_mode="tp"))
    out_tp, _ = jax.jit(lambda p, x: moe_apply(
        p, x, cfg, mesh=mesh, ep_mode="tp"))(p_tp, x)
    ref_tp, _ = moe_apply(p_tp, x, cfg, ep_mode="tp")
    np.testing.assert_allclose(out_tp, ref_tp, atol=2e-5)
    print("MOE_DISPATCH_OK")
""" % os.path.join(os.path.dirname(__file__), "..", "src"))


def test_moe_ar_a2a_tp_equivalence():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MOE_DISPATCH_OK" in r.stdout
