"""Optimizers (no optax): SGD(+momentum), AdamW.

Functional API:
    opt = adamw(schedule, b1=0.9, ...)
    state = opt.init(params)
    params, state = opt.update(grads, state, params)

Optimizer state mirrors the parameter pytree, so any parameter sharding
(including FSDP) applies verbatim to the moments — ZeRO-style sharded
optimizer state falls out of the sharding rules for free.

``trainable_mask`` filters non-trainable leaves (BatchNorm running stats,
tagged with the "_stats" logical axis) — masked leaves get zero updates.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.batchnorm import STATS_AXIS


@dataclasses.dataclass
class OptimizerState:
    step: Any
    inner: Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


def trainable_mask(axes_tree):
    """True for trainable leaves; False for running-stats leaves."""
    return jax.tree.map(
        lambda axes: not (isinstance(axes, tuple) and STATS_AXIS in axes),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def apply_mask(updates, mask):
    if mask is None:
        return updates
    return jax.tree.map(lambda u, m: u if m else jnp.zeros_like(u),
                        updates, mask)


def _to_lr(lr, step):
    return lr(step) if callable(lr) else lr


def sgd(lr, momentum: float = 0.9, *, nesterov=False, weight_decay=0.0,
        max_grad_norm: float | None = None, mask=None) -> Optimizer:
    def init(params):
        return OptimizerState(
            step=jnp.zeros((), jnp.int32),
            inner={"mom": jax.tree.map(jnp.zeros_like, params)})

    def update(grads, state, params):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        lr_t = _to_lr(lr, state.step).astype(jnp.float32) \
            if hasattr(_to_lr(lr, state.step), "astype") else _to_lr(lr, state.step)
        mom = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype),
                           state.inner["mom"], grads)
        upd = jax.tree.map(lambda m, g: momentum * m + g if nesterov else m,
                           mom, grads)
        if weight_decay:
            upd = jax.tree.map(lambda u, p: u + weight_decay * p, upd, params)
        upd = apply_mask(upd, mask)
        new = jax.tree.map(lambda p, u: (p.astype(jnp.float32)
                                         - lr_t * u.astype(jnp.float32)
                                         ).astype(p.dtype), params, upd)
        return new, OptimizerState(state.step + 1, {"mom": mom})

    return Optimizer(init, update)


def adamw(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01,
          max_grad_norm: float | None = 1.0, mask=None,
          moment_dtype=jnp.float32) -> Optimizer:
    """AdamW with decoupled weight decay and optional bf16 moments
    (`moment_dtype=jnp.bfloat16` — the DeepSeek-V3 memory trick; see
    DESIGN.md §4.4)."""
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return OptimizerState(
            step=jnp.zeros((), jnp.int32),
            inner={"m": jax.tree.map(zeros, params),
                   "v": jax.tree.map(zeros, params)})

    def update(grads, state, params):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        lr_t = _to_lr(lr, step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        m = jax.tree.map(lambda m_, g: (b1 * m_.astype(jnp.float32)
                                        + (1 - b1) * g.astype(jnp.float32)
                                        ).astype(moment_dtype),
                         state.inner["m"], grads)
        v = jax.tree.map(lambda v_, g: (b2 * v_.astype(jnp.float32)
                                        + (1 - b2) * jnp.square(
                                            g.astype(jnp.float32))
                                        ).astype(moment_dtype),
                         state.inner["v"], grads)

        def upd(m_, v_, p):
            mh = m_.astype(jnp.float32) / bc1
            vh = v_.astype(jnp.float32) / bc2
            u = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return u

        updates = jax.tree.map(upd, m, v, params)
        updates = apply_mask(updates, mask)
        new = jax.tree.map(lambda p, u: (p.astype(jnp.float32) - lr_t * u
                                         ).astype(p.dtype), params, updates)
        return new, OptimizerState(step, {"m": m, "v": v})

    return Optimizer(init, update)


jax.tree_util.register_pytree_node(
    OptimizerState,
    lambda s: ((s.step, s.inner), None),
    lambda _, c: OptimizerState(*c))
