"""Gradient accumulation (microbatching) helper.

Python-unrolled over microbatches so HLO cost analysis stays exact (a
scan would undercount — DESIGN.md §4.2); the fori-loop variant is
available for long accumulation horizons.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


class GradAccumulator:
    """accumulate(loss_fn, params, batches) -> (mean_loss, mean_grads)."""

    def __init__(self, n_micro: int):
        self.n_micro = n_micro

    def split(self, batch):
        """Split a global batch pytree into n_micro microbatches (axis 0)."""
        def sp(x):
            b = x.shape[0]
            assert b % self.n_micro == 0, (b, self.n_micro)
            return x.reshape(self.n_micro, b // self.n_micro, *x.shape[1:])
        return jax.tree.map(sp, batch)

    def accumulate(self, loss_fn, params, batch, *args):
        micro = self.split(batch)
        grads = None
        total = jnp.zeros((), jnp.float32)
        aux_last = None
        for i in range(self.n_micro):
            mb = jax.tree.map(lambda x: x[i], micro)
            (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb, *args)
            total = total + loss
            aux_last = aux
            grads = g if grads is None else jax.tree.map(jnp.add, grads, g)
        scale = 1.0 / self.n_micro
        grads = jax.tree.map(lambda g: g * scale, grads)
        return total * scale, grads, aux_last
