from repro.optim.optimizers import (adamw, sgd, OptimizerState, Optimizer,
                                    clip_by_global_norm, global_norm,
                                    trainable_mask, apply_mask)
from repro.optim.schedules import warmup_cosine, constant, linear_decay
from repro.optim.accumulate import GradAccumulator

__all__ = ["adamw", "sgd", "OptimizerState", "Optimizer",
           "clip_by_global_norm", "global_norm", "trainable_mask",
           "apply_mask", "warmup_cosine", "constant", "linear_decay",
           "GradAccumulator"]
