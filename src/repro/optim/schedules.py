"""Learning-rate schedules (callables: step -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_decay(peak, total_steps, end_frac=0.1):
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        frac = jnp.clip(s / max(total_steps, 1), 0.0, 1.0)
        return peak * (1.0 - (1.0 - end_frac) * frac)
    return f


def warmup_cosine(peak, warmup_steps, total_steps, end_frac=0.0):
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak * s / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = end_frac * peak + (1 - end_frac) * peak \
            * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup_steps, warm, cos)
    return f
