"""Pallas paged KV gather (continuous-batching decode path).

The page table is a *scalar-prefetch* operand
(``pltpu.PrefetchScalarGridSpec(num_scalar_prefetch=1)``): grid step
``(i, j)`` copies page ``table[i, j]`` of the store into row block
``(i, j)`` of the dense per-slot view, so the data movement IS the
BlockSpec index_map — the kernel body is a straight VMEM copy and no
(S*P,)-sized gather indices ever materialize in HBM.

The store may be sharded over pages under shard_map; callers then pass
a table of *local* page ids (the continuous decoder's allocator keeps
slot s's pages inside slot s's replica range, so ``table % local_N``
is exact).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:                                      # pltpu is absent on some builds
    from jax.experimental.pallas import tpu as pltpu
except ImportError:                       # pragma: no cover
    pltpu = None


def _kernel(tab_ref, pages_ref, out_ref):
    out_ref[...] = pages_ref[...]


def paged_gather_pallas(pages, page_table, *, interpret=None):
    """pages (N, psz, ...), page_table (S, P) int32 -> (S, P*psz, ...)."""
    from repro.kernels.dispatch import resolve_interpret
    if pltpu is None:                     # pragma: no cover
        raise NotImplementedError("pallas TPU grid specs unavailable")
    s, p = page_table.shape
    psz = pages.shape[1]
    rest = pages.shape[2:]
    zeros = (0,) * len(rest)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(s, p),
        in_specs=[pl.BlockSpec(
            (1, psz) + rest,
            lambda i, j, tab: (tab[i, j], 0) + zeros)],
        out_specs=pl.BlockSpec(
            (1, psz) + rest,
            lambda i, j, tab: (i, j) + zeros),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, p * psz) + rest, pages.dtype),
        interpret=resolve_interpret(interpret),
    )(page_table.astype(jnp.int32), pages)
