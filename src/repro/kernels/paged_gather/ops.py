"""Public wrapper for the paged KV gather.

Backend selection, the VMEM page-block budget check and shard_map
wrapping live in ``repro.kernels.dispatch``; this module keeps the
package's ``ops`` import path consistent with the other kernels.
"""
from __future__ import annotations

from repro.kernels import dispatch


def paged_gather(pages, page_table, *, mesh=None, axis="data",
                 backend=None):
    """Gather a slot's KV pages into the dense (S, P*psz, ...) view.
    See ``dispatch.paged_gather``."""
    return dispatch.paged_gather(pages, page_table, mesh=mesh, axis=axis,
                                 backend=backend)
