"""Pure-jnp oracle for the paged KV gather.

This IS the ``"xla"`` dispatch backend on the continuous-batching decode
hot path, so it must be BIT-IDENTICAL to reading a contiguous cache: a
page gather only *moves* rows, so the reference is a plain ``jnp.take``
over the page axis followed by a reshape — no arithmetic touches the
values.
"""
from __future__ import annotations

import jax.numpy as jnp


def ref_paged_gather(pages, page_table):
    """pages (N, psz, ...), page_table (S, P) int32 of page ids.

    Returns the dense per-slot view (S, P*psz, ...): slot i's pages
    concatenated in table order.  Table entries must be valid page ids
    (the allocator backfills unused entries with page 0; positions past
    a slot's length are masked by the caller's ``kv_len``).
    """
    s, p = page_table.shape
    psz = pages.shape[1]
    flat = jnp.take(pages, page_table.reshape(-1), axis=0, mode="clip")
    return flat.reshape(s, p * psz, *pages.shape[2:])
