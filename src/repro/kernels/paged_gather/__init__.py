# Paged KV-cache gather: page_table-indexed block gather that turns the
# continuous-batching page store into the dense (S, P*psz, ...) view the
# decode attention math consumes.
