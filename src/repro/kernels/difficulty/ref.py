"""Pure-jnp oracle for the fused difficulty kernel.

This simply re-exports the reference implementation from
``repro.core.difficulty`` (the kernel must match the paper's Eqs. 1–8
exactly as implemented there)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.difficulty import (DifficultyConfig, edge_density,
                                   pixel_variance, gradient_complexity,
                                   fuse)


def ref_components(images, *, tau_edge=0.1, var_scale=0.05, grad_scale=0.2,
                   w1=0.4, w2=0.3, w3=0.3):
    """(B, H, W, C) -> (B, 4) matching difficulty_pallas output layout."""
    cfg = DifficultyConfig(w_edge=w1, w_variance=w2, w_gradient=w3,
                           tau_edge=tau_edge, var_scale=var_scale,
                           grad_scale=grad_scale)
    e = edge_density(images, tau_edge)
    v = pixel_variance(images, var_scale)
    g = gradient_complexity(images, grad_scale)
    a = fuse(e, v, g, cfg)
    return jnp.stack([e, v, g, a], axis=1)
