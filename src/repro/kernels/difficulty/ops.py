"""Jitted public wrapper for the fused difficulty kernel.

Dispatch policy: images whose VMEM footprint exceeds the budget fall back
to the pure-jnp reference (XLA will tile those itself); everything else
takes the single-pass Pallas kernel.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.difficulty import DifficultyConfig, DEFAULT
from repro.kernels.difficulty.difficulty_kernel import difficulty_pallas
from repro.kernels.difficulty import ref

VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def _fits_vmem(shape) -> bool:
    _, h, w, c = shape
    # image + gray + 2 stencil temporaries, fp32
    return (h * w * (c + 3) * 4) <= VMEM_BUDGET_BYTES


@partial(jax.jit, static_argnames=("cfg", "interpret"))
def components(images, cfg: DifficultyConfig = DEFAULT, interpret=True):
    """(B, H, W, C) -> (B, 4): α_edge, α_var, α_grad, α."""
    kw = dict(tau_edge=cfg.tau_edge, var_scale=cfg.var_scale,
              grad_scale=cfg.grad_scale, w1=cfg.w_edge, w2=cfg.w_variance,
              w3=cfg.w_gradient)
    if _fits_vmem(images.shape):
        return difficulty_pallas(images, interpret=interpret, **kw)
    return ref.ref_components(images, **kw)


def image_difficulty(images, cfg: DifficultyConfig = DEFAULT,
                     interpret=True):
    """Fused α only — drop-in for core.difficulty.image_difficulty."""
    return components(images, cfg, interpret)[:, 3]
