"""Public wrappers for the fused difficulty kernel.

Backend selection (pallas / pallas-interpret / xla), the VMEM-budget
fallback (images whose working set exceeds the budget take the jnp
reference — XLA tiles those itself) and shard_map wrapping live in
``repro.kernels.dispatch``; these wrappers keep the historical import
path alive.  Interpret mode is NEVER a silent default here — it runs
only when explicitly forced.
"""
from __future__ import annotations

from repro.core.difficulty import DifficultyConfig, DEFAULT
from repro.kernels import dispatch


def components(images, cfg: DifficultyConfig = DEFAULT, *, mesh=None,
               axis="data", backend=None):
    """(B, H, W, C) -> (B, 4): α_edge, α_var, α_grad, α."""
    return dispatch.difficulty_components(images, cfg, mesh=mesh,
                                          axis=axis, backend=backend)


def image_difficulty(images, cfg: DifficultyConfig = DEFAULT, *, mesh=None,
                     axis="data", backend=None):
    """Fused α only — drop-in for core.difficulty.image_difficulty."""
    return dispatch.image_difficulty(images, cfg, mesh=mesh, axis=axis,
                                     backend=backend)
