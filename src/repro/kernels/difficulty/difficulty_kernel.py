"""Fused DART difficulty-estimator Pallas kernel (paper §II.A, Eqs. 1–8).

One VMEM pass per image computes ALL difficulty statistics:
grayscale → Sobel Gx/Gy → edge count, |Laplacian| sum, per-channel
mean/variance, and the fused α — the image is read from HBM exactly once
(the pure-jnp reference reads it five times: gray ×2, variance, and two
convolutions, each materializing HBM-sized intermediates).

TPU mapping: grid over the batch; each step holds one (H, W, C) image in
VMEM (224²·3·4B = 602 KB; the 1024² generation shapes use the row-strip
variant guard in ops.py).  All reductions run on the VPU; there is no MXU
work — this kernel is bandwidth-bound by design, which is exactly why
fusing the five passes into one is the win (≈5× HBM traffic reduction;
see EXPERIMENTS.md §Repro-Overhead).

Validated in interpret mode against ``ref.ref_components`` over a
shape/dtype sweep (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(img_ref, out_ref, *, tau_edge, var_scale, grad_scale, w1, w2, w3):
    img = img_ref[0].astype(jnp.float32)                 # (H, W, C)
    h, w, c = img.shape
    if c == 3:
        gray = (0.299 * img[:, :, 0] + 0.587 * img[:, :, 1]
                + 0.114 * img[:, :, 2])
    else:
        gray = jnp.mean(img, axis=-1)

    # ---- Eq. 5–6: per-channel spatial variance, averaged over channels
    mu = jnp.mean(img, axis=(0, 1), keepdims=True)       # (1, 1, C)
    var = jnp.mean(jnp.square(img - mu))                 # 1/(CHW) Σ (·)²
    a_var = 1.0 - jnp.exp(-var / var_scale)

    # ---- shifted views for the two 3x3 stencils (valid region)
    tl = gray[0:h - 2, 0:w - 2]
    tc = gray[0:h - 2, 1:w - 1]
    tr = gray[0:h - 2, 2:w]
    ml = gray[1:h - 1, 0:w - 2]
    mc = gray[1:h - 1, 1:w - 1]
    mr = gray[1:h - 1, 2:w]
    bl = gray[2:h, 0:w - 2]
    bc = gray[2:h, 1:w - 1]
    br = gray[2:h, 2:w]

    # ---- Eqs. 1–4: Sobel magnitude > τ_edge
    gx = (tr + 2.0 * mr + br) - (tl + 2.0 * ml + bl)
    gy = (bl + 2.0 * bc + br) - (tl + 2.0 * tc + tr)
    mag = jnp.sqrt(gx * gx + gy * gy)
    a_edge = jnp.mean((mag > tau_edge).astype(jnp.float32))

    # ---- Eq. 7: mean |Laplacian|
    lap = tc + ml + mr + bc - 4.0 * mc
    a_grad = 1.0 - jnp.exp(-jnp.mean(jnp.abs(lap)) / grad_scale)

    # ---- Eq. 8 fusion
    alpha = jnp.clip(w1 * a_edge + w2 * a_var + w3 * a_grad, 0.0, 1.0)
    out_ref[0, 0] = a_edge
    out_ref[0, 1] = a_var
    out_ref[0, 2] = a_grad
    out_ref[0, 3] = alpha


def difficulty_pallas(images, *, tau_edge=0.1, var_scale=0.05,
                      grad_scale=0.2, w1=0.4, w2=0.3, w3=0.3,
                      interpret=None):
    """images: (B, H, W, C) → (B, 4) = (α_edge, α_var, α_grad, α).

    ``interpret=None`` auto-resolves to interpret mode off-TPU (the raw
    kernel stays runnable in tests on this CPU container) and to the
    compiled Mosaic kernel on TPU; production traffic goes through
    ``kernels.dispatch``, which never auto-selects the interpreter.
    """
    from repro.kernels.dispatch import resolve_interpret
    b, h, w, c = images.shape
    kernel = functools.partial(_kernel, tau_edge=tau_edge,
                               var_scale=var_scale, grad_scale=grad_scale,
                               w1=w1, w2=w2, w3=w3)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, 4), jnp.float32),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, 4), lambda i: (i, 0)),
        interpret=resolve_interpret(interpret),
    )(images)
