"""ONE backend-selection layer for every fused kernel on the serving path.

Before this module each call site carried its own ``use_kernel`` flag, a
hardcoded ``interpret=True`` default and a private ``VMEM_BUDGET_BYTES``
check; the compiled serving engines simply refused to call the kernels
("pallas_call does not partition under GSPMD").  Dispatch centralizes
all of that:

* **Backend selection at trace time.**  ``select_backend(kernel,
  vmem_bytes=...)`` picks one of

  - ``"pallas"``            — the compiled Mosaic kernel (TPU),
  - ``"pallas-interpret"``  — the kernel body on the host interpreter
    (tests / debugging; NEVER auto-selected for production traffic),
  - ``"xla"``               — the pure-jnp reference chain (CPU/GPU, and
    the fallback whenever a block would not fit the VMEM budget).

  Auto policy: ``pallas`` on TPU, ``xla`` everywhere else.  Force a
  backend globally with ``REPRO_KERNEL_BACKEND=<name>``, per scope with
  ``with dispatch.force_backend("pallas-interpret"): ...``, or per call
  with ``backend=``.  Shapes are static under ``jax.jit``, so selection
  happens exactly once per compiled program — the hot path never
  branches at run time.

* **VMEM-budget fallback.**  Each kernel declares its per-grid-step VMEM
  footprint; a pallas backend whose footprint exceeds
  ``VMEM_BUDGET_BYTES`` silently degrades to ``"xla"`` (XLA tiles those
  shapes itself).  This is the one place that check lives.

* **shard_map wrapping.**  ``pallas_call`` does not partition under
  GSPMD, which is why the jit-end-to-end engines historically computed
  the gate as unfused XLA ops.  Every public op here takes ``mesh=`` /
  ``axis=``: when a pallas backend is selected inside a sharded step,
  the call is wrapped in ``shard_map`` over the (batch-sharded) axis so
  each replica runs the kernel on its local rows — zero cross-replica
  traffic, one launch per (stage, replica).  The ``xla`` backend needs
  no wrapping (GSPMD partitions the reference chain).

* **Autotuned block sizes.**  ``_AUTOTUNE`` is a small shape-keyed
  table: rows-per-grid-step for the exit gate (amortizes grid overhead
  on small vocabularies) and the vocab block for the fused exit head
  (bounded by the VMEM budget, always a divisor of V).

Public fused ops (each returns exactly what its ``ref`` computes, and
the ``xla`` backend IS the ref — bit-identical to the eager oracle):

    exit_gate(logits, thresholds)           (conf, entropy, pred, fire)
    softmax_confidence(logits)              (conf, pred) over (..., V)
    difficulty_components(images, cfg)      (B, 4) Eq. 1-8 statistics
    image_difficulty(images, cfg)           (B,) fused Eq. 8 alpha
    exit_head_gate(h, scale, table, thr)    (conf, pred, fire) --
        rmsnorm -> unembed matmul -> softmax conf -> Eq. 19 gate,
        without materializing the (B, V) logits in HBM.
"""
from __future__ import annotations

import contextlib
import functools
import os
import threading

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

BACKENDS = ("pallas", "pallas-interpret", "xla")

#: one VMEM budget for every kernel (per grid step, bytes)
VMEM_BUDGET_BYTES = 12 * 1024 * 1024

_FORCED = threading.local()


def _env_backend() -> str | None:
    b = os.environ.get("REPRO_KERNEL_BACKEND", "").strip().lower()
    if b in ("", "auto"):
        return None
    if b not in BACKENDS:
        raise ValueError(
            f"REPRO_KERNEL_BACKEND={b!r} not in {BACKENDS + ('auto',)}")
    return b


def forced_backend() -> str | None:
    """The currently forced backend (scope override > env var), or None
    for auto selection."""
    return getattr(_FORCED, "backend", None) or _env_backend()


@contextlib.contextmanager
def force_backend(backend: str | None):
    """Force every dispatch in this scope onto ``backend`` (one of
    ``BACKENDS``, or None to restore auto).  The VMEM-budget fallback
    still applies — an over-budget shape degrades to ``"xla"`` even
    under force, which is what makes the fallback boundary testable."""
    if backend is not None and backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; known: {BACKENDS}")
    prev = getattr(_FORCED, "backend", None)
    _FORCED.backend = backend
    try:
        yield
    finally:
        _FORCED.backend = prev


def select_backend(kernel: str, *, vmem_bytes: int = 0,
                   backend: str | None = None,
                   platform: str | None = None) -> str:
    """Resolve the backend for one ``kernel`` call at trace time.

    ``vmem_bytes``: the kernel's per-grid-step VMEM footprint for the
    shapes about to run.  ``backend``: per-call override (else the
    forced scope/env backend, else auto by platform)."""
    b = backend or forced_backend()
    if b is None:
        platform = platform or jax.default_backend()
        b = "pallas" if platform == "tpu" else "xla"
    if b != "xla" and vmem_bytes > VMEM_BUDGET_BYTES:
        return "xla"                      # XLA tiles over-budget shapes
    return b


def _interpret(backend: str) -> bool:
    return backend == "pallas-interpret"


def resolve_interpret(interpret) -> bool:
    """Raw-kernel default: ``None`` means interpret mode everywhere but
    TPU, so a directly-called kernel stays runnable in tests on CPU.
    Dispatch itself always passes an explicit value."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


# ---------------------------------------------------------------------------
# shape-keyed autotune table (block sizes per kernel)
# ---------------------------------------------------------------------------

#: (kernel, shape class) -> block parameters.  Extend with measured
#: entries when tuning on real hardware; unlisted shapes take the
#: class defaults below.
_AUTOTUNE: dict[tuple[str, str], dict] = {
    # small vocabularies: 8 rows per grid step amortize launch overhead
    ("exit_gate", "v<=2048"): {"block_b": 8},
    # LM vocabularies: one VMEM-resident row per step
    ("exit_gate", "v>2048"): {"block_b": 1},
    # one image per grid step (the image IS the block)
    ("difficulty", "default"): {},
    # fused exit head: vocab block target (shrunk to the VMEM budget
    # and to a divisor of V by exit_head_block_v)
    ("exit_head", "default"): {"block_v": 2048},
    # paged KV gather: one page per grid step (the page IS the block)
    ("paged_gather", "default"): {},
}


def gate_block_b(b: int, v: int) -> int:
    """Rows per grid step for the exit gate: the autotune entry for this
    vocab class, shrunk to a divisor of the (power-of-two bucketed)
    batch."""
    key = ("exit_gate", "v<=2048" if v <= 2048 else "v>2048")
    block = _AUTOTUNE[key]["block_b"]
    block = max(1, min(block, b))
    while b % block:
        block -= 1
    return block


def exit_head_block_v(v: int, d: int,
                      budget: int = VMEM_BUDGET_BYTES) -> int:
    """Vocab block for the fused exit head: the largest divisor of ``v``
    that is <= the autotune target AND fits the VMEM budget."""
    target = _AUTOTUNE[("exit_head", "default")]["block_v"]
    cap = max(1, min(v, target, budget // max(_head_step_bytes(1, d), 1)))
    while cap > 1 and (v % cap or _head_step_bytes(cap, d) > budget):
        cap -= 1
    return cap


# ---------------------------------------------------------------------------
# per-grid-step VMEM footprints (fp32 working set, temporaries included)
# ---------------------------------------------------------------------------

def _gate_step_bytes(block_b: int, v: int) -> int:
    return block_b * v * 4 * 2            # logits block + exp temporary


def _difficulty_step_bytes(h: int, w: int, c: int) -> int:
    return h * w * (c + 3) * 4            # image + gray + 2 stencil temps


def _head_step_bytes(block_v: int, d: int) -> int:
    return (block_v * d + 3 * d + 2 * block_v) * 4   # table block + row


def _paged_step_bytes(psz: int, trailing: int) -> int:
    return psz * trailing * 4 * 2         # one page in + one block out


# ---------------------------------------------------------------------------
# shard_map wrapping (pallas backends only; xla partitions under GSPMD)
# ---------------------------------------------------------------------------

def _maybe_shard_map(fn, mesh, axis, in_specs, out_specs):
    if mesh is None:
        return fn
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_vma=False)


def _axis_size(mesh, axis) -> int:
    return 1 if mesh is None else int(mesh.shape[axis])


# ---------------------------------------------------------------------------
# fused exit gate (Alg. 1 lines 5-9): conf / entropy / pred / fire
# ---------------------------------------------------------------------------
#
# Each public op resolves its backend OUTSIDE the jitted implementation
# and passes it as a static argument: `force_backend(...)` is trace-time
# Python state, so baking it into the jit cache key is what keeps a
# forced re-dispatch from silently reusing a compilation made under a
# different backend.

#: (kernel, chosen-backend) -> times that decision was taken.  Trace-time
#: bookkeeping like ``trace_counts`` — `_resolve` runs at the Python call
#: level (outside jit), so counting here adds nothing to compiled steps.
#: An unexpected "xla" fallback count for a pallas-preferred kernel is
#: the alertable signal the obs registry exports.
DISPATCH_COUNTS: dict = {}


def dispatch_counts() -> dict:
    """Snapshot of backend-resolution decisions since the last reset."""
    return dict(DISPATCH_COUNTS)


def reset_dispatch_counts() -> None:
    DISPATCH_COUNTS.clear()


def _resolve(kernel: str, vmem_bytes: int, backend, mesh, axis,
             sharded_rows: int) -> str:
    chosen = select_backend(kernel, backend=backend,
                            vmem_bytes=vmem_bytes)
    # shard_map needs the sharded dim divisible by the axis; odd row
    # counts (e.g. per-request admission batches) take the xla ref,
    # which partitions under plain GSPMD.
    if chosen != "xla" and mesh is not None \
            and sharded_rows % _axis_size(mesh, axis):
        chosen = "xla"
    key = (kernel, chosen)
    DISPATCH_COUNTS[key] = DISPATCH_COUNTS.get(key, 0) + 1
    return chosen


@functools.partial(jax.jit,
                   static_argnames=("backend", "block_b", "mesh", "axis"))
def _exit_gate_impl(logits, thresholds, *, backend, block_b, mesh, axis):
    from repro.kernels.exit_gate import ref
    if backend == "xla":
        return ref.ref_exit_gate(logits, thresholds)
    from repro.kernels.exit_gate.exit_gate_kernel import exit_gate_pallas

    def local(lg, th):
        return exit_gate_pallas(lg, th, block_b=block_b,
                                interpret=_interpret(backend))

    wrapped = _maybe_shard_map(local, mesh, axis,
                               in_specs=(P(axis), P(axis)),
                               out_specs=(P(axis),) * 4)
    return wrapped(logits, thresholds)


def exit_gate(logits, thresholds, *, mesh=None, axis: str = "data",
              backend: str | None = None):
    """Fused (conf, entropy, pred, fire).  logits (B, V), thresholds
    (B,) — the Eq. 19 difficulty-adapted per-sample thresholds.

    Inside a sharded step pass ``mesh=``/``axis=``: a pallas backend is
    then shard_map-wrapped so each replica gates its local rows."""
    b, v = logits.shape
    block_b = gate_block_b(max(b // _axis_size(mesh, axis), 1), v)
    chosen = _resolve("exit_gate", _gate_step_bytes(block_b, v), backend,
                      mesh, axis, b)
    return _exit_gate_impl(logits, thresholds, backend=chosen,
                           block_b=block_b, mesh=mesh, axis=axis)


def softmax_confidence(logits, *, mesh=None, axis: str = "data",
                       backend: str | None = None):
    """(conf, pred) over (..., V) — the gate without a threshold.
    Leading dims are flattened into the kernel grid; dim 0 is the
    sharded one when ``mesh`` is given."""
    shape = logits.shape
    flat_b = 1
    for s in shape[:-1]:
        flat_b *= s
    block_b = gate_block_b(max(flat_b // _axis_size(mesh, axis), 1),
                           shape[-1])
    chosen = _resolve("exit_gate", _gate_step_bytes(block_b, shape[-1]),
                      backend, mesh, axis, shape[0])
    if mesh is not None and logits.ndim != 2:
        chosen = "xla"          # only 2-D rows shard_map cleanly here
    if chosen == "xla":
        lf = logits.astype(jnp.float32)
        conf = jnp.max(jax.nn.softmax(lf, axis=-1), axis=-1)
        return conf, jnp.argmax(logits, axis=-1).astype(jnp.int32)
    flat = logits.reshape(-1, shape[-1])
    conf, _, pred, _ = _exit_gate_impl(
        flat, jnp.ones((flat.shape[0],), jnp.float32), backend=chosen,
        block_b=block_b, mesh=mesh, axis=axis)
    return conf.reshape(shape[:-1]), pred.reshape(shape[:-1])


# ---------------------------------------------------------------------------
# fused difficulty estimator (Eqs. 1-8)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("cfg", "backend", "mesh", "axis"))
def _difficulty_impl(images, *, cfg, backend, mesh, axis):
    from repro.kernels.difficulty import ref
    kw = dict(tau_edge=cfg.tau_edge, var_scale=cfg.var_scale,
              grad_scale=cfg.grad_scale, w1=cfg.w_edge, w2=cfg.w_variance,
              w3=cfg.w_gradient)
    if backend == "xla":
        return ref.ref_components(images, **kw)
    from repro.kernels.difficulty.difficulty_kernel import difficulty_pallas

    def local(img):
        return difficulty_pallas(img, interpret=_interpret(backend), **kw)

    wrapped = _maybe_shard_map(local, mesh, axis, in_specs=(P(axis),),
                               out_specs=P(axis))
    return wrapped(images)


def difficulty_components(images, cfg=None, *, mesh=None,
                          axis: str = "data",
                          backend: str | None = None):
    """(B, H, W, C) -> (B, 4): alpha_edge, alpha_var, alpha_grad, alpha
    (Eq. 8), one HBM read of the image per sample on pallas backends."""
    from repro.core.difficulty import DEFAULT
    cfg = DEFAULT if cfg is None else cfg
    b, h, w, c = images.shape
    chosen = _resolve("difficulty", _difficulty_step_bytes(h, w, c),
                      backend, mesh, axis, b)
    return _difficulty_impl(images, cfg=cfg, backend=chosen, mesh=mesh,
                            axis=axis)


def image_difficulty(images, cfg=None, *, mesh=None, axis: str = "data",
                     backend: str | None = None):
    """Fused Eq. 8 alpha — drop-in for ``core.difficulty.
    image_difficulty`` (the admission planner and every serving engine
    route through here)."""
    return difficulty_components(images, cfg, mesh=mesh, axis=axis,
                                 backend=backend)[:, 3]


# ---------------------------------------------------------------------------
# fused LM exit head (decode-time): rmsnorm -> unembed -> conf -> gate
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("eps", "backend", "block_v",
                                             "mesh", "axis"))
def _exit_head_impl(h, scale, table, thresholds, *, eps, backend, block_v,
                    mesh, axis):
    from repro.kernels.exit_head import ref
    if backend == "xla":
        return ref.ref_exit_head_gate(h, scale, table, thresholds, eps=eps)
    from repro.kernels.exit_head.exit_head_kernel import exit_head_gate_pallas

    def local(hh, sc, tab, th):
        return exit_head_gate_pallas(hh, sc, tab, th, eps=eps,
                                     block_v=block_v,
                                     interpret=_interpret(backend))

    wrapped = _maybe_shard_map(
        local, mesh, axis,
        in_specs=(P(axis), P(), P(), P(axis)),
        out_specs=(P(axis),) * 3)
    return wrapped(h, scale, table, thresholds)


# ---------------------------------------------------------------------------
# paged KV gather (continuous-batching decode): page store -> dense view
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("backend", "mesh", "axis"))
def _paged_gather_impl(pages, page_table, *, backend, mesh, axis):
    from repro.kernels.paged_gather import ref
    if backend == "xla":
        return ref.ref_paged_gather(pages, page_table)
    from repro.kernels.paged_gather.paged_gather_kernel import \
        paged_gather_pallas

    def local(pg, tab):
        # the continuous decoder allocates slot s's pages inside slot
        # s's replica range, so global ids map to local shard rows by a
        # plain modulo
        return paged_gather_pallas(pg, tab % pg.shape[0],
                                   interpret=_interpret(backend))

    wrapped = _maybe_shard_map(local, mesh, axis,
                               in_specs=(P(axis), P(axis)),
                               out_specs=P(axis))
    return wrapped(pages, page_table)


def paged_gather(pages, page_table, *, mesh=None, axis: str = "data",
                 backend: str | None = None):
    """Dense per-slot view of a paged KV store.

    pages (N, psz, ...) — the shared page store; page_table (S, P) int32
    — slot i's pages in order.  Returns (S, P*psz, ...), bit-identical
    to a contiguous (S, P*psz, ...) cache holding the same rows.  Inside
    a sharded step pass ``mesh=``/``axis=``: pages shard over the page
    axis, slots over the table axis (the decoder's range-partitioned
    allocator keeps each slot's pages on its own replica)."""
    psz = pages.shape[1]
    trailing = 1
    for d in pages.shape[2:]:
        trailing *= d
    chosen = _resolve("paged_gather", _paged_step_bytes(psz, trailing),
                      backend, mesh, axis, page_table.shape[0])
    if chosen != "xla" and mesh is not None \
            and pages.shape[0] % _axis_size(mesh, axis):
        chosen = "xla"            # page store must divide over replicas
    return _paged_gather_impl(pages, page_table, backend=chosen,
                              mesh=mesh, axis=axis)


def exit_head_gate(h, scale, table, thresholds, *, eps: float = 1e-6,
                   mesh=None, axis: str = "data",
                   backend: str | None = None):
    """Fused decode-time exit head for the ``lm-token`` functional.

    h (B, D) hidden rows, scale (D,) rmsnorm weight, table (V, D)
    unembed, thresholds (B,) Eq. 19 per-token thresholds.  Returns
    (conf (B,) f32, pred (B,) i32, fire (B,) i32) WITHOUT materializing
    the (B, V) logits in HBM (online softmax over vocab blocks)."""
    b, d = h.shape
    v = table.shape[0]
    block_v = exit_head_block_v(v, d)
    chosen = _resolve("exit_head", _head_step_bytes(block_v, d), backend,
                      mesh, axis, b)
    return _exit_head_impl(h, scale, table, thresholds, eps=eps,
                           backend=chosen, block_v=block_v, mesh=mesh,
                           axis=axis)
