# Fused Pallas kernels for the paper's compute hot-spots, each shipped
# as <name>_kernel.py (the kernel) + ref.py (the pure-jnp oracle that IS
# the "xla" backend) + ops.py (stable import path).  ALL production
# callers go through repro.kernels.dispatch — the one backend-selection
# layer (pallas / pallas-interpret / xla, VMEM budget, shard_map
# wrapping, block autotune).  See docs/kernels.md.
