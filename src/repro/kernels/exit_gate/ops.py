"""Public wrappers for the fused exit-gate kernel.

Backend selection (pallas / pallas-interpret / xla), the VMEM-budget
fallback and shard_map wrapping all live in ``repro.kernels.dispatch``;
these wrappers keep the historical import path alive.  Interpret mode
is NEVER a silent default here — it runs only when explicitly forced
(``dispatch.force_backend("pallas-interpret")`` or
``REPRO_KERNEL_BACKEND``) or when calling the raw kernel directly.
"""
from __future__ import annotations

from repro.kernels import dispatch


def exit_gate(logits, thresholds, *, mesh=None, axis="data", backend=None):
    """Fused (conf, entropy, pred, fire).  logits (B, V), thresholds
    (B,).  See ``dispatch.exit_gate``."""
    return dispatch.exit_gate(logits, thresholds, mesh=mesh, axis=axis,
                              backend=backend)


def softmax_confidence(logits, *, mesh=None, axis="data", backend=None):
    """(conf, pred) without a threshold (gating done by the caller).
    Accepts (..., V); leading dims are flattened into the kernel grid."""
    return dispatch.softmax_confidence(logits, mesh=mesh, axis=axis,
                                       backend=backend)
