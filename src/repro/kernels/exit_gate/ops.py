"""Jitted public wrapper for the fused exit-gate kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.exit_gate.exit_gate_kernel import exit_gate_pallas
from repro.kernels.exit_gate import ref

VMEM_BUDGET_BYTES = 12 * 1024 * 1024


@partial(jax.jit, static_argnames=("interpret",))
def exit_gate(logits, thresholds, interpret=True):
    """Fused (conf, entropy, pred, fire).  logits (B, V), thresholds (B,)."""
    b, v = logits.shape
    if v * 4 * 2 <= VMEM_BUDGET_BYTES:
        return exit_gate_pallas(logits, thresholds, interpret=interpret)
    return ref.ref_exit_gate(logits, thresholds)


@partial(jax.jit, static_argnames=("interpret",))
def softmax_confidence(logits, interpret=True):
    """(conf, pred) without a threshold (gating done by the caller).
    Accepts (..., V); flattens leading dims for the kernel grid."""
    shape = logits.shape
    flat = logits.reshape(-1, shape[-1])
    conf, _, pred, _ = exit_gate(flat, jnp.ones((flat.shape[0],),
                                                jnp.float32), interpret)
    return conf.reshape(shape[:-1]), pred.reshape(shape[:-1])
