"""Pure-jnp oracle for the fused exit-gate kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_exit_gate(logits, thresholds):
    """logits: (B, V); thresholds: (B,).
    Returns (conf, entropy, pred, fire) matching exit_gate_pallas."""
    lf = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(lf, axis=-1)
    p = jnp.exp(logp)
    conf = jnp.max(p, axis=-1)
    ent = -jnp.sum(p * logp, axis=-1)
    pred = jnp.argmax(lf, axis=-1).astype(jnp.int32)
    fire = (conf > thresholds).astype(jnp.int32)
    return conf, ent, pred, fire
