"""Pure-jnp oracle for the fused exit-gate kernel.

This IS the ``"xla"`` dispatch backend, so it must be bit-identical to
the eager serving chain: ``conf`` uses the same ``max(softmax(...))``
composition as ``core.routing.confidence_from_logits`` (NOT
``exp(log_softmax)``, which differs in the low bits), ``pred`` is
``jnp.argmax``, and ``fire`` is the strict Alg. 1 compare.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_exit_gate(logits, thresholds):
    """logits: (B, V); thresholds: (B,).
    Returns (conf, entropy, pred, fire) matching exit_gate_pallas."""
    lf = logits.astype(jnp.float32)
    conf = jnp.max(jax.nn.softmax(lf, axis=-1), axis=-1)
    logp = jax.nn.log_softmax(lf, axis=-1)
    ent = -jnp.sum(jnp.exp(logp) * logp, axis=-1)
    pred = jnp.argmax(lf, axis=-1).astype(jnp.int32)
    fire = (conf > thresholds).astype(jnp.int32)
    return conf, ent, pred, fire
