"""Fused early-exit gate Pallas kernel (paper Alg. 1, lines 5–9).

For each sample, one VMEM pass over the exit-head logits computes:
  * ``conf``    — max softmax probability (DART's confidence)
  * ``entropy`` — Shannon entropy (BranchyNet's criterion, same pass)
  * ``pred``    — argmax class
  * ``fire``    — conf > τ' (the Eq. 19 difficulty-adapted threshold)

Why a kernel: for LM exits the row is the vocabulary (DeepSeek: 129 280
floats = 517 KB — comfortably VMEM-resident).  The naive composition
softmax→max→argmax→compare reads the logits from HBM three times and
materializes the (B, V) softmax; this kernel reads each row once and
writes 4 scalars, turning the gate from memory-bound to free.

Grid: (B / block_b,) with ``block_b`` rows per step — ``block_b`` comes
from the dispatch autotune table (8 rows for classifier-sized
vocabularies, 1 VMEM-resident row for LM vocabularies).  Rows beyond
the VMEM budget never reach this kernel: ``kernels.dispatch`` routes
them to the jnp reference.  Numerics: fp32 max-subtracted log-sum-exp,
bitwise-stable argmax (first max index), matching ref.py.

``interpret=None`` auto-resolves to interpret mode off-TPU so the raw
kernel stays runnable in tests on this CPU container; production
callers go through ``kernels.dispatch``, which only ever picks the
compiled kernel on TPU and the interpreter when explicitly forced.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(logits_ref, thresh_ref, conf_ref, ent_ref, pred_ref, fire_ref):
    rows = logits_ref[...].astype(jnp.float32)           # (block_b, V)
    v = rows.shape[-1]
    m = jnp.max(rows, axis=-1, keepdims=True)
    # first-argmax (ties to lowest index, matches jnp.argmax)
    iota = jax.lax.broadcasted_iota(jnp.int32, rows.shape, 1)
    idx = jnp.min(jnp.where(rows == m, iota, v), axis=-1)
    ex = jnp.exp(rows - m)
    s = jnp.sum(ex, axis=-1)
    conf = 1.0 / s
    # H = log s − Σ (l−m)·exp(l−m) / s
    ent = jnp.log(s) - jnp.sum((rows - m) * ex, axis=-1) / s
    conf_ref[...] = conf
    ent_ref[...] = ent
    pred_ref[...] = idx.astype(jnp.int32)
    fire_ref[...] = (conf > thresh_ref[...]).astype(jnp.int32)


def exit_gate_pallas(logits, thresholds, *, block_b: int = 1,
                     interpret=None):
    """logits: (B, V); thresholds: (B,) effective τ' per sample.

    ``block_b`` rows per grid step (must divide B — the dispatch layer
    guarantees that from the autotune table and the power-of-two batch
    buckets).  Returns (conf (B,), entropy (B,), pred (B,) int32,
    fire (B,) int32)."""
    from repro.kernels.dispatch import resolve_interpret
    b, v = logits.shape
    if b % block_b:
        raise ValueError(f"block_b={block_b} does not divide batch {b}")
    return pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ),
        grid=(b // block_b,),
        in_specs=[pl.BlockSpec((block_b, v), lambda i: (i, 0)),
                  pl.BlockSpec((block_b,), lambda i: (i,))],
        out_specs=(pl.BlockSpec((block_b,), lambda i: (i,)),
                   pl.BlockSpec((block_b,), lambda i: (i,)),
                   pl.BlockSpec((block_b,), lambda i: (i,)),
                   pl.BlockSpec((block_b,), lambda i: (i,))),
        interpret=resolve_interpret(interpret),
    )(logits, thresholds)
