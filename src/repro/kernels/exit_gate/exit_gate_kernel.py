"""Fused early-exit gate Pallas kernel (paper Alg. 1, lines 5–9).

For each sample, one VMEM pass over the exit-head logits computes:
  * ``conf``    — max softmax probability (DART's confidence)
  * ``entropy`` — Shannon entropy (BranchyNet's criterion, same pass)
  * ``pred``    — argmax class
  * ``fire``    — conf > τ' (the Eq. 19 difficulty-adapted threshold)

Why a kernel: for LM exits the row is the vocabulary (DeepSeek: 129 280
floats = 517 KB — comfortably VMEM-resident).  The naive composition
softmax→max→argmax→compare reads the logits from HBM three times and
materializes the (B, V) softmax; this kernel reads each row once and
writes 4 scalars, turning the gate from memory-bound to free.

Grid: (B,) with the full row per step.  For rows beyond the VMEM budget
ops.py falls back to the jnp reference.  Numerics: fp32 max-subtracted
log-sum-exp, bitwise-stable argmax (first max index), matching ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(logits_ref, thresh_ref, conf_ref, ent_ref, pred_ref, fire_ref):
    row = logits_ref[0].astype(jnp.float32)              # (V,)
    v = row.shape[0]
    m = jnp.max(row)
    # first-argmax (ties to lowest index, matches jnp.argmax)
    idx = jnp.argmin(jnp.where(row == m, jax.lax.iota(jnp.int32, v), v))
    ex = jnp.exp(row - m)
    s = jnp.sum(ex)
    conf = 1.0 / s
    # H = log s − Σ (l−m)·exp(l−m) / s
    ent = jnp.log(s) - jnp.sum((row - m) * ex) / s
    conf_ref[0] = conf
    ent_ref[0] = ent
    pred_ref[0] = idx.astype(jnp.int32)
    fire_ref[0] = (conf > thresh_ref[0]).astype(jnp.int32)


def exit_gate_pallas(logits, thresholds, *, interpret=True):
    """logits: (B, V); thresholds: (B,) effective τ' per sample.

    Returns (conf (B,), entropy (B,), pred (B,) int32, fire (B,) int32)."""
    b, v = logits.shape
    return pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, v), lambda i: (i, 0)),
                  pl.BlockSpec((1,), lambda i: (i,))],
        out_specs=(pl.BlockSpec((1,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))),
        interpret=interpret,
    )(logits, thresholds)
