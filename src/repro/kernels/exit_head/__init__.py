# Fused decode-time LM exit head: rmsnorm -> unembed matmul -> softmax
# confidence -> Eq. 19 threshold gate, one kernel launch per stage.
