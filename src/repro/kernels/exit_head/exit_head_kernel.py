"""Fused decode-time LM exit head (paper Alg. 1 lines 5–9, LM domain).

One kernel launch per (stage, decode step) computes, for every survivor
row, the WHOLE exit decision the compiled decode step used to compose
from four XLA ops:

    rmsnorm(h) @ unembed.T  →  max-softmax confidence  →  argmax token
                            →  conf > τ' (Eq. 19 threshold)

Why a kernel: the (B, V) logits are the largest decode-time tensor (a
DeepSeek-vocab row is 517 KB), and the composed chain writes them to
HBM once and reads them three times (softmax, argmax, compare).  This
kernel never materializes them: the grid is (B, V/block_v), each step
holds one ``(block_v, D)`` unembed block in VMEM, and an online
(flash-style) softmax folds block maxima/sums/argmaxes into SMEM
scratch carried across the vocab dimension — the only HBM writes are
the three per-row scalars.

Numerics: rmsnorm and the accumulation run in fp32; the block matmul
runs in fp32 (the ref computes it in the model dtype, so parity is
allclose, not bitwise — ``kernels.dispatch`` only selects this kernel
on TPU or under an explicit force, never on the bit-parity CPU path).
Argmax ties resolve to the lowest index within AND across blocks,
matching ``jnp.argmax``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:                                      # pltpu is absent on some builds
    from jax.experimental.pallas import tpu as pltpu
except ImportError:                       # pragma: no cover
    pltpu = None


def _kernel(h_ref, scale_ref, tab_ref, th_ref, conf_ref, pred_ref,
            fire_ref, m_ref, s_ref, p_ref, *, eps, block_v, nv):
    j = pl.program_id(1)
    hrow = h_ref[0].astype(jnp.float32)                     # (D,)
    hn = hrow * jax.lax.rsqrt(jnp.mean(jnp.square(hrow)) + eps)
    hn = hn * scale_ref[...].astype(jnp.float32)
    tab = tab_ref[...].astype(jnp.float32)                  # (block_v, D)
    logits = jnp.dot(tab, hn[:, None])[:, 0]                # (block_v,)
    bm = jnp.max(logits)
    bidx = (jnp.argmin(jnp.where(logits == bm,
                                 jax.lax.iota(jnp.int32, block_v),
                                 block_v))
            + j * block_v).astype(jnp.int32)
    bs = jnp.sum(jnp.exp(logits - bm))

    @pl.when(j == 0)
    def _():
        m_ref[0] = bm
        s_ref[0] = bs
        p_ref[0] = bidx

    @pl.when(j > 0)
    def _():
        m_prev = m_ref[0]
        s_prev = s_ref[0]
        m_new = jnp.maximum(m_prev, bm)
        s_ref[0] = (s_prev * jnp.exp(m_prev - m_new)
                    + bs * jnp.exp(bm - m_new))
        m_ref[0] = m_new
        # strictly-greater keeps the earliest block on ties (jnp.argmax)
        p_ref[0] = jnp.where(bm > m_prev, bidx, p_ref[0])

    @pl.when(j == nv - 1)
    def _():
        conf = 1.0 / s_ref[0]
        conf_ref[0] = conf
        pred_ref[0] = p_ref[0]
        fire_ref[0] = (conf > th_ref[0]).astype(jnp.int32)


def exit_head_gate_pallas(h, scale, table, thresholds, *,
                          eps: float = 1e-6, block_v: int | None = None,
                          interpret=None):
    """h (B, D), scale (D,), table (V, D), thresholds (B,).

    ``block_v`` must divide V (``dispatch.exit_head_block_v`` picks a
    VMEM-budgeted divisor).  Returns (conf (B,) f32, pred (B,) i32,
    fire (B,) i32)."""
    from repro.kernels.dispatch import resolve_interpret
    b, d = h.shape
    v = table.shape[0]
    block_v = v if block_v is None else block_v
    if v % block_v:
        raise ValueError(f"block_v={block_v} does not divide vocab {v}")
    nv = v // block_v
    kern = functools.partial(_kernel, eps=eps, block_v=block_v, nv=nv)
    if pltpu is None:                     # pragma: no cover
        raise NotImplementedError("pallas TPU scratch spaces unavailable")
    return pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((b,), jnp.float32),
                   jax.ShapeDtypeStruct((b,), jnp.int32),
                   jax.ShapeDtypeStruct((b,), jnp.int32)),
        grid=(b, nv),
        in_specs=[pl.BlockSpec((1, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((d,), lambda i, j: (0,)),
                  pl.BlockSpec((block_v, d), lambda i, j: (j, 0)),
                  pl.BlockSpec((1,), lambda i, j: (i,))],
        out_specs=(pl.BlockSpec((1,), lambda i, j: (i,)),
                   pl.BlockSpec((1,), lambda i, j: (i,)),
                   pl.BlockSpec((1,), lambda i, j: (i,))),
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32),
                        pltpu.SMEM((1,), jnp.float32),
                        pltpu.SMEM((1,), jnp.int32)],
        interpret=resolve_interpret(interpret),
    )(h, scale, table, thresholds)
