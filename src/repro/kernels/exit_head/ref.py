"""Pure-jnp oracle for the fused LM exit-head gate.

This IS the ``"xla"`` dispatch backend on the LM decode hot path, so it
must be BIT-IDENTICAL to the chain the compiled decode step historically
composed: ``models.layers.rmsnorm`` (fp32 normalize, scale, cast back),
``transformer_lm.exit_logits``'s ``einsum("...d,vd->...v")`` unembed,
the ``lm-token`` confidence (``max(softmax(logits.astype(f32)))``, same
composition as ``core.routing.confidence_from_logits``), ``jnp.argmax``
and the strict Alg. 1 threshold compare.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_exit_head_gate(h, scale, table, thresholds, *, eps: float = 1e-6):
    """h (B, D), scale (D,) rmsnorm weight, table (V, D) unembed,
    thresholds (B,).  Returns (conf (B,) f32, pred (B,) i32,
    fire (B,) i32)."""
    dtype = h.dtype
    x = h.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1,
                                   keepdims=True) + eps)
    hn = (x * scale.astype(jnp.float32)).astype(dtype)
    logits = jnp.einsum("...d,vd->...v", hn, table)
    conf = jnp.max(jax.nn.softmax(logits.astype(jnp.float32), axis=-1),
                   axis=-1)
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    fire = (conf > thresholds).astype(jnp.int32)
    return conf, pred, fire
