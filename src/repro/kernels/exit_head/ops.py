"""Public wrapper for the fused LM exit-head gate.

Backend selection, the VMEM-budgeted vocab block and shard_map wrapping
live in ``repro.kernels.dispatch``; this module keeps the package's
``ops`` import path consistent with the other kernels.
"""
from __future__ import annotations

from repro.kernels import dispatch


def exit_head_gate(h, scale, table, thresholds, *, eps=1e-6, mesh=None,
                   axis="data", backend=None):
    """Fused rmsnorm → unembed → confidence → Eq. 19 gate.
    See ``dispatch.exit_head_gate``."""
    return dispatch.exit_head_gate(h, scale, table, thresholds, eps=eps,
                                   mesh=mesh, axis=axis, backend=backend)
