"""LMDecodeSession — queue-backed session handle over LMDecodeEngine.

The API seam for driving early-exit LM decoding through the same
scheduler machinery as classifier serving:

    session = engine.session()                 # LMDecodeEngine.session
    fut = session.submit(prompt_tokens, n_new=16, deadline_ms=500)
    out = fut.result()                         # {"tokens", "stages", ...}

Requests are laned by ``(prompt_len, n_new)`` — the two quantities that
fix the compiled decode shapes — and consolidated into one
``generate`` call per flushed bucket, so N concurrent callers share one
bucketed decode loop instead of N.  With a sharded engine
(``LMDecodeEngine(..., mesh=make_serving_mesh())``) each consolidated
bucket runs the fused donated-cache compiled decode loop; consolidation
sizes are padded with ``engine.bucket_key`` so every size inside a
bucket reuses one compiled program per stage.  Deadlines, priorities,
backpressure and the size-or-deadline flush policy behave exactly as in
:class:`~repro.serving.loop.AsyncDartServer`.
"""
from __future__ import annotations

from concurrent.futures import Future

import numpy as np

from repro.serving.loop import SchedulerConfig, _BucketScheduler
from repro.serving.request import Request


class LMDecodeSession(_BucketScheduler):
    def __init__(self, engine, cfg: SchedulerConfig | None = None, **kw):
        self.engine = engine
        cfg = cfg or SchedulerConfig(max_batch=engine.compactor.max_bucket,
                                     policy="reject")
        super().__init__(cfg, **kw)

    # -- hooks ----------------------------------------------------------
    def _bucket_key(self, n: int) -> int:
        if n > self.engine.compactor.max_bucket:
            return n            # oversized: generate() chunk-splits
        # the shared compile-cache key (bucket ∘ replica multiple), so
        # the flush planner agrees with the engine's compiled shapes
        return self.engine.bucket_key(n)

    def _max_batch_cap(self) -> int:
        return self.engine.compactor.max_bucket

    def _admit(self, prompt_tokens, deadline_ms, priority, *, now,
               n_new: int) -> Request:
        x = np.asarray(prompt_tokens)
        if x.ndim == 1:
            x = x[None]
        return Request(
            rid=next(self._rid), x=x, n=x.shape[0],
            alpha=np.zeros(x.shape[0], np.float32),
            lane=(x.shape[1], int(n_new)), predicted_cost=float(n_new),
            priority=priority, t_submit=now,
            deadline_s=None if deadline_ms is None
            else now + deadline_ms / 1e3,
            future=Future(), payload={"n_new": int(n_new)})

    def _dispatch(self, reqs: list, reason: str) -> None:
        n_new = reqs[0].payload["n_new"]
        prompts = np.concatenate([r.x for r in reqs])
        tokens, stages = self.engine.generate(prompts, n_new)
        now = self._clock()
        ends = np.cumsum([r.n for r in reqs])
        lats, missed = [], []
        for r, a, z in zip(reqs, np.concatenate([[0], ends[:-1]]), ends):
            lat_ms = (now - r.t_submit) * 1e3
            miss = r.deadline_s is not None and now > r.deadline_s
            lats.append(lat_ms)
            missed.append(miss)
            r.resolve({"tokens": tokens[a:z], "stages": stages[a:z],
                       "latency_ms": lat_ms, "deadline_missed": miss,
                       "lane": r.lane})
        # latency/deadline telemetry folds into the EngineState — the
        # ONE store behind both session.stats() and engine.stats()
        # (and it checkpoints with the engine)
        self.engine.record_requests(lats, missed)
        self.counters["completed"] += len(reqs)

    # -- metering -------------------------------------------------------
    def stats(self) -> dict:
        from repro.engine.state import request_stats
        return {"scheduler": {**self.counters, "shed": self.queue.shed,
                              "rejected": self.queue.rejected},
                "requests": request_stats(self.engine.state),
                "exit_hist": np.asarray(self.engine.stats_exit).tolist(),
                "layers_run": self.engine.layers_run,
                "layers_skipped": self.engine.layers_skipped}
