"""LMDecodeSession — queue-backed session handle over LMDecodeEngine.

The API seam for driving early-exit LM decoding through the same
scheduler machinery as classifier serving:

    session = engine.session()                 # LMDecodeEngine.session
    fut = session.submit(prompt_tokens, n_new=16, deadline_ms=500)
    out = fut.result()                         # {"tokens", "stages", ...}

Requests are laned by ``(prompt_len, n_new)`` — the two quantities that
fix the compiled decode shapes — and consolidated into one
``generate`` call per flushed bucket, so N concurrent callers share one
bucketed decode loop instead of N.  With a sharded engine
(``LMDecodeEngine(..., mesh=make_serving_mesh())``) each consolidated
bucket runs the fused donated-cache compiled decode loop; consolidation
sizes are padded with ``engine.bucket_key`` so every size inside a
bucket reuses one compiled program per stage.  Deadlines, priorities,
backpressure and the size-or-deadline flush policy behave exactly as in
:class:`~repro.serving.loop.AsyncDartServer`.

:class:`LMContinuousSession` (``engine.session(continuous=True)``)
replaces bucket flushes with continuous slot refill: requests are
admitted one at a time into a :class:`~repro.engine.lm
.ContinuousLMDecoder` slot pool the moment capacity frees up, so a
long request never holds a bucket open and a finished (or
early-exited) request's slot serves the queue THAT step.
"""
from __future__ import annotations

from concurrent.futures import Future

import numpy as np

from repro.obs import OBS
from repro.obs import adapters as OBS_A
from repro.obs import log as OBS_LOG
from repro.serving.loop import SchedulerConfig, _BucketScheduler
from repro.serving.predict import ExitDepthPredictor
from repro.serving.request import (DispatchError, Request,
                                   RequestRejected)


class LMDecodeSession(_BucketScheduler):
    def __init__(self, engine, cfg: SchedulerConfig | None = None, **kw):
        self.engine = engine
        cfg = cfg or SchedulerConfig(max_batch=engine.compactor.max_bucket,
                                     policy="reject")
        self.predictor = None if cfg.predict == "off" else \
            ExitDepthPredictor(engine.n_exits, edges=cfg.edges,
                               mode=cfg.predict)
        super().__init__(cfg, **kw)

    # -- hooks ----------------------------------------------------------
    def _bucket_key(self, n: int) -> int:
        if n > self.engine.compactor.max_bucket:
            return n            # oversized: generate() chunk-splits
        # the shared compile-cache key (bucket ∘ replica multiple), so
        # the flush planner agrees with the engine's compiled shapes
        return self.engine.bucket_key(n)

    def _max_batch_cap(self) -> int:
        return self.engine.compactor.max_bucket

    def _admit(self, prompt_tokens, deadline_ms, priority, *, now,
               n_new: int) -> Request:
        x = np.asarray(prompt_tokens)
        if x.ndim == 1:
            x = x[None]
        alpha = np.zeros(x.shape[0], np.float32)
        lane = (x.shape[1], int(n_new))
        payload = {"n_new": int(n_new)}
        if self.predictor is not None:
            # admission-time Eq. 8 difficulty of the prompt — the
            # pre-backbone signal the depth predictor conditions on
            alpha = self.engine.prompt_alpha(x).astype(np.float32)
            band = self.predictor.depth_band(float(np.mean(alpha)))
            lane = lane + (band,)    # predicted-depth lane component
            payload["band"] = band
        return Request(
            rid=next(self._rid), x=x, n=x.shape[0],
            alpha=alpha,
            lane=lane, predicted_cost=float(n_new),
            priority=priority, t_submit=now,
            deadline_s=None if deadline_ms is None
            else now + deadline_ms / 1e3,
            future=Future(), payload=payload)

    def _dispatch(self, reqs: list, reason: str) -> None:
        n_new = reqs[0].payload["n_new"]
        prompts = np.concatenate([r.x for r in reqs])
        t0 = self._clock()
        min_exit = 0
        if self.predictor is not None:
            # the decode-time routing alpha is the Eq. 8 EMA with
            # infimum 0.0 — the sound global head-skip bound
            min_exit = self.predictor.min_exit(self.engine, 0.0)
        tokens, stages = self._engine_call(
            lambda eng: eng.generate(prompts, n_new, min_exit=min_exit))
        now = self._clock()
        ends = np.cumsum([r.n for r in reqs])
        lats, missed, slices = [], [], []
        for r, a, z in zip(reqs, np.concatenate([[0], ends[:-1]]), ends):
            lat_ms = (now - r.t_submit) * 1e3
            miss = r.deadline_s is not None and now > r.deadline_s
            lats.append(lat_ms)
            missed.append(miss)
            slices.append(stages[a:z])
        # latency/deadline telemetry folds into the EngineState — the
        # ONE store behind both session.stats() and engine.stats()
        # (and it checkpoints with the engine)
        self.engine.record_requests(lats, missed)
        if self.predictor is not None:
            # realized depth per row = mean decode exit stage
            self.predictor.observe(
                np.concatenate([r.alpha for r in reqs]),
                np.rint(np.asarray(stages).mean(axis=1)))
        if OBS.enabled:
            OBS_A.record_lm_bucket(self, reqs, slices, t0, now)
        for r, a, z in zip(reqs, np.concatenate([[0], ends[:-1]]), ends):
            lat_ms = (now - r.t_submit) * 1e3
            r.resolve({"tokens": tokens[a:z], "stages": stages[a:z],
                       "latency_ms": lat_ms,
                       "deadline_missed": r.deadline_s is not None
                       and now > r.deadline_s,
                       "lane": r.lane})
        self.counters["completed"] += len(reqs)

    # -- metering -------------------------------------------------------
    def stats(self) -> dict:
        from repro.engine.state import request_stats
        out = {"scheduler": {**self.counters, "shed": self.queue.shed,
                             "rejected": self.queue.rejected,
                             "starved": self.queue.starved},
               "requests": request_stats(self.engine.state),
               "exit_hist": np.asarray(self.engine.stats_exit).tolist(),
               "layers_run": self.engine.layers_run,
               "layers_skipped": self.engine.layers_skipped}
        if self.predictor is not None:
            out["scheduler"]["predictor"] = self.predictor.stats()
        return out


class LMContinuousSession(LMDecodeSession):
    """Continuous-batching session over a :class:`ContinuousLMDecoder`
    (ISSUE 7): requests stream through the slot pool one at a time as
    slots and KV pages free up — no bucket consolidation, no flush
    barriers, and rows of different requests (at different depths)
    share every compiled decode launch.

        session = engine.session(continuous=True, n_slots=8)
        fut = session.submit(prompt_tokens, n_new=16)

    Admission order is (priority desc, submit time asc) across lanes
    via ``RequestQueue.pop_next``; a senior request that cannot fit
    right now reserves freed capacity after ``cfg.starve_ms`` instead
    of being backfilled around forever.  A request whose shape can
    NEVER fit the decoder is rejected at submit.  Early exits free
    pages mid-request-stream: Alg. 1 early termination is what creates
    admission capacity."""

    def __init__(self, engine, cfg: SchedulerConfig | None = None, *,
                 n_slots=None, page_size=8, max_len=None, decoder=None,
                 **kw):
        self.decoder = decoder if decoder is not None else \
            engine.continuous(n_slots=n_slots, page_size=page_size,
                              max_len=max_len)
        self._pending: dict = {}      # rid -> Request (rows in the pool)
        super().__init__(engine, cfg=cfg, **kw)

    # -- hooks ----------------------------------------------------------
    def _bucket_key(self, n: int) -> int:
        return n                      # no bucket shapes to consolidate

    def _max_batch_cap(self) -> int:
        return self.decoder.n_slots

    def submit(self, prompt_tokens, deadline_ms: float | None = None,
               priority: int = 0, **kw) -> Future:
        x = np.asarray(prompt_tokens)
        if x.ndim == 1:
            x = x[None]
        n_new = int(kw.get("n_new", 0))
        if not self.decoder.fits_ever(x.shape[0], x.shape[1], n_new):
            fut: Future = Future()
            fut.set_exception(RequestRejected(
                f"request (rows={x.shape[0]}, s0={x.shape[1]}, "
                f"n_new={n_new}) can never fit the decoder "
                f"(n_slots={self.decoder.n_slots}, "
                f"max_len={self.decoder.max_len})"))
            return fut
        return super().submit(x, deadline_ms, priority, **kw)

    def _fits(self, req: Request) -> bool:
        return self.decoder.can_admit(req.n, req.x.shape[1],
                                      req.payload["n_new"])

    # -- the scheduling loop --------------------------------------------
    def pump(self) -> bool:
        """One continuous-serving turn: refill free slots from the lane
        queues (most urgent head first, with head-of-line capacity
        reservation), then advance the pool one decode step and resolve
        whatever finished.  Returns False when fully idle."""
        did = False
        now = self._clock()
        while True:
            req = self.queue.pop_next(
                self._fits, reserve_after_s=self.cfg.starve_ms / 1e3,
                now=now, prefer=self._refill_prefer())
            if req is None:
                break
            self.decoder.admit(req.x, req.payload["n_new"], tag=req.rid)
            self._pending[req.rid] = req
            if OBS.enabled:
                OBS_A.record_slot_admit(self, req, self._clock())
            did = True
        if self.decoder.active_rows:
            try:
                stepped = self.decoder.step()
            except Exception as e:                 # noqa: BLE001
                self._fail_pool(e)
                return True
            done = []
            for tag, toks, stgs in stepped:
                req = self._pending.pop(tag)
                t_done = self._clock()
                lat_ms = (t_done - req.t_submit) * 1e3
                miss = req.deadline_s is not None \
                    and t_done > req.deadline_s
                done.append((req, toks, stgs, lat_ms, miss))
            # fold telemetry BEFORE resolving: a caller that waited on
            # result() then reads stats() must see its request counted
            if done:
                self.engine.record_requests(
                    [d[3] for d in done], [d[4] for d in done])
                if self.predictor is not None:
                    for req, toks, stgs, _, _ in done:
                        self.predictor.observe(
                            req.alpha,
                            np.rint(np.asarray(stgs).mean(axis=1)))
            for req, toks, stgs, lat_ms, miss in done:
                if OBS.enabled:
                    OBS_A.record_slot_exit(self, req, stgs, lat_ms, miss,
                                           self._clock())
                req.resolve({"tokens": toks, "stages": stgs,
                             "latency_ms": lat_ms,
                             "deadline_missed": miss, "lane": req.lane})
                self.counters["completed"] += 1
            did = True
        return did

    def _fail_pool(self, exc: Exception) -> None:
        """Contain a decode-step failure: fail exactly the pooled
        requests with a structured error, release their slots (freeing
        pages for the next admissions), and leave the daemon serving.
        Queued requests are untouched — the next pump() admits them
        into the recovered pool."""
        self.counters["step_errors"] = \
            self.counters.get("step_errors", 0) + 1
        self.last_error = exc
        victims = list(self._pending.values())
        OBS_LOG.error("lm_step", "continuous decode step failed",
                      exc=exc, n_requests=len(victims),
                      rids=[r.rid for r in victims[:8]])
        err = DispatchError("step",
                            victims[0].lane if victims else None,
                            [r.rid for r in victims], exc)
        for r in victims:
            try:
                self.decoder.release(r.rid)
            except Exception:                      # noqa: BLE001
                pass                 # slot already gone: nothing to free
            r.fail(err)
        self._pending.clear()

    def _refill_prefer(self):
        """Depth-aware refill score (``pop_next``'s ``prefer`` hook):
        among equally urgent fitting heads, favour the request whose
        predicted exit depth matches the pool's current mix, so the
        slots step in lock-step and free together.  None (urgency-only)
        when prediction is off or the pool is empty."""
        if self.predictor is None or not self._pending:
            return None
        mix = float(np.mean([q.payload.get("band", 0)
                             for q in self._pending.values()]))
        return lambda r: -abs(r.payload.get("band", 0) - mix)

    def _wait_timeout(self, now: float) -> float | None:
        if self.decoder.active_rows:
            return 1e-4               # keep stepping the pool
        return super()._wait_timeout(now)

    def _has_inflight(self) -> bool:
        return bool(self.decoder.active_rows or self._pending)

    def flush(self) -> None:
        """Serve everything queued or in flight to completion (shutdown
        / test barrier).  Always terminates: an empty pool admits any
        admissible request, and a stepped pool frees capacity."""
        while (not self.queue.empty) or self.decoder.active_rows:
            if not self.pump():
                break

    def stats(self) -> dict:
        out = super().stats()
        out["continuous"] = self.decoder.stats()
        return out
