"""LMDecodeSession — queue-backed session handle over LMDecodeEngine.

The API seam for driving early-exit LM decoding through the same
scheduler machinery as classifier serving (ROADMAP: the full
sharded-step port of LM decode builds on this):

    session = engine.session()                 # LMDecodeEngine.session
    fut = session.submit(prompt_tokens, n_new=16, deadline_ms=500)
    out = fut.result()                         # {"tokens", "stages", ...}

Requests are laned by ``(prompt_len, n_new)`` — the two quantities that
fix the compiled decode shapes — and consolidated into one
``generate`` call per flushed bucket, so N concurrent callers share one
bucketed decode loop instead of N.  Deadlines, priorities, backpressure
and the size-or-deadline flush policy behave exactly as in
:class:`~repro.serving.loop.AsyncDartServer`.
"""
from __future__ import annotations

from collections import deque
from concurrent.futures import Future

import numpy as np

from repro.serving.loop import SchedulerConfig, _BucketScheduler
from repro.serving.request import Request


class LMDecodeSession(_BucketScheduler):
    def __init__(self, engine, cfg: SchedulerConfig | None = None, **kw):
        self.engine = engine
        self._lat_ms: deque = deque(maxlen=2048)
        self._miss = 0
        cfg = cfg or SchedulerConfig(max_batch=engine.compactor.max_bucket,
                                     policy="reject")
        super().__init__(cfg, **kw)

    # -- hooks ----------------------------------------------------------
    def _bucket_key(self, n: int) -> int:
        if n > self.engine.compactor.max_bucket:
            return n            # oversized: generate() chunk-splits
        return self.engine.compactor.bucket_for(n)

    def _max_batch_cap(self) -> int:
        return self.engine.compactor.max_bucket

    def _admit(self, prompt_tokens, deadline_ms, priority, *, now,
               n_new: int) -> Request:
        x = np.asarray(prompt_tokens)
        if x.ndim == 1:
            x = x[None]
        return Request(
            rid=next(self._rid), x=x, n=x.shape[0],
            alpha=np.zeros(x.shape[0], np.float32),
            lane=(x.shape[1], int(n_new)), predicted_cost=float(n_new),
            priority=priority, t_submit=now,
            deadline_s=None if deadline_ms is None
            else now + deadline_ms / 1e3,
            future=Future(), payload={"n_new": int(n_new)})

    def _dispatch(self, reqs: list, reason: str) -> None:
        n_new = reqs[0].payload["n_new"]
        prompts = np.concatenate([r.x for r in reqs])
        tokens, stages = self.engine.generate(prompts, n_new)
        now = self._clock()
        ends = np.cumsum([r.n for r in reqs])
        for r, a, z in zip(reqs, np.concatenate([[0], ends[:-1]]), ends):
            lat_ms = (now - r.t_submit) * 1e3
            miss = r.deadline_s is not None and now > r.deadline_s
            self._lat_ms.append(lat_ms)
            self._miss += bool(miss)
            r.resolve({"tokens": tokens[a:z], "stages": stages[a:z],
                       "latency_ms": lat_ms, "deadline_missed": miss,
                       "lane": r.lane})
        self.counters["completed"] += len(reqs)

    # -- metering -------------------------------------------------------
    def stats(self) -> dict:
        n = self.counters["completed"]
        out = {"scheduler": {**self.counters, "shed": self.queue.shed,
                             "rejected": self.queue.rejected},
               "requests": {"requests": n, "deadline_miss": self._miss,
                            "miss_rate": self._miss / max(n, 1)},
               "exit_hist": np.asarray(self.engine.stats_exit).tolist(),
               "layers_run": self.engine.layers_run,
               "layers_skipped": self.engine.layers_skipped}
        if self._lat_ms:
            from repro.engine.state import latency_percentiles
            out["requests"]["latency_ms"] = \
                latency_percentiles(self._lat_ms)
        return out
