"""AdmissionPlanner — difficulty-aware cost prediction at enqueue.

The paper's Eq. 8 estimator is cheap enough (≈79 KFLOPs/image, §III.B)
to run at ADMISSION time, before the model sees the input.  That turns
the scheduler's packing problem tractable: every request gets

* ``alpha``          — its Eq. 8 difficulty, estimated once here via
  the engine's dispatch-routed estimator (``repro.kernels.dispatch``:
  the fused single-pass Pallas kernel on TPU, the jnp reference chain
  elsewhere) and handed to the engine at dispatch
  (``infer(..., alpha=...)``), so the estimator never runs twice;
* a difficulty CLASS — ``digitize(mean alpha, edges)``; the scheduler
  lanes/buckets requests per class, so buckets stay cost-homogeneous;
* ``predicted_cost`` — expected normalized MACs/sample, from the
  telemetry prior: a per-class EMA of the exit depths the scheduler
  actually observed (cold start: depth grows linearly in alpha, the
  Eq. 19 first-order effect of difficulty on thresholds).

Under ``degrade-alpha`` backpressure the planner re-admits the request
with a scaled-down alpha: Eq. 19 lowers every gate's threshold for
easier inputs, so the request exits earlier and costs less — graceful
quality degradation instead of queue growth.
"""
from __future__ import annotations

import threading

import jax.numpy as jnp
import numpy as np

from repro.core import adaptive as AD
from repro.core import difficulty as DIFF


class AdmissionPlanner:
    def __init__(self, engine, edges=DIFF.DEFAULT_EDGES,
                 ema_decay: float = 0.9):
        self.engine = engine
        self.edges = np.asarray(edges, np.float32)
        self.n_classes = len(self.edges) + 1
        self.ema_decay = float(ema_decay)
        self._depth_ema = [None] * self.n_classes
        self._stage_ms = None      # per-stage service-time EMA (quotes)
        self._lock = threading.Lock()
        cum = np.asarray(engine.cum_costs, np.float64)
        self._cum_norm = cum / cum[-1]
        # Exit-count prior from telemetry: an engine that has already
        # served (e.g. restored from a checkpoint) seeds the cold-start
        # depth prediction from its §II.C window instead of the linear-
        # in-alpha guess.
        self._global_depth = None
        if int(np.sum(np.asarray(engine.state.served))):
            adaptive = engine.state.adaptive
            if hasattr(engine, "n_replicas"):       # merge replica windows
                from repro.engine import state as EST
                adaptive = EST.merged_adaptive(engine.state)
            self._global_depth = float(
                AD.window_exit_depth(adaptive, engine.acfg))

    # ------------------------------------------------------------------
    def admit(self, x: np.ndarray):
        """(alpha (n,), difficulty class, predicted cost/sample).

        ``engine._alpha`` routes through ``kernels.dispatch``, so
        admission pays the fused difficulty kernel where available."""
        alpha = np.asarray(self.engine._alpha(jnp.asarray(x)), np.float32)
        return (alpha,) + self.classify(alpha)

    def classify(self, alpha: np.ndarray):
        """(difficulty class, predicted cost) for an already-known alpha
        (the degrade-alpha re-admission path)."""
        a = float(np.mean(alpha))
        dclass = int(DIFF.difficulty_class(a, self.edges))
        return dclass, self.predicted_cost(a, dclass)

    def predicted_cost(self, alpha_mean: float, dclass: int) -> float:
        """Expected normalized MACs/sample: telemetry-prior exit depth
        (per-class EMA, falling back to the engine's window-wide depth,
        then to linear-in-alpha) run through the engine's cumulative
        cost curve."""
        with self._lock:
            depth = self._depth_ema[dclass]
            if depth is None:
                depth = self._global_depth
        if depth is None:
            depth = alpha_mean * (self.engine.n_exits - 1)
        return float(np.interp(depth, np.arange(self.engine.n_exits),
                               self._cum_norm))

    def observe(self, exit_idx: np.ndarray, alpha: np.ndarray) -> None:
        """Fold served outcomes back into the per-class depth priors."""
        exit_idx = np.asarray(exit_idx)
        dclass = np.asarray(DIFF.difficulty_class(
            np.asarray(alpha, np.float32), self.edges))
        d_all = float(np.mean(exit_idx))
        with self._lock:
            self._global_depth = d_all if self._global_depth is None else \
                self.ema_decay * self._global_depth \
                + (1.0 - self.ema_decay) * d_all
            for c in np.unique(dclass):
                d = float(np.mean(exit_idx[dclass == c]))
                prev = self._depth_ema[int(c)]
                self._depth_ema[int(c)] = d if prev is None else \
                    self.ema_decay * prev + (1.0 - self.ema_decay) * d

    def priors(self) -> list:
        """Current per-class expected exit depth (None = never seen)."""
        with self._lock:
            return list(self._depth_ema)

    # ------------------------------------------------------------------
    # snapshot (serving-state checkpoint): the learned priors a restarted
    # server should NOT have to re-learn from a cold stream
    # ------------------------------------------------------------------
    def state_dict(self) -> dict:
        with self._lock:
            return {"depth_ema": list(self._depth_ema),
                    "global_depth": self._global_depth,
                    "stage_ms": self._stage_ms}

    def load_state_dict(self, state: dict) -> None:
        with self._lock:
            depth = list(state["depth_ema"])
            if len(depth) != self.n_classes:
                raise ValueError(
                    f"snapshot has {len(depth)} depth classes, "
                    f"planner has {self.n_classes}")
            self._depth_ema = depth
            self._global_depth = state["global_depth"]
            self._stage_ms = state["stage_ms"]

    # ------------------------------------------------------------------
    # admission-time SLO quoting (ISSUE 9): predicted depth x per-stage
    # service EMA — a latency quote in ms, not a MACs fraction.  The
    # pinned ``predicted_cost`` MACs prior stays intact (the cascade
    # planner composes on it); quotes are an additional signal.
    # ------------------------------------------------------------------
    def observe_service(self, service_ms: float,
                        depth_mean: float) -> None:
        """Fold one completed bucket's realized service time into the
        per-stage service EMA.  ``depth_mean`` is the bucket's mean
        realized exit stage, so a bucket that exited at stage d paid
        for d+1 stages."""
        per = float(service_ms) / (float(depth_mean) + 1.0)
        with self._lock:
            self._stage_ms = per if self._stage_ms is None else \
                self.ema_decay * self._stage_ms \
                + (1.0 - self.ema_decay) * per

    def quote_ms(self, depth: float) -> float | None:
        """Latency quote for a request predicted to exit at (fractional)
        stage ``depth``: (depth+1) stages x the per-stage service EMA.
        None until a completed bucket has seeded the EMA."""
        with self._lock:
            if self._stage_ms is None:
                return None
            return (float(depth) + 1.0) * self._stage_ms

    def stage_ms(self) -> float | None:
        """The per-stage service-time EMA feeding quotes (ms)."""
        with self._lock:
            return self._stage_ms
