"""ServingLoop — the async, SLO-aware dispatcher over the DART engines.

``AsyncDartServer`` turns a ``DartEngine`` / ``ShardedDartEngine`` into
a real server: callers ``submit(x, deadline_ms, priority)`` and get a
future; a background dispatcher consolidates queued requests into
``BatchCompactor`` buckets and flushes each bucket through ONE engine
call.  The lifecycle of a request:

    submit ──admit──▶ lane queue ──flush──▶ in-flight ──resolve──▶ future
           (Eq. 8 α,    (per difficulty   (one infer call  (np outputs,
            cost         class; back-      per bucket;      latency fold,
            prediction)  pressure)         pipelined)       prior update)

Flush policy (size-or-deadline):

* **deadline** — a lane flushes when its earliest deadline minus the
  estimated service time (EMA of recent bucket latencies + margin)
  would otherwise expire while waiting.
* **size**     — a lane flushes at the consolidation target
  (``max_batch``), or early when it exactly fills a power-of-two bucket
  at ≥ half the target: waiting longer could only grow padding waste,
  never shrink it ("never pad past the next bucket when waiting would
  beat padding").
* **hold**     — no BEST-EFFORT (deadline-less) request waits longer
  than ``flush_ms`` even on an idle stream.  Deadline'd requests are
  deliberately excluded: their SLO already bounds the wait, and holding
  them until deadline pressure (or a full bucket) maximizes
  consolidation at exactly the loads where it pays.

Pipelining: with a sharded engine, dispatched outputs stay ON DEVICE
(PR 2 left them lazy precisely for this) — the loop keeps up to
``pipeline_depth`` buckets in flight and only materializes (resolving
futures, folding latency telemetry into ``EngineState``) when the
pipeline is full or there is nothing left to dispatch.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import deque
from concurrent.futures import Future

import numpy as np

from repro.core import daes as DAES
from repro.core import difficulty as DIFF
from repro.obs import OBS
from repro.obs import adapters as OBS_A
from repro.obs import log as OBS_LOG
from repro.serving.planner import AdmissionPlanner
from repro.serving.predict import ExitDepthPredictor
from repro.serving.queue import RequestQueue
from repro.serving.request import DispatchError, Request, RequestRejected

#: result keys sliced per request out of a consolidated engine call
_RESULT_KEYS = ("pred", "conf", "exit_idx", "alpha", "macs")


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Knobs of the async scheduler (see module docstring for the flush
    semantics).

    max_batch:      consolidation target, samples per flushed bucket
    flush_ms:       max hold time for a non-full lane
    margin_ms:      scheduling slack subtracted from every deadline
    max_queue:      per-lane backpressure limit, in requests
    policy:         "shed" | "reject" | "degrade-alpha"
    degrade_factor: alpha scale applied under degrade-alpha
    min_fill:       min fill fraction before growing into a larger bucket
    mode:           engine inference mode for dispatched buckets
    pipeline_depth: max in-flight (unmaterialized) buckets
    edges:          difficulty-class boundaries on Eq. 8 alpha
    sample_ndim:    rank of ONE sample (submit auto-batches bare samples)
    starve_ms:      continuous slot refill only — how long the most
                    urgent queued request may be passed over for lack
                    of capacity before freed slots are reserved for it
                    (see ``RequestQueue.pop_next``)
    predict:        admission-time exit-depth prediction — "off" |
                    "conservative" (head-skip only where Eq. 19
                    provably can't fire: bit-identical decisions) |
                    "aggressive" (additionally skip gates the learned
                    histogram says never fire — opt-in, measured).
                    On, requests get predicted-depth lanes, an
                    admission latency quote, and per-bucket head-skip
                    (see ``repro.serving.predict``)
    """
    max_batch: int = 64
    flush_ms: float = 5.0
    margin_ms: float = 1.0
    max_queue: int = 256
    policy: str = "shed"
    degrade_factor: float = 0.5
    min_fill: float = 0.5
    mode: str = "masked"
    pipeline_depth: int = 2
    edges: tuple = DIFF.DEFAULT_EDGES
    sample_ndim: int = 3
    starve_ms: float = 50.0
    predict: str = "off"


class _BucketScheduler:
    """Lane-queue + dispatcher-thread machinery shared by the classifier
    scheduler (:class:`AsyncDartServer`) and the LM decode session
    (:class:`~repro.serving.lm_session.LMDecodeSession`).

    Subclasses implement ``_admit`` (build a Request) and ``_dispatch``
    (serve a flushed run of requests); the base owns admission,
    flush timing, the worker thread, and shutdown."""

    def __init__(self, cfg: SchedulerConfig, *, clock=time.monotonic,
                 start: bool = True):
        self.cfg = cfg
        self._clock = clock
        # Effective consolidation target: cfg.max_batch clamped to what
        # ONE dispatch can serve as a single compiled shape — flushing
        # more than the engine's largest bucket would make bucket_key
        # raise mid-flush and wedge the dispatcher.
        self.max_batch = max(1, min(cfg.max_batch, self._max_batch_cap()))
        self.queue = RequestQueue(max_queue=cfg.max_queue,
                                  policy=cfg.policy)
        self._rid = itertools.count()
        self._cv = threading.Condition()
        self._stop = False
        self._closed = False
        self._service_s = 0.0        # EMA of bucket service time
        self.last_error: Exception | None = None
        self.counters = {"submitted": 0, "completed": 0, "degraded": 0,
                         "flush_deadline": 0, "flush_size": 0,
                         "flush_hold": 0, "flush_forced": 0}
        self._thread = None
        if OBS.enabled:
            OBS_A.bind_scheduler(self)
        if start:
            self.start()

    # -- subclass hooks -------------------------------------------------
    def _admit(self, x, deadline_ms, priority, *, now, **kw) -> Request:
        """Build the Request.  ``now`` is stamped at the START of
        submit(), so admission work (the Eq. 8 estimate) counts toward
        the request's latency and deadline like any other service
        time."""
        raise NotImplementedError

    def _dispatch(self, reqs: list, reason: str) -> None:
        raise NotImplementedError

    def _engine_call(self, fn):
        """Run one engine call.  ``fn(engine) -> result``; the default
        binds the scheduler's single engine.  The resilience layer
        (:class:`~repro.serving.resilience.EnginePool`) overrides this
        to add engine selection, retry/backoff and hedging without the
        dispatch sites knowing."""
        return fn(self.engine)

    def _on_dispatch_error(self, reqs: list, exc: Exception) -> bool:
        """Dispatch-failure hook: return True when the requests were
        re-routed (e.g. requeued by the pool after an engine death) and
        must NOT have their futures failed.  Default: unhandled."""
        return False

    def _drain_one(self) -> bool:
        """Materialize one in-flight bucket if any; False when idle."""
        return False

    def _bucket_key(self, n: int) -> int:
        """Padded dispatch shape for n samples.  Must be TOTAL (never
        raise): oversized single requests pass through take() and are
        dispatched unpadded."""
        return n

    def _max_batch_cap(self) -> int:
        """Largest sample count one dispatch can serve as one shape."""
        return self.cfg.max_batch

    # -- lifecycle ------------------------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=type(self).__name__)
        self._thread.start()

    def submit(self, x, deadline_ms: float | None = None,
               priority: int = 0, **kw) -> Future:
        """Enqueue one request; resolves to its per-request result dict
        (or raises RequestShed/RequestRejected under backpressure)."""
        t0 = self._clock()
        req = self._admit(x, deadline_ms, priority, now=t0, **kw)
        # The closed check and the push share the cv lock with close():
        # a request either lands before _closed is set (close's flush
        # serves it) or is rejected — never silently stranded in a lane
        # no worker will ever flush.
        with self._cv:
            if self._closed:
                req.fail(RequestRejected("scheduler is closed"))
                return req.future
            action = self.queue.push(req)
            self.counters["submitted"] += 1
            self._cv.notify()
        if OBS.enabled:
            OBS_A.record_admit(self, req, action, t0, self._clock())
        return req.future

    def close(self, wait: bool = True) -> None:
        """Stop admitting, serve everything already queued, join."""
        with self._cv:
            self._closed = True
            self._stop = True
            self._cv.notify()
        if self._thread is not None:
            self._thread.join(timeout=60)
            self._thread = None
        if wait:
            self.flush()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- scheduling -----------------------------------------------------
    def _select_flush(self, now: float):
        """(lane, reason, force) of the most urgent flush-ready lane,
        or None.  Urgency: deadline pressure ≻ size ≻ hold."""
        slack = (self.cfg.margin_ms / 1e3) + self._service_s
        best = None                       # (rank, tiebreak, lane, reason)
        for key in self.queue.keys():
            n_q = self.queue.samples(key)
            if not n_q:
                continue
            edl = self.queue.earliest_deadline(key)
            held = self.queue.oldest_undeadlined(key)
            if edl is not None and edl - now <= slack:
                cand = (0, edl, key, "deadline")
            elif n_q >= self.max_batch or (
                    2 * n_q >= self.max_batch
                    and self._bucket_key(n_q) == n_q):
                cand = (1, -n_q, key, "size")
            elif held is not None \
                    and now - held >= self.cfg.flush_ms / 1e3:
                cand = (2, held, key, "hold")
            else:
                continue
            if best is None or cand < best:
                best = cand
        if best is None:
            return None
        _, _, key, reason = best
        return key, reason, reason == "deadline"

    def _wait_timeout(self, now: float) -> float | None:
        """Seconds until the next deadline/hold event (None = wait for
        a submit notification)."""
        slack = (self.cfg.margin_ms / 1e3) + self._service_s
        nxt = None
        for key in self.queue.keys():
            edl = self.queue.earliest_deadline(key)
            held = self.queue.oldest_undeadlined(key)
            for t in ((edl - slack) if edl is not None else None,
                      (held + self.cfg.flush_ms / 1e3)
                      if held is not None else None):
                if t is not None and (nxt is None or t < nxt):
                    nxt = t
        if nxt is None:
            return None
        return max(nxt - now, 1e-4)

    def pump(self) -> bool:
        """One scheduling decision: flush the most urgent ready lane, or
        materialize one in-flight bucket.  Returns False when idle.
        (The worker thread loops this; tests drive it directly.)"""
        sel = self._select_flush(self._clock())
        if sel is not None:
            key, reason, force = sel
            reqs = self.queue.take(key, self.max_batch,
                                   self._bucket_key,
                                   min_fill=self.cfg.min_fill, force=force)
            if reqs:
                self.counters[f"flush_{reason}"] += 1
                self._dispatch_safe(reqs, reason)
                return True
        return self._drain_one()

    def _dispatch_safe(self, reqs: list, reason: str) -> None:
        """A bad bucket must not kill the dispatcher: an exception from
        the engine fails THIS bucket's futures and the loop lives on
        (a shape-mismatched input would otherwise strand every pending
        future behind a dead daemon thread)."""
        if OBS.enabled:
            OBS_A.record_bucket(self, reqs, reason, self._clock())
        try:
            self._dispatch(reqs, reason)
        except Exception as e:                     # noqa: BLE001
            if self._on_dispatch_error(reqs, e):
                return                             # re-routed, not failed
            self.counters["dispatch_errors"] = \
                self.counters.get("dispatch_errors", 0) + 1
            self.last_error = e
            OBS_LOG.error("dispatch", "bucket dispatch failed", exc=e,
                          reason=reason, lane=reqs[0].lane,
                          n_requests=len(reqs),
                          rids=[r.rid for r in reqs[:8]])
            err = e if isinstance(e, DispatchError) else DispatchError(
                "dispatch", reqs[0].lane, [r.rid for r in reqs], e)
            for r in reqs:
                r.fail(err)

    def flush(self) -> None:
        """Force-dispatch every queued request and materialize all
        in-flight work (shutdown / test barrier)."""
        while True:
            keys = self.queue.keys()
            if not keys:
                break
            for key in keys:
                while True:
                    reqs = self.queue.take(key, self.max_batch,
                                           self._bucket_key, force=True)
                    if not reqs:
                        break
                    self.counters["flush_forced"] += 1
                    self._dispatch_safe(reqs, "forced")
        while self._drain_one():
            pass

    def _run(self) -> None:
        while True:
            with self._cv:
                if not self._stop:
                    busy = not self.queue.empty
                    self._cv.wait(self._wait_timeout(self._clock())
                                  if busy else
                                  (0.002 if self._has_inflight() else None))
                if self._stop:
                    return
            try:
                while self.pump():
                    if self._stop:
                        return
            except Exception as e:                 # noqa: BLE001
                # Dispatch errors are contained by _dispatch_safe; this
                # catches scheduler bugs so the thread survives (queued
                # work still fails fast through _dispatch_safe rather
                # than hanging behind a dead loop).
                self.last_error = e
                OBS_LOG.error("scheduler", "scheduler loop error",
                              exc=e, scheduler=type(self).__name__)
                time.sleep(0.01)

    def _has_inflight(self) -> bool:
        return False


class AsyncDartServer(_BucketScheduler):
    """The difficulty-aware async request scheduler over a DartEngine.

        engine = DartEngine.from_config(cfg, params, ...)
        server = AsyncDartServer(engine)
        fut = server.submit(x, deadline_ms=50)
        out = fut.result()          # same keys as engine.infer + latency
        server.stats()              # engine stats + p50/p95/p99 + misses
        server.close()

    Works with the eager engine and (better: pipelined, one compiled
    dispatch per bucket) the sharded engine.  Under a fixed policy,
    scheduler decisions never change routing decisions: completed
    outputs are identical to serving each request alone through
    ``engine.infer`` (with §II.C adaptation on, reordering shifts where
    the periodic updates fall — see docs/serving.md).

    Constructing with a :class:`~repro.cascade.engine.CascadeEngine`
    transparently builds the cascade scheduler
    (:class:`~repro.cascade.serving.CascadeAsyncServer`): lanes become
    (member, difficulty class), escalations re-enqueue into the next
    member's lanes."""

    def __new__(cls, engine=None, *args, **kw):
        if cls is AsyncDartServer and engine is not None:
            from repro.cascade.engine import CascadeEngine
            if isinstance(engine, CascadeEngine):
                from repro.cascade.serving import CascadeAsyncServer
                cls = CascadeAsyncServer
        return object.__new__(cls)

    def __init__(self, engine, cfg: SchedulerConfig = SchedulerConfig(),
                 *, clock=time.monotonic, start: bool = True):
        self.engine = engine
        self.planner = self._make_planner(cfg)
        self.predictor = None if cfg.predict == "off" else \
            ExitDepthPredictor(engine.n_exits, edges=cfg.edges,
                               mode=cfg.predict,
                               priors=self.planner.priors)
        # Per-lane Eq. 9 telemetry: static reference = the full network
        # (for a cascade engine, the biggest member's full network).
        self.daes = DAES.LaneDaesAccumulator(
            static_macs=float(np.asarray(engine.cum_costs)[-1]))
        self._inflight: deque = deque()
        super().__init__(cfg, clock=clock, start=start)

    def _make_planner(self, cfg: SchedulerConfig):
        return AdmissionPlanner(self.engine, edges=cfg.edges)

    # -- hooks ----------------------------------------------------------
    def _bucket_key(self, n: int) -> int:
        if n > self.engine.compactor.max_bucket:
            return n            # oversized single request: unpadded
        return self.engine.bucket_key(n)

    def _max_batch_cap(self) -> int:
        return self.engine.compactor.max_bucket

    def _admit(self, x, deadline_ms, priority, *, now, **kw) -> Request:
        x = np.asarray(x)
        if x.ndim == self.cfg.sample_ndim:
            x = x[None]
        alpha, lane, cost = self.planner.admit(x)
        if self.cfg.policy == "degrade-alpha" \
                and self.queue.depth(lane) >= self.cfg.max_queue:
            alpha = alpha * self.cfg.degrade_factor
            lane, cost = self.planner.classify(alpha)
            self.counters["degraded"] += 1
        payload = {}
        if self.predictor is not None:
            depth, band = self.predictor.admit_info(float(np.mean(alpha)))
            quote = self._quote_ms(depth)
            if (quote is not None and deadline_ms is not None
                    and self.cfg.policy == "degrade-alpha"
                    and quote > deadline_ms):
                # the quote says this request cannot make its SLO at
                # its predicted depth: degrade it at admission instead
                # of letting it miss
                alpha = alpha * self.cfg.degrade_factor
                lane, cost = self.planner.classify(alpha)
                self.counters["degraded"] += 1
                depth, band = self.predictor.admit_info(
                    float(np.mean(alpha)))
                quote = self._quote_ms(depth)
            # predicted-depth lane component: a flushed bucket's rows
            # are predicted to exit together
            lane = (lane, band)
            payload = {"quote_ms": quote, "depth": depth}
            if quote is not None:
                cost = quote    # predicted_cost becomes the SLO quote
        return Request(
            rid=next(self._rid), x=x, n=x.shape[0], alpha=alpha,
            lane=lane, predicted_cost=cost, priority=priority,
            t_submit=now,
            deadline_s=None if deadline_ms is None
            else now + deadline_ms / 1e3,
            future=Future(), payload=payload)

    def _quote_ms(self, depth: float):
        quote_fn = getattr(self.planner, "quote_ms", None)
        return None if quote_fn is None else quote_fn(depth)

    def _infer_batch(self, reqs: list, x, alpha) -> dict:
        """ONE engine call for a flushed run of requests.  Masked
        dispatches pad to the bucket so every consolidation size inside
        a bucket reuses ONE compiled forward; compacted mode buckets its
        stages internally.  A single request larger than the biggest
        bucket goes through unpadded (the sharded engine chunk-splits
        it; the eager forward just runs that shape) — bucket_key would
        raise BatchTooLarge on it."""
        pad_to = self.engine.bucket_key(x.shape[0]) \
            if self.cfg.mode == "masked" \
            and x.shape[0] <= self.engine.compactor.max_bucket else None
        min_exit = 0
        if self.predictor is not None:
            # the bucket's smallest difficulty bounds every row (Eq. 19
            # is monotone in alpha), so one min_exit covers the bucket
            min_exit = self.predictor.min_exit(self.engine,
                                               float(np.min(alpha)))
        return self._engine_call(
            lambda eng: eng.infer(x, mode=self.cfg.mode, record=True,
                                  alpha=alpha, pad_to=pad_to,
                                  min_exit=min_exit))

    def _dispatch(self, reqs: list, reason: str) -> None:
        x = np.concatenate([r.x for r in reqs])
        alpha = np.concatenate([r.alpha for r in reqs])
        t0 = self._clock()
        out = self._infer_batch(reqs, x, alpha)
        # Service EMA from the dispatch call itself: it feeds the
        # deadline slack, so it must not absorb pipeline idle time (a
        # deferred materialization would look like a slow engine).  For
        # a sharded engine the call returns before the device finishes —
        # an underestimate the margin_ms knob exists to cover.
        service = self._clock() - t0
        self._service_s = service if not self._service_s else \
            0.8 * self._service_s + 0.2 * service
        self._inflight.append((reqs, out, t0))
        while len(self._inflight) > self.cfg.pipeline_depth:
            self._complete_safe(*self._inflight.popleft())

    def _drain_one(self) -> bool:
        if not self._inflight:
            return False
        self._complete_safe(*self._inflight.popleft())
        return True

    def _complete_safe(self, reqs, out, t_dispatch) -> None:
        try:
            self._complete(reqs, out, t_dispatch)
        except Exception as e:                     # noqa: BLE001
            self.last_error = e
            self.counters["complete_errors"] = \
                self.counters.get("complete_errors", 0) + 1
            OBS_LOG.error("complete", "bucket materialization failed",
                          exc=e, lane=reqs[0].lane,
                          rids=[r.rid for r in reqs[:8]])
            err = e if isinstance(e, DispatchError) else DispatchError(
                "complete", reqs[0].lane, [r.rid for r in reqs], e)
            for r in reqs:
                r.fail(err)

    def _has_inflight(self) -> bool:
        return bool(self._inflight)

    # -- completion -----------------------------------------------------
    def _complete(self, reqs, out, t_dispatch) -> None:
        vals = {k: np.asarray(out[k]) for k in _RESULT_KEYS}
        now = self._clock()
        ends = np.cumsum([r.n for r in reqs])
        lats, missed, results = [], [], []
        for r, a, z in zip(reqs, np.concatenate([[0], ends[:-1]]), ends):
            res = {k: v[a:z] for k, v in vals.items()}
            lat_ms = (now - r.t_submit) * 1e3
            miss = r.deadline_s is not None and now > r.deadline_s
            res.update(latency_ms=lat_ms, deadline_missed=miss,
                       predicted_cost=r.predicted_cost, lane=r.lane)
            lats.append(lat_ms)
            missed.append(miss)
            results.append(res)
        # Telemetry folds BEFORE any future resolves: a caller woken by
        # fut.result() must find its request already in
        # stats()["requests"] (the documented pattern).
        self.engine.record_requests(lats, missed)
        self.planner.observe(vals["exit_idx"], vals["alpha"])
        if self.predictor is not None:
            self.predictor.observe(vals["alpha"], vals["exit_idx"])
            self.engine.record_quotes(
                [r.payload.get("quote_ms") for r in reqs], lats)
            svc = getattr(self.planner, "observe_service", None)
            if svc is not None:
                svc((now - t_dispatch) * 1e3,
                    float(np.mean(vals["exit_idx"])))
        for r, res in zip(reqs, results):
            self.daes.observe(r.lane, res["conf"], res["macs"],
                              res["alpha"])
        self.counters["completed"] += len(reqs)
        if OBS.enabled:
            OBS_A.record_completed(self, reqs, results, t_dispatch, now)
        for r, res in zip(reqs, results):
            r.resolve(res)

    # -- metering -------------------------------------------------------
    def stats(self) -> dict:
        """Engine stats (incl. ``requests`` latency percentiles + miss
        rate, folded into EngineState) + scheduler-level counters."""
        s = self.engine.stats()
        s["scheduler"] = {
            **self.counters,
            "shed": self.queue.shed, "rejected": self.queue.rejected,
            "starved": self.queue.starved,
            "queued": {k: self.queue.depth(k) for k in self.queue.keys()},
            "inflight": len(self._inflight),
            "depth_prior": self.planner.priors(),
            "service_ms_ema": self._service_s * 1e3,
        }
        if self.predictor is not None:
            s["scheduler"]["predictor"] = self.predictor.stats()
            stage_fn = getattr(self.planner, "stage_ms", None)
            if stage_fn is not None:
                s["scheduler"]["stage_ms_ema"] = stage_fn()
        s["daes"] = self.daes.rows()
        return s
