"""repro.serving — async, difficulty-aware request scheduling.

The serving layer the paper's pitch implies but the engines alone don't
provide: callers submit individual requests (with deadlines and
priorities) and a scheduler consolidates them into compiled-bucket
batches, packing by PREDICTED cost — the Eq. 8 difficulty estimator is
cheap enough to run at admission, before the model executes — so easy
traffic never waits behind hard traffic:

    from repro.engine import DartEngine
    from repro.serving import AsyncDartServer

    engine = DartEngine.from_config(model_cfg, params)
    with AsyncDartServer(engine) as server:
        fut = server.submit(x, deadline_ms=50, priority=1)
        out = fut.result()        # engine.infer keys + latency_ms + SLO
        print(server.stats()["requests"]["latency_ms"])   # p50/p95/p99

Pieces:

* :class:`AsyncDartServer` — the scheduler façade (loop.py): background
  dispatcher, size-or-deadline flush, pipelined sharded dispatch.
* :class:`SchedulerConfig` — its knobs (flush/hold timing, backpressure
  policy ``shed`` | ``reject`` | ``degrade-alpha``, bucket targets).
* :class:`AdmissionPlanner` — Eq. 8 difficulty + telemetry-prior cost
  prediction at enqueue (planner.py); with prediction on it also
  issues per-request latency QUOTES (predicted depth × per-stage
  service EMA).
* :class:`ExitDepthPredictor` — admission-time exit-depth prediction
  (predict.py): per-class online logistic heads over Eq. 8 difficulty
  feeding head-skip (``min_exit``), predicted-depth lanes and SLO
  quotes.  Enable via ``SchedulerConfig(predict="conservative")``
  (bit-identical) or ``"aggressive"`` (opt-in, measured).
* :class:`RequestQueue` — lane-keyed backpressure queue (queue.py).
* :class:`LMDecodeSession` — the same scheduling over
  ``LMDecodeEngine.generate`` (lm_session.py); reach it via
  ``engine.session()``.  With a sharded LM engine, each consolidated
  bucket runs the fused donated-cache compiled decode loop.

Scheduling never changes routing under a fixed policy: every completed
request's outputs are identical to serving it alone through
``engine.infer`` (the admission alpha is handed to the engine, Alg. 1
runs unchanged).  With §II.C adaptation on, request reordering shifts
where the periodic coefficient updates fall — see docs/serving.md.

Constructing ``AsyncDartServer`` with a ``repro.cascade.CascadeEngine``
transparently builds the cascade scheduler (lanes keyed by
(member, difficulty class); escalations re-enqueue into the next
member's lanes) — see docs/serving.md's cascade section.
"""
from repro.serving.loop import AsyncDartServer, SchedulerConfig
from repro.serving.lm_session import LMDecodeSession
from repro.serving.planner import AdmissionPlanner
from repro.serving.predict import ExitDepthPredictor
from repro.serving.queue import RequestQueue
from repro.serving.request import (DispatchError, InvalidEngineOutput,
                                   Request, RequestRejected, RequestShed)
from repro.serving.resilience import (EnginePool, NoHealthyEngines,
                                      PooledDartServer, ResilienceConfig,
                                      pooled_cascade_server,
                                      pooled_lm_session)

__all__ = ["AsyncDartServer", "SchedulerConfig", "AdmissionPlanner",
           "ExitDepthPredictor", "RequestQueue", "LMDecodeSession",
           "Request", "RequestRejected", "RequestShed", "DispatchError",
           "InvalidEngineOutput", "EnginePool", "PooledDartServer",
           "ResilienceConfig", "NoHealthyEngines",
           "pooled_cascade_server", "pooled_lm_session"]
