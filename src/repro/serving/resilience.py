"""Fault-tolerant serving (ISSUE 10 tentpole): the chaos-injected
engine pool with retry/hedge dispatch, elastic membership, and the
graceful-degradation ladder.

:class:`EnginePool` wraps one-or-more same-config engines behind the
scheduler's ``_engine_call`` seam.  Every dispatched bucket becomes a
``pool.call(fn)``:

    pick healthy engine ─▶ run on pool worker ─▶ validate ─▶ return
          │ (round-robin)      │ straggler deadline       │ non-finite
          │                    │ exceeded? HEDGE to       │ conf / bad
          │ engine dead /      │ another healthy engine,  │ exit stage:
          │ exception: bounded │ first result wins        │ quarantine,
          └ retry w/ backoff ◀─┴──────────────────────────┴ retry

* **Health** (healthy → degraded → dead) is driven by call outcomes
  plus a hardened :class:`~repro.runtime.fault.HeartbeatMonitor`
  (beats fire on call completion and from an idle-beater; a wedged
  compiled step starves its engine's beats and the monitor declares it
  dead).  A success on a degraded engine restores it.
* **Hedging** uses :class:`~repro.runtime.fault.StragglerPolicy` — a
  rolling-median deadline over observed call times, NOT a fixed
  timeout.  First-result-wins; futures resolve exactly once because
  the pool returns one result per call and the scheduler resolves each
  request future behind a ``done()`` guard.
* **Elastic membership**: :meth:`EnginePool.drain` removes an engine
  from routing (not a failure); :meth:`EnginePool.join` restores a
  (possibly new) engine from an ``EngineState`` checkpoint
  (``restore_with_migration``), warms the bucket shapes the pool has
  served, and only then takes traffic.
* **Degradation ladder** — as live capacity shrinks the pool escalates
  (each rung logged, gauged, and REVERSED on recovery):

    =====  ======================  ===================================
    rung   actuator                mechanism
    =====  ======================  ===================================
    1      degrade-alpha           dispatch-time alpha scale: Eq. 19
                                   lowers every gate's threshold for
                                   easier inputs → earlier exits
    2      threshold scaling       ``state.with_policy(tau * scale)``
                                   on every live engine → shallower
                                   exits for ALL traffic
    3      max-depth cap           tau sentinel (−1e3) from the cap
                                   stage on: the clipped Eq. 19
                                   threshold is 0, softmax-max conf is
                                   strictly positive, so the gate
                                   always fires — no sample runs past
                                   the cap
    4      shed lowest priority    submit-time shed below the priority
                                   floor
    =====  ======================  ===================================

* **Snapshots**: :meth:`PooledDartServer.snapshot` atomically persists
  planner / predictor / threshold state next to the engine checkpoint;
  a restarted server resumes its learned priors via
  :meth:`restore_snapshot` instead of cold-starting.

Chaos cut points (``runtime/chaos.py``) fire at dispatch (call entry),
step (inside the worker, around the engine call), complete
(materialization) and checkpoint_load (snapshot restore / join).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from concurrent.futures import wait as futures_wait

import numpy as np

from repro.obs import OBS
from repro.obs import adapters as OBS_A
from repro.obs import log as OBS_LOG
from repro.runtime.chaos import (FaultInjector, InjectedEngineDeath,
                                 NullInjector)
from repro.runtime.fault import HeartbeatMonitor, StragglerPolicy
from repro.serving.loop import AsyncDartServer, SchedulerConfig
from repro.serving.request import InvalidEngineOutput, RequestShed

HEALTHY, DEGRADED, DEAD, DRAINED = "healthy", "degraded", "dead", "drained"
#: health states that still take traffic
_LIVE = (HEALTHY, DEGRADED)
#: numeric encoding for the ``dart_engine_health`` gauge
HEALTH_LEVEL = {DEAD: 0, DRAINED: 0, DEGRADED: 1, HEALTHY: 2}

#: tau sentinel for the rung-3 max-depth cap: clip(coef*(−1e3) +
#: beta_diff*alpha, 0, 1) = 0 for any sane policy, and softmax-max
#: confidence is strictly > 0, so the capped gate ALWAYS fires.
_TAU_ALWAYS_FIRE = -1e3


class NoHealthyEngines(RuntimeError):
    """Every pool engine is dead or drained — the scheduler requeues
    the bucket (bounded) instead of failing it outright."""


class EngineWedged(RuntimeError):
    """A call exceeded the hard cap on every engine that tried it —
    the engines were marked dead and the bucket is re-routed."""


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Knobs of the engine pool.

    retries:            extra attempts per call after the first
    backoff_s:          base retry backoff (doubles per attempt)
    hedge:              enable straggler hedging
    hedge_factor:       StragglerPolicy deadline = factor x rolling
                        median call time (no hedging until the policy
                        has observations)
    straggler_window:   rolling-median window, in calls
    call_timeout_s:     hard per-call cap — past it the engine is
                        declared wedged (dead) and the call re-routes
    heartbeat_timeout_s: missed-beat deadline for the monitor
    degraded_alpha_scale: rung-1 dispatch-time alpha multiplier
    degraded_tau_scale:   rung-2 threshold scale
    depth_cap_frac:       rung-3 cap stage as a fraction of n_exits-1
    shed_priority_floor:  rung-4: shed submits with priority < floor
    requeue_limit:        max NoHealthyEngines requeues per request
    requeue_backoff_s:    real sleep before a requeue retry
    validate:             output-validation quarantine on/off
    """
    retries: int = 2
    backoff_s: float = 0.002
    hedge: bool = True
    hedge_factor: float = 3.0
    straggler_window: int = 20
    call_timeout_s: float = 30.0
    heartbeat_timeout_s: float = 5.0
    degraded_alpha_scale: float = 0.5
    degraded_tau_scale: float = 0.5
    depth_cap_frac: float = 0.5
    shed_priority_floor: int = 1
    requeue_limit: int = 3
    requeue_backoff_s: float = 0.005
    validate: bool = True


def validate_output(out, n_exits=None) -> None:
    """Output-validation quarantine: raise :class:`InvalidEngineOutput`
    on non-finite confidence or out-of-range exit stages — a poisoned
    bucket must fail structurally, not leak NaNs into telemetry."""
    if isinstance(out, dict):
        if "conf" in out:
            conf = np.asarray(out["conf"])
            if not np.all(np.isfinite(conf)):
                raise InvalidEngineOutput(
                    f"non-finite confidence in engine output "
                    f"({int(np.sum(~np.isfinite(conf)))} bad values)")
        if "exit_idx" in out and n_exits:
            e = np.asarray(out["exit_idx"])
            if e.size and (e.min() < 0 or e.max() >= n_exits):
                raise InvalidEngineOutput(
                    f"exit stage out of range [0, {n_exits}): "
                    f"[{e.min()}, {e.max()}]")
    elif isinstance(out, tuple) and len(out) == 2 and n_exits:
        stages = np.asarray(out[1])
        if stages.size and (stages.min() < 0 or stages.max() >= n_exits):
            raise InvalidEngineOutput(
                f"decode exit stage out of range [0, {n_exits}): "
                f"[{stages.min()}, {stages.max()}]")


def _corrupt(out):
    """Apply a ``nan_output`` injection: the corruption the validator
    must catch (dict outputs get NaN confidence, LM tuples get an
    impossible exit stage)."""
    if isinstance(out, dict) and "conf" in out:
        bad = np.full_like(np.asarray(out["conf"], np.float32), np.nan)
        return {**out, "conf": bad}
    if isinstance(out, tuple) and len(out) == 2:
        stages = np.asarray(out[1])
        return out[0], np.full_like(stages, np.iinfo(np.int32).max)
    return out


class EnginePool:
    """One-or-more same-config engines behind one ``call()`` seam.

        pool = EnginePool({"e0": eng0, "e1": eng1})
        srv = PooledDartServer(pool, SchedulerConfig(...))
        ...
        pool.drain("e1"); pool.join("e1", eng1, snapshot=ckpt_dir)
        pool.close()

    Engines must be built from the SAME config and parameters: a retry
    or hedge re-runs the identical pure function, so whichever engine
    answers, the result is bit-identical.
    """

    def __init__(self, engines: dict, cfg: ResilienceConfig | None = None,
                 *, injector: FaultInjector | None = None,
                 heartbeat: bool = True):
        if not engines:
            raise ValueError("EnginePool needs at least one engine")
        self.engines = dict(engines)
        self.cfg = cfg or ResilienceConfig()
        self.injector = injector or NullInjector()
        self.health = {n: HEALTHY for n in self.engines}
        self.straggler = StragglerPolicy(
            factor=self.cfg.hedge_factor,
            window=self.cfg.straggler_window)
        self.counters = {"calls": 0, "retries": 0, "hedges": 0,
                         "requeues": 0, "quarantined": 0, "deaths": 0,
                         "stragglers": 0, "joins": 0, "drains": 0}
        self._lock = threading.RLock()
        self._rr = 0
        self._rung = 0
        self.rung_history: list = []
        self.alpha_scale = 1.0
        self.shed_floor: int | None = None
        self._events: list = []
        self._inflight: dict = {n: 0 for n in self.engines}
        self._orig_tau: dict = {}
        self._warm_shapes: set = set()
        self.warm_mode = "masked"
        for eng in self._policy_targets(self.engines.values()):
            self._remember_tau(eng)
        self._exec = ThreadPoolExecutor(
            max_workers=max(2, len(self.engines)),
            thread_name_prefix="engine-pool")
        self._closed = False
        self.monitor = None
        self._beater = None
        if heartbeat:
            self.monitor = HeartbeatMonitor(
                list(self.engines), timeout_s=self.cfg.heartbeat_timeout_s,
                on_failure=self._on_missed_beats)
            self._beater = threading.Thread(target=self._beat_idle,
                                            daemon=True,
                                            name="engine-pool-beater")
            self._beater.start()
        if OBS.enabled:
            OBS_A.bind_pool(self)
            if self.injector.on_fire is None:
                self.injector.on_fire = OBS_A.record_fault

    # -- introspection ----------------------------------------------------
    @property
    def primary(self):
        """The engine backing admission planning / bucket keys /
        telemetry (the first live engine, falling back to the first)."""
        with self._lock:
            for n, eng in self.engines.items():
                if self.health[n] in _LIVE:
                    return eng
            return next(iter(self.engines.values()))

    @property
    def rung(self) -> int:
        return self._rung

    def n_live(self) -> int:
        with self._lock:
            return sum(1 for s in self.health.values() if s in _LIVE)

    def stats(self) -> dict:
        with self._lock:
            return {
                "engines": dict(self.health),
                "rung": self._rung,
                "rung_history": list(self.rung_history),
                "alpha_scale": self.alpha_scale,
                "shed_floor": self.shed_floor,
                "faults_injected": len(self.injector.trace),
                "straggler_deadline_ms":
                    self.straggler.deadline() * 1e3
                    if self.straggler.times else None,
                **self.counters,
            }

    def consume_events(self) -> list:
        """Drain the per-call event record (retry/hedge/quarantine/...)
        — the pooled scheduler uses a non-empty record to mark the
        bucket's requests as fault-touched."""
        with self._lock:
            ev, self._events = self._events, []
            return ev

    # -- the call seam ----------------------------------------------------
    def call(self, fn):
        """Run ``fn(engine)`` on a healthy engine with bounded retry,
        straggler hedging and output validation.  Raises
        :class:`NoHealthyEngines` when nothing can take traffic."""
        with self._lock:
            self.counters["calls"] += 1
        last_exc: Exception | None = None
        tried: set = set()
        for attempt in range(self.cfg.retries + 1):
            name = self._pick(exclude=tried)
            if name is None:
                name = self._pick()          # all tried: allow re-tries
            if name is None:
                raise NoHealthyEngines(
                    f"no live engine for call "
                    f"(health={dict(self.health)})") from last_exc
            tried.add(name)
            if attempt:
                with self._lock:
                    self.counters["retries"] += 1
                    self._events.append("retry")
                if OBS.enabled:
                    OBS_A.record_retry(name, attempt)
                time.sleep(self.cfg.backoff_s * (2 ** (attempt - 1)))
            try:
                return self._attempt(name, fn)
            except Exception as e:             # noqa: BLE001
                last_exc = e
        raise last_exc

    def _attempt(self, name: str, fn):
        self.injector.fire("dispatch", engine=name)
        fut = self._exec.submit(self._run_on, name, fn)
        pending = {fut: name}
        deadline = self.straggler.deadline()
        if self.cfg.hedge and math.isfinite(deadline):
            try:
                return fut.result(timeout=deadline)
            except FuturesTimeout:
                with self._lock:
                    self.counters["stragglers"] += 1
                alt = self._pick(exclude={name})
                if alt is not None:
                    with self._lock:
                        self.counters["hedges"] += 1
                        self._events.append("hedge")
                    if OBS.enabled:
                        OBS_A.record_hedge(name, alt)
                    OBS_LOG.event("pool", "hedging straggler bucket",
                                  slow=name, to=alt,
                                  deadline_ms=deadline * 1e3)
                    pending[self._exec.submit(self._run_on, alt, fn)] = alt
            except Exception:
                raise
        # first result wins; a hard cap bounds a fully wedged call
        t_end = time.monotonic() + self.cfg.call_timeout_s
        last_exc: Exception | None = None
        while pending:
            done, _ = futures_wait(set(pending),
                                   timeout=max(t_end - time.monotonic(),
                                               1e-3),
                                   return_when=FIRST_COMPLETED)
            if not done:
                for wedged in pending.values():
                    self._mark_dead(wedged, reason="wedged")
                raise EngineWedged(
                    f"call exceeded {self.cfg.call_timeout_s}s on "
                    f"{sorted(pending.values())}") from last_exc
            for f in done:
                pending.pop(f)
                try:
                    return f.result()
                except Exception as e:         # noqa: BLE001
                    last_exc = e
        raise last_exc

    def _run_on(self, name: str, fn):
        """One engine execution on a pool worker: step-point injection,
        the engine call, nan corruption + validation, bookkeeping."""
        eng = self.engines[name]
        with self._lock:
            self._inflight[name] += 1
        t0 = time.monotonic()
        try:
            action = self.injector.fire("step", engine=name)
            out = fn(eng)
            if action == "nan_output":
                out = _corrupt(out)
            if self.cfg.validate:
                validate_output(out,
                                getattr(self.primary, "n_exits", None))
        except InvalidEngineOutput as e:
            with self._lock:
                self.counters["quarantined"] += 1
                self._events.append("quarantine")
            self._note_failure(name, e)
            raise
        except Exception as e:                 # noqa: BLE001
            self._note_failure(name, e)
            raise
        finally:
            with self._lock:
                self._inflight[name] -= 1
        dt = time.monotonic() - t0
        self.straggler.record(dt)
        self._mark_success(name)
        return out

    # -- health -----------------------------------------------------------
    def _pick(self, exclude=frozenset()) -> str | None:
        with self._lock:
            live = [n for n in self.engines
                    if self.health[n] in _LIVE and n not in exclude]
            prefer = [n for n in live if self.health[n] == HEALTHY]
            cands = prefer or live
            if not cands:
                return None
            self._rr += 1
            return cands[self._rr % len(cands)]

    def _mark_success(self, name: str) -> None:
        if self.monitor is not None:
            self.monitor.beat(name)
        with self._lock:
            if self.health.get(name) == DEGRADED:
                self.health[name] = HEALTHY
                OBS_LOG.event("pool", "engine recovered", engine=name)
        self._update_ladder()

    def _note_failure(self, name: str, exc: Exception) -> None:
        if isinstance(exc, InjectedEngineDeath):
            self._mark_dead(name, reason="injected death")
            return
        with self._lock:
            cur = self.health.get(name)
            if cur == HEALTHY:
                self.health[name] = DEGRADED
                OBS_LOG.event("pool", "engine degraded", engine=name,
                              error=f"{type(exc).__name__}: {exc}")
            elif cur == DEGRADED:
                self.health[name] = DEAD
                self.counters["deaths"] += 1
                OBS_LOG.event("pool", "engine died", engine=name,
                              error=f"{type(exc).__name__}: {exc}")
        self._update_ladder()

    def _mark_dead(self, name: str, *, reason: str) -> None:
        with self._lock:
            if self.health.get(name) == DEAD:
                return
            self.health[name] = DEAD
            self.counters["deaths"] += 1
            self._events.append("death")
        OBS_LOG.event("pool", "engine declared dead", engine=name,
                      reason=reason)
        self._update_ladder()

    def _on_missed_beats(self, name: str) -> None:
        """HeartbeatMonitor callback (fires OUTSIDE its lock): an
        engine that stopped beating while a call is in flight on it is
        wedged — declare it dead so dispatch re-routes."""
        with self._lock:
            if self.health.get(name) not in _LIVE:
                return
        self._mark_dead(name, reason="missed heartbeats")

    def _beat_idle(self) -> None:
        """Beat every live engine with no in-flight call: only an
        engine actually stuck inside a call can miss its deadline."""
        period = self.cfg.heartbeat_timeout_s / 4
        while not self._closed:
            with self._lock:
                idle = [n for n in self.engines
                        if self.health[n] in _LIVE
                        and not self._inflight[n]]
            for n in idle:
                if self.monitor is not None:
                    self.monitor.beat(n)
            time.sleep(period)

    # -- elastic membership ----------------------------------------------
    def drain(self, name: str) -> None:
        """Remove an engine from routing (planned decommission, not a
        failure: no death count, no callback)."""
        with self._lock:
            if name not in self.engines:
                raise KeyError(name)
            self.health[name] = DRAINED
            self.counters["drains"] += 1
        if self.monitor is not None:
            self.monitor.remove_worker(name)
        OBS_LOG.event("pool", "engine drained", engine=name)
        self._update_ladder()

    def join(self, name: str, engine=None, *, snapshot: str | None = None,
             warm: bool = True) -> None:
        """(Re-)admit an engine: restore its ``EngineState`` from the
        snapshot checkpoint (``restore_with_migration`` under the
        ``checkpoint_load`` cut point), warm the bucket shapes the pool
        has served, THEN take traffic."""
        if engine is not None:
            self.engines[name] = engine
        elif name not in self.engines:
            raise KeyError(name)
        eng = self.engines[name]
        self.injector.fire("checkpoint_load", engine=name)
        if snapshot is not None:
            eng.restore_state(os.path.join(snapshot, "engine"))
        self._remember_tau_targets(eng)
        if warm:
            self._warm(eng)
        with self._lock:
            self.health[name] = HEALTHY
            self._inflight.setdefault(name, 0)
            self._inflight[name] = 0
            self.counters["joins"] += 1
        if self.monitor is not None:
            self.monitor.add_worker(name)
        OBS_LOG.event("pool", "engine joined", engine=name,
                      warmed=len(self._warm_shapes) if warm else 0,
                      snapshot=snapshot)
        self._update_ladder()

    def note_example(self, x) -> None:
        """Record a dispatched batch shape so a joining engine can warm
        the same compiled buckets before taking traffic."""
        x = np.asarray(x)
        with self._lock:
            self._warm_shapes.add(
                (x.shape, str(x.dtype), self.warm_mode))

    def _warm(self, eng) -> None:
        infer = getattr(eng, "infer", None)
        if infer is None:
            return
        with self._lock:
            shapes = sorted(self._warm_shapes, key=str)
        for shape, dtype, mode in shapes:
            try:
                infer(np.zeros(shape, dtype), mode=mode, record=False)
            except Exception as e:             # noqa: BLE001
                OBS_LOG.error("pool", "bucket warm failed", exc=e,
                              shape=list(shape))

    # -- the degradation ladder ------------------------------------------
    def _ladder_rung_for(self, n_live: int) -> int:
        n = len(self.engines)
        if n_live == 0:
            return 4
        lost = 1.0 - n_live / n
        return int(np.clip(np.ceil(lost * 4.0), 0, 4))

    def _update_ladder(self) -> None:
        with self._lock:
            rung = self._ladder_rung_for(
                sum(1 for s in self.health.values() if s in _LIVE))
            if rung == self._rung:
                return
            prev, self._rung = self._rung, rung
            self.rung_history.append(
                {"from": prev, "to": rung,
                 "health": dict(self.health)})
            self.alpha_scale = self.cfg.degraded_alpha_scale \
                if rung >= 1 else 1.0
            self.shed_floor = self.cfg.shed_priority_floor \
                if rung >= 4 else None
            live = [self.engines[n] for n in self.engines
                    if self.health[n] in _LIVE]
        self._apply_policy(live, rung)
        OBS_LOG.event("pool",
                      "degradation ladder moved" if rung > prev
                      else "degradation ladder reversed",
                      rung=rung, prev=prev,
                      alpha_scale=self.alpha_scale,
                      shed_floor=self.shed_floor)

    def _policy_targets(self, engines):
        """Engines whose Eq. 19 thresholds the ladder actuates — the
        members for a cascade engine, the engine itself otherwise."""
        for eng in engines:
            members = getattr(eng, "members", None)
            if members is not None:
                yield from members
            elif hasattr(eng, "state"):
                yield eng

    def _remember_tau(self, eng) -> None:
        if id(eng) not in self._orig_tau:
            self._orig_tau[id(eng)] = np.asarray(eng.state.tau,
                                                 np.float32).copy()

    def _remember_tau_targets(self, eng) -> None:
        for t in self._policy_targets([eng]):
            self._remember_tau(t)

    def _apply_policy(self, live_engines, rung: int) -> None:
        """Install the rung's threshold transform on every live engine
        (rung < 2 restores the original tau — the reversal path)."""
        for eng in self._policy_targets(live_engines):
            self._remember_tau(eng)
            tau = self._orig_tau[id(eng)].copy()
            if rung >= 2:
                tau = tau * self.cfg.degraded_tau_scale
            if rung >= 3 and tau.size:
                cap = int(np.clip(
                    np.floor(tau.size * self.cfg.depth_cap_frac),
                    0, tau.size - 1))
                tau[cap:] = _TAU_ALWAYS_FIRE
            eng.state = eng.state.with_policy(tau=tau)

    # -- lifecycle --------------------------------------------------------
    def close(self) -> None:
        self._closed = True
        if self.monitor is not None:
            self.monitor.close()
        if self._beater is not None:
            self._beater.join(timeout=2.0)
        self._exec.shutdown(wait=False)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class _PooledSchedulerMixin:
    """The scheduler-side half of pooling, mixed into the classifier /
    cascade / LM schedulers: routes ``_engine_call`` through the pool,
    turns NoHealthyEngines into a bounded backpressure-bypassing
    requeue, sheds below the rung-4 priority floor, fires the
    ``complete`` cut point, and tracks which rids any fault touched."""

    def _install_pool(self, pool: EnginePool) -> None:
        # runs BEFORE the scheduler __init__ (dispatch hooks need the
        # pool the moment the daemon starts) — don't touch self.cfg here
        self.pool = pool
        self.touched_rids: set = set()
        self._snap_stop: threading.Event | None = None
        self._snap_thread = None

    # -- dispatch routing -------------------------------------------------
    def _engine_call(self, fn):
        return self.pool.call(fn)

    def _dispatch(self, reqs: list, reason: str) -> None:
        rids = [r.rid for r in reqs]
        if self.pool.rung:
            self.touched_rids.update(rids)
        try:
            super()._dispatch(reqs, reason)
        finally:
            if self.pool.consume_events():
                self.touched_rids.update(rids)

    def _on_dispatch_error(self, reqs: list, exc: Exception) -> bool:
        if not isinstance(exc, (NoHealthyEngines, EngineWedged)):
            return False
        limit = self.pool.cfg.requeue_limit
        if any(r.payload.get("requeues", 0) >= limit for r in reqs):
            return False                       # bounded: fail the bucket
        for r in reqs:
            r.payload["requeues"] = r.payload.get("requeues", 0) + 1
            self.touched_rids.add(r.rid)
            self.queue.requeue(r)
        self.counters["requeued"] = \
            self.counters.get("requeued", 0) + len(reqs)
        with self.pool._lock:
            self.pool.counters["requeues"] += len(reqs)
        if OBS.enabled:
            OBS_A.record_requeue(len(reqs))
        OBS_LOG.event("pool", "bucket requeued (no live engine)",
                      n_requests=len(reqs), rids=[r.rid for r in reqs[:8]],
                      error=type(exc).__name__)
        time.sleep(self.pool.cfg.requeue_backoff_s)
        return True

    # -- rung-4 shed ------------------------------------------------------
    def submit(self, x, deadline_ms=None, priority: int = 0, **kw):
        floor = self.pool.shed_floor
        if floor is not None and priority < floor:
            from concurrent.futures import Future
            fut: Future = Future()
            fut.set_exception(RequestShed(
                f"degradation ladder rung {self.pool.rung}: shedding "
                f"priority {priority} < floor {floor}"))
            self.counters["shed_degraded"] = \
                self.counters.get("shed_degraded", 0) + 1
            return fut
        return super().submit(x, deadline_ms, priority, **kw)

    # -- completion cut point ---------------------------------------------
    def _complete(self, reqs, out, t_dispatch) -> None:
        self.pool.injector.fire("complete")
        super()._complete(reqs, out, t_dispatch)

    # -- serving-state snapshots ------------------------------------------
    def snapshot(self, path: str, step: int = 0) -> None:
        """Atomic serving-state checkpoint: EngineState (thresholds,
        §II.C window, telemetry) via the engine's own checkpointer plus
        the host-side planner/predictor priors as JSON (tmp + rename)."""
        os.makedirs(path, exist_ok=True)
        self.engine.save_state(os.path.join(path, "engine"), step)
        meta: dict = {"step": int(step)}
        if hasattr(self.planner, "state_dict"):
            meta["planner"] = self.planner.state_dict()
        if getattr(self, "predictor", None) is not None:
            meta["predictor"] = self.predictor.state_dict()
        tmp = os.path.join(path, "serving_state.json.tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f)
        os.replace(tmp, os.path.join(path, "serving_state.json"))

    def restore_snapshot(self, path: str) -> int:
        """Resume learned serving priors from :meth:`snapshot` (fires
        the ``checkpoint_load`` cut point; every live engine restores
        the same EngineState through ``restore_with_migration``)."""
        self.pool.injector.fire("checkpoint_load")
        step = 0
        seen: set = set()
        for name, eng in self.pool.engines.items():
            if self.pool.health[name] not in _LIVE or id(eng) in seen:
                continue
            seen.add(id(eng))
            step = eng.restore_state(os.path.join(path, "engine"))
            self.pool._remember_tau_targets(eng)
        with open(os.path.join(path, "serving_state.json")) as f:
            meta = json.load(f)
        if "planner" in meta and hasattr(self.planner, "load_state_dict"):
            self.planner.load_state_dict(meta["planner"])
        if "predictor" in meta and getattr(self, "predictor", None) \
                is not None:
            self.predictor.load_state_dict(meta["predictor"])
        OBS_LOG.event("pool", "serving state restored", path=path,
                      step=meta.get("step", step))
        return int(meta.get("step", step))

    def start_snapshots(self, path: str, every_s: float) -> None:
        """Periodic snapshot daemon (explicitly opted into)."""
        self._snap_stop = threading.Event()

        def _loop():
            n = 0
            while not self._snap_stop.wait(every_s):
                n += 1
                try:
                    self.snapshot(path, step=n)
                except Exception as e:         # noqa: BLE001
                    OBS_LOG.error("pool", "periodic snapshot failed",
                                  exc=e, path=path)
        self._snap_thread = threading.Thread(
            target=_loop, daemon=True, name="serving-snapshots")
        self._snap_thread.start()

    def close(self, wait: bool = True) -> None:
        if self._snap_stop is not None:
            self._snap_stop.set()
            self._snap_thread.join(timeout=2.0)
            self._snap_stop = None
        super().close(wait)

    # -- metering ---------------------------------------------------------
    def stats(self) -> dict:
        s = super().stats()
        s["pool"] = self.pool.stats()
        s["pool"]["touched_rids"] = len(self.touched_rids)
        return s


class PooledDartServer(_PooledSchedulerMixin, AsyncDartServer):
    """:class:`AsyncDartServer` over an :class:`EnginePool` — same
    submit/stats/close surface; admission planning, bucket keys and
    telemetry ride the pool's primary engine, dispatch rides
    ``pool.call`` with retry/hedge/requeue, and the degradation ladder
    scales dispatch-time alpha (rung 1) on top of the pool's threshold
    actuators."""

    def __init__(self, pool: EnginePool,
                 cfg: SchedulerConfig = SchedulerConfig(), **kw):
        self._install_pool(pool)
        pool.warm_mode = cfg.mode
        super().__init__(pool.primary, cfg, **kw)

    def _infer_batch(self, reqs: list, x, alpha):
        self.pool.note_example(x)
        scale = self.pool.alpha_scale
        if scale != 1.0:
            # rung 1, degrade-alpha: Eq. 19 thresholds drop for easier
            # inputs, so the whole bucket exits earlier
            alpha = np.asarray(alpha) * scale
            self.touched_rids.update(r.rid for r in reqs)
        return super()._infer_batch(reqs, x, alpha)


def pooled_cascade_server(pool: EnginePool,
                          cfg: SchedulerConfig = SchedulerConfig(), **kw):
    """Pooled cascade scheduler (lazy import: pulling the cascade
    package in at module import would be a cycle through
    ``repro.serving.__init__``)."""
    from repro.cascade.serving import CascadeAsyncServer

    class PooledCascadeServer(_PooledSchedulerMixin, CascadeAsyncServer):
        def __init__(self, pool, cfg, **kw):
            self._install_pool(pool)
            pool.warm_mode = cfg.mode
            super().__init__(pool.primary, cfg, **kw)
    return PooledCascadeServer(pool, cfg, **kw)


def pooled_lm_session(pool: EnginePool, cfg=None, **kw):
    """Pooled bucketed LM decode session: ``generate`` calls ride
    ``pool.call`` (retry/hedge/requeue as for classifier buckets)."""
    from repro.serving.lm_session import LMDecodeSession

    class PooledLMSession(_PooledSchedulerMixin, LMDecodeSession):
        def __init__(self, pool, cfg, **kw):
            self._install_pool(pool)
            super().__init__(pool.primary, cfg, **kw)
    return PooledLMSession(pool, cfg, **kw)
