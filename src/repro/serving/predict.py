"""Admission-time exit-depth prediction (ISSUE 9 tentpole).

DART's premise is that difficulty is knowable *before* paying for the
backbone (Eq. 8 runs on raw inputs).  Following Dong, Mao & Zhang
(arXiv:2206.07269, "Resource-Constrained Edge AI with Early Exit
Prediction"), a tiny pre-backbone predictor can therefore commit to an
exit depth at ADMISSION time; and per EENet, ruling a stage out up
front means its exit head + gate launches need never run.

:class:`ExitDepthPredictor` is that predictor: one online logistic
head per (difficulty class, gate) over the Eq. 8 difficulty

    P(exit <= s | alpha, class) = sigmoid(w0[c, s] + w1[c, s] * alpha)

trained by per-completion SGD from the telemetry the scheduler already
folds into ``EngineState`` (realized exit stages arrive for free in
``_complete``), plus a per-class exit-histogram EMA used as a quantile
band.  Three consumers:

* **head-skip** — :meth:`min_exit` hands the engines a per-bucket
  ``min_exit`` static arg.  ``conservative`` mode only rules a gate
  out when Eq. 19 *provably* can't fire it (the engine's
  ``min_exit_bound``: unclipped threshold >= the confidence bound) —
  decisions stay bit-identical to the eager oracle.  ``aggressive``
  mode additionally skips gates whose learned fire probability is
  below ``eps`` — opt-in, measured, NOT bit-identical.
* **depth-aware packing** — :meth:`depth_band` gives the scheduler a
  predicted-depth lane component so a bucket's rows exit together.
* **SLO quoting** — :meth:`predict_depth` feeds the admission
  planner's per-request latency quote (predicted depth x per-stage
  service EMA).

Everything is host-side numpy: admission must never pay a device
round-trip.  All methods are thread-safe (submit threads + the
dispatcher thread both touch the predictor).
"""
from __future__ import annotations

import threading

import numpy as np

from repro.core import difficulty as DIFF

MODES = ("conservative", "aggressive")


def _sigmoid(z):
    return 1.0 / (1.0 + np.exp(-z))


class ExitDepthPredictor:
    """Per-class online logistic/quantile exit-depth heads.

        pred = ExitDepthPredictor(engine.n_exits)
        pred.observe(alpha, exit_idx)          # completion telemetry
        pred.predict_depth(0.4)                # float expected stage
        pred.depth_band(0.4)                   # int lane component
        pred.min_exit(engine, alpha_lo=0.35)   # head-skip bound

    ``priors`` (optional) is a callable returning the admission
    planner's per-class depth EMAs (``AdmissionPlanner.priors``); cold
    heads blend toward it until they have seen ``prior_strength``
    observations of their class.
    """

    def __init__(self, n_exits: int, edges=DIFF.DEFAULT_EDGES, *,
                 mode: str = "conservative", lr: float = 0.25,
                 ema_decay: float = 0.98, eps: float = 0.02,
                 min_obs: int = 32, prior_strength: float = 8.0,
                 band_hysteresis: float = 0.25, priors=None):
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r}; known: {MODES}")
        if n_exits < 1:
            raise ValueError("n_exits must be >= 1")
        self.n_exits = int(n_exits)
        self.edges = tuple(edges)
        self.n_classes = len(self.edges) + 1
        self.mode = mode
        self.lr = float(lr)
        self.ema_decay = float(ema_decay)
        self.eps = float(eps)
        self.min_obs = int(min_obs)
        self.prior_strength = float(prior_strength)
        self.band_hysteresis = float(band_hysteresis)
        self._priors = priors
        self._band_cache: dict = {}     # class -> sticky lane band
        g = max(self.n_exits - 1, 1)
        # logistic heads: P(exit <= s) = sigmoid(w0 + w1 * alpha)
        self.w0 = np.zeros((self.n_classes, g), np.float64)
        self.w1 = np.zeros((self.n_classes, g), np.float64)
        # per-class exit histogram EMA (quantile band / aggressive bound)
        self.hist = np.zeros((self.n_classes, self.n_exits), np.float64)
        self.n_obs = np.zeros(self.n_classes, np.int64)
        self.hits = 0
        self.misses = 0
        self.skip_calls = 0      # min_exit() invocations (buckets)
        self.skip_stages = 0     # total gates skipped across buckets
        self._lock = threading.Lock()

    # -- training ---------------------------------------------------------
    def observe(self, alpha, exit_idx) -> None:
        """Fold realized (difficulty, exit stage) pairs — chunked
        minibatch SGD on each class's gate heads + histogram EMA.
        Hit/miss is scored against the band predicted BEFORE the
        update.  observe() rides the scheduler's completion path, so it
        is vectorized per class: it must stay cheaper than the
        head-skip launches it pays for."""
        alpha = np.atleast_1d(np.asarray(alpha, np.float64))
        exit_idx = np.clip(
            np.atleast_1d(np.asarray(exit_idx, np.int64)),
            0, self.n_exits - 1)
        classes = np.atleast_1d(DIFF.difficulty_class(alpha, self.edges))
        with self._lock:
            for c in np.unique(classes):
                m = classes == c
                self._observe_class(int(c), alpha[m], exit_idx[m])

    def _observe_class(self, c: int, a, e) -> None:
        band = self._band_batch(c, a)
        n_hit = int(np.sum(band == e))
        self.hits += n_hit
        self.misses += len(e) - n_hit
        if self.n_exits > 1:
            s = np.arange(self.n_exits - 1)
            y = (e[:, None] <= s[None, :]).astype(np.float64)
            # minibatches of 8: p refreshes every chunk, so the update
            # keeps the per-sample loop's self-limiting dynamics (the
            # gradient vanishes as p saturates toward y) at ~1/8 the
            # host cost
            for i in range(0, len(e), 8):
                ac, yc = a[i:i + 8], y[i:i + 8]
                p = _sigmoid(self.w0[c] + self.w1[c] * ac[:, None])
                grad = p - yc
                self.w0[c] -= self.lr * grad.sum(axis=0)
                self.w1[c] -= self.lr * (grad * ac[:, None]).sum(axis=0)
        mean_onehot = np.bincount(e, minlength=self.n_exits) / len(e)
        if self.n_obs[c]:
            d = self.ema_decay ** len(e)
            self.hist[c] = d * self.hist[c] + (1.0 - d) * mean_onehot
        else:
            self.hist[c] = mean_onehot
        self.n_obs[c] += len(e)

    def _band_batch(self, c: int, a) -> np.ndarray:
        """Vectorized :meth:`_band_locked` over one class's batch (one
        prior fetch for the whole batch)."""
        if self.n_exits == 1:
            depth = np.zeros_like(a)
        else:
            p_le = _sigmoid(self.w0[c] + self.w1[c] * a[:, None])
            depth = np.sum(1.0 - p_le, axis=1)
            prior = self._prior_depth(c)
            if prior is not None:
                w = self.n_obs[c] / (self.n_obs[c] + self.prior_strength)
                depth = w * depth + (1.0 - w) * prior
        return np.clip(np.round(depth), 0,
                       self.n_exits - 1).astype(np.int64)

    # -- inference --------------------------------------------------------
    def _depth_locked(self, alpha: float, c: int) -> float:
        """Expected exit stage: E[depth] = sum_s P(exit > s), blended
        toward the planner prior while the class head is cold."""
        if self.n_exits == 1:
            return 0.0
        p_le = _sigmoid(self.w0[c] + self.w1[c] * alpha)
        depth = float(np.sum(1.0 - p_le))
        prior = self._prior_depth(c)
        if prior is None:
            return depth
        n = float(self.n_obs[c])
        w = n / (n + self.prior_strength)
        return w * depth + (1.0 - w) * prior

    def _prior_depth(self, c: int):
        if self._priors is None:
            return None
        pri = self._priors()
        if isinstance(pri, dict):
            pri = pri.get(c)
        elif pri is not None and c < len(pri):
            pri = pri[c]
        else:
            pri = None
        return None if pri is None else float(pri)

    def _band_locked(self, alpha: float, c: int) -> int:
        d = self._depth_locked(alpha, c)
        return int(np.clip(round(d), 0, self.n_exits - 1))

    def predict_depth(self, alpha: float) -> float:
        """Predicted (fractional) exit stage for one Eq. 8 difficulty."""
        a = float(np.mean(np.asarray(alpha, np.float64)))
        c = int(DIFF.difficulty_class(a, self.edges))
        with self._lock:
            return self._depth_locked(a, c)

    def depth_band(self, alpha: float) -> int:
        """Predicted exit stage rounded to a lane id — the scheduler
        appends this to the difficulty-class lane key so a flushed
        bucket's rows exit together.

        The band is STICKY per class (it only switches when the
        predicted depth moves ``band_hysteresis`` past the rounding
        boundary): a depth hovering at a boundary would otherwise keep
        two live lanes for one class, and the resulting consolidation
        fragmentation costs more than the band distinction is worth."""
        return self.admit_info(alpha)[1]

    def admit_info(self, alpha: float) -> tuple:
        """``(predicted depth, sticky lane band)`` under ONE lock and
        one prior fetch — the admission fast path.  Calling
        :meth:`predict_depth` then :meth:`depth_band` separately
        computes the same head twice; admission rides every submit, so
        the combined call is what the scheduler uses."""
        a = float(np.mean(np.asarray(alpha, np.float64)))
        c = int(DIFF.difficulty_class(a, self.edges))
        with self._lock:
            d = self._depth_locked(a, c)
            cur = self._band_cache.get(c)
            if cur is not None \
                    and abs(d - cur) <= 0.5 + self.band_hysteresis:
                return d, cur
            band = int(np.clip(round(d), 0, self.n_exits - 1))
            self._band_cache[c] = band
            return d, band

    def min_exit(self, engine, alpha_lo: float = 0.0) -> int:
        """The per-bucket head-skip bound handed to ``engine.infer`` /
        ``engine.generate``.

        conservative: exactly the engine's sound Eq. 19 rule-out bound
        (bit-identical decisions).  aggressive: additionally skip gates
        the class histogram says fire with probability < ``eps``
        (requires ``min_obs`` observations; may change decisions)."""
        m = int(engine.min_exit_bound(alpha_lo))
        if self.mode == "aggressive":
            c = int(DIFF.difficulty_class(float(alpha_lo), self.edges))
            with self._lock:
                if self.n_obs[c] >= self.min_obs:
                    cum = np.cumsum(
                        self.hist[c] / max(self.hist[c].sum(), 1e-9))
                    learned = 0
                    for s in range(self.n_exits - 1):
                        if cum[s] < self.eps:
                            learned = s + 1
                        else:
                            break
                    m = max(m, learned)
        with self._lock:
            self.skip_calls += 1
            self.skip_stages += m
        return m

    # -- snapshot (serving-state checkpoint) ------------------------------
    def state_dict(self) -> dict:
        """Learned heads + histograms, JSON-serializable (lists, not
        arrays): a restarted server resumes its trained predictor."""
        with self._lock:
            return {"w0": self.w0.tolist(), "w1": self.w1.tolist(),
                    "hist": self.hist.tolist(),
                    "n_obs": self.n_obs.tolist(),
                    "hits": self.hits, "misses": self.misses,
                    "skip_calls": self.skip_calls,
                    "skip_stages": self.skip_stages,
                    "band_cache": {str(k): v for k, v
                                   in self._band_cache.items()}}

    def load_state_dict(self, state: dict) -> None:
        w0 = np.asarray(state["w0"], np.float64)
        if w0.shape != self.w0.shape:
            raise ValueError(
                f"snapshot head shape {w0.shape} != {self.w0.shape}")
        with self._lock:
            self.w0 = w0
            self.w1 = np.asarray(state["w1"], np.float64)
            self.hist = np.asarray(state["hist"], np.float64)
            self.n_obs = np.asarray(state["n_obs"], np.int64)
            self.hits = int(state["hits"])
            self.misses = int(state["misses"])
            self.skip_calls = int(state["skip_calls"])
            self.skip_stages = int(state["skip_stages"])
            self._band_cache = {int(k): int(v) for k, v
                                in state["band_cache"].items()}

    # -- introspection ------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            n = self.hits + self.misses
            return {
                "mode": self.mode,
                "observed": int(self.n_obs.sum()),
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": self.hits / n if n else None,
                "skip_calls": self.skip_calls,
                "skip_stages": self.skip_stages,
                "per_class_obs": [int(v) for v in self.n_obs],
            }
