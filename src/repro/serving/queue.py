"""RequestQueue — thread-safe, lane-keyed admission queue with
backpressure.

Lanes are FIFO deques keyed by whatever the scheduler packs together
(difficulty class for classifier serving, ``(seq_len, n_new)`` for LM
decode).  Keeping lanes cost-homogeneous is the difficulty-aware part
of the design: a bucket flushed from one lane contains requests with
similar predicted exit depth, so one hard straggler never drags a
bucket of easy requests through every stage.

Backpressure triggers when a lane holds ``max_queue`` requests:

* ``shed``   — evict the lowest-priority request (FIFO-newest among
  ties) to admit the new one; if the new request itself has the lowest
  priority, IT is shed.  Eviction resolves the victim's future with
  :class:`RequestShed`.
* ``reject`` — refuse the new request (:class:`RequestRejected` on its
  future); queued work is never dropped.
* ``degrade-alpha`` — handled upstream by the admission planner (the
  request is admitted with a scaled-down difficulty so it exits
  earlier and costs less); the queue falls back to ``shed`` if the
  degraded lane is also full.
"""
from __future__ import annotations

import threading
from collections import deque

from repro.serving.request import Request, RequestRejected, RequestShed

POLICIES = ("shed", "reject", "degrade-alpha")


class RequestQueue:
    def __init__(self, max_queue: int = 256, policy: str = "shed"):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; known: {POLICIES}")
        self.max_queue = max_queue
        self.policy = policy
        self._lanes: dict = {}
        self._lock = threading.Lock()
        self.shed = 0
        self.rejected = 0
        self.starved = 0    # pop_next held capacity for a senior head

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def push(self, req: Request) -> str:
        """Enqueue under the backpressure policy.  Returns the action
        taken: "queued" | "shed" (a victim was evicted or the new
        request itself was) | "rejected"."""
        with self._lock:
            lane = self._lanes.setdefault(req.lane, deque())
            if len(lane) < self.max_queue:
                lane.append(req)
                return "queued"
            if self.policy == "reject":
                self.rejected += 1
                req.fail(RequestRejected(
                    f"lane {req.lane!r} at its limit of {self.max_queue}"))
                return "rejected"
            # shed (also the fallback for degrade-alpha): lowest
            # priority goes first, FIFO-newest among equals.
            victim = min(lane, key=lambda r: (r.priority, -r.rid))
            if req.priority <= victim.priority:
                victim = req          # the newcomer is the least urgent
            else:
                lane.remove(victim)
                lane.append(req)
            self.shed += 1
            victim.fail(RequestShed(
                f"shed from lane {victim.lane!r} "
                f"(priority {victim.priority})"))
            return "shed"

    def requeue(self, req: Request) -> str:
        """Re-admit an in-flight continuation (a cascade escalation)
        BYPASSING the backpressure policy: the sample already passed
        admission and has paid real compute in a smaller member —
        shedding it now would waste that work AND break the invariant
        that an admitted request eventually resolves.  Escalation volume
        is bounded by what admission let in, so this cannot grow a lane
        unboundedly."""
        with self._lock:
            self._lanes.setdefault(req.lane, deque()).append(req)
        return "queued"

    # ------------------------------------------------------------------
    # lane views (all O(lane) worst case; lanes are short)
    # ------------------------------------------------------------------
    def keys(self) -> list:
        with self._lock:
            return [k for k, lane in self._lanes.items() if lane]

    def depth(self, key) -> int:
        with self._lock:
            return len(self._lanes.get(key, ()))

    def samples(self, key) -> int:
        with self._lock:
            return sum(r.n for r in self._lanes.get(key, ()))

    @property
    def empty(self) -> bool:
        with self._lock:
            return not any(self._lanes.values())

    def oldest_submit(self, key) -> float | None:
        with self._lock:
            lane = self._lanes.get(key)
            return lane[0].t_submit if lane else None

    def oldest_undeadlined(self, key) -> float | None:
        """Submit time of the oldest BEST-EFFORT (deadline-less) request
        — the hold-flush clock.  Deadline'd requests are governed by
        deadline pressure instead, so they can wait for consolidation
        as long as their SLO allows."""
        with self._lock:
            lane = self._lanes.get(key) or ()
            ts = [r.t_submit for r in lane if r.deadline_s is None]
            return min(ts) if ts else None

    def earliest_deadline(self, key) -> float | None:
        with self._lock:
            lane = self._lanes.get(key) or ()
            ds = [r.deadline_s for r in lane if r.deadline_s is not None]
            return min(ds) if ds else None

    def pop_next(self, fits, *, reserve_after_s: float = 0.05,
                 now: float | None = None,
                 prefer=None) -> Request | None:
        """Pop the most urgent lane head that ``fits`` — the continuous
        slot-refill primitive (no bucket consolidation; one request at
        a time as slots free up).

        Lane heads are ranked (priority desc, submit time asc, rid
        asc).  If the MOST urgent head does not fit right now and has
        already waited ``reserve_after_s``, returns None WITHOUT
        considering junior heads: freed capacity is reserved for the
        starved senior instead of an endless stream of smaller juniors
        backfilling around it (the anti-starvation guarantee the
        continuous session's edge test pins).

        ``prefer`` (optional, ``Request -> float``) breaks ties among
        SAME-URGENCY fitting heads (equal priority, submit times within
        ``reserve_after_s``): the depth-aware refill hook — the LM
        continuous session scores candidates by how well their
        predicted exit depth matches the slot pool's current stage mix.
        Urgency order is never violated: a strictly more urgent fitting
        head still wins regardless of score."""
        with self._lock:
            heads = [lane[0] for lane in self._lanes.values() if lane]
            heads.sort(key=lambda r: (-r.priority, r.t_submit, r.rid))
            best = None
            for r in heads:
                if fits(r):
                    if prefer is None:
                        self._lanes[r.lane].popleft()
                        return r
                    if best is None:
                        best = r
                    elif (r.priority == best.priority
                            and r.t_submit - best.t_submit
                            <= reserve_after_s):
                        if prefer(r) > prefer(best):
                            best = r
                    else:
                        break   # strictly less urgent: stop scanning
                    continue
                if best is None and now is not None \
                        and now - r.t_submit >= reserve_after_s:
                    self.starved += 1
                    return None     # hold capacity for this head
            if best is not None:
                self._lanes[best.lane].popleft()
            return best

    # ------------------------------------------------------------------
    # flush
    # ------------------------------------------------------------------
    def take(self, key, max_samples: int, bucket_key, *,
             min_fill: float = 0.5, force: bool = False) -> list[Request]:
        """Pop a FIFO run of whole requests totalling ≤ ``max_samples``.

        ``bucket_key(n)`` maps a sample count to its padded compiled
        shape.  Unless ``force`` (deadline pressure), the run stops
        before a request that would grow the padded shape into the next
        bucket while filling it below ``min_fill`` — flushing now at
        the smaller bucket beats padding waste at the larger one."""
        with self._lock:
            lane = self._lanes.get(key)
            out: list[Request] = []
            total = 0
            while lane:
                nxt = lane[0]
                new_total = total + nxt.n
                if new_total > max_samples:
                    if not out:
                        # oversized single request: dispatch it alone
                        # (the engine chunk-splits internally)
                        out.append(lane.popleft())
                    break
                if out and not force:
                    b_old, b_new = bucket_key(total), bucket_key(new_total)
                    if b_new > b_old and new_total / b_new < min_fill:
                        break
                out.append(lane.popleft())
                total = new_total
            return out

    def drain(self) -> list[Request]:
        """Pop everything (close/shutdown path), FIFO by admission id."""
        with self._lock:
            reqs = [r for lane in self._lanes.values() for r in lane]
            self._lanes.clear()
        return sorted(reqs, key=lambda r: r.rid)
