"""Request objects and completion futures for the async scheduler.

A ``Request`` is one caller-submitted sample batch travelling through
the scheduler: admitted (difficulty estimated, cost predicted), queued
in a difficulty-class lane, flushed as part of a consolidated bucket,
and finally resolved through its ``concurrent.futures.Future``.

Backpressure outcomes surface as exceptions ON THE FUTURE — submit
itself never raises for load reasons, so producers keep a uniform
``submit(...).result()`` call shape:

* :class:`RequestShed`     — evicted by a higher-priority arrival
  (``policy="shed"``).
* :class:`RequestRejected` — refused at admission because the lane was
  full (``policy="reject"``).
"""
from __future__ import annotations

import dataclasses
from concurrent.futures import Future

import numpy as np


class RequestShed(RuntimeError):
    """Queued request evicted to make room for higher-priority work."""


class RequestRejected(RuntimeError):
    """Request refused at admission (lane over its queue limit)."""


@dataclasses.dataclass
class Request:
    """One in-flight request (a sample batch + its admission metadata).

    rid:            monotonically increasing id (FIFO tiebreaker)
    x:              (n, ...) the request's samples
    n:              number of samples
    alpha:          (n,) Eq. 8 difficulty, estimated once at admission
    lane:           scheduler lane key (difficulty class, or (S, n_new)
                    for LM decode)
    predicted_cost: expected normalized MACs/sample (admission planner)
    priority:       larger = more important; sheds last
    t_submit:       scheduler-clock seconds at submit
    deadline_s:     absolute scheduler-clock deadline (None = best effort)
    future:         resolves to the per-request result dict
    """
    rid: int
    x: np.ndarray
    n: int
    alpha: np.ndarray
    lane: object
    predicted_cost: float
    priority: int
    t_submit: float
    deadline_s: float | None
    future: Future
    payload: dict = dataclasses.field(default_factory=dict)

    def fail(self, exc: Exception) -> None:
        if not self.future.done():
            self.future.set_exception(exc)

    def resolve(self, result: dict) -> None:
        if not self.future.done():
            self.future.set_result(result)
