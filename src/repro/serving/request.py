"""Request objects and completion futures for the async scheduler.

A ``Request`` is one caller-submitted sample batch travelling through
the scheduler: admitted (difficulty estimated, cost predicted), queued
in a difficulty-class lane, flushed as part of a consolidated bucket,
and finally resolved through its ``concurrent.futures.Future``.

Backpressure outcomes surface as exceptions ON THE FUTURE — submit
itself never raises for load reasons, so producers keep a uniform
``submit(...).result()`` call shape:

* :class:`RequestShed`     — evicted by a higher-priority arrival
  (``policy="shed"``).
* :class:`RequestRejected` — refused at admission because the lane was
  full (``policy="reject"``).
"""
from __future__ import annotations

import dataclasses
from concurrent.futures import Future

import numpy as np


class RequestShed(RuntimeError):
    """Queued request evicted to make room for higher-priority work."""


class RequestRejected(RuntimeError):
    """Request refused at admission (lane over its queue limit)."""


class DispatchError(RuntimeError):
    """Structured failure of one dispatched/materialized bucket.

    Futures fail with THIS (never a raw engine exception): callers see
    which stage broke (``dispatch`` | ``complete`` | ``step``), which
    lane and rids were affected, and the underlying ``cause`` — enough
    to tell an injected fault from a malformed input without scraping
    tracebacks.  Output-validation quarantine failures surface here too
    (stage ``complete``, cause :class:`InvalidEngineOutput`).
    """

    def __init__(self, stage: str, lane, rids, cause: BaseException):
        self.stage = stage
        self.lane = lane
        self.rids = list(rids)
        self.cause = cause
        super().__init__(
            f"bucket {stage} failed (lane={lane!r}, "
            f"rids={self.rids[:8]}): {type(cause).__name__}: {cause}")
        self.__cause__ = cause


class InvalidEngineOutput(RuntimeError):
    """An engine call returned values that fail validation (non-finite
    confidence or out-of-range exit stage) — quarantined instead of
    being folded into telemetry."""


@dataclasses.dataclass
class Request:
    """One in-flight request (a sample batch + its admission metadata).

    rid:            monotonically increasing id (FIFO tiebreaker)
    x:              (n, ...) the request's samples
    n:              number of samples
    alpha:          (n,) Eq. 8 difficulty, estimated once at admission
    lane:           scheduler lane key (difficulty class, or (S, n_new)
                    for LM decode)
    predicted_cost: expected normalized MACs/sample (admission planner)
    priority:       larger = more important; sheds last
    t_submit:       scheduler-clock seconds at submit
    deadline_s:     absolute scheduler-clock deadline (None = best effort)
    future:         resolves to the per-request result dict
    """
    rid: int
    x: np.ndarray
    n: int
    alpha: np.ndarray
    lane: object
    predicted_cost: float
    priority: int
    t_submit: float
    deadline_s: float | None
    future: Future
    payload: dict = dataclasses.field(default_factory=dict)

    def fail(self, exc: Exception) -> None:
        if not self.future.done():
            self.future.set_exception(exc)

    def resolve(self, result: dict) -> None:
        if not self.future.done():
            self.future.set_result(result)
