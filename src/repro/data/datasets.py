"""Procedural synthetic datasets (offline stand-ins for MNIST / CIFAR-10).

Everything is *stateless-seeded*: sample i of dataset d is a pure function
of (d.seed, i) — restarting a job replays identical data (fault-tolerance
substrate), and workers can generate any shard without coordination.

``synth-mnist``  — 28×28×1 stroke-glyph digits (bitmap font, random shift/
                   shear/thickness/noise).
``synth-cifar`` — 32×32×3 class-conditioned texture+shape composites with
                   *controlled per-class difficulty* (classes differ in
                   clutter/noise), which is the property DART exploits —
                   paper Fig. 2's easy (car) / medium (cat) / hard (ship)
                   classes map to low/mid/high clutter here.
``synth-latents``— class-conditioned latent blobs for DiT training.
``synth-tokens`` — structured token sequences (pattern grammar) for LM
                   training; per-sequence entropy varies → difficulty.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# 5x7 bitmap font for digits 0-9 (rows of 5 bits, top to bottom)
_DIGIT_FONT = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00110", "01000", "10000", "11111"],
    3: ["01110", "10001", "00001", "00110", "00001", "10001", "01110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["01110", "10000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00001", "01110"],
}
_FONT = np.zeros((10, 7, 5), np.float32)
for d, rows in _DIGIT_FONT.items():
    for r, bits in enumerate(rows):
        for c, ch in enumerate(bits):
            _FONT[d, r, c] = float(ch == "1")


@dataclasses.dataclass(frozen=True)
class DatasetConfig:
    name: str = "synth-cifar"
    n_classes: int = 10
    img_res: int = 32
    channels: int = 3
    seed: int = 0
    n_train: int = 50_000
    n_eval: int = 10_000
    # per-class difficulty profile (clutter/noise scale per class);
    # class 1 ("car") easy, 3 ("cat") medium, 8 ("ship") hard — Fig. 2.
    class_noise: tuple = (0.16, 0.05, 0.14, 0.12, 0.16, 0.18, 0.13, 0.15,
                          0.26, 0.2)


def _rng_for(cfg: DatasetConfig, index: int, split: str):
    return np.random.RandomState(
        (hash((cfg.seed, split)) % (2**31 - 1)) ^ (index * 2654435761 % (2**31 - 1)))


def synth_mnist_sample(cfg: DatasetConfig, index: int, split="train"):
    rs = _rng_for(cfg, index, split)
    label = index % cfg.n_classes
    res = cfg.img_res
    glyph = _FONT[label]
    scale = res // 9
    up = np.kron(glyph, np.ones((scale * 1, scale * 1), np.float32))
    thick = rs.randint(0, 2)
    if thick:  # dilate strokes
        up = np.maximum(up, np.roll(up, 1, axis=1))
    img = np.zeros((res, res), np.float32)
    gy, gx = up.shape
    oy = (res - gy) // 2 + rs.randint(-2, 3)
    ox = (res - gx) // 2 + rs.randint(-2, 3)
    oy, ox = np.clip(oy, 0, res - gy), np.clip(ox, 0, res - gx)
    img[oy:oy + gy, ox:ox + gx] = up
    shear = rs.uniform(-0.2, 0.2)
    rows = np.arange(res)
    shift = (shear * (rows - res / 2)).astype(int)
    img = np.stack([np.roll(img[r], shift[r]) for r in range(res)])
    noise = rs.uniform(0.02, 0.16)
    img = np.clip(img * rs.uniform(0.7, 1.0)
                  + noise * rs.rand(res, res), 0, 1)
    return img[:, :, None].astype(np.float32), label


def synth_cifar_sample(cfg: DatasetConfig, index: int, split="train"):
    rs = _rng_for(cfg, index, split)
    label = index % cfg.n_classes
    res = cfg.img_res
    yy, xx = np.mgrid[0:res, 0:res] / res

    # class-specific texture: oriented sinusoid (freq/angle keyed by class)
    freq = 2 + (label % 5) * 2
    angle = (label * 36) * np.pi / 180
    tex = 0.5 + 0.5 * np.sin(2 * np.pi * freq
                             * (xx * np.cos(angle) + yy * np.sin(angle)))
    # class-specific shape mask
    cy, cx = 0.5 + rs.uniform(-0.15, 0.15, 2)
    r = rs.uniform(0.2, 0.35)
    kind = label % 3
    if kind == 0:       # disc
        mask = ((yy - cy) ** 2 + (xx - cx) ** 2) < r ** 2
    elif kind == 1:     # square
        mask = (np.abs(yy - cy) < r) & (np.abs(xx - cx) < r)
    else:               # triangle
        mask = (yy - cy + r > 0) & (np.abs(xx - cx) < (yy - cy + r) / 2)
    # class palette
    base = np.array([((label * 37) % 255) / 255.0,
                     ((label * 91 + 60) % 255) / 255.0,
                     ((label * 151 + 120) % 255) / 255.0])
    img = np.zeros((res, res, 3), np.float32)
    bg = rs.uniform(0.2, 0.8, 3)
    img[:] = bg * (0.6 + 0.4 * tex)[:, :, None]
    img[mask] = base * (0.5 + 0.5 * tex[mask])[:, None]
    # controlled difficulty: class-dependent clutter + per-sample jitter
    noise = cfg.class_noise[label % len(cfg.class_noise)] \
        * rs.uniform(0.5, 1.5)
    n_blobs = rs.poisson(noise * 12)
    for _ in range(n_blobs):
        by, bx = rs.randint(0, res, 2)
        br = rs.randint(2, 6)
        col = rs.rand(3)
        ys, xs = np.mgrid[max(0, by - br):min(res, by + br),
                          max(0, bx - br):min(res, bx + br)]
        img[ys, xs] = 0.5 * img[ys, xs] + 0.5 * col
    img = np.clip(img + noise * rs.randn(res, res, 3) * 0.5, 0, 1)
    return img.astype(np.float32), label


def synth_latents_sample(cfg: DatasetConfig, index: int, split="train"):
    """Class-conditioned latent (res/8, res/8, 4) for DiT."""
    rs = _rng_for(cfg, index, split)
    label = index % cfg.n_classes
    res = cfg.img_res // 8
    yy, xx = np.mgrid[0:res, 0:res] / res
    cy, cx = 0.3 + 0.4 * ((label % 3) / 2), 0.3 + 0.4 * ((label // 3) / 3)
    blob = np.exp(-(((yy - cy) ** 2 + (xx - cx) ** 2) / 0.02))
    lat = np.stack([blob * np.cos(label), blob * np.sin(label),
                    1 - blob, 0.5 * blob], axis=-1)
    lat = lat + 0.1 * rs.randn(res, res, 4)
    return lat.astype(np.float32), label


def synth_tokens_sample(cfg: DatasetConfig, index: int, seq_len: int,
                        vocab: int, split="train"):
    """Structured sequences: repeated motif grammar with class-dependent
    entropy (harder classes = noisier repetitions)."""
    rs = _rng_for(cfg, index, split)
    label = index % cfg.n_classes
    motif_len = 4 + label % 5
    motif = rs.randint(2, vocab, motif_len)
    noise_p = 0.05 + 0.03 * label
    seq = np.tile(motif, seq_len // motif_len + 1)[:seq_len].copy()
    flips = rs.rand(seq_len) < noise_p
    seq[flips] = rs.randint(2, vocab, flips.sum())
    seq[0] = label % vocab  # class marker token
    return seq.astype(np.int32), label


def make_batch(cfg: DatasetConfig, indices, split="train", kind=None,
               seq_len=None, vocab=None):
    """Materialize a batch (host-side numpy)."""
    kind = kind or ("mnist" if cfg.name == "synth-mnist" else "cifar")
    if kind == "tokens":
        xs, ys = zip(*[synth_tokens_sample(cfg, i, seq_len, vocab, split)
                       for i in indices])
    elif kind == "latents":
        xs, ys = zip(*[synth_latents_sample(cfg, i, split) for i in indices])
    elif kind == "mnist":
        xs, ys = zip(*[synth_mnist_sample(cfg, i, split) for i in indices])
    else:
        xs, ys = zip(*[synth_cifar_sample(cfg, i, split) for i in indices])
    return np.stack(xs), np.asarray(ys, np.int32)


MNIST = DatasetConfig(name="synth-mnist", img_res=28, channels=1)
CIFAR = DatasetConfig(name="synth-cifar", img_res=32, channels=3)
