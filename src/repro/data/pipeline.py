"""Sharded, prefetching data pipeline.

Design (DESIGN.md §4.6):
* **stateless seeding** — the batch for step t is a pure function of
  (dataset seed, t); restart-from-checkpoint replays identical batches and
  elastic resizes only re-partition indices, never skip/duplicate them.
* **host prefetch** — a daemon thread keeps ``prefetch`` batches ahead;
  generation (numpy) overlaps with device compute.
* **sharding** — batches are placed with a batch-sharded NamedSharding
  when a mesh is given (each host would generate only its shard on a real
  multi-host pod; here one host generates all and jax.device_put scatters).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.data.datasets import DatasetConfig, make_batch
from repro.parallel.sharding import resolve_spec, LM_RULES


def batch_indices(cfg: DatasetConfig, step: int, batch_size: int,
                  split="train") -> np.ndarray:
    """Deterministic shuffled epoch order, stateless in ``step``."""
    n = cfg.n_train if split == "train" else cfg.n_eval
    epoch = (step * batch_size) // n
    rs = np.random.RandomState((cfg.seed + 17 * epoch) % (2**31 - 1))
    perm = rs.permutation(n)
    start = (step * batch_size) % n
    idx = perm[start:start + batch_size]
    if len(idx) < batch_size:                      # wrap into next epoch
        rs2 = np.random.RandomState((cfg.seed + 17 * (epoch + 1)) % (2**31 - 1))
        idx = np.concatenate([idx, rs2.permutation(n)[:batch_size - len(idx)]])
    return idx


class DataPipeline:
    def __init__(self, cfg: DatasetConfig, batch_size: int, *, kind=None,
                 split="train", seq_len=None, vocab=None, mesh=None,
                 prefetch: int = 2, start_step: int = 0):
        self.cfg = cfg
        self.batch_size = batch_size
        self.kind = kind
        self.split = split
        self.seq_len = seq_len
        self.vocab = vocab
        self.mesh = mesh
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _make(self, step: int):
        idx = batch_indices(self.cfg, step, self.batch_size, self.split)
        x, y = make_batch(self.cfg, idx, self.split, self.kind,
                          self.seq_len, self.vocab)
        return x, y

    def _producer(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self._q.put((s, self._make(s)), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def _place(self, arr):
        if self.mesh is None:
            return jax.numpy.asarray(arr)
        spec = resolve_spec(arr.shape, ("batch",) + (None,) * (arr.ndim - 1),
                            LM_RULES, self.mesh)
        return jax.device_put(arr, NamedSharding(self.mesh, spec))

    def __next__(self):
        step, (x, y) = self._q.get()
        self.step = step + 1
        return step, self._place(x), self._place(y)

    def __iter__(self) -> Iterator:
        return self

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


def eval_batches(cfg: DatasetConfig, batch_size: int, *, kind=None,
                 n: int | None = None, seq_len=None, vocab=None):
    """Sequential eval split iterator (no prefetch thread)."""
    n = n or cfg.n_eval
    for start in range(0, n, batch_size):
        idx = np.arange(start, min(start + batch_size, n))
        yield make_batch(cfg, idx, "eval", kind, seq_len, vocab)
