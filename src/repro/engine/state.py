"""EngineState — the complete DART serving state as ONE pytree.

Consolidates what used to live in three places (`DartParams` on the
server object, the raw `core.adaptive.init_state` dict, and ad-hoc
`ServerStats` counters) into a single registered pytree so the full
serving state can be jitted over, checkpointed through
``repro.checkpoint`` (flatten → leaf files → unflatten), and sharded as
one object.

Every field is a leaf (jnp array); scalar knobs like ``beta_diff`` are
stored as 0-d arrays so the state round-trips through
``checkpoint.save``/``restore`` without special-casing.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import adaptive as AD
from repro.core.routing import DartParams

_FIELDS = ("tau", "coef", "beta_diff", "beta_opt", "adaptive",
           "served", "exit_counts", "total_macs", "since_update")


@dataclasses.dataclass
class EngineState:
    """Threshold parameters + §II.C sliding-window state + serving counters.

    tau / coef:   (E-1,) Eq. 19 base thresholds and coefficients
    beta_diff:    () difficulty sensitivity (Eq. 19)
    beta_opt:     () accuracy/cost trade-off (Eq. 10)
    adaptive:     the raw ``core.adaptive.init_state`` dict (ring buffers,
                  per-class coefficients, UCB1 counters)
    served:       () int32 — total samples served
    exit_counts:  (E,) int32 — per-exit routed counts
    total_macs:   () float32 — cumulative MACs actually spent
    since_update: () int32 — samples since the last periodic update
    """
    tau: jnp.ndarray
    coef: jnp.ndarray
    beta_diff: jnp.ndarray
    beta_opt: jnp.ndarray
    adaptive: dict
    served: jnp.ndarray
    exit_counts: jnp.ndarray
    total_macs: jnp.ndarray
    since_update: jnp.ndarray

    # -- pytree protocol ------------------------------------------------
    def tree_flatten(self):
        return tuple(getattr(self, f) for f in _FIELDS), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(**dict(zip(_FIELDS, children)))

    # -- construction ---------------------------------------------------
    @classmethod
    def create(cls, n_exits: int, acfg: AD.AdaptiveConfig,
               dart: DartParams | None = None) -> "EngineState":
        dart = dart or DartParams.default(n_exits)
        return cls(
            tau=jnp.asarray(dart.tau, jnp.float32),
            coef=jnp.asarray(dart.coef, jnp.float32),
            beta_diff=jnp.asarray(dart.beta_diff, jnp.float32),
            beta_opt=jnp.asarray(dart.beta_opt, jnp.float32),
            adaptive=AD.init_state(acfg),
            served=jnp.zeros((), jnp.int32),
            exit_counts=jnp.zeros((n_exits,), jnp.int32),
            total_macs=jnp.zeros((), jnp.float32),
            since_update=jnp.zeros((), jnp.int32),
        )

    # -- views ----------------------------------------------------------
    @property
    def dart(self) -> DartParams:
        """The routing-parameter view (what `core.routing` consumes)."""
        return DartParams(tau=self.tau, coef=self.coef,
                          beta_diff=float(self.beta_diff),
                          beta_opt=float(self.beta_opt))

    def with_policy(self, tau=None, coef=None, beta_diff=None,
                    beta_opt=None) -> "EngineState":
        """Functional update of the threshold parameters."""
        rep = {}
        if tau is not None:
            rep["tau"] = jnp.asarray(tau, jnp.float32)
        if coef is not None:
            rep["coef"] = jnp.asarray(coef, jnp.float32)
        if beta_diff is not None:
            rep["beta_diff"] = jnp.asarray(beta_diff, jnp.float32)
        if beta_opt is not None:
            rep["beta_opt"] = jnp.asarray(beta_opt, jnp.float32)
        return dataclasses.replace(self, **rep)


jax.tree_util.register_pytree_node(
    EngineState,
    lambda s: s.tree_flatten(),
    EngineState.tree_unflatten)
