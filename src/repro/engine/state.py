"""EngineState — the complete DART serving state as ONE pytree.

Consolidates what used to live in three places (`DartParams` on the
server object, the raw `core.adaptive.init_state` dict, and ad-hoc
`ServerStats` counters) into a single registered pytree so the full
serving state can be jitted over, checkpointed through
``repro.checkpoint`` (flatten → leaf files → unflatten), and sharded as
one object.

Every field is a leaf (jnp array); scalar knobs like ``beta_diff`` are
stored as 0-d arrays so the state round-trips through
``checkpoint.save``/``restore`` without special-casing.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adaptive as AD
from repro.core.routing import DartParams

_FIELDS = ("tau", "coef", "beta_diff", "beta_opt", "adaptive",
           "served", "exit_counts", "total_macs", "since_update",
           "lat_ms", "lat_ptr", "lat_count", "deadline_miss",
           "slot_steps", "decode_steps", "pages_peak",
           "quote_ms_sum", "quote_err_ms_sum", "quote_count")

#: The pre-latency-telemetry field set.  New telemetry leaves are only
#: ever APPENDED to ``_FIELDS``, so every older checkpoint is a strict
#: prefix of the current flatten order — ``restore_with_migration``
#: walks ``_LAYOUT_PREFIXES`` newest-first (restored prefix fields +
#: fresh values for the rest).
LEGACY_FIELDS = _FIELDS[:-10]

#: Known historical flatten orders, newest first: the continuous-
#: batching era (PRs 7-8, before the admission-quote counters), the
#: latency-telemetry era (PRs 4-6, before the slot/page counters) and
#: the pre-latency era.  Trying the longer prefix first is what keeps a
#: latency-era checkpoint from silently dropping its latency window.
_LAYOUT_PREFIXES = (_FIELDS[:-3], _FIELDS[:-6], LEGACY_FIELDS)

#: Default size of the per-request latency ring buffer (requests, not
#: samples — sized for percentile stability, not history).
LAT_WINDOW = 2048


@dataclasses.dataclass
class EngineState:
    """Threshold parameters + §II.C sliding-window state + serving counters.

    tau / coef:   (E-1,) Eq. 19 base thresholds and coefficients
    beta_diff:    () difficulty sensitivity (Eq. 19)
    beta_opt:     () accuracy/cost trade-off (Eq. 10)
    adaptive:     the raw ``core.adaptive.init_state`` dict (ring buffers,
                  per-class coefficients, UCB1 counters)
    served:       () int32 — total samples served
    exit_counts:  (E,) int32 — per-exit routed counts
    total_macs:   () float32 — cumulative MACs actually spent
    since_update: () int32 — samples since the last periodic update
    lat_ms:       (W,) float32 — per-REQUEST latency ring buffer, written
                  host-side by the ``repro.serving`` scheduler
    lat_ptr:      () int32 — latency ring write cursor
    lat_count:    () int32 — requests completed (lifetime)
    deadline_miss: () int32 — requests completed past their deadline
    slot_steps:   () int32 — continuous batching: occupied slot-steps
                  (sum over decode steps of active slots; folded on
                  device inside the compiled step)
    decode_steps: () int32 — continuous batching: compiled decode-step
                  launches
    pages_peak:   () int32 — continuous batching: peak KV pages in use
                  (host-written at admission, like the latency window)
    quote_ms_sum: () float32 — sum of admission-time latency quotes for
                  completed quoted requests (host-written)
    quote_err_ms_sum: () float32 — sum of |quote - realized latency|
                  over the same requests (the SLO quote error)
    quote_count:  () int32 — completed requests that carried a quote
    """
    tau: jnp.ndarray
    coef: jnp.ndarray
    beta_diff: jnp.ndarray
    beta_opt: jnp.ndarray
    adaptive: dict
    served: jnp.ndarray
    exit_counts: jnp.ndarray
    total_macs: jnp.ndarray
    since_update: jnp.ndarray
    lat_ms: jnp.ndarray
    lat_ptr: jnp.ndarray
    lat_count: jnp.ndarray
    deadline_miss: jnp.ndarray
    slot_steps: jnp.ndarray
    decode_steps: jnp.ndarray
    pages_peak: jnp.ndarray
    quote_ms_sum: jnp.ndarray
    quote_err_ms_sum: jnp.ndarray
    quote_count: jnp.ndarray

    # -- pytree protocol ------------------------------------------------
    def tree_flatten(self):
        return tuple(getattr(self, f) for f in _FIELDS), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(**dict(zip(_FIELDS, children)))

    # -- construction ---------------------------------------------------
    @classmethod
    def create(cls, n_exits: int, acfg: AD.AdaptiveConfig,
               dart: DartParams | None = None,
               lat_window: int = LAT_WINDOW) -> "EngineState":
        dart = dart or DartParams.default(n_exits)
        return cls(
            tau=jnp.asarray(dart.tau, jnp.float32),
            coef=jnp.asarray(dart.coef, jnp.float32),
            beta_diff=jnp.asarray(dart.beta_diff, jnp.float32),
            beta_opt=jnp.asarray(dart.beta_opt, jnp.float32),
            adaptive=AD.init_state(acfg),
            served=jnp.zeros((), jnp.int32),
            exit_counts=jnp.zeros((n_exits,), jnp.int32),
            total_macs=jnp.zeros((), jnp.float32),
            since_update=jnp.zeros((), jnp.int32),
            lat_ms=jnp.zeros((lat_window,), jnp.float32),
            lat_ptr=jnp.zeros((), jnp.int32),
            lat_count=jnp.zeros((), jnp.int32),
            deadline_miss=jnp.zeros((), jnp.int32),
            slot_steps=jnp.zeros((), jnp.int32),
            decode_steps=jnp.zeros((), jnp.int32),
            pages_peak=jnp.zeros((), jnp.int32),
            quote_ms_sum=jnp.zeros((), jnp.float32),
            quote_err_ms_sum=jnp.zeros((), jnp.float32),
            quote_count=jnp.zeros((), jnp.int32),
        )

    # -- views ----------------------------------------------------------
    @property
    def dart(self) -> DartParams:
        """The routing-parameter view (what `core.routing` consumes)."""
        return DartParams(tau=self.tau, coef=self.coef,
                          beta_diff=float(self.beta_diff),
                          beta_opt=float(self.beta_opt))

    def with_policy(self, tau=None, coef=None, beta_diff=None,
                    beta_opt=None) -> "EngineState":
        """Functional update of the threshold parameters."""
        rep = {}
        if tau is not None:
            rep["tau"] = jnp.asarray(tau, jnp.float32)
        if coef is not None:
            rep["coef"] = jnp.asarray(coef, jnp.float32)
        if beta_diff is not None:
            rep["beta_diff"] = jnp.asarray(beta_diff, jnp.float32)
        if beta_opt is not None:
            rep["beta_opt"] = jnp.asarray(beta_opt, jnp.float32)
        return dataclasses.replace(self, **rep)


jax.tree_util.register_pytree_node(
    EngineState,
    lambda s: s.tree_flatten(),
    EngineState.tree_unflatten)


# ---------------------------------------------------------------------------
# Per-request serving telemetry (latency / deadline SLO)
# ---------------------------------------------------------------------------
# Unlike the per-SAMPLE counters above (folded on device inside the
# compiled step), request latency is a host-side quantity — the clock
# starts at submit() and stops when the scheduler materializes the
# result — so these two helpers run eagerly on numpy and the scheduler
# folds the outcome back into the state between steps.  The leaves stay
# replicated under sharding (one global latency window per engine).

def record_requests(state: EngineState, latencies_ms,
                    missed=None) -> EngineState:
    """Fold a batch of completed requests into the latency ring buffer.

    latencies_ms: (k,) per-request wall latency; ``missed``: optional
    (k,) bools — completed after the request's deadline."""
    lat = np.atleast_1d(np.asarray(latencies_ms, np.float32))
    k, w = lat.shape[0], state.lat_ms.shape[0]
    if k == 0:
        return state
    buf = np.asarray(state.lat_ms).copy()
    idx = (int(state.lat_ptr) + np.arange(k)) % w
    buf[idx] = lat
    n_miss = int(np.sum(missed)) if missed is not None else 0
    return dataclasses.replace(
        state,
        lat_ms=jnp.asarray(buf),
        lat_ptr=jnp.asarray((int(state.lat_ptr) + k) % w, jnp.int32),
        lat_count=state.lat_count + jnp.asarray(k, jnp.int32),
        deadline_miss=state.deadline_miss + jnp.asarray(n_miss, jnp.int32))


def record_quotes(state: EngineState, quotes_ms,
                  realized_ms) -> EngineState:
    """Fold admission-time latency quotes vs realized latency for a
    batch of completed requests (host-side, like the latency window).
    Entries with a None/NaN quote (admitted before the service EMA
    seeded) are skipped."""
    q = np.asarray([np.nan if v is None else v for v in quotes_ms],
                   np.float32)
    r = np.asarray(realized_ms, np.float32)
    ok = ~np.isnan(q)
    k = int(ok.sum())
    if k == 0:
        return state
    return dataclasses.replace(
        state,
        quote_ms_sum=state.quote_ms_sum
        + jnp.asarray(float(q[ok].sum()), jnp.float32),
        quote_err_ms_sum=state.quote_err_ms_sum
        + jnp.asarray(float(np.abs(q[ok] - r[ok]).sum()), jnp.float32),
        quote_count=state.quote_count + jnp.asarray(k, jnp.int32))


def latency_percentiles(lat_ms) -> dict:
    """p50/p95/p99/mean summary of a latency sample (ms).  The one
    implementation behind every ``stats()["requests"]["latency_ms"]``
    report (engine request_stats, LM decode sessions)."""
    lat = np.asarray(lat_ms, np.float32)
    p50, p95, p99 = np.percentile(lat, [50.0, 95.0, 99.0])
    return {"p50": float(p50), "p95": float(p95), "p99": float(p99),
            "mean": float(lat.mean())}


def request_stats(state: EngineState) -> dict:
    """Windowed latency percentiles + lifetime deadline-miss rate."""
    n = int(state.lat_count)
    miss = int(state.deadline_miss)
    out = {"requests": n, "deadline_miss": miss,
           "miss_rate": miss / max(n, 1)}
    if n:
        out["latency_ms"] = latency_percentiles(
            np.asarray(state.lat_ms)[:min(n, state.lat_ms.shape[0])])
    qn = int(state.quote_count)
    if qn:
        out["quote"] = {
            "quoted": qn,
            "mean_quote_ms": float(state.quote_ms_sum) / qn,
            "mean_abs_err_ms": float(state.quote_err_ms_sum) / qn}
    return out


# ---------------------------------------------------------------------------
# Per-replica (sharded) telemetry layout
# ---------------------------------------------------------------------------
# The sharded serving engine (repro.engine.sharded) keeps ONE EngineState
# whose *policy* leaves (tau/coef/beta_*, §II.C coefficients, UCB arms) are
# replicated across the mesh while the *telemetry* leaves (counters + the
# §II.C ring buffers) gain a leading replica dimension sharded over the
# data axis.  Each replica folds in only its local batch shard; readers
# reduce over the leading axis (`reduce_telemetry` / `merged_adaptive`).

#: EngineState fields that carry serving telemetry (everything else is
#: policy and stays replicated).
TELEMETRY_FIELDS = ("served", "exit_counts", "total_macs", "since_update",
                    "slot_steps", "decode_steps")

#: Keys of the `adaptive` dict that are per-replica ring-buffer state; the
#: remaining keys (coefficients, UCB counters, active_strategy, t) are
#: shared policy updated only by the periodic §II.C refinement.
ADAPTIVE_BUFFER_KEYS = ("buf_exit", "buf_class", "buf_conf", "buf_correct",
                        "buf_cost", "buf_valid", "ptr", "seen")


def split_adaptive(adaptive: dict) -> tuple[dict, dict]:
    """(per-replica ring buffers, shared coefficient/bandit state)."""
    bufs = {k: adaptive[k] for k in ADAPTIVE_BUFFER_KEYS}
    shared = {k: v for k, v in adaptive.items()
              if k not in ADAPTIVE_BUFFER_KEYS}
    return bufs, shared


def shard_telemetry(state: EngineState, n_replicas: int) -> EngineState:
    """Give telemetry leaves a leading (n_replicas,) axis.

    Existing counts land in replica 0 (zeros elsewhere) so totals are
    preserved under the cross-replica reduction."""
    def lead(v):
        v = jnp.asarray(v)
        return jnp.concatenate(
            [v[None], jnp.zeros((n_replicas - 1,) + v.shape, v.dtype)])
    bufs, shared = split_adaptive(state.adaptive)
    return dataclasses.replace(
        state,
        adaptive={**shared, **{k: lead(v) for k, v in bufs.items()}},
        **{f: lead(getattr(state, f)) for f in TELEMETRY_FIELDS})


def state_shardings(state: EngineState, repl, row) -> EngineState:
    """EngineState-of-NamedShardings for a telemetry-sharded state:
    policy leaves get ``repl`` (replicated), telemetry leaves (counters +
    the §II.C ring buffers, already carrying their leading replica axis
    from :func:`shard_telemetry`) get ``row`` (sharded over the data
    axis).  The one layout shared by every sharded engine
    (``ShardedDartEngine``, the sharded LM decode path)."""
    bufs, shared = split_adaptive(state.adaptive)
    return EngineState(
        tau=repl, coef=repl, beta_diff=repl, beta_opt=repl,
        adaptive={**{k: repl for k in shared}, **{k: row for k in bufs}},
        served=row, exit_counts=row, total_macs=row, since_update=row,
        slot_steps=row, decode_steps=row,
        # host-written telemetry: one global value per engine (no
        # replica axis) — the latency window, the page high-watermark
        # and the admission-quote error counters
        lat_ms=repl, lat_ptr=repl, lat_count=repl, deadline_miss=repl,
        pages_peak=repl,
        quote_ms_sum=repl, quote_err_ms_sum=repl, quote_count=repl)


def restore_with_migration(path: str, template: EngineState,
                           step: int | None = None):
    """``checkpoint.restore`` with legacy-layout migration: a checkpoint
    whose leaves are a strict prefix of the current flatten order (an
    older ``_LAYOUT_PREFIXES`` era) restores those fields and keeps the
    template's fresh values for the rest.  Prefixes are tried
    newest-first so a checkpoint restores the LONGEST layout it
    matches.  Returns ``(state, step)``.  Shared by every engine's
    ``restore_state``."""
    from repro import checkpoint as CK
    try:
        restored, step, _ = CK.restore(path, template, step)
        return restored, step
    except ValueError as e:
        if "leaf count" not in str(e):
            raise
    for i, fields in enumerate(_LAYOUT_PREFIXES):
        legacy = [getattr(template, f) for f in fields]
        try:
            leaves, step, _ = CK.restore(path, legacy, step)
        except ValueError as e:
            if "leaf count" not in str(e) or i == len(_LAYOUT_PREFIXES) - 1:
                raise
            continue
        return dataclasses.replace(
            template, **dict(zip(fields, leaves))), step
    raise AssertionError("unreachable")


def reduce_telemetry(state: EngineState) -> dict:
    """Cross-replica all-reduce of the counter fields -> global totals."""
    return {f: jnp.sum(getattr(state, f), axis=0) for f in TELEMETRY_FIELDS}


def telemetry_totals(state: EngineState, *, sharded: bool) -> dict:
    """Host-side numpy totals of the telemetry leaves — the single
    reduction behind every engine's ``stats()`` (and the join point the
    obs tracer reconciles its host-side spans against).  ``sharded``
    states reduce over the leading replica axis; eager states read the
    scalar leaves directly."""
    if sharded:
        return {k: np.asarray(v)
                for k, v in reduce_telemetry(state).items()}
    return {f: np.asarray(getattr(state, f)) for f in TELEMETRY_FIELDS}


def merged_adaptive(state: EngineState) -> dict:
    """One window view over all replicas: ring buffers (R, w) concatenate
    to (R*w,) — `buf_valid` already masks unwritten slots — while shared
    coefficient state passes through.  The result feeds every
    `core.adaptive` read (window_stats / periodic_update) unchanged."""
    bufs, shared = split_adaptive(state.adaptive)
    merged = {k: bufs[k].reshape((-1,) + bufs[k].shape[2:])
              for k in ADAPTIVE_BUFFER_KEYS if k.startswith("buf_")}
    # ptr/seen are per-replica write cursors; a merged window has no single
    # cursor — expose the total seen and a dead ptr.
    merged["ptr"] = jnp.zeros((), jnp.int32)
    merged["seen"] = jnp.sum(bufs["seen"]).astype(jnp.int32)
    return {**shared, **merged}
