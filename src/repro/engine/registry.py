"""String-keyed strategy registries for the DART engine.

Mirrors the style of ``configs/registry.py``: every pluggable piece of
the pipeline is looked up by name, so entry points can expose
``--confidence/--difficulty/--optimizer`` flags and new strategies can be
added without touching call sites (the EENet/Laskaridis "exit policy as a
swappable strategy" design).

Three tables:

* ``CONFIDENCE``  — raw exit outputs → (E, B) confidence scores, larger
  = more confident.  Kernel acceleration is decided by
  ``repro.kernels.dispatch`` (platform/VMEM backend selection), not by
  per-call-site flags.
* ``DIFFICULTY``  — model inputs → (B,) difficulty scores in [0, 1]
  (§II.A estimators + domain adapters).
* ``OPTIMIZERS``  — ``PolicyOptimizer`` implementations: calibration
  data → ``PolicyResult`` (§II.B solvers + the Table I baselines).

A ``PolicyOptimizer`` is any callable
``(data: CalibrationData, *, beta_opt: float, **kw) -> PolicyResult``.
Baselines that do not natively route on adapted confidence thresholds
(BranchyNet, RL-Agent) project their policy onto the Eq. 19 runtime form
and additionally stash their native router under
``diagnostics["router"]`` (a ``CalibrationData -> exit_idx`` callable)
so offline evaluation stays faithful to the original criterion.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core import baselines as BL
from repro.core import difficulty as DIFF
from repro.core import policy as POL
from repro.core import routing as R
from repro.core import thresholds as TH
from repro.core.policy import CalibrationData, PolicyResult

CONFIDENCE: dict[str, Callable] = {}
DIFFICULTY: dict[str, Callable] = {}
OPTIMIZERS: dict[str, Callable] = {}


def _register(table: dict, name: str):
    def deco(fn):
        table[name] = fn
        return fn
    return deco


def register_confidence(name):
    return _register(CONFIDENCE, name)


def register_difficulty(name):
    return _register(DIFFICULTY, name)


def register_optimizer(name):
    return _register(OPTIMIZERS, name)


def _get(table: dict, kind: str, name: str):
    if name not in table:
        raise KeyError(f"unknown {kind} strategy {name!r}; "
                       f"known: {sorted(table)}")
    return table[name]


def get_confidence(name: str) -> Callable:
    return _get(CONFIDENCE, "confidence", name)


def get_difficulty(name: str) -> Callable:
    return _get(DIFFICULTY, "difficulty", name)


def get_optimizer(name: str) -> Callable:
    return _get(OPTIMIZERS, "optimizer", name)


# ---------------------------------------------------------------------------
# Confidence functionals (raw exit outputs -> (E, B) or (B,) scores)
# ---------------------------------------------------------------------------

@register_confidence("softmax-max")
def _conf_softmax_max(logits, **kw):
    """Max softmax probability (the paper's classifier criterion)."""
    return R.confidence_from_logits(logits)


@register_confidence("entropy")
def _conf_entropy(logits, **kw):
    """exp(−H(p)) — entropy mapped onto (0, 1] so that larger = more
    confident (BranchyNet's criterion under the common gate protocol)."""
    return jnp.exp(-R.entropy_from_logits(logits))


@register_confidence("diffusion-convergence")
def _conf_diffusion(eps_stack, **kw):
    """Convergence of consecutive exit ε-predictions (diffusion)."""
    return R.diffusion_confidence(eps_stack)


@register_confidence("lm-token")
def _conf_lm_token(logits, **kw):
    """Next-token max softmax probability (CALM-style LM criterion)."""
    return R.confidence_from_logits(logits)


# ---------------------------------------------------------------------------
# Difficulty estimators (inputs -> (B,) in [0, 1])
# ---------------------------------------------------------------------------

@register_difficulty("image")
def _diff_image(inputs, cfg: DIFF.DifficultyConfig = DIFF.DEFAULT, *,
                mesh=None, axis="data", **kw):
    """Eq. 8 image difficulty through the kernel dispatch layer (fused
    Pallas estimator on TPU, jnp reference elsewhere; shard_map-wrapped
    inside sharded steps when ``mesh`` is given)."""
    from repro.kernels import dispatch as KD
    return KD.image_difficulty(inputs, cfg, mesh=mesh, axis=axis)


@register_difficulty("tokens")
def _diff_tokens(inputs, cfg: DIFF.DifficultyConfig = DIFF.DEFAULT, **kw):
    return DIFF.token_difficulty(inputs, cfg)


@register_difficulty("latent")
def _diff_latent(inputs, cfg: DIFF.DifficultyConfig = DIFF.DEFAULT, *,
                 signal_frac, **kw):
    return DIFF.latent_difficulty(inputs, signal_frac, cfg)


@register_difficulty("zero")
def _diff_zero(inputs, cfg: DIFF.DifficultyConfig = DIFF.DEFAULT, **kw):
    """Difficulty-unaware ablation: α ≡ 0 (Eq. 19 collapses to c·τ)."""
    return jnp.zeros((inputs.shape[0],), jnp.float32)


# ---------------------------------------------------------------------------
# Policy optimizers (§II.B solvers)
# ---------------------------------------------------------------------------

OPTIMIZERS["joint_dp"] = POL.optimize_joint_dp
OPTIMIZERS["brute_force"] = POL.optimize_brute_force
OPTIMIZERS["independent"] = POL.optimize_independent
# Cascade solvers take a CascadeCalibrationData and return a
# CascadePolicyResult (per-member Eq. 19 policies + escalation
# thresholds); CascadeEngine.calibrate resolves them through here.
OPTIMIZERS["cascade_dp"] = POL.optimize_cascade_dp
OPTIMIZERS["cascade_independent"] = POL.optimize_cascade_independent


def _objective(data: CalibrationData, idx, beta_opt: float) -> float:
    n = data.conf.shape[0]
    acc = float(data.correct[np.arange(n), idx].mean())
    cost = float(np.asarray(data.cum_costs)[idx].mean())
    return acc - beta_opt * cost


@register_optimizer("static")
def optimize_static(data: CalibrationData, *, beta_opt=0.5,
                    **kw) -> PolicyResult:
    """Table I "Static": never exit early (τ = 1 ⇒ conf > 1 never fires)."""
    e = data.n_exits
    idx = BL.static_route(data.conf)
    return PolicyResult(
        tau=np.ones(e - 1), coef=np.ones(e - 1), beta_diff=0.0,
        objective=_objective(data, idx, beta_opt), method="static",
        diagnostics={"router": lambda d: BL.static_route(d.conf)})


@register_optimizer("branchynet")
def optimize_branchynet(data: CalibrationData, *, beta_opt=0.5,
                        **kw) -> PolicyResult:
    """Table I "BranchyNet": fixed entropy thresholds, no difficulty term.

    Fits on ``data.entropy`` when available (the original criterion) and
    projects onto confidence space by matching per-exit firing quantiles;
    without entropy it degrades to a fixed-confidence-threshold fit."""
    e = data.n_exits
    if data.entropy is not None:
        pol = BL.fit_branchynet(data.entropy, data.correct,
                                np.asarray(data.cum_costs),
                                beta_opt=beta_opt)
        idx = pol.route(data.entropy)
        tau = np.empty(e - 1)
        for i in range(e - 1):
            fire_frac = float(
                (data.entropy[:, i] < pol.entropy_thresholds[i]).mean())
            tau[i] = np.quantile(data.conf[:, i],
                                 min(max(1.0 - fire_frac, 0.0), 1.0))

        def router(d):
            if d.entropy is None:       # entropy-less holdout: Eq. 19 form
                return np.asarray(TH.simulate_routing(
                    d.conf, np.zeros_like(d.alpha), tau,
                    np.ones(e - 1), 0.0))
            return pol.route(d.entropy)
        diag = {"router": router, "policy": pol}
    else:
        grid = np.quantile(data.conf[:, :-1],
                           [0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 0.95])
        ones = np.ones(e - 1)
        best = (-np.inf, None)
        for t in grid:
            cand = np.full(e - 1, t)
            idx = np.asarray(TH.simulate_routing(
                data.conf, np.zeros_like(data.alpha), cand, ones, 0.0))
            j = _objective(data, idx, beta_opt)
            if j > best[0]:
                best = (j, cand)
        tau = best[1]
        idx = np.asarray(TH.simulate_routing(
            data.conf, np.zeros_like(data.alpha), tau, np.ones(e - 1), 0.0))
        diag = {"router": lambda d: np.asarray(TH.simulate_routing(
            d.conf, np.zeros_like(d.alpha), tau, np.ones(e - 1), 0.0))}
    return PolicyResult(tau=tau, coef=np.ones(e - 1), beta_diff=0.0,
                        objective=_objective(data, idx, beta_opt),
                        method="branchynet", diagnostics=diag)


@register_optimizer("rl_agent")
def optimize_rl_agent(data: CalibrationData, *, beta_opt=0.5, epochs=20,
                      n_conf_bins=10, seed=0, **kw) -> PolicyResult:
    """Table I "RL-Agent": tabular Q-learning policy, projected onto
    per-exit confidence thresholds (smallest bin whose exit-action value
    dominates for every bin above it)."""
    pol = BL.fit_rl_agent(data, beta_opt=beta_opt, epochs=epochs,
                          n_conf_bins=n_conf_bins, seed=seed)
    e = data.n_exits
    edges = np.linspace(0.0, 1.0, n_conf_bins + 1)
    tau = np.ones(e - 1)
    for i in range(e - 1):
        cstar = n_conf_bins
        for c in range(n_conf_bins - 1, -1, -1):
            if pol.q[i, c, 1] >= pol.q[i, c, 0]:
                cstar = c
            else:
                break
        tau[i] = edges[cstar] if cstar < n_conf_bins else 1.0
    idx = pol.route(data.conf)
    return PolicyResult(
        tau=tau, coef=np.ones(e - 1), beta_diff=0.0,
        objective=_objective(data, idx, beta_opt), method="rl_agent",
        diagnostics={"router": lambda d: pol.route(d.conf), "policy": pol})


def route_policy(pol: PolicyResult, data: CalibrationData) -> np.ndarray:
    """Offline-route a calibration/holdout set under a fitted policy.

    Uses the policy's native router when it has one (entropy criterion,
    Q-table, …); otherwise simulates Alg. 1 with the Eq. 19 projection."""
    if pol.diagnostics and "router" in pol.diagnostics:
        return np.asarray(pol.diagnostics["router"](data))
    return np.asarray(TH.simulate_routing(
        data.conf, data.alpha, pol.tau, pol.coef, pol.beta_diff))
