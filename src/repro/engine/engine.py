"""DartEngine — the unified façade over the whole DART lifecycle.

One object owns the paper's three contributions end to end:

    engine = DartEngine.from_config(cfg, params)        # wire up
    engine.calibrate(cal_data)                          # §II.B  (policy)
    out = engine.infer(x, mode="compacted")             # Alg. 1 (serving)
    engine.update()                                     # §II.C  (adapt)
    engine.stats()                                      # metering

Every strategy is a string looked up in ``repro.engine.registry``
(confidence functional, difficulty estimator, policy optimizer), so the
same engine serves classifiers, LMs and diffusion models and new exit
criteria plug in without touching call sites.

All mutable serving state lives in ONE pytree (``EngineState``):
checkpoint it with ``repro.checkpoint.save(path, step, engine.state)``
and restore with ``engine.restore_state(...)`` — counters, ring buffers,
UCB arms and thresholds all round-trip together.

Execution modes (DESIGN.md §4.1):

* ``masked``    — single jitted full forward, Alg. 1 on the stacked exit
  confidences.  Worst-case compute; bit-identical decisions.
* ``compacted`` — stage-segmented execution with ``BatchCompactor``:
  survivors of each gate are compacted into power-of-two buckets, so
  early exits buy back real FLOPs.  Oversized request batches are split
  into max-bucket chunks (no silent clamping).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adaptive as AD
from repro.core import difficulty as DIFF
from repro.core import routing as R
from repro.core import thresholds as TH
from repro.core.policy import CalibrationData, PolicyResult
from repro.core.routing import DartParams
from repro.engine import registry as REG
from repro.engine import state as ST
from repro.engine.compactor import BatchCompactor
from repro.engine.state import EngineState
from repro.models import get_family


def _n_exits(model_cfg, family) -> int:
    if family.staged:
        return family.num_stages(model_cfg)
    if hasattr(model_cfg, "exit_layers"):
        return len(model_cfg.exit_layers) + 1
    raise ValueError(f"cannot infer exit count for {type(model_cfg)}")


class DartEngine:
    """Session object for DART inference (train → calibrate → serve → adapt).

    Construct via :meth:`from_config`; mutable state is ``self.state``
    (an :class:`EngineState` pytree), everything else is static wiring.
    """

    def __init__(self, model_cfg, params, *, state: EngineState,
                 acfg: AD.AdaptiveConfig,
                 dcfg: DIFF.DifficultyConfig = DIFF.DEFAULT,
                 confidence: str = "softmax-max",
                 difficulty: str = "image",
                 optimizer: str = "joint_dp",
                 cum_costs=None, buckets=None,
                 adapt: bool = True, update_every: int = 100):
        self.cfg = model_cfg
        self.params = params
        self.state = state
        self.acfg = acfg
        self.dcfg = dcfg
        self.family = get_family(model_cfg)
        self.n_exits = _n_exits(model_cfg, self.family)
        self.confidence = confidence
        self.difficulty = difficulty
        self.optimizer = optimizer
        self._conf_fn = REG.get_confidence(confidence)
        self._diff_fn = REG.get_difficulty(difficulty)
        self._opt_fn = REG.get_optimizer(optimizer)
        self.compactor = BatchCompactor(buckets)
        # Compile-cache key granularity: padded batch shapes are rounded
        # up to a multiple of this (1 eagerly; the sharded engine sets it
        # to the replica count so the mesh divides every bucket evenly).
        self.replica_multiple = 1
        # Difficulty/gate calls go through repro.kernels.dispatch; the
        # sharded engines extend this with their mesh so pallas backends
        # shard_map over the data axis (dispatch ignores it on xla).
        self.kernel_kw: dict = {}
        self.adapt = adapt
        self.update_every = update_every
        self.total_latency_s = 0.0
        if cum_costs is None:
            cum_costs = np.arange(1, self.n_exits + 1) / self.n_exits
        self.cum_costs = np.asarray(cum_costs, float)

        cfgc = model_cfg
        if self.family.staged:
            self._stem = jax.jit(
                lambda p, x: self.family.apply_stem(p, x, cfgc))
            self._stage = [
                jax.jit(lambda p, h, s=s: self.family.apply_stage(
                    p, h, s, cfgc)) for s in range(self.n_exits)]
            self._exit = [
                jax.jit(lambda p, h, s=s: self.family.apply_exit(
                    p, h, s, cfgc)) for s in range(self.n_exits)]
        self._alpha = jax.jit(
            lambda x: self._diff_fn(x, self.dcfg, **self.kernel_kw))
        self._forward = jax.jit(
            lambda p, x: self.family.forward(p, x, cfgc))

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def from_config(cls, model_cfg, params, *, dart: DartParams | None = None,
                    adaptive_cfg: AD.AdaptiveConfig | None = None,
                    n_classes: int | None = None,
                    beta_opt: float | None = None,
                    mesh=None, **kw) -> "DartEngine":
        """Build an engine from a model config + trained params.

        ``model_cfg`` may be a config object or an arch id resolved via
        ``configs.registry`` (e.g. ``"vit-s16"``).

        ``mesh``: a 1-D ("data",) device mesh (``launch.mesh.
        make_serving_mesh``) — serving then goes through the
        jit-end-to-end data-parallel :class:`~repro.engine.sharded.
        ShardedDartEngine` instead of the eager engine."""
        if isinstance(model_cfg, str):
            from repro.configs import registry as cfg_registry
            model_cfg = cfg_registry.get(model_cfg)
        family = get_family(model_cfg)
        e = _n_exits(model_cfg, family)
        acfg = adaptive_cfg or AD.AdaptiveConfig(
            n_exits=e,
            n_classes=n_classes or getattr(model_cfg, "n_classes", 10))
        state = EngineState.create(e, acfg, dart)
        if beta_opt is not None:
            state = state.with_policy(beta_opt=beta_opt)
        if mesh is not None:
            from repro.engine.sharded import ShardedDartEngine
            if cls is DartEngine:
                cls = ShardedDartEngine
            kw["mesh"] = mesh
        return cls(model_cfg, params, state=state, acfg=acfg, **kw)

    # ------------------------------------------------------------------
    # §II.B — calibration / policy fitting
    # ------------------------------------------------------------------
    def collect_calibration(self, data_cfg, *, n=512, split="eval",
                            offset=0, batch=64) -> CalibrationData:
        """Run the model over ``n`` samples and build per-exit calibration
        measurements (confidence, correctness, difficulty, entropy)."""
        from repro.data.datasets import make_batch
        confs, ents, corrects, alphas, labels = [], [], [], [], []
        for start in range(offset, offset + n, batch):
            x, y = make_batch(data_cfg, range(start, start + batch),
                              split=split)
            out = self._forward(self.params, jnp.asarray(x))
            logits = out["exit_logits"]                     # (E, B, C)
            conf = np.asarray(self._conf_fn(logits))
            ent = np.asarray(R.entropy_from_logits(logits))
            pred = np.asarray(jnp.argmax(logits, axis=-1))
            alpha = np.asarray(self._alpha(jnp.asarray(x)))
            confs.append(conf.T)
            ents.append(ent.T)
            corrects.append((pred == y[None]).T.astype(float))
            alphas.append(alpha)
            labels.append(y)
        return CalibrationData(
            conf=np.concatenate(confs),
            correct=np.concatenate(corrects),
            alpha=np.concatenate(alphas),
            cum_costs=self.cum_costs / self.cum_costs[-1],
            labels=np.concatenate(labels),
            entropy=np.concatenate(ents))

    def calibrate(self, data, **kw) -> PolicyResult:
        """Fit the exit policy with the registered optimizer and install
        it into the engine state.

        ``data``: a :class:`CalibrationData`, or a ``DatasetConfig`` (the
        engine collects measurements itself).  Returns the fitted
        :class:`PolicyResult`."""
        if not isinstance(data, CalibrationData):
            data = self.collect_calibration(data, **{
                k: kw.pop(k) for k in ("n", "split", "offset", "batch")
                if k in kw})
        kw.setdefault("beta_opt", float(self.state.beta_opt))
        pol = self._opt_fn(data, **kw)
        self.state = self.state.with_policy(
            tau=pol.tau, coef=pol.coef, beta_diff=pol.beta_diff)
        self._policy_mirror = None
        return pol

    # ------------------------------------------------------------------
    # serving helpers
    # ------------------------------------------------------------------
    def dart_params(self, coef=None) -> DartParams:
        """Current routing parameters (adaptive coefficients folded in)."""
        s = self.state
        if coef is None:
            coef = self._coef()
        return DartParams(tau=s.tau, coef=coef,
                          beta_diff=float(s.beta_diff),
                          beta_opt=float(s.beta_opt))

    def _coef(self):
        if self.adapt:
            return AD.effective_coef(self.state.adaptive, self.acfg)
        return self.state.coef

    #: confidence functionals bounded above by 1.0 — the precondition
    #: for the sound head-skip bound (core.thresholds.min_exit_bound)
    _BOUNDED_CONF = ("softmax-max", "lm-token")

    def min_exit_bound(self, alpha_lo: float = 0.0) -> int:
        """Sound per-bucket head-skip depth under the CURRENT policy:
        the number of leading gates Eq. 19 provably rules out for every
        input with difficulty ≥ ``alpha_lo`` (see ``core.thresholds.
        min_exit_bound``).  Returns 0 (skip nothing) for confidence
        functionals without a known upper bound."""
        if self.confidence not in self._BOUNDED_CONF or self.n_exits < 2:
            return 0
        tau, coef, beta_diff = self._policy_host()
        return TH.min_exit_bound(tau, coef, beta_diff, alpha_lo)

    def _policy_host(self):
        """Host mirror of (tau, effective coef, beta_diff), cached so
        admission-time bound checks never force a device sync of the
        serving state per dispatch.  Invalidated explicitly by
        calibrate()/update()/restore_state() (the §II.C coefficient
        path) and implicitly by ``with_policy`` installs (the cache is
        keyed on the tau/coef leaf identities, which those replace)."""
        key = (id(self.state.tau), id(self.state.coef))
        cached = getattr(self, "_policy_mirror", None)
        if cached is None or cached[0] != key:
            self._policy_mirror = (key, (
                np.asarray(self.state.tau, np.float32),
                np.asarray(self._coef(), np.float32),
                float(self.state.beta_diff)))
        return self._policy_mirror[1]

    def bucket_key(self, n: int) -> int:
        """THE compile-cache key for an ``n``-sample batch: the
        ``BatchCompactor`` bucket rounded up to ``replica_multiple``.
        Every serving path (eager compacted, sharded masked/compacted,
        the async scheduler's flush planner) must key compiled shapes
        through here so they agree on what shares a compilation."""
        return self.compactor.padded_size(n, self.replica_multiple)

    def _gate(self, logits, eff_thresh):
        if self.confidence == "softmax-max":
            from repro.kernels import dispatch as KD
            conf, _, pred, fire = KD.exit_gate(
                logits, jnp.asarray(eff_thresh, jnp.float32),
                **self.kernel_kw)
            return conf, pred, fire.astype(bool)
        conf = self._conf_fn(logits)
        pred = jnp.argmax(logits, axis=-1)
        return conf, pred, conf > eff_thresh

    def route(self, stack, inputs=None, alpha=None, **difficulty_kw):
        """Generic Alg. 1 routing over a stacked-exit output.

        ``stack``: raw per-exit outputs, shape (E, B, ...) — converted to
        confidences by the registered functional.  ``alpha`` may be given
        directly, or ``inputs`` is fed to the difficulty estimator.
        jit-safe; state is read, never written."""
        conf_stack = self._conf_fn(stack)
        if alpha is None:
            alpha = self._diff_fn(inputs, self.dcfg, **difficulty_kw)
        return R.route(conf_stack, alpha, self.dart_params())

    # ------------------------------------------------------------------
    # inference
    # ------------------------------------------------------------------
    def infer(self, x, mode: str = "compacted", record: bool | None = None,
              alpha=None, pad_to: int | None = None,
              min_exit: int = 0) -> dict:
        """Serve one request batch.

        mode="masked"    — full forward, Alg. 1 on stacked confidences.
        mode="compacted" — stage-segmented with batch compaction (same
                           decisions, real FLOP savings).
        record — update serving counters + the §II.C sliding window
                 (defaults on for compacted serving, off for masked so a
                 reference pass never perturbs the engine state).
        alpha  — optional (B,) precomputed Eq. 8 difficulty.  The async
                 scheduler (repro.serving) estimates difficulty once at
                 admission and hands it through here, so routing never
                 re-runs the estimator on the consolidated batch.
        pad_to — masked mode only: zero-pad the batch to this fixed
                 shape (normally ``engine.bucket_key(B)``) so arbitrary
                 request-consolidation sizes reuse one compiled forward
                 per bucket.  Padding never reaches outputs or telemetry.
                 The sharded engine ignores it (it pads internally).
        min_exit — head-skip depth: gates s < min_exit are skipped (no
                 exit head, no Alg. 1 gate).  With the CONSERVATIVE
                 bound (``engine.min_exit_bound(min(alpha))``) those
                 gates provably never fire, so decisions are unchanged
                 — compacted mode then skips their launches and host
                 syncs; masked mode computes every exit anyway and
                 ignores it."""
        if not 0 <= int(min_exit) < self.n_exits:
            raise ValueError(f"min_exit {min_exit} out of range for "
                             f"{self.n_exits} exits")
        if mode == "masked":
            return self._infer_masked(x, record=bool(record), alpha=alpha,
                                      pad_to=pad_to)
        if mode == "compacted":
            record = True if record is None else record
            return self._infer_compacted(x, record=record, alpha=alpha,
                                         min_exit=int(min_exit))
        raise ValueError(f"unknown mode {mode!r}; known: masked, compacted")

    # -- masked ---------------------------------------------------------
    def _infer_masked(self, x, record: bool = False, alpha=None,
                      pad_to: int | None = None) -> dict:
        t0 = time.time()
        x = jnp.asarray(x)
        b = x.shape[0]
        if pad_to is not None and pad_to > b:
            x = self.compactor.pad(x, pad_to)
            if alpha is not None:
                alpha = self.compactor.pad(
                    np.asarray(alpha, np.float32), pad_to)
        out = self._forward(self.params, x)
        logits = out["exit_logits"]                         # (E, bp, C)
        conf_stack = self._conf_fn(logits)
        alpha = self._alpha(x) if alpha is None else jnp.asarray(alpha)
        r = R.route(conf_stack, alpha, self.dart_params())
        preds_all = jnp.argmax(logits, axis=-1)
        pred = jnp.take_along_axis(preds_all, r["exit_idx"][None], axis=0)[0]
        if x.shape[0] > b:                  # strip padded lanes
            r = {k: v[:b] for k, v in r.items()}
            pred = pred[:b]
            preds_all = preds_all[:, :b]
            conf_stack = conf_stack[:, :b]
        macs = self.cum_costs[np.asarray(r["exit_idx"])]
        res = {**r, "pred": pred, "preds_all": preds_all,
               "conf_stack": conf_stack, "macs": macs,
               "latency_s": time.time() - t0}
        if record:
            idx = np.asarray(r["exit_idx"])
            self._record(idx, np.asarray(pred), np.asarray(r["conf"]), macs,
                         latency_s=res["latency_s"],
                         exit_counts=np.bincount(idx,
                                                 minlength=self.n_exits))
            self._maybe_update()
        return res

    # -- compacted ------------------------------------------------------
    def _infer_compacted(self, x, record: bool = True, alpha=None,
                         min_exit: int = 0) -> dict:
        b = x.shape[0]
        if b > self.compactor.max_bucket:
            # One request = one policy: chunks are recorded but the §II.C
            # periodic update is deferred past the last chunk, so every
            # sample of the request is gated under the same coefficients
            # (and compacted stays bit-identical to masked).
            parts = [self._infer_compacted_chunk(
                x[a:z], record=record,
                alpha=None if alpha is None else alpha[a:z],
                min_exit=min_exit)
                for a, z in self.compactor.chunks(b)]
            out = {k: np.concatenate([p[k] for p in parts])
                   for k in ("pred", "conf", "exit_idx", "alpha", "macs")}
            out["latency_s"] = sum(p["latency_s"] for p in parts)
        else:
            out = self._infer_compacted_chunk(x, record=record, alpha=alpha,
                                              min_exit=min_exit)
        if record:
            self._maybe_update()
        return out

    def _infer_compacted_chunk(self, x, record: bool, alpha=None,
                               min_exit: int = 0) -> dict:
        if not self.family.staged:
            raise ValueError(
                f"compacted mode needs a staged family; "
                f"{type(self.cfg).__name__} is not staged — use "
                f"mode='masked' or the LM decode engine")
        t0 = time.time()
        b = x.shape[0]
        x = jnp.asarray(x)
        alpha = np.asarray(self._alpha(x)) if alpha is None \
            else np.asarray(alpha, np.float32)

        out_pred = np.zeros(b, np.int64)
        out_conf = np.zeros(b, np.float32)
        out_exit = np.zeros(b, np.int64)

        coef = np.asarray(self._coef(), np.float32)
        tau = np.asarray(self.state.tau, np.float32)
        beta_diff = float(self.state.beta_diff)

        h_active = self._stem(self.params, x)
        active = np.arange(b)
        alpha_active = alpha
        exit_counts = np.zeros(self.n_exits, np.int32)
        for s in range(self.n_exits):
            n = len(active)
            bucket = self.bucket_key(n)
            h_pad = self.compactor.pad(h_active, bucket)
            h_pad = self._stage[s](self.params, h_pad)
            if s < min_exit and s < self.n_exits - 1:
                # gate ruled out for every row (predictor head-skip):
                # no exit head, no Alg. 1 gate, no fire/conf host sync
                h_active = h_pad[:n]
                continue
            logits = self._exit[s](self.params, h_pad)
            if s < self.n_exits - 1:
                eff = np.asarray(TH.stage_threshold(
                    tau[s], coef[s], alpha_active, beta_diff))
                # padded lanes get an unreachable threshold -> never fire
                eff_pad = self.compactor.pad(
                    np.asarray(eff, np.float32), bucket, fill=2.0)
                conf, pred, fire = self._gate(logits, jnp.asarray(eff_pad))
                fire = np.asarray(fire[:n])
            else:
                conf, pred, _ = self._gate(
                    logits, jnp.zeros(bucket, jnp.float32))
                fire = np.ones(n, bool)
            conf = np.asarray(conf[:n])
            pred = np.asarray(pred[:n])

            done = active[fire]
            out_pred[done] = pred[fire]
            out_conf[done] = conf[fire]
            out_exit[done] = s
            exit_counts[s] += int(fire.sum())
            keep = ~fire
            if not keep.any():
                break
            h_active = self.compactor.gather(h_pad[:n], np.nonzero(keep)[0])
            alpha_active = alpha_active[keep]
            active = active[keep]

        macs = self.cum_costs[out_exit]
        latency = time.time() - t0
        if record:
            self._record(out_exit, out_pred, out_conf, macs,
                         latency_s=latency, exit_counts=exit_counts)
        return {"pred": out_pred, "conf": out_conf, "exit_idx": out_exit,
                "alpha": alpha, "macs": macs, "latency_s": latency}

    # ------------------------------------------------------------------
    # §II.C — adaptation + metering
    # ------------------------------------------------------------------
    def _record(self, exit_idx, pred, conf, macs, *, latency_s=0.0,
                exit_counts=None):
        """Fold one served batch into the state: counters always, the
        §II.C sliding window only when adaptation is on."""
        b = len(exit_idx)
        s = self.state
        if exit_counts is None:
            exit_counts = np.bincount(exit_idx, minlength=self.n_exits)
        counts = s.exit_counts + jnp.asarray(exit_counts, jnp.int32)
        adaptive = s.adaptive
        if self.adapt:
            # confidence-calibrated pseudo-correctness (paper §II.C.1)
            adaptive = AD.record_batch(
                adaptive, self.acfg, jnp.asarray(exit_idx),
                jnp.asarray(pred % self.acfg.n_classes),
                jnp.asarray(conf), jnp.asarray(conf),
                jnp.asarray(macs / self.cum_costs[-1]))
        self.state = dataclasses.replace(
            s, adaptive=adaptive, served=s.served + b, exit_counts=counts,
            total_macs=s.total_macs + float(np.sum(macs)),
            since_update=s.since_update + b)
        self.total_latency_s += latency_s

    def _maybe_update(self):
        if self.adapt and int(self.state.since_update) >= self.update_every:
            self.update()

    def update(self) -> None:
        """One §II.C periodic refinement: run both adaptation laws on the
        sliding window, score with the Eq. 10 reward, update UCB1."""
        s = self.state
        adaptive = AD.periodic_update(s.adaptive, self.acfg,
                                      beta_opt=float(s.beta_opt))
        self.state = dataclasses.replace(
            s, adaptive=adaptive, since_update=jnp.zeros((), jnp.int32))
        self._policy_mirror = None

    def record_requests(self, latencies_ms, missed=None) -> None:
        """Fold completed-request latency/deadline telemetry into the
        engine state (host-side write; the async scheduler calls this
        once per flushed bucket)."""
        self.state = ST.record_requests(self.state, latencies_ms, missed)

    def record_quotes(self, quotes_ms, realized_ms) -> None:
        """Fold admission-time SLO quote error telemetry (quote vs
        realized latency; host-side write, like record_requests)."""
        self.state = ST.record_quotes(self.state, quotes_ms, realized_ms)

    def stats(self) -> dict:
        """Serving counters + windowed §II.C statistics."""
        from repro.obs import stats as OBS_STATS
        s = self.state
        out = OBS_STATS.engine_summary(
            ST.telemetry_totals(s, sharded=False))
        out["total_latency_s"] = self.total_latency_s
        out["active_strategy"] = AD.STRATEGIES[
            int(s.adaptive["active_strategy"])]
        if out["served"]:
            w = AD.window_stats(s.adaptive, self.acfg)
            out["window"] = {k: np.asarray(v) for k, v in w.items()}
        return OBS_STATS.attach_requests(out, s)

    # ------------------------------------------------------------------
    # state round-trip
    # ------------------------------------------------------------------
    def save_state(self, path: str, step: int = 0):
        """Checkpoint the FULL serving state (one pytree) atomically."""
        from repro import checkpoint as CK
        return CK.save(path, step, self.state)

    def restore_state(self, path: str, step: int | None = None):
        # Pre-latency-telemetry checkpoints restore through the shared
        # prefix migration (state.LEGACY_FIELDS).
        self.state, step = ST.restore_with_migration(path, self.state, step)
        self._policy_mirror = None
        return step

    # ------------------------------------------------------------------
    # cost measurement (XLA cost analysis — exact, not hand counted)
    # ------------------------------------------------------------------
    def measure_costs(self, img_shape) -> np.ndarray:
        """Cumulative MACs per exit from XLA cost analysis of each
        stage+exit; also installs the result as ``self.cum_costs``."""
        if not self.family.staged:
            raise ValueError("measure_costs needs a staged family")
        fam, cfg = self.family, self.cfg
        x = jnp.zeros((1,) + tuple(img_shape))
        h = fam.apply_stem(self.params, x, cfg)
        cum, total = [], 0.0

        def flops_of(fn, *args):
            from repro.compat import cost_analysis_dict
            c = cost_analysis_dict(jax.jit(fn).lower(*args).compile())
            return float(c.get("flops", 0.0))

        for s in range(self.n_exits):
            total += flops_of(
                lambda p, h, s=s: fam.apply_stage(p, h, s, cfg),
                self.params, h)
            h = fam.apply_stage(self.params, h, s, cfg)
            head = flops_of(
                lambda p, h, s=s: fam.apply_exit(p, h, s, cfg),
                self.params, h)
            cum.append((total + head) / 2.0)          # flops -> MACs
        self.cum_costs = np.asarray(cum)
        return self.cum_costs
