"""ShardedDartEngine — jit-compiled, data-parallel DART serving.

The eager :class:`~repro.engine.engine.DartEngine` dispatches the model,
the difficulty estimator and Alg. 1 routing as separate ops from Python;
this engine lowers the WHOLE serving step — forward, confidence
functional, difficulty estimation, Eq. 19 threshold adaptation, Alg. 1
exit selection and the §II.C telemetry fold — into one donated-state
jitted program replicated over a 1-D device mesh:

    mesh = make_serving_mesh()                  # ("data",) over devices
    engine = DartEngine.from_config(cfg, params, mesh=mesh)
    out = engine.infer(x, mode="masked")        # one compiled dispatch

Design (ISSUE 2 tentpole):

* **One compiled program per bucket.**  Request batches are padded to
  the `BatchCompactor` bucket (rounded up to a replica multiple) so the
  number of traced programs is bounded by #buckets (masked) or
  #stages × #buckets (compacted).  `trace_counts` records every trace,
  so tests can assert one trace per bucket.
* **Donated state.**  The step takes and returns the full
  :class:`EngineState`; the argument is donated, so serving is
  allocation-stable on accelerators (CPU ignores donation).
* **Sharded telemetry, replicated policy.**  Policy leaves (tau / coef /
  beta_* and the §II.C coefficient + UCB state) carry
  ``NamedSharding(mesh, P())``; telemetry leaves (counters and the ring
  buffers) gain a leading replica axis sharded over ``data`` (see
  ``state.shard_telemetry``).  Each replica folds in only its local
  batch shard — zero cross-replica traffic on the hot path — and
  ``update()`` / ``stats()`` reduce across replicas (merged §II.C
  window, summed counters).
* **The eager path stays the oracle.**  ``infer(x, mode="eager")`` runs
  the parent's eager masked pass (never records), and the equivalence
  suite asserts compiled == eager for preds, exit indices and telemetry
  after the all-reduce.

Confidence + gate (and in-step Eq. 8 difficulty) route through
``repro.kernels.dispatch`` (ISSUE 5 tentpole): the historical GSPMD
blocker — ``pallas_call`` does not partition — is solved by dispatch
wrapping pallas backends in ``shard_map`` over the ``("data",)`` axis,
so each replica gates its local rows in one fused launch per exit; on
this CPU container dispatch auto-selects the ``"xla"`` reference chain,
which is bit-identical to the eager oracle (see docs/kernels.md).
"""
from __future__ import annotations

import dataclasses
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import adaptive as AD
from repro.core import thresholds as TH
from repro.engine import state as ST
from repro.engine.engine import DartEngine
from repro.engine.state import EngineState

def _silence_donation_warning():
    """CPU backends ignore donation and warn per step; donation still
    pays off on TPU/GPU, so keep declaring it and silence the noise —
    but only once someone actually constructs a sharded engine (a plain
    `import repro.engine` must not mutate global warning filters)."""
    warnings.filterwarnings(
        "ignore", message="Some donated buffers were not usable")


class ShardedDartEngine(DartEngine):
    """Data-parallel DART serving over a 1-D ("data",) mesh.

    Construct via ``DartEngine.from_config(cfg, params, mesh=mesh)`` (or
    directly).  ``infer`` modes:

    * ``masked``    — ONE jitted program: full forward + Alg. 1 + telemetry
      fold, batch sharded over the mesh.  The serving hot path.
    * ``compacted`` — stage-segmented: one fused (stage+exit+gate) program
      per (stage, bucket), survivors compacted between stages, telemetry
      folded by a compiled step.  Same decisions, real FLOP savings.
    * ``eager``     — the parent's eager masked pass (reference oracle;
      never records).
    """

    def __init__(self, model_cfg, params, *, mesh, state: EngineState,
                 acfg, data_axis: str = "data", **kw):
        super().__init__(model_cfg, params, state=state, acfg=acfg, **kw)
        _silence_donation_warning()
        self.mesh = mesh
        self.data_axis = data_axis
        # kernels.dispatch shard_maps pallas backends over the data axis
        # inside the compiled steps (xla backends partition under GSPMD)
        self.kernel_kw = {"mesh": mesh, "axis": data_axis}
        self.n_replicas = int(mesh.shape[data_axis])
        self.replica_multiple = self.n_replicas    # bucket_key granularity
        self._repl = NamedSharding(mesh, P())
        self._row = NamedSharding(mesh, P(data_axis))
        self._state_sh = self._state_shardings()
        self.params = jax.device_put(self.params, self._repl)
        # The compiled step DONATES the state, and device_put zero-copies
        # already-placed shards — so take ownership with a deep copy, or
        # donation would invalidate buffers the caller still holds (the
        # DartParams it passed in, a sibling engine built from the same
        # DartParams).
        owned = jax.tree.map(lambda a: jnp.array(a, copy=True),
                             ST.shard_telemetry(self.state, self.n_replicas))
        self.state = jax.device_put(owned, self._state_sh)
        self._steps: dict = {}        # cache key -> compiled callable
        self.trace_counts: dict = {}  # cache key -> number of traces
        # Host mirror of sum(state.since_update): checking the periodic-
        # update schedule must not force a device sync per request, or
        # back-to-back compiled steps could never pipeline.
        self._pending = 0

    # ------------------------------------------------------------------
    # sharding layout
    # ------------------------------------------------------------------
    def _state_shardings(self) -> EngineState:
        """EngineState-of-NamedShardings: policy replicated, telemetry
        row-sharded on its leading replica axis."""
        return ST.state_shardings(self.state, self._repl, self._row)

    def _commit(self):
        """Re-pin the state to its sharding layout after any eager
        mutation (calibrate / update / restore)."""
        self.state = jax.device_put(self.state, self._state_sh)

    def _count_trace(self, key):
        # Runs in the Python body of a step function, i.e. once per trace.
        self.trace_counts[key] = self.trace_counts.get(key, 0) + 1

    # ------------------------------------------------------------------
    # traced pieces
    # ------------------------------------------------------------------
    def _coef_traced(self, state: EngineState):
        if self.adapt:
            # effective_coef touches only the shared (replicated) keys.
            return AD.effective_coef(state.adaptive, self.acfg)
        return state.coef

    def _fold_traced(self, state: EngineState, exit_idx, pred, conf, macs,
                     valid) -> EngineState:
        """Per-replica telemetry fold: each replica's segment of the
        (padded) batch lands in its own counters / ring buffer."""
        r, e = self.n_replicas, self.n_exits
        per = exit_idx.shape[0] // r
        validf = valid.astype(jnp.float32)
        oh = jax.nn.one_hot(exit_idx, e) * validf[:, None]
        n_new = validf.reshape(r, per).sum(1).astype(jnp.int32)
        exit_counts = state.exit_counts \
            + oh.reshape(r, per, e).sum(1).astype(jnp.int32)
        total_macs = state.total_macs \
            + (macs * validf).reshape(r, per).sum(1)
        adaptive = state.adaptive
        if self.adapt:
            bufs, shared = ST.split_adaptive(adaptive)
            cost = macs / float(self.cum_costs[-1])
            rec = jax.vmap(
                lambda b, ei, pc, cf, cs, v: AD.record_batch(
                    b, self.acfg, ei, pc, cf, cf, cs, valid=v))
            new_bufs = rec(
                bufs, exit_idx.reshape(r, per),
                (pred % self.acfg.n_classes).reshape(r, per),
                conf.reshape(r, per), cost.reshape(r, per),
                validf.reshape(r, per))
            adaptive = {**shared, **new_bufs}
        return dataclasses.replace(
            state, adaptive=adaptive, served=state.served + n_new,
            exit_counts=exit_counts, total_macs=total_macs,
            since_update=state.since_update + n_new)

    # ------------------------------------------------------------------
    # compiled step factories (cached per bucket)
    # ------------------------------------------------------------------
    def _masked_step(self, bp: int, record: bool, with_alpha: bool = False,
                     min_exit: int = 0):
        """Full DART serving step for a (bp,)-padded batch.

        ``with_alpha``: the variant that takes admission-time difficulty
        as an operand instead of fusing the Eq. 8 estimator into the
        step (used by the async scheduler, which estimated difficulty
        once at enqueue).

        ``min_exit`` is a STATIC head-skip depth: gates s < min_exit
        never launch inside the compiled step (the predictor ruled them
        out — under the conservative bound they provably never fire, so
        the program is decision-identical to the min_exit=0 one)."""
        key = ("masked-alpha" if with_alpha else "masked", bp, record) \
            if not min_exit else \
            ("masked-alpha-skip" if with_alpha else "masked-skip",
             bp, record, min_exit)
        if key in self._steps:
            return self._steps[key]
        cum = jnp.asarray(self.cum_costs, jnp.float32)

        def step(params, state, x, valid, *aux):
            self._count_trace(key)
            logits = self._forward_traced(params, x)     # (E, bp, C)
            alpha = aux[0] if with_alpha \
                else self._diff_fn(x, self.dcfg, **self.kernel_kw)
            eff = TH.adapt_thresholds(state.tau, self._coef_traced(state),
                                      alpha, state.beta_diff)
            exit_idx, conf, pred = self._route_traced(logits, eff,
                                                      min_exit=min_exit)
            macs = cum[exit_idx]
            if record:
                state = self._fold_traced(state, exit_idx, pred, conf,
                                          macs, valid)
            return state, {"exit_idx": exit_idx, "conf": conf,
                           "pred": pred, "alpha": alpha, "macs": macs}

        self._steps[key] = jax.jit(
            step, donate_argnums=(1,),
            out_shardings=(self._state_sh, self._row))
        return self._steps[key]

    def _forward_traced(self, params, x):
        return self.family.forward(params, x, self.cfg)["exit_logits"]

    def _route_traced(self, logits, eff, min_exit: int = 0):
        """Alg. 1 over stacked exit logits (E, bp, C) with (bp, E-1)
        effective thresholds -> (exit_idx, conf, pred).

        For the paper's ``softmax-max`` functional every exit runs ONE
        fused gate launch through ``kernels.dispatch`` (confidence +
        argmax + Eq. 19 compare in a single VMEM pass per row on pallas
        backends; the bit-identical jnp chain on xla).  Other
        functionals keep the generic conf-stack path.

        Gates i < ``min_exit`` are skipped (no gate launch; they can
        never win the argmax)."""
        e, bp = logits.shape[0], logits.shape[1]
        if self.confidence != "softmax-max":
            if min_exit:        # unreachable threshold, fires stay False
                eff = eff.at[:, :min_exit].set(jnp.inf)
            conf_stack = self._conf_fn(logits)
            exit_idx, conf = TH.select_exit(conf_stack, eff)
            preds_all = jnp.argmax(logits, axis=-1)
            pred = jnp.take_along_axis(preds_all, exit_idx[None],
                                       axis=0)[0]
            return exit_idx, conf, pred
        from repro.kernels import dispatch as KD
        confs, preds, fires = [], [], []
        for i in range(e):
            if i < min_exit and i < e - 1:
                # ruled-out gate: no fused launch, placeholder lanes
                # (argmax can never select an all-False column)
                confs.append(jnp.zeros((bp,), jnp.float32))
                preds.append(jnp.zeros((bp,), jnp.int32))
                fires.append(jnp.zeros((bp,), bool))
                continue
            th_i = eff[:, i] if i < e - 1 \
                else jnp.full((bp,), -1.0, jnp.float32)
            c, _, p, f = KD.exit_gate(logits[i], th_i, **self.kernel_kw)
            confs.append(c)
            preds.append(p)
            # Alg. 1 line 12: the final exit accepts unconditionally,
            # whatever the confidence functional's range
            fires.append(f if i < e - 1 else jnp.ones_like(f))
        fires = jnp.stack(fires, axis=1) > 0            # (bp, E)
        exit_idx = jnp.argmax(fires, axis=1)            # first firing exit
        conf = jnp.take_along_axis(jnp.stack(confs, 1), exit_idx[:, None],
                                   axis=1)[:, 0]
        pred = jnp.take_along_axis(jnp.stack(preds, 1), exit_idx[:, None],
                                   axis=1)[:, 0]
        return exit_idx, conf, pred

    def _stage_step(self, s: int, bp: int):
        """Fused stage + exit head + gate for bucket ``bp``.  The gate
        (confidence + argmax + Eq. 19 compare) is one dispatch-routed
        launch — shard_map-wrapped pallas on TPU, the bit-identical jnp
        chain on xla."""
        key = ("stage", s, bp)
        if key in self._steps:
            return self._steps[key]

        def step(params, h, eff):
            self._count_trace(key)
            h2 = self.family.apply_stage(params, h, s, self.cfg)
            logits = self.family.apply_exit(params, h2, s, self.cfg)
            if self.confidence == "softmax-max":
                from repro.kernels import dispatch as KD
                conf, _, pred, fire = KD.exit_gate(logits, eff,
                                                   **self.kernel_kw)
                return h2, conf, pred, fire > 0
            conf = self._conf_fn(logits)
            pred = jnp.argmax(logits, axis=-1)
            return h2, conf, pred, conf > eff

        self._steps[key] = jax.jit(step, out_shardings=self._row)
        return self._steps[key]

    def _stage_fwd_step(self, s: int, bp: int):
        """Forward-only stage for bucket ``bp`` — the head-skip variant
        of ``_stage_step`` for gates the predictor ruled out: no exit
        head, no gate launch, and (host-side) no fire/conf sync, since
        by the conservative bound every row survives."""
        key = ("stage-fwd", s, bp)
        if key in self._steps:
            return self._steps[key]

        def step(params, h):
            self._count_trace(key)
            return self.family.apply_stage(params, h, s, self.cfg)

        self._steps[key] = jax.jit(step, out_shardings=self._row)
        return self._steps[key]

    def _fold_step(self, bp: int):
        """Compiled telemetry fold for the compacted path."""
        key = ("fold", bp)
        if key in self._steps:
            return self._steps[key]

        def step(state, exit_idx, pred, conf, macs, valid):
            self._count_trace(key)
            return self._fold_traced(state, exit_idx, pred, conf, macs,
                                     valid)

        self._steps[key] = jax.jit(step, donate_argnums=(0,),
                                   out_shardings=self._state_sh)
        return self._steps[key]

    # ------------------------------------------------------------------
    # inference
    # ------------------------------------------------------------------
    def infer(self, x, mode: str = "masked", record: bool | None = None,
              alpha=None, pad_to: int | None = None,
              min_exit: int = 0) -> dict:
        """Serve one request batch through the compiled path.

        mode="masked"    — one jitted step (serving hot path).
        mode="compacted" — compiled stage-segmented path (FLOP savings).
        mode="eager"     — the parent's eager masked pass (oracle;
                           never records).
        record — fold serving counters + the §II.C window into the
                 sharded state (default ON for the compiled modes —
                 they ARE the serving path — and OFF for the oracle).
        alpha  — optional (B,) admission-time difficulty (see
                 ``DartEngine.infer``).
        pad_to — accepted for API parity and ignored: every compiled
                 path already pads to ``bucket_key(B)`` internally.
        min_exit — STATIC head-skip depth (see ``DartEngine.infer``):
                 compiled steps for gates s < min_exit skip the exit
                 head + fused gate launches; with the conservative
                 bound decisions are unchanged.  The eager oracle
                 ignores it."""
        if not 0 <= int(min_exit) < self.n_exits:
            raise ValueError(f"min_exit {min_exit} out of range for "
                             f"{self.n_exits} exits")
        min_exit = int(min_exit)
        if mode == "eager":
            return super()._infer_masked(np.asarray(x), record=False,
                                         alpha=alpha)
        if mode not in ("masked", "compacted"):
            raise ValueError(
                f"unknown mode {mode!r}; known: masked, compacted, eager")
        record = True if record is None else record
        x = np.asarray(x)
        b = x.shape[0]
        if b > self.compactor.max_bucket:
            parts = [self._infer_chunk(
                x[a:z], mode, record,
                alpha=None if alpha is None else alpha[a:z],
                min_exit=min_exit)
                for a, z in self.compactor.chunks(b)]
            out = {k: np.concatenate([p[k] for p in parts])
                   for k in ("pred", "conf", "exit_idx", "alpha", "macs")}
            out["latency_s"] = sum(p["latency_s"] for p in parts)
        else:
            out = self._infer_chunk(x, mode, record, alpha=alpha,
                                    min_exit=min_exit)
        if record:
            self._maybe_update()
        return out

    def _pad_batch(self, x, bp):
        pad = self.compactor.pad(x.astype(np.float32, copy=False), bp)
        valid = np.zeros(bp, np.float32)
        valid[:x.shape[0]] = 1.0
        return (jax.device_put(jnp.asarray(pad), self._row),
                jax.device_put(jnp.asarray(valid), self._row))

    def _infer_chunk(self, x, mode, record, alpha=None,
                     min_exit: int = 0) -> dict:
        t0 = time.time()
        b = x.shape[0]
        bp = self.bucket_key(b)
        if mode == "masked":
            xp, valid = self._pad_batch(x, bp)
            step = self._masked_step(bp, record, alpha is not None,
                                     min_exit=min_exit)
            if alpha is None:
                self.state, out = step(self.params, self.state, xp, valid)
            else:
                ap = jax.device_put(jnp.asarray(self.compactor.pad(
                    np.asarray(alpha, np.float32), bp)), self._row)
                self.state, out = step(self.params, self.state, xp, valid,
                                       ap)
            # Outputs stay ON DEVICE (lazy): a serving loop that doesn't
            # read them immediately pipelines compiled steps back to
            # back through the donated state chain.  np.asarray() on any
            # value materializes it.
            res = {k: v[:b] for k, v in out.items()}
        else:
            res = self._compacted_chunk(x, bp, record, alpha=alpha,
                                        min_exit=min_exit)
        if record:
            self._pending += b
        res["latency_s"] = time.time() - t0
        self.total_latency_s += res["latency_s"]
        return res

    def _compacted_chunk(self, x, bp, record, alpha=None,
                         min_exit: int = 0) -> dict:
        if not self.family.staged:
            raise ValueError(
                f"compacted mode needs a staged family; "
                f"{type(self.cfg).__name__} is not staged — use "
                f"mode='masked'")
        b = x.shape[0]
        xp, valid = self._pad_batch(x, bp)
        alpha = np.asarray(self._alpha(xp))[:b] if alpha is None \
            else np.asarray(alpha, np.float32)

        out_pred = np.zeros(b, np.int64)
        out_conf = np.zeros(b, np.float32)
        out_exit = np.zeros(b, np.int64)

        coef = np.asarray(self._coef_traced(self.state), np.float32)
        tau = np.asarray(self.state.tau, np.float32)
        beta_diff = float(self.state.beta_diff)

        h_active = self._stem(self.params, xp)[:b]
        active = np.arange(b)
        alpha_active = alpha
        for s in range(self.n_exits):
            n = len(active)
            sp = self.bucket_key(n)
            if s < min_exit and s < self.n_exits - 1:
                # ruled-out gate: forward-only compiled stage — no exit
                # head, no gate launch, no fire/conf host sync, no
                # compaction (every row provably survives)
                h_pad = jax.device_put(
                    self.compactor.pad(jnp.asarray(h_active), sp),
                    self._row)
                h_active = self._stage_fwd_step(s, sp)(
                    self.params, h_pad)[:n]
                continue
            if s < self.n_exits - 1:
                eff = np.asarray(TH.stage_threshold(
                    tau[s], coef[s], alpha_active, beta_diff))
                # padded lanes get an unreachable threshold -> never fire
                eff_pad = self.compactor.pad(
                    eff.astype(np.float32), sp, fill=2.0)
            else:
                # final gate always accepts (Alg. 1 line 12)
                eff_pad = np.full(sp, -1.0, np.float32)
            h_pad = jax.device_put(
                self.compactor.pad(jnp.asarray(h_active), sp), self._row)
            eff_pad = jax.device_put(jnp.asarray(eff_pad), self._row)
            h2, conf, pred, fire = self._stage_step(s, sp)(
                self.params, h_pad, eff_pad)
            fire = np.asarray(fire[:n])
            conf = np.asarray(conf[:n])
            pred = np.asarray(pred[:n])

            done = active[fire]
            out_pred[done] = pred[fire]
            out_conf[done] = conf[fire]
            out_exit[done] = s
            keep = ~fire
            if not keep.any():
                break
            h_active = self.compactor.gather(h2[:n], np.nonzero(keep)[0])
            alpha_active = alpha_active[keep]
            active = active[keep]

        macs = self.cum_costs[out_exit].astype(np.float32)
        if record:
            ei = self.compactor.pad(out_exit.astype(np.int32), bp)
            pr = self.compactor.pad(out_pred.astype(np.int32), bp)
            cf = self.compactor.pad(out_conf, bp)
            mc = self.compactor.pad(macs, bp)
            self.state = self._fold_step(bp)(
                self.state, jnp.asarray(ei), jnp.asarray(pr),
                jnp.asarray(cf), jnp.asarray(mc), valid)
        return {"pred": out_pred, "conf": out_conf, "exit_idx": out_exit,
                "alpha": alpha, "macs": macs}

    # ------------------------------------------------------------------
    # §II.C adaptation + metering (cross-replica reductions)
    # ------------------------------------------------------------------
    def _maybe_update(self):
        # self._pending mirrors sum(state.since_update) host-side so the
        # schedule check never blocks on the in-flight state.
        if self.adapt and self._pending >= self.update_every:
            self.update()

    def update(self) -> None:
        """One §II.C periodic refinement over the MERGED window: all
        replicas' ring buffers are reduced into one view, both
        adaptation laws + UCB1 run once, and the new (shared) policy
        coefficients are re-replicated."""
        s = self.state
        merged = AD.periodic_update(ST.merged_adaptive(s), self.acfg,
                                    beta_opt=float(s.beta_opt))
        _, new_shared = ST.split_adaptive(merged)
        bufs, _ = ST.split_adaptive(s.adaptive)
        self.state = dataclasses.replace(
            s, adaptive={**new_shared, **bufs},
            since_update=jnp.zeros_like(s.since_update))
        self._pending = 0
        self._policy_mirror = None
        self._commit()

    def calibrate(self, data, **kw):
        pol = super().calibrate(data, **kw)
        self._commit()
        return pol

    def record_requests(self, latencies_ms, missed=None) -> None:
        super().record_requests(latencies_ms, missed)
        # Re-pin the freshly host-written latency leaves so the next
        # donated step sees the same (replicated) layout every time.
        s = self.state
        self.state = dataclasses.replace(
            s, lat_ms=jax.device_put(s.lat_ms, self._repl),
            lat_ptr=jax.device_put(s.lat_ptr, self._repl),
            lat_count=jax.device_put(s.lat_count, self._repl),
            deadline_miss=jax.device_put(s.deadline_miss, self._repl))

    def record_quotes(self, quotes_ms, realized_ms) -> None:
        super().record_quotes(quotes_ms, realized_ms)
        s = self.state
        self.state = dataclasses.replace(
            s, quote_ms_sum=jax.device_put(s.quote_ms_sum, self._repl),
            quote_err_ms_sum=jax.device_put(s.quote_err_ms_sum,
                                            self._repl),
            quote_count=jax.device_put(s.quote_count, self._repl))

    def restore_state(self, path: str, step: int | None = None):
        step = super().restore_state(path, step)
        self._pending = int(np.sum(np.asarray(self.state.since_update)))
        self._commit()
        return step

    def stats(self) -> dict:
        """Global serving statistics: counters summed over replicas,
        §II.C window statistics over the merged window."""
        from repro.obs import stats as OBS_STATS
        out = OBS_STATS.engine_summary(
            ST.telemetry_totals(self.state, sharded=True))
        out.update(
            total_latency_s=self.total_latency_s,
            active_strategy=AD.STRATEGIES[
                int(self.state.adaptive["active_strategy"])],
            replicas=self.n_replicas,
            served_per_replica=np.asarray(self.state.served))
        if out["served"]:
            w = AD.window_stats(ST.merged_adaptive(self.state), self.acfg)
            out["window"] = {k: np.asarray(v) for k, v in w.items()}
        return OBS_STATS.attach_requests(out, self.state)
