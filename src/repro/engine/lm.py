"""LM decode engine — early-exit autoregressive serving on the DART gate.

The LM analogue of :class:`repro.engine.DartEngine` (re-homed from the
long-deleted ``repro.runtime.lm_server``, built on the shared
:class:`BatchCompactor` and :class:`EngineState`): per decode step the
layer stack runs stage-by-stage; exited samples *skip* the remaining
stages — their KV entries are filled by CALM-style state propagation —
and survivors (plus their cache rows) are compacted into power-of-two
buckets.

Two execution paths serve bit-identical decisions (ISSUE 4 tentpole):

* ``mode="eager"`` — the reference oracle: each stage dispatches its
  pieces (stage layers, exit head, gate, KV propagation, cache
  scatter) as separate ops from Python.
* ``mode="sharded"`` — constructed with ``mesh=make_serving_mesh()``:
  ONE donated-cache jitted program per ``(stage, bucket)`` fusing the
  stage forward, per-token confidence, the Eq. 8 decode-time difficulty
  EMA (embed step), Eq. 19 / Alg. 1 stage-threshold routing, CALM KV
  propagation for the exited rows AND the telemetry fold.  The KV
  cache, the hidden-state buffer and the :class:`EngineState` live as
  ``NamedSharding``-annotated donated pytrees (batch rows sharded over
  the ``("data",)`` mesh, policy replicated, telemetry per replica), so
  a decode step never reallocates the cache and never round-trips state
  through the host.  Compile caches are keyed by ``engine.bucket_key``
  — the same ``BatchCompactor`` bucket ∘ replica-multiple key the image
  engines and the async scheduler share.

The exit gate uses the ``lm-token`` confidence functional and the
``token_difficulty_ema`` decode-time difficulty estimator from the
engine registries.  Inside the fused step the whole exit head —
rmsnorm → unembed matmul → softmax confidence → Eq. 19 threshold gate —
is ONE ``repro.kernels.dispatch`` call (ISSUE 5 tentpole): dispatch
shard_maps the fused Pallas exit-head kernel over the ``("data",)``
axis on TPU (solving the "pallas_call does not partition under GSPMD"
blocker) and lowers to the bit-identical jnp chain on xla backends,
so the eager-oracle guarantee is unchanged on this CPU container.

MoE caveat: capacity-based expert dispatch makes a token's output
depend on which other tokens share its batch, so for MoE configs the
bucket-padded sharded path is not bit-identical to eager survivor
compaction; the oracle guarantee covers dense configs.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import adaptive as AD
from repro.core import difficulty as DIFF
from repro.core import thresholds as TH
from repro.core.routing import DartParams
from repro.engine import registry as REG
from repro.engine import state as ST
from repro.engine.compactor import BatchCompactor
from repro.engine.state import EngineState
from repro.models import layers as L
from repro.models import transformer_lm as TLM


def _stages(cfg):
    """[(start, end)) layer ranges; stage k ends at exit_layers[k]."""
    bounds = [0] + [e + 1 for e in sorted(cfg.exit_layers)] + [cfg.n_layers]
    return [(a, b) for a, b in zip(bounds[:-1], bounds[1:])]


def _stage_apply(params, x, cache_sl, cache_index, *, cfg, a, b):
    """Run layers [a, b) of the stack for one decode position.

    x: (B', 1, D); cache_sl: per-layer cache rows for exactly these
    layers.  Shared verbatim by the eager per-stage path and the fused
    sharded step, so both compute identical values row for row."""
    cos, sin = L.rope_freqs(
        cfg.qk_rope_dim if cfg.attn_kind == "mla" else cfg.hd,
        cache_sl[0]["c_kv"].shape[1] if cfg.attn_kind == "mla"
        else cache_sl[0]["k"].shape[1], cfg.rope_theta)
    new_sl = []
    for j, i in enumerate(range(a, b)):
        p = params["layers"][i]
        h = L.rmsnorm(p["attn_norm"], x)
        if cfg.attn_kind == "mla":
            att, c = L.mla_decode(p["attn"], h, cos, sin,
                                  cache_sl[j], cache_index)
        else:
            att, c = L.gqa_decode(p["attn"], h, cos, sin,
                                  cache_sl[j], cache_index)
        new_sl.append(c)
        x = x + att
        h2 = L.rmsnorm(p["ffn_norm"], x)
        if cfg.layer_is_moe(i):
            from repro.models.moe import moe_apply
            f, _ = moe_apply(p["moe"], h2, cfg.moe, ep_mode=cfg.moe_ep_mode)
        else:
            f = L.swiglu(p["ffn"], h2)
        x = x + f
    return x, new_sl


class LMDecodeEngine:
    """Early-exit LM decoding behind the engine/session API.

        engine = LMDecodeEngine(cfg, params, dart)            # eager
        engine = LMDecodeEngine(cfg, params, dart,
                                mesh=make_serving_mesh())      # sharded
        tokens, stages = engine.generate(prompts, n_new=16)
        session = engine.session()      # queue-backed concurrent callers

    ``generate`` defaults to the sharded jitted path when a mesh was
    given and to the eager path otherwise; ``mode="eager"`` always runs
    the oracle.  All policy + telemetry lives in ``engine.state`` (an
    :class:`EngineState`), checkpointable via ``save_state`` /
    ``restore_state`` exactly like the classifier engines.
    """

    def __init__(self, cfg, params, dart: DartParams, *,
                 buckets=(1, 2, 4, 8, 16, 32, 64, 128),
                 confidence: str = "lm-token", mesh=None,
                 data_axis: str = "data"):
        assert not cfg.layer_scan
        self.cfg = cfg
        self.params = params
        self.compactor = BatchCompactor(buckets)
        self.mesh = mesh
        self.confidence = confidence
        self._conf_fn = REG.get_confidence(confidence)
        self.stages = _stages(cfg)
        self.n_exits = len(self.stages)
        self.exit_names = [str(i) for i in sorted(cfg.exit_layers)] \
            + ["final"]
        # cumulative layer fraction spent by a token exiting at stage s
        self.cum_costs = np.asarray(
            [b / cfg.n_layers for _, b in self.stages], np.float32)
        self.stats_exit = np.zeros(len(self.stages), np.int64)
        self.layers_run = 0
        self.layers_skipped = 0
        self._steps: dict = {}        # cache key -> compiled callable
        self.trace_counts: dict = {}  # cache key -> number of traces

        acfg = AD.AdaptiveConfig(n_exits=self.n_exits,
                                 n_classes=min(cfg.vocab, 64))
        self.acfg = acfg
        self.state = EngineState.create(self.n_exits, acfg, dart)

        if mesh is not None:
            from repro.engine.sharded import _silence_donation_warning
            _silence_donation_warning()
            self.data_axis = data_axis
            self.n_replicas = int(mesh.shape[data_axis])
            self.replica_multiple = self.n_replicas
            self._repl = NamedSharding(mesh, P())
            self._row = NamedSharding(mesh, P(data_axis))
            self.params = jax.device_put(self.params, self._repl)
            # Donated steps would invalidate buffers the caller still
            # holds (its DartParams, a sibling engine) — take ownership
            # with a deep copy before placing the state.
            owned = jax.tree.map(
                lambda a: jnp.array(a, copy=True),
                ST.shard_telemetry(self.state, self.n_replicas))
            self._state_sh = ST.state_shardings(owned, self._repl,
                                                self._row)
            self.state = jax.device_put(owned, self._state_sh)
        else:
            self.n_replicas = 1
            self.replica_multiple = 1
        # kernels.dispatch shard_maps pallas backends over the data axis
        # inside the fused decode steps (xla partitions under GSPMD)
        self.kernel_kw = {} if mesh is None \
            else {"mesh": mesh, "axis": data_axis}

        cfgc = cfg
        self._stage_fns = [
            jax.jit(partial(_stage_apply, cfg=cfgc, a=a, b=b))
            for a, b in self.stages]
        self._exit_logits = [
            jax.jit(partial(lambda params, h, name: TLM.exit_logits(
                params, cfgc, h, name), name=n)) for n in self.exit_names]
        self._propagate = [
            jax.jit(partial(lambda params, h, cache, idx, fl:
                            TLM.lm_kv_propagate(params, h, cfgc, cache, idx,
                                                from_layer=fl), fl=b))
            for _, b in self.stages]
        self._embed = jax.jit(lambda params, t: L.embed(
            params["embed"], t).astype(cfgc.compute_dtype))

    # ------------------------------------------------------------------
    @property
    def dart(self) -> DartParams:
        """The routing-parameter view (reads the live EngineState)."""
        return self.state.dart

    def bucket_key(self, n: int) -> int:
        """THE compile-cache key for an ``n``-row decode bucket: the
        ``BatchCompactor`` bucket rounded up to a replica multiple —
        the same keying the image engines and the async scheduler
        use, so every serving path agrees on what shares a compiled
        shape."""
        return self.compactor.padded_size(n, self.replica_multiple)

    def session(self, cfg=None, **kw):
        """Queue-backed session handle: drive this decode engine through
        the async scheduler (deadlines, priorities, consolidation of
        concurrent ``generate`` callers into shared bucketed decode
        loops).  See :class:`repro.serving.LMDecodeSession`."""
        from repro.serving.lm_session import LMDecodeSession
        return LMDecodeSession(self, cfg=cfg, **kw)

    # ------------------------------------------------------------------
    # state round-trip (same machinery as DartEngine)
    # ------------------------------------------------------------------
    def save_state(self, path: str, step: int = 0):
        from repro import checkpoint as CK
        return CK.save(path, step, self.state)

    def restore_state(self, path: str, step: int | None = None):
        self.state, step = ST.restore_with_migration(path, self.state, step)
        if self.mesh is not None:
            self._commit()
        return step

    def _commit(self):
        self.state = jax.device_put(self.state, self._state_sh)

    def stats(self) -> dict:
        """Decode telemetry: per-stage exit counts, tokens served, mean
        layer fraction spent (counters reduced over replicas when
        sharded)."""
        if self.mesh is not None:
            tel = {k: np.asarray(v) for k, v in
                   ST.reduce_telemetry(self.state).items()}
        else:
            tel = {f: np.asarray(getattr(self.state, f))
                   for f in ST.TELEMETRY_FIELDS}
        served = int(tel["served"])
        counts = tel["exit_counts"]
        out = {"served": served,
               "exit_counts": counts,
               "exit_frac": counts / max(served, 1),
               "total_macs": float(tel["total_macs"]),
               "mean_macs": float(tel["total_macs"]) / max(served, 1),
               "layers_run": self.layers_run,
               "layers_skipped": self.layers_skipped,
               "replicas": self.n_replicas}
        req = ST.request_stats(self.state)
        if req["requests"]:
            out["requests"] = req
        return out

    def record_requests(self, latencies_ms, missed=None) -> None:
        """Fold completed-request latency/deadline telemetry into the
        engine state (host-side write; the LM session calls this once
        per flushed decode bucket)."""
        self.state = ST.record_requests(self.state, latencies_ms, missed)
        if self.mesh is not None:
            s = self.state
            self.state = dataclasses.replace(
                s, lat_ms=jax.device_put(s.lat_ms, self._repl),
                lat_ptr=jax.device_put(s.lat_ptr, self._repl),
                lat_count=jax.device_put(s.lat_count, self._repl),
                deadline_miss=jax.device_put(s.deadline_miss, self._repl))

    def _count_trace(self, key):
        # Runs in the Python body of a step function, i.e. once per trace.
        self.trace_counts[key] = self.trace_counts.get(key, 0) + 1

    # ------------------------------------------------------------------
    # eager path (the oracle)
    # ------------------------------------------------------------------
    def init_cache(self, batch, max_len):
        return TLM.lm_init_cache(self.cfg, batch, max_len)

    def prefill(self, tokens, cache):
        cache, _ = TLM.lm_prefill(self.params, jnp.asarray(tokens),
                                  self.cfg, cache)
        return cache

    def decode_step(self, tokens, cache, cache_index, alpha, *,
                    record: bool | None = None):
        """tokens: (B,) int; cache: full-depth list; alpha: (B,) difficulty.
        Returns (next_token (B,), exit_stage (B,), new_cache, new_alpha).

        ``record``: fold the step into ``state`` telemetry AND the host
        diagnostics (stats_exit / layers_run / layers_skipped).
        Defaults on for a pure-eager engine and OFF on a sharded one —
        there the eager path is the oracle, and a host-side fold would
        both pollute serving telemetry and broadcast scalar adds over
        the state's leading replica axis."""
        if record is None:
            record = self.mesh is None
        b = tokens.shape[0]
        x_full = self._embed(self.params, jnp.asarray(tokens)[:, None])
        alpha = np.asarray(DIFF.token_difficulty_ema(jnp.asarray(alpha),
                                                     x_full))
        tau = np.asarray(self.state.tau, np.float32)
        coef = np.asarray(self.state.coef, np.float32)
        beta_diff = float(self.state.beta_diff)

        out_tok = np.zeros(b, np.int64)
        out_stage = np.zeros(b, np.int64)
        active = np.arange(b)
        x = x_full
        n_stages = len(self.stages)
        cache = list(cache)

        for s, (a, bnd) in enumerate(self.stages):
            n = len(active)
            bucket = self.compactor.bucket_for(n)
            act = jnp.asarray(active)
            # gather cache rows for the active set (+pad with row 0)
            gather_idx = self.compactor.pad(np.asarray(active), bucket,
                                            fill=0).astype(np.int64)
            cache_sl = [jax.tree.map(
                lambda c: jnp.take(c, jnp.asarray(gather_idx), axis=0),
                cache[i]) for i in range(a, bnd)]
            x_pad = self.compactor.pad(x, bucket)
            x_new, new_sl = self._stage_fns[s](self.params, x_pad, cache_sl,
                                               cache_index)
            # scatter updated cache rows back
            for j, i in enumerate(range(a, bnd)):
                cache[i] = jax.tree.map(
                    lambda full, sl: full.at[act].set(sl[:n]),
                    cache[i], new_sl[j])
            if record:
                self.layers_run += (bnd - a) * n

            logits = self._exit_logits[s](self.params, x_new[:n, 0])
            conf = self._conf_fn(logits)
            pred = jnp.argmax(logits, -1)
            conf, pred = np.asarray(conf), np.asarray(pred)

            if s < n_stages - 1:
                eff = np.asarray(TH.stage_threshold(
                    tau[s], coef[s], alpha[active], beta_diff))
                fire = conf > eff
            else:
                fire = np.ones(n, bool)
            done = active[fire]
            out_tok[done] = pred[fire]
            out_stage[done] = s
            if record:
                self.stats_exit[s] += int(fire.sum())

            if s < n_stages - 1 and fire.any():
                # CALM state propagation for the exited rows
                h_exit = x_new[:n][jnp.asarray(np.nonzero(fire)[0])]
                sub = [jax.tree.map(lambda c: jnp.take(
                    c, jnp.asarray(done), axis=0), cache[i])
                    for i in range(len(cache))]
                sub = self._propagate[s](self.params, h_exit[:, 0], sub,
                                         cache_index)
                for i in range(self.stages[s][1], self.cfg.n_layers):
                    cache[i] = jax.tree.map(
                        lambda full, sl: full.at[jnp.asarray(done)].set(sl),
                        cache[i], sub[i])
                if record:
                    self.layers_skipped += \
                        (self.cfg.n_layers - bnd) * int(fire.sum())
            keep = ~fire
            if not keep.any():
                break
            x = x_new[:n][jnp.asarray(np.nonzero(keep)[0])]
            active = active[keep]
        if record:
            self._record_host(out_stage)
        return out_tok, out_stage, cache, alpha

    def _record_host(self, out_stage) -> None:
        """Eager-path telemetry fold (numpy, one decode step)."""
        s = self.state
        b = len(out_stage)
        counts = np.bincount(out_stage, minlength=self.n_exits)
        self.state = dataclasses.replace(
            s, served=s.served + jnp.asarray(b, jnp.int32),
            exit_counts=s.exit_counts + jnp.asarray(counts, jnp.int32),
            total_macs=s.total_macs + float(np.sum(
                self.cum_costs[out_stage])),
            since_update=s.since_update + jnp.asarray(b, jnp.int32))

    # ------------------------------------------------------------------
    # sharded path: fused per-(stage, bucket) donated-cache steps
    # ------------------------------------------------------------------
    def _embed_step(self, bp: int):
        """Fused embed + Eq. 8 decode-time difficulty EMA for a
        ``bp``-row bucket (the per-decode-step prologue)."""
        key = ("lm-embed", bp)
        if key in self._steps:
            return self._steps[key]
        cfg = self.cfg

        def step(params, toks, alpha):
            self._count_trace(key)
            x_full = L.embed(params["embed"],
                             toks[:, None]).astype(cfg.compute_dtype)
            alpha = DIFF.token_difficulty_ema(alpha, x_full)
            return x_full, alpha

        self._steps[key] = jax.jit(step, donate_argnums=(2,),
                                   out_shardings=self._row)
        return self._steps[key]

    def _prefill_step(self, bp: int, plen: int, max_len: int):
        key = ("lm-prefill", bp, plen, max_len)
        if key in self._steps:
            return self._steps[key]
        cfg = self.cfg

        def step(params, tokens, cache):
            self._count_trace(key)
            cache, _ = TLM.lm_prefill(params, tokens, cfg, cache)
            return cache

        self._steps[key] = jax.jit(step, donate_argnums=(2,),
                                   out_shardings=self._row)
        return self._steps[key]

    def _stage_step(self, s: int, sp: int, bp: int, max_len: int):
        """ONE compiled decode step for (stage ``s``, survivor bucket
        ``sp``) over a ``bp``-row generate bucket: cache-row gather,
        stage forward, exit head + confidence, Eq. 19 threshold + Alg. 1
        gate, token/stage scatter, CALM KV propagation for the fired
        rows, telemetry fold.  The cache, hidden buffer, token buffers
        and EngineState are donated, so repeated steps re-use their
        buffers (no realloc)."""
        key = ("lm-stage", s, sp, bp, max_len)
        if key in self._steps:
            return self._steps[key]
        a, bnd = self.stages[s]
        cfg = self.cfg
        final = s == len(self.stages) - 1
        exit_name = self.exit_names[s]

        def step(params, state, cache, x_full, toks, stg, idx, valid,
                 alpha, cache_index):
            self._count_trace(key)
            # gather the survivors' rows; padded lanes (idx == bp) clip
            # to the last (padding) row and are masked by ``valid``
            x = jnp.take(x_full, idx, axis=0, mode="clip")
            cache_sl = [jax.tree.map(
                lambda c: jnp.take(c, idx, axis=0, mode="clip"), cache[i])
                for i in range(a, bnd)]
            x_new, new_sl = _stage_apply(params, x, cache_sl, cache_index,
                                         cfg=cfg, a=a, b=bnd)
            cache = list(cache)
            for j, i in enumerate(range(a, bnd)):
                cache[i] = jax.tree.map(
                    lambda full, sl: full.at[idx].set(sl, mode="drop"),
                    cache[i], new_sl[j])
            x_full = x_full.at[idx].set(x_new, mode="drop")

            vb = valid > 0
            if final:
                # Alg. 1 line 12: the final head always accepts
                eff = jnp.full(idx.shape, -1.0, jnp.float32)
            else:
                al = jnp.take(alpha, idx, mode="clip")
                eff = TH.stage_threshold(state.tau[s], state.coef[s], al,
                                         state.beta_diff)
            conf, pred, fire = self._head_traced(params, x_new[:, 0],
                                                 exit_name, eff)
            # the unconditional final accept must not depend on the
            # confidence functional's range (the -1.0 eff is only a
            # belt-and-braces sentinel for bounded functionals)
            fire = vb if final else (fire & vb)
            idx_fire = jnp.where(fire, idx, bp)  # non-fired -> dropped
            toks = toks.at[idx_fire].set(pred.astype(toks.dtype),
                                         mode="drop")
            stg = stg.at[idx_fire].set(s, mode="drop")
            if not final:
                cache = self._propagate_traced(params, cache, x_new[:, 0],
                                               idx_fire, cache_index, bnd)
            state = self._fold_decode(state, s, fire)
            return state, (cache, x_full, toks, stg, fire)

        self._steps[key] = jax.jit(
            step, donate_argnums=(1, 2, 3, 4, 5),
            out_shardings=(self._state_sh, self._row))
        return self._steps[key]

    def _head_traced(self, params, h, exit_name: str, eff):
        """The decode-time exit decision for one stage: rmsnorm → unembed
        matmul → softmax confidence → Eq. 19 gate, as ONE
        ``kernels.dispatch`` call for the ``lm-token`` functional (the
        fused Pallas exit-head kernel on TPU, shard_map-wrapped over the
        data axis; the bit-identical jnp chain on xla).  Returns
        (conf, pred, fire bool)."""
        cfg = self.cfg
        if self.confidence == "lm-token":
            from repro.kernels import dispatch as KD
            norm = params["final_norm"] if exit_name == "final" \
                else params["exit_heads"][exit_name]["norm"]
            conf, pred, fire = KD.exit_head_gate(
                h, norm["scale"], TLM._unembed_table(params, cfg), eff,
                **self.kernel_kw)
            return conf, pred, fire > 0
        logits = TLM.exit_logits(params, cfg, h, exit_name)
        conf = self._conf_fn(logits)
        return conf, jnp.argmax(logits, -1), conf > eff

    def _propagate_traced(self, params, cache, h_exit, idx_fire,
                          cache_index, from_layer):
        """CALM propagation inside the fused step: the SAME projection
        math as the eager path (``transformer_lm.lm_kv_project`` is the
        one implementation both share), scattered straight into rows
        ``[idx_fire, cache_index]`` of the full donated cache
        (non-fired rows carry the out-of-bounds index and are
        dropped)."""
        cfg = self.cfg
        rows = TLM.lm_kv_project(params, h_exit, cfg, cache, cache_index,
                                 from_layer)
        cache = list(cache)
        for i, r in zip(range(from_layer, cfg.n_layers), rows):
            c = dict(cache[i])
            for name, val in r.items():
                c[name] = c[name].at[idx_fire, cache_index].set(
                    val[:, 0].astype(c[name].dtype), mode="drop")
            cache[i] = c
        return cache

    def _fold_decode(self, state: EngineState, s: int, fire) -> EngineState:
        """Per-replica telemetry fold for one (stage, bucket) step: each
        replica's segment of the padded bucket lands in its own
        counters (``stats()`` reduces across replicas)."""
        r = self.n_replicas
        per = fire.shape[0] // r
        f = fire.astype(jnp.float32).reshape(r, per)
        n_new = f.sum(1).astype(jnp.int32)
        return dataclasses.replace(
            state,
            served=state.served + n_new,
            exit_counts=state.exit_counts.at[:, s].add(n_new),
            total_macs=state.total_macs
            + n_new.astype(jnp.float32) * float(self.cum_costs[s]),
            since_update=state.since_update + n_new)

    # ------------------------------------------------------------------
    # generation
    # ------------------------------------------------------------------
    def generate(self, prompt_tokens: np.ndarray, n_new: int,
                 max_len: int | None = None, mode: str | None = None):
        """prompt_tokens: (B, S0).  Greedy generation with early exits.
        Returns (tokens (B, n_new), exit stages (B, n_new)).

        mode — "sharded" (default when built with ``mesh=``): the fused
        donated-cache compiled decode loop; "eager": the per-stage
        oracle path (never records telemetry on a sharded engine).
        Batches larger than the biggest bucket are split into chunks
        (each chunk gets its own KV cache)."""
        if mode is None:
            mode = "sharded" if self.mesh is not None else "eager"
        if mode not in ("sharded", "eager"):
            raise ValueError(
                f"unknown mode {mode!r}; known: sharded, eager")
        if mode == "sharded" and self.mesh is None:
            raise ValueError(
                "mode='sharded' needs a mesh — construct with "
                "LMDecodeEngine(..., mesh=make_serving_mesh())")
        b, s0 = prompt_tokens.shape
        if b > self.compactor.max_bucket:
            outs, stgs = [], []
            for a, z in self.compactor.chunks(b):
                o, st = self.generate(prompt_tokens[a:z], n_new, max_len,
                                      mode=mode)
                outs.append(o)
                stgs.append(st)
            return np.concatenate(outs), np.concatenate(stgs)
        if mode == "sharded":
            return self._generate_sharded(prompt_tokens, n_new, max_len)
        return self._generate_eager(prompt_tokens, n_new, max_len)

    def _generate_eager(self, prompt_tokens, n_new, max_len=None):
        b, s0 = prompt_tokens.shape
        max_len = max_len or (s0 + n_new + 1)
        cache = self.init_cache(b, max_len)
        cache = self.prefill(prompt_tokens[:, :-1], cache)
        alpha = np.full((b,), 0.5, np.float32)
        toks = prompt_tokens[:, -1]
        out = []
        stages = []
        for t in range(n_new):
            # decode_step's default record already disables the fold on
            # a sharded engine (the eager path is the oracle there)
            toks, stage, cache, alpha = self.decode_step(
                toks, cache, s0 - 1 + t, alpha)
            out.append(toks.copy())
            stages.append(stage.copy())
        return np.stack(out, 1), np.stack(stages, 1)

    def _generate_sharded(self, prompt_tokens, n_new, max_len=None):
        cfg = self.cfg
        prompts = np.asarray(prompt_tokens)
        b, s0 = prompts.shape
        bp = self.bucket_key(b)
        max_len = max_len or (s0 + n_new + 1)
        cache = jax.device_put(self.init_cache(bp, max_len), self._row)
        pad = self.compactor.pad(prompts.astype(np.int64), bp)
        if s0 > 1:
            cache = self._prefill_step(bp, s0 - 1, max_len)(
                self.params, jnp.asarray(pad[:, :-1]), cache)
        alpha = jax.device_put(jnp.full((bp,), 0.5, jnp.float32),
                               self._row)
        toks = jax.device_put(jnp.asarray(pad[:, -1], jnp.int32),
                              self._row)
        stg = jax.device_put(jnp.zeros((bp,), jnp.int32), self._row)
        n_layers = cfg.n_layers
        out, stages_out = [], []
        for t in range(n_new):
            ci = s0 - 1 + t
            x_full, alpha = self._embed_step(bp)(self.params, toks, alpha)
            active = np.arange(b)
            for s, (a, bnd) in enumerate(self.stages):
                n = active.size
                sp = self.bucket_key(n)
                idx = np.full(sp, bp, np.int32)
                idx[:n] = active
                valid = np.zeros(sp, np.float32)
                valid[:n] = 1.0
                self.state, (cache, x_full, toks, stg, fire) = \
                    self._stage_step(s, sp, bp, max_len)(
                        self.params, self.state, cache, x_full, toks, stg,
                        jnp.asarray(idx), jnp.asarray(valid), alpha, ci)
                # the ONE host sync per stage: survivors are
                # data-dependent shapes
                fire_np = np.asarray(fire)[:n]
                nf = int(fire_np.sum())
                self.layers_run += (bnd - a) * n
                self.stats_exit[s] += nf
                if s < len(self.stages) - 1:
                    self.layers_skipped += (n_layers - bnd) * nf
                active = active[~fire_np]
                if active.size == 0:
                    break
            out.append(np.asarray(toks)[:b].astype(np.int64))
            stages_out.append(np.asarray(stg)[:b].astype(np.int64))
        return np.stack(out, 1), np.stack(stages_out, 1)
