"""LM decode engine — early-exit autoregressive serving on the DART gate.

The LM analogue of :class:`repro.engine.DartEngine` (re-homed from the
long-deleted ``repro.runtime.lm_server``, built on the shared
:class:`BatchCompactor` and :class:`EngineState`): per decode step the
layer stack runs stage-by-stage; exited samples *skip* the remaining
stages — their KV entries are filled by CALM-style state propagation —
and survivors (plus their cache rows) are compacted into power-of-two
buckets.

Two execution paths serve bit-identical decisions (ISSUE 4 tentpole):

* ``mode="eager"`` — the reference oracle: each stage dispatches its
  pieces (stage layers, exit head, gate, KV propagation, cache
  scatter) as separate ops from Python.
* ``mode="sharded"`` — constructed with ``mesh=make_serving_mesh()``:
  ONE donated-cache jitted program per ``(stage, bucket)`` fusing the
  stage forward, per-token confidence, the Eq. 8 decode-time difficulty
  EMA (embed step), Eq. 19 / Alg. 1 stage-threshold routing, CALM KV
  propagation for the exited rows AND the telemetry fold.  The KV
  cache, the hidden-state buffer and the :class:`EngineState` live as
  ``NamedSharding``-annotated donated pytrees (batch rows sharded over
  the ``("data",)`` mesh, policy replicated, telemetry per replica), so
  a decode step never reallocates the cache and never round-trips state
  through the host.  Compile caches are keyed by ``engine.bucket_key``
  — the same ``BatchCompactor`` bucket ∘ replica-multiple key the image
  engines and the async scheduler share.

The exit gate uses the ``lm-token`` confidence functional and the
``token_difficulty_ema`` decode-time difficulty estimator from the
engine registries.  Inside the fused step the whole exit head —
rmsnorm → unembed matmul → softmax confidence → Eq. 19 threshold gate —
is ONE ``repro.kernels.dispatch`` call (ISSUE 5 tentpole): dispatch
shard_maps the fused Pallas exit-head kernel over the ``("data",)``
axis on TPU (solving the "pallas_call does not partition under GSPMD"
blocker) and lowers to the bit-identical jnp chain on xla backends,
so the eager-oracle guarantee is unchanged on this CPU container.

MoE caveat: capacity-based expert dispatch makes a token's output
depend on which other tokens share its batch, so for MoE configs the
bucket-padded sharded path is not bit-identical to eager survivor
compaction; the oracle guarantee covers dense configs.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import adaptive as AD
from repro.core import difficulty as DIFF
from repro.core import thresholds as TH
from repro.core.routing import DartParams
from repro.engine import registry as REG
from repro.engine import state as ST
from repro.engine.compactor import (BatchCompactor, OutOfCapacity,
                                    PageAllocator, SlotPool)
from repro.engine.state import EngineState
from repro.models import layers as L
from repro.models import transformer_lm as TLM


def _stages(cfg):
    """[(start, end)) layer ranges; stage k ends at exit_layers[k]."""
    bounds = [0] + [e + 1 for e in sorted(cfg.exit_layers)] + [cfg.n_layers]
    return [(a, b) for a, b in zip(bounds[:-1], bounds[1:])]


def _stage_apply(params, x, cache_sl, cache_index, *, cfg, a, b):
    """Run layers [a, b) of the stack for one decode position.

    x: (B', 1, D); cache_sl: per-layer cache rows for exactly these
    layers.  Shared verbatim by the eager per-stage path and the fused
    sharded step, so both compute identical values row for row."""
    cos, sin = L.rope_freqs(
        cfg.qk_rope_dim if cfg.attn_kind == "mla" else cfg.hd,
        cache_sl[0]["c_kv"].shape[1] if cfg.attn_kind == "mla"
        else cache_sl[0]["k"].shape[1], cfg.rope_theta)
    new_sl = []
    for j, i in enumerate(range(a, b)):
        p = params["layers"][i]
        h = L.rmsnorm(p["attn_norm"], x)
        if cfg.attn_kind == "mla":
            att, c = L.mla_decode(p["attn"], h, cos, sin,
                                  cache_sl[j], cache_index)
        else:
            att, c = L.gqa_decode(p["attn"], h, cos, sin,
                                  cache_sl[j], cache_index)
        new_sl.append(c)
        x = x + att
        h2 = L.rmsnorm(p["ffn_norm"], x)
        if cfg.layer_is_moe(i):
            from repro.models.moe import moe_apply
            f, _ = moe_apply(p["moe"], h2, cfg.moe, ep_mode=cfg.moe_ep_mode)
        else:
            f = L.swiglu(p["ffn"], h2)
        x = x + f
    return x, new_sl


def _stage_apply_paged(params, x, pages_sl, page_table, page_idx, offset,
                       positions, *, cfg, a, b, gather_kw=None):
    """Run layers [a, b) for one decode position against the PAGED KV
    store — the continuous-batching mirror of :func:`_stage_apply`.

    x: (S, 1, D) — the full slot pool; ``positions`` is per-slot, so
    rows at different depths coexist in one launch.  ``page_idx`` is the
    write page per slot (out-of-range for rows that must not write) and
    ``page_table`` the read indirection; the per-layer math is the same
    functions the contiguous path uses, so values are bit-identical at
    equal padded view length."""
    psz = (pages_sl[0]["c_kv"] if cfg.attn_kind == "mla"
           else pages_sl[0]["k"]).shape[1]
    view_len = page_table.shape[1] * psz
    cos, sin = L.rope_freqs(
        cfg.qk_rope_dim if cfg.attn_kind == "mla" else cfg.hd,
        view_len, cfg.rope_theta)
    new_sl = []
    for j, i in enumerate(range(a, b)):
        p = params["layers"][i]
        h = L.rmsnorm(p["attn_norm"], x)
        if cfg.attn_kind == "mla":
            att, c = L.mla_decode_paged(p["attn"], h, cos, sin,
                                        pages_sl[j], page_table, page_idx,
                                        offset, positions,
                                        gather_kw=gather_kw)
        else:
            att, c = L.gqa_decode_paged(p["attn"], h, cos, sin,
                                        pages_sl[j], page_table, page_idx,
                                        offset, positions,
                                        gather_kw=gather_kw)
        new_sl.append(c)
        x = x + att
        h2 = L.rmsnorm(p["ffn_norm"], x)
        if cfg.layer_is_moe(i):
            from repro.models.moe import moe_apply
            f, _ = moe_apply(p["moe"], h2, cfg.moe, ep_mode=cfg.moe_ep_mode)
        else:
            f = L.swiglu(p["ffn"], h2)
        x = x + f
    return x, new_sl


class LMDecodeEngine:
    """Early-exit LM decoding behind the engine/session API.

        engine = LMDecodeEngine(cfg, params, dart)            # eager
        engine = LMDecodeEngine(cfg, params, dart,
                                mesh=make_serving_mesh())      # sharded
        tokens, stages = engine.generate(prompts, n_new=16)
        session = engine.session()      # queue-backed concurrent callers

    ``generate`` defaults to the sharded jitted path when a mesh was
    given and to the eager path otherwise; ``mode="eager"`` always runs
    the oracle.  All policy + telemetry lives in ``engine.state`` (an
    :class:`EngineState`), checkpointable via ``save_state`` /
    ``restore_state`` exactly like the classifier engines.
    """

    def __init__(self, cfg, params, dart: DartParams, *,
                 buckets=(1, 2, 4, 8, 16, 32, 64, 128),
                 confidence: str = "lm-token", mesh=None,
                 data_axis: str = "data"):
        assert not cfg.layer_scan
        self.cfg = cfg
        self.params = params
        self.compactor = BatchCompactor(buckets)
        self.mesh = mesh
        self.confidence = confidence
        self._conf_fn = REG.get_confidence(confidence)
        self.stages = _stages(cfg)
        self.n_exits = len(self.stages)
        self.exit_names = [str(i) for i in sorted(cfg.exit_layers)] \
            + ["final"]
        # cumulative layer fraction spent by a token exiting at stage s
        self.cum_costs = np.asarray(
            [b / cfg.n_layers for _, b in self.stages], np.float32)
        self.stats_exit = np.zeros(len(self.stages), np.int64)
        self.layers_run = 0
        self.layers_skipped = 0
        self._steps: dict = {}        # cache key -> compiled callable
        self.trace_counts: dict = {}  # cache key -> number of traces

        acfg = AD.AdaptiveConfig(n_exits=self.n_exits,
                                 n_classes=min(cfg.vocab, 64))
        self.acfg = acfg
        self.state = EngineState.create(self.n_exits, acfg, dart)

        if mesh is not None:
            from repro.engine.sharded import _silence_donation_warning
            _silence_donation_warning()
            self.data_axis = data_axis
            self.n_replicas = int(mesh.shape[data_axis])
            self.replica_multiple = self.n_replicas
            self._repl = NamedSharding(mesh, P())
            self._row = NamedSharding(mesh, P(data_axis))
            self.params = jax.device_put(self.params, self._repl)
            # Donated steps would invalidate buffers the caller still
            # holds (its DartParams, a sibling engine) — take ownership
            # with a deep copy before placing the state.
            owned = jax.tree.map(
                lambda a: jnp.array(a, copy=True),
                ST.shard_telemetry(self.state, self.n_replicas))
            self._state_sh = ST.state_shardings(owned, self._repl,
                                                self._row)
            self.state = jax.device_put(owned, self._state_sh)
        else:
            self.n_replicas = 1
            self.replica_multiple = 1
        # kernels.dispatch shard_maps pallas backends over the data axis
        # inside the fused decode steps (xla partitions under GSPMD)
        self.kernel_kw = {} if mesh is None \
            else {"mesh": mesh, "axis": data_axis}

        cfgc = cfg
        self._stage_fns = [
            jax.jit(partial(_stage_apply, cfg=cfgc, a=a, b=b))
            for a, b in self.stages]
        self._exit_logits = [
            jax.jit(partial(lambda params, h, name: TLM.exit_logits(
                params, cfgc, h, name), name=n)) for n in self.exit_names]
        self._propagate = [
            jax.jit(partial(lambda params, h, cache, idx, fl:
                            TLM.lm_kv_propagate(params, h, cfgc, cache, idx,
                                                from_layer=fl), fl=b))
            for _, b in self.stages]
        self._embed = jax.jit(lambda params, t: L.embed(
            params["embed"], t).astype(cfgc.compute_dtype))
        self._cont_default = None  # lazy decoder for generate("continuous")

    # ------------------------------------------------------------------
    @property
    def dart(self) -> DartParams:
        """The routing-parameter view (reads the live EngineState)."""
        return self.state.dart

    #: confidence functionals provably bounded above by 1.0, for which
    #: the Eq. 19 rule-out bound is sound (see thresholds.min_exit_bound)
    _BOUNDED_CONF = ("softmax-max", "lm-token")

    def min_exit_bound(self, alpha_lo: float = 0.0) -> int:
        """Sound per-batch ``min_exit`` under the CURRENT policy: gates
        0..m-1 can never fire for any row with decode-time difficulty
        ≥ ``alpha_lo``.  The routing alpha is the Eq. 8 decode EMA
        (infimum 0.0), so callers without a tighter bound pass 0.0."""
        if self.confidence not in self._BOUNDED_CONF or self.n_exits < 2:
            return 0
        tau, coef, beta_diff = self._policy_host()
        return TH.min_exit_bound(tau, coef, beta_diff, alpha_lo)

    def _policy_host(self):
        """Host mirror of (tau, coef, beta_diff), cached on the array
        identities so the serving hot path never re-syncs policy."""
        key = (id(self.state.tau), id(self.state.coef))
        cached = getattr(self, "_policy_mirror", None)
        if cached is None or cached[0] != key:
            self._policy_mirror = (key, (
                np.asarray(self.state.tau, np.float32),
                np.asarray(self.state.coef, np.float32),
                float(self.state.beta_diff)))
        return self._policy_mirror[1]

    def prompt_alpha(self, prompt_tokens) -> np.ndarray:
        """Admission-time Eq. 8 difficulty of a prompt batch (B, S):
        the token-domain estimator over the input embeddings — what the
        exit-depth predictor conditions on before any backbone layer
        runs.  Host numpy out; one jitted launch per prompt length."""
        toks = jnp.asarray(np.asarray(prompt_tokens))
        key = ("lm-prompt-alpha", toks.shape[1])
        if key not in self._steps:
            cfg = self.cfg

            def step(params, t):
                self._count_trace(key)
                x = L.embed(params["embed"], t).astype(cfg.compute_dtype)
                return DIFF.token_difficulty(x)

            self._steps[key] = jax.jit(step)
        return np.asarray(self._steps[key](self.params, toks))

    def bucket_key(self, n: int) -> int:
        """THE compile-cache key for an ``n``-row decode bucket: the
        ``BatchCompactor`` bucket rounded up to a replica multiple —
        the same keying the image engines and the async scheduler
        use, so every serving path agrees on what shares a compiled
        shape."""
        return self.compactor.padded_size(n, self.replica_multiple)

    def session(self, cfg=None, *, continuous: bool = False, **kw):
        """Queue-backed session handle: drive this decode engine through
        the async scheduler (deadlines, priorities, consolidation of
        concurrent ``generate`` callers into shared bucketed decode
        loops).  ``continuous=True`` returns the slot-refill session
        over a :class:`ContinuousLMDecoder` instead (requests stream
        through the slot pool; no bucket flushes).  See
        :class:`repro.serving.LMDecodeSession` /
        :class:`repro.serving.lm_session.LMContinuousSession`."""
        from repro.serving.lm_session import (LMContinuousSession,
                                              LMDecodeSession)
        if continuous:
            return LMContinuousSession(self, cfg=cfg, **kw)
        return LMDecodeSession(self, cfg=cfg, **kw)

    def continuous(self, n_slots=None, page_size=8, max_len=None):
        """A slot-based continuous-batching decoder over a paged KV
        cache (ISSUE 7 tentpole).  Each call returns a fresh
        :class:`ContinuousLMDecoder` (its slot pool and page store are
        private mutable serving state); compiled steps are cached on
        the ENGINE keyed by pool geometry, so decoders of the same
        shape share traces."""
        return ContinuousLMDecoder(self, n_slots=n_slots,
                                   page_size=page_size, max_len=max_len)

    # ------------------------------------------------------------------
    # state round-trip (same machinery as DartEngine)
    # ------------------------------------------------------------------
    def save_state(self, path: str, step: int = 0):
        from repro import checkpoint as CK
        return CK.save(path, step, self.state)

    def restore_state(self, path: str, step: int | None = None):
        self.state, step = ST.restore_with_migration(path, self.state, step)
        self._policy_mirror = None
        if self.mesh is not None:
            self._commit()
        return step

    def _commit(self):
        self.state = jax.device_put(self.state, self._state_sh)

    def stats(self) -> dict:
        """Decode telemetry: per-stage exit counts, tokens served, mean
        layer fraction spent (counters reduced over replicas when
        sharded)."""
        from repro.obs import stats as OBS_STATS
        tel = ST.telemetry_totals(self.state,
                                  sharded=self.mesh is not None)
        out = OBS_STATS.engine_summary(tel)
        out.update(
            layers_run=self.layers_run,
            layers_skipped=self.layers_skipped,
            replicas=self.n_replicas,
            continuous={
                "slot_steps": int(tel["slot_steps"]),
                "decode_steps": int(tel["decode_steps"]),
                "pages_peak": int(np.asarray(self.state.pages_peak))})
        return OBS_STATS.attach_requests(out, self.state)

    def record_requests(self, latencies_ms, missed=None) -> None:
        """Fold completed-request latency/deadline telemetry into the
        engine state (host-side write; the LM session calls this once
        per flushed decode bucket)."""
        self.state = ST.record_requests(self.state, latencies_ms, missed)
        if self.mesh is not None:
            s = self.state
            self.state = dataclasses.replace(
                s, lat_ms=jax.device_put(s.lat_ms, self._repl),
                lat_ptr=jax.device_put(s.lat_ptr, self._repl),
                lat_count=jax.device_put(s.lat_count, self._repl),
                deadline_miss=jax.device_put(s.deadline_miss, self._repl))

    def record_quotes(self, quotes_ms, realized_ms) -> None:
        """Fold admission-time SLO quote error telemetry (quote vs
        realized latency; host-side write, like record_requests)."""
        self.state = ST.record_quotes(self.state, quotes_ms, realized_ms)
        if self.mesh is not None:
            s = self.state
            self.state = dataclasses.replace(
                s, quote_ms_sum=jax.device_put(s.quote_ms_sum,
                                               self._repl),
                quote_err_ms_sum=jax.device_put(s.quote_err_ms_sum,
                                                self._repl),
                quote_count=jax.device_put(s.quote_count, self._repl))

    def _count_trace(self, key):
        # Runs in the Python body of a step function, i.e. once per trace.
        self.trace_counts[key] = self.trace_counts.get(key, 0) + 1

    # ------------------------------------------------------------------
    # eager path (the oracle)
    # ------------------------------------------------------------------
    def init_cache(self, batch, max_len):
        return TLM.lm_init_cache(self.cfg, batch, max_len)

    def prefill(self, tokens, cache):
        cache, _ = TLM.lm_prefill(self.params, jnp.asarray(tokens),
                                  self.cfg, cache)
        return cache

    def decode_step(self, tokens, cache, cache_index, alpha, *,
                    record: bool | None = None):
        """tokens: (B,) int; cache: full-depth list; alpha: (B,) difficulty.
        Returns (next_token (B,), exit_stage (B,), new_cache, new_alpha).

        ``record``: fold the step into ``state`` telemetry AND the host
        diagnostics (stats_exit / layers_run / layers_skipped).
        Defaults on for a pure-eager engine and OFF on a sharded one —
        there the eager path is the oracle, and a host-side fold would
        both pollute serving telemetry and broadcast scalar adds over
        the state's leading replica axis."""
        if record is None:
            record = self.mesh is None
        b = tokens.shape[0]
        x_full = self._embed(self.params, jnp.asarray(tokens)[:, None])
        alpha = np.asarray(DIFF.token_difficulty_ema(jnp.asarray(alpha),
                                                     x_full))
        tau = np.asarray(self.state.tau, np.float32)
        coef = np.asarray(self.state.coef, np.float32)
        beta_diff = float(self.state.beta_diff)

        out_tok = np.zeros(b, np.int64)
        out_stage = np.zeros(b, np.int64)
        active = np.arange(b)
        x = x_full
        n_stages = len(self.stages)
        cache = list(cache)

        for s, (a, bnd) in enumerate(self.stages):
            n = len(active)
            bucket = self.compactor.bucket_for(n)
            act = jnp.asarray(active)
            # gather cache rows for the active set (+pad with row 0)
            gather_idx = self.compactor.pad(np.asarray(active), bucket,
                                            fill=0).astype(np.int64)
            cache_sl = [jax.tree.map(
                lambda c: jnp.take(c, jnp.asarray(gather_idx), axis=0),
                cache[i]) for i in range(a, bnd)]
            x_pad = self.compactor.pad(x, bucket)
            x_new, new_sl = self._stage_fns[s](self.params, x_pad, cache_sl,
                                               cache_index)
            # scatter updated cache rows back
            for j, i in enumerate(range(a, bnd)):
                cache[i] = jax.tree.map(
                    lambda full, sl: full.at[act].set(sl[:n]),
                    cache[i], new_sl[j])
            if record:
                self.layers_run += (bnd - a) * n

            logits = self._exit_logits[s](self.params, x_new[:n, 0])
            conf = self._conf_fn(logits)
            pred = jnp.argmax(logits, -1)
            conf, pred = np.asarray(conf), np.asarray(pred)

            if s < n_stages - 1:
                eff = np.asarray(TH.stage_threshold(
                    tau[s], coef[s], alpha[active], beta_diff))
                fire = conf > eff
            else:
                fire = np.ones(n, bool)
            done = active[fire]
            out_tok[done] = pred[fire]
            out_stage[done] = s
            if record:
                self.stats_exit[s] += int(fire.sum())

            if s < n_stages - 1 and fire.any():
                # CALM state propagation for the exited rows
                h_exit = x_new[:n][jnp.asarray(np.nonzero(fire)[0])]
                sub = [jax.tree.map(lambda c: jnp.take(
                    c, jnp.asarray(done), axis=0), cache[i])
                    for i in range(len(cache))]
                sub = self._propagate[s](self.params, h_exit[:, 0], sub,
                                         cache_index)
                for i in range(self.stages[s][1], self.cfg.n_layers):
                    cache[i] = jax.tree.map(
                        lambda full, sl: full.at[jnp.asarray(done)].set(sl),
                        cache[i], sub[i])
                if record:
                    self.layers_skipped += \
                        (self.cfg.n_layers - bnd) * int(fire.sum())
            keep = ~fire
            if not keep.any():
                break
            x = x_new[:n][jnp.asarray(np.nonzero(keep)[0])]
            active = active[keep]
        if record:
            self._record_host(out_stage)
        return out_tok, out_stage, cache, alpha

    def _record_host(self, out_stage) -> None:
        """Eager-path telemetry fold (numpy, one decode step)."""
        s = self.state
        b = len(out_stage)
        counts = np.bincount(out_stage, minlength=self.n_exits)
        self.state = dataclasses.replace(
            s, served=s.served + jnp.asarray(b, jnp.int32),
            exit_counts=s.exit_counts + jnp.asarray(counts, jnp.int32),
            total_macs=s.total_macs + float(np.sum(
                self.cum_costs[out_stage])),
            since_update=s.since_update + jnp.asarray(b, jnp.int32))

    # ------------------------------------------------------------------
    # sharded path: fused per-(stage, bucket) donated-cache steps
    # ------------------------------------------------------------------
    def _embed_step(self, bp: int):
        """Fused embed + Eq. 8 decode-time difficulty EMA for a
        ``bp``-row bucket (the per-decode-step prologue)."""
        key = ("lm-embed", bp)
        if key in self._steps:
            return self._steps[key]
        cfg = self.cfg

        def step(params, toks, alpha):
            self._count_trace(key)
            x_full = L.embed(params["embed"],
                             toks[:, None]).astype(cfg.compute_dtype)
            alpha = DIFF.token_difficulty_ema(alpha, x_full)
            return x_full, alpha

        self._steps[key] = jax.jit(step, donate_argnums=(2,),
                                   out_shardings=self._row)
        return self._steps[key]

    def _prefill_step(self, bp: int, plen: int, max_len: int):
        key = ("lm-prefill", bp, plen, max_len)
        if key in self._steps:
            return self._steps[key]
        cfg = self.cfg

        def step(params, tokens, cache):
            self._count_trace(key)
            cache, _ = TLM.lm_prefill(params, tokens, cfg, cache)
            return cache

        self._steps[key] = jax.jit(step, donate_argnums=(2,),
                                   out_shardings=self._row)
        return self._steps[key]

    def _stage_step(self, s: int, sp: int, bp: int, max_len: int):
        """ONE compiled decode step for (stage ``s``, survivor bucket
        ``sp``) over a ``bp``-row generate bucket: cache-row gather,
        stage forward, exit head + confidence, Eq. 19 threshold + Alg. 1
        gate, token/stage scatter, CALM KV propagation for the fired
        rows, telemetry fold.  The cache, hidden buffer, token buffers
        and EngineState are donated, so repeated steps re-use their
        buffers (no realloc)."""
        key = ("lm-stage", s, sp, bp, max_len)
        if key in self._steps:
            return self._steps[key]
        a, bnd = self.stages[s]
        cfg = self.cfg
        final = s == len(self.stages) - 1
        exit_name = self.exit_names[s]

        def step(params, state, cache, x_full, toks, stg, idx, valid,
                 alpha, cache_index):
            self._count_trace(key)
            # gather the survivors' rows; padded lanes (idx == bp) clip
            # to the last (padding) row and are masked by ``valid``
            x = jnp.take(x_full, idx, axis=0, mode="clip")
            cache_sl = [jax.tree.map(
                lambda c: jnp.take(c, idx, axis=0, mode="clip"), cache[i])
                for i in range(a, bnd)]
            x_new, new_sl = _stage_apply(params, x, cache_sl, cache_index,
                                         cfg=cfg, a=a, b=bnd)
            cache = list(cache)
            for j, i in enumerate(range(a, bnd)):
                cache[i] = jax.tree.map(
                    lambda full, sl: full.at[idx].set(sl, mode="drop"),
                    cache[i], new_sl[j])
            x_full = x_full.at[idx].set(x_new, mode="drop")

            vb = valid > 0
            if final:
                # Alg. 1 line 12: the final head always accepts
                eff = jnp.full(idx.shape, -1.0, jnp.float32)
            else:
                al = jnp.take(alpha, idx, mode="clip")
                eff = TH.stage_threshold(state.tau[s], state.coef[s], al,
                                         state.beta_diff)
            conf, pred, fire = self._head_traced(params, x_new[:, 0],
                                                 exit_name, eff)
            # the unconditional final accept must not depend on the
            # confidence functional's range (the -1.0 eff is only a
            # belt-and-braces sentinel for bounded functionals)
            fire = vb if final else (fire & vb)
            idx_fire = jnp.where(fire, idx, bp)  # non-fired -> dropped
            toks = toks.at[idx_fire].set(pred.astype(toks.dtype),
                                         mode="drop")
            stg = stg.at[idx_fire].set(s, mode="drop")
            if not final:
                cache = self._propagate_traced(params, cache, x_new[:, 0],
                                               idx_fire, cache_index, bnd)
            state = self._fold_decode(state, s, fire)
            return state, (cache, x_full, toks, stg, fire)

        self._steps[key] = jax.jit(
            step, donate_argnums=(1, 2, 3, 4, 5),
            out_shardings=(self._state_sh, self._row))
        return self._steps[key]

    def _stage_fwd_step(self, s: int, sp: int, bp: int, max_len: int):
        """Forward-only twin of :meth:`_stage_step` for gates the
        predictor ruled out (``min_exit`` head-skip): cache-row gather,
        stage forward, cache + hidden scatter — NO exit head, NO Alg. 1
        gate, NO propagation, NO telemetry fold and NO host fire sync.
        Sound only when the gate provably can't fire (every row
        survives), so decisions stay bit-identical to the oracle."""
        key = ("lm-stage-fwd", s, sp, bp, max_len)
        if key in self._steps:
            return self._steps[key]
        a, bnd = self.stages[s]
        cfg = self.cfg

        def step(params, cache, x_full, idx, cache_index):
            self._count_trace(key)
            x = jnp.take(x_full, idx, axis=0, mode="clip")
            cache_sl = [jax.tree.map(
                lambda c: jnp.take(c, idx, axis=0, mode="clip"), cache[i])
                for i in range(a, bnd)]
            x_new, new_sl = _stage_apply(params, x, cache_sl, cache_index,
                                         cfg=cfg, a=a, b=bnd)
            cache = list(cache)
            for j, i in enumerate(range(a, bnd)):
                cache[i] = jax.tree.map(
                    lambda full, sl: full.at[idx].set(sl, mode="drop"),
                    cache[i], new_sl[j])
            x_full = x_full.at[idx].set(x_new, mode="drop")
            return cache, x_full

        self._steps[key] = jax.jit(step, donate_argnums=(1, 2),
                                   out_shardings=self._row)
        return self._steps[key]

    def _head_traced(self, params, h, exit_name: str, eff):
        """The decode-time exit decision for one stage: rmsnorm → unembed
        matmul → softmax confidence → Eq. 19 gate, as ONE
        ``kernels.dispatch`` call for the ``lm-token`` functional (the
        fused Pallas exit-head kernel on TPU, shard_map-wrapped over the
        data axis; the bit-identical jnp chain on xla).  Returns
        (conf, pred, fire bool)."""
        cfg = self.cfg
        if self.confidence == "lm-token":
            from repro.kernels import dispatch as KD
            norm = params["final_norm"] if exit_name == "final" \
                else params["exit_heads"][exit_name]["norm"]
            conf, pred, fire = KD.exit_head_gate(
                h, norm["scale"], TLM._unembed_table(params, cfg), eff,
                **self.kernel_kw)
            return conf, pred, fire > 0
        logits = TLM.exit_logits(params, cfg, h, exit_name)
        conf = self._conf_fn(logits)
        return conf, jnp.argmax(logits, -1), conf > eff

    def _propagate_traced(self, params, cache, h_exit, idx_fire,
                          cache_index, from_layer):
        """CALM propagation inside the fused step: the SAME projection
        math as the eager path (``transformer_lm.lm_kv_project`` is the
        one implementation both share), scattered straight into rows
        ``[idx_fire, cache_index]`` of the full donated cache
        (non-fired rows carry the out-of-bounds index and are
        dropped)."""
        cfg = self.cfg
        rows = TLM.lm_kv_project(params, h_exit, cfg, cache, cache_index,
                                 from_layer)
        cache = list(cache)
        for i, r in zip(range(from_layer, cfg.n_layers), rows):
            c = dict(cache[i])
            for name, val in r.items():
                c[name] = c[name].at[idx_fire, cache_index].set(
                    val[:, 0].astype(c[name].dtype), mode="drop")
            cache[i] = c
        return cache

    def _fold_decode(self, state: EngineState, s: int, fire) -> EngineState:
        """Per-replica telemetry fold for one (stage, bucket) step: each
        replica's segment of the padded bucket lands in its own
        counters (``stats()`` reduces across replicas)."""
        r = self.n_replicas
        per = fire.shape[0] // r
        f = fire.astype(jnp.float32).reshape(r, per)
        n_new = f.sum(1).astype(jnp.int32)
        return dataclasses.replace(
            state,
            served=state.served + n_new,
            exit_counts=state.exit_counts.at[:, s].add(n_new),
            total_macs=state.total_macs
            + n_new.astype(jnp.float32) * float(self.cum_costs[s]),
            since_update=state.since_update + n_new)

    def _fold_decode_dense(self, state: EngineState, s: int,
                           fire) -> EngineState:
        """Telemetry fold against an UNSHARDED state (scalar counters,
        (E,) exit_counts) — the continuous decoder's mesh-less twin of
        :meth:`_fold_decode`."""
        n_new = jnp.sum(fire.astype(jnp.int32))
        return dataclasses.replace(
            state,
            served=state.served + n_new,
            exit_counts=state.exit_counts.at[s].add(n_new),
            total_macs=state.total_macs
            + n_new.astype(jnp.float32) * float(self.cum_costs[s]),
            since_update=state.since_update + n_new)

    # ------------------------------------------------------------------
    # generation
    # ------------------------------------------------------------------
    def generate(self, prompt_tokens: np.ndarray, n_new: int,
                 max_len: int | None = None, mode: str | None = None,
                 min_exit: int = 0):
        """prompt_tokens: (B, S0).  Greedy generation with early exits.
        Returns (tokens (B, n_new), exit stages (B, n_new)).

        mode — "sharded" (default when built with ``mesh=``): the fused
        donated-cache compiled decode loop; "eager": the per-stage
        oracle path (never records telemetry on a sharded engine);
        "continuous": the slot-pool continuous-batching decoder over
        the paged KV cache (rows admitted as slots free up — no bucket
        flushes, ONE compiled decode step for every admission
        pattern).  Batches larger than the biggest bucket are split
        into chunks (each chunk gets its own KV cache); the continuous
        path instead streams rows through the slot pool.

        min_exit — gates below this stage are skipped on the sharded
        path (forward-only stage steps: no exit head, no gate launch,
        no fire host sync).  Sound when it comes from
        :meth:`min_exit_bound`, where the gate provably never fires —
        tokens and stages stay bit-identical to the oracle.  The eager
        and continuous paths always run the full oracle."""
        if not 0 <= int(min_exit) < self.n_exits:
            raise ValueError(f"min_exit {min_exit} out of range for "
                             f"{self.n_exits} exits")
        min_exit = int(min_exit)
        if mode is None:
            mode = "sharded" if self.mesh is not None else "eager"
        if mode not in ("sharded", "eager", "continuous"):
            raise ValueError(
                f"unknown mode {mode!r}; known: sharded, eager, "
                "continuous")
        if mode == "sharded" and self.mesh is None:
            raise ValueError(
                "mode='sharded' needs a mesh — construct with "
                "LMDecodeEngine(..., mesh=make_serving_mesh())")
        if mode == "continuous":
            return self._generate_continuous(np.asarray(prompt_tokens),
                                             n_new)
        b, s0 = prompt_tokens.shape
        if b > self.compactor.max_bucket:
            outs, stgs = [], []
            for a, z in self.compactor.chunks(b):
                o, st = self.generate(prompt_tokens[a:z], n_new, max_len,
                                      mode=mode, min_exit=min_exit)
                outs.append(o)
                stgs.append(st)
            return np.concatenate(outs), np.concatenate(stgs)
        if mode == "sharded":
            return self._generate_sharded(prompt_tokens, n_new, max_len,
                                          min_exit=min_exit)
        return self._generate_eager(prompt_tokens, n_new, max_len)

    def _generate_eager(self, prompt_tokens, n_new, max_len=None):
        b, s0 = prompt_tokens.shape
        max_len = max_len or (s0 + n_new + 1)
        cache = self.init_cache(b, max_len)
        cache = self.prefill(prompt_tokens[:, :-1], cache)
        alpha = np.full((b,), 0.5, np.float32)
        toks = prompt_tokens[:, -1]
        out = []
        stages = []
        for t in range(n_new):
            # decode_step's default record already disables the fold on
            # a sharded engine (the eager path is the oracle there)
            toks, stage, cache, alpha = self.decode_step(
                toks, cache, s0 - 1 + t, alpha)
            out.append(toks.copy())
            stages.append(stage.copy())
        return np.stack(out, 1), np.stack(stages, 1)

    def _generate_sharded(self, prompt_tokens, n_new, max_len=None,
                          min_exit=0):
        cfg = self.cfg
        prompts = np.asarray(prompt_tokens)
        b, s0 = prompts.shape
        bp = self.bucket_key(b)
        max_len = max_len or (s0 + n_new + 1)
        cache = jax.device_put(self.init_cache(bp, max_len), self._row)
        pad = self.compactor.pad(prompts.astype(np.int64), bp)
        if s0 > 1:
            cache = self._prefill_step(bp, s0 - 1, max_len)(
                self.params, jnp.asarray(pad[:, :-1]), cache)
        alpha = jax.device_put(jnp.full((bp,), 0.5, jnp.float32),
                               self._row)
        toks = jax.device_put(jnp.asarray(pad[:, -1], jnp.int32),
                              self._row)
        stg = jax.device_put(jnp.zeros((bp,), jnp.int32), self._row)
        n_layers = cfg.n_layers
        out, stages_out = [], []
        for t in range(n_new):
            ci = s0 - 1 + t
            x_full, alpha = self._embed_step(bp)(self.params, toks, alpha)
            active = np.arange(b)
            for s, (a, bnd) in enumerate(self.stages):
                n = active.size
                sp = self.bucket_key(n)
                idx = np.full(sp, bp, np.int32)
                idx[:n] = active
                if s < min_exit and s < len(self.stages) - 1:
                    # gate ruled out for every row: forward-only step
                    # (no exit head, no gate, no fire host sync)
                    cache, x_full = self._stage_fwd_step(
                        s, sp, bp, max_len)(self.params, cache, x_full,
                                            jnp.asarray(idx), ci)
                    self.layers_run += (bnd - a) * n
                    continue
                valid = np.zeros(sp, np.float32)
                valid[:n] = 1.0
                self.state, (cache, x_full, toks, stg, fire) = \
                    self._stage_step(s, sp, bp, max_len)(
                        self.params, self.state, cache, x_full, toks, stg,
                        jnp.asarray(idx), jnp.asarray(valid), alpha, ci)
                # the ONE host sync per stage: survivors are
                # data-dependent shapes
                fire_np = np.asarray(fire)[:n]
                nf = int(fire_np.sum())
                self.layers_run += (bnd - a) * n
                self.stats_exit[s] += nf
                if s < len(self.stages) - 1:
                    self.layers_skipped += (n_layers - bnd) * nf
                active = active[~fire_np]
                if active.size == 0:
                    break
            out.append(np.asarray(toks)[:b].astype(np.int64))
            stages_out.append(np.asarray(stg)[:b].astype(np.int64))
        return np.stack(out, 1), np.stack(stages_out, 1)

    def _generate_continuous(self, prompts, n_new):
        """Drive the (engine-owned) default continuous decoder: admit
        each prompt row as its own request whenever the slot pool has
        room, step until every row finished.  Rows at different depths
        coexist in one launch, so a large batch streams through
        ``n_slots`` slots without bucket flushes."""
        b, s0 = prompts.shape
        if self._cont_default is None:
            self._cont_default = self.continuous()
        dec = self._cont_default
        if not dec.fits_ever(1, s0, n_new):
            raise ValueError(
                f"prompt_len={s0} + n_new={n_new} exceeds the default "
                f"continuous decoder's max_len={dec.max_len}; build one "
                "via engine.continuous(max_len=...) and admit directly")
        out_t: list = [None] * b
        out_s: list = [None] * b
        pending = list(range(b))
        done = 0
        while done < b:
            while pending and dec.can_admit(1, s0, n_new):
                i = pending.pop(0)
                dec.admit(prompts[i:i + 1], n_new, tag=("gen", i))
            if not dec.active_rows:
                raise RuntimeError("continuous generate stalled with "
                                   "pending rows and an empty pool")
            for tag, toks, stgs in dec.step():
                if isinstance(tag, tuple) and tag[0] == "gen":
                    out_t[tag[1]] = toks[0]
                    out_s[tag[1]] = stgs[0]
                    done += 1
        return np.stack(out_t), np.stack(out_s)


class ContinuousLMDecoder:
    """Slot-based continuous batching over a paged KV cache.

        dec = engine.continuous(n_slots=8, page_size=8, max_len=64)
        dec.admit(prompts, n_new=12, tag="req-0")   # any step
        events = dec.step()   # [(tag, tokens (B, n), stages (B, n))]

    ONE fixed-shape compiled decode step serves the whole pool: an
    active-mask plus per-slot position counter lets rows at different
    depths (and different requests) coexist in a single launch, so
    admission never retraces — ``trace_counts`` stays at one
    ``("lm-cont-decode", ...)`` entry for every admission pattern.

    KV lives in a page store (n_pages, page_size, ...) per layer with a
    free-list :class:`PageAllocator`; each slot reads through its row of
    the page table (a ``kernels.dispatch``-routed gather) and writes
    through a per-slot (page, offset) scatter.  A row that fires its
    exit gate stops writing KV *within the same launch* (its write page
    index goes out of range → dropped), and a finished request frees its
    slot and pages to the admission queue THAT step — Alg. 1 early
    termination is what creates serving capacity.

    Bit-identity: the per-layer math is the same functions the eager
    oracle uses, and masked-out view positions contribute exact zeros,
    so tokens AND exit stages match ``generate(mode="eager",
    max_len=dec.view_len)`` row for row (dense configs — the MoE caveat
    from the module docstring applies).

    Under a mesh, slots and pages are sharded over the data axis and the
    allocator keeps slot s's pages inside slot s's replica range, so the
    Pallas gather's shard_map sees local page ids.
    """

    def __init__(self, engine: LMDecodeEngine, *, n_slots=None,
                 page_size=8, max_len=None):
        from repro.engine.sharded import _silence_donation_warning
        _silence_donation_warning()
        self.eng = engine
        cfg = engine.cfg
        if engine.mesh is None and not getattr(engine, "_state_owned",
                                               False):
            # the continuous step DONATES the engine state — on a
            # mesh-less engine its leaves may still alias the caller's
            # DartParams (or a sibling engine built from them); take
            # ownership before the first donation, like the sharded
            # constructor does
            engine.state = jax.tree.map(
                lambda a: jnp.array(a, copy=True), engine.state)
            engine._state_owned = True
        if max_len is None:
            max_len = cfg.max_seq
        if n_slots is None:
            n_slots = max(min(16, engine.compactor.max_bucket),
                          engine.replica_multiple)
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        if n_slots % engine.replica_multiple:
            raise ValueError(
                f"n_slots={n_slots} not a multiple of the replica "
                f"multiple {engine.replica_multiple}")
        self.n_slots = int(n_slots)
        self.page_size = int(page_size)
        self.max_len = int(max_len)
        self.pages_per_slot = -(-self.max_len // self.page_size)
        #: dense attention view length (page-table width × page size);
        #: the eager oracle must be run at THIS max_len for bit-identity
        self.view_len = self.pages_per_slot * self.page_size
        self.n_pages = self.n_slots * self.pages_per_slot
        self.pool = SlotPool(self.n_slots, engine.n_replicas)
        self.allocator = PageAllocator(self.n_pages, engine.n_replicas)

        # device state: per-layer page stores + the Eq. 8 difficulty EMA
        self.pages = TLM.lm_init_cache(cfg, self.n_pages, self.page_size)
        self.alpha = jnp.full((self.n_slots,), 0.5, jnp.float32)
        if engine.mesh is not None:
            self.pages = jax.device_put(self.pages, engine._row)
            self.alpha = jax.device_put(self.alpha, engine._row)

        # host bookkeeping (numpy; shipped into each step as operands)
        s = self.n_slots
        self.pos = np.zeros(s, np.int32)        # next KV write position
        self.active = np.zeros(s, np.int32)
        self.fresh = np.zeros(s, np.int32)      # reset EMA to 0.5
        self.tokens = np.zeros(s, np.int32)     # last emitted token
        self.page_table = np.zeros((s, self.pages_per_slot), np.int32)
        self._requests: dict = {}               # rid -> record
        self._slot_req: dict = {}               # slot -> (rid, row)
        self._slot_pages: dict = {}             # slot -> [page ids]
        self._next_rid = 0
        self._pages_hwm = 0

    # -- admission ------------------------------------------------------
    @property
    def active_rows(self) -> int:
        return int(self.active.sum())

    def pages_needed(self, s0: int, n_new: int) -> int:
        """Pages reserved up-front at admission: the last KV position a
        request writes is ``s0 + n_new - 2`` (the final generated
        token's step reads the cache but its own KV write is the one
        that would serve step n_new+1)."""
        return max(1, -(-(s0 + n_new - 1) // self.page_size))

    def fits_ever(self, n_rows: int, s0: int, n_new: int) -> bool:
        """Could this request EVER be admitted (even into an empty
        pool)?  Sessions reject impossible requests instead of queueing
        them forever."""
        return (n_rows <= self.n_slots
                and self.pages_needed(s0, n_new) <= self.pages_per_slot)

    def _placement(self, n_rows: int, npg: int):
        """First-fit of ``n_rows`` (slot + npg pages each) into replica
        ranges — a slot's pages always come from its own range, so
        sharded gathers stay local.  None if it doesn't fit now."""
        r = self.eng.n_replicas
        slots = [self.pool.available(i) for i in range(r)]
        pages = [self.allocator.available(i) for i in range(r)]
        plan = []
        for _ in range(n_rows):
            for i in range(r):
                if slots[i] and pages[i] >= npg:
                    plan.append(i)
                    slots[i] -= 1
                    pages[i] -= npg
                    break
            else:
                return None
        return plan

    def can_admit(self, n_rows: int, s0: int, n_new: int) -> bool:
        if not self.fits_ever(n_rows, s0, n_new):
            return False
        return self._placement(n_rows,
                               self.pages_needed(s0, n_new)) is not None

    def admit(self, prompt_tokens, n_new: int, tag=None):
        """Admit one request (B rows, shared prompt length / n_new).
        All-or-nothing: raises :class:`OutOfCapacity` when the pool
        can't place every row right now.  Prompts prefill straight into
        the request's own pages; decode joins the pool next step."""
        prompts = np.asarray(prompt_tokens)
        b, s0 = prompts.shape
        if n_new < 1:
            raise ValueError("n_new must be >= 1")
        if not self.fits_ever(b, s0, n_new):
            raise ValueError(
                f"request (rows={b}, s0={s0}, n_new={n_new}) can never "
                f"fit this decoder (n_slots={self.n_slots}, "
                f"max_len={self.max_len})")
        npg = self.pages_needed(s0, n_new)
        plan = self._placement(b, npg)
        if plan is None:
            raise OutOfCapacity(
                f"pool full: rows={b} x pages={npg} don't fit "
                f"({self.pool.in_use}/{self.n_slots} slots, "
                f"{self.allocator.in_use}/{self.n_pages} pages in use)")
        rid = self._next_rid
        self._next_rid += 1
        rec = {"rid": rid, "tag": rid if tag is None else tag,
               "slots": [], "remaining": int(n_new),
               "toks": [[] for _ in range(b)],
               "stgs": [[] for _ in range(b)]}
        for row in range(b):
            slot = self.pool.acquire(plan[row])
            pg = self.allocator.alloc(npg, plan[row])
            self._slot_pages[slot] = pg
            self._slot_req[slot] = (rid, row)
            rec["slots"].append(slot)
            self.page_table[slot, :] = 0
            self.page_table[slot, :npg] = pg
            self.pos[slot] = s0 - 1
            self.tokens[slot] = int(prompts[row, -1])
            self.active[slot] = 1
            self.fresh[slot] = 1
            if s0 > 1:
                self._prefill_row(prompts[row, :-1], pg)
        self._requests[rid] = rec
        self._pages_hwm = max(self._pages_hwm, self.allocator.in_use)
        st = self.eng.state
        if self._pages_hwm > int(np.asarray(st.pages_peak)):
            peak = jnp.asarray(self._pages_hwm, jnp.int32)
            if self.eng.mesh is not None:
                peak = jax.device_put(peak, self.eng._repl)
            self.eng.state = dataclasses.replace(st, pages_peak=peak)
        return rec["tag"]

    def release(self, tag) -> bool:
        """Cancel an in-flight request mid-cascade: frees its slots and
        KV pages immediately (no completion event is emitted)."""
        for rid, rec in list(self._requests.items()):
            if rec["tag"] == tag or rid == tag:
                self._release_slots(rec["slots"])
                del self._requests[rid]
                return True
        return False

    def _release_slots(self, slots) -> None:
        for slot in slots:
            self.allocator.free(self._slot_pages.pop(slot))
            self.pool.release(slot)
            del self._slot_req[slot]
            self.active[slot] = 0
            self.fresh[slot] = 0
            self.pos[slot] = 0
            self.tokens[slot] = 0
            self.page_table[slot, :] = 0

    # -- compiled steps (cached on the engine, keyed by geometry) -------
    def _prefill_row(self, prompt, pg) -> None:
        plen = int(prompt.shape[0])
        npre = -(-plen // self.page_size)
        step = self._prefill_step(plen, npre)
        self.pages = step(self.eng.params,
                          jnp.asarray(prompt[None, :], jnp.int32),
                          self.pages,
                          jnp.asarray(np.asarray(pg[:npre], np.int32)))

    def _prefill_step(self, plen: int, npre: int):
        """Prefill one row into its reserved pages: the SAME
        ``lm_prefill`` as the oracle into a temporary dense cache,
        reshaped to (npre, psz, ...) page rows and scattered at the
        row's page ids (donated page store)."""
        eng = self.eng
        key = ("lm-cont-prefill", plen, npre, self.page_size)
        if key in eng._steps:
            return eng._steps[key]
        cfg = eng.cfg
        psz = self.page_size

        def step(params, tokens, pages, page_ids):
            eng._count_trace(key)
            tmp = TLM.lm_init_cache(cfg, 1, npre * psz)
            tmp, _ = TLM.lm_prefill(params, tokens, cfg, tmp)
            pages = list(pages)
            for i in range(cfg.n_layers):
                pg = dict(pages[i])
                for name, leaf in tmp[i].items():
                    rows = leaf[0].reshape((npre, psz) + leaf.shape[2:])
                    pg[name] = pg[name].at[page_ids].set(
                        rows.astype(pg[name].dtype))
                pages[i] = pg
            return pages

        kw = {} if eng.mesh is None else {"out_shardings": eng._row}
        eng._steps[key] = jax.jit(step, donate_argnums=(2,), **kw)
        return eng._steps[key]

    def _embed_step(self):
        """Embed + fresh-slot EMA reset + Eq. 8 decode-time difficulty
        EMA for the whole pool (donates the EMA buffer)."""
        eng = self.eng
        key = ("lm-cont-embed", self.n_slots)
        if key in eng._steps:
            return eng._steps[key]
        cfg = eng.cfg

        def step(params, toks, alpha, fresh):
            eng._count_trace(key)
            x = L.embed(params["embed"],
                        toks[:, None]).astype(cfg.compute_dtype)
            alpha = jnp.where(fresh > 0, jnp.float32(0.5), alpha)
            alpha = DIFF.token_difficulty_ema(alpha, x)
            return x, alpha

        kw = {} if eng.mesh is None else {"out_shardings": eng._row}
        eng._steps[key] = jax.jit(step, donate_argnums=(2,), **kw)
        return eng._steps[key]

    def _decode_step(self):
        """THE continuous decode step: every stage for every slot in one
        fixed-shape launch.  ``run`` masks inactive slots and rows that
        fired at an earlier stage this step (their KV write page goes
        out of range → scatter-dropped; their recorded token/stage stop
        updating), so one trace serves every admission pattern, every
        depth mix, every survivor count."""
        eng = self.eng
        key = ("lm-cont-decode", self.n_slots, self.page_size,
               self.pages_per_slot)
        if key in eng._steps:
            return eng._steps[key]
        cfg = eng.cfg
        psz = self.page_size
        n_pages = self.n_pages
        view_len = self.view_len
        n_layers = cfg.n_layers
        stages = eng.stages
        final_s = len(stages) - 1
        gather_kw = eng.kernel_kw
        fold = eng._fold_decode if eng.mesh is not None \
            else eng._fold_decode_dense

        def step(params, state, pages, x, alpha, pos, active, page_table):
            eng._count_trace(key)
            s_pool = pos.shape[0]
            run = active > 0
            page_w = jnp.take_along_axis(
                page_table, (pos // psz)[:, None], axis=1)[:, 0]
            off = pos % psz
            toks_out = jnp.zeros((s_pool,), jnp.int32)
            stg_out = jnp.zeros((s_pool,), jnp.int32)
            pages = list(pages)
            for s, (a, bnd) in enumerate(stages):
                final = s == final_s
                pidx = jnp.where(run, page_w, n_pages)  # OOB -> no write
                x, new_sl = _stage_apply_paged(
                    params, x, [pages[i] for i in range(a, bnd)],
                    page_table, pidx, off, pos,
                    cfg=cfg, a=a, b=bnd, gather_kw=gather_kw)
                for j, i in enumerate(range(a, bnd)):
                    pages[i] = new_sl[j]
                if final:
                    # Alg. 1 line 12: the final head always accepts
                    eff = jnp.full((s_pool,), -1.0, jnp.float32)
                else:
                    eff = TH.stage_threshold(state.tau[s], state.coef[s],
                                             alpha, state.beta_diff)
                conf, pred, fire = eng._head_traced(
                    params, x[:, 0], eng.exit_names[s], eff)
                fire = run if final else (fire & run)
                toks_out = jnp.where(fire, pred.astype(jnp.int32),
                                     toks_out)
                stg_out = jnp.where(fire, jnp.int32(s), stg_out)
                if not final:
                    # CALM propagation for the fired rows, scattered at
                    # their (page, offset) for layers [bnd, n_layers)
                    rows = TLM.lm_kv_project(params, x[:, 0], cfg, None,
                                             None, bnd, positions=pos,
                                             max_len=view_len)
                    pidx_f = jnp.where(fire, page_w, n_pages)
                    for i, rr in zip(range(bnd, n_layers), rows):
                        pg = dict(pages[i])
                        for name, val in rr.items():
                            pg[name] = pg[name].at[pidx_f, off].set(
                                val[:, 0].astype(pg[name].dtype),
                                mode="drop")
                        pages[i] = pg
                state = fold(state, s, fire)
                run = run & ~fire
            state = self._fold_slots(state, active)
            return state, (pages, toks_out, stg_out)

        kw = {} if eng.mesh is None \
            else {"out_shardings": (eng._state_sh, eng._row)}
        eng._steps[key] = jax.jit(step, donate_argnums=(1, 2, 3), **kw)
        return eng._steps[key]

    def _fold_slots(self, state: EngineState, active) -> EngineState:
        """Continuous-batching occupancy telemetry, folded on device
        inside the step (per replica when sharded; decode_steps counts
        launches once, on replica 0)."""
        eng = self.eng
        occ_all = (active > 0).astype(jnp.int32)
        if eng.mesh is None:
            return dataclasses.replace(
                state,
                slot_steps=state.slot_steps + occ_all.sum(),
                decode_steps=state.decode_steps + 1)
        r = eng.n_replicas
        occ = occ_all.reshape(r, occ_all.shape[0] // r).sum(1)
        one = jnp.zeros((r,), jnp.int32).at[0].add(1)
        return dataclasses.replace(
            state,
            slot_steps=state.slot_steps + occ,
            decode_steps=state.decode_steps + one)

    # -- the step -------------------------------------------------------
    def step(self):
        """Advance every active slot one token.  Returns completion
        events ``[(tag, tokens (B, n_new), stages (B, n_new)), ...]``;
        finished requests free their slots and KV pages before this
        returns, so the capacity is admittable immediately."""
        eng = self.eng
        if not self.active.any():
            return []
        x, self.alpha = self._embed_step()(
            eng.params, jnp.asarray(self.tokens), self.alpha,
            jnp.asarray(self.fresh))
        self.fresh[:] = 0
        eng.state, (self.pages, toks_out, stg_out) = self._decode_step()(
            eng.params, eng.state, self.pages, x, self.alpha,
            jnp.asarray(self.pos), jnp.asarray(self.active),
            jnp.asarray(self.page_table))
        tok_np = np.asarray(toks_out)   # the ONE host sync per step
        stg_np = np.asarray(stg_out)
        events = []
        finished = []
        stepped: set = set()
        for slot in np.nonzero(self.active)[0]:
            slot = int(slot)
            rid, row = self._slot_req[slot]
            rec = self._requests[rid]
            rec["toks"][row].append(int(tok_np[slot]))
            rec["stgs"][row].append(int(stg_np[slot]))
            self.pos[slot] += 1
            self.tokens[slot] = int(tok_np[slot])
            # host diagnostics use the same semantic accounting as the
            # eager engine: layers a token needed vs skipped
            st = int(stg_np[slot])
            bnd = eng.stages[st][1]
            eng.stats_exit[st] += 1
            eng.layers_run += bnd
            eng.layers_skipped += eng.cfg.n_layers - bnd
            if rid not in stepped:
                stepped.add(rid)
                rec["remaining"] -= 1
                if rec["remaining"] == 0:
                    finished.append(rid)
        for rid in finished:
            rec = self._requests.pop(rid)
            self._release_slots(rec["slots"])
            events.append((rec["tag"],
                           np.asarray(rec["toks"], np.int64),
                           np.asarray(rec["stgs"], np.int64)))
        return events

    # -- introspection --------------------------------------------------
    def slots_of(self, tag) -> list:
        """Slot ids currently held by the request admitted under ``tag``
        (empty once the request has retired)."""
        for rec in self._requests.values():
            if rec["tag"] == tag:
                return [int(s) for s in rec["slots"]]
        return []

    def occupancy(self) -> dict:
        """Slot-pool / page-allocator occupancy gauges for the obs
        registry (host ints only — never touches device state)."""
        return {"slots_total": self.n_slots,
                "slots_in_use": self.active_rows,
                "pages_total": self.n_pages,
                "pages_in_use": self.allocator.in_use,
                "pages_peak": self._pages_hwm}

    def stats(self) -> dict:
        return {"n_slots": self.n_slots,
                "active": self.active_rows,
                "page_size": self.page_size,
                "pages_total": self.n_pages,
                "pages_in_use": self.allocator.in_use,
                "pages_peak": self._pages_hwm}

    def check_invariants(self) -> None:
        """Assert the slot-pool/page-table/free-list consistency the
        property harness leans on: active-mask ↔ ownership agreement,
        no page shared between slots, every non-held page on a free
        list, per-replica placement."""
        active_slots = {int(s) for s in np.nonzero(self.active)[0]}
        assert active_slots == set(self._slot_req), \
            (active_slots, set(self._slot_req))
        assert active_slots == self.pool._held
        used = []
        for slot in active_slots:
            pg = self._slot_pages[slot]
            used.extend(pg)
            assert list(self.page_table[slot, :len(pg)]) == list(pg)
            rng = self.pool.range_of(slot)
            assert all(p // self.allocator.per_range == rng for p in pg)
        assert len(used) == len(set(used)), "page double-booked"
        assert set(used) == self.allocator._held
        n_free = sum(self.allocator.available(i)
                     for i in range(self.allocator.n_ranges))
        assert n_free + len(used) == self.n_pages
        s_free = sum(self.pool.available(i)
                     for i in range(self.pool.n_ranges))
        assert s_free + len(active_slots) == self.n_slots
