"""LM decode engine — early-exit autoregressive serving on the DART gate.

The LM analogue of :class:`repro.engine.DartEngine`'s compacted mode
(re-homed from ``repro.runtime.lm_server``, now built on the shared
:class:`BatchCompactor`): per decode step the layer stack runs
stage-by-stage; exited samples *skip* the remaining stages — their KV
entries are filled by CALM-style state propagation
(``lm_kv_propagate``) — and survivors (plus their cache rows) are
compacted into power-of-two buckets.

The exit gate uses the ``lm-token`` confidence functional and the
``token_difficulty_ema`` decode-time difficulty estimator from the
engine registries.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import difficulty as DIFF
from repro.core import thresholds as TH
from repro.core.routing import DartParams
from repro.engine import registry as REG
from repro.engine.compactor import BatchCompactor
from repro.models import layers as L
from repro.models import transformer_lm as TLM


def _stages(cfg: TLM.LMConfig):
    """[(start, end)) layer ranges; stage k ends at exit_layers[k]."""
    bounds = [0] + [e + 1 for e in sorted(cfg.exit_layers)] + [cfg.n_layers]
    return [(a, b) for a, b in zip(bounds[:-1], bounds[1:])]


class LMDecodeEngine:
    def __init__(self, cfg: TLM.LMConfig, params, dart: DartParams, *,
                 buckets=(1, 2, 4, 8, 16, 32, 64, 128), use_kernel=False,
                 confidence: str = "lm-token"):
        assert not cfg.layer_scan
        self.cfg = cfg
        self.params = params
        self.dart = dart
        self.compactor = BatchCompactor(buckets)
        self.use_kernel = use_kernel
        self._conf_fn = REG.get_confidence(confidence)
        self.stages = _stages(cfg)
        self.exit_names = [str(i) for i in sorted(cfg.exit_layers)] \
            + ["final"]
        self.stats_exit = np.zeros(len(self.stages), np.int64)
        self.layers_run = 0
        self.layers_skipped = 0

        cfgc = cfg

        def stage_fn(params, x, cache_sl, cache_index, a, b):
            cos, sin = L.rope_freqs(
                cfgc.qk_rope_dim if cfgc.attn_kind == "mla" else cfgc.hd,
                cache_sl[0]["c_kv"].shape[1] if cfgc.attn_kind == "mla"
                else cache_sl[0]["k"].shape[1], cfgc.rope_theta)
            new_sl = []
            for j, i in enumerate(range(a, b)):
                p = params["layers"][i]
                h = L.rmsnorm(p["attn_norm"], x)
                if cfgc.attn_kind == "mla":
                    att, c = L.mla_decode(p["attn"], h, cos, sin,
                                          cache_sl[j], cache_index)
                else:
                    att, c = L.gqa_decode(p["attn"], h, cos, sin,
                                          cache_sl[j], cache_index)
                new_sl.append(c)
                x = x + att
                h2 = L.rmsnorm(p["ffn_norm"], x)
                if cfgc.layer_is_moe(i):
                    from repro.models.moe import moe_apply
                    f, _ = moe_apply(p["moe"], h2, cfgc.moe,
                                     ep_mode=cfgc.moe_ep_mode)
                else:
                    f = L.swiglu(p["ffn"], h2)
                x = x + f
            return x, new_sl

        self._stage_fns = [
            jax.jit(partial(stage_fn, a=a, b=b), static_argnames=())
            for a, b in self.stages]
        self._exit_logits = [
            jax.jit(partial(lambda params, h, name: TLM.exit_logits(
                params, cfgc, h, name), name=n)) for n in self.exit_names]
        self._propagate = [
            jax.jit(partial(lambda params, h, cache, idx, fl:
                            TLM.lm_kv_propagate(params, h, cfgc, cache, idx,
                                                from_layer=fl), fl=b))
            for _, b in self.stages]
        self._embed = jax.jit(lambda params, t: L.embed(
            params["embed"], t).astype(cfgc.compute_dtype))

    # ------------------------------------------------------------------
    def session(self, cfg=None, **kw):
        """Queue-backed session handle: drive this decode engine through
        the async scheduler (deadlines, priorities, consolidation of
        concurrent ``generate`` callers into shared bucketed decode
        loops).  See :class:`repro.serving.LMDecodeSession`."""
        from repro.serving.lm_session import LMDecodeSession
        return LMDecodeSession(self, cfg=cfg, **kw)

    # ------------------------------------------------------------------
    def init_cache(self, batch, max_len):
        return TLM.lm_init_cache(self.cfg, batch, max_len)

    def prefill(self, tokens, cache):
        cache, _ = TLM.lm_prefill(self.params, jnp.asarray(tokens),
                                  self.cfg, cache)
        return cache

    def decode_step(self, tokens, cache, cache_index, alpha):
        """tokens: (B,) int; cache: full-depth list; alpha: (B,) difficulty.
        Returns (next_token (B,), exit_stage (B,), new_cache, new_alpha)."""
        b = tokens.shape[0]
        x_full = self._embed(self.params, jnp.asarray(tokens)[:, None])
        alpha = np.asarray(DIFF.token_difficulty_ema(jnp.asarray(alpha),
                                                     x_full))
        tau = np.asarray(self.dart.tau, np.float32)
        coef = np.asarray(self.dart.coef, np.float32)

        out_tok = np.zeros(b, np.int64)
        out_stage = np.zeros(b, np.int64)
        active = np.arange(b)
        x = x_full
        n_stages = len(self.stages)
        cache = list(cache)

        for s, (a, bnd) in enumerate(self.stages):
            n = len(active)
            bucket = self.compactor.bucket_for(n)
            act = jnp.asarray(active)
            # gather cache rows for the active set (+pad with row 0)
            gather_idx = self.compactor.pad(np.asarray(active), bucket,
                                            fill=0).astype(np.int64)
            cache_sl = [jax.tree.map(
                lambda c: jnp.take(c, jnp.asarray(gather_idx), axis=0),
                cache[i]) for i in range(a, bnd)]
            x_pad = self.compactor.pad(x, bucket)
            x_new, new_sl = self._stage_fns[s](self.params, x_pad, cache_sl,
                                               cache_index)
            # scatter updated cache rows back
            for j, i in enumerate(range(a, bnd)):
                cache[i] = jax.tree.map(
                    lambda full, sl: full.at[act].set(sl[:n]),
                    cache[i], new_sl[j])
            self.layers_run += (bnd - a) * n

            logits = self._exit_logits[s](self.params, x_new[:n, 0])
            conf = self._conf_fn(logits, use_kernel=self.use_kernel)
            pred = jnp.argmax(logits, -1)
            conf, pred = np.asarray(conf), np.asarray(pred)

            if s < n_stages - 1:
                eff = np.asarray(TH.stage_threshold(
                    tau[s], coef[s], alpha[active], self.dart.beta_diff))
                fire = conf > eff
            else:
                fire = np.ones(n, bool)
            done = active[fire]
            out_tok[done] = pred[fire]
            out_stage[done] = s
            self.stats_exit[s] += int(fire.sum())

            if s < n_stages - 1 and fire.any():
                # CALM state propagation for the exited rows
                h_exit = x_new[:n][jnp.asarray(np.nonzero(fire)[0])]
                sub = [jax.tree.map(lambda c: jnp.take(
                    c, jnp.asarray(done), axis=0), cache[i])
                    for i in range(len(cache))]
                sub = self._propagate[s](self.params, h_exit[:, 0], sub,
                                         cache_index)
                for i in range(self.stages[s][1], self.cfg.n_layers):
                    cache[i] = jax.tree.map(
                        lambda full, sl: full.at[jnp.asarray(done)].set(sl),
                        cache[i], sub[i])
                self.layers_skipped += \
                    (self.cfg.n_layers - bnd) * int(fire.sum())
            keep = ~fire
            if not keep.any():
                break
            x = x_new[:n][jnp.asarray(np.nonzero(keep)[0])]
            active = active[keep]
        return out_tok, out_stage, cache, alpha

    def generate(self, prompt_tokens: np.ndarray, n_new: int,
                 max_len: int | None = None):
        """prompt_tokens: (B, S0).  Greedy generation with early exits.
        Batches larger than the biggest bucket are split into chunks
        (each chunk gets its own KV cache)."""
        b, s0 = prompt_tokens.shape
        if b > self.compactor.max_bucket:
            outs, stgs = [], []
            for a, z in self.compactor.chunks(b):
                o, st = self.generate(prompt_tokens[a:z], n_new, max_len)
                outs.append(o)
                stgs.append(st)
            return np.concatenate(outs), np.concatenate(stgs)
        max_len = max_len or (s0 + n_new + 1)
        cache = self.init_cache(b, max_len)
        cache = self.prefill(prompt_tokens[:, :-1], cache)
        alpha = np.full((b,), 0.5, np.float32)
        toks = prompt_tokens[:, -1]
        out = []
        stages = []
        for t in range(n_new):
            toks, stage, cache, alpha = self.decode_step(
                toks, cache, s0 - 1 + t, alpha)
            out.append(toks.copy())
            stages.append(stage.copy())
        return np.stack(out, 1), np.stack(stages, 1)
