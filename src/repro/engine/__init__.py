"""repro.engine — the unified, pluggable DART inference session API.

The paper's three contributions (difficulty estimation §II.A, joint
policy optimization §II.B, adaptive coefficient management §II.C) used
to be wired together by hand at every call site.  This package is the
single composable façade over that lifecycle:

    from repro.engine import DartEngine

    engine = DartEngine.from_config(model_cfg, params)   # 1. wire up
    engine.calibrate(cal_data)                           # 2. fit policy
    out = engine.infer(x, mode="compacted")              # 3. serve
    engine.update()                                      # 4. adapt
    engine.stats()                                       # 5. meter

Pieces:

* :class:`DartEngine`     — the session object (engine.py)
* :class:`EngineState`    — ALL mutable serving state as one pytree:
  thresholds + §II.C sliding window + counters.  Checkpoint-, jit- and
  shard-compatible as a single object (state.py)
* :mod:`registry`         — string-keyed strategy tables: confidence
  functionals, difficulty estimators, policy optimizers (incl. the
  Table I baselines behind the same ``PolicyOptimizer`` protocol)
* :class:`BatchCompactor` — bucket-padded batch compaction shared by the
  staged classifier path and the LM decode engine (compactor.py)
* :class:`LMDecodeEngine` — early-exit autoregressive decoding with
  CALM-style KV propagation (lm.py).  Pass ``mesh=make_serving_mesh()``
  for the jit-end-to-end sharded decode loop (one donated-cache
  compiled step per (stage, bucket)); the eager per-stage path stays
  available as the oracle (``generate(..., mode="eager")``)
* :class:`ShardedDartEngine` — jit-end-to-end, data-parallel serving
  over a device mesh: donated-state compiled step, per-bucket compile
  caches, replicated policy + per-replica telemetry (sharded.py); reach
  it via ``DartEngine.from_config(..., mesh=make_serving_mesh())``

One layer up, :mod:`repro.serving` turns an engine into an async server
(``AsyncDartServer(engine).submit(x, deadline_ms) -> Future``) with
difficulty-aware admission and SLO-driven batch consolidation;
``LMDecodeEngine.session()`` is the same machinery for decode requests.

(The legacy ``repro.runtime.server`` / ``repro.runtime.lm_server``
shims were removed in PR 4; import from here instead.)
"""
from repro.engine import registry
from repro.engine.compactor import BatchCompactor, BatchTooLarge
from repro.engine.engine import DartEngine
from repro.engine.lm import LMDecodeEngine
from repro.engine.registry import (get_confidence, get_difficulty,
                                   get_optimizer, register_confidence,
                                   register_difficulty, register_optimizer,
                                   route_policy)
from repro.engine.sharded import ShardedDartEngine
from repro.engine.state import EngineState

__all__ = ["registry", "BatchCompactor", "BatchTooLarge", "DartEngine",
           "LMDecodeEngine", "get_confidence", "get_difficulty",
           "get_optimizer", "register_confidence", "register_difficulty",
           "register_optimizer", "route_policy", "ShardedDartEngine",
           "EngineState"]
