"""BatchCompactor — bucket-padded batch compaction for staged serving.

Both serving engines (the staged classifier engine and the LM decode
engine) run survivors of each stage through power-of-two buckets so the
number of distinct compiled shapes is bounded by #stages × #buckets.
This class centralizes that machinery:

* ``bucket_for(n)``   — smallest bucket ≥ n; RAISES on overflow instead
  of silently clamping (the old ``_next_bucket`` returned the largest
  bucket for any ``n > max``, making ``pad = bucket - n`` negative and
  corrupting ``jnp.concatenate`` pads).
* ``chunks(n)``       — split an oversized request into ≤ max_bucket
  spans so callers can serve arbitrarily large batches.
* ``pad(arr, bucket, fill)``      — pad axis 0 up to the bucket.
* ``pad_tree(tree, bucket)``      — same, mapped over a pytree.
* ``gather(arr, idx, bucket)``    — compact survivors (+ pad) in one
  ``take``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_BUCKETS = tuple(2 ** i for i in range(0, 11))       # 1 .. 1024


class BatchTooLarge(ValueError):
    """Raised when a batch exceeds the largest bucket (use ``chunks``)."""


class BatchCompactor:
    def __init__(self, buckets=None):
        buckets = DEFAULT_BUCKETS if buckets is None \
            else tuple(sorted(buckets))
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"invalid buckets {buckets!r}")
        self.buckets = buckets

    @property
    def max_bucket(self) -> int:
        return self.buckets[-1]

    # ------------------------------------------------------------------
    def bucket_for(self, n: int) -> int:
        if n > self.max_bucket:
            raise BatchTooLarge(
                f"batch of {n} exceeds largest bucket {self.max_bucket}; "
                f"split it with .chunks({n})")
        for b in self.buckets:
            if n <= b:
                return b
        raise BatchTooLarge(f"no bucket for n={n} in {self.buckets}")

    def padded_size(self, n: int, multiple_of: int = 1) -> int:
        """Fixed serving shape for an ``n``-sample batch: the bucket for
        ``n``, rounded up to a multiple of ``multiple_of`` (so a
        data-parallel mesh divides it evenly).  Call it through
        ``engine.bucket_key(n)`` — the ONE compile-cache key shared by
        the eager compacted path, the sharded step caches and the async
        scheduler's flush planner (``multiple_of`` = the engine's
        ``replica_multiple``)."""
        b = self.bucket_for(n)
        return -(-b // multiple_of) * multiple_of

    def chunks(self, n: int) -> list[tuple[int, int]]:
        """[(start, end)) spans covering an n-sample request, each span
        no larger than the biggest bucket."""
        m = self.max_bucket
        return [(s, min(s + m, n)) for s in range(0, max(n, 0), m)]

    # ------------------------------------------------------------------
    @staticmethod
    def pad(arr, bucket: int, fill=0.0):
        """Pad axis 0 of ``arr`` (jnp or np) up to ``bucket`` with
        ``fill``."""
        n = arr.shape[0]
        pad = bucket - n
        if pad < 0:
            raise BatchTooLarge(f"array of {n} rows > bucket {bucket}")
        if pad == 0:
            return arr
        if isinstance(arr, np.ndarray):
            return np.concatenate(
                [arr, np.full((pad,) + arr.shape[1:], fill, arr.dtype)])
        return jnp.concatenate(
            [arr, jnp.full((pad,) + arr.shape[1:], fill, arr.dtype)])

    def pad_tree(self, tree, bucket: int, fill=0.0):
        return jax.tree.map(lambda a: self.pad(a, bucket, fill), tree)

    @staticmethod
    def gather(arr, idx, bucket: int | None = None):
        """Compact rows ``idx`` of ``arr`` (and optionally re-pad to a
        bucket by repeating row 0 — callers mask those lanes)."""
        idx = jnp.asarray(idx)
        if bucket is not None:
            pad = bucket - idx.shape[0]
            if pad < 0:
                raise BatchTooLarge(
                    f"{idx.shape[0]} survivors > bucket {bucket}")
            if pad:
                idx = jnp.concatenate([idx, jnp.zeros((pad,), idx.dtype)])
        return jnp.take(arr, idx, axis=0)
