"""BatchCompactor — bucket-padded batch compaction for staged serving.

Both serving engines (the staged classifier engine and the LM decode
engine) run survivors of each stage through power-of-two buckets so the
number of distinct compiled shapes is bounded by #stages × #buckets.
This class centralizes that machinery:

* ``bucket_for(n)``   — smallest bucket ≥ n; RAISES on overflow instead
  of silently clamping (the old ``_next_bucket`` returned the largest
  bucket for any ``n > max``, making ``pad = bucket - n`` negative and
  corrupting ``jnp.concatenate`` pads).
* ``chunks(n)``       — split an oversized request into ≤ max_bucket
  spans so callers can serve arbitrarily large batches.
* ``pad(arr, bucket, fill)``      — pad axis 0 up to the bucket.
* ``pad_tree(tree, bucket)``      — same, mapped over a pytree.
* ``gather(arr, idx, bucket)``    — compact survivors (+ pad) in one
  ``take``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_BUCKETS = tuple(2 ** i for i in range(0, 11))       # 1 .. 1024


class BatchTooLarge(ValueError):
    """Raised when a batch exceeds the largest bucket (use ``chunks``)."""


class BatchCompactor:
    def __init__(self, buckets=None):
        buckets = DEFAULT_BUCKETS if buckets is None \
            else tuple(sorted(buckets))
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"invalid buckets {buckets!r}")
        self.buckets = buckets

    @property
    def max_bucket(self) -> int:
        return self.buckets[-1]

    # ------------------------------------------------------------------
    def bucket_for(self, n: int) -> int:
        if n > self.max_bucket:
            raise BatchTooLarge(
                f"batch of {n} exceeds largest bucket {self.max_bucket}; "
                f"split it with .chunks({n})")
        for b in self.buckets:
            if n <= b:
                return b
        raise BatchTooLarge(f"no bucket for n={n} in {self.buckets}")

    def padded_size(self, n: int, multiple_of: int = 1) -> int:
        """Fixed serving shape for an ``n``-sample batch: the bucket for
        ``n``, rounded up to a multiple of ``multiple_of`` (so a
        data-parallel mesh divides it evenly).  Call it through
        ``engine.bucket_key(n)`` — the ONE compile-cache key shared by
        the eager compacted path, the sharded step caches and the async
        scheduler's flush planner (``multiple_of`` = the engine's
        ``replica_multiple``)."""
        b = self.bucket_for(n)
        return -(-b // multiple_of) * multiple_of

    def chunks(self, n: int) -> list[tuple[int, int]]:
        """[(start, end)) spans covering an n-sample request, each span
        no larger than the biggest bucket."""
        m = self.max_bucket
        return [(s, min(s + m, n)) for s in range(0, max(n, 0), m)]

    # ------------------------------------------------------------------
    @staticmethod
    def pad(arr, bucket: int, fill=0.0):
        """Pad axis 0 of ``arr`` (jnp or np) up to ``bucket`` with
        ``fill``."""
        n = arr.shape[0]
        pad = bucket - n
        if pad < 0:
            raise BatchTooLarge(f"array of {n} rows > bucket {bucket}")
        if pad == 0:
            return arr
        if isinstance(arr, np.ndarray):
            return np.concatenate(
                [arr, np.full((pad,) + arr.shape[1:], fill, arr.dtype)])
        return jnp.concatenate(
            [arr, jnp.full((pad,) + arr.shape[1:], fill, arr.dtype)])

    def pad_tree(self, tree, bucket: int, fill=0.0):
        return jax.tree.map(lambda a: self.pad(a, bucket, fill), tree)

    @staticmethod
    def gather(arr, idx, bucket: int | None = None):
        """Compact rows ``idx`` of ``arr`` (and optionally re-pad to a
        bucket by repeating row 0 — callers mask those lanes)."""
        idx = jnp.asarray(idx)
        if bucket is not None:
            pad = bucket - idx.shape[0]
            if pad < 0:
                raise BatchTooLarge(
                    f"{idx.shape[0]} survivors > bucket {bucket}")
            if pad:
                idx = jnp.concatenate([idx, jnp.zeros((pad,), idx.dtype)])
        return jnp.take(arr, idx, axis=0)


# ---------------------------------------------------------------------------
# Slot-pool continuous batching: host-side resource accounting
# ---------------------------------------------------------------------------
#
# Both allocators partition their id space into ``n_ranges`` contiguous
# ranges (one per mesh replica).  The continuous decoder keeps the
# invariant "slot s draws KV pages only from range(s)" so a replica's
# page-table entries always resolve into its own page shard — the
# pallas paged-gather then needs only a local ``% pages_per_replica``
# under shard_map, and the XLA path is free of cross-replica gathers.


class OutOfCapacity(RuntimeError):
    """Raised on alloc from an exhausted slot/page range (callers are
    expected to gate on ``available`` / ``can_admit`` first)."""


class PageAllocator:
    """Free-list allocator over ``n_pages`` fixed-size KV pages.

    Double-alloc and double-free are programming errors and raise —
    the continuous-batching property harness leans on that.
    """

    def __init__(self, n_pages: int, n_ranges: int = 1):
        if n_pages <= 0 or n_ranges <= 0 or n_pages % n_ranges:
            raise ValueError(f"n_pages={n_pages} not divisible into "
                             f"{n_ranges} ranges")
        self.n_pages = n_pages
        self.n_ranges = n_ranges
        self.per_range = n_pages // n_ranges
        self._free = [list(range(r * self.per_range,
                                 (r + 1) * self.per_range))
                      for r in range(n_ranges)]
        self._held: set[int] = set()

    def available(self, rng: int = 0) -> int:
        return len(self._free[rng])

    @property
    def in_use(self) -> int:
        return len(self._held)

    def alloc(self, n: int, rng: int = 0) -> list[int]:
        free = self._free[rng]
        if n > len(free):
            raise OutOfCapacity(
                f"need {n} pages, range {rng} has {len(free)}")
        pages, self._free[rng] = free[:n], free[n:]
        for p in pages:
            if p in self._held:
                raise AssertionError(f"page {p} double-allocated")
            self._held.add(p)
        return pages

    def free(self, pages) -> None:
        for p in pages:
            if p not in self._held:
                raise AssertionError(f"page {p} freed but not held")
            self._held.discard(p)
            self._free[p // self.per_range].append(p)

    def occupancy(self) -> dict:
        """Host-side occupancy snapshot (the obs gauge source)."""
        return {"total": self.n_pages, "in_use": self.in_use,
                "free_per_range": [len(f) for f in self._free]}


class SlotPool:
    """Free-list over ``n_slots`` decode slots, range-partitioned like
    :class:`PageAllocator`."""

    def __init__(self, n_slots: int, n_ranges: int = 1):
        if n_slots <= 0 or n_ranges <= 0 or n_slots % n_ranges:
            raise ValueError(f"n_slots={n_slots} not divisible into "
                             f"{n_ranges} ranges")
        self.n_slots = n_slots
        self.n_ranges = n_ranges
        self.per_range = n_slots // n_ranges
        self._free = [list(range(r * self.per_range,
                                 (r + 1) * self.per_range))
                      for r in range(n_ranges)]
        self._held: set[int] = set()

    def available(self, rng: int = 0) -> int:
        return len(self._free[rng])

    @property
    def in_use(self) -> int:
        return len(self._held)

    def range_of(self, slot: int) -> int:
        return slot // self.per_range

    def acquire(self, rng: int = 0) -> int:
        free = self._free[rng]
        if not free:
            raise OutOfCapacity(f"slot range {rng} exhausted")
        slot = free.pop(0)
        if slot in self._held:
            raise AssertionError(f"slot {slot} double-allocated")
        self._held.add(slot)
        return slot

    def release(self, slot: int) -> None:
        if slot not in self._held:
            raise AssertionError(f"slot {slot} released but not held")
        self._held.discard(slot)
        self._free[self.range_of(slot)].append(slot)

    def occupancy(self) -> dict:
        """Host-side occupancy snapshot (the obs gauge source)."""
        return {"total": self.n_slots, "in_use": self.in_use,
                "free_per_range": [len(f) for f in self._free]}
