"""DART core — the paper's contribution.

difficulty   — §II.A multi-modal difficulty estimation (Eqs. 1–8, 17)
thresholds   — Eq. 12 calibration, Eq. 19 adaptation, Alg. 1 selection
policy       — §II.B joint exit-policy optimization (Eqs. 10–11)
adaptive     — §II.C coefficient management (Eqs. 13–15, UCB1)
routing      — batched execution modes + confidence functionals
baselines    — Static / BranchyNet / RL-Agent (Table I)
daes         — §II.A.3 DAES metric (Eq. 9) + Eqs. 20–22
"""
from repro.core import (adaptive, baselines, daes, difficulty, policy,
                        routing, thresholds)
from repro.core.routing import DartParams
from repro.core.policy import CalibrationData, PolicyResult
from repro.core.difficulty import DifficultyConfig

__all__ = ["adaptive", "baselines", "daes", "difficulty", "policy",
           "routing", "thresholds", "DartParams", "CalibrationData",
           "PolicyResult", "DifficultyConfig"]
