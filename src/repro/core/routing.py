"""Batched DART routing — the glue between models and the DART policy.

Execution modes (DESIGN.md §4.1):

* ``train``          — all exits computed; Eq. 18 multi-exit loss.
* ``serve-masked``   — single jitted program: full forward, then Alg. 1
  selection on the stacked exit confidences.  Bitwise-identical decisions
  to the sequential algorithm; compute is worst-case (used by the dry-run).
* ``serve-compacted``— the stage-segmented engine in
  ``repro.engine`` (real FLOP savings via batch compaction).

Confidence functionals per family:
* classifiers — max softmax probability (paper); the serving engines
  fuse it with the Alg. 1 gate through ``repro.kernels.dispatch``;
* diffusion  — convergence of consecutive exit predictions.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import thresholds as TH
from repro.core import difficulty as DIFF


@dataclasses.dataclass(frozen=True)
class DartParams:
    """Runtime routing parameters (learned offline, adapted online)."""
    tau: Any                     # (E-1,) base thresholds
    coef: Any                    # (E-1,) or (B, E-1) coefficients
    beta_diff: float = 0.3
    beta_opt: float = 0.5

    @staticmethod
    def default(n_exits: int, tau: float = 0.7):
        return DartParams(tau=jnp.full((n_exits - 1,), tau),
                          coef=jnp.ones((n_exits - 1,)))


def confidence_from_logits(logits, use_kernel: bool = False):
    """Max softmax probability per sample.  logits: (..., V) -> (...).

    This jnp composition IS the reference the fused kernels are held to
    (``kernels/exit_gate/ref.py`` reuses it bit for bit).
    ``use_kernel=True`` routes through ``kernels.dispatch`` — which
    picks the fused Pallas gate only where it pays (TPU, VMEM-resident
    rows) and this same chain everywhere else."""
    if use_kernel:
        from repro.kernels import dispatch as KD
        return KD.softmax_confidence(logits)[0]
    return jnp.max(jax.nn.softmax(logits.astype(jnp.float32), axis=-1),
                   axis=-1)


def entropy_from_logits(logits):
    """Shannon entropy (BranchyNet's criterion)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.sum(jnp.exp(logp) * logp, axis=-1)


def diffusion_confidence(eps_stack):
    """Exit confidence for diffusion models: convergence of consecutive
    exit predictions.  eps_stack: (E, B, H, W, C) -> (E, B).

    conf_i = 1 − ‖ε_i − ε_{i−1}‖ / (‖ε_i‖ + ‖ε_{i−1}‖); exit 0 has no
    history → confidence 0 (never exits unless threshold is 0)."""
    e = eps_stack.shape[0]
    flat = eps_stack.reshape(e, eps_stack.shape[1], -1).astype(jnp.float32)
    norms = jnp.linalg.norm(flat, axis=-1)
    diffs = jnp.linalg.norm(flat[1:] - flat[:-1], axis=-1)
    conf = 1.0 - diffs / (norms[1:] + norms[:-1] + 1e-8)
    first = jnp.zeros((1, eps_stack.shape[1]), jnp.float32)
    return jnp.concatenate([first, jnp.clip(conf, 0.0, 1.0)], axis=0)


# ---------------------------------------------------------------------------
# Masked-mode routing (Alg. 1 on stacked exits)
# ---------------------------------------------------------------------------

def route(conf_stack, alpha, dart: DartParams):
    """Alg. 1: adapt thresholds (Eq. 19) and pick the first firing exit.

    conf_stack: (E, B); alpha: (B,).  Returns dict with exit_idx, conf,
    eff_thresholds."""
    eff = TH.adapt_thresholds(jnp.asarray(dart.tau), jnp.asarray(dart.coef),
                              alpha, dart.beta_diff)
    exit_idx, conf = TH.select_exit(conf_stack, eff)
    return {"exit_idx": exit_idx, "conf": conf, "eff_thresholds": eff,
            "alpha": alpha}


def classify_routed(exit_logits, images, dart: DartParams,
                    dcfg: DIFF.DifficultyConfig = DIFF.DEFAULT,
                    alpha=None, use_kernel: bool = False):
    """Masked-mode DART classification.

    exit_logits: (E, B, n_classes) — all exits computed.
    Returns predictions taken from each sample's selected exit."""
    conf_stack = confidence_from_logits(exit_logits, use_kernel)   # (E, B)
    if alpha is None:
        alpha = DIFF.image_difficulty(images, dcfg)
    r = route(conf_stack, alpha, dart)
    preds_all = jnp.argmax(exit_logits, axis=-1)                   # (E, B)
    preds = jnp.take_along_axis(preds_all, r["exit_idx"][None], axis=0)[0]
    return {**r, "pred": preds, "preds_all": preds_all,
            "conf_stack": conf_stack}


def diffusion_routed(eps_stack, latents, signal_frac, dart: DartParams,
                     dcfg: DIFF.DifficultyConfig = DIFF.DEFAULT):
    """Masked-mode DART for diffusion: pick the earliest converged exit."""
    conf_stack = diffusion_confidence(eps_stack)
    alpha = DIFF.latent_difficulty(latents, signal_frac, dcfg)
    r = route(conf_stack, alpha, dart)
    eps = jnp.take_along_axis(
        eps_stack, r["exit_idx"][None, :, None, None, None], axis=0)[0]
    return {**r, "eps": eps, "conf_stack": conf_stack}


# ---------------------------------------------------------------------------
# Multi-exit training loss for classifiers (paper Eq. 18)
# ---------------------------------------------------------------------------

def multi_exit_xent(exit_logits, labels, *, policy_weight: float = 0.01,
                    exit_weights=None):
    """L = Σ_i w_i·CE(y, ŷ_i) + λ·L_policy, w_i = i/N (Eq. 18).

    exit_logits: (E, B, C); labels: (B,)."""
    e = exit_logits.shape[0]
    if exit_weights is None:
        exit_weights = [(i + 1) / e for i in range(e)]
    logp = jax.nn.log_softmax(exit_logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logp, labels[None, :, None], axis=-1)[..., 0]
    ces = -jnp.mean(gold, axis=-1)                          # (E,)
    total = jnp.sum(jnp.asarray(exit_weights) * ces)
    # policy regularizer: penalize late-exit overuse by pushing early heads
    # toward the final head's loss
    policy = jnp.sum(jnp.maximum(ces[:-1] - ces[-1], 0.0)) if e > 1 else 0.0
    return total + policy_weight * policy, {"ce_per_exit": ces}


# ---------------------------------------------------------------------------
# Routed-cost accounting
# ---------------------------------------------------------------------------

def routed_macs(exit_idx, cum_macs):
    """Per-sample MACs actually spent under the routing (+ the difficulty
    estimator overhead is added by callers via difficulty.estimator_flops)."""
    return jnp.asarray(cum_macs)[exit_idx]
