"""DART threshold machinery — Eq. 12 (quantile candidates), Eq. 19
(difficulty-aware adaptation) and Algorithm 1 (adaptive exit decision).

All functions are batched and jit-safe; the serving engine and the
masked-mode dry-run step call straight into these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def candidate_thresholds(confidences, qs=None):
    """Eq. 12: τ_i^cand = quantile(C_i, q), q ∈ {0.1, …, 0.9}.

    confidences: (n_samples,) conf values observed at one exit on the
    calibration set.  Returns (9,) candidates (host-side, numpy)."""
    qs = np.arange(0.1, 0.91, 0.1) if qs is None else np.asarray(qs)
    return np.quantile(np.asarray(confidences), qs)


def adapt_thresholds(tau, coef, alpha, beta_diff):
    """Eq. 19 + clamp: τ'_i = clip(c_i ⊙ τ_i + β_diff·α, 0, 1).

    tau:   (E-1,) learned base thresholds
    coef:  (E-1,) adaptive coefficients (or (B, E-1) per-sample/class)
    alpha: (B,) per-input difficulty
    Returns (B, E-1) effective thresholds."""
    tau_adapted = coef * tau                       # element-wise (Alg.1 l.3)
    if tau_adapted.ndim == 1:
        tau_adapted = tau_adapted[None, :]
    eff = tau_adapted + beta_diff * alpha[:, None]
    return jnp.clip(eff, 0.0, 1.0)


def stage_threshold(tau_s, coef_s, alpha, beta_diff, lo=0.0, hi=1.0):
    """Eq. 19 for ONE gate: τ'_s = clip(c_s·τ_s + β_diff·α, lo, hi).

    The per-stage form used by the segmented serving engines (classifier
    compacted mode, sharded compacted mode, LM decode); `adapt_thresholds`
    is the all-gates batched form."""
    return jnp.clip(coef_s * tau_s + beta_diff * alpha, lo, hi)


def select_exit(conf_stack, eff_thresholds):
    """Algorithm 1 lines 4–12, batched.

    conf_stack:      (E, B)   confidence at every exit (final included)
    eff_thresholds:  (B, E-1) difficulty-aware thresholds
    Returns (exit_idx (B,), exited_conf (B,)).  The final exit always
    accepts (line 12)."""
    e, b = conf_stack.shape
    fires = conf_stack[:-1].T > eff_thresholds          # (B, E-1)
    fires = jnp.concatenate(
        [fires, jnp.ones((b, 1), bool)], axis=1)        # final always fires
    exit_idx = jnp.argmax(fires, axis=1)                # first True
    exited_conf = jnp.take_along_axis(conf_stack.T, exit_idx[:, None],
                                      axis=1)[:, 0]
    return exit_idx, exited_conf


def ruled_out_stages(tau, coef, beta_diff, alpha_lo, conf_max=1.0):
    """Which gates can provably NEVER fire for any input with
    difficulty ≥ ``alpha_lo`` under the CURRENT policy (host-side).

    Alg. 1 fires gate s iff ``conf > clip(c_s·τ_s + β_diff·α, 0, 1)``
    (strict).  Confidence functionals bounded above by ``conf_max``
    (max-softmax and the LM token head are ≤ 1.0 by construction)
    therefore can never fire once the UNCLIPPED Eq. 19 threshold
    reaches ``conf_max``; and with β_diff ≥ 0 the threshold is
    monotone nondecreasing in α, so checking the bucket's smallest
    difficulty bounds every row.  Returns a (E-1,) bool mask —
    ``True`` = gate s is ruled out, sound to skip."""
    tau = np.asarray(tau, np.float64)
    coef = np.asarray(coef, np.float64)
    if float(beta_diff) < 0.0:      # threshold no longer monotone in α
        return np.zeros(tau.shape, bool)
    return (coef * tau + float(beta_diff) * float(alpha_lo)
            >= float(conf_max))


def min_exit_bound(tau, coef, beta_diff, alpha_lo, conf_max=1.0):
    """Largest m such that gates 0..m-1 are ALL ruled out for every
    input with difficulty ≥ ``alpha_lo`` (see ``ruled_out_stages``) —
    the sound per-bucket ``min_exit`` the serving predictor hands to
    the engines' head-skip path.  0 = nothing can be skipped."""
    ruled = ruled_out_stages(tau, coef, beta_diff, alpha_lo, conf_max)
    m = 0
    for r in ruled:
        if not r:
            break
        m += 1
    return m


def exit_distribution(exit_idx, n_exits):
    """π_i — empirical exit distribution (Eq. 10's π)."""
    return jnp.mean(jax.nn.one_hot(exit_idx, n_exits), axis=0)


def expected_cost(exit_idx, cum_costs):
    """Mean computational cost under the routing (C_i = cumulative cost up
    to exit i, e.g. MACs)."""
    cum = jnp.asarray(cum_costs)
    return jnp.mean(cum[exit_idx])


def simulate_routing(conf_matrix, alpha, tau, coef, beta_diff):
    """Vectorized Alg. 1 over a calibration set.

    conf_matrix: (n, E); alpha: (n,); tau/coef: (E-1,).
    Returns exit_idx (n,)."""
    eff = adapt_thresholds(jnp.asarray(tau), jnp.asarray(coef),
                           jnp.asarray(alpha), beta_diff)
    return select_exit(jnp.asarray(conf_matrix).T, eff)[0]


def objective(conf_matrix, alpha, correct_matrix, cum_costs, tau, coef,
              beta_diff, beta_opt):
    """Eq. 10: J(τ) = Σ_i π_i(τ)[A_i − β_opt·C_i], evaluated empirically.

    correct_matrix: (n, E) 0/1 — was exit i's prediction correct.
    cum_costs: (E,) normalized cumulative cost."""
    idx = simulate_routing(conf_matrix, alpha, tau, coef, beta_diff)
    acc = jnp.take_along_axis(jnp.asarray(correct_matrix), idx[:, None],
                              axis=1)[:, 0]
    cost = jnp.asarray(cum_costs)[idx]
    return jnp.mean(acc - beta_opt * cost)
