"""DART joint exit-policy optimization — paper §II.B (Eqs. 10–12).

Maximizes  J(τ) = Σ_i π_i(τ)·[A_i − β_opt·C_i]  (Eq. 10) over the *whole*
threshold vector jointly, via value iteration on the state space
``s = (exit_index, α_bin, confidence_bin)`` with the Q-update of Eq. 11:

    Q(s, a) = R(s, a) + γ Σ_s' P(s'|s, a) V(s')

* ``a = exit``     → R = Â(i, α_bin, conf_bin) − β_opt·C_i, terminal.
* ``a = continue`` → R = 0; transition to exit i+1 with the *empirical*
  conf-bin transition kernel P(c'| i, α_bin, c) estimated from the
  calibration set (with hierarchical fallback for sparse bins).

Because the MDP is a finite horizon chain over exits, value iteration
converges in exactly N sweeps — we run backward induction, which is the
same fixed point.  The DP solution (a per-(exit, α_bin) confidence
threshold) is then projected onto the paper's runtime parameterization
(Eq. 19: τ'_i = c_i·τ_i + β_diff·α) by weighted least squares over the
Eq. 12 quantile candidates.

Also provides the brute-force joint search (oracle for tests) and the
independent-per-exit baseline the paper argues against.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core import thresholds as TH


@dataclasses.dataclass
class CalibrationData:
    """Per-sample calibration measurements.

    conf:     (n, E) confidence of each exit's prediction
    correct:  (n, E) 1.0 if exit i's prediction is correct
    alpha:    (n,)   difficulty scores (Eq. 8)
    cum_costs:(E,)   cumulative normalized compute up to each exit
                     (full network = 1.0)
    labels:   (n,) optional class ids (for class-aware adaptation)
    entropy:  (n, E) optional per-exit softmax entropy (lets entropy-
                     criterion baselines like BranchyNet fit faithfully)
    """
    conf: np.ndarray
    correct: np.ndarray
    alpha: np.ndarray
    cum_costs: np.ndarray
    labels: np.ndarray | None = None
    entropy: np.ndarray | None = None

    @property
    def n_exits(self) -> int:
        return self.conf.shape[1]

    def split(self, frac=0.8, seed=0):
        n = self.conf.shape[0]
        rs = np.random.RandomState(seed)
        perm = rs.permutation(n)
        k = int(n * frac)
        tr, va = perm[:k], perm[k:]
        pick = lambda idx: CalibrationData(
            self.conf[idx], self.correct[idx], self.alpha[idx],
            self.cum_costs, None if self.labels is None else self.labels[idx],
            None if self.entropy is None else self.entropy[idx])
        return pick(tr), pick(va)


@dataclasses.dataclass
class PolicyResult:
    tau: np.ndarray              # (E-1,) base thresholds
    coef: np.ndarray             # (E-1,) coefficients (init 1.0)
    beta_diff: float
    objective: float             # empirical J on the calibration set
    method: str
    dp_thresholds: np.ndarray | None = None   # (E-1, A) per-α-bin DP solution
    diagnostics: dict | None = None


def _bin_edges(n_bins):
    return np.linspace(0.0, 1.0, n_bins + 1)


def _digitize(x, n_bins):
    return np.clip((np.asarray(x) * n_bins).astype(int), 0, n_bins - 1)


def _empirical_tables(data: CalibrationData, n_alpha_bins, n_conf_bins,
                      smooth=1.0):
    """Accuracy table Â[i,a,c] and transition kernel P[i,a,c,c']."""
    n, e = data.conf.shape
    ab = _digitize(data.alpha, n_alpha_bins)
    cb = _digitize(data.conf, n_conf_bins)                 # (n, E)

    acc = np.zeros((e, n_alpha_bins, n_conf_bins))
    cnt = np.zeros_like(acc)
    np.add.at(cnt, (slice(None),), 0)  # no-op, keeps shape clear
    for i in range(e):
        np.add.at(cnt[i], (ab, cb[:, i]), 1.0)
        np.add.at(acc[i], (ab, cb[:, i]), data.correct[:, i])
    # hierarchical fallback: (i,a,c) -> (i,c) -> (i)
    acc_ic = np.zeros((e, n_conf_bins))
    cnt_ic = np.zeros_like(acc_ic)
    for i in range(e):
        np.add.at(cnt_ic[i], cb[:, i], 1.0)
        np.add.at(acc_ic[i], cb[:, i], data.correct[:, i])
    acc_i = data.correct.mean(axis=0)                      # (E,)
    acc_ic_s = (acc_ic + smooth * acc_i[:, None]) / (cnt_ic + smooth)
    acc_s = (acc + smooth * acc_ic_s[:, None, :]) / (cnt + smooth)

    # transitions i -> i+1
    trans = np.zeros((e - 1, n_alpha_bins, n_conf_bins, n_conf_bins))
    tcnt = np.zeros_like(trans)
    for i in range(e - 1):
        np.add.at(tcnt[i], (ab, cb[:, i], cb[:, i + 1]), 1.0)
        np.add.at(trans[i], (ab, cb[:, i], cb[:, i + 1]), 1.0)
    # fallback kernel: P(c' | i) marginal
    marg = np.zeros((e - 1, n_conf_bins))
    for i in range(e - 1):
        np.add.at(marg[i], cb[:, i + 1], 1.0)
        marg[i] /= max(marg[i].sum(), 1.0)
    denom = tcnt.sum(axis=-1, keepdims=True)
    trans_s = (trans + smooth * marg[:, None, None, :]) \
        / (denom + smooth)
    return acc_s, trans_s


def optimize_joint_dp(data: CalibrationData, *, beta_opt=0.5, gamma=1.0,
                      n_alpha_bins=4, n_conf_bins=10, beta_diff=0.3,
                      fit_beta_diff=False, smooth=1.0) -> PolicyResult:
    """Backward-induction value iteration over (exit, α_bin, conf_bin)."""
    e = data.n_exits
    acc, trans = _empirical_tables(data, n_alpha_bins, n_conf_bins, smooth)
    costs = np.asarray(data.cum_costs, float)

    v = np.zeros((e, n_alpha_bins, n_conf_bins))
    exit_decision = np.zeros((e - 1, n_alpha_bins, n_conf_bins), bool)
    v[e - 1] = acc[e - 1] - beta_opt * costs[e - 1]        # forced exit
    for i in range(e - 2, -1, -1):
        q_exit = acc[i] - beta_opt * costs[i]              # (A, C)
        q_cont = gamma * np.einsum("acd,ad->ac", trans[i], v[i + 1])
        exit_decision[i] = q_exit >= q_cont
        v[i] = np.maximum(q_exit, q_cont)

    # per-(exit, α_bin) threshold: smallest conf bin from which the policy
    # always exits (monotone suffix projection)
    edges = _bin_edges(n_conf_bins)
    dp_thr = np.ones((e - 1, n_alpha_bins))
    for i in range(e - 1):
        for a in range(n_alpha_bins):
            dec = exit_decision[i, a]
            cstar = n_conf_bins
            for c in range(n_conf_bins - 1, -1, -1):
                if dec[c]:
                    cstar = c
                else:
                    break
            dp_thr[i, a] = edges[cstar] if cstar < n_conf_bins else 1.0

    # project onto Eq. 19 runtime form using Eq. 12 candidates
    ab = _digitize(data.alpha, n_alpha_bins)
    occupancy = np.bincount(ab, minlength=n_alpha_bins).astype(float)
    occupancy /= max(occupancy.sum(), 1.0)
    alpha_mid = (_bin_edges(n_alpha_bins)[:-1]
                 + _bin_edges(n_alpha_bins)[1:]) / 2

    betas = [beta_diff] if not fit_beta_diff else \
        [0.0, 0.1, 0.2, 0.3, 0.4, 0.5]
    best = None
    ones = np.ones(e - 1)

    def joint_j(tau, bd):
        return float(TH.objective(data.conf, data.alpha, data.correct,
                                  data.cum_costs, tau, ones, bd, beta_opt))

    def polish(tau, bd, sweeps=2):
        """Coordinate ascent on the TRUE joint objective (Eq. 10) over the
        Eq. 12 candidates, starting from the DP projection.  This keeps
        threshold interdependence (each coordinate move is scored against
        the full routing) and repairs projection losses from the binned
        value iteration."""
        tau = tau.copy()
        best_j = joint_j(tau, bd)
        for _ in range(sweeps):
            improved = False
            for i in range(e - 1):
                for c in TH.candidate_thresholds(data.conf[:, i]):
                    t = tau.copy()
                    t[i] = c
                    j = joint_j(t, bd)
                    if j > best_j + 1e-12:
                        best_j, tau = j, t
                        improved = True
            if not improved:
                break
        return tau, best_j

    for bd in betas:
        tau = np.zeros(e - 1)
        for i in range(e - 1):
            cands = TH.candidate_thresholds(data.conf[:, i])
            # choose the candidate minimizing weighted sq. error to DP
            err = [(occupancy * (c + bd * alpha_mid - dp_thr[i]) ** 2).sum()
                   for c in cands]
            tau[i] = cands[int(np.argmin(err))]
        tau, j = polish(tau, bd)
        if best is None or j > best[0]:
            best = (j, tau, bd)
    j, tau, bd = best
    return PolicyResult(tau=tau, coef=ones, beta_diff=bd,
                        objective=j, method="joint_dp",
                        dp_thresholds=dp_thr,
                        diagnostics={"value": v, "acc_table": acc})


def optimize_brute_force(data: CalibrationData, *, beta_opt=0.5,
                         beta_diff=0.3, max_combos=20000) -> PolicyResult:
    """Exhaustive joint search over the Eq. 12 candidate grid (oracle)."""
    e = data.n_exits
    cand = [TH.candidate_thresholds(data.conf[:, i]) for i in range(e - 1)]
    total = int(np.prod([len(c) for c in cand]))
    if total > max_combos:
        raise ValueError(f"brute force too large: {total}")
    best = (-np.inf, None)
    ones = np.ones(e - 1)
    for combo in itertools.product(*cand):
        tau = np.asarray(combo)
        j = float(TH.objective(data.conf, data.alpha, data.correct,
                               data.cum_costs, tau, ones, beta_diff,
                               beta_opt))
        if j > best[0]:
            best = (j, tau)
    return PolicyResult(tau=best[1], coef=ones, beta_diff=beta_diff,
                        objective=best[0], method="brute_force")


def optimize_independent(data: CalibrationData, *, beta_opt=0.5,
                         beta_diff=0.3) -> PolicyResult:
    """The baseline DART argues against: each exit's threshold tuned in
    isolation (others pinned at their median candidate)."""
    e = data.n_exits
    tau = np.array([np.median(TH.candidate_thresholds(data.conf[:, i]))
                    for i in range(e - 1)])
    ones = np.ones(e - 1)
    for i in range(e - 1):
        best = (-np.inf, tau[i])
        for c in TH.candidate_thresholds(data.conf[:, i]):
            t = tau.copy()
            t[i] = c
            j = float(TH.objective(data.conf, data.alpha, data.correct,
                                   data.cum_costs, t, ones, beta_diff,
                                   beta_opt))
            if j > best[0]:
                best = (j, c)
        tau[i] = best[1]
    j = float(TH.objective(data.conf, data.alpha, data.correct,
                           data.cum_costs, tau, ones, beta_diff, beta_opt))
    return PolicyResult(tau=tau, coef=ones, beta_diff=beta_diff,
                        objective=j, method="independent")
