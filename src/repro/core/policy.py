"""DART joint exit-policy optimization — paper §II.B (Eqs. 10–12).

Maximizes  J(τ) = Σ_i π_i(τ)·[A_i − β_opt·C_i]  (Eq. 10) over the *whole*
threshold vector jointly, via value iteration on the state space
``s = (exit_index, α_bin, confidence_bin)`` with the Q-update of Eq. 11:

    Q(s, a) = R(s, a) + γ Σ_s' P(s'|s, a) V(s')

* ``a = exit``     → R = Â(i, α_bin, conf_bin) − β_opt·C_i, terminal.
* ``a = continue`` → R = 0; transition to exit i+1 with the *empirical*
  conf-bin transition kernel P(c'| i, α_bin, c) estimated from the
  calibration set (with hierarchical fallback for sparse bins).

Because the MDP is a finite horizon chain over exits, value iteration
converges in exactly N sweeps — we run backward induction, which is the
same fixed point.  The DP solution (a per-(exit, α_bin) confidence
threshold) is then projected onto the paper's runtime parameterization
(Eq. 19: τ'_i = c_i·τ_i + β_diff·α) by weighted least squares over the
Eq. 12 quantile candidates.

Also provides the brute-force joint search (oracle for tests) and the
independent-per-exit baseline the paper argues against.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core import thresholds as TH


@dataclasses.dataclass
class CalibrationData:
    """Per-sample calibration measurements.

    conf:     (n, E) confidence of each exit's prediction
    correct:  (n, E) 1.0 if exit i's prediction is correct
    alpha:    (n,)   difficulty scores (Eq. 8)
    cum_costs:(E,)   cumulative normalized compute up to each exit
                     (full network = 1.0)
    labels:   (n,) optional class ids (for class-aware adaptation)
    entropy:  (n, E) optional per-exit softmax entropy (lets entropy-
                     criterion baselines like BranchyNet fit faithfully)
    """
    conf: np.ndarray
    correct: np.ndarray
    alpha: np.ndarray
    cum_costs: np.ndarray
    labels: np.ndarray | None = None
    entropy: np.ndarray | None = None

    @property
    def n_exits(self) -> int:
        return self.conf.shape[1]

    def split(self, frac=0.8, seed=0):
        n = self.conf.shape[0]
        rs = np.random.RandomState(seed)
        perm = rs.permutation(n)
        k = int(n * frac)
        tr, va = perm[:k], perm[k:]
        pick = lambda idx: CalibrationData(
            self.conf[idx], self.correct[idx], self.alpha[idx],
            self.cum_costs, None if self.labels is None else self.labels[idx],
            None if self.entropy is None else self.entropy[idx])
        return pick(tr), pick(va)


@dataclasses.dataclass
class PolicyResult:
    tau: np.ndarray              # (E-1,) base thresholds
    coef: np.ndarray             # (E-1,) coefficients (init 1.0)
    beta_diff: float
    objective: float             # empirical J on the calibration set
    method: str
    dp_thresholds: np.ndarray | None = None   # (E-1, A) per-α-bin DP solution
    diagnostics: dict | None = None


def _bin_edges(n_bins):
    return np.linspace(0.0, 1.0, n_bins + 1)


def _digitize(x, n_bins):
    return np.clip((np.asarray(x) * n_bins).astype(int), 0, n_bins - 1)


def _empirical_tables(data: CalibrationData, n_alpha_bins, n_conf_bins,
                      smooth=1.0):
    """Accuracy table Â[i,a,c] and transition kernel P[i,a,c,c']."""
    n, e = data.conf.shape
    ab = _digitize(data.alpha, n_alpha_bins)
    cb = _digitize(data.conf, n_conf_bins)                 # (n, E)

    acc = np.zeros((e, n_alpha_bins, n_conf_bins))
    cnt = np.zeros_like(acc)
    np.add.at(cnt, (slice(None),), 0)  # no-op, keeps shape clear
    for i in range(e):
        np.add.at(cnt[i], (ab, cb[:, i]), 1.0)
        np.add.at(acc[i], (ab, cb[:, i]), data.correct[:, i])
    # hierarchical fallback: (i,a,c) -> (i,c) -> (i)
    acc_ic = np.zeros((e, n_conf_bins))
    cnt_ic = np.zeros_like(acc_ic)
    for i in range(e):
        np.add.at(cnt_ic[i], cb[:, i], 1.0)
        np.add.at(acc_ic[i], cb[:, i], data.correct[:, i])
    acc_i = data.correct.mean(axis=0)                      # (E,)
    acc_ic_s = (acc_ic + smooth * acc_i[:, None]) / (cnt_ic + smooth)
    acc_s = (acc + smooth * acc_ic_s[:, None, :]) / (cnt + smooth)

    # transitions i -> i+1
    trans = np.zeros((e - 1, n_alpha_bins, n_conf_bins, n_conf_bins))
    tcnt = np.zeros_like(trans)
    for i in range(e - 1):
        np.add.at(tcnt[i], (ab, cb[:, i], cb[:, i + 1]), 1.0)
        np.add.at(trans[i], (ab, cb[:, i], cb[:, i + 1]), 1.0)
    # fallback kernel: P(c' | i) marginal
    marg = np.zeros((e - 1, n_conf_bins))
    for i in range(e - 1):
        np.add.at(marg[i], cb[:, i + 1], 1.0)
        marg[i] /= max(marg[i].sum(), 1.0)
    denom = tcnt.sum(axis=-1, keepdims=True)
    trans_s = (trans + smooth * marg[:, None, None, :]) \
        / (denom + smooth)
    return acc_s, trans_s


def optimize_joint_dp(data: CalibrationData, *, beta_opt=0.5, gamma=1.0,
                      n_alpha_bins=4, n_conf_bins=10, beta_diff=0.3,
                      fit_beta_diff=False, smooth=1.0) -> PolicyResult:
    """Backward-induction value iteration over (exit, α_bin, conf_bin)."""
    e = data.n_exits
    acc, trans = _empirical_tables(data, n_alpha_bins, n_conf_bins, smooth)
    costs = np.asarray(data.cum_costs, float)

    v = np.zeros((e, n_alpha_bins, n_conf_bins))
    exit_decision = np.zeros((e - 1, n_alpha_bins, n_conf_bins), bool)
    v[e - 1] = acc[e - 1] - beta_opt * costs[e - 1]        # forced exit
    for i in range(e - 2, -1, -1):
        q_exit = acc[i] - beta_opt * costs[i]              # (A, C)
        q_cont = gamma * np.einsum("acd,ad->ac", trans[i], v[i + 1])
        exit_decision[i] = q_exit >= q_cont
        v[i] = np.maximum(q_exit, q_cont)

    # per-(exit, α_bin) threshold: smallest conf bin from which the policy
    # always exits (monotone suffix projection)
    edges = _bin_edges(n_conf_bins)
    dp_thr = np.ones((e - 1, n_alpha_bins))
    for i in range(e - 1):
        for a in range(n_alpha_bins):
            dec = exit_decision[i, a]
            cstar = n_conf_bins
            for c in range(n_conf_bins - 1, -1, -1):
                if dec[c]:
                    cstar = c
                else:
                    break
            dp_thr[i, a] = edges[cstar] if cstar < n_conf_bins else 1.0

    # project onto Eq. 19 runtime form using Eq. 12 candidates
    ab = _digitize(data.alpha, n_alpha_bins)
    occupancy = np.bincount(ab, minlength=n_alpha_bins).astype(float)
    occupancy /= max(occupancy.sum(), 1.0)
    alpha_mid = (_bin_edges(n_alpha_bins)[:-1]
                 + _bin_edges(n_alpha_bins)[1:]) / 2

    betas = [beta_diff] if not fit_beta_diff else \
        [0.0, 0.1, 0.2, 0.3, 0.4, 0.5]
    best = None
    ones = np.ones(e - 1)

    def joint_j(tau, bd):
        return float(TH.objective(data.conf, data.alpha, data.correct,
                                  data.cum_costs, tau, ones, bd, beta_opt))

    def polish(tau, bd, sweeps=2):
        """Coordinate ascent on the TRUE joint objective (Eq. 10) over the
        Eq. 12 candidates, starting from the DP projection.  This keeps
        threshold interdependence (each coordinate move is scored against
        the full routing) and repairs projection losses from the binned
        value iteration."""
        tau = tau.copy()
        best_j = joint_j(tau, bd)
        for _ in range(sweeps):
            improved = False
            for i in range(e - 1):
                for c in TH.candidate_thresholds(data.conf[:, i]):
                    t = tau.copy()
                    t[i] = c
                    j = joint_j(t, bd)
                    if j > best_j + 1e-12:
                        best_j, tau = j, t
                        improved = True
            if not improved:
                break
        return tau, best_j

    for bd in betas:
        tau = np.zeros(e - 1)
        for i in range(e - 1):
            cands = TH.candidate_thresholds(data.conf[:, i])
            # choose the candidate minimizing weighted sq. error to DP
            err = [(occupancy * (c + bd * alpha_mid - dp_thr[i]) ** 2).sum()
                   for c in cands]
            tau[i] = cands[int(np.argmin(err))]
        tau, j = polish(tau, bd)
        if best is None or j > best[0]:
            best = (j, tau, bd)
    j, tau, bd = best
    return PolicyResult(tau=tau, coef=ones, beta_diff=bd,
                        objective=j, method="joint_dp",
                        dp_thresholds=dp_thr,
                        diagnostics={"value": v, "acc_table": acc})


def optimize_brute_force(data: CalibrationData, *, beta_opt=0.5,
                         beta_diff=0.3, max_combos=20000) -> PolicyResult:
    """Exhaustive joint search over the Eq. 12 candidate grid (oracle)."""
    e = data.n_exits
    cand = [TH.candidate_thresholds(data.conf[:, i]) for i in range(e - 1)]
    total = int(np.prod([len(c) for c in cand]))
    if total > max_combos:
        raise ValueError(f"brute force too large: {total}")
    best = (-np.inf, None)
    ones = np.ones(e - 1)
    for combo in itertools.product(*cand):
        tau = np.asarray(combo)
        j = float(TH.objective(data.conf, data.alpha, data.correct,
                               data.cum_costs, tau, ones, beta_diff,
                               beta_opt))
        if j > best[0]:
            best = (j, tau)
    return PolicyResult(tau=best[1], coef=ones, beta_diff=beta_diff,
                        objective=best[0], method="brute_force")


def _route_np(conf, alpha, tau, coef, beta_diff):
    """Numpy twin of :func:`TH.simulate_routing` (Alg. 1 over a conf
    matrix) — same semantics (strict ``>`` gates, final exit always
    accepts), kept host-side so the cascade coordinate ascent does not
    pay a jax dispatch per candidate evaluation."""
    eff = np.clip(np.asarray(coef)[None, :] * np.asarray(tau)[None, :]
                  + beta_diff * np.asarray(alpha)[:, None], 0.0, 1.0)
    fires = np.concatenate(
        [conf[:, :-1] > eff, np.ones((conf.shape[0], 1), bool)], axis=1)
    return np.argmax(fires, axis=1)


@dataclasses.dataclass
class CascadeCalibrationData:
    """Pooled calibration measurements for a model cascade.

    The SAME n samples are measured through every member (so escalation
    outcomes can be replayed exactly), difficulty is shared:

    members:      per-member :class:`CalibrationData`, ordered by
                  capacity (smallest first); ``alpha`` of members[0] is
                  the cascade's admission difficulty.
    member_costs: (M,) full-network cost of each member in ONE shared
                  unit (normalized so the biggest member = 1.0) — each
                  member's ``cum_costs`` stays normalized within the
                  member; the product is the cascade-absolute cost.
    """
    members: list
    member_costs: np.ndarray

    def __post_init__(self):
        mc = np.asarray(self.member_costs, float)
        if len(mc) != len(self.members):
            raise ValueError(f"{len(mc)} costs for {len(self.members)} "
                             "members")
        self.member_costs = mc / mc[-1]
        n = {d.conf.shape[0] for d in self.members}
        if len(n) != 1:
            raise ValueError(f"members measured on different sample "
                             f"counts: {sorted(n)}")

    @property
    def n_members(self) -> int:
        return len(self.members)

    @property
    def alpha(self) -> np.ndarray:
        return self.members[0].alpha

    def split(self, frac=0.8, seed=0):
        """Train/holdout split applied consistently across members."""
        n = self.members[0].conf.shape[0]
        rs = np.random.RandomState(seed)
        perm = rs.permutation(n)
        k = int(n * frac)

        def pick(d, idx):
            return CalibrationData(
                d.conf[idx], d.correct[idx], d.alpha[idx], d.cum_costs,
                None if d.labels is None else d.labels[idx],
                None if d.entropy is None else d.entropy[idx])
        tr = CascadeCalibrationData(
            [pick(d, perm[:k]) for d in self.members], self.member_costs)
        va = CascadeCalibrationData(
            [pick(d, perm[k:]) for d in self.members], self.member_costs)
        return tr, va


@dataclasses.dataclass
class CascadePolicyResult:
    """Joint policy for a cascade: per-member Eq. 19 exit policies plus
    the inter-member escalation thresholds."""
    members: list                # per-member PolicyResult
    theta: np.ndarray            # (M-1,) escalation base thresholds
    beta_esc: float              # difficulty sensitivity of escalation
    prior_weight: float          # weight of (1 - conf) in the next alpha
    objective: float             # empirical cascade J on the pool
    method: str
    diagnostics: dict | None = None


def escalation_gate(theta_m, alpha, conf, beta_esc):
    """Escalate iff the terminal confidence fails the difficulty-adapted
    escalation threshold: conf <= clip(θ_m + β_esc·α, 0, 1) — Eq. 19
    transposed across networks (Bolukbasi-style cascading)."""
    eff = np.clip(theta_m + beta_esc * np.asarray(alpha), 0.0, 1.0)
    return np.asarray(conf) <= eff


def escalation_alpha(alpha, conf, prior_weight=0.5):
    """Admission difficulty handed to the NEXT member: the raw Eq. 8
    alpha blended with the smaller member's residual uncertainty
    (1 − top confidence), so the big model's thresholds see an
    escalation prior instead of the raw pixel statistics."""
    a = (1.0 - prior_weight) * np.asarray(alpha) \
        + prior_weight * (1.0 - np.asarray(conf))
    return np.clip(a, 0.0, 1.0)


def simulate_cascade(data: CascadeCalibrationData, member_pols, theta, *,
                     beta_esc=0.3, prior_weight=0.5) -> dict:
    """Replay the full cascade on the pooled calibration set.

    Per member: Alg. 1 routing under that member's (tau, coef, beta_diff)
    — with the ESCALATION-prior alpha for members > 0 — then the
    escalation gate on the terminal confidence.  Returns per-sample
    terminal member/exit/conf/correct and the TOTAL cascade cost paid
    (every visited member's routed cost, in biggest-member units)."""
    m_count = data.n_members
    n = data.members[0].conf.shape[0]
    alpha0 = np.asarray(data.members[0].alpha, float)
    theta = np.asarray(theta, float)

    member = np.zeros(n, np.int64)
    exit_idx = np.zeros(n, np.int64)
    conf_out = np.zeros(n)
    correct = np.zeros(n)
    cost = np.zeros(n)

    active = np.arange(n)
    a_cur = alpha0.copy()
    for m in range(m_count):
        if not len(active):
            break
        d, pol = data.members[m], member_pols[m]
        conf_m = np.asarray(d.conf)[active]
        idx = _route_np(conf_m, a_cur, pol.tau, pol.coef, pol.beta_diff)
        csel = conf_m[np.arange(len(active)), idx]
        cum = np.asarray(d.cum_costs, float)
        cost[active] += data.member_costs[m] * cum[idx] / cum[-1]
        esc = np.zeros(len(active), bool) if m == m_count - 1 else \
            escalation_gate(theta[m], a_cur, csel, beta_esc)
        term = active[~esc]
        member[term] = m
        exit_idx[term] = idx[~esc]
        conf_out[term] = csel[~esc]
        correct[term] = np.asarray(d.correct)[term, idx[~esc]]
        a_cur = escalation_alpha(a_cur[esc], csel[esc], prior_weight)
        active = active[esc]
    return {"member": member, "exit_idx": exit_idx, "conf": conf_out,
            "correct": correct, "cost": cost, "alpha": alpha0}


def cascade_objective(data: CascadeCalibrationData, member_pols, theta, *,
                      beta_opt=0.5, beta_esc=0.3,
                      prior_weight=0.5) -> float:
    """Eq. 10 generalized to the cascade: J = mean(A_terminal −
    β_opt·C_total) with C_total the cost of EVERY member visited."""
    sim = simulate_cascade(data, member_pols, theta, beta_esc=beta_esc,
                           prior_weight=prior_weight)
    return float(np.mean(sim["correct"] - beta_opt * sim["cost"]))


def _theta_candidates(data, member_pols, m, beta_esc, prior_weight,
                      theta) -> np.ndarray:
    """Eq. 12 transposed to the m-th escalation gate: quantiles of the
    member's TERMINAL confidence under the current cascade routing, plus
    never-/always-escalate sentinels (clip maps −1 → gate at 0, which
    softmax confidence never undercuts, and 1 → always)."""
    sim = simulate_cascade(data, member_pols, theta, beta_esc=beta_esc,
                           prior_weight=prior_weight)
    at_m = sim["member"] == m
    conf = sim["conf"][at_m] if at_m.any() \
        else np.asarray(data.members[m].conf)[:, -1]
    return np.concatenate([[-1.0], TH.candidate_thresholds(conf), [1.0]])


def optimize_cascade_dp(data: CascadeCalibrationData, *, beta_opt=0.5,
                        beta_esc=0.3, fit_beta_esc=False,
                        prior_weight=0.5, sweeps=2,
                        **dp_kw) -> CascadePolicyResult:
    """Jointly optimize every member's exit thresholds AND the
    escalation thresholds.

    1. Seed each member with :func:`optimize_joint_dp` on its own
       measurements, with the cost pressure scaled by the member's share
       of cascade cost (β_opt·member_cost — a cheap member should spend
       its exits freely, the big member is where compute hurts).
    2. Pick each escalation threshold θ_m greedily over the Eq. 12
       candidate grid against the TRUE pooled cascade objective.
    3. Alternating coordinate ascent: re-polish every member tau and
       every θ against the cascade objective until no move improves
       (threshold interdependence ACROSS members — the reason
       independent calibration is suboptimal — is scored exactly).
    """
    m_count = data.n_members
    seeds = [optimize_joint_dp(d, beta_opt=beta_opt * data.member_costs[m],
                               **dp_kw)
             for m, d in enumerate(data.members)]
    pols = [dataclasses.replace(p, tau=np.asarray(p.tau, float).copy())
            for p in seeds]

    betas = [beta_esc] if not fit_beta_esc else [0.0, 0.1, 0.2, 0.3, 0.4]
    best = None
    for be in betas:
        theta = np.full(m_count - 1, 0.5)
        cur = [dataclasses.replace(p, tau=p.tau.copy()) for p in pols]

        def joint_j(ps, th):
            return cascade_objective(data, ps, th, beta_opt=beta_opt,
                                     beta_esc=be,
                                     prior_weight=prior_weight)

        # greedy theta init, boundary by boundary (small → large)
        for m in range(m_count - 1):
            cands = _theta_candidates(data, cur, m, be, prior_weight,
                                      theta)
            js = []
            for c in cands:
                t = theta.copy()
                t[m] = c
                js.append(joint_j(cur, t))
            theta[m] = cands[int(np.argmax(js))]
        best_j = joint_j(cur, theta)

        for _ in range(sweeps):
            improved = False
            for m in range(m_count):           # member taus
                e = data.members[m].n_exits
                for i in range(e - 1):
                    for c in TH.candidate_thresholds(
                            data.members[m].conf[:, i]):
                        trial = [dataclasses.replace(p, tau=p.tau.copy())
                                 for p in cur]
                        trial[m].tau[i] = c
                        j = joint_j(trial, theta)
                        if j > best_j + 1e-12:
                            best_j, cur, improved = j, trial, True
            for m in range(m_count - 1):       # escalation thresholds
                for c in _theta_candidates(data, cur, m, be, prior_weight,
                                           theta):
                    t = theta.copy()
                    t[m] = c
                    j = joint_j(cur, t)
                    if j > best_j + 1e-12:
                        best_j, theta, improved = j, t, True
            if not improved:
                break
        if best is None or best_j > best[0]:
            best = (best_j, cur, theta, be)

    j, cur, theta, be = best
    return CascadePolicyResult(
        members=[dataclasses.replace(p, method="cascade_dp")
                 for p in cur],
        theta=theta, beta_esc=be, prior_weight=prior_weight,
        objective=j, method="cascade_dp",
        diagnostics={"seed_objectives": [p.objective for p in seeds]})


def optimize_cascade_independent(data: CascadeCalibrationData, *,
                                 beta_opt=0.5, beta_esc=0.3,
                                 prior_weight=0.5,
                                 **dp_kw) -> CascadePolicyResult:
    """The baseline the cascade DP argues against: each member calibrated
    in isolation (same per-member DP seeds), escalation thresholds fixed
    at the median Eq. 12 candidate — no cross-member interdependence."""
    pols = [optimize_joint_dp(d, beta_opt=beta_opt * data.member_costs[m],
                              **dp_kw)
            for m, d in enumerate(data.members)]
    theta = np.full(data.n_members - 1, 0.5)
    for m in range(data.n_members - 1):
        cands = _theta_candidates(data, pols, m, beta_esc, prior_weight,
                                  theta)
        theta[m] = float(np.median(cands[1:-1]))
    j = cascade_objective(data, pols, theta, beta_opt=beta_opt,
                          beta_esc=beta_esc, prior_weight=prior_weight)
    return CascadePolicyResult(
        members=pols, theta=theta, beta_esc=beta_esc,
        prior_weight=prior_weight, objective=j,
        method="cascade_independent")


def optimize_independent(data: CalibrationData, *, beta_opt=0.5,
                         beta_diff=0.3) -> PolicyResult:
    """The baseline DART argues against: each exit's threshold tuned in
    isolation (others pinned at their median candidate)."""
    e = data.n_exits
    tau = np.array([np.median(TH.candidate_thresholds(data.conf[:, i]))
                    for i in range(e - 1)])
    ones = np.ones(e - 1)
    for i in range(e - 1):
        best = (-np.inf, tau[i])
        for c in TH.candidate_thresholds(data.conf[:, i]):
            t = tau.copy()
            t[i] = c
            j = float(TH.objective(data.conf, data.alpha, data.correct,
                                   data.cum_costs, t, ones, beta_diff,
                                   beta_opt))
            if j > best[0]:
                best = (j, c)
        tau[i] = best[1]
    j = float(TH.objective(data.conf, data.alpha, data.correct,
                           data.cum_costs, tau, ones, beta_diff, beta_opt))
    return PolicyResult(tau=tau, coef=ones, beta_diff=beta_diff,
                        objective=j, method="independent")
