"""Baselines from the paper's Table I: Static, BranchyNet, RL-Agent.

* Static      — no early exits; always the final head.
* BranchyNet  — fixed per-exit thresholds on softmax *entropy*
  (Teerapittayanon et al. 2016): exit when H(p) < T_i.  No difficulty
  awareness, no coefficients, thresholds tuned once.
* RL-Agent    — tabular Q-learning exit policy over (exit, conf_bin)
  states (Taheri et al. 2025 lineage): learned from calibration episodes
  with an accuracy−cost reward, no difficulty input.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.policy import CalibrationData


# ---------------------------------------------------------------------------
# Static
# ---------------------------------------------------------------------------

def static_route(conf_matrix: np.ndarray) -> np.ndarray:
    """Everything exits at the final head."""
    n, e = conf_matrix.shape
    return np.full((n,), e - 1, dtype=np.int64)


# ---------------------------------------------------------------------------
# BranchyNet
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BranchyNetPolicy:
    entropy_thresholds: np.ndarray       # (E-1,)

    def route(self, entropy_matrix: np.ndarray) -> np.ndarray:
        """entropy_matrix: (n, E).  First exit with H < T_i, else final."""
        n, e = entropy_matrix.shape
        fires = entropy_matrix[:, :-1] < self.entropy_thresholds[None, :]
        fires = np.concatenate([fires, np.ones((n, 1), bool)], axis=1)
        return np.argmax(fires, axis=1)


def fit_branchynet(entropy_matrix: np.ndarray, correct: np.ndarray,
                   cum_costs: np.ndarray, *, beta_opt=0.5,
                   grid=None) -> BranchyNetPolicy:
    """Tune one global entropy scale on the calibration set (BranchyNet
    tunes T by screening a scalar grid; thresholds are *fixed* afterwards
    — the paper's criticism)."""
    n, e = entropy_matrix.shape
    if grid is None:
        grid = np.quantile(entropy_matrix[:, :-1],
                           [0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8])
    best = (-np.inf, None)
    for t in grid:
        pol = BranchyNetPolicy(np.full((e - 1,), t))
        idx = pol.route(entropy_matrix)
        acc = correct[np.arange(n), idx].mean()
        cost = cum_costs[idx].mean()
        j = acc - beta_opt * cost
        if j > best[0]:
            best = (j, pol)
    return best[1]


# ---------------------------------------------------------------------------
# RL-Agent (tabular Q-learning)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RLAgentPolicy:
    q: np.ndarray                        # (E, C, 2) Q[exit, conf_bin, action]
    n_conf_bins: int

    def route(self, conf_matrix: np.ndarray) -> np.ndarray:
        n, e = conf_matrix.shape
        cb = np.clip((conf_matrix * self.n_conf_bins).astype(int), 0,
                     self.n_conf_bins - 1)
        out = np.full((n,), e - 1, dtype=np.int64)
        decided = np.zeros((n,), bool)
        for i in range(e - 1):
            act = self.q[i, cb[:, i], 1] >= self.q[i, cb[:, i], 0]
            take = act & ~decided
            out[take] = i
            decided |= take
        return out


def fit_rl_agent(data: CalibrationData, *, beta_opt=0.5, n_conf_bins=10,
                 epochs=20, lr=0.2, gamma=1.0, eps=0.2,
                 seed=0) -> RLAgentPolicy:
    """Tabular Q-learning (Watkins) on calibration episodes.

    State (exit i, conf bin); actions {0: continue, 1: exit}.
    Reward on exit: correct_i − β_opt·C_i; continuing pays the marginal
    cost at the final forced exit."""
    rs = np.random.RandomState(seed)
    n, e = data.conf.shape
    cb = np.clip((data.conf * n_conf_bins).astype(int), 0, n_conf_bins - 1)
    q = np.zeros((e, n_conf_bins, 2))
    costs = np.asarray(data.cum_costs, float)
    for ep in range(epochs):
        order = rs.permutation(n)
        for s in order:
            for i in range(e):
                c = cb[s, i]
                if i == e - 1:
                    r = data.correct[s, i] - beta_opt * costs[i]
                    q[i, c, 1] += lr * (r - q[i, c, 1])
                    q[i, c, 0] += lr * (r - q[i, c, 0])   # forced exit
                    break
                explore = rs.rand() < eps
                a = rs.randint(2) if explore \
                    else int(q[i, c, 1] >= q[i, c, 0])
                if a == 1:
                    r = data.correct[s, i] - beta_opt * costs[i]
                    q[i, c, 1] += lr * (r - q[i, c, 1])
                    break
                nxt = np.max(q[i + 1, cb[s, i + 1]])
                q[i, c, 0] += lr * (gamma * nxt - q[i, c, 0])
    return RLAgentPolicy(q=q, n_conf_bins=n_conf_bins)
