"""DART evaluation metrics — DAES (Eq. 9) and Eqs. 20–22.

    Speedup(m)          = T_static / T_m                     (Eq. 20)
    P_m                 = E_m / T_m                           (Eq. 21)
    Power_Efficiency(m) = E_static / E_m                      (Eq. 22)
    DAES                = Acc × Speedup × PowerEff / (1 + ᾱ)  (Eq. 9)

On hardware the paper integrates NVIDIA-SMI power; in this container we
report two energy models, both recorded in EXPERIMENTS.md:
* ``macs``   — E ∝ MACs (the paper's own "architecture-agnostic" argument)
* ``measured`` — CPU wall-clock × constant power (relative numbers only)
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np


@dataclasses.dataclass
class MethodMeasurement:
    name: str
    accuracy: float              # top-1 in [0, 1]
    time_s: float                # median per-inference wall clock
    macs: float                  # mean MACs per inference
    energy_j: float | None = None


def speedup(static: MethodMeasurement, m: MethodMeasurement) -> float:
    return static.time_s / max(m.time_s, 1e-12)


def power_efficiency(static: MethodMeasurement, m: MethodMeasurement,
                     energy_model: str = "macs") -> float:
    if energy_model == "measured" and m.energy_j and static.energy_j:
        return static.energy_j / max(m.energy_j, 1e-12)
    return static.macs / max(m.macs, 1e-12)


def avg_power(m: MethodMeasurement) -> float | None:
    if m.energy_j is None:
        return None
    return m.energy_j / max(m.time_s, 1e-12)


def daes(static: MethodMeasurement, m: MethodMeasurement,
         mean_alpha: float, energy_model: str = "macs") -> float:
    """Eq. 9.  ``mean_alpha`` = dataset mean difficulty (paper: MNIST 0.76,
    CIFAR-10 0.85)."""
    return (m.accuracy * speedup(static, m)
            * power_efficiency(static, m, energy_model)) / (1.0 + mean_alpha)


def summary_row(static: MethodMeasurement, m: MethodMeasurement,
                mean_alpha: float, energy_model: str = "macs") -> dict:
    return {
        "method": m.name,
        "acc_pct": 100.0 * m.accuracy,
        "time_ms": 1e3 * m.time_s,
        "macs_m": m.macs / 1e6,
        "speedup": speedup(static, m),
        "power_eff": power_efficiency(static, m, energy_model),
        "daes": daes(static, m, mean_alpha, energy_model),
    }


# ---------------------------------------------------------------------------
# Streaming per-lane DAES (serving telemetry)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _LaneAccum:
    n: int = 0
    sum_conf: float = 0.0
    sum_macs: float = 0.0
    sum_alpha: float = 0.0


class LaneDaesAccumulator:
    """Eq. 9 folded online, one accumulator per scheduler lane.

    At serving time there are no labels, so accuracy is the §II.C
    confidence-calibrated pseudo-correctness (mean exited confidence),
    and the energy/time reference is the ``macs`` model: the static
    baseline always pays ``static_macs`` (the full network — for a
    cascade, the BIGGEST member's full network), a lane pays its mean
    routed MACs.  ``rows()`` renders everything through
    :func:`summary_row`, so the serving report and the offline Table I
    report share one formula."""

    def __init__(self, static_macs: float = 1.0):
        self.static_macs = float(static_macs)
        self._lanes: dict = {}
        self._lock = threading.Lock()

    def observe(self, lane, conf, macs, alpha) -> None:
        """Fold one completed request's per-sample conf/macs/alpha."""
        conf = np.asarray(conf, np.float64)
        with self._lock:
            a = self._lanes.setdefault(lane, _LaneAccum())
            a.n += int(conf.size)
            a.sum_conf += float(conf.sum())
            a.sum_macs += float(np.sum(macs))
            a.sum_alpha += float(np.sum(alpha))

    def rows(self, energy_model: str = "macs") -> dict:
        """lane -> :func:`summary_row` dict (+ sample count ``n``)."""
        static = MethodMeasurement("static", accuracy=1.0,
                                   time_s=self.static_macs,
                                   macs=self.static_macs)
        out = {}
        with self._lock:
            lanes = list(self._lanes.items())
        for lane, a in sorted(lanes, key=lambda kv: str(kv[0])):
            if not a.n:
                continue
            mean_macs = a.sum_macs / a.n
            m = MethodMeasurement(name=str(lane), accuracy=a.sum_conf / a.n,
                                  time_s=mean_macs, macs=mean_macs)
            row = summary_row(static, m, a.sum_alpha / a.n, energy_model)
            row["n"] = a.n
            out[lane] = row
        return out
