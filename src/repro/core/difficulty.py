"""DART difficulty estimation — paper §II.A (Eqs. 1–8) and §II.D (Eq. 17).

Three complementary per-input metrics, fused with weights (w1, w2, w3):

* ``edge density``      — Sobel gradient magnitude thresholded (Eqs. 1–4)
* ``pixel variance``    — spatial variance per channel, averaged (Eqs. 5–6)
* ``gradient complexity`` — mean |Laplacian| response (Eq. 7)

The paper's empirical weights are (0.4, 0.3, 0.3); β_diff = 0.3.

This module is the pure-jnp reference ("ref") implementation; the fused
Pallas kernel lives in ``repro.kernels.difficulty`` and is validated
against :func:`image_difficulty` (see tests/test_kernels.py).  The
``estimate`` dispatcher picks the kernel when enabled.

Domain adapters (DESIGN.md §3):
* images  — the paper, verbatim.
* tokens  — LM inputs: the three metrics transposed to embedding space
  (transition energy / feature variance / second difference).
* latents — diffusion: image metrics on the current latent, scaled by the
  signal fraction sqrt(ᾱ_t) (high-noise steps are easy).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

SOBEL_X = jnp.array([[-1.0, 0.0, 1.0],
                     [-2.0, 0.0, 2.0],
                     [-1.0, 0.0, 1.0]], jnp.float32)
SOBEL_Y = SOBEL_X.T
LAPLACIAN = jnp.array([[0.0, 1.0, 0.0],
                       [1.0, -4.0, 1.0],
                       [0.0, 1.0, 0.0]], jnp.float32)

LUMA = jnp.array([0.299, 0.587, 0.114], jnp.float32)


@dataclasses.dataclass(frozen=True)
class DifficultyConfig:
    w_edge: float = 0.4          # paper: w1
    w_variance: float = 0.3      # paper: w2
    w_gradient: float = 0.3      # paper: w3
    tau_edge: float = 0.1        # Eq. 4 threshold (on [0,1] images)
    var_scale: float = 0.05      # variance squashing scale
    grad_scale: float = 0.2      # |Laplacian| squashing scale
    beta_diff: float = 0.3       # Eq. 19 sensitivity

    @property
    def weights(self):
        return (self.w_edge, self.w_variance, self.w_gradient)


DEFAULT = DifficultyConfig()


def to_grayscale(images):
    """(B, H, W, C) -> (B, H, W).  Luminance for C==3, mean otherwise."""
    c = images.shape[-1]
    if c == 3:
        return jnp.einsum("bhwc,c->bhw", images.astype(jnp.float32), LUMA)
    return jnp.mean(images.astype(jnp.float32), axis=-1)


def _conv3x3(img, kernel):
    """Valid 3x3 convolution on (B, H, W) with a (3,3) kernel."""
    return lax.conv_general_dilated(
        img[:, :, :, None], kernel[:, :, None, None],
        window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[:, :, :, 0]


def edge_density(images, tau_edge=DEFAULT.tau_edge):
    """Eqs. 1–4.  images: (B, H, W, C) in [0,1].  Returns (B,)."""
    g = to_grayscale(images)
    gx = _conv3x3(g, SOBEL_X)
    gy = _conv3x3(g, SOBEL_Y)
    mag = jnp.sqrt(gx * gx + gy * gy)
    return jnp.mean((mag > tau_edge).astype(jnp.float32), axis=(1, 2))


def pixel_variance(images, var_scale=DEFAULT.var_scale):
    """Eqs. 5–6 with squashing to [0,1].  Returns (B,)."""
    x = images.astype(jnp.float32)
    mu = jnp.mean(x, axis=(1, 2), keepdims=True)           # per (b, c)
    var = jnp.mean(jnp.square(x - mu), axis=(1, 2, 3))     # 1/(CHW) Σ (·)²
    return 1.0 - jnp.exp(-var / var_scale)


def gradient_complexity(images, grad_scale=DEFAULT.grad_scale):
    """Eq. 7 with squashing to [0,1].  Returns (B,)."""
    g = to_grayscale(images)
    lap = _conv3x3(g, LAPLACIAN)
    mean_abs = jnp.mean(jnp.abs(lap), axis=(1, 2))
    return 1.0 - jnp.exp(-mean_abs / grad_scale)


def fuse(alpha_edge, alpha_var, alpha_grad, cfg: DifficultyConfig = DEFAULT):
    """Eq. 8: α = w1·α_edge + w2·α_var + w3·α_grad, clamped to [0,1]."""
    a = (cfg.w_edge * alpha_edge + cfg.w_variance * alpha_var
         + cfg.w_gradient * alpha_grad)
    return jnp.clip(a, 0.0, 1.0)


def image_difficulty(images, cfg: DifficultyConfig = DEFAULT):
    """The paper's difficulty score for a batch of images.  (B,) in [0,1]."""
    return fuse(edge_density(images, cfg.tau_edge),
                pixel_variance(images, cfg.var_scale),
                gradient_complexity(images, cfg.grad_scale), cfg)


def image_difficulty_components(images, cfg: DifficultyConfig = DEFAULT):
    e = edge_density(images, cfg.tau_edge)
    v = pixel_variance(images, cfg.var_scale)
    g = gradient_complexity(images, cfg.grad_scale)
    return {"edge": e, "variance": v, "gradient": g, "alpha": fuse(e, v, g, cfg)}


# ---------------------------------------------------------------------------
# Token domain (LM) — Eq. 17 transposed to embedding space (DESIGN.md §3)
# ---------------------------------------------------------------------------

def token_difficulty(embeddings, cfg: DifficultyConfig = DEFAULT,
                     edge_tau: float = 1.0):
    """embeddings: (B, S, D) input-token embeddings.  Returns (B,) in [0,1].

    * edge analogue    — fraction of token transitions with RMS step > τ
    * variance analogue — feature variance (squashed)
    * gradient analogue — RMS second difference (squashed)
    """
    x = embeddings.astype(jnp.float32)
    if x.shape[1] < 3:
        # decode steps: fall back to feature variance only
        var = jnp.var(x, axis=(1, 2))
        return jnp.clip(1.0 - jnp.exp(-var / cfg.var_scale), 0.0, 1.0)
    d1 = x[:, 1:] - x[:, :-1]
    step = jnp.sqrt(jnp.mean(jnp.square(d1), axis=-1))      # (B, S-1) RMS
    a_edge = jnp.mean((step > edge_tau).astype(jnp.float32), axis=-1)
    var = jnp.var(x, axis=(1, 2))
    a_var = 1.0 - jnp.exp(-var / (10 * cfg.var_scale))
    d2 = x[:, 2:] - 2 * x[:, 1:-1] + x[:, :-2]
    curv = jnp.mean(jnp.sqrt(jnp.mean(jnp.square(d2), axis=-1)), axis=-1)
    a_grad = 1.0 - jnp.exp(-curv / (10 * cfg.grad_scale))
    return fuse(a_edge, a_var, a_grad, cfg)


def token_difficulty_ema(prev_alpha, new_embedding, cfg=DEFAULT,
                         decay: float = 0.9):
    """Decode-time difficulty: EMA over per-token feature stats.
    prev_alpha: (B,); new_embedding: (B, 1, D)."""
    var = jnp.var(new_embedding.astype(jnp.float32), axis=(1, 2))
    inst = jnp.clip(1.0 - jnp.exp(-var / (10 * cfg.var_scale)), 0.0, 1.0)
    return decay * prev_alpha + (1.0 - decay) * inst


# ---------------------------------------------------------------------------
# Latent domain (diffusion) — DESIGN.md §3
# ---------------------------------------------------------------------------

def latent_difficulty(latents, signal_frac, cfg: DifficultyConfig = DEFAULT):
    """latents: (B, H, W, C); signal_frac: (B,) = sqrt(ᾱ_t) ∈ [0,1].

    Image-complexity of the current latent, scaled by how much signal is
    present — high-noise (early) steps are easy, so α→0 there."""
    base = image_difficulty(latents, cfg)
    return jnp.clip(base * signal_frac, 0.0, 1.0)


# ---------------------------------------------------------------------------
# Difficulty classes (admission-time traffic partitioning)
# ---------------------------------------------------------------------------

#: Default class boundaries on Eq. 8 alpha — easy (0, 0.35], medium
#: (0.35, 0.65], hard (0.65, 1].  The single source of truth for every
#: consumer that partitions traffic by difficulty (the async scheduler's
#: lanes, the admission planner's priors, cascade member routing).
DEFAULT_EDGES = (0.35, 0.65)


def difficulty_class(alpha, edges=DEFAULT_EDGES):
    """Partition Eq. 8 difficulties into classes: class k ⇔ alpha in
    (edges[k-1], edges[k]].  The async scheduler lanes requests by this
    so buckets stay cost-homogeneous.  Host inputs (python scalars /
    numpy) stay on numpy — the admission hot path must not pay a device
    round-trip per request — while jax arrays/tracers take the jnp
    path.  Returns int class indices shaped like ``alpha``."""
    if isinstance(alpha, jax.Array):        # includes tracers
        edges_j = jnp.asarray(edges, jnp.float32)
        return jnp.sum(alpha[..., None] > edges_j,
                       axis=-1).astype(jnp.int32)
    a = np.asarray(alpha, np.float32)
    e = np.asarray(edges, np.float32)
    return np.sum(a[..., None] > e, axis=-1).astype(np.int32)


# ---------------------------------------------------------------------------
# FLOPs of the estimator (paper §III.B overhead comparison)
# ---------------------------------------------------------------------------

def estimator_flops(h: int, w: int, c: int = 3) -> int:
    """Per-image FLOPs of the difficulty estimator (conv MACs ×2 + pointwise).

    Paper reports 78.9 KFLOPs for its configuration; RACENet-style adaptive
    normalization costs 3.96 MFLOPs (50.3× more)."""
    gray = h * w * (2 * c - 1) if c == 3 else h * w * c
    hv, wv = h - 2, w - 2
    sobel = 2 * hv * wv * 9 * 2            # two 3x3 convs
    mag = hv * wv * 3                      # square, add, sqrt
    edge_thresh = hv * wv + hv * wv        # compare + mean
    var = 4 * h * w * c                    # mean + centered square + mean
    lap = hv * wv * 9 * 2 + 2 * hv * wv    # conv + |·| + mean
    return int(gray + sobel + mag + edge_thresh + var + lap + 16)


# ---------------------------------------------------------------------------
# Dispatcher
# ---------------------------------------------------------------------------

def estimate(inputs, kind: str = "image", cfg: DifficultyConfig = DEFAULT,
             use_kernel: bool = False, **kw):
    """Unified entry point.  kind: image | tokens | latent.

    ``use_kernel=True`` routes the image estimator through
    ``repro.kernels.dispatch`` (fused Pallas kernel on TPU, this
    module's reference chain elsewhere)."""
    if kind == "image":
        if use_kernel:
            from repro.kernels import dispatch as KD
            return KD.image_difficulty(inputs, cfg)
        return image_difficulty(inputs, cfg)
    if kind == "tokens":
        return token_difficulty(inputs, cfg)
    if kind == "latent":
        return latent_difficulty(inputs, kw["signal_frac"], cfg)
    raise ValueError(kind)
