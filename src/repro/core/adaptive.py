"""DART adaptive coefficient management — paper §II.C (Eqs. 13–15).

State is a pure pytree (jit-, shard- and checkpoint-friendly):

* sliding window (w = 1000) of per-inference records: exit index, class
  (pseudo-label), confidence, correctness-proxy, cost;
* per-exit temporal coefficients (Eq. 13, exponential decay);
* per-(class, exit) coefficients (Eq. 14, pseudo-label updates);
* UCB1 bandit counters over adaptation strategies (Eq. 15).

With UCB disabled the system reduces to deterministic threshold
adaptation driven by the same sliding-window statistics (paper §II.C.2).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

STRATEGIES = ("temporal", "class_aware", "hybrid", "frozen")


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    n_exits: int
    n_classes: int
    window: int = 1000              # paper: w = 1000
    alpha_decay: float = 0.95       # paper: α_decay
    eta: float = 0.05               # Eq. 14 adaptation rate
    a_target: float = 0.85          # Eq. 14 target accuracy
    kappa: float = 0.5              # Eq. 13 performance→coefficient gain
    coef_min: float = 0.5
    coef_max: float = 1.5
    pseudo_label_conf: float = 0.9  # min confidence to accept pseudo-label
    ucb_enabled: bool = True
    update_every: int = 100         # small periodic updates


def init_state(cfg: AdaptiveConfig):
    e1 = cfg.n_exits - 1
    w = cfg.window
    return {
        # ring buffers (sliding window)
        "buf_exit": jnp.zeros((w,), jnp.int32),
        "buf_class": jnp.zeros((w,), jnp.int32),
        "buf_conf": jnp.zeros((w,), jnp.float32),
        "buf_correct": jnp.zeros((w,), jnp.float32),   # pseudo-correctness
        "buf_cost": jnp.zeros((w,), jnp.float32),
        "buf_valid": jnp.zeros((w,), jnp.float32),
        "ptr": jnp.zeros((), jnp.int32),
        "seen": jnp.zeros((), jnp.int32),
        # coefficients
        "coef_temporal": jnp.ones((e1,), jnp.float32),
        "coef_class": jnp.ones((cfg.n_classes, e1), jnp.float32),
        # UCB1 (Eq. 15)
        "ucb_counts": jnp.zeros((len(STRATEGIES),), jnp.float32),
        "ucb_rewards": jnp.zeros((len(STRATEGIES),), jnp.float32),
        "active_strategy": jnp.zeros((), jnp.int32),
        "t": jnp.zeros((), jnp.int32),
    }


def record_batch(state, cfg: AdaptiveConfig, exit_idx, pseudo_class, conf,
                 correct, cost, valid=None):
    """Append a batch of inference records into the ring buffer.
    All args: (B,) arrays.  ``correct`` may be pseudo-correctness (agreement
    with the final head or high-confidence self-agreement) when no labels
    exist during deployment.

    ``valid``: optional (B,) 0/1 mask for lanes that are bucket padding
    rather than real samples (the jitted sharded serving path records a
    fixed-shape batch).  Padded lanes still occupy window slots — their
    ``buf_valid`` entry is 0, so every statistic ignores them — which
    keeps the write pattern shape-static under jit."""
    b = exit_idx.shape[0]
    w = cfg.window
    idx = (state["ptr"] + jnp.arange(b)) % w
    s = dict(state)
    s["buf_exit"] = state["buf_exit"].at[idx].set(exit_idx.astype(jnp.int32))
    s["buf_class"] = state["buf_class"].at[idx].set(
        pseudo_class.astype(jnp.int32))
    s["buf_conf"] = state["buf_conf"].at[idx].set(conf.astype(jnp.float32))
    s["buf_correct"] = state["buf_correct"].at[idx].set(
        correct.astype(jnp.float32))
    s["buf_cost"] = state["buf_cost"].at[idx].set(cost.astype(jnp.float32))
    if valid is None:
        s["buf_valid"] = state["buf_valid"].at[idx].set(1.0)
        n_real = b
    else:
        validf = jnp.asarray(valid, jnp.float32)
        s["buf_valid"] = state["buf_valid"].at[idx].set(validf)
        n_real = jnp.sum(validf).astype(jnp.int32)
    s["ptr"] = (state["ptr"] + b) % w
    s["seen"] = state["seen"] + n_real
    return s


def window_stats(state, cfg: AdaptiveConfig):
    """Windowed accuracy / cost / per-class accuracy / per-exit counts."""
    v = state["buf_valid"]
    n = jnp.maximum(jnp.sum(v), 1.0)
    acc = jnp.sum(state["buf_correct"] * v) / n
    cost = jnp.sum(state["buf_cost"] * v) / n
    onehot_c = jax.nn.one_hot(state["buf_class"], cfg.n_classes) * v[:, None]
    cls_n = jnp.maximum(jnp.sum(onehot_c, axis=0), 1.0)
    cls_acc = jnp.sum(onehot_c * state["buf_correct"][:, None], axis=0) / cls_n
    onehot_e = jax.nn.one_hot(state["buf_exit"], cfg.n_exits) * v[:, None]
    exit_frac = jnp.sum(onehot_e, axis=0) / n
    return {"acc": acc, "cost": cost, "class_acc": cls_acc,
            "class_n": jnp.sum(onehot_c, axis=0), "exit_frac": exit_frac,
            "n": n}


def window_exit_depth(state, cfg: AdaptiveConfig):
    """Mean routed exit index over the valid window — the exit-count
    prior from telemetry: at what depth has traffic ACTUALLY been
    exiting.  The serving admission planner seeds its cost prediction
    with this before it has per-difficulty-class observations."""
    st = window_stats(state, cfg)
    return jnp.sum(st["exit_frac"] * jnp.arange(cfg.n_exits,
                                                dtype=jnp.float32))


def temporal_update(state, cfg: AdaptiveConfig):
    """Eq. 13: c_t = α_decay·c_{t−1} + (1−α_decay)·f(performance_t).

    f maps windowed accuracy to a coefficient target: accuracy below the
    target raises coefficients (more conservative exits)."""
    st = window_stats(state, cfg)
    target = 1.0 + cfg.kappa * (cfg.a_target - st["acc"])
    c = cfg.alpha_decay * state["coef_temporal"] \
        + (1.0 - cfg.alpha_decay) * target
    s = dict(state)
    s["coef_temporal"] = jnp.clip(c, cfg.coef_min, cfg.coef_max)
    return s


def class_aware_update(state, cfg: AdaptiveConfig):
    """Eq. 14: c_class += η·(A_target − A_class), from pseudo-labels."""
    st = window_stats(state, cfg)
    has_data = (st["class_n"] > 0).astype(jnp.float32)[:, None]
    delta = cfg.eta * (cfg.a_target - st["class_acc"])[:, None] * has_data
    s = dict(state)
    s["coef_class"] = jnp.clip(state["coef_class"] + delta,
                               cfg.coef_min, cfg.coef_max)
    return s


def ucb_select(state, cfg: AdaptiveConfig):
    """Eq. 15: UCB_i(t) = r̄_i + sqrt(2 ln t / n_i).  Untried arms first."""
    t = jnp.maximum(state["t"].astype(jnp.float32), 1.0)
    n = state["ucb_counts"]
    mean_r = state["ucb_rewards"] / jnp.maximum(n, 1.0)
    ucb = jnp.where(n > 0, mean_r + jnp.sqrt(2.0 * jnp.log(t)
                                             / jnp.maximum(n, 1.0)),
                    jnp.inf)
    return jnp.argmax(ucb).astype(jnp.int32)


def ucb_update(state, cfg: AdaptiveConfig, reward):
    """Credit the active strategy with the windowed Eq. 10 reward."""
    arm = state["active_strategy"]
    s = dict(state)
    s["ucb_counts"] = state["ucb_counts"].at[arm].add(1.0)
    s["ucb_rewards"] = state["ucb_rewards"].at[arm].add(reward)
    s["t"] = state["t"] + 1
    if cfg.ucb_enabled:
        s["active_strategy"] = ucb_select(s, cfg)
    return s


def effective_coef(state, cfg: AdaptiveConfig, pseudo_class=None):
    """Coefficient vector for the *active* strategy.

    pseudo_class: (B,) predicted classes (class-aware strategies index the
    per-class table with them); None → batch-agnostic (E-1,)."""
    temporal = state["coef_temporal"]
    if pseudo_class is None:
        class_c = jnp.mean(state["coef_class"], axis=0)
    else:
        class_c = state["coef_class"][pseudo_class]         # (B, E-1)
        temporal = jnp.broadcast_to(temporal, class_c.shape)
    frozen = jnp.ones_like(temporal)
    hybrid = 0.5 * (temporal + class_c)
    stacked = jnp.stack([temporal, class_c, hybrid, frozen])
    return stacked[state["active_strategy"]]


def periodic_update(state, cfg: AdaptiveConfig, beta_opt=0.5):
    """One small periodic refinement step (paper §II.C.2): run both
    adaptation laws, score the window with the Eq. 10 reward, update UCB."""
    st = window_stats(state, cfg)
    reward = st["acc"] - beta_opt * st["cost"]
    state = temporal_update(state, cfg)
    state = class_aware_update(state, cfg)
    state = ucb_update(state, cfg, reward)
    return state
