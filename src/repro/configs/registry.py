"""Architecture registry: ``--arch <id>`` lookup for every entrypoint.

``get(arch)``         — full (assignment-exact) config
``get_reduced(arch)`` — smoke-test config of the same family
``shapes(arch)``      — the arch's assigned input-shape set
``cells()``           — the full 40-cell (arch × shape) dry-run matrix
"""
from __future__ import annotations

import importlib

from repro.models import family_of
from repro.configs.shapes import shapes_for_family, ShapeSpec

ASSIGNED = {
    "tinyllama-1.1b": "repro.configs.tinyllama_1_1b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "dit-s2": "repro.configs.dit_s2",
    "dit-xl2": "repro.configs.dit_xl2",
    "vit-h14": "repro.configs.vit_h14",
    "convnext-b": "repro.configs.convnext_b",
    "resnet-152": "repro.configs.resnet_152",
    "vit-s16": "repro.configs.vit_s16",
}


def _module(arch: str):
    if arch not in ASSIGNED:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ASSIGNED)}")
    return importlib.import_module(ASSIGNED[arch])


def get(arch: str):
    return _module(arch).CONFIG


def get_reduced(arch: str):
    return _module(arch).REDUCED


def shapes(arch: str) -> tuple[ShapeSpec, ...]:
    return shapes_for_family(family_of(get(arch)))


def cells():
    """All (arch, shape) dry-run cells."""
    out = []
    for arch in ASSIGNED:
        for sp in shapes(arch):
            out.append((arch, sp))
    return out


def paper_testbeds():
    from repro.configs import paper_testbeds as pt
    return {
        "alexnet": pt.ALEXNET_CIFAR, "alexnet-mnist": pt.ALEXNET_MNIST,
        "resnet-18": pt.RESNET18_CIFAR, "vgg16": pt.VGG16_CIFAR,
        "levit-128s": pt.LEVIT_128S, "levit-192": pt.LEVIT_192,
        "levit-256": pt.LEVIT_256,
    }
