"""dit-s2 — DiT-S/2 [arXiv:2212.09748]: 12L, d_model 384, 6 heads, patch 2."""
import dataclasses
import jax.numpy as jnp
from repro.models.dit import DiTConfig

CONFIG = DiTConfig(
    name="dit-s2", img_res=256, patch=2, n_layers=12, d_model=384,
    n_heads=6, n_classes=1000, exit_layers=(3, 7),
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16, remat=True,
)

REDUCED = dataclasses.replace(
    CONFIG, img_res=64, n_layers=3, d_model=64, n_heads=4, n_classes=10,
    exit_layers=(0,), remat=False,
    param_dtype=jnp.float32, compute_dtype=jnp.float32)
