"""vit-s16 — ViT-Small/16 [arXiv:2010.11929]: 12L, d 384, 6H, ff 1536."""
import dataclasses
import jax.numpy as jnp
from repro.models.vit import ViTConfig

CONFIG = ViTConfig(
    name="vit-s16", img_res=224, patch=16, n_layers=12, d_model=384,
    n_heads=6, d_ff=1536, n_classes=1000, exit_layers=(3, 7),
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
)

REDUCED = dataclasses.replace(
    CONFIG, img_res=32, patch=8, n_layers=3, d_model=48, n_heads=4,
    d_ff=96, n_classes=10, exit_layers=(0,),
    param_dtype=jnp.float32, compute_dtype=jnp.float32)
