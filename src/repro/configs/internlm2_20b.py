"""internlm2-20b — GQA [arXiv:2403.17297].

48L, d_model 6144, 48 heads (GQA kv=8), d_ff 16384, vocab 92544.
"""
import dataclasses
import jax.numpy as jnp
from repro.models.transformer_lm import LMConfig

CONFIG = LMConfig(
    name="internlm2-20b", n_layers=48, d_model=6144, n_heads=48,
    n_kv_heads=8, d_ff=16384, vocab=92544, exit_layers=(11, 23, 35),
    max_seq=4096, rope_theta=1000000.0, param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16, remat=True, tie_embeddings=False,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=4, d_model=96, n_heads=6, n_kv_heads=2, d_ff=256,
    vocab=256, exit_layers=(1,), max_seq=128, remat=False,
    rope_theta=10000.0, param_dtype=jnp.float32,
    compute_dtype=jnp.float32)
