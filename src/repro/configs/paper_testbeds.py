"""The paper's own testbeds (Table I/II): AlexNet, ResNet-18, VGG-16,
LeViT-128S/192/256 — CIFAR-10 / MNIST scale, used by the reproduction
benchmarks (not part of the 40-cell dry-run matrix)."""
import dataclasses

from repro.models.cnn_zoo import AlexNetConfig, VGGConfig, LeViTConfig
from repro.models.resnet import ResNetConfig

ALEXNET_CIFAR = AlexNetConfig(name="alexnet", img_res=32, in_channels=3,
                              n_classes=10)
ALEXNET_MNIST = AlexNetConfig(name="alexnet-mnist", img_res=28,
                              in_channels=1, n_classes=10,
                              channels=(32, 64, 96, 64, 64),
                              fc_dims=(256, 128))
RESNET18_CIFAR = ResNetConfig(name="resnet-18", depths=(2, 2, 2, 2),
                              width=64, block="basic", img_res=32,
                              n_classes=10, small_input=True)
VGG16_CIFAR = VGGConfig(name="vgg16", img_res=32, n_classes=10)

LEVIT_128S = LeViTConfig(name="levit-128s", img_res=32, n_classes=10,
                         dims=(128, 256, 384), heads=(4, 6, 8),
                         depths=(2, 3, 4), stem_convs=2)
LEVIT_192 = LeViTConfig(name="levit-192", img_res=32, n_classes=10,
                        dims=(192, 288, 384), heads=(3, 5, 6),
                        depths=(4, 4, 4), stem_convs=2)
LEVIT_256 = LeViTConfig(name="levit-256", img_res=32, n_classes=10,
                        dims=(256, 384, 512), heads=(4, 6, 8),
                        depths=(4, 4, 4), stem_convs=2)

# small variants for fast CI
ALEXNET_TINY = dataclasses.replace(ALEXNET_CIFAR,
                                   channels=(16, 32, 48, 32, 32),
                                   fc_dims=(128, 64))
