"""resnet-152 — [arXiv:1512.03385]: bottleneck 3-8-36-3, width 64."""
import dataclasses
import jax.numpy as jnp
from repro.models.resnet import ResNetConfig

CONFIG = ResNetConfig(
    name="resnet-152", depths=(3, 8, 36, 3), width=64, block="bottleneck",
    img_res=224, n_classes=1000, exit_stages=(0, 1, 2),
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
)

REDUCED = dataclasses.replace(
    CONFIG, depths=(1, 1, 2, 1), width=16, img_res=32, n_classes=10,
    small_input=True, param_dtype=jnp.float32, compute_dtype=jnp.float32)
