"""dit-xl2 — DiT-XL/2 [arXiv:2212.09748]: 28L, d_model 1152, 16 heads."""
import dataclasses
import jax.numpy as jnp
from repro.models.dit import DiTConfig

CONFIG = DiTConfig(
    name="dit-xl2", img_res=256, patch=2, n_layers=28, d_model=1152,
    n_heads=16, n_classes=1000, exit_layers=(6, 13, 20),
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16, remat=True,
)

REDUCED = dataclasses.replace(
    CONFIG, img_res=64, n_layers=4, d_model=96, n_heads=4, n_classes=10,
    exit_layers=(1,), remat=False,
    param_dtype=jnp.float32, compute_dtype=jnp.float32)
