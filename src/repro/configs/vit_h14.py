"""vit-h14 — ViT-Huge/14 [arXiv:2010.11929]: 32L, d 1280, 16H, ff 5120."""
import dataclasses
import jax.numpy as jnp
from repro.models.vit import ViTConfig

CONFIG = ViTConfig(
    name="vit-h14", img_res=224, patch=14, n_layers=32, d_model=1280,
    n_heads=16, d_ff=5120, n_classes=1000, exit_layers=(7, 15, 23),
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16, remat=True,
)

REDUCED = dataclasses.replace(
    CONFIG, img_res=32, patch=8, n_layers=4, d_model=64, n_heads=4,
    d_ff=128, n_classes=10, exit_layers=(1,), remat=False,
    param_dtype=jnp.float32, compute_dtype=jnp.float32)
