"""granite-moe-3b-a800m — [hf:ibm-granite/granite-3.0-1b-a400m-base].

32L, d_model 1536, 24 heads (GQA kv=8), per-expert d_ff 512, vocab 49155,
MoE 40 experts top-8.  40 % 16 != 0 => TP-in-expert layout ("tp" mode,
DESIGN.md §4.3); vocab 49155 is odd => embedding sharded on d_model.
"""
import dataclasses
import jax.numpy as jnp
from repro.models.transformer_lm import LMConfig
from repro.models.moe import MoEConfig

CONFIG = LMConfig(
    name="granite-moe-3b-a800m", n_layers=32, d_model=1536, n_heads=24,
    n_kv_heads=8, d_ff=512, vocab=49155,
    moe=MoEConfig(n_experts=40, top_k=8, d_ff=512, n_shared=0),
    moe_ep_mode="tp", n_dense_layers=0, exit_layers=(7, 15, 23),
    max_seq=4096, param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat=True, tie_embeddings=True,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64,
    vocab=256, moe=MoEConfig(n_experts=8, top_k=2, d_ff=64, n_shared=0),
    exit_layers=(0,), max_seq=128, remat=False,
    param_dtype=jnp.float32, compute_dtype=jnp.float32)
