"""tinyllama-1.1b — llama2-arch small [arXiv:2401.02385].

22L, d_model 2048, 32 heads (GQA kv=4), d_ff 5632, vocab 32000.
"""
import dataclasses
import jax.numpy as jnp
from repro.models.transformer_lm import LMConfig

CONFIG = LMConfig(
    name="tinyllama-1.1b", n_layers=22, d_model=2048, n_heads=32,
    n_kv_heads=4, d_ff=5632, vocab=32000, exit_layers=(5, 10, 15),
    max_seq=4096, rope_theta=10000.0, param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16, remat=True, tie_embeddings=False,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab=256, exit_layers=(1,), max_seq=128, remat=False,
    param_dtype=jnp.float32, compute_dtype=jnp.float32)
