"""convnext-b — ConvNeXt-Base [arXiv:2201.03545]: 3-3-27-3, 128..1024."""
import dataclasses
import jax.numpy as jnp
from repro.models.convnext import ConvNeXtConfig

CONFIG = ConvNeXtConfig(
    name="convnext-b", depths=(3, 3, 27, 3), dims=(128, 256, 512, 1024),
    img_res=224, n_classes=1000, exit_stages=(0, 1, 2),
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
)

REDUCED = dataclasses.replace(
    CONFIG, depths=(1, 1, 2, 1), dims=(16, 32, 48, 64), img_res=32,
    n_classes=10, param_dtype=jnp.float32, compute_dtype=jnp.float32)
