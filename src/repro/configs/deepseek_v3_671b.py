"""deepseek-v3-671b — MLA + 1 shared + 256 routed top-8 MoE + MTP
[arXiv:2412.19437].

61L, d_model 7168, 128 heads, per-expert d_ff 2048, vocab 129280,
first 3 layers dense (d_ff 18432).  MLA latent cache (c_kv 512 + rope 64).
"""
import dataclasses
import jax.numpy as jnp
from repro.models.transformer_lm import LMConfig
from repro.models.moe import MoEConfig

CONFIG = LMConfig(
    name="deepseek-v3-671b", n_layers=61, d_model=7168, n_heads=128,
    n_kv_heads=128, d_ff=18432, vocab=129280, attn_kind="mla",
    moe=MoEConfig(n_experts=256, top_k=8, d_ff=2048, n_shared=1),
    moe_ep_mode="ep", n_dense_layers=3, exit_layers=(14, 29, 44),
    max_seq=4096, rope_theta=10000.0, param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16, remat=True, tie_embeddings=False,
    mtp=True, q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
    qk_rope_dim=64, v_head_dim=128,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=256, moe=MoEConfig(n_experts=8, top_k=2, d_ff=32, n_shared=1),
    n_dense_layers=1, exit_layers=(1,), max_seq=128, remat=False,
    mtp=True, q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
    qk_rope_dim=8, v_head_dim=16,
    param_dtype=jnp.float32, compute_dtype=jnp.float32)
