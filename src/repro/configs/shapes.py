"""Assigned input-shape sets per architecture family (40 cells total).

Each shape names the *step kind* the dry-run lowers:
* ``train``   — full train_step (fwd + bwd + optimizer update)
* ``prefill`` — LM prompt processing filling the KV cache
* ``decode``  — LM single-token serve_step against a KV cache
* ``denoise`` — one diffusion sampler step (the N-step loop repeats it)
* ``serve``   — vision forward with DART routing (masked mode)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                 # train | prefill | decode | denoise | serve
    batch: int
    seq_len: int | None = None          # LM
    img_res: int | None = None          # vision / diffusion (pixel res)
    steps: int | None = None            # diffusion sampler steps (loop count)
    note: str = ""


LM_SHAPES = (
    ShapeSpec("train_4k", "train", batch=256, seq_len=4096),
    ShapeSpec("prefill_32k", "prefill", batch=32, seq_len=32768),
    ShapeSpec("decode_32k", "decode", batch=128, seq_len=32768),
    ShapeSpec("long_500k", "decode", batch=1, seq_len=524288,
              note="single-token decode is LINEAR in cache length, so this "
                   "cell is runnable even for softmax attention; the "
                   "assignment's sub-quadratic skip rule applies to "
                   "prefill-like quadratic work (DESIGN.md §3)"),
)

DIFFUSION_SHAPES = (
    ShapeSpec("train_256", "train", batch=256, img_res=256, steps=1000),
    ShapeSpec("gen_1024", "denoise", batch=4, img_res=1024, steps=50),
    ShapeSpec("gen_fast", "denoise", batch=16, img_res=512, steps=4),
    ShapeSpec("train_1024", "train", batch=32, img_res=1024, steps=1000),
)

VISION_SHAPES = (
    ShapeSpec("cls_224", "train", batch=256, img_res=224),
    ShapeSpec("cls_384", "train", batch=64, img_res=384),
    ShapeSpec("serve_b1", "serve", batch=1, img_res=224),
    ShapeSpec("serve_b128", "serve", batch=128, img_res=224),
)


def shapes_for_family(family: str):
    return {"lm": LM_SHAPES, "dit": DIFFUSION_SHAPES}.get(family,
                                                          VISION_SHAPES)
