"""Gradient compression for bandwidth-limited inter-pod links.

Two schemes, both with error feedback (the residual of the compression is
carried to the next step so the compressed SGD trajectory tracks the
uncompressed one — Stich et al. / Deep Gradient Compression lineage):

* ``topk``  — keep the k largest-magnitude entries per leaf (sparsity
  controls cross-pod all-reduce bytes 1/sparsity);
* ``int8``  — per-leaf symmetric quantization (4× fewer bytes than f32).

Under SPMD these wrap the *pod-axis* combine: within a pod gradients
all-reduce at full precision (fast ICI); across pods only compressed
tensors move (slow DCI) — ``compressed_psum`` expresses that pattern with
shard_map when a "pod" axis exists, and degrades to identity otherwise.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def quantize_int8(x):
    """(values_int8, scale).  Symmetric per-tensor quantization."""
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def topk_sparsify(x, frac: float):
    """Keep the top-`frac` fraction by |value| (dense mask representation —
    the wire format would be (indices, values); bytes accounting uses
    2·k·4B)."""
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jnp.sort(jnp.abs(flat))[-k]
    mask = (jnp.abs(x) >= thresh).astype(x.dtype)
    return x * mask, mask


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    scheme: str = "none"          # none | int8 | topk
    topk_frac: float = 0.01


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, ef_state, cfg: CompressionConfig):
    """Apply compression with error feedback.  Returns
    (compressed_grads, new_ef_state, wire_bytes_estimate)."""
    if cfg.scheme == "none":
        return grads, ef_state, sum(
            g.size * 4 for g in jax.tree.leaves(grads))

    wire = 0
    new_g, new_ef = [], []
    g_leaves, treedef = jax.tree.flatten(grads)
    e_leaves = jax.tree.leaves(ef_state)
    for g, e in zip(g_leaves, e_leaves):
        acc = g.astype(jnp.float32) + e
        if cfg.scheme == "int8":
            q, s = quantize_int8(acc)
            dq = dequantize_int8(q, s)
            wire += q.size + 4
        else:  # topk
            dq, _ = topk_sparsify(acc, cfg.topk_frac)
            wire += int(acc.size * cfg.topk_frac) * 8
        new_g.append(dq.astype(g.dtype))
        new_ef.append(acc - dq)
    return (jax.tree.unflatten(treedef, new_g),
            jax.tree.unflatten(treedef, new_ef), wire)
