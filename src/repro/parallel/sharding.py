"""Parameter metadata + logical-axis sharding resolution.

Models in ``repro.models`` build parameter pytrees whose leaves are
:class:`Param` — a value (concrete array or ``ShapeDtypeStruct``) tagged
with *logical* axis names ("embed", "heads", "vocab", ...).  A per-family
rules table maps logical names onto physical mesh axes; :func:`resolve_spec`
turns the tag into a ``PartitionSpec`` and *downgrades* any entry whose
mesh-axis product does not divide the corresponding dimension (recording
the downgrade so callers can report it).  This is the same logical-axis
approach MaxText/T5X use, kept dependency-free.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = str | None
LogicalAxes = tuple[AxisName, ...]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Param:
    """A parameter value tagged with logical axis names.

    ``axes`` must have one entry per value dimension; ``None`` marks a
    dimension that is never sharded (e.g. small biases, norm scales).
    """

    value: Any
    axes: LogicalAxes = ()

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)

    @property
    def shape(self):
        return self.value.shape

    @property
    def dtype(self):
        return self.value.dtype


def is_param(x) -> bool:
    return isinstance(x, Param)


def unzip(tree):
    """Split a Param tree into (value_tree, axes_tree) with identical structure.
    Non-Param leaves pass through (their axes default to all-None)."""
    values = jax.tree.map(lambda p: p.value if is_param(p) else p, tree,
                          is_leaf=is_param)
    axes = jax.tree.map(
        lambda p: p.axes if is_param(p)
        else (None,) * getattr(p, "ndim", 0), tree, is_leaf=is_param)
    return values, axes


def zip_params(values, axes):
    return jax.tree.map(Param, values, axes,
                        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))


# ---------------------------------------------------------------------------
# Logical-axis rules
# ---------------------------------------------------------------------------

# A rules table maps logical axis name -> mesh axis name(s).  Values may be
# None (replicate), a str, or a tuple of str (sharded over several mesh axes).
Rules = Mapping[str, Any]

# Default rules for a (pod?, data, model) mesh.  "fsdp" entries shard the
# weight-stationary dimension over the data axes (ZeRO-3 style); they are
# enabled by `with_fsdp`.
LM_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_shard": ("pod", "data"),   # sequence-sharded activations / KV (long ctx)
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "moe_mlp": "model",             # per-expert hidden dim (TP-in-expert)
    "experts": "model",             # expert-parallel stacking dim
    "vocab": "model",
    "latent": None,                 # MLA latent dims stay replicated
    "classes": None,
    "channels": "model",            # conv output channels
    "in_channels": None,
    "spatial": None,
    "patch": None,
}

VISION_RULES = dict(LM_RULES)
DIFFUSION_RULES = dict(LM_RULES)


def with_fsdp(rules: Rules, axes=("pod", "data")) -> dict[str, Any]:
    """Return rules where weight 'embed'/'in_channels' dims are data-sharded
    (fully-sharded data parallel for the parameter/optimizer state)."""
    out = dict(rules)
    out["embed"] = axes
    out["in_channels"] = axes
    return out


# Pure-FSDP rules: the model axis is repurposed as extra data parallelism
# (ZeRO-3).  No tensor parallelism => no per-layer activation all-reduces;
# weights are all-gathered per use instead.  The right regime for models
# whose layers are small relative to the batch (tinyllama — §Perf).
FSDP_DP_RULES: dict[str, Any] = {
    "batch": ("pod", "data", "model"),
    "seq": None,
    "seq_shard": ("pod", "data", "model"),
    "embed": ("pod", "data", "model"),
    "heads": None, "kv_heads": None, "head_dim": None,
    "mlp": None, "moe_mlp": None, "experts": None,
    "vocab": None, "latent": None, "classes": None,
    "channels": None, "in_channels": ("pod", "data", "model"),
    "spatial": None, "patch": None,
}


@dataclasses.dataclass
class Downgrade:
    path: str
    dim: int
    logical: str
    wanted: Any
    reason: str


def _mesh_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]


def resolve_spec(shape: Sequence[int], axes: LogicalAxes, rules: Rules,
                 mesh: Mesh, path: str = "",
                 downgrades: list[Downgrade] | None = None,
                 used_axes: set | None = None) -> P:
    """Map logical axes -> PartitionSpec, dropping non-divisible entries.

    For tuple mesh axes we try the longest divisible prefix, e.g. a batch
    of 4 on (("pod","data")) with pod=2, data=16 resolves to ("pod",).
    A mesh axis may appear at most once in a spec; duplicates replicate.
    """
    if downgrades is None:
        downgrades = []
    used = set() if used_axes is None else used_axes
    entries: list[Any] = []
    if len(axes) != len(shape):
        raise ValueError(f"{path}: axes {axes} rank != shape {shape}")
    for d, (dim, name) in enumerate(zip(shape, axes)):
        if name is None or name not in rules or rules[name] is None:
            entries.append(None)
            continue
        want = rules[name]
        cand = tuple(want) if isinstance(want, (tuple, list)) else (want,)
        # Drop mesh axes absent from this mesh or already used by another
        # dim of this param.
        cand = tuple(a for a in cand if a in mesh.shape and a not in used)
        # Longest prefix of the candidate tuple that divides dim.
        chosen: tuple[str, ...] = ()
        for k in range(len(cand), 0, -1):
            prefix = cand[:k]
            if dim % _mesh_size(mesh, prefix) == 0:
                chosen = prefix
                break
        if chosen != (tuple(want) if isinstance(want, (tuple, list)) else (want,)):
            downgrades.append(Downgrade(path, d, name, want,
                                        f"dim {dim} not divisible / axis reuse"))
        if not chosen:
            entries.append(None)
        elif len(chosen) == 1:
            entries.append(chosen[0])
            used.add(chosen[0])
        else:
            entries.append(chosen)
            used.update(chosen)
    # Trim trailing Nones (canonical PartitionSpec form).
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def tree_specs(axes_tree, shapes_tree, rules: Rules, mesh: Mesh,
               collect_downgrades: list[Downgrade] | None = None):
    """Build a PartitionSpec tree matching the param tree."""
    # jax.tree.flatten_with_path landed after 0.4.37; the tree_util
    # spelling works on every version we support.
    paths_axes = jax.tree_util.tree_flatten_with_path(
        axes_tree, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    flat_axes, treedef = paths_axes
    flat_shapes = [tuple(v.shape) for v in jax.tree.leaves(shapes_tree)]
    if len(flat_axes) != len(flat_shapes):
        raise ValueError(f"axes/shapes leaf mismatch: {len(flat_axes)} vs "
                         f"{len(flat_shapes)}")
    specs = []
    for (path, axes), shape in zip(flat_axes, flat_shapes):
        pstr = jax.tree_util.keystr(path)
        specs.append(resolve_spec(shape, axes, rules, mesh, pstr,
                                  collect_downgrades))
    return jax.tree.unflatten(treedef, specs)


def tree_shardings(axes_tree, shapes_tree, rules: Rules, mesh: Mesh,
                   collect_downgrades: list[Downgrade] | None = None):
    specs = tree_specs(axes_tree, shapes_tree, rules, mesh, collect_downgrades)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def named_sharding(mesh: Mesh, *spec_entries) -> NamedSharding:
    return NamedSharding(mesh, P(*spec_entries))


def batch_spec(mesh: Mesh, batch: int, rank: int, rules: Rules = LM_RULES) -> P:
    """PartitionSpec for a batched activation: shard dim 0 over data axes
    (with divisibility auto-downgrade), replicate the rest."""
    return resolve_spec((batch,) + (1,) * (rank - 1),
                        ("batch",) + (None,) * (rank - 1), rules, mesh)


# ---------------------------------------------------------------------------
# Abstract init (no allocation) — used by the dry-run.
# ---------------------------------------------------------------------------

def abstract_init(init_fn: Callable, *args, **kwargs):
    """Run an init function under eval_shape: Param leaves keep their logical
    axes (aux data) while values become ShapeDtypeStructs."""
    return jax.eval_shape(lambda: init_fn(*args, **kwargs))


def param_count(values_tree) -> int:
    return sum(int(math.prod(v.shape)) for v in jax.tree.leaves(values_tree))


def param_bytes(values_tree) -> int:
    return sum(int(math.prod(v.shape)) * jnp.dtype(v.dtype).itemsize
               for v in jax.tree.leaves(values_tree))
