"""Checkpointing: atomic, async, integrity-checked, mesh-elastic.

Layout:  <dir>/step_<N>/manifest.msgpack + leaf_<i>.bin

* **atomic**   — written to ``step_N.tmp`` then os.rename'd (restart never
  sees a torn checkpoint).
* **async**    — ``save_async`` snapshots to host memory synchronously
  (cheap) and writes on a background thread, overlapping training.
* **integrity**— CRC32 per leaf, verified on restore.
* **elastic**  — leaves are stored as full (host-gathered) arrays; restore
  re-shards onto *any* mesh via the provided sharding tree, so a job can
  restart with a different pod count (runtime/fault.py drives this).
* **GC**       — keep-last-k.
"""
from __future__ import annotations

import os
import shutil
import zlib
from concurrent.futures import Future, ThreadPoolExecutor

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree, extra: dict | None = None) -> str:
    """Synchronous atomic save.  Returns the final directory."""
    leaves, treedef = _flatten(tree)
    host = [np.asarray(x) for x in leaves]
    return _write(path, step, host, treedef, extra or {})


_EXEC = ThreadPoolExecutor(max_workers=1, thread_name_prefix="ckpt")


def save_async(path: str, step: int, tree, extra: dict | None = None
               ) -> Future:
    """Snapshot to host now, write in the background."""
    leaves, treedef = _flatten(tree)
    host = [np.asarray(x) for x in leaves]          # device->host sync point
    return _EXEC.submit(_write, path, step, host, treedef, extra or {})


def _write(path, step, host_leaves, treedef, extra):
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": int(step), "treedef": str(treedef),
                "extra": extra, "leaves": []}
    for i, arr in enumerate(host_leaves):
        raw = np.ascontiguousarray(arr).tobytes()
        manifest["leaves"].append({
            "file": f"leaf_{i:05d}.bin",
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(raw) & 0xFFFFFFFF,
        })
        with open(os.path.join(tmp, f"leaf_{i:05d}.bin"), "wb") as f:
            f.write(raw)
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(path: str, target_tree, step: int | None = None, *,
            shardings=None, strict_structure=True):
    """Restore into the structure of ``target_tree``.

    shardings: optional pytree of NamedSharding matching target — leaves
    are device_put with them (elastic re-shard onto the current mesh)."""
    step = latest_step(path) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    t_leaves, treedef = jax.tree.flatten(target_tree)
    if strict_structure and len(t_leaves) != len(manifest["leaves"]):
        raise ValueError(
            f"leaf count mismatch: ckpt {len(manifest['leaves'])} "
            f"vs target {len(t_leaves)}")
    s_leaves = jax.tree.leaves(shardings) if shardings is not None \
        else [None] * len(t_leaves)
    out = []
    for i, (meta, tgt, shd) in enumerate(zip(manifest["leaves"], t_leaves,
                                             s_leaves)):
        with open(os.path.join(d, meta["file"]), "rb") as f:
            raw = f.read()
        if (zlib.crc32(raw) & 0xFFFFFFFF) != meta["crc32"]:
            raise IOError(f"CRC mismatch in {meta['file']}")
        arr = np.frombuffer(raw, dtype=np.dtype(meta["dtype"])
                            ).reshape(meta["shape"])
        if tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(f"shape mismatch leaf {i}: "
                             f"{arr.shape} vs {tgt.shape}")
        arr = arr.astype(tgt.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out), manifest["step"], \
        manifest["extra"]


class CheckpointManager:
    """keep-last-k + async orchestration + restore-or-init."""

    def __init__(self, path: str, keep: int = 3, save_every: int = 100):
        self.path = path
        self.keep = keep
        self.save_every = save_every
        self._pending: Future | None = None
        os.makedirs(path, exist_ok=True)

    def maybe_save(self, step: int, tree, extra=None, force=False):
        if not force and (step == 0 or step % self.save_every):
            return None
        if self._pending is not None:
            self._pending.result()                 # backpressure
        self._pending = save_async(self.path, step, tree, extra)
        self._pending.add_done_callback(lambda _: self._gc())
        return self._pending

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.path)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_or_none(self, target_tree, shardings=None):
        if latest_step(self.path) is None:
            return None
        return restore(self.path, target_tree, shardings=shardings)
