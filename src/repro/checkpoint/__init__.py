from repro.checkpoint.checkpoint import (save, save_async, restore,
                                         latest_step, CheckpointManager)

__all__ = ["save", "save_async", "restore", "latest_step",
           "CheckpointManager"]
