"""Production mesh definitions.

``make_production_mesh()`` is a FUNCTION (importing this module never
touches jax device state).  Single pod: 16×16 = 256 chips (TPU v5e pod);
multi-pod: 2×16×16 = 512 chips with a leading "pod" axis whose collectives
ride the (slower) inter-pod links — gradient compression
(repro.parallel.compression) targets exactly that axis.

``make_serving_mesh()`` is the 1-D data-parallel mesh the sharded DART
serving engine (``repro.engine.sharded``) replicates over: one "data"
axis covering every addressable device.
"""
from __future__ import annotations

import jax

# jax >= 0.5 takes axis_types=(AxisType.Auto, ...); 0.4.x has neither the
# enum nor the kwarg (same version-gate pattern as the `shard_map` import
# in models/moe.py).
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def _make_mesh(shape, axes):
    if _AXIS_TYPE is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(_AXIS_TYPE.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (fake or real) devices exist — used by
    reduced-config tests."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return _make_mesh((data, model), ("data", "model"))


def make_serving_mesh(data: int | None = None):
    """1-D ("data",) mesh for data-parallel serving.  ``data`` defaults to
    every addressable device (fake CPU devices included)."""
    n = len(jax.devices())
    data = n if data is None else data
    assert data <= n, (data, n)
    return _make_mesh((data,), ("data",))


def dp_size(mesh) -> int:
    s = 1
    for a in ("pod", "data"):
        s *= mesh.shape.get(a, 1)
    return s
