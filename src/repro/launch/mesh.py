"""Production mesh definitions.

``make_production_mesh()`` is a FUNCTION (importing this module never
touches jax device state).  Single pod: 16×16 = 256 chips (TPU v5e pod);
multi-pod: 2×16×16 = 512 chips with a leading "pod" axis whose collectives
ride the (slower) inter-pod links — gradient compression
(repro.parallel.compression) targets exactly that axis.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (fake or real) devices exist — used by
    reduced-config tests."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def dp_size(mesh) -> int:
    s = 1
    for a in ("pod", "data"):
        s *= mesh.shape.get(a, 1)
    return s
