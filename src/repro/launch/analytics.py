"""Analytic per-device HBM-traffic model for the roofline memory term.

XLA:CPU's ``bytes accessed`` counts every HLO op's operands independently
(no fusion accounting) and is f32-inflated — measured 10–100× above
physical HBM traffic, so the §Roofline memory term uses this analytic
model instead (the HLO number is kept as a diagnostic column).

Model (per device, per step; bytes):
  train   : P_used·2·3   (bf16 weights read in fwd + bwd×2)
          + P_stored·(2+2 + m+v io + master io)      (grad write + optimizer)
          + ACT·c_act    (residual-stream reads/writes across the layer
                          stack; flash-chunked attention keeps the S²
                          score traffic in VMEM so it does NOT appear)
  prefill : P_used·2 + ACT·c_act + KV_write
  decode  : P_active_used·2 + KV_read + small vectors
  serve   : P_used·2 + ACT·c_act
with ACT = L·B_loc·S_loc·D·2 and c_act = 12 (norm/attn/mlp intermediates,
~6 reads + 6 writes per layer — MaxText-style napkin constant).
"""
from __future__ import annotations


from repro.configs import registry
from repro.models import family_of
from repro.models.transformer_lm import lm_param_count, lm_active_param_count

C_ACT = 12.0


def _mesh_sizes(multi_pod):
    dp = 32 if multi_pod else 16
    model = 16
    n_dev = dp * model
    return dp, model, n_dev


def _vision_params(cfg):
    from repro.parallel.sharding import unzip, param_count, abstract_init
    from repro.models import get_family
    import jax
    tree = abstract_init(get_family(cfg).init, jax.random.key(0), cfg)
    return param_count(unzip(tree)[0])


def model_bytes(arch: str, shape_name: str, *, multi_pod: bool,
                variant: str = "baseline") -> float:
    """Per-device HBM bytes for one step of the cell."""
    import dataclasses
    cfg = registry.get(arch)
    sp = next(s for s in registry.shapes(arch) if s.name == shape_name)
    fam = family_of(cfg)
    dp, model, n_dev = _mesh_sizes(multi_pod)
    fsdp_like = ("fsdp" in variant) or arch in ("internlm2-20b",
                                                "deepseek-v3-671b")
    # truncK variants (DART expected-depth serving components)
    trunc = next((p for p in variant.split("+") if p.startswith("trunc")),
                 None)
    if trunc is not None and fam in ("lm", "dit"):
        k = int(trunc[5:])
        exits = tuple(e for e in cfg.exit_layers if e < k - 1)
        cfg = dataclasses.replace(cfg, n_layers=k, exit_layers=exits)

    if fam == "lm":
        p_total = lm_param_count(cfg)
        p_active = lm_active_param_count(cfg)
        b_loc = max(1, sp.batch // dp)
        if sp.kind == "train":
            p_stored = p_total / n_dev if fsdp_like else p_total / model
            p_used = p_total / model          # weights touched per device
            opt_io = 2 + 2 + 8 + 8            # grad w + m/v r+w (bf16/f32 mix)
            act = (cfg.n_layers * b_loc * sp.seq_len * cfg.d_model * 2
                   * C_ACT)
            return p_used * 2 * 3 + p_stored * opt_io + act
        if sp.kind == "prefill":
            p_used = p_total / model
            act = cfg.n_layers * b_loc * sp.seq_len * cfg.d_model * 2 * C_ACT
            if cfg.attn_kind == "mla":
                kv = cfg.n_layers * b_loc * sp.seq_len \
                    * (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
            else:
                kv = cfg.n_layers * b_loc * sp.seq_len * 2 \
                    * cfg.n_kv_heads * cfg.hd * 2
            return p_used * 2 + act + kv
        # decode: weights stream once, KV cache read once
        p_used = p_active / model
        if cfg.attn_kind == "mla":
            kv_row = (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
        else:
            kv_row = 2 * cfg.n_kv_heads * cfg.hd * 2
        # cache sharded over batch when divisible, else over seq
        kv_loc = cfg.n_layers * sp.batch * sp.seq_len * kv_row \
            / (dp if sp.batch % dp == 0 else n_dev if sp.batch == 1 else 1)
        return p_used * 2 + kv_loc + b_loc * cfg.d_model * 2 * cfg.n_layers * 4

    if fam == "dit":
        cfg = dataclasses.replace(cfg, img_res=sp.img_res)
        p_total = _vision_params(cfg)
        b_loc = max(1, sp.batch // dp)
        act = cfg.n_layers * b_loc * cfg.n_tokens * cfg.d_model * 2 * C_ACT
        p_used = p_total / model
        if sp.kind == "train":
            return p_used * 2 * 3 + p_total / model * 20 + act
        return p_used * 2 + act

    # vision
    cfg = dataclasses.replace(cfg, img_res=sp.img_res)
    p_total = _vision_params(cfg)
    b_loc = max(1, sp.batch // dp)
    # activation footprint ~ flops / (2 * d): use tokens*channels heuristic
    res = sp.img_res
    act = b_loc * res * res * 64 * 2 * C_ACT        # conv-pyramid napkin
    p_used = p_total / model
    if sp.kind == "train":
        return p_used * 2 * 3 + p_total / model * 20 + act
    return p_used * 2 + act
