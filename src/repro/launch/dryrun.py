import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces (artifacts/dryrun/<arch>__<shape>__<mesh>.json):
  * ``memory_analysis``  — per-device argument/output/temp bytes (fits?)
  * ``cost_analysis``    — per-device HLO FLOPs and bytes accessed
  * ``collectives``      — per-class counts and per-device bytes parsed
                           from the compiled (post-SPMD) HLO
  * ``model_flops``      — analytic MODEL_FLOPS (6ND-style) for §Roofline
  * sharding downgrades, compile time

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch vit-s16 --shape cls_224
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import registry
from repro.launch.mesh import make_production_mesh
from repro.compat import cost_analysis_dict
from repro.launch import steps as steps_mod

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")
# bytes-on-wire multiplier per op result (ring algorithm accounting)
WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}

_DTYPE_BYTES = {"pred": 0.125, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
                "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _result_bytes(hlo_line: str) -> float:
    """Sum the byte size of the result type(s) on an HLO op line."""
    lhs = hlo_line.split(" = ", 1)
    if len(lhs) != 2:
        return 0.0
    # result type is at the start of the RHS, possibly a tuple
    rhs = lhs[1]
    op_pos = min((rhs.find(k) for k in COLLECTIVE_KINDS if k in rhs),
                 default=-1)
    head = rhs[:op_pos] if op_pos > 0 else rhs.split("(")[0]
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(head):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, bf16_model: bool = False) -> dict:
    """Per-class collective counts/bytes from the post-SPMD HLO.

    CPU-backend caveat (documented in EXPERIMENTS.md §Dry-run): XLA:CPU
    legalizes bf16 compute to f32, so collectives that would be bf16 on
    TPU appear as f32 here.  For bf16 models we additionally report
    ``total_bytes_bf16corr`` = f32-typed collective bytes × 0.5 (verified
    against the bf16 StableHLO dot types)."""
    stats = {k: {"count": 0, "bytes": 0.0} for k in COLLECTIVE_KINDS}
    corr_total = 0.0
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("//") or " = " not in s:
            continue
        for kind in COLLECTIVE_KINDS:
            # match `all-reduce(` / `all-reduce-start(`; skip `-done` (the
            # async pair would double-count the same transfer)
            if re.search(rf"(?<![\w-]){kind}(?:-start)?\(", s):
                by = _result_bytes(s) * WIRE_FACTOR[kind]
                stats[kind]["count"] += 1
                stats[kind]["bytes"] += by
                is_f32 = " f32[" in s or "(f32[" in s
                corr_total += by * (0.5 if (bf16_model and is_f32) else 1.0)
                break
    stats["total_bytes"] = sum(v["bytes"] for k, v in stats.items()
                               if isinstance(v, dict))
    stats["total_bytes_bf16corr"] = corr_total
    return stats


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             outdir: str = "artifacts/dryrun", reduced=False,
             keep_hlo=False, step_builder=None,
             variant: str = "baseline") -> dict:
    sp = next(s for s in registry.shapes(arch) if s.name == shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    bundle = (step_builder or steps_mod.build)(arch, sp, mesh,
                                               reduced=reduced,
                                               variant=variant)
    lowered = jax.jit(bundle.step,
                      in_shardings=bundle.in_shardings).lower(*bundle.inputs)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    ca = cost_analysis_dict(compiled)
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    bf16_model = bundle.meta.get("bf16", True) and not reduced
    coll = parse_collectives(hlo, bf16_model=bf16_model)
    n_dev = mesh.devices.size

    # segment-scan cells: XLA costs each scan body once; compile a
    # single-layer probe and extrapolate the missing layer instances.
    probe_fn = bundle.meta.pop("probe", None)
    scan_corr = None
    if probe_fn is not None:
        pb = probe_fn()
        plow = jax.jit(pb.step, in_shardings=pb.in_shardings).lower(
            *pb.inputs)
        pcomp = plow.compile()
        pca = cost_analysis_dict(pcomp)
        pcoll = parse_collectives(pcomp.as_text(), bf16_model=bf16_model)
        extra = (bundle.meta["scan_layers_total"]
                 - bundle.meta["scan_body_instances"])
        scan_corr = {
            "probe_flops_per_device": float(pca.get("flops", 0.0)),
            "probe_bytes_per_device": float(pca.get("bytes accessed", 0.0)),
            "probe_collective_bytes": pcoll["total_bytes_bf16corr"],
            "extrapolated_layers": extra,
        }
        ca = dict(ca)
        ca["flops"] = float(ca.get("flops", 0.0)) \
            + scan_corr["probe_flops_per_device"] * extra
        ca["bytes accessed"] = float(ca.get("bytes accessed", 0.0)) \
            + scan_corr["probe_bytes_per_device"] * extra
        for key in ("total_bytes", "total_bytes_bf16corr"):
            coll[key] = coll[key] + pcoll[key] * extra

    rec = {
        "arch": arch, "shape": sp.name, "kind": bundle.meta.get("kind"),
        "mesh": mesh_name, "devices": n_dev,
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        "model_flops_global": int(bundle.model_flops),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "collectives": coll,
        "downgrades": [f"{d.path} dim{d.dim} {d.logical}->{d.wanted}"
                       for d in bundle.downgrades],
        "scan_correction": scan_corr,
        "meta": bundle.meta,
    }
    rec["variant"] = variant
    os.makedirs(outdir, exist_ok=True)
    suffix = "" if variant == "baseline" else f"__{variant}"
    fn = os.path.join(outdir,
                      f"{arch}__{sp.name}__{mesh_name}{suffix}.json")
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1)
    if keep_hlo:
        with open(fn.replace(".json", ".hlo.txt"), "w") as f:
            f.write(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--outdir", default="artifacts/dryrun")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    pods = {"single": [False], "multi": [True],
            "both": [False, True]}[args.multi_pod]
    cells = registry.cells() if args.all else [
        (args.arch, next(s for s in registry.shapes(args.arch)
                         if s.name == args.shape))]
    failures = []
    for arch, sp in cells:
        for mp in pods:
            mesh_name = '2x16x16' if mp else '16x16'
            tag = f"{arch} × {sp.name} × {mesh_name}"
            if args.skip_existing:
                suffix = "" if args.variant == "baseline" \
                    else f"__{args.variant}"
                fn = os.path.join(args.outdir,
                                  f"{arch}__{sp.name}__{mesh_name}{suffix}.json")
                if os.path.exists(fn):
                    print(f"SKIP {tag} (artifact exists)")
                    continue
            try:
                rec = run_cell(arch, sp.name, multi_pod=mp,
                               outdir=args.outdir, reduced=args.reduced,
                               keep_hlo=args.keep_hlo,
                               variant=args.variant)
                print(f"OK   {tag}: compile {rec['compile_s']}s, "
                      f"flops/dev {rec['flops_per_device']:.3e}, "
                      f"temp {rec['memory']['temp_bytes']/2**30:.2f} GiB, "
                      f"coll {rec['collectives']['total_bytes']/2**30:.3f} GiB")
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"FAIL {tag}: {e!r}")
                traceback.print_exc(limit=3)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nALL CELLS PASSED")


if __name__ == "__main__":
    main()
