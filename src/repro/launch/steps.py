"""Dry-run step builders: one jit-able step per (arch × shape × mesh) cell.

Every builder returns a ``StepBundle``: the step function, abstract
example inputs (ShapeDtypeStructs — *no allocation*), and input shardings
resolved from the logical-axis rules.  ``launch.dryrun`` lowers and
compiles these; ``benchmarks.roofline`` reads their cost analyses.

The steps are the *real* production steps (optimizer update included for
training; DART routing included for serving) — not stripped-down facsimiles.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.shapes import ShapeSpec
from repro.core import difficulty as DIFF
from repro.core import routing as R
from repro.core.routing import DartParams
from repro.models import get_family, family_of
from repro.models import transformer_lm as TLM
from repro.models import dit as DIT
from repro.optim import adamw
from repro.parallel.sharding import (abstract_init, unzip, tree_shardings,
                                     resolve_spec, LM_RULES, with_fsdp,
                                     Downgrade)

# big-LM training wants FSDP param/optimizer sharding by default
FSDP_TRAIN = {"internlm2-20b", "deepseek-v3-671b"}
# archs whose train/prefill paths use segment-scan (compile-size control;
# the dry-run extrapolates exact per-layer costs from a probe compile)
SCAN_ARCHS = {"deepseek-v3-671b", "internlm2-20b"}


@dataclasses.dataclass
class StepBundle:
    name: str
    step: Callable
    inputs: tuple            # ShapeDtypeStructs
    in_shardings: tuple
    model_flops: int         # analytic (MODEL_FLOPS for §Roofline)
    downgrades: list
    meta: dict


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _replicated(mesh):
    return NamedSharding(mesh, P())


def _batch_sharding(mesh, shape, rules=LM_RULES):
    spec = resolve_spec(shape, ("batch",) + (None,) * (len(shape) - 1),
                        rules, mesh)
    return NamedSharding(mesh, spec)


def _abstract_params(cfg, rules, mesh, downgrades):
    tree = abstract_init(get_family(cfg).init, jax.random.key(0), cfg)
    values, axes = unzip(tree)
    shardings = tree_shardings(axes, values, rules, mesh, downgrades)
    return values, axes, shardings


def _opt_shardings(opt_state_abs, param_shardings, mesh):
    """Optimizer state mirrors params; step counter replicated."""
    from repro.optim.optimizers import OptimizerState
    return OptimizerState(
        step=_replicated(mesh),
        inner={k: param_shardings for k in opt_state_abs.inner})


def _cache_axes(cfg: TLM.LMConfig):
    if cfg.attn_kind == "mla":
        one = {"c_kv": ("batch", "seq_shard", "latent"),
               "k_rope": ("batch", "seq_shard", "latent")}
    else:
        one = {"k": ("batch", "seq_shard", "kv_heads", "head_dim"),
               "v": ("batch", "seq_shard", "kv_heads", "head_dim")}
    return [dict(one) for _ in range(cfg.n_layers)]


def _cache_shardings(cache_abs, cfg, mesh, downgrades):
    axes = _cache_axes(cfg)
    return tree_shardings(axes, cache_abs, LM_RULES, mesh, downgrades)


# ---------------------------------------------------------------------------
# LM steps
# ---------------------------------------------------------------------------

def _lm_probe_bundle(arch, cfg: TLM.LMConfig, sp: ShapeSpec, mesh,
                     kind: str):
    """Single-MoE-layer probe (fwd+bwd for train, fwd for prefill) used to
    extrapolate exact per-layer FLOPs/collectives for scanned segments."""
    dg: list = []
    layer_tree = abstract_init(TLM._layer_init, jax.random.key(0), cfg,
                               cfg.n_dense_layers)
    lvals, laxes = unzip(layer_tree)
    rules = with_fsdp(LM_RULES) if arch in FSDP_TRAIN and kind == "train" \
        else LM_RULES
    lshard = tree_shardings(laxes, lvals, rules, mesh, dg)
    x = _sds((sp.batch, sp.seq_len, cfg.d_model), cfg.compute_dtype)
    xshard = _batch_sharding(mesh, x.shape)
    cos, sin = TLM.L.rope_freqs(
        cfg.qk_rope_dim if cfg.attn_kind == "mla" else cfg.hd,
        sp.seq_len, cfg.rope_theta)

    if kind == "train":
        def probe(lp, x):
            def loss(lp):
                y, aux = TLM._layer_apply(lp, x, cfg, cfg.n_dense_layers,
                                          cos, sin, mesh)
                return jnp.sum(y.astype(jnp.float32)) + aux
            return jax.grad(loss)(lp)
    else:
        def probe(lp, x):
            y, aux = TLM._layer_apply(lp, x, cfg, cfg.n_dense_layers, cos,
                                      sin, mesh)
            return jnp.sum(y.astype(jnp.float32)) + aux

    return StepBundle(f"{arch}:{sp.name}:probe", probe, (lvals, x),
                      (lshard, xshard), 0, dg, {"kind": f"probe-{kind}"})


def _lm_train(arch, cfg: TLM.LMConfig, sp: ShapeSpec, mesh, downgrades,
              fsdp_dp: bool = False):
    scan = arch in SCAN_ARCHS
    cfg = dataclasses.replace(cfg, max_seq=sp.seq_len,
                              attn_chunked=sp.seq_len > 4096,
                              layer_scan=scan)
    if fsdp_dp:
        from repro.parallel.sharding import FSDP_DP_RULES
        rules = FSDP_DP_RULES
        cfg = dataclasses.replace(cfg, act_shard="none")
    else:
        rules = with_fsdp(LM_RULES) if arch in FSDP_TRAIN else LM_RULES
    params, axes, pshard = _abstract_params(cfg, rules, mesh, downgrades)
    opt = adamw(1e-4, moment_dtype=jnp.bfloat16
                if arch in FSDP_TRAIN else jnp.float32)
    opt_state = jax.eval_shape(opt.init, params)
    oshard = _opt_shardings(opt_state, pshard, mesh)
    toks = _sds((sp.batch, sp.seq_len), jnp.int32)
    labs = _sds((sp.batch, sp.seq_len), jnp.int32)
    bshard = _batch_sharding(mesh, toks.shape, rules)

    def step(params, opt_state, tokens, labels):
        def loss_fn(p):
            return TLM.lm_multi_exit_loss(p, tokens, labels, cfg, mesh=mesh)
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    meta = {"kind": "train", "fsdp": arch in FSDP_TRAIN}
    if scan:
        meta.update(
            scan_layers_total=cfg.n_layers - cfg.n_dense_layers,
            scan_body_instances=len(TLM.scan_segments(cfg)),
            probe=lambda: _lm_probe_bundle(arch, cfg, sp, mesh, "train"))
    flops = TLM.lm_train_flops(cfg, sp.batch, sp.seq_len)
    return StepBundle(f"{arch}:{sp.name}", step,
                      (params, opt_state, toks, labs),
                      (pshard, oshard, bshard, bshard), flops, downgrades,
                      meta)


def _lm_prefill(arch, cfg: TLM.LMConfig, sp: ShapeSpec, mesh, downgrades):
    scan = arch in SCAN_ARCHS
    cfg = dataclasses.replace(cfg, max_seq=sp.seq_len, attn_chunked=True,
                              remat=False, layer_scan=scan)
    params, axes, pshard = _abstract_params(cfg, LM_RULES, mesh, downgrades)
    toks = _sds((sp.batch, sp.seq_len), jnp.int32)
    bshard = _batch_sharding(mesh, toks.shape)
    dart = DartParams.default(cfg.n_exits)

    def gate(params, tokens, exit_h):
        emb = jnp.take(params["embed"]["table"], tokens[:, -64:], axis=0)
        alpha = DIFF.token_difficulty(emb)
        names = [str(i) for i in cfg.exit_layers] + ["final"]
        logits = jnp.stack([TLM.exit_logits(params, cfg, h, n)
                            for n, h in zip(names, exit_h)])   # (E, B, V)
        conf = R.confidence_from_logits(logits)
        routed = R.route(conf, alpha, dart)
        preds = jnp.argmax(logits, axis=-1)                    # (E, B)
        tok = jnp.take_along_axis(preds, routed["exit_idx"][None], 0)[0]
        return tok, routed["exit_idx"], alpha

    if scan:
        def step(params, tokens):
            dense_c, seg_c, exit_h = TLM.lm_prefill_scan(params, tokens,
                                                         cfg, mesh=mesh)
            tok, idx, alpha = gate(params, tokens, exit_h)
            return tok, idx, alpha, dense_c, seg_c
    else:
        def step(params, tokens):
            cache = TLM.lm_init_cache(cfg, sp.batch, sp.seq_len)
            new_cache, exit_h = TLM.lm_prefill(params, tokens, cfg, cache,
                                               mesh=mesh)
            tok, idx, alpha = gate(params, tokens, exit_h)
            return tok, idx, alpha, new_cache

    meta = {"kind": "prefill"}
    if scan:
        meta.update(
            scan_layers_total=cfg.n_layers - cfg.n_dense_layers,
            scan_body_instances=len(TLM.scan_segments(cfg)),
            probe=lambda: _lm_probe_bundle(arch, cfg, sp, mesh, "prefill"))
    flops = TLM.lm_forward_flops(cfg, sp.batch, sp.seq_len)
    return StepBundle(f"{arch}:{sp.name}", step, (params, toks),
                      (pshard, bshard), flops, downgrades, meta)


def _lm_decode(arch, cfg: TLM.LMConfig, sp: ShapeSpec, mesh, downgrades):
    cfg = dataclasses.replace(cfg, max_seq=sp.seq_len, remat=False)
    params, axes, pshard = _abstract_params(cfg, LM_RULES, mesh, downgrades)
    cache_abs = TLM.abstract_cache(cfg, sp.batch, sp.seq_len)
    cshard = _cache_shardings(cache_abs, cfg, mesh, downgrades)
    toks = _sds((sp.batch, 1), jnp.int32)
    alpha = _sds((sp.batch,), jnp.float32)
    idx = _sds((), jnp.int32)
    bshard = _batch_sharding(mesh, toks.shape)
    ashard = _batch_sharding(mesh, (sp.batch,))
    dart = DartParams.default(cfg.n_exits)

    def step(params, tokens, cache, cache_index, alpha_state):
        exit_h, new_cache = TLM.lm_decode_step(params, tokens, cache,
                                               cache_index, cfg, mesh=mesh)
        names = [str(i) for i in cfg.exit_layers] + ["final"]
        logits = jnp.stack([TLM.exit_logits(params, cfg, h, n)
                            for n, h in zip(names, exit_h)])   # (E, B, V)
        conf = R.confidence_from_logits(logits)
        emb = jnp.take(params["embed"]["table"], tokens, axis=0)
        alpha_state = DIFF.token_difficulty_ema(alpha_state, emb)
        routed = R.route(conf, alpha_state, dart)
        preds = jnp.argmax(logits, axis=-1)
        tok = jnp.take_along_axis(preds, routed["exit_idx"][None], 0)[0]
        return tok, routed["exit_idx"], alpha_state, new_cache

    flops = TLM.lm_forward_flops(cfg, sp.batch, 1, kv_len=sp.seq_len)
    return StepBundle(f"{arch}:{sp.name}", step,
                      (params, toks, cache_abs, idx, alpha),
                      (pshard, bshard, cshard, _replicated(mesh), ashard),
                      flops, downgrades, {"kind": "decode"})


# ---------------------------------------------------------------------------
# Vision steps
# ---------------------------------------------------------------------------

def _vision_cfg_at_res(cfg, res):
    return dataclasses.replace(cfg, img_res=res)


def _vision_train(arch, cfg, sp: ShapeSpec, mesh, downgrades):
    cfg = _vision_cfg_at_res(cfg, sp.img_res)
    fam = get_family(cfg)
    params, axes, pshard = _abstract_params(cfg, LM_RULES, mesh, downgrades)
    opt = adamw(1e-4)
    opt_state = jax.eval_shape(opt.init, params)
    oshard = _opt_shardings(opt_state, pshard, mesh)
    imgs = _sds((sp.batch, sp.img_res, sp.img_res, 3), cfg.compute_dtype)
    labs = _sds((sp.batch,), jnp.int32)
    ishard = _batch_sharding(mesh, imgs.shape)
    lshard = _batch_sharding(mesh, labs.shape)

    def step(params, opt_state, images, labels):
        def loss_fn(p):
            out = fam.forward(p, images, cfg, mesh=mesh, train=True)
            loss, aux = R.multi_exit_xent(out["exit_logits"], labels)
            return loss, out.get("bn_updates", {})
        (loss, bn), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    flops = (fam.forward_flops(cfg, sp.batch) * 3
             if fam.forward_flops else 0)
    return StepBundle(f"{arch}:{sp.name}", step,
                      (params, opt_state, imgs, labs),
                      (pshard, oshard, ishard, lshard), flops, downgrades,
                      {"kind": "train"})


def _vision_serve(arch, cfg, sp: ShapeSpec, mesh, downgrades):
    cfg = _vision_cfg_at_res(cfg, sp.img_res)
    fam = get_family(cfg)
    params, axes, pshard = _abstract_params(cfg, LM_RULES, mesh, downgrades)
    imgs = _sds((sp.batch, sp.img_res, sp.img_res, 3), cfg.compute_dtype)
    ishard = _batch_sharding(mesh, imgs.shape)
    dart = DartParams.default(cfg.n_exits)

    def step(params, images):
        out = fam.forward(params, images, cfg, mesh=mesh)
        routed = R.classify_routed(out["exit_logits"], images, dart)
        return routed["pred"], routed["exit_idx"], routed["conf"]

    flops = fam.forward_flops(cfg, sp.batch) if fam.forward_flops else 0
    return StepBundle(f"{arch}:{sp.name}", step, (params, imgs),
                      (pshard, ishard), flops, downgrades, {"kind": "serve"})


# ---------------------------------------------------------------------------
# Diffusion steps
# ---------------------------------------------------------------------------

def _dit_train(arch, cfg: DIT.DiTConfig, sp: ShapeSpec, mesh, downgrades):
    cfg = dataclasses.replace(cfg, img_res=sp.img_res)
    params, axes, pshard = _abstract_params(cfg, LM_RULES, mesh, downgrades)
    opt = adamw(1e-4)
    opt_state = jax.eval_shape(opt.init, params)
    oshard = _opt_shardings(opt_state, pshard, mesh)
    lat = _sds((sp.batch, cfg.latent_res, cfg.latent_res, cfg.in_channels),
               cfg.compute_dtype)
    y = _sds((sp.batch,), jnp.int32)
    seed = _sds((), jnp.int32)

    def step(params, opt_state, x0, labels, seed):
        key = jax.random.key(seed)
        def loss_fn(p):
            return DIT.diffusion_loss(p, cfg, x0, labels, key, mesh=mesh)
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    flops = DIT.dit_forward_flops(cfg, sp.batch) * 3
    return StepBundle(f"{arch}:{sp.name}", step,
                      (params, opt_state, lat, y, seed),
                      (pshard, oshard, _batch_sharding(mesh, lat.shape),
                       _batch_sharding(mesh, y.shape), _replicated(mesh)),
                      flops, downgrades, {"kind": "train"})


def _dit_denoise(arch, cfg: DIT.DiTConfig, sp: ShapeSpec, mesh, downgrades):
    cfg = dataclasses.replace(cfg, img_res=sp.img_res, remat=False)
    params, axes, pshard = _abstract_params(cfg, LM_RULES, mesh, downgrades)
    lat = _sds((sp.batch, cfg.latent_res, cfg.latent_res, cfg.in_channels),
               cfg.compute_dtype)
    t = _sds((sp.batch,), jnp.int32)
    tp = _sds((sp.batch,), jnp.int32)
    y = _sds((sp.batch,), jnp.int32)
    dart = DartParams.default(cfg.n_exits, tau=0.9)
    lshard = _batch_sharding(mesh, lat.shape)
    vshard = _batch_sharding(mesh, t.shape)

    def step(params, xt, t, t_prev, labels):
        abar = DIT.cosine_alpha_bar()
        out = DIT.dit_forward(params, xt, t, labels, cfg, mesh=mesh)
        eps_stack = jnp.stack([e[..., :cfg.in_channels]
                               for e in out["exit_eps"]])
        routed = R.diffusion_routed(eps_stack, xt, jnp.sqrt(abar[t]), dart)
        eps_hat = routed["eps"]
        at = abar[t][:, None, None, None]
        ap = abar[t_prev][:, None, None, None]
        x0_hat = (xt - jnp.sqrt(1 - at) * eps_hat) / jnp.sqrt(at)
        x_next = jnp.sqrt(ap) * x0_hat + jnp.sqrt(1 - ap) * eps_hat
        return x_next, routed["exit_idx"]

    flops = DIT.dit_forward_flops(cfg, sp.batch)
    return StepBundle(f"{arch}:{sp.name}", step, (params, lat, t, tp, y),
                      (pshard, lshard, vshard, vshard, vshard), flops,
                      downgrades, {"kind": "denoise",
                                   "sampler_steps": sp.steps})


# ---------------------------------------------------------------------------
# Dispatcher
# ---------------------------------------------------------------------------

def build(arch: str, sp: ShapeSpec, mesh, *, reduced=False,
          variant: str = "baseline") -> StepBundle:
    """variant — §Perf hillclimbing knobs, '+'-combinable:
      baseline   : the paper-faithful default sharding
      sp         : Megatron sequence-parallel residual stream
      a2a        : token-sharded all-to-all EP MoE dispatch (implies sp)
      fsdp-dp    : pure FSDP — model axis becomes extra data parallelism
      trunc<K>   : serve only the first K layers + that exit head (the
                   DART expected-depth component for blended rooflines)
    """
    cfg = registry.get_reduced(arch) if reduced else registry.get(arch)
    fam = family_of(cfg)
    downgrades: list[Downgrade] = []
    parts = set(variant.split("+"))
    if fam == "lm":
        if "sp" in parts or "a2a" in parts:
            cfg = dataclasses.replace(cfg, act_shard="sp")
        if "a2a" in parts:
            cfg = dataclasses.replace(cfg, moe_dispatch="a2a")
        trunc = next((p for p in parts if p.startswith("trunc")), None)
        if trunc is not None:
            k = int(trunc[5:])
            exits = tuple(e for e in cfg.exit_layers if e < k - 1)
            cfg = dataclasses.replace(cfg, n_layers=k, exit_layers=exits)
        fn = {"train": _lm_train, "prefill": _lm_prefill,
              "decode": _lm_decode}[sp.kind]
    elif fam == "dit":
        trunc = next((p for p in parts if p.startswith("trunc")), None)
        if trunc is not None:
            k = int(trunc[5:])
            exits = tuple(e for e in cfg.exit_layers if e < k - 1)
            cfg = dataclasses.replace(cfg, n_layers=k, exit_layers=exits)
        fn = {"train": _dit_train, "denoise": _dit_denoise}[sp.kind]
    else:
        fn = {"train": _vision_train, "serve": _vision_serve}[sp.kind]
    bundle = fn(arch, cfg, sp, mesh, downgrades,
                fsdp_dp="fsdp-dp" in parts) \
        if fam == "lm" and sp.kind == "train" \
        else fn(arch, cfg, sp, mesh, downgrades)
    bundle.meta["variant"] = variant
    return bundle
