"""Deterministic fault injection for the serving path (ISSUE 10).

Chaos testing only earns its keep when a failure found once can be
found again: every fault here is driven by a seeded, serializable
:class:`FaultPlan` replayed through named CUT POINTS on the serving hot
path, and the injector records an injection TRACE so two runs of the
same plan over the same call sequence can be diffed for identity (the
CI determinism check in ``benchmarks/serving_chaos.py``).

Cut points (where :meth:`FaultInjector.fire` is called from):

* ``dispatch``        — a bucket is about to be routed to an engine
  (``EnginePool.call`` entry).
* ``step``            — inside one engine's compiled-step execution
  (the pool's per-engine worker, around ``engine.infer`` /
  ``infer_member`` / ``generate``).
* ``complete``        — a materialized bucket is about to resolve
  futures.
* ``checkpoint_load`` — a serving-state snapshot restore
  (``resilience.restore_snapshot`` / ``EnginePool.join``).

Fault kinds and what the pool does with the returned action:

* ``engine_death`` — raises :class:`InjectedEngineDeath` out of the cut
  point; the pool marks the engine dead and retries/requeues.
* ``straggler``    — sleeps ``delay_s`` inside the cut point; the
  pool's :class:`~repro.runtime.fault.StragglerPolicy` deadline then
  triggers a hedged re-dispatch.
* ``nan_output``   — returned as an action; the pool corrupts the
  engine output (non-finite confidence), which the output-validation
  quarantine must catch before it poisons telemetry.
* ``queue_stall``  — sleeps ``delay_s`` at the cut point WITHOUT
  marking anything unhealthy: models a wedged queue/host, visible only
  through latency.

Everything is host-side and dependency-free; the injector is
thread-safe (pool workers fire concurrently) and the NULL injector is
a no-op cheap enough to leave on the hot path.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time

import numpy as np

CUT_POINTS = ("dispatch", "step", "complete", "checkpoint_load")
KINDS = ("engine_death", "straggler", "nan_output", "queue_stall")


class InjectedFault(RuntimeError):
    """Base class for exceptions raised by the fault injector."""


class InjectedEngineDeath(InjectedFault):
    """An injected engine death: the pool must mark the engine dead,
    requeue its in-flight work and serve it elsewhere."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """ONE planned fault.

    kind:    one of :data:`KINDS`
    point:   cut point it fires at (:data:`CUT_POINTS`)
    at:      fires on the ``at``-th invocation (0-based) of that cut
             point — counted per (point, engine) when ``engine`` is
             set, per point globally when it is None
    engine:  target engine name, or None for "whichever engine hits
             the trigger count"
    delay_s: hold time for ``straggler`` / ``queue_stall``
    """
    kind: str
    point: str
    at: int
    engine: str | None = None
    delay_s: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown kind {self.kind!r}; known: {KINDS}")
        if self.point not in CUT_POINTS:
            raise ValueError(
                f"unknown cut point {self.point!r}; known: {CUT_POINTS}")
        if self.at < 0:
            raise ValueError("at must be >= 0")


class FaultPlan:
    """A replayable schedule of :class:`FaultSpec`\\ s.

    Plans are VALUE objects: build one by hand (targeted tests), via
    :meth:`generate` (seeded random schedules for the property test /
    chaos benchmark), or round-trip through :meth:`to_json` /
    :meth:`from_json`.  The same plan driven through the same sequence
    of :meth:`FaultInjector.fire` calls yields the same injections —
    that is the determinism contract CI checks.
    """

    def __init__(self, specs=()):
        self.specs = tuple(specs)

    def __iter__(self):
        return iter(self.specs)

    def __len__(self):
        return len(self.specs)

    @classmethod
    def generate(cls, seed: int, *, n_faults: int = 4,
                 engines=("e0", "e1"), kinds=KINDS,
                 points=("dispatch", "step", "complete"),
                 horizon: int = 32, max_delay_s: float = 0.05,
                 targeted_p: float = 0.75) -> "FaultPlan":
        """Seeded random plan: ``n_faults`` faults over the first
        ``horizon`` invocations of the allowed cut points.  Same seed
        (and kwargs) => same plan, always."""
        rng = np.random.RandomState(seed)
        specs = []
        for _ in range(int(n_faults)):
            kind = str(kinds[rng.randint(len(kinds))])
            point = str(points[rng.randint(len(points))])
            engine = None
            if engines and rng.random_sample() < targeted_p:
                engine = str(engines[rng.randint(len(engines))])
            specs.append(FaultSpec(
                kind=kind, point=point, at=int(rng.randint(horizon)),
                engine=engine,
                delay_s=float(rng.random_sample()) * max_delay_s
                if kind in ("straggler", "queue_stall") else 0.0))
        return cls(specs)

    # -- serialization ---------------------------------------------------
    def to_json(self) -> str:
        return json.dumps([dataclasses.asdict(s) for s in self.specs])

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        return cls([FaultSpec(**d) for d in json.loads(text)])


class FaultInjector:
    """Fires a :class:`FaultPlan` at named cut points and records what
    it did.

        inj = FaultInjector(FaultPlan.generate(seed=7))
        action = inj.fire("dispatch", engine="e0")   # None or a kind

    ``fire`` raises :class:`InjectedEngineDeath` for ``engine_death``,
    sleeps through ``straggler``/``queue_stall`` (still returning the
    kind so the caller can account for it), and returns ``nan_output``
    for the caller to apply (only the caller knows the output shape).

    ``trace`` is the replay record: one dict per injection, in firing
    order — ``benchmarks/serving_chaos.py`` replays a plan twice over a
    scripted call sequence and asserts trace identity.  Each fault in
    the plan fires at most once.
    """

    def __init__(self, plan: FaultPlan | None = None, *,
                 sleep=time.sleep, on_fire=None):
        self.plan = plan or FaultPlan()
        self._sleep = sleep
        #: optional callback(point, kind, engine) per injection, fired
        #: outside the lock (the pool wires obs counters through it)
        self.on_fire = on_fire
        self._counts: dict = {}        # (point, engine-or-None) -> calls
        self._fired: set = set()       # indices into plan.specs
        self.trace: list[dict] = []
        self._lock = threading.Lock()

    @property
    def enabled(self) -> bool:
        return bool(self.plan.specs)

    def counts(self) -> dict:
        with self._lock:
            return dict(self._counts)

    def fire(self, point: str, engine: str | None = None) -> str | None:
        """Advance the (point, engine) trigger counters and inject the
        first unfired matching fault, if any.  Returns the injected
        kind (or None); raises for ``engine_death``."""
        if point not in CUT_POINTS:
            raise ValueError(
                f"unknown cut point {point!r}; known: {CUT_POINTS}")
        delay = None
        with self._lock:
            n_global = self._counts.get((point, None), 0)
            self._counts[(point, None)] = n_global + 1
            n_engine = None
            if engine is not None:
                n_engine = self._counts.get((point, engine), 0)
                self._counts[(point, engine)] = n_engine + 1
            hit = None
            for i, s in enumerate(self.plan.specs):
                if i in self._fired or s.point != point:
                    continue
                if s.engine is None:
                    if s.at != n_global:
                        continue
                elif s.engine != engine or s.at != n_engine:
                    continue
                hit = (i, s)
                break
            if hit is None:
                return None
            i, s = hit
            self._fired.add(i)
            self.trace.append({
                "seq": len(self.trace), "point": point, "engine": engine,
                "kind": s.kind, "at": s.at, "spec": i})
            if s.kind in ("straggler", "queue_stall"):
                delay = s.delay_s
        if self.on_fire is not None:
            self.on_fire(point, s.kind, engine)
        # sleep OUTSIDE the lock: a straggler hold must not serialize
        # concurrent fire() calls from other pool workers
        if delay is not None:
            self._sleep(delay)
            return s.kind
        if s.kind == "engine_death":
            raise InjectedEngineDeath(
                f"injected engine death at {point} "
                f"(engine={engine!r}, call #{s.at})")
        return s.kind                  # nan_output: caller applies it


class NullInjector(FaultInjector):
    """The default injector: no plan, ``fire`` is a cheap no-op that
    still validates the cut-point name (typos in cut points must fail
    tests, not silently never fire)."""

    def __init__(self):
        super().__init__(FaultPlan())

    def fire(self, point: str, engine: str | None = None) -> None:
        if point not in CUT_POINTS:
            raise ValueError(
                f"unknown cut point {point!r}; known: {CUT_POINTS}")
        return None
