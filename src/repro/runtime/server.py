"""DART serving engine — stage-segmented early exit with batch compaction.

This is where early exits buy back *real* compute (DESIGN.md §4.1 mode c).
The model is split into stages at exit boundaries; after each stage the
engine:

  1. runs the stage and its exit head on the surviving (bucket-padded)
     batch,
  2. gates each sample with the Eq. 19 difficulty-adapted threshold
     (Alg. 1), using the fused exit-gate kernel,
  3. emits results for exited samples and *compacts* survivors into the
     next power-of-two bucket (bounded retraces: #stages × #buckets).

The adaptive manager (§II.C) runs inline: every request batch is recorded
into the sliding window with confidence-calibrated pseudo-correctness
(the paper's label-free deployment mode), and coefficients/UCB update
every ``update_every`` inferences.

Decisions are bit-identical to the masked-mode reference
(``core.routing.classify_routed``) for stage-wise classifiers — asserted
in tests/test_server.py.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adaptive as AD
from repro.core import difficulty as DIFF
from repro.core import thresholds as TH
from repro.core.routing import DartParams
from repro.models import get_family


def _next_bucket(n: int, buckets) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclasses.dataclass
class ServerStats:
    served: int = 0
    total_macs: float = 0.0
    total_latency_s: float = 0.0
    exit_counts: np.ndarray | None = None


class DartServer:
    def __init__(self, model_cfg, params, dart: DartParams, *,
                 cum_costs, adaptive_cfg: AD.AdaptiveConfig | None = None,
                 dcfg: DIFF.DifficultyConfig = DIFF.DEFAULT,
                 use_kernel: bool = True, buckets=None,
                 adapt: bool = True, update_every: int = 100):
        self.cfg = model_cfg
        self.params = params
        self.dart = dart
        self.dcfg = dcfg
        self.family = get_family(model_cfg)
        if not self.family.staged:
            raise ValueError("DartServer requires a staged family")
        self.n_stages = self.family.num_stages(model_cfg)
        self.cum_costs = np.asarray(cum_costs, float)
        self.use_kernel = use_kernel
        self.buckets = tuple(buckets) if buckets else tuple(
            2 ** i for i in range(0, 11))
        self.adapt = adapt
        self.update_every = update_every
        self._since_update = 0
        self.acfg = adaptive_cfg or AD.AdaptiveConfig(
            n_exits=self.n_stages, n_classes=getattr(model_cfg, "n_classes",
                                                     10))
        self.astate = AD.init_state(self.acfg)
        self.stats = ServerStats(exit_counts=np.zeros(self.n_stages, int))

        cfgc = model_cfg
        self._stem = jax.jit(lambda p, x: self.family.apply_stem(p, x, cfgc))
        self._stage = [jax.jit(partial(
            lambda p, h, s=s: self.family.apply_stage(p, h, s, cfgc)))
            for s in range(self.n_stages)]
        self._exit = [jax.jit(partial(
            lambda p, h, s=s: self.family.apply_exit(p, h, s, cfgc)))
            for s in range(self.n_stages)]
        self._alpha = jax.jit(lambda x: DIFF.image_difficulty(x, self.dcfg))

    # ------------------------------------------------------------------
    def _gate(self, logits, eff_thresh):
        if self.use_kernel:
            from repro.kernels.exit_gate import ops as gops
            conf, ent, pred, fire = gops.exit_gate(
                logits, jnp.asarray(eff_thresh, jnp.float32))
            return conf, pred, fire.astype(bool)
        p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        conf = jnp.max(p, axis=-1)
        pred = jnp.argmax(logits, axis=-1)
        return conf, pred, conf > eff_thresh

    def _coef_for(self, n):
        c = AD.effective_coef(self.astate, self.acfg) if self.adapt \
            else jnp.asarray(self.dart.coef)
        return c

    # ------------------------------------------------------------------
    def infer_batch(self, images: np.ndarray) -> dict:
        """Serve one request batch.  Returns per-sample results + metering."""
        t0 = time.time()
        b = images.shape[0]
        images = jnp.asarray(images)
        alpha = np.asarray(self._alpha(images))

        out_pred = np.zeros(b, np.int64)
        out_conf = np.zeros(b, np.float32)
        out_exit = np.zeros(b, np.int64)

        coef = np.asarray(self._coef_for(b), np.float32)
        tau = np.asarray(self.dart.tau, np.float32)

        h = self._stem(self.params, images)
        active = np.arange(b)
        h_active = h
        alpha_active = alpha
        for s in range(self.n_stages):
            n = len(active)
            bucket = _next_bucket(n, self.buckets)
            pad = bucket - n
            h_pad = jnp.concatenate(
                [h_active, jnp.zeros((pad,) + h_active.shape[1:],
                                     h_active.dtype)]) if pad else h_active
            h_pad = self._stage[s](self.params, h_pad)
            logits = self._exit[s](self.params, h_pad)
            if s < self.n_stages - 1:
                eff = np.clip(coef[s] * tau[s]
                              + self.dart.beta_diff * alpha_active, 0.0, 1.0)
                eff_pad = np.concatenate([eff, np.full(pad, 2.0)]) if pad \
                    else eff
                conf, pred, fire = self._gate(logits, eff_pad)
                fire = np.asarray(fire[:n])
            else:
                conf, pred, _ = self._gate(
                    logits, jnp.zeros(bucket, jnp.float32))
                fire = np.ones(n, bool)
            conf = np.asarray(conf[:n])
            pred = np.asarray(pred[:n])

            done = active[fire]
            out_pred[done] = pred[fire]
            out_conf[done] = conf[fire]
            out_exit[done] = s
            self.stats.exit_counts[s] += int(fire.sum())
            keep = ~fire
            if not keep.any():
                break
            survivors = jnp.asarray(np.nonzero(keep)[0])
            h_active = jnp.take(h_pad[:n], survivors, axis=0)
            alpha_active = alpha_active[keep]
            active = active[keep]

        macs = self.cum_costs[out_exit]
        latency = time.time() - t0
        self.stats.served += b
        self.stats.total_macs += float(macs.sum())
        self.stats.total_latency_s += latency

        if self.adapt:
            # confidence-calibrated pseudo-correctness (paper §II.C.1)
            self.astate = AD.record_batch(
                self.astate, self.acfg, jnp.asarray(out_exit),
                jnp.asarray(out_pred % self.acfg.n_classes),
                jnp.asarray(out_conf), jnp.asarray(out_conf),
                jnp.asarray(macs / self.cum_costs[-1]))
            self._since_update += b
            if self._since_update >= self.update_every:
                self.astate = AD.periodic_update(self.astate, self.acfg,
                                                 beta_opt=self.dart.beta_opt)
                self._since_update = 0

        return {"pred": out_pred, "conf": out_conf, "exit_idx": out_exit,
                "alpha": alpha, "macs": macs, "latency_s": latency}

    # ------------------------------------------------------------------
    def masked_reference(self, images: np.ndarray) -> dict:
        """Masked-mode forward (all exits) for equivalence testing."""
        from repro.core.routing import classify_routed
        out = self.family.forward(self.params, jnp.asarray(images), self.cfg)
        coef = self._coef_for(images.shape[0])
        dart = DartParams(tau=self.dart.tau, coef=coef,
                          beta_diff=self.dart.beta_diff,
                          beta_opt=self.dart.beta_opt)
        return classify_routed(out["exit_logits"], jnp.asarray(images), dart,
                               self.dcfg)
