"""DartServer — legacy entry point, now a thin shim over
:class:`repro.engine.DartEngine`.

The stage-segmented serving loop, bucket compaction, adaptive updates
and metering all live in ``repro.engine`` (engine.py / compactor.py /
state.py); this module keeps the original constructor and method
signatures working so existing callers don't break.

New code should use the engine API directly:

    from repro.engine import DartEngine
    engine = DartEngine.from_config(cfg, params, cum_costs=...)
    out = engine.infer(x, mode="compacted")

Removal timeline (README "Deprecations"): deprecated since PR 1,
scheduled for removal in PR 4 — port callers to ``repro.engine``.
The sharded serving path (``DartEngine.from_config(..., mesh=...)``)
is engine-only and has no shim.
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from repro.core import adaptive as AD
from repro.core import difficulty as DIFF
from repro.core.routing import DartParams
from repro.engine import BatchCompactor, DartEngine


def _next_bucket(n: int, buckets) -> int:
    """Smallest bucket ≥ n.  Raises ``BatchTooLarge`` when ``n`` exceeds
    the largest bucket (the old behaviour silently clamped, producing a
    negative pad that corrupted ``infer_batch``; oversized batches are
    now split by the engine via ``BatchCompactor.chunks``)."""
    return BatchCompactor(buckets).bucket_for(n)


@dataclasses.dataclass
class ServerStats:
    served: int = 0
    total_macs: float = 0.0
    total_latency_s: float = 0.0
    exit_counts: np.ndarray | None = None


class DartServer:
    """Deprecated: delegate to :class:`repro.engine.DartEngine`."""

    def __init__(self, model_cfg, params, dart: DartParams, *,
                 cum_costs, adaptive_cfg: AD.AdaptiveConfig | None = None,
                 dcfg: DIFF.DifficultyConfig = DIFF.DEFAULT,
                 use_kernel: bool = True, buckets=None,
                 adapt: bool = True, update_every: int = 100):
        warnings.warn(
            "repro.runtime.server.DartServer is deprecated and will be "
            "removed in PR 4; use repro.engine.DartEngine (or "
            "repro.serving.AsyncDartServer for async serving) instead",
            DeprecationWarning, stacklevel=2)
        self.engine = DartEngine.from_config(
            model_cfg, params, dart=dart, adaptive_cfg=adaptive_cfg,
            dcfg=dcfg, cum_costs=cum_costs, buckets=buckets,
            use_kernel=use_kernel, adapt=adapt, update_every=update_every)
        if not self.engine.family.staged:
            raise ValueError("DartServer requires a staged family")
        self.cfg = model_cfg
        self.params = params

    # -- legacy surface -------------------------------------------------
    @property
    def dart(self) -> DartParams:
        return self.engine.state.dart

    @property
    def n_stages(self) -> int:
        return self.engine.n_exits

    @property
    def acfg(self) -> AD.AdaptiveConfig:
        return self.engine.acfg

    @property
    def astate(self):
        return self.engine.state.adaptive

    @property
    def stats(self) -> ServerStats:
        s = self.engine.state
        return ServerStats(
            served=int(s.served),
            total_macs=float(s.total_macs),
            total_latency_s=self.engine.total_latency_s,
            exit_counts=np.asarray(s.exit_counts))

    def infer_batch(self, images: np.ndarray) -> dict:
        """Serve one request batch (compacted mode)."""
        return self.engine.infer(images, mode="compacted")

    def masked_reference(self, images: np.ndarray) -> dict:
        """Masked-mode forward (all exits) for equivalence testing."""
        return self.engine.infer(images, mode="masked")
