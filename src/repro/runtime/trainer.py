"""Distributed training loop with the paper's multi-exit objective.

One Trainer serves every architecture family:
* classifiers — Eq. 18 multi-exit cross-entropy (+ BN stats merging)
* LMs         — Eq. 18 with chunked-vocab CE (+ MoE aux, + MTP)
* diffusion   — Eq. 18 with per-exit ε-MSE

Production features: sharded params/optimizer via logical-axis rules,
microbatch gradient accumulation, gradient compression hooks (pod axis),
async checkpointing, deterministic restart (stateless data seeding), and
the fault hooks used by ``repro.runtime.fault``.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt_lib
from repro.core import routing as R
from repro.data.datasets import DatasetConfig
from repro.data.pipeline import DataPipeline
from repro.models import get_family, family_of
from repro.models import batchnorm as BN
from repro.models.transformer_lm import lm_multi_exit_loss
from repro.models.dit import diffusion_loss
from repro.optim import (adamw, sgd, warmup_cosine, trainable_mask,
                         GradAccumulator)
from repro.parallel.sharding import (unzip, tree_shardings, LM_RULES,
                                     with_fsdp, Downgrade)
from repro.parallel.compression import (CompressionConfig, compress_grads,
                                        init_error_feedback)


@dataclasses.dataclass
class TrainConfig:
    batch_size: int = 32
    steps: int = 200
    lr: float = 1e-3
    warmup: int = 20
    optimizer: str = "adamw"
    weight_decay: float = 0.01
    max_grad_norm: float = 1.0
    microbatches: int = 1
    seed: int = 0
    ckpt_dir: str | None = None
    ckpt_every: int = 100
    log_every: int = 20
    fsdp: bool = False
    compression: CompressionConfig = dataclasses.field(
        default_factory=CompressionConfig)
    policy_weight: float = 0.01


class Trainer:
    def __init__(self, model_cfg, train_cfg: TrainConfig,
                 data_cfg: DatasetConfig | None = None, *, mesh=None,
                 data_kind: str | None = None):
        self.model_cfg = model_cfg
        self.cfg = train_cfg
        self.mesh = mesh
        self.family_name = family_of(model_cfg)
        self.family = get_family(model_cfg)
        self.data_cfg = data_cfg or DatasetConfig()
        self.data_kind = data_kind
        self.downgrades: list[Downgrade] = []

        key = jax.random.key(train_cfg.seed)
        ptree = self.family.init(key, model_cfg)
        self.params, self.axes = unzip(ptree)
        rules = with_fsdp(LM_RULES) if train_cfg.fsdp else LM_RULES
        if mesh is not None:
            self.param_shardings = tree_shardings(
                self.axes, self.params, rules, mesh, self.downgrades)
            self.params = jax.tree.map(jax.device_put, self.params,
                                       self.param_shardings)
        else:
            self.param_shardings = None

        mask = trainable_mask(self.axes)
        schedule = warmup_cosine(train_cfg.lr, train_cfg.warmup,
                                 train_cfg.steps)
        if train_cfg.optimizer == "adamw":
            self.opt = adamw(schedule, weight_decay=train_cfg.weight_decay,
                             max_grad_norm=train_cfg.max_grad_norm,
                             mask=mask)
        else:
            self.opt = sgd(schedule, max_grad_norm=train_cfg.max_grad_norm,
                           mask=mask)
        self.opt_state = self.opt.init(self.params)
        self.ef_state = (init_error_feedback(self.params)
                         if train_cfg.compression.scheme != "none" else None)
        self.step = 0
        self.manager = (ckpt_lib.CheckpointManager(
            train_cfg.ckpt_dir, save_every=train_cfg.ckpt_every)
            if train_cfg.ckpt_dir else None)
        self._train_step = self._build_step()
        self.history: list[dict] = []

    # -- loss per family ---------------------------------------------------
    def _loss_fn(self, params, batch, rng):
        x, y = batch
        cfg = self.model_cfg
        if self.family_name == "lm":
            return lm_multi_exit_loss(params, x, y, cfg, mesh=self.mesh,
                                      policy_weight=self.cfg.policy_weight)
        if self.family_name == "dit":
            return diffusion_loss(params, cfg, x, y, rng, mesh=self.mesh)
        out = self.family.forward(params, x, cfg, mesh=self.mesh, train=True)
        loss, aux = R.multi_exit_xent(out["exit_logits"], y,
                                      policy_weight=self.cfg.policy_weight)
        aux["bn_updates"] = out.get("bn_updates", {})
        return loss, aux

    def _build_step(self):
        acc = GradAccumulator(self.cfg.microbatches)

        def step_fn(params, opt_state, ef_state, batch, rng):
            if self.cfg.microbatches > 1:
                loss, grads, aux = acc.accumulate(
                    lambda p, b: self._loss_fn(p, b, rng), params, batch)
            else:
                (loss, aux), grads = jax.value_and_grad(
                    self._loss_fn, has_aux=True)(params, batch, rng)
            if ef_state is not None:
                grads, ef_state, _ = compress_grads(
                    grads, ef_state, self.cfg.compression)
            new_params, opt_state = self.opt.update(grads, opt_state, params)
            bn_updates = aux.pop("bn_updates", {}) if isinstance(aux, dict) \
                else {}
            return new_params, opt_state, ef_state, loss, bn_updates

        donate = (0, 1)
        return jax.jit(step_fn, donate_argnums=donate)

    # -- LM labels are shifted inputs --------------------------------------
    def _prepare(self, x, y):
        if self.family_name == "lm":
            inputs = x[:, :-1]
            labels = x[:, 1:]
            return inputs, labels
        return x, y

    def train_step(self, batch, rng=None):
        rng = rng if rng is not None else jax.random.key(
            self.cfg.seed * 1000003 + self.step)
        x, y = self._prepare(*batch)
        (self.params, self.opt_state, self.ef_state, loss,
         bn_updates) = self._train_step(self.params, self.opt_state,
                                        self.ef_state, (x, y), rng)
        if bn_updates:
            self.params = BN.merge_updates(self.params, bn_updates)
        self.step += 1
        return float(loss)

    def run(self, steps: int | None = None, pipeline: DataPipeline | None = None):
        steps = steps or self.cfg.steps
        own_pipe = pipeline is None
        seq_len = getattr(self.model_cfg, "max_seq", None)
        vocab = getattr(self.model_cfg, "vocab", None)
        if own_pipe:
            pipeline = DataPipeline(
                self.data_cfg, self.cfg.batch_size, kind=self.data_kind,
                seq_len=None if seq_len is None else seq_len + 1,
                vocab=vocab, mesh=self.mesh, start_step=self.step)
        t0 = time.time()
        try:
            while self.step < steps:
                _, x, y = next(pipeline)
                loss = self.train_step((x, y))
                if self.step % self.cfg.log_every == 0 or self.step == steps:
                    rec = {"step": self.step, "loss": loss,
                           "elapsed_s": time.time() - t0}
                    self.history.append(rec)
                if self.manager:
                    self.manager.maybe_save(self.step, self.state_tree(),
                                            extra={"loss": loss})
        finally:
            if own_pipe:
                pipeline.close()
            if self.manager:
                self.manager.maybe_save(self.step, self.state_tree(),
                                        extra={}, force=True)
                self.manager.wait()
        return self.history

    # -- checkpoint plumbing -------------------------------------------------
    def state_tree(self):
        return {"params": self.params, "opt": self.opt_state,
                "step": jnp.asarray(self.step)}

    def restore(self, path=None):
        mgr = self.manager if path is None else ckpt_lib.CheckpointManager(path)
        got = mgr.restore_or_none(self.state_tree())
        if got is None:
            return False
        tree, step, _ = got
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.step = int(tree["step"])
        if self.param_shardings is not None:
            self.params = jax.tree.map(jax.device_put, self.params,
                                       self.param_shardings)
        return True
