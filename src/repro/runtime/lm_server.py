"""LMDecodeServer — legacy entry point, now a thin alias of
:class:`repro.engine.lm.LMDecodeEngine`.

The early-exit decode loop (real layer skipping + CALM-style KV
propagation + bucketed survivor compaction) lives in
``repro.engine.lm``; this module keeps the original import path
working.  New code should use::

    from repro.engine import LMDecodeEngine

Removal timeline (README "Deprecations"): deprecated since PR 1,
scheduled for removal in PR 4 — port imports to ``repro.engine``.
"""
from __future__ import annotations

from repro.engine.lm import LMDecodeEngine


class LMDecodeServer(LMDecodeEngine):
    """Deprecated: use :class:`repro.engine.LMDecodeEngine`."""
