"""LMDecodeServer — legacy entry point, now a thin alias of
:class:`repro.engine.lm.LMDecodeEngine`.

The early-exit decode loop (real layer skipping + CALM-style KV
propagation + bucketed survivor compaction) lives in
``repro.engine.lm``; this module keeps the original import path
working.  New code should use::

    from repro.engine import LMDecodeEngine

Removal timeline (README "Deprecations"): deprecated since PR 1,
scheduled for removal in PR 4 — port imports to ``repro.engine``.
"""
from __future__ import annotations

import warnings

from repro.engine.lm import LMDecodeEngine


class LMDecodeServer(LMDecodeEngine):
    """Deprecated: use :class:`repro.engine.LMDecodeEngine`."""

    def __init__(self, *a, **kw):
        warnings.warn(
            "repro.runtime.lm_server.LMDecodeServer is deprecated and "
            "will be removed in PR 4; use repro.engine.LMDecodeEngine "
            "(and .session() for queue-backed decoding) instead",
            DeprecationWarning, stacklevel=2)
        super().__init__(*a, **kw)
