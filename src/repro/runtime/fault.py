"""Fault tolerance: heartbeats, checkpoint-restart, straggler mitigation,
elastic re-meshing.

This container has one host, so cluster behaviour is exercised through a
faithful single-process simulation (threads = workers) of the control
plane; the *data plane* mechanisms (atomic checkpoints, stateless data
seeding, mesh-elastic restore) are the real implementations and are what
a multi-host deployment would run unchanged:

* **HeartbeatMonitor** — workers tick; a missed deadline marks the worker
  dead and fires the recovery callback (on a real pod: the coordinator
  initiates job restart from the last checkpoint).
* **checkpoint-restart** — ``Trainer`` checkpoints are atomic and carry
  the step; ``resume`` rebuilds a Trainer (possibly on a *different*
  mesh) and restores — the stateless data pipeline then replays the
  exact batch sequence from that step (no skipped/duplicated data).
* **straggler mitigation** — per-step deadline; a slow worker's shard is
  re-assigned by re-slicing the (stateless) batch indices across the
  remaining workers, i.e. backup-worker semantics without data loss.
* **elastic scaling** — restore onto a mesh with a different device
  count; parameter shardings are recomputed from the same logical-axis
  rules, so any pod count that divides the dims works.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable

import numpy as np

from repro.runtime.trainer import Trainer, TrainConfig


# ---------------------------------------------------------------------------
# Heartbeats
# ---------------------------------------------------------------------------

class HeartbeatMonitor:
    """Deadline-based liveness with elastic membership.

    ``on_failure`` callbacks fire OUTSIDE the internal lock: a callback
    is allowed to call ``beat``/``add_worker``/``remove_worker`` (a
    recovery path that re-registers a replacement worker does exactly
    that) without deadlocking the watch thread.
    """

    def __init__(self, workers: list[str], timeout_s: float = 1.0,
                 on_failure: Callable[[str], None] | None = None):
        self.timeout_s = timeout_s
        self.on_failure = on_failure
        self.last = {w: time.monotonic() for w in workers}
        self.dead: set[str] = set()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._watch, daemon=True)
        self._thread.start()

    def beat(self, worker: str):
        with self._lock:
            self.last[worker] = time.monotonic()

    def add_worker(self, worker: str):
        """(Re-)register a worker: fresh deadline, cleared death mark."""
        with self._lock:
            self.last[worker] = time.monotonic()
            self.dead.discard(worker)

    def remove_worker(self, worker: str):
        """Deregister a worker (drained/decommissioned — not a failure:
        no callback fires and it is not marked dead)."""
        with self._lock:
            self.last.pop(worker, None)
            self.dead.discard(worker)

    def workers(self) -> list[str]:
        with self._lock:
            return list(self.last)

    def _watch(self):
        while not self._stop.is_set():
            now = time.monotonic()
            newly_dead = []
            with self._lock:
                for w, t in self.last.items():
                    if w not in self.dead and now - t > self.timeout_s:
                        self.dead.add(w)
                        newly_dead.append(w)
            # callbacks outside the lock: they may beat/re-register
            for w in newly_dead:
                if self.on_failure:
                    self.on_failure(w)
            time.sleep(self.timeout_s / 4)

    def close(self):
        self._stop.set()
        self._thread.join(timeout=1.0)


# ---------------------------------------------------------------------------
# Straggler mitigation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardPlan:
    """Assignment of batch index ranges to workers for one step."""
    assignments: dict[str, np.ndarray]

    @staticmethod
    def even(workers: list[str], indices: np.ndarray) -> "ShardPlan":
        parts = np.array_split(indices, len(workers))
        return ShardPlan(dict(zip(workers, parts)))

    def reassign(self, straggler: str) -> "ShardPlan":
        """Re-slice the straggler's shard across the healthy workers.
        Because batches are stateless-seeded, this loses no data."""
        healthy = [w for w in self.assignments if w != straggler]
        orphan = self.assignments[straggler]
        parts = np.array_split(orphan, len(healthy))
        new = {w: self.assignments[w] for w in healthy}
        for w, extra in zip(healthy, parts):
            new[w] = np.concatenate([new[w], extra])
        return ShardPlan(new)


class StragglerPolicy:
    """Deadline-based detection over a rolling step-time estimate."""

    def __init__(self, factor: float = 3.0, window: int = 20):
        self.factor = factor
        self.times: list[float] = []
        self.window = window

    def deadline(self) -> float:
        if not self.times:
            return float("inf")
        return self.factor * float(np.median(self.times[-self.window:]))

    def record(self, dt: float):
        self.times.append(dt)

    def is_straggling(self, dt: float) -> bool:
        return dt > self.deadline()


# ---------------------------------------------------------------------------
# Elastic checkpoint-restart
# ---------------------------------------------------------------------------

def resume(model_cfg, train_cfg: TrainConfig, *, mesh=None,
           data_cfg=None, data_kind=None) -> Trainer:
    """Rebuild a Trainer (possibly on a different mesh) and restore the
    latest checkpoint if one exists."""
    t = Trainer(model_cfg, train_cfg, data_cfg, mesh=mesh,
                data_kind=data_kind)
    t.restore()
    return t


def simulate_failure_and_recover(model_cfg, train_cfg: TrainConfig, *,
                                 fail_at: int, total_steps: int,
                                 data_cfg=None, data_kind=None,
                                 new_mesh=None):
    """Train → kill at ``fail_at`` → restart (optionally on a new mesh) →
    finish.  Returns (losses_before, losses_after, trainer)."""
    t1 = Trainer(model_cfg, train_cfg, data_cfg, data_kind=data_kind)
    t1.run(steps=fail_at)
    t1.manager.wait()
    before = list(t1.history)
    del t1                                   # the "crash"

    t2 = resume(model_cfg, train_cfg, mesh=new_mesh, data_cfg=data_cfg,
                data_kind=data_kind)
    assert t2.step == fail_at or t2.step % train_cfg.ckpt_every == 0, \
        f"resumed at {t2.step}"
    t2.run(steps=total_steps)
    return before, t2.history, t2
