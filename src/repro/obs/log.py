"""repro.obs.log — structured logging for scheduler/dispatcher threads.

The dispatcher threads used to fail futures SILENTLY (`_dispatch_safe`
set ``last_error`` and moved on); a production operator only found out
when a caller's ``fut.result()`` raised.  Every such path now routes
through :func:`error`:

* a ``key=value`` structured log line (request ids, lane, exception)
  on the ``repro.obs.<component>`` logger, and
* an increment of the ``dart_errors_total{component}`` counter in the
  global registry — alertable, unlike a buried attribute.

No handler is installed here: with nothing configured, Python's
last-resort handler prints WARNING+ to stderr, and an application that
configures ``logging`` owns the routing.  ``error`` never raises —
it runs inside except blocks on daemon threads.
"""
from __future__ import annotations

import logging

__all__ = ["get_logger", "error", "event"]

_BASE = "repro.obs"


def get_logger(component: str = "") -> logging.Logger:
    name = f"{_BASE}.{component}" if component else _BASE
    return logging.getLogger(name)


def _kv(fields: dict) -> str:
    return " ".join(f"{k}={v!r}" for k, v in fields.items())


def event(component: str, msg: str, level: int = logging.INFO,
          **fields) -> None:
    """Structured (key=value) log line on ``repro.obs.<component>``."""
    try:
        get_logger(component).log(level, "%s %s", msg, _kv(fields))
    except Exception:                              # noqa: BLE001
        pass


def error(component: str, msg: str, *, exc: BaseException | None = None,
          **fields) -> None:
    """Structured error + ``dart_errors_total{component}`` increment.
    Always counts (error paths are cold — the zero-cost-when-disabled
    budget is about the request hot path)."""
    try:
        from repro.obs import OBS
        OBS.registry.counter(
            "dart_errors_total",
            "scheduler/dispatcher errors by component",
            ("component",)).inc(1, component=component)
        get_logger(component).error("%s %s", msg, _kv(fields),
                                    exc_info=exc)
    except Exception:                              # noqa: BLE001
        pass
