"""repro.obs.trace — lock-light per-request span recorder.

Spans follow a request through the scheduler lifecycle::

    admit -> queue_wait -> bucket|slot -> compiled_step -> exit
                                                         | escalate
                                                         | shed / reject

carrying difficulty class (lane), predicted vs realized exit depth,
cascade member, slot ids and deadline slack.  Spans are recorded
HOST-SIDE only, from scheduler/session code — never inside jitted step
functions: device telemetry keeps flowing through the ``EngineState``
fold, and the tracer is *joined* against it after the ``stats()``
reduction (the reconciliation test pins span exits == telemetry exit
histogram).

The ring is a ``collections.deque(maxlen=capacity)``: append is O(1),
overflow drops the OLDEST span, and CPython's deque append is atomic
under the GIL so the record path takes no lock (the ``dropped`` counter
is therefore approximate under contention — by design; it is a gauge of
pressure, not an audit log).

Export: JSONL (one span per line) and Chrome ``trace_event`` JSON via
:func:`chrome_trace` — ``tools/trace_view.py`` converts a JSONL dump
into a file Perfetto / ``chrome://tracing`` loads directly.
"""
from __future__ import annotations

import json
from collections import deque

__all__ = ["Tracer", "chrome_trace", "load_jsonl"]

#: canonical span names (informational; the tracer accepts any name)
SPAN_NAMES = ("admit", "queue_wait", "bucket", "slot", "compiled_step",
              "exit", "escalate", "shed", "reject")


def _jsonable(v):
    if hasattr(v, "item") and getattr(v, "ndim", None) == 0:
        return v.item()
    if hasattr(v, "tolist"):
        return v.tolist()
    if isinstance(v, tuple):
        return list(v)
    return str(v)


class Tracer:
    """Bounded span ring.  ``record`` is the only hot-path method; it
    builds one dict and appends — no locks, no syncs, no I/O."""

    def __init__(self, capacity: int = 16384):
        self.capacity = int(capacity)
        self._ring: deque = deque(maxlen=max(self.capacity, 1))
        self.dropped = 0

    def record(self, name: str, *, ts: float, dur: float = 0.0,
               rid=None, lane=None, **attrs) -> None:
        """One span: ``ts``/``dur`` in scheduler-clock seconds."""
        if self.capacity <= 0:
            return
        if len(self._ring) == self._ring.maxlen:
            self.dropped += 1                      # approximate, lock-free
        span = {"name": name, "ts": ts, "dur": dur}
        if rid is not None:
            span["rid"] = rid
        if lane is not None:
            span["lane"] = lane
        if attrs:
            span.update(attrs)
        self._ring.append(span)

    def __len__(self) -> int:
        return len(self._ring)

    def spans(self, name: str | None = None) -> list:
        """Snapshot (oldest first), optionally filtered by span name."""
        out = list(self._ring)
        if name is not None:
            out = [s for s in out if s["name"] == name]
        return out

    def clear(self) -> None:
        self._ring.clear()
        self.dropped = 0

    def export_jsonl(self, path: str) -> int:
        """Write one span per line; returns the number written."""
        spans = self.spans()
        with open(path, "w") as f:
            for s in spans:
                f.write(json.dumps(s, default=_jsonable) + "\n")
        return len(spans)


def load_jsonl(path: str) -> list:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def chrome_trace(spans) -> dict:
    """Chrome ``trace_event`` JSON (the object format Perfetto and
    ``chrome://tracing`` load).  Each lane becomes a named thread;
    span attrs ride along in ``args``."""
    tids: dict = {}
    events = []
    for s in spans:
        lane = s.get("lane", "-")
        key = repr(lane)
        tid = tids.get(key)
        if tid is None:
            tid = tids[key] = len(tids) + 1
            events.append({"ph": "M", "pid": 0, "tid": tid,
                           "name": "thread_name",
                           "args": {"name": f"lane {key}"}})
        args = {k: _jsonable(v) if not isinstance(
                    v, (int, float, str, bool, type(None))) else v
                for k, v in s.items() if k not in ("name", "ts", "dur")}
        events.append({"name": s["name"], "ph": "X", "pid": 0, "tid": tid,
                       "ts": float(s["ts"]) * 1e6,
                       "dur": max(float(s.get("dur", 0.0)), 0.0) * 1e6,
                       "args": args})
    return {"traceEvents": events, "displayTimeUnit": "ms"}
