"""repro.obs.adapters — wire every existing serving signal into the
registry and the tracer.

Two kinds of adapter, matching the two ways data flows:

* **push-side** ``record_*`` helpers, called from the scheduler /
  session hot path ONLY behind an ``if OBS.enabled`` check.  They see
  values the serving code already materialized (numpy outputs at
  completion, host counters) — no extra device syncs.
* **pull-side** ``bind_*`` collectors, registered once per object and
  run at SCRAPE time: ``EngineState`` telemetry after the
  ``reduce_telemetry`` fold, per-lane DAES from
  ``LaneDaesAccumulator``, ``trace_counts`` (a recompile in production
  becomes the alertable ``dart_recompiles_total``), kernel dispatch
  decisions from ``repro.kernels.dispatch``, queue depths / starvation
  reservations from ``RequestQueue``, and slot-pool / page-allocator
  occupancy from the continuous decoder.  Collectors hold weakrefs, so
  a garbage-collected server unregisters itself.

Metric catalog: see docs/observability.md.
"""
from __future__ import annotations

import time
import weakref

import numpy as np

from repro.obs import OBS
from repro.obs.metrics import LATENCY_BUCKETS_MS

__all__ = ["record_admit", "record_bucket", "record_completed",
           "record_escalations", "record_lm_bucket", "record_slot_admit",
           "record_slot_exit", "record_retry", "record_hedge",
           "record_requeue", "record_fault", "bind_scheduler",
           "bind_dispatch", "bind_pool"]


def _lane(lane) -> str:
    return str(lane)


def _latency_hist(reg):
    return reg.histogram("dart_request_latency_ms",
                         "end-to-end request latency by lane",
                         ("lane",), buckets=LATENCY_BUCKETS_MS)


# ---------------------------------------------------------------------------
# push side (hot path; callers guard with OBS.enabled)
# ---------------------------------------------------------------------------

def record_admit(sched, req, action: str, t0: float, t1: float) -> None:
    """One admitted (or dropped-at-admission) request: the ``admit``
    span covers the admission work itself (the Eq. 8 estimate)."""
    lane = _lane(req.lane)
    alpha = float(np.mean(req.alpha)) if req.n else 0.0
    OBS.tracer.record("admit", ts=t0, dur=t1 - t0, rid=req.rid,
                      lane=req.lane, n=req.n, alpha=alpha,
                      predicted_cost=float(req.predicted_cost),
                      priority=req.priority, action=action)
    reg = OBS.registry
    reg.counter("dart_requests_total", "requests submitted by lane",
                ("lane",)).inc(1, lane=lane)
    if action in ("shed", "rejected"):
        OBS.tracer.record("shed" if action == "shed" else "reject",
                          ts=t1, rid=req.rid, lane=req.lane, n=req.n)
        reg.counter("dart_requests_dropped_total",
                    "requests dropped at admission (backpressure)",
                    ("lane", "action")).inc(1, lane=lane, action=action)


def record_bucket(sched, reqs: list, reason: str, now: float) -> None:
    """One flushed bucket: which lane, how many requests/samples, and
    WHY it flushed (deadline pressure / size / hold / forced)."""
    OBS.tracer.record("bucket", ts=now, lane=reqs[0].lane,
                      n_requests=len(reqs),
                      n_samples=sum(r.n for r in reqs), reason=reason)
    OBS.registry.counter("dart_flushes_total", "bucket flushes by reason",
                         ("reason",)).inc(1, reason=reason)


def record_completed(server, reqs: list, results: list, t_dispatch: float,
                     now: float) -> None:
    """Completed requests of one materialized bucket: spans
    ``queue_wait`` (submit -> dispatch) and ``compiled_step``
    (dispatch -> materialized), plus the ``exit`` span joining the
    host-side view (predicted cost, deadline slack) with the realized
    exit depths the engine computed."""
    reg, tr = OBS.registry, OBS.tracer
    hist = _latency_hist(reg)
    comp = reg.counter("dart_requests_completed_total",
                       "requests completed by lane", ("lane",))
    miss_c = reg.counter("dart_deadline_miss_total",
                         "deadline misses by lane", ("lane",))
    exits = reg.counter("dart_exits_total",
                        "served samples by cascade member and exit stage",
                        ("member", "stage"))
    for r, res in zip(reqs, results):
        lane = _lane(r.lane)
        exit_idx = np.asarray(res["exit_idx"]).ravel()
        members = np.asarray(res["member"]).ravel() \
            if "member" in res else np.zeros(exit_idx.shape, np.int64)
        slack = None if r.deadline_s is None else r.deadline_s - now
        tr.record("queue_wait", ts=r.t_submit,
                  dur=max(t_dispatch - r.t_submit, 0.0),
                  rid=r.rid, lane=r.lane)
        tr.record("compiled_step", ts=t_dispatch,
                  dur=max(now - t_dispatch, 0.0), rid=r.rid, lane=r.lane,
                  n=r.n)
        tr.record("exit", ts=now, rid=r.rid, lane=r.lane,
                  exits=exit_idx.tolist(), members=members.tolist(),
                  predicted_cost=float(r.predicted_cost),
                  realized_cost=float(np.mean(np.asarray(res["macs"]))),
                  deadline_slack_s=slack,
                  deadline_missed=bool(res["deadline_missed"]))
        hist.observe(float(res["latency_ms"]), lane=lane)
        comp.inc(1, lane=lane)
        if res["deadline_missed"]:
            miss_c.inc(1, lane=lane)
        for m in np.unique(members):
            sel = members == m
            for s in np.unique(exit_idx[sel]):
                exits.inc(int(np.sum(exit_idx[sel] == s)),
                          member=str(int(m)), stage=str(int(s)))


def record_escalations(member: int, continuations: list,
                       now: float) -> None:
    """Cascade escalations re-enqueued into the next member's lanes.
    ``continuations``: (root, idx, x, alpha, next_member) tuples, as
    assembled by ``CascadeAsyncServer._complete``."""
    esc = OBS.registry.counter(
        "dart_escalations_total",
        "samples escalated past a cascade boundary", ("member",))
    for root, idx, x, a_esc, nxt in continuations:
        n = int(x.shape[0])
        OBS.tracer.record("escalate", ts=now, rid=root.rid,
                          lane=root.lane, n=n, member=member,
                          to_member=int(nxt),
                          alpha=float(np.mean(a_esc)) if n else 0.0)
        esc.inc(n, member=str(member))


def record_lm_bucket(session, reqs: list, stage_slices: list, t0: float,
                     now: float) -> None:
    """One flushed LM decode bucket: per-request spans with realized
    per-token exit stages."""
    reg, tr = OBS.registry, OBS.tracer
    hist = _latency_hist(reg)
    comp = reg.counter("dart_requests_completed_total",
                       "requests completed by lane", ("lane",))
    toks = reg.counter("dart_lm_tokens_total", "decoded tokens", ())
    for r, stages in zip(reqs, stage_slices):
        lane = _lane(r.lane)
        stages = np.asarray(stages)
        tr.record("queue_wait", ts=r.t_submit,
                  dur=max(t0 - r.t_submit, 0.0), rid=r.rid, lane=r.lane)
        tr.record("compiled_step", ts=t0, dur=max(now - t0, 0.0),
                  rid=r.rid, lane=r.lane, n=r.n)
        tr.record("exit", ts=now, rid=r.rid, lane=r.lane,
                  exits=stages.ravel().tolist(),
                  n_tokens=int(stages.size),
                  predicted_cost=float(r.predicted_cost),
                  deadline_slack_s=None if r.deadline_s is None
                  else r.deadline_s - now)
        hist.observe((now - r.t_submit) * 1e3, lane=lane)
        comp.inc(1, lane=lane)
        toks.inc(int(stages.size))


def record_slot_admit(session, req, now: float) -> None:
    """Continuous batching: a request entered the slot pool — the
    ``slot`` span carries its slot ids and the pool pressure."""
    slots = None
    slots_of = getattr(session.decoder, "slots_of", None)
    if slots_of is not None:
        slots = slots_of(req.rid)
    OBS.tracer.record("slot", ts=now, dur=0.0, rid=req.rid,
                      lane=req.lane, slots=slots,
                      pages_in_use=session.decoder.allocator.in_use,
                      queue_wait_s=max(now - req.t_submit, 0.0))


def record_slot_exit(session, req, stages, lat_ms: float, miss: bool,
                     now: float) -> None:
    reg, tr = OBS.registry, OBS.tracer
    lane = _lane(req.lane)
    stages = np.asarray(stages)
    tr.record("exit", ts=now, rid=req.rid, lane=req.lane,
              exits=stages.ravel().tolist(), n_tokens=int(stages.size),
              deadline_missed=bool(miss),
              deadline_slack_s=None if req.deadline_s is None
              else req.deadline_s - now)
    _latency_hist(reg).observe(lat_ms, lane=lane)
    reg.counter("dart_requests_completed_total",
                "requests completed by lane", ("lane",)).inc(1, lane=lane)
    if miss:
        reg.counter("dart_deadline_miss_total",
                    "deadline misses by lane", ("lane",)).inc(1, lane=lane)
    reg.counter("dart_lm_tokens_total", "decoded tokens",
                ()).inc(int(stages.size))


def record_retry(engine: str, attempt: int) -> None:
    """One retried dispatch (the engine pool re-running a bucket on
    another engine after a failure)."""
    OBS.tracer.record("retry", ts=time.monotonic(), engine=engine,
                      attempt=attempt)
    OBS.registry.counter("dart_retries_total",
                         "bucket dispatch retries by engine",
                         ("engine",)).inc(1, engine=engine)


def record_hedge(slow: str, to: str) -> None:
    """One hedged re-dispatch: the straggler-policy deadline expired on
    ``slow`` and the bucket was duplicated onto ``to``."""
    OBS.tracer.record("hedge", ts=time.monotonic(), slow=slow, to=to)
    OBS.registry.counter("dart_hedges_total",
                         "hedged straggler re-dispatches by slow engine",
                         ("engine",)).inc(1, engine=slow)


def record_requeue(n_requests: int) -> None:
    """One dead-engine bucket requeue (backpressure-bypassing)."""
    OBS.tracer.record("requeue", ts=time.monotonic(),
                      n_requests=n_requests)
    OBS.registry.counter("dart_requeues_total",
                         "requests requeued after losing their engine",
                         ()).inc(n_requests)


def record_fault(point: str, kind: str, engine) -> None:
    """One injected fault firing (chaos runs only)."""
    OBS.tracer.record("fault", ts=time.monotonic(), point=point,
                      kind=kind, engine=engine)
    OBS.registry.counter("dart_faults_injected_total",
                         "chaos faults injected by cut point and kind",
                         ("point", "kind")).inc(1, point=point, kind=kind)


# ---------------------------------------------------------------------------
# pull side (scrape-time collectors)
# ---------------------------------------------------------------------------

def bind_scheduler(sched, name: str | None = None) -> None:
    """Register a scrape-time collector exporting everything the
    scheduler (and the engine behind it) already knows.  Weakly bound:
    the collector unregisters itself once the scheduler is collected."""
    if name is None:
        name = type(sched).__name__
    ref = weakref.ref(sched)

    def collect(reg):
        obj = ref()
        if obj is None:
            return "dead"
        _collect_scheduler(reg, obj, name)
        return None

    OBS.registry.register_collector(collect)


def _collect_scheduler(reg, sched, name: str) -> None:
    # scheduler counters (submitted/completed/flush_*/degraded/...)
    ev = reg.counter("dart_scheduler_events_total",
                     "scheduler counters by event", ("event",))
    for k, v in sched.counters.items():
        ev.set_total(v, event=k)
    q = sched.queue
    ev.set_total(q.shed, event="shed")
    ev.set_total(q.rejected, event="rejected")
    ev.set_total(getattr(q, "starved", 0), event="starved")
    depth = reg.gauge("dart_queue_depth", "queued requests by lane",
                      ("lane",))
    for k in q.keys():
        depth.set(q.depth(k), lane=_lane(k))
    if hasattr(sched, "_inflight"):
        reg.gauge("dart_inflight",
                  "dispatched, unmaterialized buckets").set(
            len(sched._inflight))
    if getattr(sched, "_service_s", None) is not None:
        reg.gauge("dart_service_ms_ema",
                  "EMA of bucket service time").set(
            sched._service_s * 1e3)

    # per-lane DAES (Eq. 9) from the streaming accumulator
    daes = getattr(sched, "daes", None)
    if daes is not None:
        for lane, row in daes.rows().items():
            for col in ("daes", "speedup", "power_eff", "acc_pct", "n"):
                reg.gauge(f"dart_lane_{col}",
                          f"per-lane {col} (Eq. 9 telemetry)",
                          ("lane",)).set(float(row[col]),
                                         lane=_lane(lane))

    # admission-planner depth priors
    planner = getattr(sched, "planner", None)
    if planner is not None:
        pri = planner.priors()
        gd = reg.gauge("dart_depth_prior",
                       "admission planner expected exit depth",
                       ("member", "dclass"))
        if isinstance(pri, dict):                  # cascade planner
            for m, per in enumerate(pri["depth"]):
                for c, d in enumerate(per):
                    if d is not None:
                        gd.set(d, member=str(m), dclass=str(c))
            ge = reg.gauge("dart_escalation_ema",
                           "per-(boundary, class) escalation-rate EMA",
                           ("member", "dclass"))
            for m, per in enumerate(pri["escalation"]):
                for c, r in enumerate(per):
                    if r is not None:
                        ge.set(r, member=str(m), dclass=str(c))
        else:
            for c, d in enumerate(pri):
                if d is not None:
                    gd.set(d, member="0", dclass=str(c))

    # exit-depth predictor (ISSUE 9): hit/miss + head-skip counters
    predictor = getattr(sched, "predictor", None)
    if predictor is not None:
        ps = predictor.stats()
        pe = reg.counter("dart_predictor_events_total",
                         "exit-depth predictor counters by event",
                         ("event",))
        for k in ("hits", "misses", "skip_calls", "skip_stages",
                  "observed"):
            pe.set_total(ps[k], event=k)
        if ps["hit_rate"] is not None:
            reg.gauge("dart_predictor_hit_rate",
                      "fraction of requests whose predicted depth band "
                      "matched the realized exit").set(ps["hit_rate"])
        # admission-quote error (quote vs realized latency), from the
        # EngineState quote counters
        est = getattr(sched, "engine", None)
        if est is not None:
            qs = est.state
            qn = int(np.asarray(qs.quote_count))
            if qn:
                reg.gauge("dart_quote_mean_abs_err_ms",
                          "mean |admission quote - realized latency|"
                          ).set(float(np.asarray(qs.quote_err_ms_sum))
                                / qn)
                reg.gauge("dart_quote_mean_ms",
                          "mean admission-time latency quote").set(
                    float(np.asarray(qs.quote_ms_sum)) / qn)

    # engine telemetry (after the reduce_telemetry fold inside stats())
    engine = getattr(sched, "engine", None)
    if engine is None:
        return
    members = getattr(engine, "members", None)
    if members is not None:
        for i, m in enumerate(members):
            _collect_engine(reg, m, f"{name}.m{i}")
    else:
        _collect_engine(reg, engine, name)

    # continuous decoder slot/page occupancy
    decoder = getattr(sched, "decoder", None)
    if decoder is not None:
        for k, v in decoder.occupancy().items():
            reg.gauge(f"dart_{k}",
                      "continuous-batching pool occupancy").set(v)


def _collect_engine(reg, engine, name: str) -> None:
    st = engine.stats()
    reg.counter("dart_engine_served_total", "samples served by engine",
                ("engine",)).set_total(st["served"], engine=name)
    reg.gauge("dart_engine_mean_macs", "mean normalized MACs per sample",
              ("engine",)).set(st["mean_macs"], engine=name)
    exits = reg.counter("dart_engine_exits_total",
                        "EngineState exit histogram by stage",
                        ("engine", "stage"))
    for s, c in enumerate(np.asarray(st["exit_counts"]).ravel()):
        exits.set_total(int(c), engine=name, stage=str(s))
    req = st.get("requests")
    if req:
        lm = req["latency_ms"]
        g = reg.gauge("dart_engine_latency_ms",
                      "EngineState latency-ring percentiles",
                      ("engine", "quantile"))
        for qk in ("p50", "p95", "p99", "mean"):
            g.set(lm[qk], engine=name, quantile=qk)
        reg.gauge("dart_engine_miss_rate", "deadline miss rate",
                  ("engine",)).set(req["miss_rate"], engine=name)
    tc = getattr(engine, "trace_counts", None) or {}
    fam = reg.counter("dart_trace_total",
                      "compiled-step traces by cache key",
                      ("engine", "key"))
    for key, c in tc.items():
        fam.set_total(c, engine=name, key=repr(key))
    reg.counter("dart_recompiles_total",
                "re-traces of an already-compiled step key "
                "(alertable: should stay 0)",
                ("engine",)).set_total(
        sum(max(0, c - 1) for c in tc.values()), engine=name)


def bind_pool(pool) -> None:
    """Register a scrape-time collector for an
    :class:`~repro.serving.resilience.EnginePool`: per-engine health
    gauges (2 healthy / 1 degraded / 0 dead-or-drained), the chaos /
    retry / hedge / requeue / quarantine totals, the degradation-ladder
    rung, and the straggler-policy hedge deadline.  Weakly bound, like
    ``bind_scheduler``."""
    ref = weakref.ref(pool)

    def collect(reg):
        obj = ref()
        if obj is None:
            return "dead"
        from repro.serving.resilience import HEALTH_LEVEL
        st = obj.stats()
        health = reg.gauge("dart_engine_health",
                           "pool engine health (2 healthy / 1 degraded "
                           "/ 0 dead or drained)", ("engine",))
        for name, state in st["engines"].items():
            health.set(HEALTH_LEVEL[state], engine=name)
        reg.gauge("dart_degradation_rung",
                  "graceful-degradation ladder rung (0 = full service)"
                  ).set(st["rung"])
        ev = reg.counter("dart_pool_events_total",
                         "engine-pool counters by event", ("event",))
        for k in ("calls", "retries", "hedges", "requeues",
                  "quarantined", "deaths", "stragglers", "joins",
                  "drains"):
            ev.set_total(st[k], event=k)
        reg.counter("dart_retries_total",
                    "bucket dispatch retries by engine",
                    ("engine",)).set_total(st["retries"], engine="_pool")
        reg.counter("dart_hedges_total",
                    "hedged straggler re-dispatches by slow engine",
                    ("engine",)).set_total(st["hedges"], engine="_pool")
        reg.counter("dart_faults_injected_total",
                    "chaos faults injected by cut point and kind",
                    ("point", "kind")).set_total(
            st["faults_injected"], point="_all", kind="_all")
        if st["straggler_deadline_ms"] is not None:
            reg.gauge("dart_hedge_deadline_ms",
                      "straggler-policy rolling-median hedge deadline"
                      ).set(st["straggler_deadline_ms"])
        return None

    OBS.registry.register_collector(collect)


def bind_dispatch(reg) -> None:
    """Export ``repro.kernels.dispatch`` backend decisions (pallas /
    pallas-interpret / xla selection counts — the xla ones are the
    fallback counter)."""

    def collect(reg):
        from repro.kernels import dispatch as KD
        fam = reg.counter("dart_kernel_dispatch_total",
                          "kernel backend dispatch decisions",
                          ("kernel", "backend"))
        for (kernel, backend), c in KD.dispatch_counts().items():
            fam.set_total(c, kernel=kernel, backend=backend)
        return None

    reg.register_collector(collect)
