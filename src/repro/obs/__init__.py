"""repro.obs — the serving observability layer.

One switch, three surfaces:

* **per-request tracing** (:mod:`repro.obs.trace`) — host-side spans
  ``admit -> queue_wait -> bucket/slot -> compiled_step -> exit |
  escalate | shed`` in a bounded drop-oldest ring; JSONL + Chrome
  ``trace_event`` export (``tools/trace_view.py``).
* **metrics registry** (:mod:`repro.obs.metrics`) — counters / gauges /
  histograms with label sets and a Prometheus text exposition (file
  and stdlib-``http.server`` endpoint); :mod:`repro.obs.adapters`
  mirrors every existing signal into it (EngineState telemetry,
  per-lane DAES, ``trace_counts``, kernel dispatch decisions, queue
  depths, slot/page occupancy).
* **structured logging** (:mod:`repro.obs.log`) — the dispatcher
  threads' failure paths log ``key=value`` lines and count
  ``dart_errors_total``.

Usage::

    from repro import obs
    obs.configure(enabled=True, textfile="artifacts/metrics.prom")
    server = AsyncDartServer(engine)        # auto-instrumented
    ...
    obs.flush_textfile()                    # or let the writer thread
    print(obs.OBS.registry.render())        # Prometheus text

Disabled (the default) is zero-cost on the hot path: every
instrumentation site is a single ``if OBS.enabled`` attribute check,
spans are recorded only from host-side scheduler code (never inside
jitted step functions), and no extra host syncs are introduced —
the differential suites pin bit-identical outputs and unchanged
``trace_counts`` with obs off.  Enabled-mode overhead is gated in CI
(``obs.overhead`` in ``benchmarks/baselines/smoke.json``, <=5%).
"""
from __future__ import annotations

import threading

from repro.obs import log  # noqa: F401  (re-export)
from repro.obs.metrics import (Registry, parse_prometheus,
                               render_prometheus, start_http_server,
                               write_textfile)
from repro.obs.trace import Tracer, chrome_trace

__all__ = ["OBS", "configure", "reset", "is_enabled", "get_registry",
           "get_tracer", "flush_textfile", "Registry", "Tracer",
           "chrome_trace", "render_prometheus", "parse_prometheus",
           "log"]

DEFAULT_TRACE_CAPACITY = 16384


class _ObsState:
    """The process-wide observability switchboard.  Hot-path code reads
    ONE attribute (``OBS.enabled``) and does nothing else when off."""

    def __init__(self):
        self.enabled = False
        self.registry = Registry()
        self.tracer = Tracer(DEFAULT_TRACE_CAPACITY)
        self.textfile: str | None = None
        self._writer: threading.Thread | None = None
        self._writer_stop: threading.Event | None = None
        self._http = None

    @property
    def http_port(self) -> int | None:
        return None if self._http is None else self._http.server_address[1]


OBS = _ObsState()


def is_enabled() -> bool:
    return OBS.enabled


def get_registry() -> Registry:
    return OBS.registry


def get_tracer() -> Tracer:
    return OBS.tracer


def configure(enabled: bool | None = None, *,
              trace_capacity: int | None = None,
              textfile: str | None = None,
              textfile_interval_s: float | None = None,
              http_port: int | None = None) -> _ObsState:
    """Configure the global observability state.

    enabled:             master switch for hot-path instrumentation
    trace_capacity:      span ring size (drop-oldest past it)
    textfile:            path to (re)write the Prometheus exposition to
    textfile_interval_s: start a daemon writer rewriting ``textfile``
                         every interval (atomic rename — safe to tail)
    http_port:           serve ``/metrics`` via stdlib http.server
                         (0 = OS-assigned; read it back from
                         ``OBS.http_port``)
    """
    if enabled is not None:
        OBS.enabled = bool(enabled)
    if trace_capacity is not None:
        OBS.tracer = Tracer(trace_capacity)
    if textfile is not None:
        OBS.textfile = textfile
        if textfile_interval_s:
            _stop_writer()
            stop = threading.Event()

            def loop():
                while not stop.wait(textfile_interval_s):
                    try:
                        write_textfile(OBS.registry, textfile)
                    except Exception:              # noqa: BLE001
                        pass

            t = threading.Thread(target=loop, daemon=True,
                                 name="obs-textfile-writer")
            OBS._writer, OBS._writer_stop = t, stop
            t.start()
    if http_port is not None and OBS._http is None:
        OBS._http = start_http_server(OBS.registry, port=http_port)
    if OBS.enabled:
        # kernel dispatch decisions are always counted (trace-time
        # bookkeeping, like trace_counts); export them once enabled
        from repro.obs import adapters
        adapters.bind_dispatch(OBS.registry)
    return OBS


def flush_textfile() -> str | None:
    """Write the exposition file now (regardless of the writer thread)."""
    if OBS.textfile is None:
        return None
    return write_textfile(OBS.registry, OBS.textfile)


def _stop_writer() -> None:
    if OBS._writer_stop is not None:
        OBS._writer_stop.set()
    OBS._writer = OBS._writer_stop = None


def reset() -> _ObsState:
    """Tear down exporters and return to the disabled default (tests)."""
    _stop_writer()
    if OBS._http is not None:
        try:
            OBS._http.shutdown()
        except Exception:                          # noqa: BLE001
            pass
        OBS._http = None
    OBS.enabled = False
    OBS.textfile = None
    OBS.registry = Registry()
    OBS.tracer = Tracer(DEFAULT_TRACE_CAPACITY)
    return OBS
