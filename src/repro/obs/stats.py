"""repro.obs.stats — the ONE stats() assembly shared by every engine.

``DartEngine``, ``ShardedDartEngine`` and ``LMDecodeEngine`` used to
each hand-build the same summary dict (served / exit_counts /
exit_frac / total_macs / mean_macs / requests) and the three copies had
started to drift.  They now all call:

    tel = ST.telemetry_totals(self.state, sharded=...)   # ONE reduction
    out = OBS_STATS.engine_summary(tel)                  # ONE key set
    ...engine-specific extras...
    return OBS_STATS.attach_requests(out, self.state)    # ONE percentile
                                                         # implementation

so key naming cannot drift again, and the obs adapters (which join the
tracer's host-side spans against exactly these reductions) read one
canonical shape.
"""
from __future__ import annotations

import numpy as np

__all__ = ["engine_summary", "attach_requests"]

#: keys every engine's stats() is guaranteed to carry
SUMMARY_KEYS = ("served", "exit_counts", "exit_frac", "total_macs",
                "mean_macs")


def engine_summary(telemetry: dict) -> dict:
    """Canonical serving summary from reduced telemetry totals (the
    output of :func:`repro.engine.state.telemetry_totals`)."""
    served = int(telemetry["served"])
    counts = np.asarray(telemetry["exit_counts"])
    total_macs = float(telemetry["total_macs"])
    return {"served": served,
            "exit_counts": counts,
            "exit_frac": counts / max(served, 1),
            "total_macs": total_macs,
            "mean_macs": total_macs / max(served, 1)}


def attach_requests(out: dict, state) -> dict:
    """Attach the latency-ring percentiles/miss-rate block (if any
    requests were recorded) — the single percentile implementation is
    :func:`repro.engine.state.latency_percentiles`."""
    from repro.engine import state as ST
    req = ST.request_stats(state)
    if req["requests"]:
        out["requests"] = req
    return out
