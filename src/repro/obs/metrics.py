"""repro.obs.metrics — the unified serving metrics registry.

Counters / gauges / histograms with label sets (``lane``, ``member``,
``stage``, ``bucket``, ``backend``), one :class:`Registry` behind all of
them, and a Prometheus text-exposition writer (plus a parser, so the
dashboard and the tests consume the exact bytes an external scraper
would).  Everything here is stdlib + numpy — no client library.

Two ways data gets in:

* **push** — the serving hot path calls ``counter.inc`` /
  ``histogram.observe`` directly (only when ``repro.obs`` is enabled).
* **pull** — ``Registry.register_collector(fn)`` registers a scrape-time
  callback that refreshes gauges from live objects (queue depths, slot
  occupancy, ``EngineState`` telemetry after ``reduce_telemetry``);
  collectors run inside ``collect()``/``render()``, never on the
  request path.

Export: :func:`write_textfile` (atomic tmp+rename, so a scraper or
``tools/dartop.py`` never reads a half-written file) and
:func:`start_http_server` (stdlib ``http.server`` on a daemon thread).
"""
from __future__ import annotations

import os
import threading

__all__ = ["Counter", "Gauge", "Histogram", "Registry",
           "render_prometheus", "parse_prometheus", "write_textfile",
           "start_http_server", "LATENCY_BUCKETS_MS"]

#: default histogram edges for request latency in milliseconds
LATENCY_BUCKETS_MS = (1.0, 2.0, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
                      500.0, 1000.0, 2500.0, 5000.0)


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _escape_help(v: str) -> str:
    return v.replace("\\", "\\\\").replace("\n", "\\n")


def _fmt(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class _Family:
    """One named metric family: a map labelvalues -> value."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "", labelnames=()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()
        self._values: dict = {}

    def _key(self, labels: dict) -> tuple:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: got labels {sorted(labels)}, "
                f"declared {sorted(self.labelnames)}")
        return tuple(str(labels[k]) for k in self.labelnames)

    def samples(self) -> list:
        """[(suffix, labels dict, value), ...] — exposition order."""
        with self._lock:
            items = sorted(self._values.items())
        out = []
        for key, v in items:
            out.append(("", dict(zip(self.labelnames, key)), v))
        return out

    def clear(self) -> None:
        with self._lock:
            self._values.clear()


class Counter(_Family):
    kind = "counter"

    def inc(self, v: float = 1.0, **labels) -> None:
        k = self._key(labels)
        with self._lock:
            self._values[k] = self._values.get(k, 0.0) + v

    def set_total(self, v: float, **labels) -> None:
        """Adopt an externally-maintained monotonic total (the pull
        adapters mirror existing counters — scheduler ``counters``,
        ``trace_counts`` — instead of double-counting them)."""
        k = self._key(labels)
        with self._lock:
            self._values[k] = float(v)

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._values.get(self._key(labels), 0.0))


class Gauge(_Family):
    kind = "gauge"

    def set(self, v: float, **labels) -> None:
        k = self._key(labels)
        with self._lock:
            self._values[k] = float(v)

    def inc(self, v: float = 1.0, **labels) -> None:
        k = self._key(labels)
        with self._lock:
            self._values[k] = self._values.get(k, 0.0) + v

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._values.get(self._key(labels), 0.0))


class _HistValue:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)     # +1 for +Inf
        self.sum = 0.0
        self.count = 0


class Histogram(_Family):
    kind = "histogram"

    def __init__(self, name, help="", labelnames=(), buckets=None):
        super().__init__(name, help, labelnames)
        b = tuple(sorted(LATENCY_BUCKETS_MS if buckets is None
                         else buckets))
        if not b:
            raise ValueError("histogram needs at least one bucket")
        self.buckets = b

    def observe(self, v: float, **labels) -> None:
        k = self._key(labels)
        v = float(v)
        with self._lock:
            h = self._values.get(k)
            if h is None:
                h = self._values[k] = _HistValue(len(self.buckets))
            i = len(self.buckets)
            for j, le in enumerate(self.buckets):
                if v <= le:
                    i = j
                    break
            h.counts[i] += 1
            h.sum += v
            h.count += 1

    def percentile(self, q: float, **labels) -> float | None:
        """Estimated q-th percentile (0..100) from the bucket counts —
        the single estimator :mod:`tools.dartop` also uses (via
        :func:`estimate_percentile`)."""
        k = self._key(labels)
        with self._lock:
            h = self._values.get(k)
            if h is None or not h.count:
                return None
            counts = list(h.counts)
        return estimate_percentile(self.buckets, counts, q)

    def samples(self) -> list:
        with self._lock:
            items = sorted(self._values.items())
        out = []
        for key, h in items:
            labels = dict(zip(self.labelnames, key))
            cum = 0
            for le, c in zip(self.buckets, h.counts):
                cum += c
                out.append(("_bucket", {**labels, "le": _fmt(le)}, cum))
            out.append(("_bucket", {**labels, "le": "+Inf"}, h.count))
            out.append(("_sum", labels, h.sum))
            out.append(("_count", labels, h.count))
        return out


def estimate_percentile(buckets, counts, q: float) -> float:
    """q-th percentile (0..100) from per-bucket (non-cumulative) counts
    via linear interpolation inside the winning bucket.  ``counts`` has
    ``len(buckets) + 1`` entries (last = overflow past the top edge,
    credited at the top edge — an explicit floor, not an estimate)."""
    total = sum(counts)
    if not total:
        return 0.0
    target = (q / 100.0) * total
    cum = 0.0
    for i, c in enumerate(counts):
        prev = cum
        cum += c
        if cum >= target and c:
            hi = buckets[i] if i < len(buckets) else buckets[-1]
            lo = buckets[i - 1] if 0 < i <= len(buckets) else 0.0
            frac = (target - prev) / c
            return lo + frac * (hi - lo)
    return float(buckets[-1])


class Registry:
    """Get-or-create factory + scrape surface for metric families."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}
        self._collectors: list = []

    # -- family factories (idempotent; type/labels must agree) ----------
    def _get(self, cls, name, help, labelnames, **kw):
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = self._families[name] = cls(name, help, labelnames,
                                                 **kw)
                return fam
        if not isinstance(fam, cls) or fam.labelnames != tuple(labelnames):
            raise ValueError(
                f"metric {name!r} re-declared as {cls.kind} with labels "
                f"{tuple(labelnames)} (was {fam.kind} {fam.labelnames})")
        return fam

    def counter(self, name, help="", labelnames=()) -> Counter:
        return self._get(Counter, name, help, labelnames)

    def gauge(self, name, help="", labelnames=()) -> Gauge:
        return self._get(Gauge, name, help, labelnames)

    def histogram(self, name, help="", labelnames=(),
                  buckets=None) -> Histogram:
        return self._get(Histogram, name, help, labelnames,
                         buckets=buckets)

    def get(self, name) -> _Family | None:
        with self._lock:
            return self._families.get(name)

    # -- pull-side collectors -------------------------------------------
    def register_collector(self, fn) -> None:
        """``fn(registry)`` runs at every scrape, BEFORE the families
        are read — refresh gauges from live objects there.  A collector
        that raises is dropped (a dead server must not poison the whole
        scrape) ; one that returns the string ``"dead"`` unregisters
        itself quietly (weakref-bound adapters)."""
        with self._lock:
            self._collectors.append(fn)

    def collect(self) -> list[_Family]:
        with self._lock:
            collectors = list(self._collectors)
        dead = []
        for fn in collectors:
            try:
                if fn(self) == "dead":
                    dead.append(fn)
            except Exception:                      # noqa: BLE001
                dead.append(fn)
        if dead:
            with self._lock:
                self._collectors = [c for c in self._collectors
                                    if c not in dead]
        with self._lock:
            return sorted(self._families.values(), key=lambda f: f.name)

    def render(self) -> str:
        return render_prometheus(self)


def render_prometheus(registry: Registry) -> str:
    """Prometheus text exposition format, version 0.0.4."""
    lines = []
    for fam in registry.collect():
        if fam.help:
            lines.append(f"# HELP {fam.name} {_escape_help(fam.help)}")
        lines.append(f"# TYPE {fam.name} {fam.kind}")
        for suffix, labels, value in fam.samples():
            if labels:
                lab = ",".join(
                    f'{k}="{_escape_label(str(v))}"'
                    for k, v in labels.items())
                lines.append(f"{fam.name}{suffix}{{{lab}}} {_fmt(value)}")
            else:
                lines.append(f"{fam.name}{suffix} {_fmt(value)}")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# parsing (the dashboard + the round-trip tests read what we wrote)
# ---------------------------------------------------------------------------

def _parse_labels(s: str) -> dict:
    out, i = {}, 0
    while i < len(s):
        while i < len(s) and s[i] in ", ":
            i += 1
        if i >= len(s):
            break
        eq = s.index("=", i)
        name = s[i:eq].strip()
        if s[eq + 1] != '"':
            raise ValueError(f"unquoted label value in {s!r}")
        j, buf = eq + 2, []
        while s[j] != '"':
            if s[j] == "\\":
                nxt = s[j + 1]
                buf.append({"n": "\n", "\\": "\\", '"': '"'}.get(nxt, nxt))
                j += 2
            else:
                buf.append(s[j])
                j += 1
        out[name] = "".join(buf)
        i = j + 1
    return out


def parse_prometheus(text: str) -> dict:
    """text -> {family: {"type", "help", "samples": [(name, labels,
    value), ...]}}.  Histogram series (``_bucket``/``_sum``/``_count``)
    attach to their base family."""
    families: dict = {}
    order: list = []

    def fam_for(sample_name: str) -> dict:
        for base in order[::-1]:
            if sample_name == base or (
                    families[base]["type"] == "histogram"
                    and sample_name in (base + "_bucket", base + "_sum",
                                        base + "_count")):
                return families[base]
        f = families.setdefault(
            sample_name, {"type": "untyped", "help": "", "samples": []})
        if sample_name not in order:
            order.append(sample_name)
        return f

    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("# HELP "):
            _, _, rest = line.partition("# HELP ")
            name, _, help_ = rest.partition(" ")
            f = families.setdefault(
                name, {"type": "untyped", "help": "", "samples": []})
            f["help"] = help_.replace("\\n", "\n").replace("\\\\", "\\")
            if name not in order:
                order.append(name)
            continue
        if line.startswith("# TYPE "):
            _, _, rest = line.partition("# TYPE ")
            name, _, kind = rest.partition(" ")
            f = families.setdefault(
                name, {"type": "untyped", "help": "", "samples": []})
            f["type"] = kind.strip()
            if name not in order:
                order.append(name)
            continue
        if line.startswith("#"):
            continue
        if "{" in line:
            name = line[:line.index("{")]
            rest = line[line.index("{") + 1:]
            labels_s = rest[:rest.rindex("}")]
            value_s = rest[rest.rindex("}") + 1:].strip()
            labels = _parse_labels(labels_s)
        else:
            name, _, value_s = line.partition(" ")
            labels = {}
        fam_for(name)["samples"].append(
            (name, labels, float(value_s.replace("+Inf", "inf"))))
    return families


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def write_textfile(registry: Registry, path: str) -> str:
    """Atomically (re)write the exposition file a node-exporter-style
    scraper or ``tools/dartop.py --file`` tails."""
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(render_prometheus(registry))
    os.replace(tmp, path)
    return path


def start_http_server(registry: Registry, port: int = 0,
                      addr: str = "127.0.0.1"):
    """Serve ``GET /metrics`` (and ``/``) from a daemon thread; returns
    the ``http.server`` instance (``.server_address[1]`` is the bound
    port — pass ``port=0`` to let the OS pick; ``.shutdown()`` stops
    it)."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):                          # noqa: N802
            body = render_prometheus(registry).encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):                 # quiet by default
            pass

    srv = ThreadingHTTPServer((addr, port), Handler)
    t = threading.Thread(target=srv.serve_forever, daemon=True,
                         name="obs-metrics-http")
    t.start()
    return srv
