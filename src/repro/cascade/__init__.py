"""repro.cascade — difficulty-routed multi-model cascade serving.

DART's difficulty signal applied ACROSS networks: a
:class:`CascadeEngine` fronts an ordered list of DART engines of
increasing capacity; easy requests terminate in the small model via its
normal exits, hard ones escalate (Bolukbasi-style) carrying the smaller
model's top confidence forward as an escalation prior.

    from repro.cascade import CascadeEngine
    from repro.serving import AsyncDartServer

    cascade = CascadeEngine([small_engine, big_engine],
                            member_costs=[0.2, 1.0])
    cascade.calibrate(cal_data)            # joint cascade DP
    with AsyncDartServer(cascade) as server:   # cascade scheduler
        out = server.submit(x, deadline_ms=50).result()
        out["member"]                      # which member resolved it

Pieces:

* :class:`CascadeEngine` (engine.py) — the cascade façade: escalation
  gates, cascade-absolute cost accounting, joint calibration, batched +
  per-request-oracle inference.
* :class:`CascadeAsyncServer` / :class:`CascadePlanner` (serving.py) —
  the async scheduler integration: (member, class) lanes, escalation
  re-enqueue, per-member telemetry.  ``AsyncDartServer(cascade)``
  builds it transparently.
* The joint optimizer lives in ``repro.core.policy``
  (``optimize_cascade_dp``) and is registered as ``"cascade_dp"`` in
  ``repro.engine.registry``.
"""
from repro.cascade.engine import CascadeEngine
from repro.cascade.serving import CascadeAsyncServer, CascadePlanner

__all__ = ["CascadeEngine", "CascadeAsyncServer", "CascadePlanner"]
