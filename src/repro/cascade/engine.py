"""CascadeEngine — difficulty-routed serving over a cascade of DART
engines of increasing capacity.

DART routes WITHIN one network; the same difficulty signal pays again
ACROSS networks (Bolukbasi et al., "Adaptive Neural Networks for
Efficient Inference"): easy requests terminate in a small model via its
normal DART exits, hard ones escalate to the next member.  The cascade
composes engines the rest of the repo already provides —

    small = DartEngine.from_config(small_cfg, small_params)
    big   = DartEngine.from_config(big_cfg, big_params, mesh=mesh)
    cascade = CascadeEngine([small, big], member_costs=[0.2, 1.0])
    cascade.calibrate(cal_data)          # joint cascade DP (§II.B ext.)
    out = cascade.infer(x)               # pred/conf/exit_idx/member/macs

Escalation semantics (per sample, elementwise — so the batched cascade
is bit-identical to the per-request oracle on dense configs):

* member m serves the sample with its OWN Alg. 1 routing, producing a
  terminal (exit_idx, conf);
* the sample escalates iff ``conf <= clip(θ_m + β_esc·α, 0, 1)`` —
  Eq. 19 transposed across networks (the escalation analogue of the
  within-network gate; final member always terminates);
* the NEXT member's admission difficulty is the escalation prior
  ``clip((1−w)·α + w·(1−conf), 0, 1)`` — the smaller model's residual
  uncertainty folded into Eq. 8, so the big model's thresholds are
  better informed than raw pixel statistics (Dong/Mao/Zhang:
  exit outcomes are predictable from cheap pre-backbone signals).

Cost accounting is cascade-absolute: ``member_costs`` gives each
member's full-network cost in one shared unit (normalized so the
BIGGEST member = 1.0; default: relative parameter counts), and a
sample's ``macs`` is the sum over every member visited of that member's
routed cost times its scale — directly comparable against the
biggest-member-only baseline (its static cost is exactly 1.0).

Modes:

* ``masked``/``compacted`` — batched cascade; each member serves the
  still-active subset through its own compiled path (one compiled step
  per (member, bucket) — ``trace_counts`` nests per member).
* ``oracle`` — per-request eager cascade: every sample served alone
  through each member's eager/reference pass.  The equivalence suite
  asserts batched == oracle for member/exit/pred.
"""
from __future__ import annotations

import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy as POL
from repro.engine import registry as REG


def _param_cost(engine) -> float:
    """Default capacity proxy: total parameter count (used only when no
    measured ``member_costs`` are given)."""
    return float(sum(np.size(l) for l in jax.tree.leaves(engine.params)))


class CascadeEngine:
    """Ordered cascade of :class:`~repro.engine.engine.DartEngine` /
    :class:`~repro.engine.sharded.ShardedDartEngine` members (smallest
    first).  Duck-types the slice of the engine API the serving layer
    consumes (``compactor`` / ``bucket_key`` / ``cum_costs`` /
    ``record_requests`` / ``stats`` / ``infer``)."""

    def __init__(self, members, *, theta=None, beta_esc: float = 0.3,
                 prior_weight: float = 0.5, member_costs=None,
                 optimizer: str = "cascade_dp"):
        if len(members) < 2:
            raise ValueError("a cascade needs at least 2 members")
        self.members = list(members)
        if member_costs is None:
            member_costs = [_param_cost(m) for m in self.members]
        mc = np.asarray(member_costs, float)
        if len(mc) != len(self.members):
            raise ValueError(f"{len(mc)} costs for {len(self.members)} "
                             "members")
        self.member_costs = mc / mc[-1]
        if np.any(np.diff(self.member_costs) < 0):
            raise ValueError(
                f"members must be ordered by increasing capacity; got "
                f"costs {self.member_costs}")
        self.theta = np.full(len(members) - 1, 0.5) if theta is None \
            else np.asarray(theta, float)
        if self.theta.shape != (len(members) - 1,):
            raise ValueError(f"theta must have {len(members) - 1} "
                             f"entries, got {self.theta.shape}")
        self.beta_esc = float(beta_esc)
        self.prior_weight = float(prior_weight)
        self.optimizer = optimizer
        self._opt_fn = REG.get_optimizer(optimizer)
        # Members must agree on the bucket lattice: the scheduler's flush
        # planner keys consolidation on ONE bucket_key, and an escalated
        # batch re-buckets under the next member.
        b0 = tuple(self.members[0].compactor.buckets)
        for m in self.members[1:]:
            if tuple(m.compactor.buckets) != b0:
                raise ValueError("cascade members must share the same "
                                 "compactor buckets")
        # Admission difficulty comes from the SMALLEST member's Eq. 8
        # estimator (the cascade analogue of pre-backbone prediction).
        self._alpha = self.members[0]._alpha
        self._lock = threading.Lock()
        self.admitted = 0
        self.escalated = np.zeros(len(members) - 1, np.int64)
        self.total_macs = 0.0

    # ------------------------------------------------------------------
    # scheduler duck-typing
    # ------------------------------------------------------------------
    @property
    def compactor(self):
        return self.members[0].compactor

    def bucket_key(self, n: int) -> int:
        """Conservative compile-cache key across members: the max of the
        members' keys (they share buckets; only ``replica_multiple``
        differs).  Per-member dispatches still pad with the member's own
        ``bucket_key`` — this is the flush planner's view."""
        return max(m.bucket_key(n) for m in self.members)

    @property
    def cum_costs(self) -> np.ndarray:
        """The BIGGEST member's cost curve in cascade units (its full
        network = 1.0) — the static reference every speedup/DAES number
        is measured against."""
        cum = np.asarray(self.members[-1].cum_costs, float)
        return self.member_costs[-1] * cum / cum[-1]

    @property
    def n_exits(self) -> int:
        return self.members[-1].n_exits

    @property
    def trace_counts(self) -> dict:
        """(member_idx, *member_key) -> traces, pooled over members."""
        out = {}
        for i, m in enumerate(self.members):
            for k, v in getattr(m, "trace_counts", {}).items():
                out[(i,) + (k if isinstance(k, tuple) else (k,))] = v
        return out

    def record_requests(self, latencies_ms, missed=None) -> None:
        """Request latency/SLO telemetry folds into the FIRST member's
        state (one cascade = one request stream; ``stats()`` surfaces it
        at the cascade level)."""
        self.members[0].record_requests(latencies_ms, missed)

    # ------------------------------------------------------------------
    # escalation rule (host-side, elementwise)
    # ------------------------------------------------------------------
    def should_escalate(self, m: int, conf, alpha) -> np.ndarray:
        """(B,) bool — escalate member ``m``'s terminal decisions.  The
        final member never escalates."""
        if m >= len(self.members) - 1:
            return np.zeros(np.shape(conf), bool)
        return POL.escalation_gate(float(self.theta[m]), alpha,
                                   np.asarray(conf), self.beta_esc)

    def escalation_alpha(self, alpha, conf) -> np.ndarray:
        """Admission difficulty for the next member (escalation prior)."""
        return np.asarray(POL.escalation_alpha(
            alpha, np.asarray(conf), self.prior_weight), np.float32)

    def member_macs(self, m: int, exit_idx) -> np.ndarray:
        """Cascade-unit cost of member ``m`` terminating at
        ``exit_idx``."""
        cum = np.asarray(self.members[m].cum_costs, float)
        return self.member_costs[m] * cum[np.asarray(exit_idx)] / cum[-1]

    def fold(self, m: int, esc_count: int, macs_sum: float,
             n_admitted: int = 0) -> None:
        """Host-side cascade counters (the serving layer calls this per
        dispatched member bucket; ``infer`` folds its own)."""
        with self._lock:
            self.admitted += int(n_admitted)
            if m < len(self.members) - 1:
                self.escalated[m] += int(esc_count)
            self.total_macs += float(macs_sum)

    # ------------------------------------------------------------------
    # calibration (§II.B extended across members)
    # ------------------------------------------------------------------
    def collect_calibration(self, data_cfg, *, n=512, split="eval",
                            offset=0, batch=64) -> POL.CascadeCalibrationData:
        """Measure the SAME ``n`` samples through every member and pool
        them; the admission alpha (member 0's estimator) is shared so
        escalation replay is exact."""
        import dataclasses
        ms = [m.collect_calibration(data_cfg, n=n, split=split,
                                    offset=offset, batch=batch)
              for m in self.members]
        a0 = ms[0].alpha
        ms = [ms[0]] + [dataclasses.replace(d, alpha=a0) for d in ms[1:]]
        return POL.CascadeCalibrationData(ms, self.member_costs)

    def calibrate(self, data, **kw) -> POL.CascadePolicyResult:
        """Fit the joint cascade policy with the registered optimizer
        (default ``cascade_dp``) and install it: each member's (tau,
        coef, beta_diff) into that member's state, the escalation
        thresholds into the cascade."""
        if not isinstance(data, POL.CascadeCalibrationData):
            data = self.collect_calibration(data, **{
                k: kw.pop(k) for k in ("n", "split", "offset", "batch")
                if k in kw})
        kw.setdefault("beta_opt", float(self.members[-1].state.beta_opt))
        kw.setdefault("beta_esc", self.beta_esc)
        kw.setdefault("prior_weight", self.prior_weight)
        pol = self._opt_fn(data, **kw)
        for eng, p in zip(self.members, pol.members):
            eng.state = eng.state.with_policy(
                tau=p.tau, coef=p.coef, beta_diff=p.beta_diff)
            if hasattr(eng, "_commit"):     # sharded member: re-pin
                eng._commit()
        self.theta = np.asarray(pol.theta, float)
        self.beta_esc = float(pol.beta_esc)
        self.prior_weight = float(pol.prior_weight)
        return pol

    # ------------------------------------------------------------------
    # inference
    # ------------------------------------------------------------------
    def infer_member(self, m: int, x, *, alpha, mode: str = "masked",
                     record: bool = True, pad_to: int | None = None) -> dict:
        """One member's serving pass on an (already-routed) batch — the
        async scheduler's per-(member, bucket) dispatch entry point.
        ``alpha`` is the difficulty THIS member admits under (the raw
        Eq. 8 estimate for member 0, the escalation prior after)."""
        return self.members[m].infer(x, mode=mode, record=record,
                                     alpha=alpha, pad_to=pad_to)

    def infer(self, x, mode: str = "masked", record: bool | None = None,
              alpha=None, pad_to: int | None = None) -> dict:
        """Serve one batch through the whole cascade.

        mode="masked"/"compacted" — batched: each member serves the
            still-active subset through its own serving path.
        mode="oracle" — per-request reference: every sample served alone
            through each member's eager pass (never records).
        Returns pred/conf/exit_idx (within the terminal member), member,
        alpha (the ADMISSION Eq. 8 difficulty), macs (cascade units)."""
        if mode == "oracle":
            parts = [self._infer_eager(np.asarray(x)[i:i + 1],
                                       None if alpha is None
                                       else np.asarray(alpha)[i:i + 1])
                     for i in range(np.asarray(x).shape[0])]
            return {k: np.concatenate([p[k] for p in parts])
                    for k in ("pred", "conf", "exit_idx", "member",
                              "alpha", "macs")}
        if mode == "eager":
            return self._infer_eager(np.asarray(x), alpha)
        if mode not in ("masked", "compacted"):
            raise ValueError(f"unknown mode {mode!r}; known: masked, "
                             "compacted, eager, oracle")
        return self._infer_batched(np.asarray(x), mode,
                                   False if record is None else record,
                                   alpha)

    def _infer_eager(self, x, alpha=None) -> dict:
        """Batched cascade over each member's eager/reference pass."""
        from repro.engine.sharded import ShardedDartEngine

        def call(eng, xs, a):
            if isinstance(eng, ShardedDartEngine):
                return eng.infer(xs, mode="eager", alpha=a)
            return eng.infer(xs, mode="masked", record=False, alpha=a)
        return self._cascade_pass(x, alpha, call, record=False)

    def _infer_batched(self, x, mode, record, alpha=None) -> dict:
        def call(eng, xs, a):
            n = xs.shape[0]
            pad = eng.bucket_key(n) if mode == "masked" \
                and n <= eng.compactor.max_bucket else None
            return eng.infer(xs, mode=mode, record=record, alpha=a,
                             pad_to=pad)
        return self._cascade_pass(x, alpha, call, record=record)

    def _cascade_pass(self, x, alpha, call, record: bool) -> dict:
        b = x.shape[0]
        if alpha is None:
            alpha = np.asarray(self._alpha(jnp.asarray(x)), np.float32)
        else:
            alpha = np.asarray(alpha, np.float32)

        pred = np.zeros(b, np.int64)
        conf = np.zeros(b, np.float32)
        exit_idx = np.zeros(b, np.int64)
        member = np.zeros(b, np.int64)
        macs = np.zeros(b, np.float64)

        active = np.arange(b)
        a_cur = alpha
        for m, eng in enumerate(self.members):
            out = call(eng, x[active], a_cur)
            c = np.asarray(out["conf"])
            ei = np.asarray(out["exit_idx"])
            pr = np.asarray(out["pred"])
            macs[active] += self.member_macs(m, ei)
            esc = self.should_escalate(m, c, a_cur)
            term = active[~esc]
            pred[term] = pr[~esc]
            conf[term] = c[~esc]
            exit_idx[term] = ei[~esc]
            member[term] = m
            if record:
                self.fold(m, int(esc.sum()),
                          float(self.member_macs(m, ei).sum()),
                          n_admitted=b if m == 0 else 0)
            a_cur = self.escalation_alpha(a_cur[esc], c[esc])
            active = active[esc]
            if not active.size:
                break
        return {"pred": pred, "conf": conf, "exit_idx": exit_idx,
                "member": member, "alpha": alpha, "macs": macs}

    # ------------------------------------------------------------------
    # metering
    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """Cascade-level counters + every member's own stats."""
        mstats = [m.stats() for m in self.members]
        with self._lock:
            admitted = self.admitted
            escalated = self.escalated.copy()
            total_macs = self.total_macs
        out = {
            "members": mstats,
            "admitted": admitted,
            "escalated": escalated.tolist(),
            "escalation_rate": (escalated / max(admitted, 1)).tolist(),
            "total_macs": total_macs,
            "mean_macs": total_macs / max(admitted, 1),
            "member_costs": self.member_costs.tolist(),
            "theta": np.asarray(self.theta).tolist(),
        }
        if "requests" in mstats[0]:
            out["requests"] = mstats[0]["requests"]
        return out
