"""Cascade serving — the async scheduler over a CascadeEngine.

``AsyncDartServer(cascade)`` transparently constructs
:class:`CascadeAsyncServer` (the façade's ``__new__`` dispatches here).
The request lifecycle grows one loop over the plain scheduler's:

    submit ──admit──▶ (member, class) lane ──flush──▶ member bucket
          (Eq. 8 α +      │                               │
           member choice) │   ┌──── escalate? ────────────┘
                          ◀───┘ re-enqueue @ (member+1, class(α'))
                                α' = escalation prior
                          └──▶ all samples terminal → resolve future

* **Admission** — :class:`CascadePlanner` routes each request to the
  CHEAPEST member whose per-(member, class) escalation prior predicts
  termination (cold start: the smallest member), and predicts cascade
  cost as the escalation-rate-weighted sum of member costs.
* **Dispatch** — one engine call per flushed (member, bucket) lane via
  ``CascadeEngine.infer_member`` (the member pads with its OWN
  bucket_key, so the per-member trace-count guarantees hold).
* **Escalation** — completed buckets apply the cascade's elementwise
  escalation gate; escalated samples re-enqueue as CONTINUATION
  requests into the next member's lane (``RequestQueue.requeue``:
  already-admitted work bypasses backpressure), carrying the
  escalation-prior alpha.  A request's future resolves only when every
  sample is terminal; outputs are assembled per sample into the ROOT
  request's buffer, so partial escalation inside one request works.
* **Telemetry** — per-member depth priors + escalation EMAs fold per
  bucket; request latency/SLO and per-(terminal member, class) DAES
  fold when a ROOT resolves.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future

import jax.numpy as jnp
import numpy as np

from repro.core import difficulty as DIFF
from repro.obs import OBS
from repro.obs import adapters as OBS_A
from repro.obs import log as OBS_LOG
from repro.serving.loop import _RESULT_KEYS, AsyncDartServer
from repro.serving.planner import AdmissionPlanner
from repro.serving.request import Request


class CascadePlanner:
    """Admission planning for a cascade: difficulty class + member
    choice + cascade-cost prediction.

    Wraps one :class:`AdmissionPlanner` per member (the per-class exit-
    depth EMAs stay per member) and adds the cross-member state: a
    per-(boundary, class) escalation-rate EMA.  ``admit``/``classify``
    return the same ``(alpha, lane, cost)``/``(lane, cost)`` shapes the
    base scheduler consumes — the lane is ``(member, class)``."""

    def __init__(self, cascade, edges=DIFF.DEFAULT_EDGES,
                 ema_decay: float = 0.9, escalation_cut: float = 0.5):
        self.cascade = cascade
        self.edges = np.asarray(edges, np.float32)
        self.n_classes = len(self.edges) + 1
        self.ema_decay = float(ema_decay)
        self.escalation_cut = float(escalation_cut)
        self.members = [AdmissionPlanner(m, edges=edges,
                                         ema_decay=ema_decay)
                        for m in cascade.members]
        self._esc_ema = [[None] * self.n_classes
                         for _ in cascade.members[:-1]]
        self._lock = threading.Lock()

    # -- admission ------------------------------------------------------
    def admit(self, x):
        """(alpha (n,), lane=(member, class), predicted cascade cost)."""
        alpha = np.asarray(self.cascade._alpha(jnp.asarray(x)),
                           np.float32)
        return (alpha,) + self.classify(alpha)

    def classify(self, alpha):
        """(lane, cost) for a known alpha (degrade-alpha re-admission)."""
        a = float(np.mean(alpha))
        dclass = int(DIFF.difficulty_class(a, self.edges))
        member = self.choose_member(dclass)
        return (member, dclass), self.predicted_cost(member, a, dclass)

    def classify_escalated(self, member: int, alpha):
        """Lane + cost for an escalation into ``member`` — the class is
        re-derived from the escalation-prior alpha (a sample that looked
        easy but stumped the small member IS hard traffic now)."""
        a = float(np.mean(alpha))
        dclass = int(DIFF.difficulty_class(a, self.edges))
        return (member, dclass), self.predicted_cost(member, a, dclass)

    def choose_member(self, dclass: int) -> int:
        """Cheapest member whose per-class prior predicts termination:
        walk small → large, skipping members whose observed escalation
        rate for this class exceeds the cut (admitting there would just
        pay the small model AND escalate).  Cold start is optimistic —
        the smallest member."""
        with self._lock:
            for m in range(len(self.cascade.members) - 1):
                r = self._esc_ema[m][dclass]
                if r is None or r < self.escalation_cut:
                    return m
        return len(self.cascade.members) - 1

    def predicted_cost(self, member: int, alpha_mean: float,
                       dclass: int) -> float:
        """Expected cascade MACs/sample from ``member`` on: each visited
        member's within-member predicted cost (its planner's depth
        prior) scaled to cascade units, weighted by the probability of
        reaching it (product of escalation-rate EMAs; unseen = 0)."""
        mc = self.cascade.member_costs
        cost, p_reach = 0.0, 1.0
        for m in range(member, len(mc)):
            cost += p_reach * float(mc[m]) \
                * self.members[m].predicted_cost(alpha_mean, dclass)
            if m == len(mc) - 1:
                break
            with self._lock:
                r = self._esc_ema[m][dclass]
            p_reach *= 0.0 if r is None else r
            if p_reach <= 0.0:
                break
        return float(cost)

    # -- telemetry fold -------------------------------------------------
    def observe(self, member: int, exit_idx, alpha) -> None:
        """Fold one served member-bucket into that member's depth
        priors."""
        self.members[member].observe(exit_idx, alpha)

    def observe_escalation(self, member: int, dclass: int,
                           esc_mask) -> None:
        """Fold a bucket's escalation fraction into the (member, class)
        EMA that drives ``choose_member``/``predicted_cost``."""
        r = float(np.mean(esc_mask))
        with self._lock:
            prev = self._esc_ema[member][dclass]
            self._esc_ema[member][dclass] = r if prev is None else \
                self.ema_decay * prev + (1.0 - self.ema_decay) * r

    def priors(self) -> dict:
        """Depth priors per member + escalation-rate EMAs per boundary."""
        with self._lock:
            esc = [list(row) for row in self._esc_ema]
        return {"depth": [p.priors() for p in self.members],
                "escalation": esc}

    # -- snapshot (serving-state checkpoint) ----------------------------
    def state_dict(self) -> dict:
        with self._lock:
            esc = [list(row) for row in self._esc_ema]
        return {"members": [p.state_dict() for p in self.members],
                "escalation": esc}

    def load_state_dict(self, state: dict) -> None:
        for p, s in zip(self.members, state["members"]):
            p.load_state_dict(s)
        with self._lock:
            for row, saved in zip(self._esc_ema, state["escalation"]):
                row[:] = list(saved)


class CascadeAsyncServer(AsyncDartServer):
    """The async scheduler over a :class:`CascadeEngine` — construct it
    as ``AsyncDartServer(cascade_engine, cfg)``; the façade dispatches
    here.  Same submit/close/stats surface; results additionally carry
    ``member`` (per-sample terminal member) and ``macs`` in cascade
    units (biggest member full network = 1.0)."""

    def _make_planner(self, cfg):
        return CascadePlanner(self.engine, edges=cfg.edges)

    # -- dispatch -------------------------------------------------------
    def _infer_batch(self, reqs: list, x, alpha) -> dict:
        member = reqs[0].lane[0]
        eng = self.engine.members[member]
        pad_to = eng.bucket_key(x.shape[0]) \
            if self.cfg.mode == "masked" \
            and x.shape[0] <= eng.compactor.max_bucket else None
        return self._engine_call(
            lambda cas: cas.infer_member(member, x, alpha=alpha,
                                         mode=self.cfg.mode, record=True,
                                         pad_to=pad_to))

    # -- completion -----------------------------------------------------
    def _root_buffer(self, root: Request) -> dict:
        buf = root.payload.get("buf")
        if buf is None:
            n = root.n
            buf = {"pred": np.zeros(n, np.int64),
                   "conf": np.zeros(n, np.float32),
                   "exit_idx": np.zeros(n, np.int64),
                   "member": np.zeros(n, np.int64),
                   "macs": np.zeros(n, np.float64),
                   "alpha": np.asarray(root.alpha, np.float32).copy(),
                   "remaining": n}
            root.payload["buf"] = buf
        return buf

    def _complete(self, reqs, out, t_dispatch) -> None:
        vals = {k: np.asarray(out[k]) for k in _RESULT_KEYS}
        member = reqs[0].lane[0]
        dclass = reqs[0].lane[1]
        last = len(self.engine.members) - 1
        now = self._clock()

        # elementwise escalation gate on the member's terminal decisions
        # (vals["alpha"] is what THIS member admitted under: the raw
        # Eq. 8 alpha at member 0, the escalation prior after)
        esc_all = self.engine.should_escalate(member, vals["conf"],
                                              vals["alpha"])
        macs_all = self.engine.member_macs(member, vals["exit_idx"])

        # telemetry folds BEFORE any future resolves (the documented
        # pattern: a caller woken by fut.result() finds its request
        # already in stats())
        self.planner.observe(member, vals["exit_idx"], vals["alpha"])
        if member < last:
            self.planner.observe_escalation(member, dclass, esc_all)
        self.engine.fold(member, int(esc_all.sum()),
                         float(macs_all.sum()),
                         n_admitted=sum(r.n for r in reqs
                                        if "root" not in r.payload))

        continuations, finished = [], []
        ends = np.cumsum([r.n for r in reqs])
        for r, a, z in zip(reqs, np.concatenate([[0], ends[:-1]]), ends):
            sl = {k: v[a:z] for k, v in vals.items()}
            esc = esc_all[a:z] if member < last \
                else np.zeros(r.n, bool)
            root = r.payload.get("root", r)
            idx = r.payload.get("idx")
            if idx is None:
                idx = np.arange(r.n)
            buf = self._root_buffer(root)
            buf["macs"][idx] += macs_all[a:z]
            term = ~esc
            for k in ("pred", "conf", "exit_idx"):
                buf[k][idx[term]] = sl[k][term]
            buf["member"][idx[term]] = member
            buf["remaining"] -= int(term.sum())
            if esc.any():
                new_alpha = self.engine.escalation_alpha(
                    sl["alpha"][esc], sl["conf"][esc])
                continuations.append((root, idx[esc], r.x[esc],
                                      new_alpha, member + 1))
            if buf["remaining"] == 0:
                finished.append((root, buf))

        # escalations re-enqueue into the larger member's lanes,
        # bypassing backpressure (already-admitted work)
        for root, idx_esc, x_esc, a_esc, nxt in continuations:
            lane, cost = self.planner.classify_escalated(nxt, a_esc)
            cont = Request(
                rid=next(self._rid), x=x_esc, n=x_esc.shape[0],
                alpha=a_esc, lane=lane, predicted_cost=cost,
                priority=root.priority, t_submit=root.t_submit,
                deadline_s=root.deadline_s, future=Future(),
                payload={"root": root, "idx": idx_esc})
            # nobody awaits a continuation's own future — a dispatch
            # failure must surface on the ROOT future instead
            cont.future.add_done_callback(
                self._make_root_failer(root, cont))
            self.queue.requeue(cont)
            self.counters["escalated"] = \
                self.counters.get("escalated", 0) + cont.n
        if OBS.enabled and continuations:
            OBS_A.record_escalations(member, continuations, now)

        lats, missed, resolutions = [], [], []
        for root, buf in finished:
            lat_ms = (now - root.t_submit) * 1e3
            miss = root.deadline_s is not None and now > root.deadline_s
            res = {k: buf[k] for k in ("pred", "conf", "exit_idx",
                                       "member", "alpha", "macs")}
            res.update(latency_ms=lat_ms, deadline_missed=miss,
                       predicted_cost=root.predicted_cost,
                       lane=root.lane)
            lats.append(lat_ms)
            missed.append(miss)
            # DAES keyed by (TERMINAL member, admission class): cascade-
            # total macs are attributed to the member that resolved the
            # sample (it carries the smaller members' spend with it)
            for m in np.unique(buf["member"]):
                sel = buf["member"] == m
                self.daes.observe((int(m), int(root.lane[1])),
                                  buf["conf"][sel], buf["macs"][sel],
                                  buf["alpha"][sel])
            resolutions.append((root, res))
        if lats:
            self.engine.record_requests(lats, missed)
        self.counters["completed"] += len(finished)
        if OBS.enabled and resolutions:
            OBS_A.record_completed(self, [r for r, _ in resolutions],
                                   [res for _, res in resolutions],
                                   t_dispatch, now)
        for root, res in resolutions:
            root.resolve(res)

    @staticmethod
    def _make_root_failer(root: Request, cont: Request):
        """Done-callback propagating a continuation's failure to its
        ROOT future — logged, because the root caller only sees the
        exception, not WHICH member's continuation died."""
        def fail_root(f):
            exc = f.exception()
            if exc is None:
                return
            OBS_LOG.error("cascade", "escalation continuation failed",
                          exc=exc, rid=root.rid, cont_rid=cont.rid,
                          lane=cont.lane)
            root.fail(exc)
        return fail_root

    # -- shutdown -------------------------------------------------------
    def flush(self) -> None:
        """The base flush drains the queue then materializes in-flight
        buckets — but materializing can RE-ENQUEUE escalations, so loop
        until no member has pending work (terminates: the member index
        strictly increases per escalation)."""
        while True:
            super().flush()
            if self.queue.empty and not self._inflight:
                break
