"""Functional BatchNorm with running statistics.

Running stats are stored inside the param tree (axes-tagged with the
``"_stats"`` logical axis marker on dim 0 so the optimizer can filter them
out — see ``repro.optim.trainable_mask``).  Train-mode apply returns the
EMA-updated stats; the trainer merges them back with ``merge_updates``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import Param

STATS_AXIS = "_stats"


def bn_init(dim, dtype):
    return {
        "scale": Param(jnp.ones((dim,), dtype), ("channels",)),
        "bias": Param(jnp.zeros((dim,), dtype), ("channels",)),
        "mean": Param(jnp.zeros((dim,), jnp.float32), (STATS_AXIS,)),
        "var": Param(jnp.ones((dim,), jnp.float32), (STATS_AXIS,)),
    }


def bn_apply(p, x, *, train: bool, momentum=0.9, eps=1e-5, updates=None,
             name=""):
    """x: (..., C), normalized over all leading axes.

    In train mode, batch statistics normalize and (name -> new stats) is
    appended to ``updates`` (a dict supplied by the caller)."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if train:
        axes = tuple(range(x.ndim - 1))
        mu = jnp.mean(xf, axis=axes)
        var = jnp.var(xf, axis=axes)
        if updates is not None:
            updates[name] = {
                "mean": momentum * p["mean"] + (1 - momentum) * mu,
                "var": momentum * p["var"] + (1 - momentum) * var,
            }
    else:
        mu, var = p["mean"], p["var"]
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dtype)


def merge_updates(params, updates: dict):
    """Merge {name: {"mean","var"}} back into the param tree.  Names are
    '/'-joined key paths to the BN module dict."""
    params = jax.tree.map(lambda x: x, params)  # shallow copy tree
    for name, upd in updates.items():
        node = params
        parts = name.split("/")
        for k in parts[:-1]:
            node = node[int(k)] if isinstance(node, list) else node[k]
        leaf_parent = node[int(parts[-1])] if isinstance(node, list) \
            else node[parts[-1]]
        leaf_parent["mean"] = upd["mean"]
        leaf_parent["var"] = upd["var"]
    return params
