"""Diffusion Transformer (DiT) with early-exit noise heads.

Assigned archs ``dit-s2`` / ``dit-xl2`` (Peebles & Xie, arXiv:2212.09748).
Operates in latent space: input = (B, R/8, R/8, 4) latents, patchified at
``patch``.  adaLN-Zero conditioning on (timestep, class).

DART adaptation (DESIGN.md §3): exit heads are intermediate FinalLayer
replicas predicting the noise; exit "confidence" is the *convergence* of
consecutive exit predictions (small relative residual => exit), computed
by ``repro.core.routing.diffusion_confidence``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.parallel.sharding import Param


@dataclasses.dataclass(frozen=True)
class DiTConfig:
    name: str
    img_res: int = 256                    # pixel resolution (latent = /8)
    patch: int = 2
    n_layers: int = 12
    d_model: int = 384
    n_heads: int = 6
    n_classes: int = 1000
    in_channels: int = 4                  # latent channels
    learn_sigma: bool = True
    exit_layers: tuple[int, ...] = ()
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    remat: bool = False

    @property
    def latent_res(self) -> int:
        return self.img_res // 8

    @property
    def n_tokens(self) -> int:
        return (self.latent_res // self.patch) ** 2

    @property
    def out_channels(self) -> int:
        return self.in_channels * (2 if self.learn_sigma else 1)

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    @property
    def n_exits(self) -> int:
        return len(self.exit_layers) + 1


def timestep_embedding(t, dim, max_period=10000.0):
    """(B,) int/float timesteps -> (B, dim) sinusoidal features."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period)
                    * jnp.arange(half, dtype=jnp.float32) / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


def _block_init(key, cfg: DiTConfig):
    dt = cfg.param_dtype
    return {
        "norm1": L.layernorm_init(cfg.d_model, dt),
        "attn": L.mha_init(L.rng(key, "attn"), cfg.d_model, cfg.n_heads, dt),
        "norm2": L.layernorm_init(cfg.d_model, dt),
        "mlp": L.mlp_init(L.rng(key, "mlp"), cfg.d_model, cfg.d_ff, dt),
        # adaLN-Zero: 6 modulation vectors; zero-init final projection
        "ada": {"w": Param(jnp.zeros((cfg.d_model, 6 * cfg.d_model), dt),
                           ("embed", "mlp")),
                "b": Param(jnp.zeros((6 * cfg.d_model,), dt), (None,))},
    }


def _final_layer_init(key, cfg: DiTConfig):
    dt = cfg.param_dtype
    out = cfg.patch * cfg.patch * cfg.out_channels
    return {
        "norm": L.layernorm_init(cfg.d_model, dt),
        "ada": {"w": Param(jnp.zeros((cfg.d_model, 2 * cfg.d_model), dt),
                           ("embed", "mlp")),
                "b": Param(jnp.zeros((2 * cfg.d_model,), dt), (None,))},
        "proj": {"w": Param(jnp.zeros((cfg.d_model, out), dt),
                            ("embed", None)),
                 "b": Param(jnp.zeros((out,), dt), (None,))},
    }


def dit_init(key, cfg: DiTConfig):
    dt = cfg.param_dtype
    grid = cfg.latent_res // cfg.patch
    p = {
        "patch": L.patch_embed_init(L.rng(key, "patch"), cfg.patch,
                                    cfg.in_channels, cfg.d_model, dt),
        "pos": Param(L.sincos_pos_embed_2d(grid, grid, cfg.d_model, dt),
                     ("seq", "embed")),
        "t_mlp": {
            "fc1": L.linear_init(L.rng(key, "t1"), 256, cfg.d_model, dt,
                                 axes=("embed", "mlp")),
            "fc2": L.linear_init(L.rng(key, "t2"), cfg.d_model, cfg.d_model,
                                 dt, axes=("mlp", "embed")),
        },
        "y_embed": L.embed_init(L.rng(key, "y"), cfg.n_classes + 1,
                                cfg.d_model, dt),
        "blocks": [_block_init(L.rng(key, f"b{i}"), cfg)
                   for i in range(cfg.n_layers)],
        "final": _final_layer_init(L.rng(key, "final"), cfg),
        "exit_heads": {str(i): _final_layer_init(L.rng(key, f"exit{i}"), cfg)
                       for i in cfg.exit_layers},
    }
    return p


def _modulate(x, shift, scale):
    return x * (1 + scale[:, None, :]) + shift[:, None, :]


def _block_apply(p, x, c):
    mod = L.linear(p["ada"], jax.nn.silu(c))
    s1, sc1, g1, s2, sc2, g2 = jnp.split(mod, 6, axis=-1)
    h = _modulate(L.layernorm(p["norm1"], x), s1, sc1)
    x = x + g1[:, None, :] * L.mha_apply(p["attn"], h)
    h = _modulate(L.layernorm(p["norm2"], x), s2, sc2)
    x = x + g2[:, None, :] * L.mlp(p["mlp"], h)
    return x


def _final_apply(p, x, c, cfg: DiTConfig):
    mod = L.linear(p["ada"], jax.nn.silu(c))
    s, sc = jnp.split(mod, 2, axis=-1)
    h = _modulate(L.layernorm(p["norm"], x), s, sc)
    out = L.linear(p["proj"], h)                        # (B, N, p*p*Cout)
    return unpatchify(out, cfg)


def unpatchify(x, cfg: DiTConfig):
    b, n, _ = x.shape
    g = cfg.latent_res // cfg.patch
    pch, c = cfg.patch, cfg.out_channels
    x = x.reshape(b, g, g, pch, pch, c)
    x = jnp.einsum("bhwpqc->bhpwqc", x)
    return x.reshape(b, g * pch, g * pch, c)


def conditioning(params, t, y, cfg: DiTConfig):
    te = timestep_embedding(t, 256).astype(cfg.compute_dtype)
    te = L.linear(params["t_mlp"]["fc2"],
                  jax.nn.silu(L.linear(params["t_mlp"]["fc1"], te)))
    ye = L.embed(params["y_embed"], y).astype(cfg.compute_dtype)
    return te + ye


def dit_forward(params, latents, t, y, cfg: DiTConfig, *, mesh=None,
                collect_exits=True):
    """Returns {"exit_eps": list[(B, H, W, Cout)] — one per exit + final}."""
    c = conditioning(params, t, y, cfg)
    x = L.patch_embed(params["patch"], latents.astype(cfg.compute_dtype),
                      cfg.patch)
    x = x + params["pos"].astype(cfg.compute_dtype)
    blk = jax.checkpoint(_block_apply) if cfg.remat else _block_apply
    outs = []
    for i in range(cfg.n_layers):
        x = blk(params["blocks"][i], x, c)
        if collect_exits and i in cfg.exit_layers:
            outs.append(_final_apply(params["exit_heads"][str(i)], x, c, cfg))
    outs.append(_final_apply(params["final"], x, c, cfg))
    return {"exit_eps": outs}


def dit_forward_flops(cfg: DiTConfig, batch: int) -> int:
    n, d = cfg.n_tokens, cfg.d_model
    per_block = (2 * n * d * d * 4            # qkvo
                 + 2 * 2 * n * n * d          # attention
                 + 2 * n * d * cfg.d_ff * 2   # mlp
                 + 2 * d * 6 * d)             # adaLN
    stem = 2 * n * d * (cfg.patch ** 2 * cfg.in_channels)
    fin = cfg.n_exits * (2 * n * d * cfg.patch ** 2 * cfg.out_channels
                         + 2 * d * 2 * d)
    return int(batch * (stem + cfg.n_layers * per_block + fin))


# ---------------------------------------------------------------------------
# Diffusion process (DDPM cosine schedule + DDIM sampling)
# ---------------------------------------------------------------------------

def cosine_alpha_bar(n_steps=1000, s=0.008):
    t = jnp.arange(n_steps + 1, dtype=jnp.float32) / n_steps
    f = jnp.cos((t + s) / (1 + s) * math.pi / 2) ** 2
    return f / f[0]


def diffusion_loss(params, cfg: DiTConfig, x0, y, key, *, mesh=None,
                   exit_weights=None, n_steps=1000):
    """Paper Eq. 18 adapted to diffusion: Σ_i w_i · MSE(ε, ε̂_i)."""
    b = x0.shape[0]
    abar = cosine_alpha_bar(n_steps)
    t = jax.random.randint(L.rng(key, "t"), (b,), 0, n_steps)
    eps = jax.random.normal(L.rng(key, "eps"), x0.shape, x0.dtype)
    at = abar[t][:, None, None, None]
    xt = jnp.sqrt(at) * x0 + jnp.sqrt(1 - at) * eps
    out = dit_forward(params, xt, t, y, cfg, mesh=mesh)
    n = len(out["exit_eps"])
    if exit_weights is None:
        exit_weights = [(i + 1) / n for i in range(n)]
    total = jnp.zeros((), jnp.float32)
    per_exit = []
    for w, pred in zip(exit_weights, out["exit_eps"]):
        eps_hat = pred[..., :cfg.in_channels]
        mse = jnp.mean(jnp.square(eps_hat.astype(jnp.float32)
                                  - eps.astype(jnp.float32)))
        per_exit.append(mse)
        total = total + w * mse
    return total, {"mse_per_exit": per_exit}


def ddim_step(params, cfg: DiTConfig, xt, t, t_prev, y, *, mesh=None,
              n_steps=1000, exit_select=None):
    """One DDIM update.  ``exit_select``: optional (B,) int exit indices from
    the DART policy — the engine picks which exit's ε̂ to use per sample."""
    abar = cosine_alpha_bar(n_steps)
    out = dit_forward(params, xt, t, y, cfg, mesh=mesh)
    eps_stack = jnp.stack([e[..., :cfg.in_channels]
                           for e in out["exit_eps"]])     # (E, B, H, W, C)
    if exit_select is None:
        eps_hat = eps_stack[-1]
    else:
        eps_hat = jnp.take_along_axis(
            eps_stack, exit_select[None, :, None, None, None], axis=0)[0]
    at = abar[t][:, None, None, None]
    ap = abar[t_prev][:, None, None, None]
    x0_hat = (xt - jnp.sqrt(1 - at) * eps_hat) / jnp.sqrt(at)
    return jnp.sqrt(ap) * x0_hat + jnp.sqrt(1 - ap) * eps_hat, eps_stack
