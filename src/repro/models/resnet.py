"""ResNet (bottleneck) with BranchyNet-style early exits.

Assigned arch ``resnet-152`` (depths 3-8-36-3) plus the paper's ResNet-18
testbed (basic blocks, depths 2-2-2-2).  Exits sit after each stage
(GAP -> Linear heads); staged interface for the DART serving engine.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.batchnorm import bn_init, bn_apply


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str
    depths: tuple[int, ...] = (3, 8, 36, 3)
    width: int = 64
    block: str = "bottleneck"              # "bottleneck" | "basic"
    img_res: int = 224
    n_classes: int = 1000
    in_channels: int = 3
    exit_stages: tuple[int, ...] = (0, 1, 2)   # early exits after these stages
    small_input: bool = False              # CIFAR-style stem (3x3, no pool)
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32

    @property
    def expansion(self) -> int:
        return 4 if self.block == "bottleneck" else 1

    @property
    def n_exits(self) -> int:
        return len(self.exit_stages) + 1


def _block_init(key, cin, planes, cfg, stride):
    dt = cfg.param_dtype
    e = cfg.expansion
    if cfg.block == "bottleneck":
        p = {
            "conv1": L.conv_init(L.rng(key, "c1"), 1, 1, cin, planes, dt,
                                 bias=False),
            "bn1": bn_init(planes, dt),
            "conv2": L.conv_init(L.rng(key, "c2"), 3, 3, planes, planes, dt,
                                 bias=False),
            "bn2": bn_init(planes, dt),
            "conv3": L.conv_init(L.rng(key, "c3"), 1, 1, planes, planes * e,
                                 dt, bias=False),
            "bn3": bn_init(planes * e, dt),
        }
    else:
        p = {
            "conv1": L.conv_init(L.rng(key, "c1"), 3, 3, cin, planes, dt,
                                 bias=False),
            "bn1": bn_init(planes, dt),
            "conv2": L.conv_init(L.rng(key, "c2"), 3, 3, planes, planes, dt,
                                 bias=False),
            "bn2": bn_init(planes, dt),
        }
    if stride != 1 or cin != planes * e:
        p["down_conv"] = L.conv_init(L.rng(key, "dc"), 1, 1, cin, planes * e,
                                     dt, bias=False)
        p["down_bn"] = bn_init(planes * e, dt)
    return p


def _block_apply(p, x, cfg, stride, *, train, updates, name):
    idn = x
    if cfg.block == "bottleneck":
        h = jax.nn.relu(bn_apply(p["bn1"], L.conv2d(p["conv1"], x),
                                 train=train, updates=updates,
                                 name=f"{name}/bn1"))
        h = jax.nn.relu(bn_apply(p["bn2"], L.conv2d(p["conv2"], h,
                                                    stride=stride),
                                 train=train, updates=updates,
                                 name=f"{name}/bn2"))
        h = bn_apply(p["bn3"], L.conv2d(p["conv3"], h), train=train,
                     updates=updates, name=f"{name}/bn3")
    else:
        h = jax.nn.relu(bn_apply(p["bn1"], L.conv2d(p["conv1"], x,
                                                    stride=stride),
                                 train=train, updates=updates,
                                 name=f"{name}/bn1"))
        h = bn_apply(p["bn2"], L.conv2d(p["conv2"], h), train=train,
                     updates=updates, name=f"{name}/bn2")
    if "down_conv" in p:
        idn = bn_apply(p["down_bn"], L.conv2d(p["down_conv"], x,
                                              stride=stride),
                       train=train, updates=updates, name=f"{name}/down_bn")
    return jax.nn.relu(h + idn)


def resnet_init(key, cfg: ResNetConfig):
    dt = cfg.param_dtype
    e = cfg.expansion
    stem_out = cfg.width
    if cfg.small_input:
        stem = {"conv": L.conv_init(L.rng(key, "stem"), 3, 3, cfg.in_channels,
                                    stem_out, dt, bias=False),
                "bn": bn_init(stem_out, dt)}
    else:
        stem = {"conv": L.conv_init(L.rng(key, "stem"), 7, 7, cfg.in_channels,
                                    stem_out, dt, bias=False),
                "bn": bn_init(stem_out, dt)}
    stages = []
    cin = stem_out
    for s, depth in enumerate(cfg.depths):
        planes = cfg.width * (2 ** s)
        blocks = []
        for b in range(depth):
            stride = 2 if (b == 0 and s > 0) else 1
            blocks.append(_block_init(L.rng(key, f"s{s}b{b}"), cin, planes,
                                      cfg, stride))
            cin = planes * e
        stages.append(blocks)
    heads = {}
    for s in cfg.exit_stages:
        cdim = cfg.width * (2 ** s) * e
        heads[str(s)] = L.linear_init(L.rng(key, f"exit{s}"), cdim,
                                      cfg.n_classes, dt,
                                      axes=("embed", "classes"))
    return {
        "stem": stem,
        "stages": stages,
        "head": L.linear_init(L.rng(key, "head"),
                              cfg.width * (2 ** (len(cfg.depths) - 1)) * e,
                              cfg.n_classes, dt, axes=("embed", "classes")),
        "exit_heads": heads,
    }


# -- staged interface -------------------------------------------------------

def apply_stem(params, images, cfg: ResNetConfig, *, train=False,
               updates=None):
    x = images.astype(cfg.compute_dtype)
    stride = 1 if cfg.small_input else 2
    x = jax.nn.relu(bn_apply(params["stem"]["bn"],
                             L.conv2d(params["stem"]["conv"], x,
                                      stride=stride),
                             train=train, updates=updates, name="stem/bn"))
    if not cfg.small_input:
        x = L.max_pool(x, 3, 2)
    return x


def apply_stage(params, x, stage: int, cfg: ResNetConfig, *, train=False,
                updates=None):
    for b, bp in enumerate(params["stages"][stage]):
        stride = 2 if (b == 0 and stage > 0) else 1
        x = _block_apply(bp, x, cfg, stride, train=train, updates=updates,
                         name=f"stages/{stage}/{b}")
    return x


def apply_exit(params, x, stage: int, cfg: ResNetConfig):
    h = L.global_avg_pool(x)
    if stage == len(cfg.depths) - 1:
        return L.linear(params["head"], h)
    return L.linear(params["exit_heads"][str(stage)], h)


def num_stages(cfg: ResNetConfig) -> int:
    return len(cfg.depths)


def resnet_forward(params, images, cfg: ResNetConfig, *, mesh=None,
                   train=False):
    updates: dict = {}
    x = apply_stem(params, images, cfg, train=train, updates=updates)
    logits = []
    for s in range(num_stages(cfg)):
        x = apply_stage(params, x, s, cfg, train=train, updates=updates)
        if s in cfg.exit_stages or s == num_stages(cfg) - 1:
            logits.append(apply_exit(params, x, s, cfg))
    return {"exit_logits": jnp.stack(logits), "bn_updates": updates}


def resnet_forward_flops(cfg: ResNetConfig, batch: int) -> int:
    """Analytic conv MACs*2 (approximate: ignores bias/norm)."""
    res = cfg.img_res // (1 if cfg.small_input else 4)
    fl = 0
    cin = cfg.width
    stem_res = cfg.img_res // (1 if cfg.small_input else 2)
    fl += 2 * (7 * 7 if not cfg.small_input else 9) * cfg.in_channels \
        * cfg.width * stem_res * stem_res
    e = cfg.expansion
    for s, depth in enumerate(cfg.depths):
        planes = cfg.width * (2 ** s)
        if s > 0:
            res //= 2
        for b in range(depth):
            c_in = cin if b == 0 else planes * e
            if cfg.block == "bottleneck":
                fl += 2 * res * res * (c_in * planes + 9 * planes * planes
                                       + planes * planes * e)
                if b == 0:
                    fl += 2 * res * res * c_in * planes * e
            else:
                fl += 2 * res * res * (9 * c_in * planes
                                       + 9 * planes * planes)
                if b == 0 and s > 0:
                    fl += 2 * res * res * c_in * planes
        cin = planes * e
    return int(batch * fl)
